"""Comm-chunnel tests: collective transports agree with psum; flash-decode
combine agrees with the local oracle; compression round-trips."""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.comm import chunnels, compress, kvshard
from repro.comm import collectives as C


@pytest.fixture(scope="module")
def mesh():
    return compat.make_mesh((2, 4), ("pod", "data"),
                            axis_types=(compat.AUTO,) * 2)


def tree_of(key, sizes=((17,), (3, 5), (64,))):
    ks = jax.random.split(key, len(sizes))
    return {f"p{i}": jax.random.normal(k, s) for i, (k, s) in enumerate(zip(ks, sizes))}


def run_manual(mesh, axes, fn, *args):
    # partial-manual shard_map composes with the auto partitioner, so it must
    # run under jit (as it always does in the real step functions)
    f = compat.shard_map(fn, mesh=mesh, in_specs=P(), out_specs=P(),
                         check_vma=False, axis_names=set(axes))
    return jax.jit(f)(*args)


class TestCollectives:
    def test_ring_equals_psum(self, mesh):
        t = tree_of(jax.random.PRNGKey(0))
        ref = run_manual(mesh, {"pod"}, lambda x: C.psum_tree(x, "pod"), t)
        out = run_manual(mesh, {"pod"}, lambda x: C.ring_tree(x, "pod"), t)
        jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b, rtol=1e-5), ref, out)

    def test_ring_lowers_to_collective_permute(self, mesh):
        t = tree_of(jax.random.PRNGKey(0))
        f = jax.jit(lambda x: run_manual(mesh, {"pod"}, lambda y: C.ring_tree(y, "pod"), x))
        txt = f.lower(t).compile().as_text()
        assert "collective-permute" in txt
        assert txt.count("all-reduce") == 0  # truly manual schedule

    def test_hierarchical_equals_psum(self, mesh):
        t = tree_of(jax.random.PRNGKey(1))
        ref = run_manual(mesh, {"pod", "data"},
                         lambda x: C.psum_tree(C.psum_tree(x, "pod"), "data"), t)
        out = run_manual(mesh, {"pod", "data"},
                         lambda x: C.hierarchical_tree(x, "data", "pod"), t)
        jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b, rtol=1e-5), ref, out)

    def test_hierarchical_schedule_ops(self, mesh):
        t = tree_of(jax.random.PRNGKey(1))
        f = jax.jit(lambda x: run_manual(
            mesh, {"pod", "data"}, lambda y: C.hierarchical_tree(y, "data", "pod"), x))
        txt = f.lower(t).compile().as_text()
        assert "reduce-scatter" in txt and "all-gather" in txt

    def test_compressed_close_to_psum(self, mesh):
        t = tree_of(jax.random.PRNGKey(2))
        ref = run_manual(mesh, {"pod"}, lambda x: C.psum_tree(x, "pod"), t)
        out = run_manual(mesh, {"pod"}, lambda x: C.compressed_tree(x, "pod", block=32), t)
        # int8 wire: 1/127 relative error per element bound
        jax.tree.map(
            lambda a, b: np.testing.assert_allclose(a, b, atol=2 * 4 / 127 * np.abs(a).max()),
            ref, out)

    def test_hier_compressed_close_to_psum(self, mesh):
        t = tree_of(jax.random.PRNGKey(3))
        ref = run_manual(mesh, {"pod", "data"},
                         lambda x: C.psum_tree(C.psum_tree(x, "pod"), "data"), t)
        out = run_manual(mesh, {"pod", "data"},
                         lambda x: C.hierarchical_compressed_tree(x, "data", "pod", block=32), t)
        jax.tree.map(
            lambda a, b: np.testing.assert_allclose(a, b, atol=4e-1 * max(1.0, np.abs(a).max())),
            ref, out)


class TestCompression:
    @pytest.mark.parametrize("shape", [(100,), (17, 3), (256,), (1, 1)])
    @pytest.mark.parametrize("block", [16, 256])
    def test_roundtrip_error_bound(self, shape, block):
        x = jax.random.normal(jax.random.PRNGKey(0), shape) * 3.0
        q, s = compress.quantize_int8(x, block=block)
        y = compress.dequantize_int8(q, s, shape, block=block)
        per_block_max = np.abs(np.asarray(x)).max()
        assert np.abs(np.asarray(x - y)).max() <= per_block_max / 127.0 + 1e-6

    def test_error_feedback_reduces_bias(self):
        # with EF, the *accumulated* transmitted signal tracks the true signal
        x = jax.random.normal(jax.random.PRNGKey(1), (512,)) * 0.01
        resid = jnp.zeros_like(x)
        sent_sum = jnp.zeros_like(x)
        for _ in range(20):
            g = x + resid
            q, s = compress.quantize_int8(g, block=64)
            dq = compress.dequantize_int8(q, s, g.shape, block=64)
            sent_sum = sent_sum + dq
            resid = g - dq
        drift = np.abs(np.asarray(sent_sum - 20 * x)).max()
        assert drift <= np.abs(np.asarray(x)).max() + 1e-5  # bounded by one quantum


class TestGradChunnels:
    def test_transports_numerically_equivalent(self, mesh):
        t = tree_of(jax.random.PRNGKey(4))
        ctx = {"mesh": mesh}
        ref = None
        for name in ("psum", "ring", "hierarchical"):
            ch = chunnels.make_transport(
                name, **({"fast_axis": "data", "slow_axis": "pod"}
                         if name == "hierarchical" else {"axis": "pod"}))
            st = ch.init_state(jax.eval_shape(lambda: t))
            out, _ = run_manual(mesh, set(ch.manual_axes) or {"pod"},
                                lambda x: ch.apply(x, st, ctx), t)
            if name == "hierarchical":
                # hierarchical normalizes by pod*data; compare against double pmean
                ref2 = run_manual(mesh, {"pod", "data"},
                                  lambda x: C.pmean_tree(C.pmean_tree(x, "pod"), "data"), t)
                jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b, rtol=1e-5),
                             ref2, out)
                continue
            if ref is None:
                ref = out
            else:
                jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b, rtol=1e-5),
                             ref, out)

    def test_localsgd_syncs_on_schedule(self, mesh):
        ch = chunnels.GradLocalSGD(axis="pod", sync_every=2)
        t = tree_of(jax.random.PRNGKey(5))
        ctx = {"mesh": mesh}
        st = ch.init_state(None)
        out1, st = run_manual(mesh, {"pod"}, lambda x: ch.apply(x, st, ctx), t)
        jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b), t, out1)  # no sync
        out2, st = run_manual(mesh, {"pod"}, lambda x: ch.apply(x, st, ctx), t)
        ref = run_manual(mesh, {"pod"}, lambda x: C.pmean_tree(x, "pod"), t)
        jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b, rtol=1e-5), ref, out2)


class TestFlashDecode:
    @pytest.mark.parametrize("kv_heads,S,B,H", [(2, 64, 2, 4), (1, 32, 3, 5)])
    def test_seq_sharded_matches_local(self, mesh, kv_heads, S, B, H):
        from repro.models.attention import decode_attention_local

        hd = 16
        rng = jax.random.PRNGKey(0)
        ks = jax.random.split(rng, 4)
        q = jax.random.normal(ks[0], (B, 1, H, hd), jnp.float32)
        kc = jax.random.normal(ks[1], (B, S, kv_heads, hd), jnp.float32)
        vc = jax.random.normal(ks[2], (B, S, kv_heads, hd), jnp.float32)
        kv_len = S - 3

        ref = decode_attention_local(q, kc, vc, kv_len)
        # shard sequence over the 4-way 'data' axis of the test mesh
        attn_fn = kvshard.make_seq_sharded_decode(mesh, axis="data")
        out = jax.jit(lambda *a: attn_fn(*a))(q, kc, vc, kv_len, None)
        np.testing.assert_allclose(np.asarray(ref, np.float32),
                                   np.asarray(out, np.float32), atol=2e-2, rtol=2e-2)
