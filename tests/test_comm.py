"""Comm-chunnel tests: collective transports agree with psum; flash-decode
combine agrees with the local oracle; compression round-trips."""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.comm import chunnels, compress, kvshard
from repro.comm import collectives as C


@pytest.fixture(scope="module")
def mesh():
    return compat.make_mesh((2, 4), ("pod", "data"),
                            axis_types=(compat.AUTO,) * 2)


def tree_of(key, sizes=((17,), (3, 5), (64,))):
    ks = jax.random.split(key, len(sizes))
    return {f"p{i}": jax.random.normal(k, s) for i, (k, s) in enumerate(zip(ks, sizes))}


def run_manual(mesh, axes, fn, *args):
    # partial-manual shard_map composes with the auto partitioner, so it must
    # run under jit (as it always does in the real step functions)
    f = compat.shard_map(fn, mesh=mesh, in_specs=P(), out_specs=P(),
                         check_vma=False, axis_names=set(axes))
    return jax.jit(f)(*args)


class TestCollectives:
    def test_ring_equals_psum(self, mesh):
        t = tree_of(jax.random.PRNGKey(0))
        ref = run_manual(mesh, {"pod"}, lambda x: C.psum_tree(x, "pod"), t)
        out = run_manual(mesh, {"pod"}, lambda x: C.ring_tree(x, "pod"), t)
        jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b, rtol=1e-5), ref, out)

    def test_ring_lowers_to_collective_permute(self, mesh):
        t = tree_of(jax.random.PRNGKey(0))
        f = jax.jit(lambda x: run_manual(mesh, {"pod"}, lambda y: C.ring_tree(y, "pod"), x))
        txt = f.lower(t).compile().as_text()
        assert "collective-permute" in txt
        assert txt.count("all-reduce") == 0  # truly manual schedule

    def test_hierarchical_equals_psum(self, mesh):
        t = tree_of(jax.random.PRNGKey(1))
        ref = run_manual(mesh, {"pod", "data"},
                         lambda x: C.psum_tree(C.psum_tree(x, "pod"), "data"), t)
        out = run_manual(mesh, {"pod", "data"},
                         lambda x: C.hierarchical_tree(x, "data", "pod"), t)
        jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b, rtol=1e-5), ref, out)

    def test_hierarchical_schedule_ops(self, mesh):
        t = tree_of(jax.random.PRNGKey(1))
        f = jax.jit(lambda x: run_manual(
            mesh, {"pod", "data"}, lambda y: C.hierarchical_tree(y, "data", "pod"), x))
        txt = f.lower(t).compile().as_text()
        assert "reduce-scatter" in txt and "all-gather" in txt

    def test_compressed_close_to_psum(self, mesh):
        t = tree_of(jax.random.PRNGKey(2))
        ref = run_manual(mesh, {"pod"}, lambda x: C.psum_tree(x, "pod"), t)
        out = run_manual(mesh, {"pod"}, lambda x: C.compressed_tree(x, "pod", block=32), t)
        # int8 wire: 1/127 relative error per element bound
        jax.tree.map(
            lambda a, b: np.testing.assert_allclose(a, b, atol=2 * 4 / 127 * np.abs(a).max()),
            ref, out)

    def test_hier_compressed_close_to_psum(self, mesh):
        t = tree_of(jax.random.PRNGKey(3))
        ref = run_manual(mesh, {"pod", "data"},
                         lambda x: C.psum_tree(C.psum_tree(x, "pod"), "data"), t)
        out = run_manual(mesh, {"pod", "data"},
                         lambda x: C.hierarchical_compressed_tree(x, "data", "pod", block=32), t)
        jax.tree.map(
            lambda a, b: np.testing.assert_allclose(a, b, atol=4e-1 * max(1.0, np.abs(a).max())),
            ref, out)


class TestCompression:
    @pytest.mark.parametrize("shape", [(100,), (17, 3), (256,), (1, 1)])
    @pytest.mark.parametrize("block", [16, 256])
    def test_roundtrip_error_bound(self, shape, block):
        x = jax.random.normal(jax.random.PRNGKey(0), shape) * 3.0
        q, s = compress.quantize_int8(x, block=block)
        y = compress.dequantize_int8(q, s, shape, block=block)
        per_block_max = np.abs(np.asarray(x)).max()
        assert np.abs(np.asarray(x - y)).max() <= per_block_max / 127.0 + 1e-6

    def test_error_feedback_reduces_bias(self):
        # with EF, the *accumulated* transmitted signal tracks the true signal
        x = jax.random.normal(jax.random.PRNGKey(1), (512,)) * 0.01
        resid = jnp.zeros_like(x)
        sent_sum = jnp.zeros_like(x)
        for _ in range(20):
            g = x + resid
            q, s = compress.quantize_int8(g, block=64)
            dq = compress.dequantize_int8(q, s, g.shape, block=64)
            sent_sum = sent_sum + dq
            resid = g - dq
        drift = np.abs(np.asarray(sent_sum - 20 * x)).max()
        assert drift <= np.abs(np.asarray(x)).max() + 1e-5  # bounded by one quantum


class TestGradChunnels:
    def test_transports_numerically_equivalent(self, mesh):
        t = tree_of(jax.random.PRNGKey(4))
        ctx = {"mesh": mesh}
        ref = None
        for name in ("psum", "ring", "hierarchical"):
            ch = chunnels.make_transport(
                name, **({"fast_axis": "data", "slow_axis": "pod"}
                         if name == "hierarchical" else {"axis": "pod"}))
            st = ch.init_state(jax.eval_shape(lambda: t))
            out, _ = run_manual(mesh, set(ch.manual_axes) or {"pod"},
                                lambda x: ch.apply(x, st, ctx), t)
            if name == "hierarchical":
                # hierarchical normalizes by pod*data; compare against double pmean
                ref2 = run_manual(mesh, {"pod", "data"},
                                  lambda x: C.pmean_tree(C.pmean_tree(x, "pod"), "data"), t)
                jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b, rtol=1e-5),
                             ref2, out)
                continue
            if ref is None:
                ref = out
            else:
                jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b, rtol=1e-5),
                             ref, out)

    def test_localsgd_syncs_on_schedule(self, mesh):
        ch = chunnels.GradLocalSGD(axis="pod", sync_every=2)
        t = tree_of(jax.random.PRNGKey(5))
        ctx = {"mesh": mesh}
        st = ch.init_state(None)
        out1, st = run_manual(mesh, {"pod"}, lambda x: ch.apply(x, st, ctx), t)
        jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b), t, out1)  # no sync
        out2, st = run_manual(mesh, {"pod"}, lambda x: ch.apply(x, st, ctx), t)
        ref = run_manual(mesh, {"pod"}, lambda x: C.pmean_tree(x, "pod"), t)
        jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b, rtol=1e-5), ref, out2)


class TestFlashDecode:
    @pytest.mark.parametrize("kv_heads,S,B,H", [(2, 64, 2, 4), (1, 32, 3, 5)])
    def test_seq_sharded_matches_local(self, mesh, kv_heads, S, B, H):
        from repro.models.attention import decode_attention_local

        hd = 16
        rng = jax.random.PRNGKey(0)
        ks = jax.random.split(rng, 4)
        q = jax.random.normal(ks[0], (B, 1, H, hd), jnp.float32)
        kc = jax.random.normal(ks[1], (B, S, kv_heads, hd), jnp.float32)
        vc = jax.random.normal(ks[2], (B, S, kv_heads, hd), jnp.float32)
        kv_len = S - 3

        ref = decode_attention_local(q, kc, vc, kv_len)
        # shard sequence over the 4-way 'data' axis of the test mesh
        attn_fn = kvshard.make_seq_sharded_decode(mesh, axis="data")
        out = jax.jit(lambda *a: attn_fn(*a))(q, kc, vc, kv_len, None)
        np.testing.assert_allclose(np.asarray(ref, np.float32),
                                   np.asarray(out, np.float32), atol=2e-2, rtol=2e-2)


# ---------------------------------------------------------------------------
# Batched datapath contract + fused compressed wire path (docs §8)
# ---------------------------------------------------------------------------


def _fabric_pair(fabric=None, chunnel=None, chunnel_rx=None):
    """A connected (tx, rx) datapath pair over a fresh loopback fabric,
    optionally wrapped by a chunnel on each side."""
    from repro.core.fabric import Fabric
    from repro.core.runtime import FabricTransport

    fab = fabric or Fabric()
    a = fab.register("pair-a")
    b = fab.register("pair-b")
    tx = FabricTransport(a, "pair-b").connect_wrap(None)
    rx = FabricTransport(b, "pair-a").connect_wrap(None)
    if chunnel is not None:
        tx = chunnel.connect_wrap(tx)
        rx = (chunnel_rx or chunnel).connect_wrap(rx)
    return tx, rx


def _drain(rx, n_expected, timeout=2.0):
    import time as _t

    buf = [None] * max(n_expected, 1)
    got = []
    deadline = _t.monotonic() + timeout
    while len(got) < n_expected and _t.monotonic() < deadline:
        n = rx.recv(buf, timeout=0.1)
        got.extend(buf[:n])
    return got


class TestBatchedDatapathContract:
    """Every shipped host chunnel's send(msgs)/recv(buf) preserves order,
    count, and content for batch sizes 0/1/odd/large."""

    BATCHES = [0, 1, 3, 7, 64, 257]

    @pytest.mark.parametrize("n", BATCHES)
    def test_fabric_transport(self, n):
        tx, rx = _fabric_pair()
        msgs = [f"m{i}".encode() for i in range(n)]
        tx.send(msgs)
        got = _drain(rx, n)
        assert got == msgs

    @pytest.mark.parametrize("n", BATCHES)
    def test_fn_chunnel_per_message_adapter(self, n):
        from repro.core.chunnel import FnChunnel

        ch = FnChunnel(fn_name="Rev",
                       on_send=lambda m: m[::-1], on_recv=lambda m: m[::-1])
        tx, rx = _fabric_pair(chunnel=ch)
        msgs = [f"msg-{i}".encode() for i in range(n)]
        tx.send(msgs)
        got = _drain(rx, n)
        assert got == msgs

    @pytest.mark.parametrize("n", BATCHES)
    def test_fn_chunnel_batch_transform(self, n):
        from repro.core.chunnel import FnChunnel

        seen_batches = []

        def send_batch(msgs):
            seen_batches.append(len(msgs))
            return [m + b"!" for m in msgs]

        ch = FnChunnel(fn_name="Batch", on_send_batch=send_batch,
                       on_recv_batch=lambda msgs: [m[:-1] for m in msgs])
        tx, rx = _fabric_pair(chunnel=ch)
        msgs = [f"b{i}".encode() for i in range(n)]
        tx.send(msgs)
        got = _drain(rx, n)
        assert got == msgs
        # the whole batch went through ONE transform call
        assert seen_batches == [n]

    @pytest.mark.parametrize("n", [0, 1, 3, 64])
    def test_compress_wire_chunnel(self, n):
        from repro.comm.wire import CompressChunnel

        ch = CompressChunnel(block=64, use_kernel=True, chunk_bytes=256)
        tx, rx = _fabric_pair(chunnel=ch)
        rng = np.random.default_rng(n)
        msgs = [rng.standard_normal(17 + i).astype(np.float32) for i in range(n)]
        tx.send(msgs)
        got = _drain(rx, n)
        assert len(got) == n
        for a, b in zip(msgs, got):
            assert a.shape == b.shape
            amax = np.abs(a).max(initial=0.0)
            np.testing.assert_allclose(a, b, atol=amax / 100.0 + 1e-6)

    @pytest.mark.parametrize("n", [1, 7, 64])
    def test_routed_batch_is_one_inner_send(self, n):
        from repro.core.chunnel import Datapath
        from repro.serving.router import ClientShardChunnel

        calls = []

        class Sink(Datapath):
            def send(self, msgs):
                calls.append(list(msgs))

            def recv(self, buf, timeout=None):
                return 0

        ch = ClientShardChunnel(backends=("s0", "s1", "s2"))
        dp = ch.connect_wrap(Sink())
        dp.send([{"key": f"k{i}"} for i in range(n)])
        assert len(calls) == 1 and len(calls[0]) == n
        assert all("_route_to" in m for m in calls[0])


class TestFusedWire:
    """The fused Pallas wire path (use_kernel=True) is byte- and
    numerically-equal to the jnp oracle in interpret mode."""

    @pytest.mark.parametrize("block", [64, 256])
    def test_kernel_oracle_byte_equality(self, block):
        from repro.comm import wire

        rng = np.random.default_rng(0)
        msgs = [rng.standard_normal(s).astype(np.float32) * 3.0
                for s in [(33,), (8, 9), (301,)]]
        fk = wire.encode_batch(msgs, block=block, use_kernel=True)
        fo = wire.encode_batch(msgs, block=block, use_kernel=False)
        assert b"".join(f["data"] for f in fk) == b"".join(f["data"] for f in fo)

    def test_kernel_oracle_decode_equality(self):
        from repro.comm import wire

        rng = np.random.default_rng(1)
        msgs = [rng.standard_normal(129).astype(np.float32)]
        frames = wire.encode_batch(msgs, block=64, use_kernel=True)
        payload = b"".join(f["data"] for f in frames)
        hdr = frames[0]["hdr"]
        via_kernel = wire.decode_blob(payload, hdr, use_kernel=True)
        via_oracle = wire.decode_blob(payload, hdr, use_kernel=False)
        for a, b in zip(via_kernel, via_oracle):
            np.testing.assert_array_equal(a, b)

    def test_one_device_call_per_batch(self):
        from repro.comm import wire

        rng = np.random.default_rng(2)
        msgs = [rng.standard_normal(64).astype(np.float32) for _ in range(32)]
        frames = wire.encode_batch(msgs, block=64, use_kernel=False)
        # one blob for the whole batch (chunked only by size), one header
        ids = {f["_wire"][0] for f in frames}
        assert len(ids) == 1
        assert sum(f["hdr"] is not None for f in frames) == 1

    def test_chunked_reassembly_over_fabric(self):
        from repro.comm.wire import CompressChunnel

        ch = CompressChunnel(block=64, chunk_bytes=128)  # force many chunks
        tx, rx = _fabric_pair(chunnel=ch)
        rng = np.random.default_rng(3)
        msgs = [rng.standard_normal(500).astype(np.float32)]
        tx.send(msgs)
        got = _drain(rx, 1)
        assert len(got) == 1 and got[0].shape == (500,)
