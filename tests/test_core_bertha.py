"""Unit + integration tests for the Bertha core (stacks, negotiation,
reconfiguration, rendezvous)."""
import threading
import time

import pytest

from repro.core import (
    BarrierConn,
    Capability,
    CapabilitySet,
    Fabric,
    FabricTransport,
    FnChunnel,
    HostAgent,
    KVStore,
    LinkModel,
    LockedConn,
    NegotiationError,
    Select,
    Stack,
    StackTypeError,
    WireType,
    make_stack,
)
from repro.core import rendezvous


def T(name, upper, lower, caps=None, multilateral=False):
    return FnChunnel(
        fn_name=name,
        upper=WireType.of(upper),
        lower=WireType.of(lower),
        caps=caps,
        multilateral_=multilateral,
    )


class TestStackTyping:
    def test_compose_ok(self):
        s = make_stack(T("Ser", "obj", "bytes"), T("Udp", "bytes", "unit"))
        assert len(s.preferred()) == 2

    def test_type_mismatch_rejected_at_assembly(self):
        with pytest.raises(StackTypeError):
            make_stack(T("Ser", "obj", "bytes"), T("Tcp", "string", "unit"))

    def test_select_filters_ill_typed_branches(self):
        s = make_stack(
            T("Ser", "obj", "bytes"),
            Select(T("Bad", "string", "unit"), T("Udp", "bytes", "unit")),
        )
        opts = s.options()
        assert len(opts) == 1 and opts[0].chunnels[1].name == "Udp"

    def test_select_preference_order(self):
        s = make_stack(Select(T("A", "bytes", "unit"), T("B", "bytes", "unit")))
        assert [o.chunnels[0].name for o in s.options()] == ["A", "B"]

    def test_nested_select(self):
        s = make_stack(
            Select(
                T("PSP", "bytes", "unit"),
                Select(T("QUIC", "bytes", "unit"),
                       (T("TLS", "bytes", "bytes"), T("TCP", "bytes", "unit"))),
            )
        )
        names = [" ".join(c.name for c in o) for o in s.options()]
        assert names == ["PSP", "QUIC", "TLS TCP"]  # paper §7.1 example

    def test_composition_not_commutative(self):
        a, b = T("A", "x", "x"), T("B", "x", "x")
        assert make_stack(a, b).preferred().fingerprint() != make_stack(
            b, a).preferred().fingerprint()


class TestCapabilities:
    def test_exact_must_match_both(self):
        a = CapabilitySet.exact("ser:protobuf")
        b = CapabilitySet.exact("ser:protobuf")
        c = CapabilitySet.exact("ser:capnproto")
        assert a.compatible_with(b)
        assert not a.compatible_with(c)

    def test_compose_one_side_suffices(self):
        a = CapabilitySet.exact("ser:pb").union_(CapabilitySet.compose("shard"))
        b = CapabilitySet.exact("ser:pb")
        assert a.compatible_with(b) and b.compatible_with(a)

    def test_relative_compat_reuse_label(self):
        # ProtoACC reuses the protobuf capability label (paper §5.2)
        sw = CapabilitySet.exact("ser:protobuf")
        hw = CapabilitySet.exact("ser:protobuf")  # different impl, same label
        assert sw.compatible_with(hw)


def _mk_pair(fabric, caps_client=None, caps_server=None, server_first=True):
    server = HostAgent(fabric, "srv")
    client = HostAgent(fabric, "cli")
    return server, client


class TestNegotiation:
    def test_one_rtt_negotiation(self):
        fabric = Fabric()
        server, client = _mk_pair(fabric)
        sstack = make_stack(
            Select(
                T("Kafka", "obj", "unit", CapabilitySet.exact("pubsub:kafka")),
                T("SQS", "obj", "unit", CapabilitySet.exact("pubsub:sqs")),
            )
        )
        cstack = make_stack(T("SQS", "obj", "unit", CapabilitySet.exact("pubsub:sqs")))
        server.listen(sstack)
        conn = client.connect("srv", cstack)
        assert conn.stack.chunnels[0].name == "SQS"
        assert server.accept_stack("cli").chunnels[0].name == "SQS"
        server.close(); client.close()

    def test_incompatible_rejected(self):
        fabric = Fabric()
        server, client = _mk_pair(fabric)
        server.listen(make_stack(T("A", "obj", "unit", CapabilitySet.exact("fmt:a"))))
        with pytest.raises(NegotiationError):
            client.connect("srv", make_stack(T("B", "obj", "unit",
                                               CapabilitySet.exact("fmt:b"))))
        server.close(); client.close()

    def test_negotiation_over_lossy_base_connection(self):
        fabric = Fabric(default_link=LinkModel(latency_s=0.001, loss=0.3), seed=7)
        server, client = _mk_pair(fabric)
        st = make_stack(T("X", "obj", "unit", CapabilitySet.exact("x")))
        server.listen(st)
        conn = client.connect("srv", st)  # reliability layer must recover
        assert conn.stack.chunnels[0].name == "X"
        server.close(); client.close()

    def test_zero_rtt_resumption(self):
        fabric = Fabric()
        server, client = _mk_pair(fabric)
        st = make_stack(T("X", "obj", "unit", CapabilitySet.exact("x")))
        server.listen(st)
        c1 = client.connect("srv", st, use_zero_rtt=True)
        assert not c1.was_zero_rtt  # first connection pays the RTT
        c2 = client.connect("srv", st, use_zero_rtt=True)
        assert c2.was_zero_rtt
        assert c2.stack.fingerprint() == c1.stack.fingerprint()
        server.close(); client.close()

    def test_server_preference_wins(self):
        fabric = Fabric()
        server, client = _mk_pair(fabric)
        ka = T("Kafka", "obj", "unit", CapabilitySet.exact("pubsub:kafka"))
        sq = T("SQS", "obj", "unit", CapabilitySet.exact("pubsub:sqs"))
        server.listen(make_stack(Select(ka, sq)))
        conn = client.connect("srv", make_stack(Select(sq, ka)))
        # server prefers kafka; client offered both; server preference rules
        assert conn.stack.chunnels[0].name == "Kafka"
        server.close(); client.close()


class _CountingChunnel(FnChunnel):
    pass


def _counting(name):
    calls = {"n": 0}

    def on_send(m):
        calls["n"] += 1
        return m

    ch = FnChunnel(fn_name=name, on_send=on_send)
    return ch, calls


class TestReconfiguration:
    def _echo_stack(self, fabric, name="A"):
        ep = fabric.register(f"ep-{name}-{time.monotonic_ns()}")
        ch, calls = _counting(name)
        st = make_stack(ch, FabricTransport(ep, "nowhere"))
        return st, calls

    @pytest.mark.parametrize("cls", [LockedConn, BarrierConn])
    def test_unilateral_swap_preserves_service(self, cls):
        fabric = Fabric()
        st_a, calls_a = self._echo_stack(fabric, "A")
        st_b, calls_b = self._echo_stack(fabric, "B")
        handle = cls(st_a.preferred()) if cls is LockedConn else cls(
            st_a.preferred(), n_threads=1)

        stop = threading.Event()
        sent = {"n": 0}

        def pump():
            while not stop.is_set():
                handle.send([b"x"])
                sent["n"] += 1

        t = threading.Thread(target=pump)
        t.start()
        time.sleep(0.05)
        ok = handle.reconfigure(st_b.preferred())
        time.sleep(0.05)
        stop.set(); t.join()
        assert ok
        assert calls_a["n"] > 0 and calls_b["n"] > 0  # traffic on both impls
        assert handle.stats.switches == 1
        assert sent["n"] == calls_a["n"] + calls_b["n"]  # nothing lost/duplicated

    def test_coordinate_false_aborts(self):
        fabric = Fabric()
        st_a, _ = self._echo_stack(fabric, "A")
        st_b, _ = self._echo_stack(fabric, "B")
        handle = LockedConn(st_a.preferred())
        assert handle.reconfigure(st_b.preferred(), coordinate=lambda: False) is False
        assert handle.stack.chunnels[0].name == "A"


class TestRendezvous:
    def test_first_proposer_wins_cas(self):
        store = KVStore()
        r1 = rendezvous.join(store, "conn", "m1", ["fpA"], [[{"name": "A", "caps": []}]],
                             lambda desc: 0)
        assert r1.proposed and r1.stack_fp == "fpA"
        r2 = rendezvous.join(store, "conn", "m2", ["fpB", "fpA"],
                             [[{"name": "B", "caps": []}], [{"name": "A", "caps": []}]],
                             lambda desc: 1)
        assert not r2.proposed and r2.stack_fp == "fpA" and r2.participants == 2

    def test_incompatible_joiner_raises(self):
        store = KVStore()
        rendezvous.join(store, "conn", "m1", ["fpA"], [[{"name": "A", "caps": []}]],
                        lambda desc: 0)
        with pytest.raises(ValueError):
            rendezvous.join(store, "conn", "m2", ["fpB"], [[{"name": "B", "caps": []}]],
                            lambda desc: None)

    def test_late_joiner_recovers_stack(self):
        store = KVStore()
        rendezvous.join(store, "conn", "m1", ["fpA"], [[{"name": "A", "caps": []}]],
                        lambda desc: 0)
        cur = rendezvous.current_stack(store, "conn")
        assert cur["fp"] == "fpA" and cur["epoch"] == 1

    def test_transition_commits_when_all_ack(self):
        store = KVStore()
        for m in ("m1", "m2", "m3"):
            rendezvous.join(store, "conn", m, ["fpA"], [[{"name": "A", "caps": []}]],
                            lambda desc: 0)
        epoch = rendezvous.propose_transition(store, "conn", "m1", "fpB",
                                              [{"name": "B", "caps": []}])
        assert rendezvous.try_commit(store, "conn", epoch, 5.0) is None  # pending
        rendezvous.vote(store, "conn", "m2", epoch, True)
        rendezvous.vote(store, "conn", "m3", epoch, True)
        assert rendezvous.try_commit(store, "conn", epoch, 5.0) is True
        assert rendezvous.current_stack(store, "conn")["fp"] == "fpB"

    def test_any_refusal_aborts(self):
        store = KVStore()
        for m in ("m1", "m2"):
            rendezvous.join(store, "conn", m, ["fpA"], [[{"name": "A", "caps": []}]],
                            lambda desc: 0)
        epoch = rendezvous.propose_transition(store, "conn", "m1", "fpB", [])
        rendezvous.vote(store, "conn", "m2", epoch, False)
        assert rendezvous.try_commit(store, "conn", epoch, 5.0) is False
        assert rendezvous.current_stack(store, "conn")["fp"] == "fpA"

    def test_timeout_aborts(self):
        store = KVStore()
        for m in ("m1", "m2"):
            rendezvous.join(store, "conn", m, ["fpA"], [[{"name": "A", "caps": []}]],
                            lambda desc: 0)
        epoch = rendezvous.propose_transition(store, "conn", "m1", "fpB", [])
        t0 = time.monotonic() - 10.0
        assert rendezvous.try_commit(store, "conn", epoch, 5.0, t0) is False
