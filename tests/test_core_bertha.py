"""Unit + integration tests for the Bertha core (stacks, negotiation,
reconfiguration, rendezvous)."""
import threading
import time

import pytest

from repro.core import (
    ANY,
    BarrierConn,
    Capability,
    CapabilitySet,
    Chunnel,
    Datapath,
    Fabric,
    FabricTransport,
    FnChunnel,
    HostAgent,
    KVStore,
    LinkModel,
    LockedConn,
    NegotiationError,
    Select,
    ServerNegotiator,
    Stack,
    StackTypeError,
    WireType,
    make_stack,
)
from repro.core import rendezvous
from repro.core.reconfigure import two_phase_commit


def T(name, upper, lower, caps=None, multilateral=False):
    return FnChunnel(
        fn_name=name,
        upper=WireType.of(upper),
        lower=WireType.of(lower),
        caps=caps,
        multilateral_=multilateral,
    )


class TestStackTyping:
    def test_compose_ok(self):
        s = make_stack(T("Ser", "obj", "bytes"), T("Udp", "bytes", "unit"))
        assert len(s.preferred()) == 2

    def test_type_mismatch_rejected_at_assembly(self):
        with pytest.raises(StackTypeError):
            make_stack(T("Ser", "obj", "bytes"), T("Tcp", "string", "unit"))

    def test_select_filters_ill_typed_branches(self):
        s = make_stack(
            T("Ser", "obj", "bytes"),
            Select(T("Bad", "string", "unit"), T("Udp", "bytes", "unit")),
        )
        opts = s.options()
        assert len(opts) == 1 and opts[0].chunnels[1].name == "Udp"

    def test_select_preference_order(self):
        s = make_stack(Select(T("A", "bytes", "unit"), T("B", "bytes", "unit")))
        assert [o.chunnels[0].name for o in s.options()] == ["A", "B"]

    def test_nested_select(self):
        s = make_stack(
            Select(
                T("PSP", "bytes", "unit"),
                Select(T("QUIC", "bytes", "unit"),
                       (T("TLS", "bytes", "bytes"), T("TCP", "bytes", "unit"))),
            )
        )
        names = [" ".join(c.name for c in o) for o in s.options()]
        assert names == ["PSP", "QUIC", "TLS TCP"]  # paper §7.1 example

    def test_composition_not_commutative(self):
        a, b = T("A", "x", "x"), T("B", "x", "x")
        assert make_stack(a, b).preferred().fingerprint() != make_stack(
            b, a).preferred().fingerprint()


class TestCapabilities:
    def test_exact_must_match_both(self):
        a = CapabilitySet.exact("ser:protobuf")
        b = CapabilitySet.exact("ser:protobuf")
        c = CapabilitySet.exact("ser:capnproto")
        assert a.compatible_with(b)
        assert not a.compatible_with(c)

    def test_compose_one_side_suffices(self):
        a = CapabilitySet.exact("ser:pb").union_(CapabilitySet.compose("shard"))
        b = CapabilitySet.exact("ser:pb")
        assert a.compatible_with(b) and b.compatible_with(a)

    def test_relative_compat_reuse_label(self):
        # ProtoACC reuses the protobuf capability label (paper §5.2)
        sw = CapabilitySet.exact("ser:protobuf")
        hw = CapabilitySet.exact("ser:protobuf")  # different impl, same label
        assert sw.compatible_with(hw)


def _mk_pair(fabric, caps_client=None, caps_server=None, server_first=True):
    server = HostAgent(fabric, "srv")
    client = HostAgent(fabric, "cli")
    return server, client


class TestNegotiation:
    def test_one_rtt_negotiation(self):
        fabric = Fabric()
        server, client = _mk_pair(fabric)
        sstack = make_stack(
            Select(
                T("Kafka", "obj", "unit", CapabilitySet.exact("pubsub:kafka")),
                T("SQS", "obj", "unit", CapabilitySet.exact("pubsub:sqs")),
            )
        )
        cstack = make_stack(T("SQS", "obj", "unit", CapabilitySet.exact("pubsub:sqs")))
        server.listen(sstack)
        conn = client.connect("srv", cstack)
        assert conn.stack.chunnels[0].name == "SQS"
        assert server.accept_stack("cli").chunnels[0].name == "SQS"
        server.close(); client.close()

    def test_incompatible_rejected(self):
        fabric = Fabric()
        server, client = _mk_pair(fabric)
        server.listen(make_stack(T("A", "obj", "unit", CapabilitySet.exact("fmt:a"))))
        with pytest.raises(NegotiationError):
            client.connect("srv", make_stack(T("B", "obj", "unit",
                                               CapabilitySet.exact("fmt:b"))))
        server.close(); client.close()

    def test_negotiation_over_lossy_base_connection(self):
        fabric = Fabric(default_link=LinkModel(latency_s=0.001, loss=0.3), seed=7)
        server, client = _mk_pair(fabric)
        st = make_stack(T("X", "obj", "unit", CapabilitySet.exact("x")))
        server.listen(st)
        conn = client.connect("srv", st)  # reliability layer must recover
        assert conn.stack.chunnels[0].name == "X"
        server.close(); client.close()

    def test_zero_rtt_resumption(self):
        fabric = Fabric()
        server, client = _mk_pair(fabric)
        st = make_stack(T("X", "obj", "unit", CapabilitySet.exact("x")))
        server.listen(st)
        c1 = client.connect("srv", st, use_zero_rtt=True)
        assert not c1.was_zero_rtt  # first connection pays the RTT
        c2 = client.connect("srv", st, use_zero_rtt=True)
        assert c2.was_zero_rtt
        assert c2.stack.fingerprint() == c1.stack.fingerprint()
        server.close(); client.close()

    def test_zero_rtt_nonce_matches_original_negotiation(self):
        # The nonce encodes the agreed select branches (§7.3 uses it to let
        # backends accept a client's requests) — resuming the SAME stack via
        # 0-RTT must therefore mint the SAME nonce as the 1-RTT negotiation.
        fabric = Fabric()
        server, client = _mk_pair(fabric)
        st = make_stack(T("X", "obj", "unit", CapabilitySet.exact("x")))
        server.listen(st)
        c1 = client.connect("srv", st, use_zero_rtt=True)
        c2 = client.connect("srv", st, use_zero_rtt=True)
        assert not c1.was_zero_rtt and c2.was_zero_rtt
        assert c2.nonce == c1.nonce
        server.close(); client.close()

    def test_zero_rtt_claim_validated_against_cache(self):
        st = make_stack(T("X", "obj", "unit", CapabilitySet.exact("x")))
        neg = ServerNegotiator(st)
        # no prior negotiation with this peer: claim must be rejected
        r = neg.handle("stranger", {"type": "zero_rtt", "fp": "anything"})
        assert r["type"] == "negotiate_failed"
        # negotiate, then claim a DIFFERENT fingerprint: must be rejected too
        accept = neg.handle("cli", {
            "type": "offer", "options": st.offer(),
            "fps": [o.fingerprint() for o in st.options()],
        })
        assert accept["type"] == "accept"
        r = neg.handle("cli", {"type": "zero_rtt", "fp": "not-what-we-agreed"})
        assert r["type"] == "negotiate_failed"
        # the real fingerprint resumes, and with the original nonce
        good = neg.handle("cli", {"type": "zero_rtt",
                                  "fp": st.preferred().fingerprint()})
        assert good["type"] == "zero_rtt_ok"
        assert good["nonce"] == accept["nonce"]

    def test_server_preference_wins(self):
        fabric = Fabric()
        server, client = _mk_pair(fabric)
        ka = T("Kafka", "obj", "unit", CapabilitySet.exact("pubsub:kafka"))
        sq = T("SQS", "obj", "unit", CapabilitySet.exact("pubsub:sqs"))
        server.listen(make_stack(Select(ka, sq)))
        conn = client.connect("srv", make_stack(Select(sq, ka)))
        # server prefers kafka; client offered both; server preference rules
        assert conn.stack.chunnels[0].name == "Kafka"
        server.close(); client.close()


class _CountingChunnel(FnChunnel):
    pass


def _counting(name):
    calls = {"n": 0}

    def on_send(m):
        calls["n"] += 1
        return m

    ch = FnChunnel(fn_name=name, on_send=on_send)
    return ch, calls


class TestReconfiguration:
    def _echo_stack(self, fabric, name="A"):
        ep = fabric.register(f"ep-{name}-{time.monotonic_ns()}")
        ch, calls = _counting(name)
        st = make_stack(ch, FabricTransport(ep, "nowhere"))
        return st, calls

    @pytest.mark.parametrize("cls", [LockedConn, BarrierConn])
    def test_unilateral_swap_preserves_service(self, cls):
        fabric = Fabric()
        st_a, calls_a = self._echo_stack(fabric, "A")
        st_b, calls_b = self._echo_stack(fabric, "B")
        handle = cls(st_a.preferred()) if cls is LockedConn else cls(
            st_a.preferred(), n_threads=1)

        stop = threading.Event()
        sent = {"n": 0}

        def pump():
            while not stop.is_set():
                handle.send([b"x"])
                sent["n"] += 1

        t = threading.Thread(target=pump)
        t.start()
        time.sleep(0.05)
        ok = handle.reconfigure(st_b.preferred())
        time.sleep(0.05)
        stop.set(); t.join()
        assert ok
        assert calls_a["n"] > 0 and calls_b["n"] > 0  # traffic on both impls
        assert handle.stats.switches == 1
        assert sent["n"] == calls_a["n"] + calls_b["n"]  # nothing lost/duplicated

    def test_coordinate_false_aborts(self):
        fabric = Fabric()
        st_a, _ = self._echo_stack(fabric, "A")
        st_b, _ = self._echo_stack(fabric, "B")
        handle = LockedConn(st_a.preferred())
        assert handle.reconfigure(st_b.preferred(), coordinate=lambda: False) is False
        assert handle.stack.chunnels[0].name == "A"


class _PassDP(Datapath):
    def __init__(self, inner):
        self.inner = inner

    def send(self, msgs):
        if self.inner is not None:
            self.inner.send(msgs)

    def recv(self, buf, timeout=None):
        return self.inner.recv(buf, timeout) if self.inner else 0


class _MigCh(Chunnel):
    """Pass-through chunnel that logs every migrate_state call."""

    upper_type = ANY
    lower_type = ANY

    def __init__(self, name, log):
        self._name = name
        self.log = log

    @property
    def name(self):
        return self._name

    def connect_wrap(self, inner):
        return _PassDP(inner)

    def migrate_state(self, old):
        self.log.append(self._name)
        return {f"from_{self._name}": 1}


class _MigChV2(_MigCh):
    """Same name as a _MigCh, different implementation class."""


class TestStateMigrationAlignment:
    def test_new_trailing_layer_migrates_on_depth_mismatch(self):
        # old [A] -> new [A, C]: a positional zip pairs only (A, A) and C
        # never gets to extract state; name alignment must call C.
        log = []
        handle = LockedConn(make_stack(_MigCh("A", log)).preferred())
        new = make_stack(_MigCh("A", log), _MigCh("C", log)).preferred()
        log.clear()
        assert handle.reconfigure(new)
        assert log == ["C"]

    def test_shorter_stack_changed_head_still_migrates(self):
        # old [A, B, C] -> new [D, C]: zip pairs (A,D),(B,C) and the kept C is
        # compared against B (spurious) while D's pairing is right by luck;
        # name alignment: D (new) migrates, C (unchanged, just moved) does not.
        log = []
        handle = LockedConn(
            make_stack(_MigCh("A", log), _MigCh("B", log), _MigCh("C", log)).preferred())
        new = make_stack(_MigCh("D", log), _MigCh("C", log)).preferred()
        log.clear()
        assert handle.reconfigure(new)
        assert log == ["D"]

    def test_reordered_unchanged_layers_do_not_spuriously_migrate(self):
        log = []
        handle = LockedConn(make_stack(_MigCh("A", log), _MigCh("C", log)).preferred())
        new = make_stack(_MigCh("C", log), _MigCh("A", log)).preferred()
        log.clear()
        assert handle.reconfigure(new)
        assert log == []

    def test_same_name_different_impl_migrates(self):
        # relative-compatibility: a different implementation reusing the name
        # still needs the state translated.
        log = []
        handle = LockedConn(make_stack(_MigCh("M", log)).preferred())
        new = make_stack(_MigChV2("M", log)).preferred()
        log.clear()
        assert handle.reconfigure(new)
        assert log == ["M"]


class TestTwoPhaseCommitAbortSafety:
    def _chan(self, sent, *, commit_timeout_for=(), refuse=(), abort_timeout_for=()):
        def chan_request(p, m):
            t = m["type"]
            sent.append((p, t))
            if t == "reconfig_prepare":
                if p in refuse:
                    return {"type": "reconfig_refuse"}
                return {"type": "reconfig_ready"}
            if t == "reconfig_commit" and p in commit_timeout_for:
                raise TimeoutError(p)
            if t == "reconfig_abort" and p in abort_timeout_for:
                raise TimeoutError(p)
            return {"type": "reconfig_done"}
        return chan_request

    def test_commit_phase_timeout_does_not_escape(self):
        # Once all peers are prepared the decision is commit: a delivery
        # failure to p2 must neither raise nor stop p3 from being notified.
        sent = []
        ok = two_phase_commit(self._chan(sent, commit_timeout_for={"p2"}),
                              ["p1", "p2", "p3"], "fp-new")
        assert ok is True
        commits = [p for p, t in sent if t == "reconfig_commit"]
        assert commits == ["p1", "p2", "p3"]

    def test_refusal_aborts_and_abort_timeout_swallowed(self):
        sent = []
        ok = two_phase_commit(
            self._chan(sent, refuse={"p3"}, abort_timeout_for={"p1"}),
            ["p1", "p2", "p3"], "fp-new")
        assert ok is False
        aborts = [p for p, t in sent if t == "reconfig_abort"]
        assert aborts == ["p1", "p2"]  # p1's timeout didn't stop p2's abort
        assert not [p for p, t in sent if t == "reconfig_commit"]


class TestDispatchConnIsolation:
    def test_unknown_conn_refused_and_correct_conn_swaps(self):
        fabric = Fabric()
        srv = HostAgent(fabric, "iso-srv")
        cli = HostAgent(fabric, "iso-cli")
        stack = make_stack(Select(T("A", "obj", "unit"), T("B", "obj", "unit")))
        handle = LockedConn(stack.options()[0])
        srv.register_participant("connA", handle, stack.find)
        fp_b = stack.options()[1].fingerprint()
        # a prepare/commit for an unknown conn must be refused, not routed to
        # an arbitrary participant (it would swap conn A's stack)
        r = cli.request("iso-srv", {"type": "reconfig_prepare", "fp": fp_b,
                                    "conn": "connB"})
        assert r["type"] == "reconfig_refuse"
        r = cli.request("iso-srv", {"type": "reconfig_commit", "fp": fp_b,
                                    "conn": "connB"})
        assert r["type"] == "reconfig_refuse"
        assert handle.stack.fingerprint() == stack.options()[0].fingerprint()
        assert handle.stats.switches == 0
        # the registered conn id still works end-to-end
        r = cli.request("iso-srv", {"type": "reconfig_prepare", "fp": fp_b,
                                    "conn": "connA"})
        assert r["type"] == "reconfig_ready"
        r = cli.request("iso-srv", {"type": "reconfig_commit", "fp": fp_b,
                                    "conn": "connA"})
        assert r["type"] == "reconfig_done"
        assert handle.stack.fingerprint() == fp_b
        srv.close(); cli.close()


class TestRendezvous:
    def test_first_proposer_wins_cas(self):
        store = KVStore()
        r1 = rendezvous.join(store, "conn", "m1", ["fpA"], [[{"name": "A", "caps": []}]],
                             lambda desc: 0)
        assert r1.proposed and r1.stack_fp == "fpA"
        r2 = rendezvous.join(store, "conn", "m2", ["fpB", "fpA"],
                             [[{"name": "B", "caps": []}], [{"name": "A", "caps": []}]],
                             lambda desc: 1)
        assert not r2.proposed and r2.stack_fp == "fpA" and r2.participants == 2

    def test_incompatible_joiner_raises(self):
        store = KVStore()
        rendezvous.join(store, "conn", "m1", ["fpA"], [[{"name": "A", "caps": []}]],
                        lambda desc: 0)
        with pytest.raises(ValueError):
            rendezvous.join(store, "conn", "m2", ["fpB"], [[{"name": "B", "caps": []}]],
                            lambda desc: None)

    def test_late_joiner_recovers_stack(self):
        store = KVStore()
        rendezvous.join(store, "conn", "m1", ["fpA"], [[{"name": "A", "caps": []}]],
                        lambda desc: 0)
        cur = rendezvous.current_stack(store, "conn")
        assert cur["fp"] == "fpA" and cur["epoch"] == 1

    def test_transition_commits_when_all_ack(self):
        store = KVStore()
        for m in ("m1", "m2", "m3"):
            rendezvous.join(store, "conn", m, ["fpA"], [[{"name": "A", "caps": []}]],
                            lambda desc: 0)
        epoch = rendezvous.propose_transition(store, "conn", "m1", "fpB",
                                              [{"name": "B", "caps": []}])
        assert rendezvous.try_commit(store, "conn", epoch, 5.0) is None  # pending
        rendezvous.vote(store, "conn", "m2", epoch, True)
        rendezvous.vote(store, "conn", "m3", epoch, True)
        assert rendezvous.try_commit(store, "conn", epoch, 5.0) is True
        assert rendezvous.current_stack(store, "conn")["fp"] == "fpB"

    def test_any_refusal_aborts(self):
        store = KVStore()
        for m in ("m1", "m2"):
            rendezvous.join(store, "conn", m, ["fpA"], [[{"name": "A", "caps": []}]],
                            lambda desc: 0)
        epoch = rendezvous.propose_transition(store, "conn", "m1", "fpB", [])
        rendezvous.vote(store, "conn", "m2", epoch, False)
        assert rendezvous.try_commit(store, "conn", epoch, 5.0) is False
        assert rendezvous.current_stack(store, "conn")["fp"] == "fpA"

    def test_timeout_aborts(self):
        store = KVStore()
        for m in ("m1", "m2"):
            rendezvous.join(store, "conn", m, ["fpA"], [[{"name": "A", "caps": []}]],
                            lambda desc: 0)
        epoch = rendezvous.propose_transition(store, "conn", "m1", "fpB", [])
        t0 = time.monotonic() - 10.0
        assert rendezvous.try_commit(store, "conn", epoch, 5.0, t0) is False


class TestBatchedFabric:
    """PR 7 data plane: vectorized delivery, split counters, bulk drain."""

    def test_recv_many_order_and_drain(self):
        fabric = Fabric()
        a = fabric.register("bf-a")
        b = fabric.register("bf-b")
        msgs = [f"m{i}".encode() for i in range(100)]
        a.send_batch("bf-b", msgs)
        buf = [None] * 100
        got = []
        deadline = time.monotonic() + 2.0
        while len(got) < 100 and time.monotonic() < deadline:
            n = b.recv_many(buf, timeout=0.1)
            got.extend((src, m) for src, m in buf[:n])
        assert [m for _, m in got] == msgs
        assert all(src == "bf-a" for src, _ in got)
        # drained: an immediate follow-up sees nothing
        assert b.recv_many(buf, timeout=0.0) == 0

    def test_recv_many_respects_max_n(self):
        fabric = Fabric()
        a = fabric.register("mx-a")
        b = fabric.register("mx-b")
        a.send_batch("mx-b", [b"x"] * 10)
        buf = [None] * 10
        deadline = time.monotonic() + 2.0
        while time.monotonic() < deadline:
            if b.recv_many(buf, max_n=3, timeout=0.1) == 3:
                break
        n2 = b.recv_many(buf, max_n=100, timeout=0.5)
        assert 1 <= n2 <= 7

    def test_split_counters_loss_and_unroutable(self):
        fabric = Fabric(default_link=LinkModel(loss=0.5), seed=3)
        a = fabric.register("sc-a")
        fabric.register("sc-b")
        a.send_batch("sc-b", [b"p" * 8] * 200)
        a.send_batch("ghost", [b"q" * 8] * 10)
        c = fabric.counters.snapshot()
        assert c["sent"] == 210
        assert c["dropped_unroutable"] == 10
        assert 0 < c["dropped_loss"] < 200
        assert c["delivered"] == 200 - c["dropped_loss"]
        assert c["sent_bytes"] == 200 * 8 + 10 * 8
        # legacy aliases stay wired up for older callers, but warn now
        with pytest.warns(DeprecationWarning, match="counter alias"):
            assert fabric.sent_msgs == c["sent"]
        with pytest.warns(DeprecationWarning, match="counter alias"):
            assert fabric.sent_bytes == c["sent_bytes"]

    def test_batch_loss_is_per_message(self):
        """One RNG draw per message within the batch mask — a lossy link
        drops some of a batch, not all-or-nothing."""
        fabric = Fabric(default_link=LinkModel(loss=0.3), seed=11)
        a = fabric.register("pm-a")
        b = fabric.register("pm-b")
        a.send_batch("pm-b", [bytes([i]) for i in range(200)])
        buf = [None] * 200
        got = 0
        deadline = time.monotonic() + 2.0
        while time.monotonic() < deadline:
            n = b.recv_many(buf, timeout=0.1)
            if n == 0 and got:
                break
            got += n
        assert 0 < got < 200


class TestWindowedReliable:
    """request_window pipelines W frames with cumulative acks (go-back-N)."""

    def _pair(self, loss=0.0, seed=0):
        from repro.core.fabric import ReliableChannel

        fabric = Fabric(default_link=LinkModel(loss=loss), seed=seed)
        c = fabric.register("wr-c")
        s = fabric.register("wr-s")
        cli = ReliableChannel(c, "wr-s", timeout=0.05, retries=60, window=4)
        srv = ReliableChannel(s, "wr-c", timeout=0.05)
        return cli, srv

    def _serve(self, srv, handler, stop):
        while not stop.is_set():
            srv.serve_one(handler, timeout=0.05)

    def test_replies_in_order_over_lossy_link(self):
        cli, srv = self._pair(loss=0.25, seed=5)
        calls = []

        def handler(src, body):
            calls.append(body)
            return body * 10

        stop = threading.Event()
        t = threading.Thread(target=self._serve, args=(srv, handler, stop),
                             daemon=True)
        t.start()
        try:
            replies = cli.request_window(list(range(20)))
        finally:
            stop.set()
            t.join(timeout=2)
        assert replies == [i * 10 for i in range(20)]
        # exactly-once despite retransmissions over a 25%-loss link
        assert sorted(calls) == list(range(20))

    def test_empty_window(self):
        cli, _ = self._pair()
        assert cli.request_window([]) == []

    def test_window_timeout_when_unserved(self):
        from repro.core.fabric import ReliableChannel

        fabric = Fabric()
        c = fabric.register("to-c")
        fabric.register("to-s")
        cli = ReliableChannel(c, "to-s", timeout=0.01, retries=3)
        with pytest.raises(TimeoutError):
            cli.request_window([1, 2, 3])

    def test_reply_cache_bounded(self):
        cli, srv = self._pair()
        srv_small = srv
        srv_small.reply_cache_size = 8
        stop = threading.Event()
        t = threading.Thread(target=self._serve,
                             args=(srv_small, lambda s, b: b, stop), daemon=True)
        t.start()
        try:
            for i in range(50):
                assert cli.request(i) == i
        finally:
            stop.set()
            t.join(timeout=2)
        assert len(srv_small._reply_cache) <= 8
        assert sum(len(d) for d in srv_small._reply_order.values()) <= 8
