"""Multi-objective scorer + policy plugin registry tests: cost-model folding,
argmax-vs-first-compatible negotiation, degenerate/weight-zero objectives,
ScoredTarget resolution inside a controller, and registry semantics."""
import pytest

from repro.core import (
    BYTES_FIRST,
    CapabilitySet,
    Candidate,
    CostModel,
    FnChunnel,
    LATENCY_FIRST,
    LockedConn,
    Objective,
    PolicyContext,
    ReconfigController,
    Rule,
    ScoredTarget,
    Select,
    WireType,
    available_policies,
    conn_controller,
    get_policy,
    make_stack,
    pick_compatible,
    policy_rules,
    register_policy,
    score_stack,
    stack_cost,
    utility,
)
from repro.core.controller import _POLICIES


CAPS = CapabilitySet.exact("wire:obj")


def impl(name, lat=0.0, ratio=1.0, blip=0.0, caps=CAPS):
    return FnChunnel(fn_name=name, caps=caps,
                     cost=CostModel(op_latency_s=lat, dcn_bytes_per_byte=ratio,
                                    switch_blip_s=blip))


class TestCostModel:
    def test_stack_cost_folds_latency_sum_ratio_product(self):
        st = make_stack(impl("A", lat=1e-3, ratio=0.5, blip=0.1),
                        impl("B", lat=2e-3, ratio=0.5, blip=0.2)).preferred()
        c = stack_cost(st)
        assert c.op_latency_s == pytest.approx(3e-3)
        assert c.dcn_bytes_per_byte == pytest.approx(0.25)
        assert c.switch_blip_s == pytest.approx(0.3)

    def test_unannotated_chunnel_is_neutral(self):
        st = make_stack(FnChunnel(fn_name="Plain")).preferred()
        c = stack_cost(st)
        assert (c.op_latency_s, c.dcn_bytes_per_byte, c.switch_blip_s) == (0.0, 1.0, 0.0)

    def test_utility_scales_with_telemetry(self):
        c = CostModel(op_latency_s=1e-3, dcn_bytes_per_byte=1.0)
        quiet = utility(c, snapshot={"ops_per_s": 1.0, "bytes_per_s": 0.0})
        busy = utility(c, snapshot={"ops_per_s": 1000.0, "bytes_per_s": 0.0})
        assert busy < quiet  # same stack costs more under more load

    def test_no_snapshot_keeps_byte_annotations_in_play(self):
        # BYTES_FIRST with no telemetry must still prefer the low-byte option
        # (nominal workload), not silently degrade to latency-only scoring
        fat = CostModel(op_latency_s=3e-3, dcn_bytes_per_byte=1.0)
        lean = CostModel(op_latency_s=5e-3, dcn_bytes_per_byte=0.25)
        assert utility(lean, BYTES_FIRST) > utility(fat, BYTES_FIRST)

    def test_weight_zero_objective_ignores_that_dimension(self):
        slow_cheap = CostModel(op_latency_s=10.0, dcn_bytes_per_byte=0.1)
        fast_fat = CostModel(op_latency_s=1e-6, dcn_bytes_per_byte=1.0)
        snap = {"ops_per_s": 100.0, "bytes_per_s": 1e6}
        bytes_only = Objective(w_latency=0.0, w_bytes=1.0)
        assert utility(slow_cheap, bytes_only, snap) > utility(fast_fat, bytes_only, snap)
        lat_only = Objective(w_latency=1.0, w_bytes=0.0)
        assert utility(fast_fat, lat_only, snap) > utility(slow_cheap, lat_only, snap)


class TestScoredNegotiation:
    def _stacks(self):
        # distinct exact caps: each server option pairs 1:1 with the client
        # option speaking the same wire format
        def mk(name, lat, ratio):
            return impl(name, lat=lat, ratio=ratio,
                        caps=CapabilitySet.exact(f"wire:{name}"))

        server = make_stack(Select(mk("Legacy", 5e-3, 1.0),
                                   mk("ZipWire", 3e-3, 0.25),
                                   mk("FastPath", 4e-4, 1.0)))
        client = make_stack(Select(mk("Legacy", 5e-3, 1.0),
                                   mk("ZipWire", 3e-3, 0.25),
                                   mk("FastPath", 4e-4, 1.0)))
        return server, client.offer()

    def test_argmax_beats_first_compatible_on_crafted_costs(self):
        server, offer = self._stacks()
        first, _ = pick_compatible(server, offer, mode="first")
        assert first.chunnels[0].name == "Legacy"  # server preference
        chatty = {"ops_per_s": 2000.0, "bytes_per_s": 5e4}
        scored, idx = pick_compatible(server, offer, snapshot=chatty,
                                      objective=LATENCY_FIRST)
        assert scored.chunnels[0].name == "FastPath"
        assert offer[idx][0]["name"] == "FastPath"  # client idx tracks the pick
        bulk = {"ops_per_s": 5.0, "bytes_per_s": 5e7}
        scored, _ = pick_compatible(server, offer, snapshot=bulk,
                                    objective=BYTES_FIRST)
        assert scored.chunnels[0].name == "ZipWire"

    def test_neutral_costs_preserve_preference_order(self):
        a = FnChunnel(fn_name="A", caps=CAPS)
        b = FnChunnel(fn_name="B", caps=CAPS)
        server = make_stack(Select(a, b))
        offer = make_stack(Select(b, a)).offer()
        picked, _ = pick_compatible(server, offer,
                                    snapshot={"ops_per_s": 1e4, "bytes_per_s": 1e7})
        assert picked.chunnels[0].name == "A"  # ties break to server preference

    def test_degenerate_single_option_set(self):
        only = impl("Only", lat=1.0, ratio=2.0, blip=3.0)
        server = make_stack(only)
        offer = make_stack(only).offer()
        picked = pick_compatible(server, offer,
                                 snapshot={"ops_per_s": 1e6, "bytes_per_s": 1e9})
        assert picked is not None and picked[0].chunnels[0].name == "Only"

    def test_no_compatible_option_returns_none(self):
        server = make_stack(impl("A", caps=CapabilitySet.exact("fmt:a")))
        offer = make_stack(impl("B", caps=CapabilitySet.exact("fmt:b"))).offer()
        assert pick_compatible(server, offer) is None
        assert pick_compatible(server, offer, mode="first") is None


class TestScoredTarget:
    def test_resolves_argmax_under_live_snapshot(self):
        cands = [Candidate("fat", CostModel(dcn_bytes_per_byte=1.0), "fat"),
                 Candidate("lean", CostModel(dcn_bytes_per_byte=0.1), "lean")]
        st = ScoredTarget(cands, BYTES_FIRST)
        assert st.resolve({"bytes_per_s": 1e7}, "fat") == "lean"

    def test_margin_keeps_current_on_small_gains(self):
        cands = [Candidate("a", CostModel(op_latency_s=1.00e-3), "a"),
                 Candidate("b", CostModel(op_latency_s=0.99e-3), "b")]
        st = ScoredTarget(cands, LATENCY_FIRST, margin=0.5)
        # b is 1% better: inside the 50% margin, stay on a
        assert st.resolve({"ops_per_s": 100.0}, current_label="a") == "a"
        # but from nowhere (no current), pick the argmax
        assert st.resolve({"ops_per_s": 100.0}) == "b"

    def test_empty_candidates_rejected(self):
        with pytest.raises(ValueError):
            ScoredTarget([])

    def test_controller_switches_to_resolved_target(self):
        cands = [Candidate("A", CostModel(op_latency_s=5e-3), "A"),
                 Candidate("B", CostModel(op_latency_s=1e-4), "B")]
        committed = []
        cur = {"v": "A"}

        def switch(t):
            committed.append(t)
            cur["v"] = t
            return True

        ctl = ReconfigController(
            [Rule("lat", lambda s: True, ScoredTarget(cands, LATENCY_FIRST), hold=1)],
            switch, lambda: cur["v"], cooldown_s=0.0)
        d = ctl.tick({"ops_per_s": 1000.0})
        assert d.committed and committed == ["B"] and d.target == "B"
        # once B is active the same rule resolves to B -> idle, no flap
        d = ctl.tick({"ops_per_s": 1000.0})
        assert d.reason == "idle" and committed == ["B"]


class TestPolicyRegistry:
    def test_builtins_registered(self):
        for name in ("cost_aware", "latency_slo", "byte_budget"):
            assert name in available_policies()

    def test_duplicate_registration_rejected(self):
        @register_policy("test_dup_policy")
        def p1(ctx):
            return []

        try:
            with pytest.raises(ValueError, match="already registered"):
                @register_policy("test_dup_policy")
                def p2(ctx):
                    return []

            # explicit override is allowed
            @register_policy("test_dup_policy", override=True)
            def p3(ctx):
                return [Rule("r", lambda s: True, "X")]

            assert len(policy_rules("test_dup_policy", PolicyContext())) == 1
        finally:
            _POLICIES.pop("test_dup_policy", None)

    def test_unknown_policy_name_raises_with_listing(self):
        with pytest.raises(KeyError, match="cost_aware"):
            get_policy("no_such_policy")

    def test_cost_aware_policy_rules(self):
        ctx = PolicyContext(candidates=[Candidate("a"), Candidate("b")])
        rules = policy_rules("cost_aware", ctx)
        assert len(rules) == 1 and isinstance(rules[0].target, ScoredTarget)

    def test_latency_slo_requires_slo_param(self):
        ctx = PolicyContext(candidates=[Candidate("a")])
        with pytest.raises(KeyError):
            policy_rules("latency_slo", ctx)
        ctx.params["slo_s"] = 0.1
        ctx.default = "a"
        rules = policy_rules("latency_slo", ctx)
        assert {r.name for r in rules} == {"latency_slo:breach", "latency_slo:recovered"}

    def test_byte_budget_drives_controller_to_lean_option(self):
        ctx = PolicyContext(
            candidates=[Candidate("fat", CostModel(dcn_bytes_per_byte=1.0), "fat"),
                        Candidate("lean", CostModel(dcn_bytes_per_byte=0.1), "lean")],
            default="fat", params={"bytes_per_s": 1000.0, "hold": 1})
        rules = policy_rules("byte_budget", ctx)
        committed = []
        cur = {"v": "fat"}

        def switch(t):
            committed.append(t)
            cur["v"] = t
            return True

        ctl = ReconfigController(rules, switch, lambda: cur["v"], cooldown_s=0.0)
        ctl.tick({"bytes_per_s": 5000.0})
        assert committed == ["lean"]
        for _ in range(2):  # recovery (hold=2) brings it back to the default
            ctl.tick({"bytes_per_s": 10.0})
        assert committed == ["lean", "fat"]


class TestTrainerDefaultPolicy:
    def test_scored_budget_target_excludes_mitigation(self):
        # localsgd wins BOTH communication dimensions (it simply skips syncs)
        # but changes training semantics — only the straggler rule may pick
        # it; the scored byte-budget argmax must land on a sync transport
        from repro.train.trainer import trainer_default_policy

        cands = [Candidate("xla", CostModel(3e-3, 1.0, 2.0), "xla"),
                 Candidate("compressed_int8", CostModel(2.5e-3, 0.254, 2.0),
                           "compressed_int8"),
                 Candidate("localsgd", CostModel(1e-3, 0.25, 2.0), "localsgd")]
        ctx = PolicyContext(candidates=cands, default="xla",
                            params={"dcn_budget_bytes_per_s": 1000.0,
                                    "budget_target": None, "hold": 1})
        rules = trainer_default_policy(ctx)
        budget_rule = next(r for r in rules if r.name == "dcn-budget->compressed")
        # 1 GB/s of DCN gradients: the byte savings dwarf the re-jit blip
        resolved = budget_rule.target.resolve(
            {"ops_per_s": 10.0, "bytes_per_s": 1e9}, "xla")
        assert resolved == "compressed_int8"
        # at a low byte rate the amortized re-jit blip wins: stay put
        assert budget_rule.target.resolve(
            {"ops_per_s": 10.0, "bytes_per_s": 1e6}, "xla") == "xla"
        straggler_rule = next(r for r in rules if r.name == "straggler->mitigation")
        assert straggler_rule.target == "localsgd"  # mitigation stays reachable

    def test_transport_candidates_exclude_staleness_trades_by_default(self):
        # any scoring policy fed transport_candidates (cost_aware included)
        # must not see localsgd: it wins the comm-cost contest by changing
        # training semantics, so only an explicit mitigation rule names it
        from types import SimpleNamespace

        from repro.train.trainer import HostSpec, ReconfigurableTrainer

        offers = ["xla", "localsgd", "compressed_int8"]
        shim = SimpleNamespace(hosts=[HostSpec(0, offers), HostSpec(1, offers)])
        cands = ReconfigurableTrainer.transport_candidates(shim)
        assert [c.label for c in cands] == ["xla", "compressed_int8"]
        with_mit = ReconfigurableTrainer.transport_candidates(
            shim, include_mitigations=True)
        assert [c.label for c in with_mit] == offers


class TestConnControllerPolicyPath:
    def _stack(self):
        from repro.core import Fabric, FabricTransport

        fabric = Fabric()
        ep = fabric.register("pol-ep")
        fastpath = FnChunnel(fn_name="FastPath", upper=WireType.of("bytes"),
                             lower=WireType.of("bytes"),
                             cost=CostModel(op_latency_s=1e-4))
        slowpath = FnChunnel(fn_name="SlowPath", upper=WireType.of("bytes"),
                             lower=WireType.of("bytes"),
                             cost=CostModel(op_latency_s=5e-3))
        return make_stack(Select(slowpath, fastpath), FabricTransport(ep, "sink"))

    def test_policy_by_name_replaces_flat_rule_list(self):
        stack = self._stack()
        handle = LockedConn(stack.preferred())
        ctl = conn_controller(handle, stack, policy="cost_aware",
                              policy_params={"hold": 1, "margin": 0.0},
                              cooldown_s=0.0)
        for _ in range(300):
            handle.send([b"x"])
        d = ctl.tick(handle.telemetry.snapshot())
        assert d.committed
        assert handle.stack.chunnels[0].name == "FastPath"

    def test_rules_and_policy_are_mutually_exclusive(self):
        stack = self._stack()
        handle = LockedConn(stack.preferred())
        with pytest.raises(ValueError, match="exactly one"):
            conn_controller(handle, stack)
        with pytest.raises(ValueError, match="exactly one"):
            conn_controller(handle, stack, [Rule("r", lambda s: True, "X")],
                            policy="cost_aware")


class TestScorerInNegotiator:
    def test_negotiator_scores_with_telemetry_without_resetting_window(self):
        from repro.core import ConnTelemetry, ServerNegotiator

        legacy = impl("Legacy", lat=5e-3)
        fast = impl("FastPath", lat=4e-4)
        server_stack = make_stack(Select(legacy, fast))
        tel = ConnTelemetry()
        for _ in range(50):
            tel.record_send(1, 100, 0.001)
        neg = ServerNegotiator(server_stack, objective=LATENCY_FIRST, telemetry=tel)
        client = make_stack(Select(legacy, fast))
        reply = neg.handle("cli", {
            "type": "offer", "options": client.offer(),
            "fps": [o.fingerprint() for o in client.options()],
        })
        assert reply["type"] == "accept"
        assert neg.negotiated["cli"].chunnels[0].name == "FastPath"
        # the negotiator peeked: the controller's rate window is undisturbed
        assert tel.snapshot()["ops_per_s"] > 0.0

    def test_bare_negotiator_honors_preference_over_annotations(self):
        # evidence-gated scoring: with no telemetry and no objective, static
        # cost annotations must not override the operator's declared Select
        # order (the routing_stack prefer="server" contract)
        from repro.core import ServerNegotiator

        slow_default = impl("SlowDefault", lat=2.4e-3)  # deliberately first
        fast = impl("FastAlt", lat=1.6e-3)
        neg = ServerNegotiator(make_stack(Select(slow_default, fast)))
        client = make_stack(Select(slow_default, fast))
        reply = neg.handle("cli", {
            "type": "offer", "options": client.offer(),
            "fps": [o.fingerprint() for o in client.options()],
        })
        assert reply["type"] == "accept"
        assert neg.negotiated["cli"].chunnels[0].name == "SlowDefault"
