"""PR 10 SLO plane: error-budget engine arithmetic on a fake clock, metrics
federation over the KV obs plane, trace-derived cost calibration, and the
``slo_guard`` policy — the federation → SLO → policy lifecycle of
docs/architecture.md §11, unit-sized.
"""
from __future__ import annotations

import pytest

from repro.core.controller import PolicyContext, Rule, policy_rules
from repro.core.cost import (
    Candidate,
    CostModel,
    chunnel_cost,
    measured_costs,
    reset_measured_costs,
)
from repro.core.rendezvous import KVStore
from repro.core.chunnel import FnChunnel
from repro.fleet.aggregate import FleetAggregator
from repro.fleet.publish import roster_key
from repro.obs import SLO, MetricsRegistry, TRACER, parse_prometheus
from repro.obs.calibrate import calibrate_from_traces
from repro.obs.federate import OBS_PLANE, MetricsFederator, MetricsPublisher
from repro.obs.slo import (
    SLOEngine,
    availability_slo_for,
    error_ratio_slo_for,
    latency_slo_for,
)


@pytest.fixture(autouse=True)
def clean_tracer():
    TRACER.disable()
    TRACER.reset()
    yield
    TRACER.disable()
    TRACER.reset()


class _FakeRecorder:
    """Captures SLOEngine breach dumps without touching the filesystem."""

    def __init__(self):
        self.dumps = []

    def dump(self, name, extra=None, once=False):
        self.dumps.append((name, extra, once))
        return name


def engine(slos, **kw):
    kw.setdefault("recorder", None)
    return SLOEngine(slos, **kw)


# ---------------------------------------------------------------------------
# SLO declaration + classification
# ---------------------------------------------------------------------------


class TestSLODeclaration:
    def test_unknown_kind_rejected(self):
        with pytest.raises(ValueError, match="unknown SLO kind"):
            SLO("x", "m", kind="throughput")

    def test_objective_must_be_sub_one(self):
        with pytest.raises(ValueError, match="objective"):
            SLO("x", "m", objective=1.0, threshold=1.0)

    def test_latency_needs_threshold(self):
        with pytest.raises(ValueError, match="threshold"):
            SLO("x", "m", kind="latency")

    def test_budget_is_one_minus_objective(self):
        assert SLO("x", "m", objective=0.95, threshold=1.0).budget == (
            pytest.approx(0.05))

    def test_helpers_build_each_kind(self):
        assert latency_slo_for("m", 0.005).kind == "latency"
        assert error_ratio_slo_for("m").kind == "error_ratio"
        assert availability_slo_for("m").kind == "availability"

    def test_latency_classification(self):
        s = latency_slo_for("rtt", 0.005, objective=0.95)
        assert s.bad_fraction({"rtt": 0.004}) == 0.0
        assert s.bad_fraction({"rtt": 0.006}) == 1.0

    def test_missing_nan_and_nonnumeric_are_no_data(self):
        s = latency_slo_for("rtt", 0.005)
        assert s.bad_fraction({}) is None
        assert s.bad_fraction({"rtt": float("nan")}) is None
        assert s.bad_fraction({"rtt": "broken"}) is None

    def test_error_ratio_clamps(self):
        s = error_ratio_slo_for("err")
        assert s.bad_fraction({"err": 0.02}) == pytest.approx(0.02)
        assert s.bad_fraction({"err": 7.0}) == 1.0
        assert s.bad_fraction({"err": -3.0}) == 0.0

    def test_availability_inverts(self):
        s = availability_slo_for("up")
        assert s.bad_fraction({"up": 1.0}) == 0.0
        assert s.bad_fraction({"up": 0.25}) == pytest.approx(0.75)


# ---------------------------------------------------------------------------
# engine lifecycle on a fake clock
# ---------------------------------------------------------------------------

LAT = SLO("lat", "rtt_p95_s", objective=0.95, threshold=0.005)


class TestSLOEngine:
    def test_needs_slos_and_unique_names(self):
        with pytest.raises(ValueError, match="at least one"):
            engine([])
        with pytest.raises(ValueError, match="duplicate"):
            engine([LAT, SLO("lat", "x", threshold=1.0)])

    def test_healthy_run_burns_nothing(self):
        e = engine([LAT], fast_window_s=5.0, slow_window_s=60.0)
        for t in range(1, 61):
            sigs = e.observe({"rtt_p95_s": 0.001}, now=float(t))
        assert sigs["slo.lat.burn_fast"] == 0.0
        assert sigs["slo.lat.burn_slow"] == 0.0
        assert sigs["slo.lat.alarm"] == 0.0
        assert sigs["slo.lat.budget_remaining"] == 1.0
        assert e.events == []

    def test_short_spike_trips_fast_window_only(self):
        # multi-window point: 10 bad seconds after 100 good ones saturate the
        # fast window (burn 20 > 14.4) while the slow window stays diluted
        # (10/60 / 0.05 = 3.3 < 6.0) — no page
        e = engine([LAT], fast_window_s=5.0, slow_window_s=60.0)
        t = 0.0
        for _ in range(100):
            t += 1.0
            e.observe({"rtt_p95_s": 0.001}, now=t)
        for _ in range(10):
            t += 1.0
            sigs = e.observe({"rtt_p95_s": 0.02}, now=t)
        assert sigs["slo.lat.burn_fast"] > e.fast_burn
        assert sigs["slo.lat.burn_slow"] < e.slow_burn
        assert sigs["slo.lat.alarm"] == 0.0

    def test_sustained_badness_breaches_then_recovers(self):
        rec = _FakeRecorder()
        e = SLOEngine([LAT], fast_window_s=5.0, slow_window_s=60.0,
                      budget_window_s=3600.0, recorder=rec)
        TRACER.enable()
        t = 0.0
        for _ in range(60):
            t += 1.0
            e.observe({"rtt_p95_s": 0.001}, now=t)
        for _ in range(40):
            t += 1.0
            sigs = e.observe({"rtt_p95_s": 0.02}, now=t)
        assert sigs["slo.lat.alarm"] == 1.0
        assert sigs["slo.alarms"] == 1
        assert e.alarmed() == ["lat"]
        assert [ev["kind"] for ev in e.events] == ["breach"]
        # the breach tripped the recorder exactly once, with the event data
        assert len(rec.dumps) == 1
        name, extra, once = rec.dumps[0]
        assert name == "slo_breach_lat" and once and extra["slo"] == "lat"
        # ... and emitted a tracer instant
        kinds = [r["name"] for r in TRACER.collect()
                 if r.get("kind") == "event"]
        assert "slo.breach" in kinds

        for _ in range(10):
            t += 1.0
            sigs = e.observe({"rtt_p95_s": 0.001}, now=t)
        assert sigs["slo.lat.alarm"] == 0.0
        assert [ev["kind"] for ev in e.events] == ["breach", "recovery"]
        assert len(rec.dumps) == 1  # recovery does not dump

    def test_budget_spends_over_the_run(self):
        e = engine([LAT], budget_window_s=1000.0)
        t = 0.0
        for _ in range(25):
            t += 1.0
            sigs = e.observe({"rtt_p95_s": 0.02}, now=t)
        # 24 bad-held seconds / (0.05 budget * 1000s window) = 0.48
        assert sigs["slo.lat.budget_spent"] == pytest.approx(0.48)
        assert sigs["slo.lat.budget_remaining"] == pytest.approx(0.52)

    def test_missing_metric_leaves_state_untouched(self):
        e = engine([LAT])
        e.observe({"rtt_p95_s": 0.02}, now=1.0)
        before = e.report(now=2.0)[0]["samples"]
        e.observe({}, now=2.0)
        assert e.report(now=2.0)[0]["samples"] == before

    def test_report_row_shape(self):
        e = engine([LAT])
        e.observe({"rtt_p95_s": 0.001}, now=1.0)
        (row,) = e.report(now=2.0)
        assert row["slo"] == "lat" and row["objective"] == 0.95
        assert row["budget"] == pytest.approx(0.05)
        assert row["alarm"] is False and row["breaches"] == 0

    def test_view_fn_makes_it_a_signal_source(self):
        view = {"rtt_p95_s": 0.02}
        e = engine([LAT], view_fn=lambda: view)
        sigs = e.read(now=1.0)
        assert sigs["slo.lat.bad"] == 1.0
        # signals() peeks without re-sampling
        assert e.signals()["slo.lat.bad"] == 1.0

    def test_engine_feeds_fleet_aggregator(self):
        store = KVStore()
        agg = FleetAggregator(store, "f", now=lambda: 100.0)
        e = engine([LAT], view_fn=lambda: {"rtt_p95_s": 0.02})
        agg.add_source(e)
        snap = agg.aggregate(now=100.0)
        assert snap["slo.lat.bad"] == 1.0
        assert "slo.alarms" in snap


# ---------------------------------------------------------------------------
# federation over the KV obs plane
# ---------------------------------------------------------------------------


def _member(store, name, region, metrics, now):
    reg = MetricsRegistry()
    reg.register("conn", lambda m=metrics: dict(m), instance=f"{name}-c")
    pub = MetricsPublisher(store, "fed", name, reg, region=region, now=now)
    pub.publish()
    return pub


class TestFederation:
    M1 = {"ops_per_s": 100.0, "rtt_p50_s": 0.001, "rtt_p95_s": 0.005}
    M2 = {"ops_per_s": 300.0, "rtt_p50_s": 0.002, "rtt_p95_s": 0.003}

    def test_merge_modes(self):
        store = KVStore()
        now = lambda: 10.0
        _member(store, "m1", "edge", self.M1, now)
        _member(store, "m2", "core", self.M2, now)
        fed = MetricsFederator(store, "fed", ttl_s=5.0, now=now)
        conn = fed.merged()["conn"]
        assert conn["ops_per_s"] == pytest.approx(400.0)        # sum
        assert conn["rtt_p95_s"] == pytest.approx(0.005)        # max
        # load-weighted mean: (100*1ms + 300*2ms) / 400
        assert conn["rtt_p50_s"] == pytest.approx(0.00175)

    def test_view_has_flat_and_region_keys(self):
        store = KVStore()
        now = lambda: 10.0
        _member(store, "m1", "edge", self.M1, now)
        _member(store, "m2", "core", self.M2, now)
        fed = MetricsFederator(store, "fed", ttl_s=5.0, now=now)
        v = fed.view()
        assert v["obs.members"] == 2 and v["obs.stale_members"] == 0
        assert v["obs.availability"] == 1.0
        assert v["obs.conn.ops_per_s"] == pytest.approx(400.0)
        assert v["obs.region.edge.conn.rtt_p95_s"] == pytest.approx(0.005)
        assert v["obs.region.core.conn.rtt_p95_s"] == pytest.approx(0.003)
        assert v["obs.member_ops_per_s"] == {"m1": 100.0, "m2": 300.0}

    def test_obs_plane_keys_stay_off_the_fleet_plane(self):
        store = KVStore()
        now = lambda: 10.0
        _member(store, "m1", "edge", self.M1, now)
        assert store.get(roster_key("fed", OBS_PLANE)) is not None
        assert store.get(roster_key("fed")) is None  # coordination untouched

    def test_heartbeat_expiry_spares_rendezvous_membership(self):
        store = KVStore()
        t = [0.0]
        now = lambda: t[0]
        # a rendezvous membership map that obs-plane expiry must NOT evict
        store.transact(
            lambda txn: txn.put("fleet/fed/members", {"m2": "prepared"}))
        _member(store, "m2", "core", self.M2, now)
        t[0] = 10.0
        _member(store, "m1", "edge", self.M1, now)
        fed = MetricsFederator(store, "fed", ttl_s=5.0, now=now)
        fresh, stale = fed.members()
        assert set(fresh) == {"m1"} and stale == ["m2"]
        assert fed.expired_total == 1
        assert store.get("fleet/fed/members") == {"m2": "prepared"}

    def test_nonnumeric_and_private_keys_dropped_from_merge(self):
        store = KVStore()
        now = lambda: 10.0
        _member(store, "m1", "edge",
                {"ops_per_s": 10.0, "_err": "boom", "state": "ok",
                 "nested": {"x": 2.0}}, now)
        fed = MetricsFederator(store, "fed", ttl_s=5.0, now=now)
        conn = fed.merged()["conn"]
        assert conn == {"ops_per_s": 10.0, "nested.x": 2.0}

    def test_federated_registry_prometheus_round_trip(self):
        store = KVStore()
        now = lambda: 10.0
        _member(store, "m1", "edge", self.M1, now)
        _member(store, "m2", "core", self.M2, now)
        fed = MetricsFederator(store, "fed", ttl_s=5.0, now=now)
        text = fed.federated_registry().to_prometheus()
        samples = parse_prometheus(text)
        insts = {s["labels"]["instance"] for s in samples}
        assert {"m1/m1-c", "m2/m2-c", "_fleet"} <= insts
        fleet_ops = [s for s in samples
                     if s["labels"]["instance"] == "_fleet"
                     and s["name"].endswith("ops_per_s")]
        assert fleet_ops and fleet_ops[0]["value"] == pytest.approx(400.0)


# ---------------------------------------------------------------------------
# trace-derived calibration
# ---------------------------------------------------------------------------


def _batch(ch, dur, bi=0, bo=None):
    return {"name": "chunnel.send", "kind": "batch",
            "attrs": {"chunnel": ch, "dur": dur,
                      "bytes_in": bi, "bytes_out": bo}}


class TestCalibrateFromTraces:
    def test_median_latency_and_bytes_ratio(self):
        recs = [_batch("A", d, bi=100, bo=50)
                for d in (0.002, 0.003, 0.002, 0.9)]  # tail outlier ignored
        cal = calibrate_from_traces(recs, min_samples=3, apply=False)
        assert cal.chunnels["A"]["op_latency_s"] == pytest.approx(0.0025)
        assert cal.chunnels["A"]["dcn_bytes_per_byte"] == pytest.approx(0.5)
        assert cal.samples["A"] == 4

    def test_min_samples_gates_chunnels(self):
        cal = calibrate_from_traces([_batch("A", 0.002)] * 2,
                                    min_samples=3, apply=False)
        assert not cal
        assert cal.chunnels == {}

    def test_wan_span_records_count(self):
        recs = [{"name": "wan.send", "kind": "span", "dur": 0.004,
                 "attrs": {"chunnel": "W"}}] * 3
        cal = calibrate_from_traces(recs, apply=False)
        assert cal.chunnels["W"]["op_latency_s"] == pytest.approx(0.004)

    def test_swap_blip_applies_from_one_sample(self):
        recs = [{"name": "reconfig.swap", "kind": "span", "dur": 0.01,
                 "attrs": {"new": "fp1"}}]
        cal = calibrate_from_traces(recs, apply=False)
        assert cal.stack_blips == {"fp1": pytest.approx(0.01)}

    def test_apply_installs_measured_override(self):
        ch = FnChunnel("CalTest", cost=CostModel(op_latency_s=1e-6))
        try:
            calibrate_from_traces([_batch("CalTest", 0.002)] * 3, apply=True)
            assert "CalTest" in measured_costs()[0]
            assert chunnel_cost(ch).op_latency_s == pytest.approx(0.002)
        finally:
            reset_measured_costs()
        assert chunnel_cost(ch).op_latency_s == pytest.approx(1e-6)


# ---------------------------------------------------------------------------
# slo_guard policy
# ---------------------------------------------------------------------------


class TestSLOGuardPolicy:
    def ctx(self, **params):
        cands = [Candidate("fast", CostModel(op_latency_s=1e-4), "Fast"),
                 Candidate("safe", CostModel(op_latency_s=2e-3), "Safe")]
        return PolicyContext(candidates=cands, default="fast",
                             params={"slo": "lat", **params})

    def test_burn_rule_arms_on_both_windows(self):
        rules = policy_rules("slo_guard", self.ctx(safe_names=("Safe",)))
        burn = next(r for r in rules if r.name == "slo_guard:lat:burn")
        assert burn.target == "safe"
        assert not burn.when({"slo.lat.burn_fast": 20.0,
                              "slo.lat.burn_slow": 1.0})
        assert not burn.when({"slo.lat.burn_fast": 1.0,
                              "slo.lat.burn_slow": 10.0})
        assert burn.when({"slo.lat.burn_fast": 20.0,
                          "slo.lat.burn_slow": 10.0})

    def test_recovery_rule_returns_to_default(self):
        rules = policy_rules("slo_guard", self.ctx(safe_names=("Safe",)))
        rec = next(r for r in rules if r.name == "slo_guard:lat:recovered")
        assert rec.target == "fast"
        assert rec.when({"slo.lat.alarm": 0.0})
        assert not rec.when({"slo.lat.alarm": 1.0})

    def test_no_default_no_recovery_rule(self):
        ctx = self.ctx(safe_names=("Safe",))
        ctx.default = None
        names = [r.name for r in policy_rules("slo_guard", ctx)]
        assert names == ["slo_guard:lat:burn"]

    def test_scored_target_without_safe_names(self):
        rules = policy_rules("slo_guard", self.ctx())
        burn = next(r for r in rules if r.name == "slo_guard:lat:burn")
        # a ScoredTarget re-ranks candidates at fire time
        assert hasattr(burn.target, "resolve") or burn.target not in (
            "fast", "safe")

    def test_custom_burn_thresholds(self):
        rules = policy_rules("slo_guard", self.ctx(
            safe_names=("Safe",), fast_burn=2.0, slow_burn=1.0))
        burn = next(r for r in rules if r.name == "slo_guard:lat:burn")
        assert burn.when({"slo.lat.burn_fast": 3.0,
                          "slo.lat.burn_slow": 1.5})
