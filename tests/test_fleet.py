"""Fleet signal plane tests: optimistic KV transactions under contention,
versioned heartbeat publishing, stale-member expiry, multi-source
aggregation, mesh-aware cost calibration, and the acceptance scenario — a
fleet of KV clients switching ServerRouter↔ClientShard exactly once,
fleet-wide, in a single rendezvous epoch, on the AGGREGATE offered load."""
import threading
import time
import types

import pytest

from repro.core import ConnTelemetry, Fabric, KVStore, LockedConn, TxnConflict
from repro.core import rendezvous
from repro.fleet import (
    CallbackSignal,
    CarbonIntensitySignal,
    FleetAggregator,
    FleetMember,
    FleetPublisher,
    LinkBandwidthSignal,
    SignalError,
    SpotPriceSignal,
    StaticSignal,
    fleet_conn_id,
    fleet_controller,
    measure_link_bandwidth,
)
from repro.serving.router import (
    AddressedTransport,
    ServerRouterChunnel,
    routing_stack,
)
from repro.core.stack import make_stack


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


# ---------------------------------------------------------------------------
# KVStore optimistic transactions
# ---------------------------------------------------------------------------


class TestOptimisticTransactions:
    def test_try_transact_detects_interleaved_write(self):
        store = KVStore()
        store.transact(lambda t: t.put("k", 1))

        def fn(txn):
            v = txn.get("k")
            # another writer commits between our read and our commit
            store.transact(lambda t: t.put("k", 99))
            txn.put("k", v + 1)

        with pytest.raises(TxnConflict):
            store.try_transact(fn)
        assert store.get("k") == 99  # the conflicting txn left no partial write
        assert store.conflicts == 1

    def test_snapshot_view_is_stable_within_txn(self):
        store = KVStore()
        store.transact(lambda t: t.put("k", "v0"))
        seen = []

        def fn(txn):
            seen.append(txn.get("k"))
            store.transact(lambda t: t.put("k", "v1"))
            seen.append(txn.get("k"))  # pinned first-read value, not v1
            txn.put("other", 1)

        with pytest.raises(TxnConflict):
            store.try_transact(fn)
        assert seen == ["v0", "v0"]

    def test_transact_retry_converges_under_contention(self):
        """Concurrent read-modify-writes force TxnConflict retries (the sleep
        widens the read->commit window so writers genuinely interleave), and
        no increment is lost."""
        store = KVStore()
        conflicts = []
        n_threads, n_incr = 4, 25

        def incr(txn):
            v = txn.get("ctr") or 0
            time.sleep(0.0004)
            txn.put("ctr", v + 1)

        def worker():
            for _ in range(n_incr):
                store.transact_retry(incr, max_retries=200,
                                     on_conflict=lambda: conflicts.append(1))

        threads = [threading.Thread(target=worker) for _ in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert store.get("ctr") == n_threads * n_incr
        assert conflicts, "contention never produced a TxnConflict retry"
        assert store.conflicts == len(conflicts)

    def test_transact_retry_gives_up(self):
        store = KVStore()

        def always_conflicts(txn):
            txn.get("k")
            store.transact(lambda t: t.put("k", object()))
            txn.put("k", 1)

        with pytest.raises(TxnConflict):
            store.transact_retry(always_conflicts, max_retries=3, backoff_s=0.0)
        assert store.conflicts == 4  # initial try + 3 retries

    def test_keys_prefix_scan(self):
        store = KVStore()
        for k in ("fleet/a/member/x", "fleet/a/member/y", "fleet/b/member/z"):
            store.transact(lambda t, k=k: t.put(k, 1))
        assert store.keys("fleet/a/member/") == [
            "fleet/a/member/x", "fleet/a/member/y"]
        assert len(store.keys()) == 3


# ---------------------------------------------------------------------------
# Publish
# ---------------------------------------------------------------------------


class TestFleetPublisher:
    def test_versioned_heartbeat_records(self):
        clock = FakeClock()
        store = KVStore()
        tel = ConnTelemetry(now=clock)
        pub = FleetPublisher(store, "f", "m0", tel, period_s=0.5, now=clock)
        tel.record_send(2, 200, 0.001)
        rec = pub.publish()
        assert rec["seq"] == 1 and rec["at"] == 0.0
        assert rec["snapshot"]["msgs_out"] == 2
        assert store.get("fleet/f/roster") == {"m0": 0.0}

        clock.advance(0.2)
        assert pub.maybe_publish() is None  # within period
        clock.advance(0.4)
        rec2 = pub.maybe_publish()
        assert rec2["seq"] == 2 and rec2["at"] == pytest.approx(0.6)
        assert store.get("fleet/f/member/m0")["seq"] == 2
        # versions are store-level too: the record key advanced twice
        assert store.version("fleet/f/member/m0") == 2

    def test_publish_rates_are_windowed_per_publish(self):
        clock = FakeClock()
        store = KVStore()
        tel = ConnTelemetry(now=clock)
        pub = FleetPublisher(store, "f", "m0", tel, period_s=0.0, now=clock)
        clock.advance(1.0)
        for _ in range(10):
            tel.record_send(1, 100, 0.001)
        assert pub.publish()["snapshot"]["ops_per_s"] == pytest.approx(10.0)
        clock.advance(1.0)
        for _ in range(4):
            tel.record_send(1, 100, 0.001)
        # reset_window=True: the second publish measures only its own window
        assert pub.publish()["snapshot"]["ops_per_s"] == pytest.approx(4.0)

    def test_reset_window_false_leaves_rates_to_other_consumer(self):
        clock = FakeClock()
        store = KVStore()
        tel = ConnTelemetry(now=clock)
        pub = FleetPublisher(store, "f", "m0", tel, period_s=0.0,
                             reset_window=False, now=clock)
        clock.advance(1.0)
        for _ in range(6):
            tel.record_send(1, 100, 0.001)
        assert pub.publish()["snapshot"]["ops_per_s"] == pytest.approx(6.0)
        clock.advance(1.0)
        # no traffic since, but the window was NOT reset by our publish:
        # rates still cover the whole 2 s interval (3 ops/s), not 0
        assert pub.publish()["snapshot"]["ops_per_s"] == pytest.approx(3.0)

    def test_concurrent_publishers_lose_no_roster_entries(self):
        store = KVStore()
        n = 6
        pubs = [FleetPublisher(store, "f", f"m{i}", ConnTelemetry(),
                               period_s=0.0) for i in range(n)]

        def worker(p):
            for _ in range(10):
                p.publish()

        threads = [threading.Thread(target=worker, args=(p,)) for p in pubs]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        roster = store.get("fleet/f/roster")
        assert sorted(roster) == [f"m{i}" for i in range(n)]
        for i in range(n):
            assert store.get(f"fleet/f/member/m{i}")["seq"] == 10

    def test_retire_removes_record_and_roster_entry(self):
        store = KVStore()
        pub = FleetPublisher(store, "f", "m0", ConnTelemetry(), period_s=0.0)
        pub.publish()
        pub.retire()
        assert store.get("fleet/f/roster") == {}
        assert store.get("fleet/f/member/m0") is None


# ---------------------------------------------------------------------------
# Aggregate + expiry
# ---------------------------------------------------------------------------


def _publish_member(store, fleet_id, name, clock, *, ops=0, rtt=None,
                    straggler=None, period_s=0.0):
    tel = ConnTelemetry(now=clock)
    pub = FleetPublisher(store, fleet_id, name, tel, period_s=period_s,
                         now=clock)
    for _ in range(ops):
        tel.record_send(1, 100, 0.001)
    if rtt is not None:
        for _ in range(50):  # drive the EWMA quantiles to the value
            tel.record_rtt(rtt)
    if straggler is not None:
        tel.record_step({"p0": 1.0, "p1": straggler})
    return pub, tel


class TestFleetAggregator:
    def test_folds_members_and_merges_signals(self):
        clock = FakeClock()
        store = KVStore()
        pa, ta = _publish_member(store, "f", "a", clock)
        pb, tb = _publish_member(store, "f", "b", clock)
        clock.advance(1.0)
        for _ in range(30):
            ta.record_send(1, 100, 0.001)
        for _ in range(10):
            tb.record_send(1, 50, 0.001)
        for _ in range(50):
            ta.record_rtt(0.004)
            tb.record_rtt(0.012)
        pa.publish()
        pb.publish()
        agg = FleetAggregator(
            store, "f", ttl_s=10.0, now=clock,
            sources=[StaticSignal({"ext.carbon_gco2": 310.0})])
        s = agg.aggregate()
        assert s["fleet.members"] == 2 and s["fleet.stale_members"] == 0
        assert s["fleet.offered_qps"] == pytest.approx(40.0)
        assert s["fleet.bytes_per_s"] == pytest.approx(3500.0)
        # p95 combines conservatively (max); p50 is qps-weighted toward the
        # member carrying more load (30 qps at ~4ms vs 10 qps at ~12ms)
        assert s["fleet.rtt_p95_s"] == pytest.approx(0.012, rel=0.2)
        assert s["fleet.rtt_p50_s"] < 0.008
        assert s["fleet.qps_imbalance"] == pytest.approx(1.5)
        assert s["fleet.member_qps"]["a"] == pytest.approx(30.0)
        assert s["ext.carbon_gco2"] == 310.0

    def test_straggler_view_is_max_over_members(self):
        clock = FakeClock()
        store = KVStore()
        pa, _ = _publish_member(store, "f", "a", clock, straggler=1.1)
        pb, _ = _publish_member(store, "f", "b", clock, straggler=2.5)
        pa.publish()
        pb.publish()
        s = FleetAggregator(store, "f", ttl_s=10.0, now=clock).aggregate()
        assert s["fleet.straggler_ratio"] == pytest.approx(2.5)

    def test_heartbeat_expiry_drops_and_deletes_stale_members(self):
        clock = FakeClock()
        store = KVStore()
        pa, _ = _publish_member(store, "f", "a", clock)
        pb, _ = _publish_member(store, "f", "b", clock)
        pa.publish()
        pb.publish()
        agg = FleetAggregator(store, "f", ttl_s=1.0, now=clock)
        assert agg.aggregate()["fleet.members"] == 2

        clock.advance(0.8)
        pb.publish()          # b heartbeats; a goes silent
        clock.advance(0.5)    # a's heartbeat age: 1.3 > ttl; b's: 0.5
        s = agg.aggregate()
        assert s["fleet.members"] == 1
        assert s["fleet.stale_members"] == 1
        # expiry physically removed a's record + roster entry
        assert store.get("fleet/f/member/a") is None
        assert sorted(store.get("fleet/f/roster")) == ["b"]
        assert agg.expired_total == 1

        pa.publish()          # a recovers: next aggregate sees it again
        assert agg.aggregate()["fleet.members"] == 2

    def test_expiry_spares_member_that_republished_in_between(self):
        clock = FakeClock()
        store = KVStore()
        pa, _ = _publish_member(store, "f", "a", clock)
        pa.publish()
        agg = FleetAggregator(store, "f", ttl_s=1.0, now=clock)
        clock.advance(2.0)
        # a looked stale when the aggregator read it, but republishes before
        # the expiry txn runs — the txn re-checks freshness and must not
        # delete the now-live record (the read->expire race)
        pa.publish()
        agg._expire(["a"], clock())
        assert store.get("fleet/f/member/a") is not None
        assert "a" in store.get("fleet/f/roster")
        assert agg.expired_total == 0

    def test_failing_signal_source_is_isolated(self):
        store = KVStore()
        pub = FleetPublisher(store, "f", "a", ConnTelemetry(), period_s=0.0)
        pub.publish()

        def boom(now):
            raise RuntimeError("api down")

        agg = FleetAggregator(store, "f", ttl_s=10.0,
                              sources=[CallbackSignal(boom),
                                       StaticSignal({"ext.spot_usd_per_h": 1.5})])
        s = agg.aggregate()
        assert s["fleet.members"] == 1
        assert s["ext.spot_usd_per_h"] == 1.5
        assert agg.signal_errors == 1


# ---------------------------------------------------------------------------
# Signals
# ---------------------------------------------------------------------------


class TestSignals:
    def test_trace_signals_replay_against_the_clock(self):
        clock = FakeClock()
        carbon = CarbonIntensitySignal([100.0, 400.0], period_s=60.0, now=clock)
        spot = SpotPriceSignal([1.0, 5.0, 2.0], period_s=10.0, now=clock)
        assert carbon.read()["ext.carbon_gco2"] == 100.0
        clock.advance(61.0)
        assert carbon.read()["ext.carbon_gco2"] == 400.0
        assert spot.read()["ext.spot_usd_per_h"] == 1.0  # 61s -> idx 6 % 3 = 0
        clock.advance(60.0)
        assert carbon.read()["ext.carbon_gco2"] == 100.0  # wraps

    def test_measure_link_bandwidth_probe(self):
        bw = measure_link_bandwidth(payload_bytes=1 << 12, n_msgs=8)
        assert bw > 0

    def test_link_bandwidth_signal_caches_until_refresh(self):
        clock = FakeClock()
        values = iter([1e9, 2e9])
        sig = LinkBandwidthSignal(probe=lambda: next(values),
                                  refresh_s=30.0, now=clock)
        s1 = sig.read()
        assert s1["ext.link_bytes_per_s"] == 1e9
        assert s1["ext.dcn_s_per_byte"] == pytest.approx(1e-9)
        clock.advance(10.0)
        assert sig.read()["ext.link_bytes_per_s"] == 1e9  # cached
        clock.advance(25.0)
        assert sig.read()["ext.link_bytes_per_s"] == 2e9  # refreshed
        assert sig.probes == 2

    def test_link_bandwidth_failed_refresh_serves_cache(self):
        clock = FakeClock()
        calls = []

        def probe():
            calls.append(1)
            if len(calls) > 1:
                raise TimeoutError("link flap")
            return 1e9

        sig = LinkBandwidthSignal(probe=probe, refresh_s=30.0, now=clock)
        assert sig.read()["ext.link_bytes_per_s"] == 1e9
        clock.advance(31.0)
        # refresh probe fails: the cached measurement keeps being served...
        assert sig.read()["ext.link_bytes_per_s"] == 1e9
        clock.advance(1.0)
        sig.read()
        assert len(calls) == 2  # ...and the probe is NOT retried every tick
        clock.advance(30.0)
        sig.read()
        assert len(calls) == 3  # retried after another refresh window

        # with no cached value at all, the first failure propagates
        # (aggregator counts it in signal_errors) — and subsequent ticks
        # refuse CHEAPLY until the refresh window, not by re-probing
        probes = []

        def bad_probe():
            probes.append(1)
            raise TimeoutError("down")

        bad = LinkBandwidthSignal(probe=bad_probe, refresh_s=30.0, now=clock)
        with pytest.raises(SignalError) as ei:
            bad.read()
        assert isinstance(ei.value.__cause__, TimeoutError)  # probe chained
        clock.advance(1.0)
        with pytest.raises(SignalError):
            bad.read()          # within refresh_s: no blocking probe attempt
        assert len(probes) == 1
        clock.advance(30.0)
        with pytest.raises(SignalError):
            bad.read()          # next window: probed again
        assert len(probes) == 2


# ---------------------------------------------------------------------------
# Mesh-aware cost calibration (ROADMAP starter)
# ---------------------------------------------------------------------------


class TestMeshAwareCosts:
    @pytest.fixture(autouse=True)
    def _reset(self):
        # both sides: an earlier test constructing a trainer (which installs
        # its mesh process-wide) must not skew our baseline asserts
        from repro.comm import chunnels
        chunnels.reset_cost_calibration()
        yield
        chunnels.reset_cost_calibration()

    def test_live_mesh_width_replaces_nominal_fast(self):
        from repro.comm.chunnels import (
            GradHierarchical,
            calibrate_cost_models,
            reset_cost_calibration,
        )
        ch = GradHierarchical()
        assert ch.cost_model().dcn_bytes_per_byte == pytest.approx(
            1.0 / ch.NOMINAL_FAST)
        mesh = types.SimpleNamespace(axis_names=("pod", "data"),
                                     shape={"pod": 2, "data": 8})
        calibrate_cost_models(mesh=mesh)
        assert ch.cost_model().dcn_bytes_per_byte == pytest.approx(1.0 / 8)
        reset_cost_calibration()
        assert ch.cost_model().dcn_bytes_per_byte == pytest.approx(
            1.0 / ch.NOMINAL_FAST)

    def test_measured_bandwidth_flows_into_objective(self):
        from repro.core.cost import DEFAULT_OBJECTIVE
        from repro.comm.chunnels import calibrate_cost_models, calibrated_objective

        clock = FakeClock()
        sig = LinkBandwidthSignal(probe=lambda: 4e9, now=clock)
        calibrate_cost_models(signal=sig)
        obj = calibrated_objective(DEFAULT_OBJECTIVE)
        assert obj.dcn_s_per_byte == pytest.approx(1.0 / 4e9)
        assert obj.name.endswith("@measured")
        # mesh calibration afterwards must not wipe the measured bandwidth
        mesh = types.SimpleNamespace(axis_names=("pod", "data"),
                                     shape={"pod": 2, "data": 2})
        cal = calibrate_cost_models(mesh=mesh)
        assert cal.n_fast == 2 and cal.dcn_bytes_per_s == pytest.approx(4e9)

    def test_uncalibrated_objective_passes_through(self):
        from repro.core.cost import LATENCY_FIRST
        from repro.comm.chunnels import calibrated_objective
        assert calibrated_objective(LATENCY_FIRST) is LATENCY_FIRST


# ---------------------------------------------------------------------------
# Fleet-wide switching (the acceptance scenario, deterministic clock)
# ---------------------------------------------------------------------------


def _mk_fleet(n=3, *, clock, store=None, only_server_router=frozenset()):
    """n members over §7.3 routing stacks (no live traffic — load is driven
    synthetically through each member's telemetry)."""
    store = store or KVStore()
    fabric = Fabric()
    members = []
    for i in range(n):
        ep = fabric.register(f"fcli{i}")
        if i in only_server_router:
            st = make_stack(ServerRouterChunnel(router_addr="router"),
                            AddressedTransport(ep))
        else:
            st = routing_stack(ep, ["b0", "b1"], "router", prefer="server")
        h = LockedConn(st.preferred())
        h.telemetry = ConnTelemetry(now=clock)
        h.telemetry.bind_reconfig(h.stats)
        pub = FleetPublisher(store, "kv", f"cli{i}", h.telemetry,
                             period_s=0.0, now=clock)
        m = FleetMember(store, "kv", f"cli{i}", h, st, publisher=pub)
        m.join()
        members.append(m)
    return store, members


def _drive(members, clock, agg, ctl, *, k_sends, n_ticks, dt=0.05):
    """Advance the fleet n_ticks control intervals at k_sends ops per member
    per interval (member qps = k_sends / dt)."""
    out = []
    for _ in range(n_ticks):
        clock.advance(dt)
        for m in members:
            for _ in range(k_sends):
                m.handle.telemetry.record_send(1, 100, 0.001)
            m.poll(clock())
        out.append(ctl.tick(agg.aggregate(clock())))
    return out


class TestFleetWideSwitch:
    def _controller(self, store, members, clock, *, params=None, sources=()):
        agg = FleetAggregator(store, "kv", ttl_s=1.0, now=clock,
                              sources=list(sources))
        ctl = fleet_controller(
            store, "kv", members[0].stack,
            policy="kv_fleet_adaptive",
            policy_params={"fleet_high_qps": 180.0, "fleet_low_qps": 110.0,
                           "hold": 2, **(params or {})},
            pump=lambda: [m.poll(clock()) for m in members],
            cooldown_s=0.0, now=clock)
        return agg, ctl

    def test_aggregate_crossing_switches_whole_fleet_in_one_epoch(self):
        clock = FakeClock()
        store, members = _mk_fleet(3, clock=clock)
        agg, ctl = self._controller(store, members, clock)

        # low: 20 qps/member, 60 aggregate — nothing fires
        _drive(members, clock, agg, ctl, k_sends=1, n_ticks=3)
        assert ctl.counts()["fired"] == 0
        assert store.get(f"{fleet_conn_id('kv')}/stack")["epoch"] == 1

        # high: 80 qps/member — EVERY member is far below the 150 qps a
        # per-client policy needs, but the aggregate (240) crosses 180
        decisions = _drive(members, clock, agg, ctl, k_sends=4, n_ticks=4)
        fired = [d for d in decisions if d.fired]
        assert len(fired) == 1 and fired[0].committed
        assert fired[0].rule == "fleet-high-load->client-shard"
        snap = fired[0].snapshot
        assert snap["fleet.offered_qps"] > 180.0
        assert max(snap["fleet.member_qps"].values()) < 150.0

        # fleet-wide, single epoch: every member runs the same stack at the
        # same committed epoch, having switched exactly once
        cur = store.get(f"{fleet_conn_id('kv')}/stack")
        assert cur["epoch"] == 2
        for m in members:
            assert repr(m.handle.stack).startswith("ClientShard")
            assert m.epoch == 2
            assert m.handle.stats.switches == 1

        # drain: aggregate below the low-water mark moves everyone back
        decisions = _drive(members, clock, agg, ctl, k_sends=1, n_ticks=4)
        assert [d for d in decisions if d.fired and d.committed]
        cur = store.get(f"{fleet_conn_id('kv')}/stack")
        assert cur["epoch"] == 3
        for m in members:
            assert repr(m.handle.stack).startswith("ServerRouter")
            assert m.handle.stats.switches == 2

    def test_multi_source_predicate_combines_aggregate_and_signal(self):
        """A spot-price spike (external SignalSource) while aggregate load is
        below the high-water mark consolidates the fleet behind the router —
        neither signal alone arms the rule."""
        clock = FakeClock()
        store, members = _mk_fleet(3, clock=clock)
        spot = SpotPriceSignal([0.5, 5.0], period_s=100.0, now=clock)
        agg, ctl = self._controller(
            store, members, clock,
            params={"fleet_high_qps": 200.0, "spot_cap_usd_per_h": 3.0},
            sources=[spot])

        # get the fleet onto ClientShard first (high load, cheap spot)
        _drive(members, clock, agg, ctl, k_sends=4, n_ticks=4)  # 240 qps agg
        assert all(repr(m.handle.stack).startswith("ClientShard")
                   for m in members)

        # mid load (180 < 200) + cheap spot: nothing fires
        before = ctl.counts()["fired"]
        _drive(members, clock, agg, ctl, k_sends=3, n_ticks=3)
        assert ctl.counts()["fired"] == before

        # same mid load, spot spikes over the cap -> the multi-source rule
        clock.advance(100.0 - clock() % 100.0)  # move the trace to 5.0 $/h
        decisions = _drive(members, clock, agg, ctl, k_sends=3, n_ticks=3)
        fired = [d for d in decisions if d.fired and d.committed]
        assert fired and fired[0].rule == "fleet-spot-spike->server-router"
        assert fired[0].snapshot["ext.spot_usd_per_h"] == 5.0
        assert all(repr(m.handle.stack).startswith("ServerRouter")
                   for m in members)

    def test_member_without_target_vetoes_fleet_transition(self):
        """One member only ever offered ServerRouter: the fleet proposal to
        ClientShard aborts for EVERYONE (§4.2 at fleet scope) — no member is
        forced onto a stack it cannot run, and no member switches alone."""
        clock = FakeClock()
        store, members = _mk_fleet(3, clock=clock, only_server_router={2})
        agg, ctl = self._controller(store, members, clock)
        decisions = _drive(members, clock, agg, ctl, k_sends=4, n_ticks=4)
        refused = [d for d in decisions if d.fired]
        assert refused and not any(d.committed for d in refused)
        assert store.get(f"{fleet_conn_id('kv')}/stack")["epoch"] == 1
        assert all(repr(m.handle.stack).startswith("ServerRouter")
                   for m in members)

    def test_late_joiner_adopts_committed_stack(self):
        clock = FakeClock()
        store, members = _mk_fleet(3, clock=clock)
        agg, ctl = self._controller(store, members, clock)
        _drive(members, clock, agg, ctl, k_sends=4, n_ticks=4)
        assert store.get(f"{fleet_conn_id('kv')}/stack")["epoch"] == 2

        fabric = Fabric()
        ep = fabric.register("late")
        st = routing_stack(ep, ["b0", "b1"], "router", prefer="server")
        h = LockedConn(st.preferred())
        late = FleetMember(store, "kv", "late", h, st)
        res = late.join()
        assert not res.proposed and res.epoch == 2
        # §5.3a: recovered (and adopted) the committed stack without having
        # participated in the negotiation that picked it
        assert repr(h.stack).startswith("ClientShard")
        assert late.epoch == 2

    def test_crashed_member_is_evicted_from_commit_plane_and_can_rejoin(self):
        """A member that crashes without leave() ages out of BOTH planes:
        aggregation (roster/record) and the rendezvous membership map — so
        its missing ack cannot block every future fleet transition. If it
        comes back, its next poll() re-joins."""
        clock = FakeClock()
        store, members = _mk_fleet(3, clock=clock)
        alive, crashed = members[:2], members[2]
        agg, ctl = self._controller(store, alive, clock)

        # everyone heartbeats once, then cli2 goes silent past the TTL
        for m in members:
            m.poll(clock())
        for _ in range(30):   # ttl_s=1.0, dt=0.05: cli2 ages out
            clock.advance(0.05)
            for m in alive:
                m.poll(clock())
            agg.aggregate(clock())
        rdv = store.get(f"{fleet_conn_id('kv')}/members")
        assert sorted(rdv) == ["cli0", "cli1"]
        assert store.get("fleet/kv/member/cli2") is None

        # the surviving fleet can still commit a transition (unanimous acks
        # no longer include the dead member): 100 qps each, 200 aggregate
        decisions = _drive(alive, clock, agg, ctl, k_sends=5, n_ticks=4)
        assert [d for d in decisions if d.fired and d.committed]
        assert store.get(f"{fleet_conn_id('kv')}/stack")["epoch"] == 2
        assert all(repr(m.handle.stack).startswith("ClientShard")
                   for m in alive)

        # revival: the evicted member's next poll re-joins and adopts the
        # committed stack it missed
        crashed.poll(clock())
        rdv = store.get(f"{fleet_conn_id('kv')}/members")
        assert "cli2" in rdv
        assert repr(crashed.handle.stack).startswith("ClientShard")
        assert crashed.epoch == 2

    def test_failed_switch_attempts_are_backed_off(self):
        """A refused transition must not become a propose/abort storm: after
        a failed attempt, no new proposal is published until retry_backoff_s
        passes, even though the rule stays armed every tick."""
        clock = FakeClock()
        store, members = _mk_fleet(3, clock=clock, only_server_router={2})
        agg = FleetAggregator(store, "kv", ttl_s=10.0, now=clock)
        ctl = fleet_controller(
            store, "kv", members[0].stack,
            policy="kv_fleet_adaptive",
            policy_params={"fleet_high_qps": 180.0, "fleet_low_qps": 110.0,
                           "hold": 2},
            pump=lambda: [m.poll(clock()) for m in members],
            retry_backoff_s=3600.0,   # effectively: one attempt only
            cooldown_s=0.0, now=clock)
        before = store.version(f"{fleet_conn_id('kv')}/proposal")
        decisions = _drive(members, clock, agg, ctl, k_sends=4, n_ticks=6)
        # ONE real attempt (propose + 3 votes + aborting try_commit = 5
        # proposal-version bumps), then pure backoff — not one per armed tick
        bumps = store.version(f"{fleet_conn_id('kv')}/proposal") - before
        assert bumps <= 6, bumps
        assert ctl.counts()["committed"] == 0
        # the rule stayed armed and kept firing; only the proposal was damped
        assert sum(d.fired for d in decisions) > 1

    def test_unresolvable_commit_keeps_member_epoch_behind(self):
        """A committed fingerprint a member cannot run must not be silently
        marked adopted: the epoch stays behind (the divergence is visible in
        ``transitions``), it is logged once — and a later resolvable commit
        is still picked up."""
        clock = FakeClock()
        store, members = _mk_fleet(1, clock=clock)
        m = members[0]
        assert not m._adopt("Bogus(caps)<x->y>", 5)
        assert m.epoch == 1  # still the join epoch
        assert m.transitions == [
            {"epoch": 5, "fp": "Bogus(caps)<x->y>", "applied": False}]
        assert not m._adopt("Bogus(caps)<x->y>", 5)
        assert len(m.transitions) == 1  # logged once per epoch
        # a later epoch with a fingerprint we CAN run is adopted normally
        target = m.stack.options()[1]
        assert m._adopt(target.fingerprint(), 6)
        assert m.epoch == 6
        assert repr(m.handle.stack).startswith("ClientShard")

    def test_concurrent_proposal_reports_uncommitted(self):
        clock = FakeClock()
        store, members = _mk_fleet(3, clock=clock)
        agg, ctl = self._controller(store, members, clock)
        # park a foreign proposal in flight: the controller's own proposal
        # must fail cleanly (refused), not crash or double-propose
        rendezvous.propose_transition(
            store, fleet_conn_id("kv"), "someone-else", "fp-x",
            [{"name": "X", "caps": []}])
        decisions = _drive(members, clock, agg, ctl, k_sends=4, n_ticks=3)
        fired = [d for d in decisions if d.fired]
        assert fired and not any(d.committed for d in fired)
        assert all(d.reason == "refused" for d in fired)
