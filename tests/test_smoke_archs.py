"""Per-arch smoke tests: reduced config of the same family, one forward/train
step on CPU, asserting output shapes and no NaNs (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.configs.base import ShapeConfig
from repro.models import build

B, S = 2, 32


def make_batch(model, cfg):
    rng = jax.random.PRNGKey(1)
    batch = {
        "tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(rng, (B, S), 0, cfg.vocab_size),
    }
    if cfg.family == "vlm":
        f = cfg.frontend
        batch["patches"] = jax.random.normal(rng, (B, f.num_positions, f.embed_dim),
                                             jnp.bfloat16)
    if cfg.family == "audio":
        src = max(1, S // cfg.encdec.src_ratio)
        batch["frames"] = jax.random.normal(rng, (B, src, cfg.frontend.embed_dim),
                                            jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = get_smoke_config(arch)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(model, cfg)

    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    # sane magnitude: random init => loss near ln(vocab)
    assert 0.5 * np.log(cfg.vocab_size) < float(loss) < 3 * np.log(cfg.vocab_size) + 1
    for path, g in jax.tree_util.tree_flatten_with_path(grads)[0]:
        assert bool(jnp.all(jnp.isfinite(g))), f"{arch}: non-finite grad at {path}"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_smoke(arch):
    cfg = get_smoke_config(arch)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(model, cfg)
    prefill_batch = {k: v for k, v in batch.items() if k != "labels"}

    cache, logits = jax.jit(model.prefill)(params, prefill_batch)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: non-finite prefill logits"

    # grow dense-style caches so one more token fits
    def grow(leaf):
        if hasattr(leaf, "ndim") and leaf.ndim >= 4:  # kv leaves (..., B, S, KH, hd)
            pad = [(0, 0)] * leaf.ndim
            pad[-3] = (0, 4)
            return jnp.pad(leaf, pad)
        return leaf

    if cfg.family in ("dense", "moe", "vlm", "audio"):
        cache = jax.tree.map(grow, cache)
    if cfg.family == "hybrid":
        for i in cfg.global_layers:
            for n in ("k", "v"):
                cache["layers"][i][n] = jnp.pad(
                    cache["layers"][i][n], ((0, 0), (0, 4), (0, 0), (0, 0))
                )

    nt = jnp.argmax(logits, -1)[:, None]
    cache2, logits2 = jax.jit(model.decode)(params, cache, {"tokens": nt})
    assert logits2.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits2))), f"{arch}: non-finite decode logits"
    assert int(cache2["len"]) == S + 1
