"""Closed-loop reconfiguration controller tests: telemetry estimators, policy
damping (hysteresis / cooldown / no-flap), conn-level integration (unilateral
and multilateral 2PC switches from live telemetry), and the trainer plane."""
import os
import random
import time

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import pytest

from repro.core import (
    CapabilitySet,
    ConnTelemetry,
    EwmaQuantile,
    Fabric,
    FabricTransport,
    FnChunnel,
    HostAgent,
    LockedConn,
    ReconfigController,
    Rule,
    Select,
    WireType,
    above,
    below,
    conn_controller,
    make_stack,
    option_named,
)
from repro.core.reconfigure import ReconfigParticipant, ReconfigStats


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def T(name, upper="obj", lower="unit", caps=None, multilateral=False):
    return FnChunnel(fn_name=name, upper=WireType.of(upper),
                     lower=WireType.of(lower), caps=caps,
                     multilateral_=multilateral)


class TestEwmaQuantile:
    def test_quantile_ordering_on_uniform(self):
        rng = random.Random(0)
        p50, p95 = EwmaQuantile(0.50), EwmaQuantile(0.95)
        for _ in range(5000):
            x = rng.uniform(0.0, 1.0)
            p50.update(x)
            p95.update(x)
        assert 0.3 < p50.value < 0.7
        assert p95.value > p50.value
        assert p95.value > 0.75

    def test_tracks_level_shift(self):
        q = EwmaQuantile(0.5)
        for _ in range(300):
            q.update(1.0)
        low = q.value
        for _ in range(600):
            q.update(10.0)
        assert q.value > low + 1.0


class TestTelemetry:
    def test_counters_and_windowed_rates(self):
        clock = FakeClock()
        t = ConnTelemetry(now=clock)
        for _ in range(10):
            t.record_send(2, 100, 0.001)
        clock.advance(2.0)
        s = t.snapshot()
        assert s["ops"] == 10 and s["msgs_out"] == 20 and s["bytes_out"] == 1000
        assert s["ops_per_s"] == pytest.approx(5.0)
        assert s["bytes_per_s"] == pytest.approx(500.0)
        clock.advance(1.0)  # nothing new in this window
        assert t.snapshot()["ops_per_s"] == 0.0

    def test_straggler_ratio_needs_two_pods(self):
        t = ConnTelemetry()
        for _ in range(30):
            t.record_step({"a": 0.1})
        assert t.straggler_ratio() == 1.0
        for _ in range(30):
            t.record_step({"b": 0.1, "c": 0.3})
        assert t.straggler_ratio() == pytest.approx(3.0, rel=0.2)

    def test_straggler_excluded_from_its_own_baseline(self):
        # with the straggler inside the denominator a 2-pod job could never
        # read above 2.0 (3x straggler -> exactly 1.5), capping thresholds
        t = ConnTelemetry()
        for _ in range(30):
            t.record_step({"a": 0.1, "b": 0.3})
        assert t.straggler_ratio() == pytest.approx(3.0, rel=0.2)

    def test_steps_counted_once_per_step_not_per_pod(self):
        t = ConnTelemetry()
        for _ in range(10):
            t.record_step({"a": 0.1, "b": 0.1, "c": 0.1})
        s = t.snapshot()
        assert s["steps"] == 10 and s["ops"] == 10  # not inflated by n_pods

    def test_reconfig_stats_folded_into_snapshot(self):
        t = ConnTelemetry()
        st = ReconfigStats()
        t.bind_reconfig(st)
        st.switches, st.last_switch_s = 2, 0.5
        s = t.snapshot()
        assert s["switches"] == 2 and s["last_switch_s"] == 0.5

    def test_batch_shape_counters(self):
        # PR 7: the data plane is batched, so telemetry tracks msgs/op shape
        t = ConnTelemetry()
        for n in (1, 1, 3, 8, 64, 0):
            t.record_send(n, 10 * n, 0.001)
        s = t.snapshot()
        assert s["batch_hist"] == {"1": 2, "2-3": 1, "8-15": 1, "64-127": 1,
                                   "0": 1}
        assert s["msgs_per_op"] == pytest.approx((1 + 1 + 3 + 8 + 64) / 6)
        assert s["batch_p50"] <= s["batch_p95"]

    def test_batch_quantiles_track_batch_size(self):
        t = ConnTelemetry()
        for _ in range(200):
            t.record_send(64, 64, 0.001)
        s = t.snapshot()
        assert s["batch_p50"] == pytest.approx(64, rel=0.2)
        assert s["batch_p95"] == pytest.approx(64, rel=0.2)


class TestControllerPolicy:
    def mk(self, rules, *, clock=None, cooldown=0.0, refuse=False, start="A"):
        committed = []
        cur = {"v": start}

        def switch(target):
            if refuse:
                return False
            committed.append(target)
            cur["v"] = target
            return True

        ctl = ReconfigController(rules, switch, lambda: cur["v"],
                                 cooldown_s=cooldown,
                                 now=clock if clock is not None else time.monotonic)
        return ctl, committed

    def test_hysteresis_requires_consecutive_ticks(self):
        ctl, committed = self.mk([Rule("hot", above("x", 1.0), "B", hold=3)])
        for snap in ({"x": 2}, {"x": 2}, {"x": 0}, {"x": 2}, {"x": 2}):
            d = ctl.tick(snap)
            assert not d.fired
        assert committed == []
        d = ctl.tick({"x": 2})  # third consecutive tick above threshold
        assert d.fired and d.committed and committed == ["B"]

    def test_no_flap_under_oscillating_telemetry(self):
        rules = [Rule("hot", above("x", 1.0), "B", hold=2, priority=1),
                 Rule("cold", below("x", 1.0), "A", hold=2)]
        ctl, committed = self.mk(rules)
        for i in range(60):
            ctl.tick({"x": 2.0 if i % 2 == 0 else 0.0})
        assert committed == []  # neither predicate ever holds twice in a row

    def test_cooldown_blocks_then_releases(self):
        clock = FakeClock()
        rules = [Rule("hot", above("x", 1.0), "B", hold=1, priority=1),
                 Rule("cold", below("x", 1.0), "A", hold=1)]
        ctl, committed = self.mk(rules, clock=clock, cooldown=10.0)
        assert ctl.tick({"x": 2.0}).committed  # A -> B
        clock.advance(1.0)
        d = ctl.tick({"x": 0.0})  # cold armed but inside cooldown
        assert not d.fired and d.reason == "cooldown"
        clock.advance(20.0)
        d = ctl.tick({"x": 0.0})
        assert d.committed and committed == ["B", "A"]

    def test_current_target_never_reselected(self):
        ctl, committed = self.mk([Rule("same", above("x", 1.0), "A", hold=1)])
        for _ in range(5):
            d = ctl.tick({"x": 2.0})
            assert d.reason == "idle"
        assert committed == []

    def test_priority_breaks_same_tick_ties(self):
        rules = [Rule("lo", above("x", 1.0), "B", hold=1, priority=0),
                 Rule("hi", above("x", 1.0), "C", hold=1, priority=5)]
        ctl, committed = self.mk(rules)
        ctl.tick({"x": 2.0})
        assert committed == ["C"]

    def test_refused_switch_reported_and_no_cooldown(self):
        clock = FakeClock()
        ctl, committed = self.mk([Rule("hot", above("x", 1.0), "B", hold=1)],
                                 clock=clock, cooldown=10.0, refuse=True)
        d = ctl.tick({"x": 2.0})
        assert d.fired and not d.committed and d.reason == "refused"
        d = ctl.tick({"x": 2.0})  # refusal must not start the cooldown timer
        assert d.fired and d.reason == "refused"
        assert committed == []

    def test_missing_metric_does_not_arm(self):
        ctl, committed = self.mk([Rule("hot", above("x", 1.0), "B", hold=1)])
        d = ctl.tick({"y": 5.0})
        assert d.reason == "idle" and committed == []

    def test_satisfied_high_priority_rule_suppresses_lower(self):
        # two persistently-armed rules with different targets (straggler=>B,
        # budget=>C) must not ping-pong: once B is active the satisfied
        # high-priority rule claims every tick and the budget rule stays quiet
        rules = [Rule("strag", above("x", 1.0), "B", hold=1, priority=2),
                 Rule("budget", above("y", 1.0), "C", hold=1, priority=1)]
        ctl, committed = self.mk(rules)
        for _ in range(10):
            ctl.tick({"x": 2.0, "y": 2.0})
        assert committed == ["B"]

    def test_duplicate_rule_names_rejected(self):
        with pytest.raises(ValueError):
            ReconfigController(
                [Rule("r", above("x", 1.0), "B"), Rule("r", below("y", 1.0), "C")],
                lambda t: True, lambda: "A")

    def test_decision_log_is_bounded(self):
        # max_decisions is the legacy alias of max_history — both must bound
        ctl = ReconfigController([Rule("hot", above("x", 1.0), "B", hold=99)],
                                 lambda t: True, lambda: "A", max_decisions=10)
        for _ in range(50):
            ctl.tick({"x": 0.0})
        assert len(ctl.decisions) == 10

    def test_counts_survive_history_eviction(self):
        # every tick fires (target B never becomes current): with only 5
        # retained decisions, the lifetime totals must still count all 20
        ctl = ReconfigController([Rule("hot", above("x", 1.0), "B", hold=1)],
                                 lambda t: True, lambda: "A",
                                 max_history=5, cooldown_s=0.0)
        for _ in range(20):
            ctl.tick({"x": 2.0})
        assert len(ctl.decisions) == 5
        assert len(ctl.switch_log()) == 5          # windowed view
        c = ctl.counts()                           # lifetime view
        assert c == {"ticks": 20, "fired": 20, "committed": 20,
                     "by_rule": {"hot": 20}}

    def test_counts_track_refused_switches(self):
        ctl = ReconfigController([Rule("hot", above("x", 1.0), "B", hold=1)],
                                 lambda t: False, lambda: "A",
                                 max_history=4, cooldown_s=0.0)
        for _ in range(9):
            ctl.tick({"x": 2.0})
        c = ctl.counts()
        assert c["fired"] == 9 and c["committed"] == 0
        assert not ctl.switch_log()


class TestConnControllerIntegration:
    def test_unilateral_switch_from_live_telemetry(self):
        fabric = Fabric()
        ep = fabric.register("ctl-uni")
        stack = make_stack(Select(T("A", "bytes", "bytes"), T("B", "bytes", "bytes")),
                           FabricTransport(ep, "sink"))
        handle = LockedConn(stack.preferred())
        ctl = conn_controller(
            handle, stack,
            [Rule("busy", above("ops_per_s", 10.0),
                  option_named(stack, "B"), hold=2)],
            cooldown_s=0.0)
        for _ in range(100):
            handle.send([b"x"])
        assert not ctl.tick(handle.telemetry.snapshot()).fired  # hold=2
        for _ in range(100):
            handle.send([b"x"])
        d = ctl.tick(handle.telemetry.snapshot())
        assert d.fired and d.committed
        assert handle.stack.chunnels[0].name == "B"
        assert handle.telemetry.snapshot()["switches"] == 1  # blip folded in

    def test_multilateral_switch_runs_2pc(self):
        fabric = Fabric()
        srv = HostAgent(fabric, "ctl-srv")
        cli = HostAgent(fabric, "ctl-cli")
        caps = CapabilitySet.exact("x")
        stack = make_stack(Select(T("A", caps=caps, multilateral=True),
                                  T("B", caps=caps, multilateral=True)))
        srv.listen(stack)
        conn = cli.connect("ctl-srv", stack)
        assert conn.stack.chunnels[0].name == "A"
        srv_handle = LockedConn(srv.accept_stack("ctl-cli"))
        srv.register_participant("c1", srv_handle, stack.find)
        ctl = conn_controller(
            conn, stack,
            [Rule("go", above("ops", -1.0), option_named(stack, "B"), hold=1)],
            agent=cli, peers=["ctl-srv"], conn_id="c1", cooldown_s=0.0)
        d = ctl.tick(conn.telemetry.snapshot())
        assert d.committed
        assert conn.stack.chunnels[0].name == "B"      # client swapped
        assert srv_handle.stack.chunnels[0].name == "B"  # peer swapped via 2PC
        srv.close(); cli.close()

    def test_multilateral_target_without_agent_refused(self):
        stack = make_stack(Select(T("A", multilateral=True),
                                  T("B", multilateral=True)))
        handle = LockedConn(stack.preferred())
        with pytest.raises(ValueError, match="multilateral"):
            conn_controller(
                handle, stack,
                [Rule("go", above("ops", -1.0), option_named(stack, "B"), hold=1)])


class TestPreparedPeerResync:
    """A 2PC peer that misses the commit notification must resync eagerly via
    the epoch query instead of waiting for its next prepare (presumed-commit
    fix, ROADMAP)."""

    def _stack(self):
        caps = CapabilitySet.exact("x")
        return make_stack(Select(T("A", caps=caps, multilateral=True),
                                 T("B", caps=caps, multilateral=True)))

    def test_missed_commit_applied_from_epoch_query(self):
        clock = FakeClock()
        stack = self._stack()
        handle = LockedConn(stack.preferred())
        part = ReconfigParticipant(handle, stack.find,
                                   resync_after_s=1.0, now=clock)
        target = option_named(stack, "B")
        r = part.handle_msg("coord", {"type": "reconfig_prepare",
                                      "fp": target.fingerprint()})
        assert r["type"] == "reconfig_ready"
        # commit notification lost; not yet overdue
        assert part.needs_resync() is None
        clock.advance(2.0)
        assert part.needs_resync() == "coord"  # query the prepare's sender
        # coordinator swapped (its epoch advanced): peer adopts the commit
        applied = part.apply_state({"type": "reconfig_state", "epoch": 1,
                                    "fp": target.fingerprint()})
        assert applied and handle.stack.chunnels[0].name == "B"
        assert part.epoch == 1 and part.needs_resync() is None

    def test_aborted_proposal_clears_prepared_state(self):
        clock = FakeClock()
        stack = self._stack()
        handle = LockedConn(stack.preferred())
        part = ReconfigParticipant(handle, stack.find,
                                   resync_after_s=1.0, now=clock)
        target = option_named(stack, "B")
        part.handle_msg("coord", {"type": "reconfig_prepare",
                                  "fp": target.fingerprint()})
        clock.advance(2.0)
        # coordinator reports no new epoch (proposal aborted elsewhere)
        applied = part.apply_state({"type": "reconfig_state", "epoch": 0,
                                    "fp": stack.preferred().fingerprint()})
        assert not applied and handle.stack.chunnels[0].name == "A"
        assert part.needs_resync() is None  # stale prepared state cleared

    def test_pending_reply_defers_instead_of_clearing(self):
        # during phase 1 nothing is decided: a resync landing then must keep
        # the peer prepared (re-query next window), not misread the unchanged
        # epoch as an abort and later refuse the real commit
        clock = FakeClock()
        stack = self._stack()
        handle = LockedConn(stack.preferred())
        part = ReconfigParticipant(handle, stack.find,
                                   resync_after_s=1.0, now=clock)
        target = option_named(stack, "B")
        part.handle_msg("coord", {"type": "reconfig_prepare",
                                  "fp": target.fingerprint()})
        clock.advance(2.0)
        assert part.needs_resync() == "coord"
        applied = part.apply_state({"type": "reconfig_state", "epoch": 0,
                                    "fp": stack.preferred().fingerprint(),
                                    "pending": True})
        assert not applied
        assert part.needs_resync() is None  # deferred, but still prepared...
        clock.advance(2.0)
        assert part.needs_resync() == "coord"  # ...so the next window re-asks
        # and the eventually-arriving commit still lands normally
        r = part.handle_msg("coord", {"type": "reconfig_commit",
                                      "fp": target.fingerprint(), "epoch": 1})
        assert r["type"] == "reconfig_done"
        assert handle.stack.chunnels[0].name == "B" and part.epoch == 1

    def test_refuse_reply_clears_prepared_state(self):
        clock = FakeClock()
        stack = self._stack()
        handle = LockedConn(stack.preferred())
        part = ReconfigParticipant(handle, stack.find,
                                   resync_after_s=1.0, now=clock)
        part.handle_msg("coord", {"type": "reconfig_prepare",
                                  "fp": option_named(stack, "B").fingerprint()})
        clock.advance(2.0)
        assert not part.apply_state({"type": "reconfig_refuse"})
        assert part.needs_resync() is None
        assert handle.stack.chunnels[0].name == "A"

    def test_in_flight_commit_query_answers_with_decided_epoch(self):
        # phase-2 notifications can block for seconds on an unreachable peer
        # while the coordinator's local swap has not applied yet; a query in
        # that window must see the commit DECISION, or a merely-delayed peer
        # reads "aborted", clears prepared, and refuses the real commit
        fabric = Fabric()
        coord = HostAgent(fabric, "rs-dec")
        querier = HostAgent(fabric, "rs-q")
        stack = self._stack()
        handle = LockedConn(stack.preferred())
        target = option_named(stack, "B")
        try:
            coord.coordinate("c1", handle)
            # what two_phase_commit's on_decide hook records at commit point
            coord.record_decision("c1", handle.stats.switches + 1,
                                  target.fingerprint())
            r = querier.request("rs-dec", {"type": "reconfig_query",
                                           "conn": "c1"})
            assert r["type"] == "reconfig_state"
            assert r["epoch"] == 1 and r["fp"] == target.fingerprint()
            # once the local swap lands, live state and decision agree
            handle.reconfigure(target)
            r = querier.request("rs-dec", {"type": "reconfig_query",
                                           "conn": "c1"})
            assert r["epoch"] == 1 and r["fp"] == target.fingerprint()
        finally:
            coord.close(); querier.close()

    def test_agent_loop_resyncs_prepared_peer_end_to_end(self):
        fabric = Fabric()
        coord = HostAgent(fabric, "rs-coord")
        peer = HostAgent(fabric, "rs-peer")
        stack = self._stack()
        peer_handle = LockedConn(stack.preferred())
        peer.register_participant("c1", peer_handle, stack.find,
                                  resync_after_s=0.2)
        coord_handle = LockedConn(stack.preferred())
        target = option_named(stack, "B")
        try:
            # phase 1 reaches the peer...
            r = coord.request("rs-peer", {"type": "reconfig_prepare",
                                          "fp": target.fingerprint(),
                                          "conn": "c1"})
            assert r["type"] == "reconfig_ready"
            # ...then the commit notification is "lost": the coordinator
            # swaps locally and only answers queries
            coord.coordinate("c1", coord_handle)
            coord_handle.reconfigure(target)
            deadline = time.monotonic() + 3.0
            while (time.monotonic() < deadline
                   and peer_handle.stack.chunnels[0].name != "B"):
                time.sleep(0.02)
            assert peer_handle.stack.chunnels[0].name == "B"
        finally:
            coord.close(); peer.close()


class TestTrainerControllerPlane:
    def test_trainer_controller_initiates_mitigation(self):
        import jax
        from repro import compat
        from repro.configs import get_smoke_config
        from repro.configs.base import ShapeConfig, TrainConfig
        from repro.data.synthetic import batches_for
        from repro.launch.mesh import make_test_mesh
        from repro.train.trainer import HostSpec, ReconfigurableTrainer

        cfg = get_smoke_config("llama3.2-1b")
        shape = ShapeConfig("ctl-test", 64, 4, "train")
        mesh = make_test_mesh((2, 1), ("pod", "model"))
        offers = ["xla", "localsgd"]

        def pod_times(step_idx, dt):
            # host1's heartbeat reports a persistent 3x straggler from step 3
            return {"host0": dt, "host1": dt * (3.0 if step_idx >= 3 else 1.0)}

        # use_mesh, not set_mesh: the ambient mesh must not leak into test
        # modules that run later (compat.set_mesh is deliberately persistent)
        with compat.use_mesh(mesh):
            tr = ReconfigurableTrainer(
                cfg, shape, mesh,
                tcfg=TrainConfig(learning_rate=1e-3, warmup_steps=2, total_steps=32),
                transport="xla",
                hosts=[HostSpec(0, list(offers)), HostSpec(1, list(offers))],
            )
            ctl = tr.make_controller(straggler_threshold=1.3, hold=2, cooldown_s=0.0)
            state = tr.init_state(jax.random.PRNGKey(0))
            gen = batches_for(cfg, shape)
            state, hist = tr.run(state, gen, 12, controller=ctl, pod_times=pod_times)
        assert tr.transport_name == "localsgd"
        last = tr.reconfig_log[-1]
        assert last["committed"] and last["from"] == "xla" and last["to"] == "localsgd"
        fired = [d for d in ctl.decisions if d.fired and d.committed]
        assert fired and fired[0].rule == "straggler->mitigation"
        assert all(l == l for l in (float(m["loss"]) for m in hist))  # finite

    def test_policy_cannot_override_peer_negotiation(self):
        # a transition target outside a PEER's offer set must abort at the
        # rendezvous vote (the proposer consents by proposing; peers veto)
        from repro import compat
        from repro.configs import get_smoke_config
        from repro.configs.base import ShapeConfig
        from repro.launch.mesh import make_test_mesh
        from repro.train.trainer import HostSpec, ReconfigurableTrainer

        cfg = get_smoke_config("llama3.2-1b")
        mesh = make_test_mesh((2, 1), ("pod", "model"))
        with compat.use_mesh(mesh):
            tr = ReconfigurableTrainer(
                cfg, ShapeConfig("veto", 64, 4, "train"), mesh,
                transport="xla",
                hosts=[HostSpec(0, ["xla", "localsgd"]), HostSpec(1, ["xla"])],
            )
            tr.reconfigure(None, "localsgd")  # host1 never offered localsgd
        assert tr.reconfig_log[-1]["committed"] is False
        assert tr.transport_name == "xla"
