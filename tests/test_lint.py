"""Tests for repro.lint: every rule gets a good/bad fixture pair, the runtime
stack verifier is proven clean on the repo's real stacks and loud on seeded-bad
ones, and the CI contract (``--strict`` clean over src/repro) is itself a test.
"""
import json
import subprocess
import sys
from pathlib import Path

import pytest

from repro.core import CapabilitySet, FnChunnel, Select, WireType, make_stack
from repro.lint import (
    RULES,
    builtin_stacks,
    lint_paths,
    lint_sources,
    verify_stack,
)
from repro.lint.findings import apply_baseline, load_baseline, write_baseline

REPO = Path(__file__).resolve().parents[1]
SRC = REPO / "src" / "repro"

# fixture paths: hygiene/concurrency rules scope on path fragments, so bad
# snippets are "located" inside the control plane
CORE = "src/repro/core/fixture.py"


def rules_of(findings):
    return {f.rule for f in findings}


def T(name, upper, lower, caps=None, multilateral=False):
    return FnChunnel(
        fn_name=name,
        upper=WireType.of(upper),
        lower=WireType.of(lower),
        caps=caps,
        multilateral_=multilateral,
    )


# ---------------------------------------------------------------------------
# stack verifier: static (AST) half
# ---------------------------------------------------------------------------


class TestMigrateSignature:
    def test_bad_arity_flagged(self):
        src = (
            "class C:\n"
            "    def migrate_state(self):\n"
            "        return {}\n"
            "    def apply_state(self, state, extra):\n"
            "        pass\n"
        )
        fs = lint_sources({CORE: src})
        assert [f.rule for f in fs] == ["stack-migrate-signature"] * 2

    def test_good_arity_clean(self):
        src = (
            "class C:\n"
            "    def migrate_state(self, old):\n"
            "        return {}\n"
            "    def apply_state(self, state):\n"
            "        pass\n"
            "    def restore_state(self, state):\n"
            "        pass\n"
        )
        assert lint_sources({CORE: src}) == []

    def test_star_args_flagged(self):
        src = "class C:\n    def migrate_state(self, *a):\n        pass\n"
        assert rules_of(lint_sources({CORE: src})) == {"stack-migrate-signature"}


# ---------------------------------------------------------------------------
# stack verifier: runtime half
# ---------------------------------------------------------------------------


class TestVerifyStack:
    def test_shipped_stacks_clean(self):
        # the satellite guarantee: zero false positives on the real router
        # Select and the trainer transport Select (imports jax)
        for name, stack in builtin_stacks().items():
            assert verify_stack(stack, name) == [], name

    def test_dead_option_detected(self):
        # B's lower type clashes with the transport: that Select arm is dead
        st = make_stack(
            Select(T("A", "obj", "bytes"), T("B", "obj", "string")),
            T("Udp", "bytes", "unit"),
        )
        fs = verify_stack(st, "seeded")
        assert rules_of(fs) == {"stack-dead-option"}
        assert "B" in fs[0].message

    def test_capability_closure_violation(self):
        # exact wire capabilities differ across options on NON-multilateral
        # chunnels: a unilateral swap would break the wire contract
        st = make_stack(Select(
            T("Json", "obj", "unit", CapabilitySet.exact("fmt:json")),
            T("Proto", "obj", "unit", CapabilitySet.exact("fmt:proto")),
        ))
        assert rules_of(verify_stack(st, "seeded")) == {"stack-capability-closure"}

    def test_capability_closure_ok_when_multilateral(self):
        st = make_stack(Select(
            T("Json", "obj", "unit", CapabilitySet.exact("fmt:json"),
              multilateral=True),
            T("Proto", "obj", "unit", CapabilitySet.exact("fmt:proto"),
              multilateral=True),
        ))
        assert verify_stack(st, "ok") == []

    def test_compose_capabilities_never_block(self):
        st = make_stack(Select(
            T("A", "obj", "unit", CapabilitySet.compose("route:a")),
            T("B", "obj", "unit", CapabilitySet.compose("route:b")),
        ))
        assert verify_stack(st, "ok") == []

    def test_swap_alignment_name_reuse_across_classes(self):
        class Other(FnChunnel):
            pass

        st = make_stack(Select(
            T("Same", "obj", "unit"),
            Other(fn_name="Same", upper=WireType.of("obj"),
                  lower=WireType.of("unit")),
        ))
        assert rules_of(verify_stack(st, "seeded")) == {"stack-swap-alignment"}

    def test_swap_alignment_duplicate_in_one_option(self):
        st = make_stack(T("Dup", "obj", "obj"), T("Dup", "obj", "unit"))
        assert rules_of(verify_stack(st, "seeded")) == {"stack-swap-alignment"}

    def test_semantic_order(self):
        comp = T("Lz", "obj", "obj", CapabilitySet.exact("compression:lz"),
                 multilateral=True)
        rel = T("Ack", "obj", "obj", CapabilitySet.exact("reliability:ack"),
                multilateral=True)
        udp = T("Udp", "obj", "unit")
        good = make_stack(comp, rel, udp)
        assert verify_stack(good, "good") == []
        bad = make_stack(rel, comp, udp)
        fs = verify_stack(bad, "seeded")
        assert rules_of(fs) == {"stack-semantic-order"}
        assert "reliability" in fs[0].message


# ---------------------------------------------------------------------------
# concurrency analyzer
# ---------------------------------------------------------------------------


LOCK_PREAMBLE = (
    "import threading\n"
    "import time\n"
    "import queue\n"
    "class C:\n"
    "    def __init__(self):\n"
    "        self._lock = threading.Lock()\n"
    "        self._other = threading.Lock()\n"
    "        self._q = queue.Queue()\n"
    "        self.x = 0\n"
)


class TestLockOrder:
    def test_inversion_detected(self):
        src = LOCK_PREAMBLE + (
            "    def a(self):\n"
            "        with self._lock:\n"
            "            with self._other:\n"
            "                pass\n"
            "    def b(self):\n"
            "        with self._other:\n"
            "            with self._lock:\n"
            "                pass\n"
        )
        fs = lint_sources({CORE: src})
        assert rules_of(fs) == {"lock-order"}
        assert "opposite orders" in fs[0].message

    def test_consistent_order_clean(self):
        src = LOCK_PREAMBLE + (
            "    def a(self):\n"
            "        with self._lock:\n"
            "            with self._other:\n"
            "                pass\n"
            "    def b(self):\n"
            "        with self._lock:\n"
            "            with self._other:\n"
            "                pass\n"
        )
        assert lint_sources({CORE: src}) == []

    def test_reacquire_nonreentrant(self):
        src = LOCK_PREAMBLE + (
            "    def a(self):\n"
            "        with self._lock:\n"
            "            with self._lock:\n"
            "                pass\n"
        )
        fs = lint_sources({CORE: src})
        assert rules_of(fs) == {"lock-order"}
        assert "re-acquired" in fs[0].message

    def test_rlock_reentry_allowed(self):
        src = (
            "import threading\n"
            "class C:\n"
            "    def __init__(self):\n"
            "        self._lock = threading.RLock()\n"
            "    def a(self):\n"
            "        with self._lock:\n"
            "            with self._lock:\n"
            "                pass\n"
        )
        assert lint_sources({CORE: src}) == []


class TestBlockingUnderLock:
    def test_sleep_under_lock(self):
        src = LOCK_PREAMBLE + (
            "    def a(self):\n"
            "        with self._lock:\n"
            "            time.sleep(0.1)\n"
        )
        assert rules_of(lint_sources({CORE: src})) == {"blocking-under-lock"}

    def test_sleep_outside_lock_clean(self):
        src = LOCK_PREAMBLE + (
            "    def a(self):\n"
            "        with self._lock:\n"
            "            self.x = 1\n"
            "        time.sleep(0.1)\n"
        )
        assert lint_sources({CORE: src}) == []

    def test_queue_get_under_lock(self):
        src = LOCK_PREAMBLE + (
            "    def a(self):\n"
            "        with self._lock:\n"
            "            return self._q.get(timeout=1.0)\n"
        )
        assert rules_of(lint_sources({CORE: src})) == {"blocking-under-lock"}

    def test_kv_transact_under_lock(self):
        src = LOCK_PREAMBLE + (
            "    def a(self, store):\n"
            "        with self._lock:\n"
            "            store.transact_retry(lambda t: None)\n"
        )
        assert rules_of(lint_sources({CORE: src})) == {"blocking-under-lock"}

    def test_caller_supplied_callable_under_lock(self):
        src = LOCK_PREAMBLE + (
            "    def a(self, fn):\n"
            "        with self._lock:\n"
            "            return fn()\n"
        )
        fs = lint_sources({CORE: src})
        assert rules_of(fs) == {"blocking-under-lock"}
        assert "caller-supplied" in fs[0].message

    def test_txn_closure_analyzed_as_locked(self):
        # fn passed to a PESSIMISTIC .transact runs with the store lock held
        src = (
            "import time\n"
            "def hot(store):\n"
            "    def _fn(txn):\n"
            "        time.sleep(1.0)\n"
            "    return store.transact(_fn)\n"
        )
        fs = lint_sources({CORE: src})
        assert rules_of(fs) == {"blocking-under-lock"}
        assert "pessimistic" in fs[0].message

    def test_condition_wait_on_held_condition_allowed(self):
        src = (
            "import threading\n"
            "class C:\n"
            "    def __init__(self):\n"
            "        self._cv = threading.Condition()\n"
            "    def a(self):\n"
            "        with self._cv:\n"
            "            self._cv.wait(timeout=0.1)\n"
        )
        assert lint_sources({CORE: src}) == []

    def test_event_wait_under_lock_flagged(self):
        src = LOCK_PREAMBLE + (
            "    def a(self, ev):\n"
            "        with self._lock:\n"
            "            ev.wait()\n"
        )
        assert rules_of(lint_sources({CORE: src})) == {"blocking-under-lock"}


class TestUnguardedAttr:
    def test_unguarded_write_flagged(self):
        src = LOCK_PREAMBLE + (
            "    def a(self):\n"
            "        self.x = 1\n"
            "    def b(self):\n"
            "        with self._lock:\n"
            "            return self.x\n"
        )
        fs = lint_sources({CORE: src})
        assert rules_of(fs) == {"unguarded-attr"}
        assert "self.x" in fs[0].message

    def test_guarded_write_clean(self):
        src = LOCK_PREAMBLE + (
            "    def a(self):\n"
            "        with self._lock:\n"
            "            self.x = 1\n"
            "    def b(self):\n"
            "        with self._lock:\n"
            "            return self.x\n"
        )
        assert lint_sources({CORE: src}) == []

    def test_private_to_one_method_clean(self):
        # written without the lock but no OTHER method touches it
        src = LOCK_PREAMBLE + (
            "    def a(self):\n"
            "        self.only_here = 1\n"
            "        return self.only_here\n"
        )
        assert lint_sources({CORE: src}) == []

    def test_thread_target_write_flagged(self):
        src = (
            "import threading\n"
            "class C:\n"
            "    def __init__(self):\n"
            "        self.n = 0\n"
            "        threading.Thread(target=self._loop).start()\n"
            "    def _loop(self):\n"
            "        self.n = self.n + 1\n"
            "    def snapshot(self):\n"
            "        return self.n\n"
        )
        fs = lint_sources({CORE: src})
        assert rules_of(fs) == {"unguarded-attr"}
        assert "spawned thread" in fs[0].message


# ---------------------------------------------------------------------------
# compat boundary
# ---------------------------------------------------------------------------


class TestCompatBoundary:
    def test_direct_gated_attribute(self):
        src = "import jax\njax.shard_map(lambda x: x)\n"
        assert rules_of(lint_sources({"src/repro/comm/x.py": src})) == \
            {"compat-boundary"}

    def test_from_import_gated(self):
        src = "from jax.experimental.shard_map import shard_map\n"
        assert rules_of(lint_sources({"src/repro/comm/x.py": src})) == \
            {"compat-boundary"}

    def test_aliased_module_chain(self):
        src = ("import jax.experimental.shard_map\n"
               "f = jax.experimental.shard_map.shard_map\n")
        assert rules_of(lint_sources({"src/repro/comm/x.py": src})) == \
            {"compat-boundary"}

    def test_axis_type_and_mesh_api(self):
        src = ("from jax.sharding import AxisType\n"
               "import jax\n"
               "jax.sharding.set_mesh(None)\n")
        fs = lint_sources({"src/repro/models/x.py": src})
        assert [f.rule for f in fs] == ["compat-boundary"] * 2

    def test_make_mesh_axis_types_kwarg_only(self):
        bad = "import jax\njax.make_mesh((1,), ('x',), axis_types=None)\n"
        good = "import jax\njax.make_mesh((1,), ('x',))\n"
        assert rules_of(lint_sources({"src/repro/models/x.py": bad})) == \
            {"compat-boundary"}
        assert lint_sources({"src/repro/models/x.py": good}) == []

    def test_cost_analysis_outside_compat(self):
        bad = "def f(compiled):\n    return compiled.cost_analysis()\n"
        good = ("from repro import compat\n"
                "def f(compiled):\n    return compat.cost_analysis(compiled)\n")
        assert rules_of(lint_sources({"src/repro/launch/x.py": bad})) == \
            {"compat-boundary"}
        assert lint_sources({"src/repro/launch/x.py": good}) == []

    def test_compat_package_exempt(self):
        src = "import jax\njax.shard_map(lambda x: x)\n"
        assert lint_sources({"src/repro/compat/x.py": src}) == []

    def test_sanctioned_wrapper_clean(self):
        src = ("from repro import compat\n"
               "mesh = compat.make_mesh((1,), ('x',))\n"
               "compat.set_mesh(mesh)\n")
        assert lint_sources({"src/repro/train/x.py": src}) == []


# ---------------------------------------------------------------------------
# hygiene
# ---------------------------------------------------------------------------


class TestHygiene:
    def test_silent_except_flagged(self):
        src = "try:\n    f()\nexcept Exception:\n    pass\n"
        assert rules_of(lint_sources({CORE: src})) == {"silent-except"}

    def test_bare_except_flagged(self):
        src = "try:\n    f()\nexcept:\n    pass\n"
        assert rules_of(lint_sources({CORE: src})) == {"silent-except"}

    def test_typed_except_pass_ok(self):
        # swallowing a SPECIFIC exception is a statement, not an accident
        src = "try:\n    f()\nexcept TimeoutError:\n    pass\n"
        assert lint_sources({CORE: src}) == []

    def test_handled_broad_except_ok(self):
        src = ("import logging\n"
               "try:\n    f()\n"
               "except Exception as e:\n    logging.debug('%s', e)\n")
        assert lint_sources({CORE: src}) == []

    def test_out_of_scope_not_flagged(self):
        src = "try:\n    f()\nexcept Exception:\n    pass\n"
        assert lint_sources({"src/repro/compat/x.py": src}) == []
        assert lint_sources({"src/repro/models/x.py": src}) == []

    def test_mutable_default(self):
        bad = "def f(x, acc=[]):\n    return acc\n"
        good = "def f(x, acc=None):\n    return acc or []\n"
        assert rules_of(lint_sources({CORE: bad})) == {"mutable-default"}
        assert lint_sources({CORE: good}) == []


# ---------------------------------------------------------------------------
# pragmas + baseline
# ---------------------------------------------------------------------------


class TestPragmas:
    BAD = "def f(x, acc=[]):  # lint: allow[mutable-default] fixture justification\n    return acc\n"

    def test_inline_pragma_suppresses(self):
        assert lint_sources({CORE: self.BAD}) == []

    def test_pragma_on_line_above(self):
        src = ("# lint: allow[mutable-default] fixture justification\n"
               "def f(x, acc=[]):\n    return acc\n")
        assert lint_sources({CORE: src}) == []

    def test_pragma_needs_reason(self):
        src = "def f(x, acc=[]):  # lint: allow[mutable-default]\n    return acc\n"
        assert rules_of(lint_sources({CORE: src})) == {"pragma-missing-reason"}

    def test_unknown_rule_flagged(self):
        src = "x = 1  # lint: allow[no-such-rule] because\n"
        assert rules_of(lint_sources({CORE: src})) == {"pragma-unknown-rule"}

    def test_wrong_rule_does_not_suppress(self):
        src = "def f(x, acc=[]):  # lint: allow[silent-except] wrong rule\n    return acc\n"
        assert rules_of(lint_sources({CORE: src})) == {"mutable-default"}

    def test_def_line_pragma_covers_function(self):
        src = (
            "import threading\n"
            "class C:\n"
            "    def __init__(self):\n"
            "        self._lock = threading.Lock()\n"
            "        self.x = self.y = 0\n"
            "    def apply(self):  # lint: allow[unguarded-attr] callers hold the lock\n"
            "        self.x = 1\n"
            "        self.y = 2\n"
            "    def read(self):\n"
            "        with self._lock:\n"
            "            return self.x + self.y\n"
        )
        assert lint_sources({CORE: src}) == []

    def test_pragma_in_docstring_inert(self):
        src = '"""Docs mention # lint: allow[nope] syntax."""\nx = 1\n'
        assert lint_sources({CORE: src}) == []


def scoped(tmp_path, name="legacy.py"):
    # hygiene rules scope on the "repro/core/" path fragment
    d = tmp_path / "repro" / "core"
    d.mkdir(parents=True, exist_ok=True)
    return d / name


class TestBaseline:
    def test_roundtrip(self, tmp_path):
        f = scoped(tmp_path)
        f.write_text("def f(x, acc=[]):\n    return acc\n")
        findings, lines = lint_paths([str(f)])
        assert rules_of(findings) == {"mutable-default"}
        bl = tmp_path / "baseline.json"
        write_baseline(bl, findings, lines)
        left = apply_baseline(findings, load_baseline(bl), lines)
        assert left == []

    def test_baseline_resurfaces_on_change(self, tmp_path):
        f = scoped(tmp_path)
        f.write_text("def f(x, acc=[]):\n    return acc\n")
        findings, lines = lint_paths([str(f)])
        bl = tmp_path / "baseline.json"
        write_baseline(bl, findings, lines)
        # the flagged line CHANGES: its fingerprint no longer matches
        f.write_text("def f(y, acc=[]):\n    return acc\n")
        findings2, lines2 = lint_paths([str(f)])
        left = apply_baseline(findings2, load_baseline(bl), lines2)
        assert rules_of(left) == {"mutable-default"}


# ---------------------------------------------------------------------------
# the CI contract
# ---------------------------------------------------------------------------


class TestRepoIsClean:
    def test_src_repro_lints_clean(self):
        # what --strict enforces in CI, as a test: every suppression in the
        # tree is justified and nothing else fires
        findings, _ = lint_paths([str(SRC)], root=REPO)
        assert findings == [], [f.format() for f in findings]

    def test_every_rule_documented(self):
        for rule, doc in RULES.items():
            assert doc and len(doc) > 10, rule


class TestCLI:
    def run(self, *args, cwd=None):
        env_src = str(REPO / "src")
        return subprocess.run(
            [sys.executable, "-m", "repro.lint", *args],
            capture_output=True, text=True, cwd=cwd or REPO,
            env={"PYTHONPATH": env_src, "PATH": "/usr/bin:/bin:/usr/local/bin"},
        )

    def test_strict_fails_on_violation(self, tmp_path):
        bad = scoped(tmp_path, "bad.py")
        bad.write_text("def f(x, acc=[]):\n    return acc\n")
        r = self.run("--strict", str(bad))
        assert r.returncode == 1
        assert "mutable-default" in r.stdout

    def test_strict_clean_exits_zero(self, tmp_path):
        good = tmp_path / "good.py"
        good.write_text("def f(x, acc=None):\n    return acc\n")
        r = self.run("--strict", str(good))
        assert r.returncode == 0, r.stdout + r.stderr

    def test_json_report(self, tmp_path):
        bad = scoped(tmp_path, "bad.py")
        bad.write_text("def f(x, acc=[]):\n    return acc\n")
        out = tmp_path / "report.json"
        r = self.run(str(bad), "--json", str(out))
        assert r.returncode == 0  # not strict: report, don't fail
        rep = json.loads(out.read_text())
        assert rep["n_findings"] == 1
        assert rep["findings"][0]["rule"] == "mutable-default"

    def test_list_rules(self):
        r = self.run("--list-rules")
        assert r.returncode == 0
        for rule in ("lock-order", "compat-boundary", "stack-dead-option"):
            assert rule in r.stdout


class TestPerMessageHotPath:
    BAD_DP = (
        "class ShimDP:\n"
        "    def send(self, msgs):\n"
        "        for m in msgs:\n"
        "            self.inner.send([m])\n"
    )
    BAD_FABRIC = (
        "class Fabric:\n"
        "    def send_batch(self, src, dst, msgs):\n"
        "        for m in msgs:\n"
        "            self._eps[dst].inbox.put((src, m))\n"
    )
    GOOD_BATCH = (
        "class ShimDP:\n"
        "    def send(self, msgs):\n"
        "        out = [self.fn(m) for m in msgs]\n"
        "        self.inner.send(out)\n"
    )
    GOOD_GROUPING = (
        "class RouteDP:\n"
        "    def send(self, msgs):\n"
        "        by_dst = {}\n"
        "        for m in msgs:\n"
        "            by_dst.setdefault(m['dst'], []).append(m)\n"
        "        for dst, batch in by_dst.items():\n"
        "            self.ep.send_batch(dst, batch)\n"
    )

    def test_singleton_send_loop_flagged(self):
        assert rules_of(lint_sources({CORE: self.BAD_DP})) == {
            "per-message-hot-path"}

    def test_per_message_queue_put_flagged(self):
        assert rules_of(lint_sources({CORE: self.BAD_FABRIC})) == {
            "per-message-hot-path"}

    def test_comprehension_delivery_flagged(self):
        src = ("class PushDP:\n"
               "    def send(self, msgs):\n"
               "        [self.broker.publish(t, m) for t, m in msgs]\n")
        assert rules_of(lint_sources({CORE: src})) == {"per-message-hot-path"}

    def test_batched_send_ok(self):
        assert lint_sources({CORE: self.GOOD_BATCH}) == []

    def test_per_destination_send_batch_ok(self):
        # grouping loops that forward whole sub-batches stay legal
        assert lint_sources({CORE: self.GOOD_GROUPING}) == []

    def test_inherited_datapath_base_is_hot(self):
        src = ("class Shim(Datapath):\n"
               "    def recv(self, buf, timeout=None):\n"
               "        while True:\n"
               "            buf.append(self.inner.request(1))\n")
        assert rules_of(lint_sources({CORE: src})) == {"per-message-hot-path"}

    def test_cold_class_not_flagged(self):
        src = ("class Planner:\n"
               "    def send(self, msgs):\n"
               "        for m in msgs:\n"
               "            self.inner.send([m])\n")
        assert lint_sources({CORE: src}) == []

    def test_cold_method_not_flagged(self):
        src = ("class ShimDP:\n"
               "    def close(self):\n"
               "        for c in self.children:\n"
               "            c.send(b'bye')\n")
        assert lint_sources({CORE: src}) == []

    def test_pragma_suppresses(self):
        src = ("class ShimDP:\n"
               "    def send(self, msgs):\n"
               "        for m in msgs:\n"
               "            # lint: allow[per-message-hot-path] fixture justification\n"
               "            self.inner.send([m])\n")
        assert lint_sources({CORE: src}) == []


class TestSpanInHotLoop:
    BAD_SPAN_LOOP = (
        "class ShimDP:\n"
        "    def send(self, msgs):\n"
        "        for m in msgs:\n"
        "            with TRACER.span('msg'):\n"
        "                pass\n"
        "        self.inner.send(msgs)\n"
    )
    BAD_BEGIN_SPAN_WHILE = (
        "class Fabric:\n"
        "    def recv_many(self, buf, timeout=None):\n"
        "        while True:\n"
        "            sp = TRACER.begin_span('frame')\n"
        "            sp.end()\n"
    )
    GOOD_BATCH_SPAN = (
        "class ShimDP:\n"
        "    def send(self, msgs):\n"
        "        with TRACER.span('batch'):\n"
        "            self.inner.send(msgs)\n"
    )
    GOOD_RECORD_BATCH = (
        "class ShimDP:\n"
        "    def send(self, msgs):\n"
        "        for dst, batch in msgs.items():\n"
        "            TRACER.record_batch('chunnel.send', len(batch), len(batch))\n"
        "            self.ep.send_batch(dst, batch)\n"
    )

    def test_span_per_message_flagged(self):
        assert rules_of(lint_sources({CORE: self.BAD_SPAN_LOOP})) == {
            "span-in-hot-loop"}

    def test_begin_span_in_while_flagged(self):
        assert rules_of(lint_sources({CORE: self.BAD_BEGIN_SPAN_WHILE})) == {
            "span-in-hot-loop"}

    def test_batch_level_span_ok(self):
        assert lint_sources({CORE: self.GOOD_BATCH_SPAN}) == []

    def test_record_batch_in_loop_ok(self):
        # record_batch is the sanctioned per-batch instrument — legal even
        # inside a per-destination grouping loop
        assert lint_sources({CORE: self.GOOD_RECORD_BATCH}) == []

    def test_cold_class_span_loop_ok(self):
        src = ("class Planner:\n"
               "    def send(self, msgs):\n"
               "        for m in msgs:\n"
               "            with TRACER.span('plan'):\n"
               "                pass\n")
        assert lint_sources({CORE: src}) == []

    def test_pragma_suppresses(self):
        src = ("class ShimDP:\n"
               "    def send(self, msgs):\n"
               "        for m in msgs:\n"
               "            # lint: allow[span-in-hot-loop] fixture justification\n"
               "            sp = TRACER.span('m')\n")
        assert lint_sources({CORE: src}) == []


class TestObsHotClasses:
    """PR 10: the observability aggregation classes are hot — their per-tick
    methods run over every member/SLO, so the data-plane rules apply, and
    the SLO engine's lock discipline (compute locked, I/O after release) is
    checkable as blocking-under-lock."""

    OBS = "src/repro/obs/fixture.py"

    def test_span_per_member_in_federator_view_flagged(self):
        src = ("class MetricsFederator:\n"
               "    def view(self, now=None):\n"
               "        out = {}\n"
               "        for m, rec in self.members().items():\n"
               "            with TRACER.span('member'):\n"
               "                out[m] = rec\n"
               "        return out\n")
        assert rules_of(lint_sources({self.OBS: src})) == {"span-in-hot-loop"}

    def test_per_member_publish_loop_flagged(self):
        src = ("class MetricsPublisher:\n"
               "    def publish(self):\n"
               "        for key, rec in self.records():\n"
               "            self.store.put(key, rec)\n")
        assert rules_of(lint_sources({self.OBS: src})) == {
            "per-message-hot-path"}

    def test_kv_transact_under_engine_lock_flagged(self):
        # the SLO engine must never touch the KV plane while holding its
        # lock: the view is sampled before, side effects fire after release
        src = ("import threading\n"
               "class SLOEngine:\n"
               "    def __init__(self):\n"
               "        self._lock = threading.Lock()\n"
               "    def observe(self, store):\n"
               "        with self._lock:\n"
               "            store.transact_retry(lambda t: None)\n")
        assert rules_of(lint_sources({self.OBS: src})) == {
            "blocking-under-lock"}

    def test_compute_locked_io_after_release_clean(self):
        # the shipped SLOEngine.observe shape: fold under the lock, fire
        # recorder/tracer work on the collected list afterwards
        src = ("import threading\n"
               "class SLOEngine:\n"
               "    def __init__(self):\n"
               "        self._lock = threading.Lock()\n"
               "        self.fired = []\n"
               "    def observe(self, view, recorder):\n"
               "        with self._lock:\n"
               "            fired = list(self.fired)\n"
               "        for ev in fired:\n"
               "            recorder.dump(ev)\n")
        assert lint_sources({self.OBS: src}) == []

    def test_shipped_obs_modules_clean_under_extended_rules(self):
        fs, _src = lint_paths([SRC / "obs" / "federate.py",
                               SRC / "obs" / "slo.py"])
        assert fs == [], [str(f) for f in fs]
