"""End-to-end behaviour test for the paper's system: negotiate -> train ->
reconfigure -> checkpoint/restore, through the public API."""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import numpy as np
from repro import compat

from repro.configs import get_smoke_config
from repro.configs.base import ShapeConfig, TrainConfig
from repro.data.synthetic import batches_for
from repro.launch.mesh import make_test_mesh
from repro.train.trainer import HostSpec, ReconfigurableTrainer


def test_end_to_end_train_reconfigure_restore(tmp_path):
    cfg = get_smoke_config("qwen2-7b")
    shape = ShapeConfig("sys", 64, 4, "train")
    mesh = make_test_mesh((2, 4), ("pod", "model"))
    compat.set_mesh(mesh)
    tr = ReconfigurableTrainer(
        cfg, shape, mesh,
        tcfg=TrainConfig(learning_rate=1e-3, warmup_steps=2, total_steps=40),
        transport="psum", ckpt_dir=str(tmp_path),
        hosts=[HostSpec(0, ["psum", "compressed_int8"]),
               HostSpec(1, ["psum", "compressed_int8"])],
    )
    state = tr.init_state(jax.random.PRNGKey(0))
    gen = batches_for(cfg, shape)
    state, h1 = tr.run(state, gen, 10, ckpt_every=5)
    state = tr.reconfigure(state, "compressed_int8")
    assert tr.reconfig_log[-1]["committed"]
    state, h2 = tr.run(state, gen, 10)
    tr.save(state)
    restored, at = tr.restore()
    assert at == 20
    state, h3 = tr.run(restored, gen, 5)
    losses = [m["loss"] for m in h1 + h2 + h3]
    assert all(np.isfinite(l) for l in losses)
    assert losses[-1] < losses[0]
