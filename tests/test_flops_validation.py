"""Roofline FLOPs validation: the analytic model vs XLA cost_analysis on an
UNROLLED single-device compile (where cost_analysis counts everything exactly
once — see DESIGN.md §7 for why the scanned/partitioned numbers can't be used
directly)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat

from repro.analysis import flops as F
from repro.configs import get_config, get_smoke_config
from repro.configs.base import ShapeConfig
from repro.models import build


def hlo_flops(fn, *args):
    c = jax.jit(fn).lower(*args).compile()
    return compat.cost_analysis(c)["flops"]


class TestAnalyticFlops:
    def test_dense_fwd_matches_hlo_unrolled(self):
        """Forward-only FLOPs of a small dense config: analytic within 15% of
        the unrolled single-device HLO count."""
        cfg = get_config("llama3.2-1b").replace(
            num_layers=2, scan_layers=False, remat="none", attn_impl="xla_dense",
            loss_chunk=None, vocab_size=1024)
        B, S = 2, 256
        shape = ShapeConfig("probe", S, B, "train")
        model = build(cfg)
        batch = {
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
        params = model.param_shapes()
        measured = hlo_flops(model.loss, params, batch)
        layers_fwd, head_fwd = F.fwd_flops_layerwise(cfg, shape, "train")
        analytic = layers_fwd + head_fwd
        ratio = measured / analytic
        assert 0.85 < ratio < 1.15, f"fwd ratio {ratio}"

    def test_dense_train_matches_hlo_unrolled(self):
        """fwd+bwd (remat=none => 3x matmul fwd cost) within 20%."""
        cfg = get_config("llama3.2-1b").replace(
            num_layers=2, scan_layers=False, remat="none", attn_impl="xla_dense",
            loss_chunk=None, vocab_size=1024)
        B, S = 2, 256
        shape = ShapeConfig("probe", S, B, "train")
        model = build(cfg)
        batch = {
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
        params = model.param_shapes()
        measured = hlo_flops(jax.grad(model.loss), params, batch)
        layers_fwd, head_fwd = F.fwd_flops_layerwise(cfg, shape, "train")
        analytic = 3.0 * (layers_fwd + head_fwd)  # bwd = 2x fwd matmuls
        ratio = measured / analytic
        assert 0.75 < ratio < 1.25, f"train ratio {ratio}"

    def test_param_counts_match_declared_sizes(self):
        """Analytic parameter counts land near the archs' declared sizes."""
        expected = {
            "qwen2-7b": 7.6e9,
            "granite-34b": 34e9,
            "llama3.2-1b": 1.3e9,
            "mistral-nemo-12b": 12.5e9,
            "qwen3-moe-235b-a22b": 235e9,
            "dbrx-132b": 132e9,
            "xlstm-125m": 0.16e9,
            "phi-3-vision-4.2b": 3.9e9,
            "hymba-1.5b": 1.6e9,
        }
        for arch, want in expected.items():
            got = F.param_count(get_config(arch))
            assert 0.7 < got / want < 1.35, f"{arch}: {got/1e9:.1f}B vs {want/1e9:.1f}B"

    def test_moe_active_params(self):
        cfg = get_config("qwen3-moe-235b-a22b")
        active = F.active_param_count(cfg)
        assert 0.7 < active / 22e9 < 1.4, f"active {active/1e9:.1f}B vs ~22B"

    def test_decode_flops_scale_with_cache(self):
        cfg = get_config("llama3.2-1b")
        c1 = F.step_cost(cfg, ShapeConfig("d", 1024, 8, "decode"), {"data": 16, "model": 16})
        c2 = F.step_cost(cfg, ShapeConfig("d", 32768, 8, "decode"), {"data": 16, "model": 16})
        assert c2.flops > c1.flops  # attention grows with cache
        assert c2.bytes_hbm > c1.bytes_hbm  # cache read dominates

    def test_param_count_matches_real_tree(self):
        """Analytic count within 2% of the actual initialized tree (smoke cfg,
        modulo vocab padding which the analytic model excludes)."""
        for arch in ("qwen2-7b", "hymba-1.5b", "xlstm-125m"):
            cfg = get_smoke_config(arch)
            model = build(cfg)
            tree = model.param_shapes()
            n_real = sum(np.prod(l.shape) for l in jax.tree.leaves(tree))
            n_analytic = F.param_count(cfg)
            pad = (cfg.vocab_padded - cfg.vocab_size) * cfg.d_model
            n_real_unpadded = n_real - pad * (1 if cfg.tie_embeddings else 2)
            assert abs(n_real_unpadded - n_analytic) / n_real < 0.1, arch
