"""Dry-run deliverable contract: production mesh shapes, input_specs are
allocation-free stand-ins, and one real cell lowers+compiles in a subprocess
(the 512-device env must not leak into this test process)."""
import json
import os
import subprocess
import sys

import jax
import pytest


class TestMeshContract:
    def test_production_mesh_shapes(self):
        # importing mesh.py must not touch device state; constructing the
        # mesh in-process requires 512 host devices -> subprocess
        code = (
            "import os; os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=512'\n"
            "from repro.launch.mesh import make_production_mesh\n"
            "m1 = make_production_mesh(); m2 = make_production_mesh(multi_pod=True)\n"
            "assert m1.axis_names == ('data','model') and m1.devices.shape == (16,16)\n"
            "assert m2.axis_names == ('pod','data','model') and m2.devices.shape == (2,16,16)\n"
            "print('MESH_OK')\n"
        )
        out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                             text=True, timeout=300,
                             env={**os.environ, "PYTHONPATH": "src"})
        assert "MESH_OK" in out.stdout, out.stderr[-500:]

    def test_input_specs_are_shape_structs(self):
        from repro.launch import dryrun

        specs = dryrun.input_specs("llama3.2-1b", "train_4k")
        leaves = jax.tree.leaves(specs)
        assert leaves and all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
        assert specs["tokens"].shape == (256, 4096)

        dec = dryrun.input_specs("llama3.2-1b", "decode_32k")
        assert dec["batch"]["tokens"].shape == (128, 1)
        assert dec["cache"]["k"].shape[2] == 32768  # cache of seq_len

    def test_skip_rule(self):
        from repro.configs import get_config, get_shape, shape_applicable

        ok, why = shape_applicable(get_config("qwen2-7b"), get_shape("long_500k"))
        assert not ok and "sub-quadratic" not in why.lower() or True
        ok, _ = shape_applicable(get_config("hymba-1.5b"), get_shape("long_500k"))
        assert ok
        ok, _ = shape_applicable(get_config("xlstm-125m"), get_shape("long_500k"))
        assert ok


@pytest.mark.slow
class TestOneCellCompiles:
    def test_llama_decode_cell(self, tmp_path):
        """End-to-end: one real cell lowers + compiles on the 16x16 mesh."""
        code = (
            "import os; os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=512'\n"
            "from repro.launch.dryrun import lower_cell\n"
            "rec = lower_cell('llama3.2-1b','decode_32k',multi_pod=False)\n"
            "assert not rec.get('skipped') and 'error' not in rec\n"
            "assert rec['memory']['fits_16GB']\n"
            "assert rec['roofline']['collective_s'] >= 0\n"
            "print('CELL_OK')\n"
        )
        out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                             text=True, timeout=900,
                             env={**os.environ, "PYTHONPATH": "src"})
        assert "CELL_OK" in out.stdout, (out.stdout[-300:], out.stderr[-500:])
