"""Pallas kernel validation (interpret mode): shape/dtype sweeps vs the pure
jnp oracles + hypothesis property tests on the invariants.

hypothesis is an optional dev dependency (requirements-dev.txt): without it
the property-test methods are skipped while the parametrized oracle sweeps
still run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:

    def _skip_without_hypothesis(*_args, **_kwargs):
        def deco(fn):
            def stub(*args, **kwargs):
                pytest.skip("hypothesis not installed (see requirements-dev.txt)")

            stub.__name__ = fn.__name__
            stub.__doc__ = fn.__doc__
            return stub

        return deco

    given = settings = _skip_without_hypothesis

    class st:  # noqa: N801 - stands in for hypothesis.strategies
        integers = staticmethod(lambda *a, **k: None)
        floats = staticmethod(lambda *a, **k: None)

from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.quantize import ops as q_ops
from repro.kernels.quantize.ref import dequantize_blocks_ref, quantize_blocks_ref
from repro.kernels.quantize.quantize import dequantize_blocks, quantize_blocks
from repro.kernels.ssm_scan import ops as ssm_ops
from repro.kernels.ssm_scan.ref import ssm_scan_chunk_ref


class TestQuantizeKernel:
    @pytest.mark.parametrize("n_blocks", [1, 7, 128, 300])
    @pytest.mark.parametrize("block", [64, 256])
    def test_matches_ref_sweep(self, n_blocks, block):
        x = jax.random.normal(jax.random.PRNGKey(n_blocks), (n_blocks, block)) * 5.0
        q_k, s_k = quantize_blocks(x, block=block, interpret=True)
        q_r, s_r = quantize_blocks_ref(x, block=block)
        np.testing.assert_array_equal(np.asarray(q_k), np.asarray(q_r))
        np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), rtol=1e-6)
        y_k = dequantize_blocks(q_k, s_k, block=block, interpret=True)
        y_r = dequantize_blocks_ref(q_r, s_r, block=block)
        np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), rtol=1e-6)

    @pytest.mark.parametrize("shape", [(1000,), (3, 5, 7), (256, 256)])
    def test_ops_roundtrip_shapes(self, shape):
        x = jax.random.normal(jax.random.PRNGKey(0), shape) * 2.0
        q, s = q_ops.quantize_int8(x, block=128)
        y = q_ops.dequantize_int8(q, s, shape, block=128)
        assert y.shape == shape
        err = np.abs(np.asarray(x) - np.asarray(y))
        assert err.max() <= np.abs(np.asarray(x)).max() / 127.0 + 1e-6

    @settings(max_examples=25, deadline=None)
    @given(
        n=st.integers(1, 64),
        scale=st.floats(1e-3, 1e3),
        seed=st.integers(0, 2**16),
    )
    def test_property_error_bound(self, n, scale, seed):
        """|x - dq(q(x))| <= block_amax/127 elementwise, any scale."""
        x = jax.random.normal(jax.random.PRNGKey(seed), (n, 64)) * scale
        q, s = quantize_blocks(x, block=64, interpret=True)
        y = dequantize_blocks(q, s, block=64, interpret=True)
        amax = np.abs(np.asarray(x)).max(axis=1, keepdims=True)
        assert (np.abs(np.asarray(x - y)) <= amax / 127.0 + 1e-6).all()

    def test_zero_block_is_exact(self):
        x = jnp.zeros((4, 64))
        q, s = quantize_blocks(x, block=64, interpret=True)
        y = dequantize_blocks(q, s, block=64, interpret=True)
        np.testing.assert_array_equal(np.asarray(y), 0.0)


class TestFlashAttentionKernel:
    @pytest.mark.parametrize("B,S,H,KH,hd", [
        (1, 128, 4, 4, 64),   # MHA
        (2, 256, 8, 2, 32),   # GQA 4:1
        (1, 384, 6, 1, 64),   # MQA
        (2, 96, 4, 2, 16),    # ragged block boundary (S % block != 0)
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref_sweep(self, B, S, H, KH, hd, dtype):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
        k = jax.random.normal(ks[1], (B, S, KH, hd), dtype)
        v = jax.random.normal(ks[2], (B, S, KH, hd), dtype)
        out = fa_ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
        ref = flash_attention_ref(q, k, v, causal=True)
        tol = 2e-2 if dtype == jnp.bfloat16 else 2e-3
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32), atol=tol, rtol=tol)

    @pytest.mark.parametrize("window", [16, 64])
    def test_sliding_window(self, window):
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(ks[0], (1, 128, 2, 32))
        k = jax.random.normal(ks[1], (1, 128, 2, 32))
        v = jax.random.normal(ks[2], (1, 128, 2, 32))
        out = fa_ops.flash_attention(q, k, v, causal=True, window=window,
                                     block_q=32, block_k=32)
        ref = flash_attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32), atol=3e-3, rtol=3e-3)

    def test_non_causal(self):
        ks = jax.random.split(jax.random.PRNGKey(2), 3)
        q = jax.random.normal(ks[0], (2, 64, 2, 16))
        k = jax.random.normal(ks[1], (2, 64, 2, 16))
        v = jax.random.normal(ks[2], (2, 64, 2, 16))
        out = fa_ops.flash_attention(q, k, v, causal=False, block_q=32, block_k=32)
        ref = flash_attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32), atol=3e-3, rtol=3e-3)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**16), s_pow=st.integers(5, 8))
    def test_property_softmax_convexity(self, seed, s_pow):
        """Attention output rows lie inside the convex hull of V rows: the
        per-dim output is bounded by V's min/max over valid positions."""
        S = 2**s_pow
        ks = jax.random.split(jax.random.PRNGKey(seed), 3)
        q = jax.random.normal(ks[0], (1, S, 2, 16))
        k = jax.random.normal(ks[1], (1, S, 2, 16))
        v = jax.random.normal(ks[2], (1, S, 2, 16))
        out = np.asarray(fa_ops.flash_attention(q, k, v, causal=False,
                                                block_q=32, block_k=32), np.float32)
        vmin = np.asarray(v, np.float32).min(axis=1, keepdims=True)
        vmax = np.asarray(v, np.float32).max(axis=1, keepdims=True)
        assert (out >= vmin - 1e-3).all() and (out <= vmax + 1e-3).all()


class TestSsmScanKernel:
    @pytest.mark.parametrize("B,C,d,N", [
        (1, 16, 32, 4), (2, 64, 256, 16), (3, 8, 300, 16),  # incl. d % tile != 0
    ])
    def test_matches_ref_sweep(self, B, C, d, N):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, C, d, N)))  # decay in (0,1)
        bx = jax.random.normal(ks[1], (B, C, d, N)) * 0.1
        h0 = jax.random.normal(ks[2], (B, d, N)) * 0.1
        h_seq, h_last = ssm_ops.ssm_scan_chunk(a, bx, h0)
        r_seq, r_last = ssm_scan_chunk_ref(a, bx, h0)
        np.testing.assert_allclose(np.asarray(h_seq), np.asarray(r_seq),
                                   atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(h_last), np.asarray(r_last),
                                   atol=1e-5, rtol=1e-5)

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**16), C=st.integers(2, 32))
    def test_property_composition(self, seed, C):
        """Scanning a chunk equals scanning its two halves sequentially."""
        B, d, N = 1, 16, 4
        ks = jax.random.split(jax.random.PRNGKey(seed), 3)
        C = 2 * C
        a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, C, d, N)))
        bx = jax.random.normal(ks[1], (B, C, d, N)) * 0.1
        h0 = jnp.zeros((B, d, N))
        _, h_full = ssm_ops.ssm_scan_chunk(a, bx, h0)
        _, h_half = ssm_ops.ssm_scan_chunk(a[:, : C // 2], bx[:, : C // 2], h0)
        _, h_two = ssm_ops.ssm_scan_chunk(a[:, C // 2 :], bx[:, C // 2 :], h_half)
        np.testing.assert_allclose(np.asarray(h_full), np.asarray(h_two),
                                   atol=1e-5, rtol=1e-5)

    def test_identity_decay_accumulates(self):
        """a=1 => h_last = h0 + sum_t bx_t."""
        B, C, d, N = 1, 8, 8, 4
        a = jnp.ones((B, C, d, N))
        bx = jax.random.normal(jax.random.PRNGKey(3), (B, C, d, N))
        h0 = jax.random.normal(jax.random.PRNGKey(4), (B, d, N))
        _, h_last = ssm_ops.ssm_scan_chunk(a, bx, h0)
        np.testing.assert_allclose(np.asarray(h_last),
                                   np.asarray(h0 + bx.sum(axis=1)), atol=1e-5)


class TestFusedWirePath:
    """comm.wire fuses quantize -> pack-to-bytes -> chunk into one jitted
    device call; in interpret mode the Pallas kernel path must be
    byte-identical to the jnp oracle (tier-1 acceptance for ISSUE 7)."""

    @pytest.mark.parametrize("block", [64, 128, 256])
    @pytest.mark.parametrize("n_blocks", [1, 3, 8])
    def test_encode_kernel_equals_oracle(self, block, n_blocks):
        from repro.comm import wire

        x = jax.random.normal(jax.random.PRNGKey(block + n_blocks),
                              (n_blocks, block)).astype(jnp.float32) * 5.0
        pk = np.asarray(wire._fused_encode(x, block=block, use_kernel=True))
        po = np.asarray(wire._fused_encode(x, block=block, use_kernel=False))
        np.testing.assert_array_equal(pk, po)

    @pytest.mark.parametrize("block", [64, 256])
    def test_decode_kernel_equals_oracle(self, block):
        from repro.comm import wire

        n_blocks = 4
        x = jax.random.normal(jax.random.PRNGKey(9),
                              (n_blocks, block)).astype(jnp.float32)
        packed = wire._fused_encode(x, block=block, use_kernel=False)
        dk = np.asarray(wire._fused_decode(packed, n_blocks=n_blocks,
                                           block=block, use_kernel=True))
        do = np.asarray(wire._fused_decode(packed, n_blocks=n_blocks,
                                           block=block, use_kernel=False))
        np.testing.assert_array_equal(dk, do)

    def test_roundtrip_error_bound(self):
        """Wire roundtrip matches the standalone block-quantization error:
        per-block max abs error <= scale/2 = amax/254."""
        from repro.comm import wire

        block = 128
        x = jax.random.normal(jax.random.PRNGKey(5), (4, block)) * 3.0
        x = x.astype(jnp.float32)
        packed = wire._fused_encode(x, block=block, use_kernel=True)
        y = np.asarray(wire._fused_decode(packed, n_blocks=4, block=block,
                                          use_kernel=True))
        xb = np.asarray(x).reshape(4, block)
        amax = np.abs(xb).max(axis=1)
        err = np.abs(xb - y.reshape(4, block)).max(axis=1)
        assert np.all(err <= amax / 254.0 + 1e-7)

    def test_packed_layout(self):
        """Packed blob = int8 codes then float32 scales as raw bytes."""
        from repro.comm import wire

        block, n_blocks = 64, 2
        x = jnp.ones((n_blocks, block), jnp.float32)
        packed = np.asarray(wire._fused_encode(x, block=block, use_kernel=True))
        assert packed.dtype == np.uint8
        assert packed.shape == (n_blocks * block + 4 * n_blocks,)
        codes = packed[: n_blocks * block].view(np.int8)
        scales = packed[n_blocks * block :].view(np.float32)
        np.testing.assert_array_equal(codes, np.full(n_blocks * block, 127, np.int8))
        np.testing.assert_allclose(scales, np.full(n_blocks, 1.0 / 127.0), rtol=1e-6)
