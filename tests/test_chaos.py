"""Chaos harness tier-1 suite (docs/architecture.md §9).

Covers the PR-8 satellites: LinkModel edge semantics on the batched data
path, ``recv_many`` timeout semantics, go-back-N retransmission + reply-cache
exactly-once under injected loss, the ``ChaosPlan``/``ChaosInjector``
schedule machinery on a virtual clock, WAN-link chunking/keepalives/bounded
reassembly, and the partition/churn regressions (coordinator crash
mid-commit converges via resync; fleet churn never blocks ``try_commit``).
"""
import threading
import time

import numpy as np
import pytest

from repro.chaos import (
    BLACKHOLE,
    ChaosInjector,
    ChaosPlan,
    VirtualClock,
    node_matches,
)
from repro.core import rendezvous
from repro.core.fabric import Fabric, LinkModel, ReliableChannel
from repro.core.rendezvous import KVStore


def counters_balance(fabric: Fabric) -> bool:
    """Every sent datagram is accounted exactly once (no in-flight timers
    with zero-latency links)."""
    c = fabric.counters
    return c.sent == (c.delivered + c.dropped_loss
                      + c.dropped_unroutable + c.dropped_overflow)


# ---------------------------------------------------------------------------
# LinkModel edges on the batched data path
# ---------------------------------------------------------------------------


class TestLinkModelEdges:
    @pytest.mark.parametrize("n", [0, 1, 7])
    def test_loss_zero_delivers_all(self, seeded_fabric, n):
        f = seeded_fabric(seed=1)
        a, b = f.register("a"), f.register("b")
        msgs = [f"m{i}" for i in range(n)]
        assert a.send_batch("b", msgs) == n
        assert b.pending() == n  # zero latency ⇒ synchronous delivery
        assert f.counters.sent == n and f.counters.delivered == n
        assert counters_balance(f)

    @pytest.mark.parametrize("n", [1, 7])
    def test_loss_one_drops_all(self, seeded_fabric, n):
        f = seeded_fabric(seed=1)
        a, b = f.register("a"), f.register("b")
        f.set_link("a", "b", LinkModel(loss=1.0))
        assert a.send_batch("b", [b"x"] * n) == 0
        assert b.pending() == 0
        assert f.counters.dropped_loss == n
        assert counters_balance(f)

    @pytest.mark.parametrize("n", [0, 1, 7])
    def test_loss_mask_deterministic_per_seed(self, seeded_fabric, n):
        """Same seed ⇒ identical Bernoulli mask at every batch size,
        including the empty and single-message batches."""
        accepted = []
        for _ in range(2):
            f = seeded_fabric(seed=42)
            a, b = f.register("a"), f.register("b")
            f.set_link("a", "b", LinkModel(loss=0.5))
            got = [a.send_batch("b", [f"m{i}" for i in range(n)])
                   for _ in range(8)]
            buf = [None] * 64
            drained = b.recv_many(buf, timeout=0.0)
            accepted.append((got, [m for _, m in buf[:drained]]))
            assert counters_balance(f)
        assert accepted[0] == accepted[1]

    def test_unroutable_counted(self, seeded_fabric):
        f = seeded_fabric()
        a = f.register("a")
        assert a.send_batch("ghost", ["x", "y"]) == 0
        assert f.counters.dropped_unroutable == 2
        assert counters_balance(f)

    def test_jitter_exceeding_latency_still_delivers(self, seeded_fabric):
        """delay = latency + U[0,1)·jitter stays non-negative and finite even
        when jitter dwarfs latency — messages arrive, just late."""
        f = seeded_fabric(seed=3)
        a, b = f.register("a"), f.register("b")
        f.set_link("a", "b", LinkModel(latency_s=0.001, jitter_s=0.02))
        assert a.send_batch("b", ["x", "y", "z"]) == 3
        buf = [None] * 3
        got = 0
        deadline = time.monotonic() + 2.0
        while got < 3 and time.monotonic() < deadline:
            got += b.recv_many(buf, timeout=0.05)
        assert got == 3
        assert f.counters.delivered == 3

    def test_zero_latency_synchronous(self, seeded_fabric):
        f = seeded_fabric()
        a, b = f.register("a"), f.register("b")
        f.set_link("a", "b", LinkModel(latency_s=0.0, jitter_s=0.0))
        a.send_batch("b", ["x"])
        assert b.pending() == 1  # no timer hop on the zero-delay path


# ---------------------------------------------------------------------------
# recv_many timeout semantics
# ---------------------------------------------------------------------------


class TestRecvMany:
    def test_zero_timeout_returns_immediately(self, seeded_fabric):
        f = seeded_fabric()
        b = f.register("b")
        t0 = time.monotonic()
        assert b.recv_many([None] * 4, timeout=0.0) == 0
        assert time.monotonic() - t0 < 0.1

    def test_timeout_expires_empty(self, seeded_fabric):
        f = seeded_fabric()
        b = f.register("b")
        t0 = time.monotonic()
        assert b.recv_many([None] * 4, timeout=0.05) == 0
        assert time.monotonic() - t0 >= 0.04

    def test_first_message_only_never_fills(self, seeded_fabric):
        """Blocks for the FIRST message only — an 8-slot buffer with one
        queued message returns 1, it does not wait for 8."""
        f = seeded_fabric()
        a, b = f.register("a"), f.register("b")
        a.send_batch("b", ["solo"])
        t0 = time.monotonic()
        buf = [None] * 8
        assert b.recv_many(buf, timeout=1.0) == 1
        assert time.monotonic() - t0 < 0.5
        assert buf[0] == ("a", "solo")

    def test_blocks_until_delayed_delivery(self, seeded_fabric):
        f = seeded_fabric()
        a, b = f.register("a"), f.register("b")
        t = threading.Timer(0.05, lambda: a.send_batch("b", ["late"]))
        t.start()
        try:
            assert b.recv_many([None] * 2, timeout=1.0) == 1
        finally:
            t.join()

    def test_max_n_caps_the_drain(self, seeded_fabric):
        f = seeded_fabric()
        a, b = f.register("a"), f.register("b")
        a.send_batch("b", [f"m{i}" for i in range(5)])
        buf = [None] * 8
        assert b.recv_many(buf, max_n=2, timeout=0.0) == 2
        assert [m for _, m in buf[:2]] == ["m0", "m1"]
        assert b.recv_many(buf, timeout=0.0) == 3  # the rest, in order


# ---------------------------------------------------------------------------
# ReliableChannel under injected loss
# ---------------------------------------------------------------------------


class TestReliableUnderLoss:
    def _serve(self, chan, handler, stop):
        while not stop.is_set():
            chan.serve_one(handler, timeout=0.02)

    @pytest.mark.slow
    def test_request_window_retransmits_exactly_once(self, seeded_fabric):
        """30% loss each way: go-back-N repairs every frame, replies come
        back in order, and the reply cache keeps the handler exactly-once."""
        f = seeded_fabric(seed=9)
        c, s = f.register("rc"), f.register("rs")
        lossy = LinkModel(loss=0.3)
        f.set_link("rc", "rs", lossy)
        f.set_link("rs", "rc", lossy)
        client = ReliableChannel(c, "rs", timeout=0.02, retries=40, window=8)
        server = ReliableChannel(s, "rs")
        seen: list = []
        stop = threading.Event()
        t = threading.Thread(target=self._serve, args=(
            server, lambda src, body: seen.append(body) or {"echo": body},
            stop))
        t.start()
        try:
            msgs = [{"i": i} for i in range(25)]
            replies = client.request_window(msgs)
        finally:
            stop.set()
            t.join()
        assert [r["echo"] for r in replies] == msgs       # ordered
        assert seen == msgs                               # exactly-once
        assert client.retransmits > 0                     # loss was repaired
        assert f.counters.dropped_loss > 0

    def test_request_retries_override_fails_fast(self, seeded_fabric):
        f = seeded_fabric()
        c = f.register("rc")
        f.register("dead")
        f.set_link("rc", "dead", BLACKHOLE)
        chan = ReliableChannel(c, "dead", timeout=0.01, retries=100)
        t0 = time.monotonic()
        with pytest.raises(TimeoutError):
            chan.request({"probe": 1}, retries=2)  # per-call budget wins
        assert time.monotonic() - t0 < 0.5
        assert chan.retransmits >= 1

    def test_reply_cache_answers_duplicate_without_handler(self, seeded_fabric):
        f = seeded_fabric()
        c, s = f.register("rc"), f.register("rs")
        server = ReliableChannel(s, "rs")
        calls = []
        frame = {"_seq": 7, "body": {"x": 1}}
        for _ in range(2):  # identical retransmission
            c.send_batch("rs", [frame])
            server.serve_one(lambda src, b: calls.append(b) or {"ok": 1},
                             timeout=0.2)
        assert len(calls) == 1           # handler ran once
        assert server.dup_replies == 1   # duplicate answered from the cache


# ---------------------------------------------------------------------------
# ChaosPlan / ChaosInjector on a virtual clock
# ---------------------------------------------------------------------------


class TestChaosInjector:
    def test_node_matches_prefix_only(self):
        assert node_matches("b", ["b"])
        assert node_matches("b/ctrl", ["b"])
        assert not node_matches("bx", ["b"])

    def test_exactly_one_of_at_or_on(self):
        plan = ChaosPlan()
        with pytest.raises(ValueError):
            plan.crash("n")                       # neither
        with pytest.raises(ValueError):
            plan.crash("n", at=1.0, on="trig")    # both

    def test_schedule_applies_and_autoheals(self, seeded_fabric,
                                            virtual_clock):
        f = seeded_fabric()
        f.register("a"), f.register("b")
        weather = LinkModel(latency_s=0.002, loss=0.4)
        plan = ChaosPlan()
        plan.degrade("a", "b", weather, at=1.0, for_s=2.0, label="w")
        inj = ChaosInjector(f, plan).start(now=virtual_clock())
        inj.poll(now=virtual_clock.advance(0.5))
        assert f.get_link("a", "b").loss == 0.0       # not due yet
        inj.poll(now=virtual_clock.advance(0.6))      # t=1.1: applied
        assert f.get_link("a", "b") == weather
        assert f.get_link("b", "a") == weather        # symmetric
        inj.poll(now=virtual_clock.advance(2.5))      # t=3.6: autohealed
        assert f.get_link("a", "b").loss == 0.0
        assert inj.active_labels() == []
        inj.stop()

    def test_heal_restores_previous_override(self, seeded_fabric,
                                             virtual_clock):
        """A partition layered on a degrade heals back to the DEGRADE, and
        healing the degrade restores the default — LIFO restore."""
        f = seeded_fabric()
        f.register("a"), f.register("b")
        weather = LinkModel(latency_s=0.001, loss=0.2)
        plan = ChaosPlan()
        plan.degrade("a", "b", weather, at=0.0, label="weather")
        plan.partition("a", "b", at=1.0, label="cut")
        plan.heal("cut", at=2.0)
        plan.heal("weather", at=3.0)
        inj = ChaosInjector(f, plan).start(now=virtual_clock())
        inj.poll(now=virtual_clock.advance(0.1))
        assert f.get_link("a", "b") == weather
        inj.poll(now=virtual_clock.advance(1.0))
        assert f.get_link("a", "b").loss == 1.0       # partitioned
        inj.poll(now=virtual_clock.advance(1.0))
        assert f.get_link("a", "b") == weather        # back to the degrade
        inj.poll(now=virtual_clock.advance(1.0))
        assert f.get_link("a", "b").loss == 0.0       # pristine
        inj.stop()

    def test_crash_covers_child_endpoints_and_new_registrations(
            self, seeded_fabric, virtual_clock):
        f = seeded_fabric()
        f.register("n"), f.register("n/ctrl"), f.register("other")
        plan = ChaosPlan()
        plan.crash("n", at=0.0, label="boom")
        inj = ChaosInjector(f, plan).start(now=virtual_clock())
        inj.poll(now=virtual_clock.advance(0.1))
        assert f.get_link("n/ctrl", "other").loss == 1.0
        assert f.get_link("other", "n", ).loss == 1.0
        # a fresh endpoint under the crashed prefix cannot escape the fault
        f.register("n/new")
        assert f.get_link("n/new", "other").loss == 1.0
        inj.stop()                                    # heals everything
        assert f.get_link("n/new", "other").loss == 0.0

    def test_stop_heals_lifo(self, seeded_fabric, virtual_clock):
        f = seeded_fabric()
        f.register("a"), f.register("b")
        weather = LinkModel(loss=0.1)
        plan = ChaosPlan()
        plan.degrade("a", "b", weather, at=0.0)
        plan.partition("a", "b", at=0.0)
        inj = ChaosInjector(f, plan).start(now=virtual_clock())
        inj.poll(now=virtual_clock.advance(0.1))
        inj.stop()
        assert f.get_link("a", "b").loss == 0.0       # fully restored

    def test_churn_is_seed_deterministic(self):
        def labels(seed):
            p = ChaosPlan(seed=seed)
            return p.churn(["m1", "m2", "m3"], start_s=0.0, period_s=1.0,
                           down_s=0.4, rounds=8)

        assert labels(5) == labels(5)
        with pytest.raises(ValueError):
            ChaosPlan().churn(["m1"], start_s=0, period_s=1.0, down_s=1.0,
                              rounds=1)

    def test_trigger_fires_once(self, seeded_fabric, virtual_clock):
        f = seeded_fabric()
        f.register("a"), f.register("b")
        plan = ChaosPlan()
        plan.partition("a", "b", on="go")
        inj = ChaosInjector(f, plan).start(now=virtual_clock())
        assert inj.fire("go") == 1
        assert f.get_link("a", "b").loss == 1.0
        assert inj.fire("go") == 0                    # consumed
        inj.stop()


# ---------------------------------------------------------------------------
# WAN link: chunking, exactly-once, keepalives, bounded reassembly
# ---------------------------------------------------------------------------


def _wan_pair(fabric, a="wa", b="wb", **kw):
    from repro.comm.chunnels import WanLinkChunnel

    epa, epb = fabric.register(a), fabric.register(b)
    kw.setdefault("use_kernel", False)
    dpa = WanLinkChunnel(epa, b, **kw).connect_wrap(None)
    dpb = WanLinkChunnel(epb, a, **kw).connect_wrap(None)
    return dpa, dpb


def _collect(dp, n, out, timeout_s=5.0):
    buf = [None] * n
    deadline = time.monotonic() + timeout_s
    while len(out) < n and time.monotonic() < deadline:
        got = dp.recv(buf, timeout=0.05)
        out.extend(buf[:got])


class TestWanLink:
    def test_mtu_chunking_roundtrip(self, seeded_fabric):
        """A tensor larger than the MTU is chunked, reassembled and decoded
        (int8 block quantization ⇒ bounded error); raw bytes and control
        objects ride the same window exactly."""
        f = seeded_fabric()
        dpa, dpb = _wan_pair(f, mtu_bytes=1024, block=64)
        tensor = np.linspace(-3.0, 3.0, 40 * 130,
                             dtype=np.float32).reshape(40, 130)
        raw = bytes(range(256)) * 9          # 2304 B > one MTU
        obj = {"kind": "ctrl", "i": 7}
        out: list = []
        rx = threading.Thread(target=_collect, args=(dpb, 3, out))
        rx.start()
        dpa.send([tensor, raw, obj])
        rx.join()
        assert len(out) == 3
        got_t, got_raw, got_obj = out
        assert got_t.shape == tensor.shape
        atol = float(np.abs(tensor).max()) / 127  # 2x the quantization step
        assert np.allclose(got_t, tensor, atol=atol)
        assert got_raw == raw                 # raw path is exact
        assert got_obj == obj
        assert dpa.frames_sent > 3            # really chunked

    @pytest.mark.slow
    def test_exactly_once_in_order_under_loss(self, seeded_fabric):
        f = seeded_fabric(seed=13)
        lossy = LinkModel(loss=0.25)
        f.set_link("wa", "wb", lossy)
        f.set_link("wb", "wa", lossy)
        dpa, dpb = _wan_pair(f, timeout_s=0.02, retries=40)
        msgs = [{"i": i} for i in range(12)]
        out: list = []
        rx = threading.Thread(target=_collect, args=(dpb, len(msgs), out))
        rx.start()
        for m in msgs:
            dpa.send([m])                     # delivery-confirmed send
        rx.join()
        assert out == msgs                    # exactly once, in order
        assert dpa.retransmits > 0            # loss really repaired
        assert dpa.failed_sends == 0

    def test_keepalive_detects_partition_and_heal(self, seeded_fabric,
                                                  virtual_clock):
        f = seeded_fabric()
        dpa, dpb = _wan_pair(f, timeout_s=0.01)
        plan = ChaosPlan()
        plan.partition("wa", "wb", at=0.0, label="cut")
        inj = ChaosInjector(f, plan).start(now=virtual_clock())

        served = threading.Event()

        def serve_pings():
            while not served.is_set():
                dpb.recv([None], timeout=0.02)  # pumps serve_one → pong

        t = threading.Thread(target=serve_pings)
        t.start()
        try:
            assert dpa.ping(retries=2)        # pre-fault: pong arrives
            inj.poll(now=virtual_clock.advance(0.1))
            assert not dpa.ping(retries=2)    # partitioned: fail-fast
            assert dpa.keepalive_failures == 1
            inj.stop()                        # heal
            assert dpa.ping(retries=4)
        finally:
            served.set()
            t.join()

    def test_reassembly_is_bounded(self):
        from repro.comm.wire import Reassembler, chunk_payload

        r = Reassembler(max_partial=2)
        heads = [chunk_payload(b"x" * 300, {"kind": "raw"}, chunk_bytes=100)[0]
                 for _ in range(4)]
        for h in heads:                       # 4 openers, bound of 2
            assert r.ingest(h) is None
        assert r.partial_count() <= 2
        assert r.evicted == 2                 # oldest partials dropped

    def test_chunk_payload_edges(self):
        from repro.comm.wire import Reassembler, chunk_payload

        assert len(chunk_payload(b"", {"k": 1}, chunk_bytes=10)) == 1
        assert len(chunk_payload(b"x" * 10, {"k": 1}, chunk_bytes=10)) == 1
        frames = chunk_payload(b"x" * 11, {"k": 1}, chunk_bytes=10)
        assert len(frames) == 2
        assert frames[0]["hdr"] == {"k": 1} and frames[1]["hdr"] is None
        r = Reassembler()
        assert r.ingest(frames[1]) is None    # out-of-order completion works
        payload, hdr = r.ingest(frames[0])
        assert payload == b"x" * 11 and hdr == {"k": 1}


# ---------------------------------------------------------------------------
# Partition / churn regressions
# ---------------------------------------------------------------------------


class TestPartitionRegressions:
    @pytest.mark.slow
    def test_coordinator_crash_mid_commit_converges(self, seeded_fabric):
        """2PC coordinator crashes exactly at the commit point (before any
        phase-2 notification): the prepared peer's resync queries fail while
        the crash holds, then converge after the restart — zero stranded
        prepared peers, every survivor on the committed epoch."""
        from repro.core import (
            FabricTransport,
            FnChunnel,
            HostAgent,
            LockedConn,
            Select,
            make_stack,
        )

        f = seeded_fabric(default_link=LinkModel(latency_s=0.0002), seed=17)
        hA, hB = HostAgent(f, "cA"), HostAgent(f, "cB")
        conn = "reg-conn"

        def stack_for(tag):
            ep = f.register(f"{tag}/data")
            return make_stack(
                Select(FnChunnel(fn_name="Blue", on_send=lambda m: m),
                       FnChunnel(fn_name="Green", on_send=lambda m: m)),
                FabricTransport(ep, "hub"))

        stA, stB = stack_for("cA"), stack_for("cB")
        handleA = LockedConn(stA.preferred())
        target = stA.options()[1]
        hB.register_participant(conn, LockedConn(stB.preferred()), stB.find,
                                resync_after_s=0.08)

        plan = ChaosPlan()
        plan.crash("cA", on="mid_commit", label="boom")
        plan.restart("boom", at=0.3)
        inj = ChaosInjector(f, plan).start()
        record = hA.record_decision
        hA.record_decision = (lambda cid, epoch, fp:
                              (record(cid, epoch, fp),
                               inj.fire("mid_commit")) and None)
        try:
            ok = hA.reconfigure_multilateral(handleA, target, ["cB"], conn,
                                             timeout=0.03, retries=2)
            assert ok                         # presumed commit
            part = hB.participant(conn)
            assert part.prepared is not None  # stranded while A is down
            deadline = time.monotonic() + 4.0
            while time.monotonic() < deadline and part.prepared is not None:
                inj.poll()
                time.sleep(0.01)
            assert part.prepared is None      # zero stranded prepared peers
            assert part.resync_failures >= 1  # the crash really blocked it
            assert part.epoch == handleA.stats.switches == 1
            assert (part.handle.stack.fingerprint()
                    == handleA.stack.fingerprint()
                    == target.fingerprint())
        finally:
            inj.stop()
            hA.close()
            hB.close()

    def test_churn_during_aggregation_unblocks_try_commit(self):
        """A member crashing mid-aggregation-window stops heartbeating but
        still sits in the rendezvous membership map: ``try_commit`` pends on
        its ack until the aggregator's TTL expiry evicts it — never blocked
        past one aggregation pass (all on virtual time)."""
        from repro.core.telemetry import ConnTelemetry
        from repro.fleet import FleetAggregator, FleetPublisher
        from repro.fleet.publish import fleet_conn_id

        clk = VirtualClock(0.0)
        store = KVStore()
        conn = fleet_conn_id("f1")
        members = ("ma", "mb", "mc")
        for m in members:
            rendezvous.join(store, conn, m, ["fpX"], [["dX"]], lambda d: 0)
        pubs = {m: FleetPublisher(store, "f1", m, ConnTelemetry(), now=clk)
                for m in members}
        for p in pubs.values():
            p.publish(now=clk())
        agg = FleetAggregator(store, "f1", ttl_s=0.5, now=clk)

        # mc crashes (stops heartbeating); ma proposes, mb acks
        epoch = rendezvous.propose_transition(store, conn, "ma", "fpY", ["dY"])
        rendezvous.vote(store, conn, "mb", epoch, True)
        t0 = time.monotonic()
        assert rendezvous.try_commit(store, conn, epoch, 60.0, t0) is None

        # survivors keep heartbeating through the churn window
        clk.advance(0.6)
        for m in ("ma", "mb"):
            pubs[m].publish(now=clk())
        agg.aggregate(now=clk())              # TTL expiry evicts mc
        assert agg.expired_total == 1
        assert "mc" not in (store.get(f"{conn}/members") or {})
        assert rendezvous.try_commit(store, conn, epoch, 60.0, t0) is True
        assert store.get(f"{conn}/stack")["fp"] == "fpY"
