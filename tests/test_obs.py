"""Observability plane: tracer, wire-level trace stitching, metrics
registry, flight recorder, exporters, and the traced KV-switch scenario.

The trace-context edge cases here are part of the PR's acceptance: spans
must survive CompressChunnel chunking/reassembly, WanLink retransmits must
reuse the original span id (tagged ``retry=n``), and dropped messages must
close their span/record with a ``drop_reason``.
"""
from __future__ import annotations

import json
import math
import threading
import time
from collections import deque

import numpy as np
import pytest

from repro.core.chunnel import Datapath
from repro.core.fabric import Fabric, LinkModel, ReliableChannel
from repro.core.telemetry import ConnTelemetry
from repro.obs import (
    NOOP_SPAN,
    RECORDER,
    TRACER,
    FlightRecorder,
    MetricsRegistry,
    parse_prometheus,
    phase_durations,
    render_timeline,
    stitched_trace_ids,
    to_chrome,
)


@pytest.fixture(autouse=True)
def clean_tracer():
    """Every test starts and ends with a disabled, empty tracer."""
    TRACER.disable()
    TRACER.reset()
    yield
    TRACER.disable()
    TRACER.reset()


def spans_named(records, name):
    return [r for r in records if r["kind"] == "span" and r["name"] == name]


def events_named(records, name):
    return [r for r in records if r["kind"] == "event" and r["name"] == name]


# ---------------------------------------------------------------------------
# Tracer core
# ---------------------------------------------------------------------------


class TestTracer:
    def test_disabled_is_noop(self):
        sp = TRACER.span("x")
        assert sp is NOOP_SPAN and not sp
        with sp:
            sp.set(a=1).event("e")
        assert TRACER.ctx() is None
        assert TRACER.collect() == []

    def test_span_nesting_and_trace_id(self):
        TRACER.enable()
        with TRACER.span("outer") as outer:
            with TRACER.span("inner") as inner:
                assert inner.trace_id == outer.trace_id
                assert inner.parent_id == outer.span_id
                assert TRACER.ctx() == (inner.trace_id, inner.span_id)
        recs = TRACER.collect()
        assert {r["name"] for r in recs} == {"outer", "inner"}
        by = {r["name"]: r for r in recs}
        assert by["inner"]["parent_id"] == by["outer"]["span_id"]
        assert by["outer"]["dur"] >= by["inner"]["dur"] >= 0

    def test_separate_roots_get_separate_traces(self):
        TRACER.enable()
        with TRACER.span("a"):
            pass
        with TRACER.span("b"):
            pass
        assert len(stitched_trace_ids(TRACER.collect())) == 2

    def test_exception_marks_error_status(self):
        TRACER.enable()
        with pytest.raises(RuntimeError):
            with TRACER.span("boom"):
                raise RuntimeError("nope")
        (rec,) = TRACER.collect()
        assert rec["status"] == "error"
        assert "RuntimeError" in rec["attrs"]["error"]

    def test_adopt_reparents_across_threads(self):
        TRACER.enable()
        got = {}

        def remote(tc):
            with TRACER.adopt(tc):
                with TRACER.span("remote.work") as sp:
                    got["trace"] = sp.trace_id

        with TRACER.span("local") as sp:
            t = threading.Thread(target=remote, args=(sp.ctx,))
            t.start()
            t.join()
        assert got["trace"] == sp.trace_id
        recs = TRACER.collect()
        assert len(stitched_trace_ids(recs)) == 1
        (remote_rec,) = spans_named(recs, "remote.work")
        assert remote_rec["parent_id"] == sp.span_id

    def test_ring_capacity_bounds_history(self):
        TRACER.enable(capacity=16)
        TRACER._tls.__dict__.clear()  # force a fresh ring at the new capacity
        for i in range(100):
            TRACER.record_batch("b", i, i)
        assert len(TRACER.collect()) == 16
        TRACER.enable(capacity=8192)
        TRACER._tls.__dict__.clear()

    def test_batch_record_normalization(self):
        TRACER.enable()
        TRACER.record_batch("fab", 8, 5, {"drop_reason": "loss"})
        (rec,) = TRACER.collect()
        assert rec["kind"] == "batch"
        assert rec["status"] == "partial"          # n_ok < n
        assert rec["attrs"] == {"n": 8, "n_ok": 5, "drop_reason": "loss"}

    def test_collect_clear(self):
        TRACER.enable()
        with TRACER.span("once"):
            pass
        assert len(TRACER.collect(clear=True)) == 1
        assert TRACER.collect() == []


# ---------------------------------------------------------------------------
# Wire-level stitching: ReliableChannel, Compress reassembly, WAN retransmit
# ---------------------------------------------------------------------------


class TestReliableChannelStitching:
    def test_request_stitches_one_trace_across_endpoints(self):
        TRACER.enable()
        fab = Fabric(default_link=LinkModel(), seed=0)
        cli, srv = fab.register("rc-cli"), fab.register("rc-srv")
        server_chan = ReliableChannel(srv, peer="*")
        stop = threading.Event()

        def handler(src, body):
            with TRACER.span("server.work", attrs={"src": src}):
                return {"type": "ok"}

        def serve():
            while not stop.is_set():
                server_chan.serve_one(handler, timeout=0.05)

        t = threading.Thread(target=serve)
        t.start()
        try:
            chan = ReliableChannel(cli, "rc-srv")
            with TRACER.span("client.call") as root:
                reply = chan.request({"type": "ping"})
            assert reply["type"] == "ok"
        finally:
            stop.set()
            t.join()
        recs = TRACER.collect()
        (work,) = spans_named(recs, "server.work")
        (rc,) = spans_named(recs, "rc.request")
        # the handler span (listener thread) and the rc span (client thread)
        # both live in the caller's trace — that's the over-the-wire stitch
        assert work["trace_id"] == rc["trace_id"] == root.trace_id
        assert rc["parent_id"] == root.span_id
        assert work["parent_id"] == rc["span_id"]
        assert work["thread"] != rc["thread"]

    def test_request_timeout_closes_span_with_drop_reason(self):
        TRACER.enable()
        fab = Fabric(default_link=LinkModel(loss=1.0), seed=0)
        cli = fab.register("to-cli")
        fab.register("to-srv")
        chan = ReliableChannel(cli, "to-srv", timeout=0.01, retries=2)
        with pytest.raises(TimeoutError):
            chan.request({"type": "ping"})
        (rc,) = spans_named(TRACER.collect(), "rc.request")
        assert rc["status"] == "timeout"
        assert rc["attrs"]["drop_reason"] == "no_reply"


class _LoopbackDP(Datapath):
    """In-memory datapath bridging a Compress send side to a recv side."""

    def __init__(self, q: deque):
        self.q = q

    def send(self, msgs):
        self.q.extend(msgs)

    def recv(self, buf, timeout=None):
        n = 0
        while n < len(buf) and self.q:
            buf[n] = self.q.popleft()
            n += 1
        return n


class TestCompressReassemblyCtx:
    def test_span_survives_chunking_and_reassembly(self):
        from repro.comm.wire import CompressChunnel

        TRACER.enable()
        q: deque = deque()
        ch = CompressChunnel(use_kernel=False, chunk_bytes=256)
        tx = ch.connect_wrap(_LoopbackDP(q))
        rx = ch.connect_wrap(_LoopbackDP(q))
        x = np.linspace(-1, 1, 2048, dtype=np.float32)
        with TRACER.span("blob.send") as root:
            tx.send([x])
        buf = [None]
        assert rx.recv(buf) == 1
        recs = TRACER.collect()
        (ev,) = events_named(recs, "wire.reassembled")
        # reassembly on the receive side is parented to the SENDER's span
        assert ev["trace_id"] == root.trace_id
        assert ev["parent_id"] == root.span_id
        assert ev["attrs"]["msgs"] == 1

    def test_eviction_closes_sender_story_with_drop_reason(self):
        from repro.comm.wire import Reassembler, chunk_payload

        TRACER.enable()
        reasm = Reassembler(max_partial=1)
        with TRACER.span("lost.blob") as root:
            frames = chunk_payload(b"x" * 512, {"kind": "t"}, chunk_bytes=128)
        assert frames[0]["hdr"]["tc"] == (root.trace_id, root.span_id)
        reasm.ingest(frames[0])               # partial blob #1 (incomplete)
        other = chunk_payload(b"y" * 512, {"kind": "t"}, chunk_bytes=128)
        reasm.ingest(other[0])                # evicts blob #1
        assert reasm.evicted == 1
        (ev,) = events_named(TRACER.collect(), "wire.evicted")
        assert ev["trace_id"] == root.trace_id
        assert ev["attrs"]["drop_reason"] == "reassembly_overflow"


class TestWanRetransmitSpans:
    def _pair(self, fab, **kw):
        from repro.comm.chunnels import WanLinkChunnel

        epa, epb = fab.register("wa"), fab.register("wb")
        kw.setdefault("use_kernel", False)
        return (WanLinkChunnel(epa, "wb", **kw).connect_wrap(None),
                WanLinkChunnel(epb, "wa", **kw).connect_wrap(None), epa)

    def test_retransmit_reuses_span_id_tagged_retry(self):
        TRACER.enable()
        fab = Fabric(default_link=LinkModel(), seed=13)
        lossy = LinkModel(loss=0.3)
        fab.set_link("wa", "wb", lossy)
        fab.set_link("wb", "wa", lossy)
        dpa, dpb, epa = self._pair(fab, timeout_s=0.02, retries=40)

        sent_tcs = []
        orig = epa.send_batch

        def spy(dst, msgs):
            sent_tcs.extend(m["_tc"] for m in msgs
                            if isinstance(m, dict) and "_tc" in m)
            return orig(dst, msgs)

        epa.send_batch = spy
        out: list = []
        done = threading.Event()

        def rx():
            # keep pumping past the last payload: a LOST final ack must be
            # re-served (re-acked) or the sender's window never completes
            buf = [None] * 4
            deadline = time.monotonic() + 10.0
            while not done.is_set() and time.monotonic() < deadline:
                got = dpb.recv(buf, timeout=0.05)
                out.extend(buf[:got])

        t = threading.Thread(target=rx)
        t.start()
        msgs = [{"i": i} for i in range(6)]
        try:
            for m in msgs:
                dpa.send([m])
        finally:
            done.set()
            t.join()
        assert out == msgs
        assert dpa.retransmits > 0, "loss never forced a retransmit"
        recs = TRACER.collect()
        windows = spans_named(recs, "rc.window")
        assert len(windows) == len(msgs)       # one window span per batch
        # a retransmitted frame keeps its ORIGINAL wire span id: every _tc
        # that went over the wire belongs to a recorded rc.window span
        window_ids = {(w["trace_id"], w["span_id"]) for w in windows}
        assert sent_tcs and set(sent_tcs) <= window_ids
        assert len(sent_tcs) > len(set(sent_tcs)), \
            "resends should repeat the same ctx, not mint new span ids"
        retries = [e for w in windows for e in w["events"]
                   if e["name"] == "retransmit"]
        assert retries and all(e["attrs"]["retry"] >= 1 for e in retries)
        wans = spans_named(recs, "wan.send")
        assert len(wans) == len(msgs) and all(w["status"] == "ok" for w in wans)

    def test_partitioned_send_drops_with_reason(self):
        TRACER.enable()
        fab = Fabric(default_link=LinkModel(), seed=0)
        dead = LinkModel(loss=1.0)
        fab.set_link("wa", "wb", dead)
        fab.set_link("wb", "wa", dead)
        dpa, _dpb, _ = self._pair(fab, timeout_s=0.01, retries=2)
        with pytest.raises(TimeoutError):
            dpa.send([{"i": 0}])
        assert dpa.failed_sends == 1
        recs = TRACER.collect()
        (wan,) = spans_named(recs, "wan.send")
        assert wan["status"] == "dropped"
        assert wan["attrs"]["drop_reason"] == "window_stalled"
        (win,) = spans_named(recs, "rc.window")
        assert win["status"] == "timeout"
        assert win["attrs"]["drop_reason"] == "window_stalled"


class TestFabricDropRecords:
    def test_unroutable_and_loss_record_drop_reason(self):
        TRACER.enable()
        fab = Fabric(default_link=LinkModel(loss=0.5), seed=3)
        a = fab.register("da")
        fab.register("db")
        a.send_batch("db", [b"x"] * 100)
        a.send_batch("ghost", [b"y"] * 4)
        recs = TRACER.collect()
        batches = [r for r in recs if r["kind"] == "batch"
                   and r["name"] == "fabric.send_batch"]
        reasons = {r["attrs"].get("drop_reason") for r in batches}
        assert "loss" in reasons and "unroutable" in reasons
        lossy = next(r for r in batches if r["attrs"].get("drop_reason") == "loss")
        assert lossy["status"] == "partial"
        assert lossy["attrs"]["n_ok"] < lossy["attrs"]["n"]


# ---------------------------------------------------------------------------
# Metrics registry + exporters
# ---------------------------------------------------------------------------


class TestMetricsRegistry:
    def test_unifies_counter_families(self):
        fab = Fabric(seed=0)
        a = fab.register("m-a")
        fab.register("m-b")
        a.send_batch("m-b", [b"x" * 8] * 10)
        tel = ConnTelemetry()
        tel.record_send(4, 64, 0.001)
        reg = MetricsRegistry()
        reg.watch("fabric", fab.counters)
        reg.watch("conn", tel, instance="left")
        reg.register("custom", lambda: {"answer": 42})
        snap = reg.collect()
        assert snap["fabric"]["default"]["sent"] == 10
        assert snap["conn"]["left"]["ops"] == 1
        assert snap["custom"]["default"]["answer"] == 42

    def test_prometheus_round_trip(self):
        reg = MetricsRegistry()
        reg.register("fam", lambda: {"x": 1, "nested": {"a": 2.5, "b": 3},
                                     "skipped": "text"})
        text = reg.to_prometheus()
        samples = parse_prometheus(text)
        by = {(s["name"], s["labels"].get("key", "")): s["value"]
              for s in samples}
        assert by[("repro_fam_x", "")] == 1
        assert by[("repro_fam_nested", "a")] == 2.5
        assert by[("repro_fam_nested", "b")] == 3
        assert ("repro_fam_skipped", "") not in by   # non-numeric: JSON only
        assert json.loads(reg.to_json())["fam"]["default"]["skipped"] == "text"

    def test_parse_rejects_malformed(self):
        with pytest.raises(ValueError, match="unparseable"):
            parse_prometheus("this is { not metrics\n")

    def test_failing_source_isolated(self):
        reg = MetricsRegistry()
        reg.register("bad", lambda: 1 / 0)
        reg.register("good", lambda: {"v": 1})
        snap = reg.collect()
        assert "_error" in snap["bad"]["default"]
        assert snap["good"]["default"]["v"] == 1
        parse_prometheus(reg.to_prometheus())   # still emits valid text

    def test_watch_numeric_attr_fallback(self):
        class Bare:
            def __init__(self):
                self.retransmits = 3
                self.timeout = 0.1
                self._private = 9

        reg = MetricsRegistry()
        reg.watch("rc", Bare())
        m = reg.collect()["rc"]["default"]
        assert m == {"retransmits": 3, "timeout": 0.1}


# ---------------------------------------------------------------------------
# Flight recorder
# ---------------------------------------------------------------------------


class TestFlightRecorder:
    def test_dump_noop_when_disabled(self, tmp_path):
        rec = FlightRecorder(out_dir=str(tmp_path))
        assert rec.dump("why") is None
        assert list(tmp_path.iterdir()) == []

    def test_capture_dumps_on_assert_and_reraises(self, tmp_path):
        TRACER.enable()
        rec = FlightRecorder(out_dir=str(tmp_path))
        with TRACER.span("doomed"):
            pass
        with pytest.raises(AssertionError):
            with rec.capture("smoke"):
                assert False, "scenario shape broke"
        (path,) = tmp_path.iterdir()
        assert path.name == "flightrec_smoke_assert.json"
        doc = json.loads(path.read_text())
        # pytest's assertion rewriting appends the expression source; the
        # user-supplied message is the first line
        assert doc["extra"]["assertion"].splitlines()[0] == "scenario shape broke"
        assert any(r["name"] == "doomed" for r in doc["records"])

    def test_capture_passes_through_success(self, tmp_path):
        TRACER.enable()
        rec = FlightRecorder(out_dir=str(tmp_path))
        with rec.capture("smoke"):
            pass
        assert list(tmp_path.iterdir()) == []

    def test_dump_once_dedupes(self, tmp_path):
        TRACER.enable()
        rec = FlightRecorder(out_dir=str(tmp_path))
        assert rec.dump("strand_c1", once=True) is not None
        assert rec.dump("strand_c1", once=True) is None
        assert rec.dumps == 1

    def test_strand_alarm_records_event_and_dumps(self, tmp_path, monkeypatch):
        from repro.obs import flight

        TRACER.enable()
        monkeypatch.setattr(flight.RECORDER, "out_dir", str(tmp_path))
        monkeypatch.setattr(flight.RECORDER, "_dumped", set())
        path = flight.strand_alarm("conn9", "peer-x", 3)
        assert path and "strand_conn9" in path
        (ev,) = events_named(TRACER.collect(), "2pc.strand_alarm")
        assert ev["attrs"]["drop_reason"] == "resync_stalled"
        assert flight.strand_alarm("conn9", "peer-x", 3) is None  # deduped


# ---------------------------------------------------------------------------
# Telemetry window handoff (read-reset race regression)
# ---------------------------------------------------------------------------


class TestTelemetryWindowHandoff:
    def test_window_partitions_ops_exactly_under_concurrent_writes(self):
        """Regression: snapshot() used to read ``self.ops`` twice (once for
        the rate, once for the reset), so ops recorded between the two reads
        vanished from every window. The fix reads once; now consecutive
        snapshots partition the op stream exactly:
        ``round(ops_per_s * window_s) == ops_delta`` for every window."""
        tel = ConnTelemetry()
        stop = threading.Event()

        def hammer():
            while not stop.is_set():
                tel.record_send(1, 8, 1e-6)

        t = threading.Thread(target=hammer)
        t.start()
        try:
            prev = tel.snapshot()
            for _ in range(200):
                snap = tel.snapshot()
                window_ops = round(snap["ops_per_s"] * snap["window_s"])
                assert window_ops == snap["ops"] - prev["ops"], \
                    "ops recorded mid-snapshot leaked out of both windows"
                prev = snap
        finally:
            stop.set()
            t.join()

    def test_window_s_key_present_and_sane(self):
        tel = ConnTelemetry()
        tel.record_send(2, 16, 1e-6)
        time.sleep(0.01)
        snap = tel.snapshot()
        assert snap["window_s"] > 0
        assert round(snap["ops_per_s"] * snap["window_s"]) == 1


# ---------------------------------------------------------------------------
# Exporters + the end-to-end scenario
# ---------------------------------------------------------------------------


class TestExport:
    def _records(self):
        TRACER.enable()
        with TRACER.span("controller.tick", attrs={"rule": "r"}) as sp:
            sp.event("vote", peer="p")
            with TRACER.span("reconfig.swap"):
                pass
        TRACER.record_batch("fabric.send_batch", 4, 4)
        return TRACER.collect()

    def test_chrome_trace_shape(self):
        doc = to_chrome(self._records())
        assert doc["displayTimeUnit"] == "ms"
        phases = {e["ph"] for e in doc["traceEvents"]}
        assert {"X", "i", "M"} <= phases
        complete = [e for e in doc["traceEvents"] if e["ph"] == "X"]
        assert {e["name"] for e in complete} == {"controller.tick",
                                                "reconfig.swap"}
        assert all(e["dur"] > 0 for e in complete)
        instants = {e["name"] for e in doc["traceEvents"] if e["ph"] == "i"}
        assert "controller.tick:vote" in instants
        assert "fabric.send_batch" in instants
        json.dumps(doc)   # must be serializable as-is

    def test_timeline_and_phases(self):
        recs = self._records()
        pd = phase_durations(recs)
        assert "detect" in pd and "swap" in pd
        text = render_timeline(recs)
        assert "switch timeline" in text and "detect" in text

    def test_empty_timeline(self):
        assert render_timeline([]) == "(no phase spans recorded)"


class TestKvSwitchScenario:
    @pytest.mark.slow
    def test_one_stitched_trace_through_the_switch(self, tmp_path):
        from repro.obs.__main__ import check_records
        from repro.obs.scenario import run_kv_switch_scenario

        res = run_kv_switch_scenario(seed=7)
        assert res["committed"], res["decisions"]
        assert res["client_fp"] == res["server_fp"]
        assert "Compact" in res["client_fp"]
        summary = check_records(res["records"])
        assert summary["swaps"] >= 2            # both endpoints, one trace
        names = {r["name"] for r in res["records"] if r["kind"] == "span"}
        assert {"negotiate.client", "negotiate.offer", "negotiate.score",
                "2pc.prepare", "2pc.peer.prepare", "2pc.commit",
                "2pc.peer.commit", "scenario.drain"} <= names
        # the offer span carries the per-candidate negotiation scores
        (offer,) = spans_named(res["records"], "negotiate.offer")
        assert offer["attrs"]["candidates"], "scored offer lost its scores"
        # metrics plane sees every family the scenario touched
        samples = parse_prometheus(res["registry"].to_prometheus())
        families = {s["name"].split("_")[1] for s in samples}
        assert {"fabric", "conn", "controller"} <= families
        # scenario leaves the global tracer the way it found it
        assert not TRACER.enabled


# ---------------------------------------------------------------------------
# PR 10: flight-recorder rotation + Prometheus exposition edge cases
# ---------------------------------------------------------------------------


class TestFlightRecorderRotation:
    def test_oldest_dumps_rotated_out(self, tmp_path):
        TRACER.enable()
        rec = FlightRecorder(out_dir=str(tmp_path), max_dumps=3)
        for i in range(5):
            assert rec.dump(f"r{i}") is not None
        names = sorted(p.name for p in tmp_path.iterdir())
        assert names == ["flightrec_r2.json", "flightrec_r3.json",
                         "flightrec_r4.json"]

    def test_just_written_dump_survives_mtime_ties(self, tmp_path):
        # give every prior dump an identical (newer) mtime: (mtime, name)
        # ordering alone would then delete the newest file — the keep guard
        # must protect it
        import os as _os

        TRACER.enable()
        rec = FlightRecorder(out_dir=str(tmp_path), max_dumps=1)
        rec.dump("a")
        path = rec.dump("z_last")
        for p in tmp_path.iterdir():
            _os.utime(p, (2_000_000_000, 2_000_000_000))
        rec.dump("b")  # triggers rotation over the tied set
        assert (tmp_path / "flightrec_b.json").exists()

    def test_zero_disables_rotation(self, tmp_path):
        TRACER.enable()
        rec = FlightRecorder(out_dir=str(tmp_path), max_dumps=0)
        for i in range(6):
            rec.dump(f"r{i}")
        assert len(list(tmp_path.iterdir())) == 6

    def test_env_var_sets_default_cap(self, tmp_path, monkeypatch):
        monkeypatch.setenv("REPRO_FLIGHTREC_KEEP", "2")
        TRACER.enable()
        rec = FlightRecorder(out_dir=str(tmp_path))
        assert rec.max_dumps == 2
        for i in range(4):
            rec.dump(f"r{i}")
        assert len(list(tmp_path.iterdir())) == 2


class TestPrometheusEdgeCases:
    def test_label_escaping_round_trips(self):
        from repro.obs.metrics import _unescape

        reg = MetricsRegistry()
        nasty = 'quote:" back:\\ newline:\n comma:, done'
        reg.register("conn", lambda: {"ops": 1.0}, instance=nasty)
        samples = parse_prometheus(reg.to_prometheus())
        assert samples[0]["labels"]["instance"] == nasty
        # the scanner is left-to-right: the four-char sequence \\n is an
        # escaped backslash then a literal n, NOT a newline
        assert _unescape("\\\\n") == "\\n"
        assert _unescape("\\n") == "\n"
        assert _unescape('\\"x\\"') == '"x"'

    def test_non_finite_values_round_trip(self):
        reg = MetricsRegistry()
        reg.register("conn", lambda: {"nan_v": float("nan"),
                                      "pinf": float("inf"),
                                      "ninf": float("-inf")}, instance="i")
        by_name = {s["name"].rsplit("_", 1)[-1]: s["value"]
                   for s in parse_prometheus(reg.to_prometheus())}
        assert math.isnan(by_name["v"])        # repro_conn_nan_v
        assert by_name["pinf"] == math.inf
        assert by_name["ninf"] == -math.inf

    def test_federated_multi_member_output_parses(self):
        from repro.core.rendezvous import KVStore
        from repro.obs.federate import MetricsFederator, MetricsPublisher

        store = KVStore()
        now = lambda: 5.0
        for name, ops in (("edge-1", 10.0), ('odd"member', 20.0)):
            reg = MetricsRegistry()
            reg.register("conn", lambda o=ops: {"ops_per_s": o},
                         instance=f"{name}/c")
            MetricsPublisher(store, "promfed", name, reg, now=now).publish()
        fed = MetricsFederator(store, "promfed", ttl_s=5.0, now=now)
        samples = parse_prometheus(fed.federated_registry().to_prometheus())
        insts = {s["labels"]["instance"] for s in samples}
        assert 'odd"member/odd"member/c' in insts
        assert "_fleet" in insts
