"""Shared tier-1 fixtures (chaos harness satellites, docs §9).

``seeded_fabric`` builds deterministically-seeded fabrics: the same seed
replays the same loss-mask / jitter draws, and the default link is
zero-latency so delivery is synchronous — tests drive time explicitly
(``virtual_clock`` + ``ChaosInjector.poll(now=...)``) instead of sleeping.
"""
import pytest

from repro.chaos import VirtualClock
from repro.core.fabric import Fabric, LinkModel


@pytest.fixture
def seeded_fabric():
    """Factory: ``seeded_fabric(seed=..., default_link=...) -> Fabric``.

    Zero-latency default link unless overridden, so sends deliver before
    ``send_batch`` returns and assertions never race a timer thread."""

    def make(seed: int = 0, *, default_link: LinkModel = None, **kw) -> Fabric:
        return Fabric(default_link=default_link or LinkModel(),
                      seed=seed, **kw)

    return make


@pytest.fixture
def virtual_clock():
    """A ``VirtualClock`` starting at t=0 — pass ``now=clock()`` to
    ``ChaosInjector.start/poll`` (and ``advance`` between polls) to replay a
    chaos schedule deterministically on any CI machine."""
    return VirtualClock(0.0)
