"""Substrate tests: data determinism, checkpoint atomicity/resharding,
trainer negotiation + live reconfiguration + straggler mitigation."""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from repro import compat

from repro.checkpoint.ckpt import Checkpointer
from repro.configs import get_smoke_config
from repro.configs.base import ShapeConfig, TrainConfig
from repro.data.synthetic import SyntheticLM, DataConfig, batches_for
from repro.launch.mesh import make_test_mesh
from repro.train.trainer import HostSpec, ReconfigurableTrainer, StragglerPolicy


class TestData:
    def test_deterministic_resume(self):
        ds = SyntheticLM(DataConfig(seq_len=32, global_batch=4))
        a = ds.batch(7)
        b = ds.batch(7)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_sharded_equals_global(self):
        """2 hosts' shards concatenate to the 1-host global batch (elastic
        resharding invariant)."""
        cfg = DataConfig(seq_len=16, global_batch=4)
        full = SyntheticLM(cfg).batch(3)
        h0 = SyntheticLM(cfg, host_id=0, num_hosts=2).batch(3)
        h1 = SyntheticLM(cfg, host_id=1, num_hosts=2).batch(3)
        np.testing.assert_array_equal(
            full["tokens"], np.concatenate([h0["tokens"], h1["tokens"]]))

    def test_labels_are_shifted_tokens(self):
        b = SyntheticLM(DataConfig(seq_len=32, global_batch=2)).batch(0)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


class TestCheckpoint:
    def test_roundtrip_mixed_dtypes(self, tmp_path):
        ck = Checkpointer(tmp_path)
        state = {"w": jnp.arange(6.0).reshape(2, 3),
                 "m": jnp.ones((4,), jnp.bfloat16),
                 "n": jnp.asarray(3, jnp.int32)}
        ck.save(5, state)
        like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state)
        restored, step = ck.restore(like)
        assert step == 5
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
                     state, restored)

    def test_crash_mid_save_keeps_previous(self, tmp_path):
        ck = Checkpointer(tmp_path)
        ck.save(1, {"w": jnp.zeros(3)})
        # simulate a crash: a stale tmp dir from a dead writer
        (tmp_path / "step_2.tmp").mkdir()
        (tmp_path / "step_2.tmp" / "garbage").write_text("x")
        restored, step = ck.restore({"w": jax.ShapeDtypeStruct((3,), jnp.float32)})
        assert step == 1

    def test_gc_keeps_last_k(self, tmp_path):
        ck = Checkpointer(tmp_path, keep=2)
        for s in (1, 2, 3, 4):
            ck.save(s, {"w": jnp.zeros(2)})
        assert ck.steps() == [3, 4]

    def test_async_save_consistent_cut(self, tmp_path):
        ck = Checkpointer(tmp_path)
        x = jnp.ones(4)
        fut = ck.save(1, {"w": x}, asynchronous=True)
        fut.result()
        restored, _ = ck.restore({"w": jax.ShapeDtypeStruct((4,), jnp.float32)})
        np.testing.assert_array_equal(np.asarray(restored["w"]), 1.0)

    def test_restore_with_resharding(self, tmp_path):
        """Elastic restart: restore onto a different mesh layout."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        ck = Checkpointer(tmp_path)
        state = {"w": jnp.arange(16.0).reshape(4, 4)}
        ck.save(1, state)
        mesh = make_test_mesh((2, 4))
        sh = {"w": NamedSharding(mesh, P("data", None))}
        restored, _ = ck.restore(
            {"w": jax.ShapeDtypeStruct((4, 4), jnp.float32)}, shardings=sh)
        np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(state["w"]))
        assert restored["w"].sharding.spec == P("data", None)


@pytest.fixture(scope="module")
def pod_mesh():
    m = make_test_mesh((2, 4), ("pod", "model"))
    compat.set_mesh(m)
    return m


class TestTrainer:
    def _trainer(self, pod_mesh, transport="psum", hosts=None, **kw):
        cfg = get_smoke_config("llama3.2-1b")
        shape = ShapeConfig("t", 64, 4, "train")
        return ReconfigurableTrainer(
            cfg, shape, pod_mesh,
            tcfg=TrainConfig(learning_rate=1e-3, warmup_steps=2, total_steps=50),
            transport=transport,
            hosts=hosts or [HostSpec(0, [transport, "xla"])],
            **kw,
        ), cfg, shape

    def test_negotiation_picks_common_transport(self, pod_mesh):
        tr, _, _ = self._trainer(
            pod_mesh, transport="psum",
            hosts=[HostSpec(0, ["compressed_int8", "psum"]),
                   HostSpec(1, ["psum"])])  # host1 can't do compressed
        # first proposer commits compressed_int8? host0 proposes first; host1
        # must be compatible -> host1 joins via its psum? Incompatible would
        # raise; compatible via the committed stack name check:
        assert tr.transport_name in ("compressed_int8", "psum")

    def test_train_and_reconfigure_preserves_state(self, pod_mesh):
        tr, cfg, shape = self._trainer(pod_mesh, transport="psum")
        gen = batches_for(cfg, shape)
        state = tr.init_state(jax.random.PRNGKey(0))
        state, h1 = tr.run(state, gen, 6)
        step_before = int(state.step)
        state = tr.reconfigure(state, "compressed_int8")
        assert tr.transport_name == "compressed_int8"
        assert int(state.step) == step_before  # params/opt state carried over
        state, h2 = tr.run(state, gen, 6)
        assert np.isfinite(h2[-1]["loss"])
        # EF residual state was created for the new wire format
        assert tr.reconfig_log[-1]["committed"]

    def test_straggler_triggers_reconfiguration(self, pod_mesh):
        tr, cfg, shape = self._trainer(pod_mesh, transport="psum")
        gen = batches_for(cfg, shape)
        state = tr.init_state(jax.random.PRNGKey(0))
        pol = StragglerPolicy(window=3, slow_factor=1.5, fallback="compressed_int8")
        state, _ = tr.run(state, gen, 14, straggler=pol,
                          inject_slow=lambda i: 0.3 if i >= 6 else 0.0)
        assert tr.transport_name == "compressed_int8"
        assert any(r.get("committed") for r in tr.reconfig_log)

    def test_checkpoint_restart_loss_continuity(self, pod_mesh, tmp_path):
        tr, cfg, shape = self._trainer(pod_mesh, transport="psum",
                                       ckpt_dir=str(tmp_path))
        gen = batches_for(cfg, shape)
        state = tr.init_state(jax.random.PRNGKey(0))
        state, h1 = tr.run(state, gen, 8)
        tr.save(state)
        restored, at = tr.restore()
        assert at == 8
        state2, h2 = tr.run(restored, gen, 4)
        assert np.isfinite(h2[-1]["loss"])
        assert h2[-1]["loss"] < h1[0]["loss"]
