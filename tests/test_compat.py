"""Tests for the JAX version-compatibility layer itself (repro.compat).

These run against whatever JAX is installed: they assert the *contract*
of the shim (round-trips, context tracking, report contents), with
per-path assertions where native and legacy behavior legitimately differ.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat


class TestMakeMesh:
    def test_roundtrips_axis_names_and_shape(self):
        mesh = compat.make_mesh((2, 4), ("data", "model"),
                                axis_types=(compat.AUTO,) * 2)
        assert tuple(mesh.axis_names) == ("data", "model")
        assert mesh.devices.shape == (2, 4)
        assert compat.axis_size(mesh, "data") == 2
        assert compat.axis_size(mesh, "model") == 4

    def test_axis_types_are_queryable_without_private_attrs(self):
        mesh = compat.make_mesh((2, 4), ("data", "model"),
                                axis_types=(compat.EXPLICIT, compat.AUTO))
        assert not compat.axis_is_auto(mesh, "data")
        assert compat.axis_is_auto(mesh, "model")

    def test_default_axis_types_are_auto(self):
        mesh = compat.make_mesh((8,), ("data",))
        assert compat.axis_is_auto(mesh, "data")
        # unknown axis names default to Auto rather than raising
        assert compat.axis_is_auto(mesh, "nonexistent")
        assert compat.axis_is_auto(None, "data")

    def test_agrees_with_native_axis_types(self):
        """On JAX with real axis types, compat must report exactly what the
        native mesh says; on 0.4.x the side table must stand in for it."""
        mesh = compat.make_mesh((2, 4), ("data", "model"),
                                axis_types=(compat.AUTO,) * 2)
        if compat.has("axis_types"):
            native = dict(zip(mesh.axis_names, mesh.axis_types))
            for name in mesh.axis_names:
                assert compat.axis_is_auto(mesh, name) == (
                    getattr(native[name], "name", None) == "Auto")
        else:
            assert all(compat.axis_is_auto(mesh, a) for a in mesh.axis_names)


class TestMeshContext:
    def test_use_mesh_scopes_the_ambient_mesh(self):
        # compat.set_mesh is deliberately persistent, and other test modules
        # in the same process may have called it — assert restoration to
        # whatever was ambient before, not to None.
        before = compat.current_mesh()
        mesh = compat.make_mesh((2, 4), ("data", "model"),
                                axis_types=(compat.AUTO,) * 2)
        with compat.use_mesh(mesh):
            seen = compat.current_mesh()
            assert seen is not None
            assert tuple(seen.axis_names) == ("data", "model")
            assert compat.axis_size(seen, "model") == 4
        after = compat.current_mesh()
        assert (after is before) or (after == before)

    def test_sharding_constraint_works_under_use_mesh(self):
        """The property the whole stack depends on: bare-PartitionSpec
        with_sharding_constraint composes with jit inside the mesh context."""
        mesh = compat.make_mesh((2, 4), ("data", "model"),
                                axis_types=(compat.AUTO,) * 2)
        with compat.use_mesh(mesh):
            f = jax.jit(
                lambda x: jax.lax.with_sharding_constraint(x, P("data", None)))
            out = f(jnp.ones((4, 8)))
            np.testing.assert_array_equal(np.asarray(out), 1.0)


class TestShardMap:
    def test_psum_matches_tree_sum(self):
        mesh = compat.make_mesh((2, 4), ("pod", "data"),
                                axis_types=(compat.AUTO,) * 2)
        f = compat.shard_map(lambda x: jax.lax.psum(x, "pod"), mesh=mesh,
                             in_specs=P(), out_specs=P(), check_vma=False,
                             axis_names={"pod"})
        out = jax.jit(f)(jnp.arange(6.0))
        np.testing.assert_allclose(np.asarray(out), 2 * np.arange(6.0))

    def test_named_axis_size_is_static(self):
        mesh = compat.make_mesh((2, 4), ("pod", "data"),
                                axis_types=(compat.AUTO,) * 2)

        def fn(x):
            n = compat.named_axis_size("pod")
            # must be usable as a Python int (loop bounds in the ring
            # collectives) — a tracer would throw here
            assert int(n) == 2
            return x

        f = compat.shard_map(fn, mesh=mesh, in_specs=P(), out_specs=P(),
                             check_vma=False, axis_names={"pod"})
        jax.jit(f)(jnp.arange(2.0))

    def test_manual_axes_reported_not_auto(self):
        """Inside shard_map, manual axes must stop reporting as Auto so the
        pshard constraint helpers skip them (on 0.6 the abstract mesh says
        Manual; on 0.4.x the trace-time axis env stands in)."""
        mesh = compat.make_mesh((2, 4), ("pod", "data"),
                                axis_types=(compat.AUTO,) * 2)
        seen = {}

        def fn(x):
            m = compat.current_mesh()
            seen["pod_auto"] = compat.axis_is_auto(m, "pod")
            return x

        f = compat.shard_map(fn, mesh=mesh, in_specs=P(), out_specs=P(),
                             check_vma=False, axis_names={"pod"})
        with compat.use_mesh(mesh):
            jax.jit(f)(jnp.arange(2.0))
        assert seen["pod_auto"] is False


class TestCostAnalysis:
    def test_returns_flat_dict(self):
        c = jax.jit(lambda x: x @ x).lower(jnp.ones((8, 8))).compile()
        cost = compat.cost_analysis(c)
        assert hasattr(cost, "keys") and "flops" in cost
        assert float(cost["flops"]) > 0


class TestReport:
    def test_report_names_active_code_path(self):
        r = compat.report()
        assert jax.__version__ in r
        # every shim entry point states which implementation it bound
        for api in ("make_mesh", "shard_map", "set_mesh", "tree_map"):
            assert api in r
        assert ("native" in r) or ("legacy" in r)

    def test_feature_registry(self):
        feats = compat.features()
        assert feats  # non-empty, all booleans
        assert all(isinstance(v, bool) for v in feats.values())
        assert compat.has("axis_types") == feats["axis_type"]
        with pytest.raises(KeyError):
            compat.has("not_a_feature")

    def test_jax_at_least(self):
        assert compat.jax_at_least("0.4")
        assert compat.jax_at_least("0.4.37")
        assert not compat.jax_at_least("99.0")

    def test_tree_map(self):
        out = compat.tree_map(lambda a: a + 1, {"x": jnp.zeros(2)})
        np.testing.assert_array_equal(np.asarray(out["x"]), 1.0)
