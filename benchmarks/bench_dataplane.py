"""Data-plane throughput: msgs/s and bytes/s-per-core vs batch size.

Measures the batched hot path (docs/architecture.md §8) across three stacks —
default (raw fabric datapath), compressed (fused Pallas int8 wire), reliable
(windowed ReliableChannel) — at 1/8/64/512-message batches, against the
PR-6-era per-message baseline (global fabric lock, per-message RNG draw,
``queue.Queue`` inbox) replicated below and measured in the same run.

Writes ``benchmarks/out/dataplane.json``; the acceptance gate is
``speedup_batch64`` (batched default stack at batch=64 over the per-message
baseline) ≥ 10x. The driver is single-threaded, so msgs/s IS msgs/s-per-core.
"""
from __future__ import annotations

import json
import queue
import random
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from benchmarks.common import emit
from repro.core.fabric import Fabric, LinkModel, ReliableChannel
from repro.core.runtime import FabricTransport

OUT = Path(__file__).parent / "out" / "dataplane.json"

BATCHES = (1, 8, 64, 512)
PAYLOAD = 64  # bytes per message on the default/reliable stacks


# ---------------------------------------------------------------------------
# Per-message baseline: a faithful replica of the pre-batching fabric
# (PR-6 era): one global lock + RNG draw + byte accounting per message, and a
# queue.Queue inbox delivering one (src, msg) tuple per put/get.
# ---------------------------------------------------------------------------


class _LegacyEndpoint:
    def __init__(self, addr: str, fabric: "_LegacyFabric"):
        self.addr = addr
        self.fabric = fabric
        self.inbox: "queue.Queue[Tuple[str, Any]]" = queue.Queue()

    def send(self, dst: str, msg: Any) -> None:
        self.fabric.send(self.addr, dst, msg)

    def recv(self, timeout: Optional[float] = None) -> Optional[Tuple[str, Any]]:
        try:
            return self.inbox.get(timeout=timeout)
        except queue.Empty:
            return None


class _LegacyFabric:
    def __init__(self, seed: int = 0):
        self._eps: Dict[str, _LegacyEndpoint] = {}
        self._rng = random.Random(seed)
        self._lock = threading.Lock()
        # internal tally emulating the legacy fabric's per-send accounting
        # cost (NOT the deprecated Fabric.sent_msgs/sent_bytes aliases)
        self.byte_count = 0
        self.msg_count = 0

    def register(self, addr: str) -> _LegacyEndpoint:
        ep = _LegacyEndpoint(addr, self)
        self._eps[addr] = ep
        return ep

    def send(self, src: str, dst: str, msg: Any) -> None:
        size = len(msg) if isinstance(msg, (bytes, str)) else 8
        with self._lock:
            self._rng.random()  # loss draw (loss=0 here, but the draw is paid)
            ep = self._eps.get(dst)
            self.msg_count += 1
            self.byte_count += size
        if ep is not None:
            ep.inbox.put((src, msg))


def bench_per_message_baseline(n_msgs: int) -> dict:
    fab = _LegacyFabric()
    a = fab.register("legacy-a")
    b = fab.register("legacy-b")
    payload = b"x" * PAYLOAD
    t0 = time.perf_counter()
    for _ in range(n_msgs):
        a.send("legacy-b", payload)
    while b.recv(timeout=0) is not None:
        pass
    dt = time.perf_counter() - t0
    return {"n_msgs": n_msgs, "msgs_per_s": n_msgs / dt,
            "bytes_per_s": n_msgs * PAYLOAD / dt}


# ---------------------------------------------------------------------------
# Batched stacks
# ---------------------------------------------------------------------------


def bench_default(batch: int, n_msgs: int) -> dict:
    """Raw fabric datapath: Endpoint.send_batch + recv_many."""
    fab = Fabric()
    a = fab.register("dflt-a")
    b = fab.register("dflt-b")
    msgs = [b"x" * PAYLOAD] * batch
    buf: List[Any] = [None] * max(batch, 64)
    iters = max(1, n_msgs // batch)
    t0 = time.perf_counter()
    for _ in range(iters):
        a.send_batch("dflt-b", msgs)
        got = 0
        while got < batch:
            n = b.recv_many(buf, timeout=0.1)
            if not n:
                break
            got += n
    dt = time.perf_counter() - t0
    total = iters * batch
    return {"batch": batch, "n_msgs": total, "msgs_per_s": total / dt,
            "bytes_per_s": total * PAYLOAD / dt}


def bench_compressed(batch: int, iters: int, *, msg_elems: int = 1024) -> dict:
    """Fused Pallas wire path: one device call per batch (quantize→pack on
    send, unpack→dequantize on recv), chunked over the fabric."""
    from repro.comm.wire import CompressChunnel

    fab = Fabric()
    a = fab.register("cmp-a")
    b = fab.register("cmp-b")
    tx = CompressChunnel(use_kernel=True).connect_wrap(
        FabricTransport(a, "cmp-b").connect_wrap(None))
    rx = CompressChunnel(use_kernel=True).connect_wrap(
        FabricTransport(b, "cmp-a").connect_wrap(None))
    rng = np.random.default_rng(0)
    msgs = [rng.standard_normal(msg_elems).astype(np.float32)
            for _ in range(batch)]
    buf: List[Any] = [None] * batch
    payload_bytes = batch * msg_elems * 4
    tx.send(msgs)  # warmup: jit compile both directions for this shape
    assert rx.recv(buf, timeout=2.0) == batch
    t0 = time.perf_counter()
    for _ in range(iters):
        tx.send(msgs)
        got = 0
        while got < batch:
            n = rx.recv(buf, timeout=2.0)
            if not n:
                break
            got += n
    dt = time.perf_counter() - t0
    total = iters * batch
    wire = fab.counters.sent_bytes
    return {"batch": batch, "n_msgs": total, "msgs_per_s": total / dt,
            "bytes_per_s": iters * payload_bytes / dt,
            "wire_ratio": wire / max(1, (iters + 1) * payload_bytes)}


def bench_reliable(batch: int, n_msgs: int, *, window: int = 32,
                   link_latency_s: float = 2e-4) -> dict:
    """Windowed ReliableChannel over a latency link vs stop-and-wait: up to W
    frames in flight instead of one RTT per frame."""
    fab = Fabric(default_link=LinkModel(latency_s=link_latency_s))
    cli = fab.register("rel-cli")
    srv = fab.register("rel-srv")
    server_chan = ReliableChannel(srv, peer="rel-cli")
    stop = threading.Event()

    def serve():
        while not stop.is_set():
            server_chan.serve_one(lambda src, m: {"ok": m["i"]}, timeout=0.02)

    th = threading.Thread(target=serve, daemon=True)
    th.start()
    chan = ReliableChannel(cli, peer="rel-srv", timeout=0.5, window=window)
    try:
        iters = max(1, n_msgs // batch)
        t0 = time.perf_counter()
        for _ in range(iters):
            replies = chan.request_window([{"i": i} for i in range(batch)])
            assert len(replies) == batch
        dt = time.perf_counter() - t0
    finally:
        stop.set()
        th.join(timeout=1.0)
    total = iters * batch
    return {"batch": batch, "n_msgs": total, "msgs_per_s": total / dt,
            "window": window}


def bench_reliable_stop_and_wait(n_msgs: int, *,
                                 link_latency_s: float = 2e-4) -> dict:
    fab = Fabric(default_link=LinkModel(latency_s=link_latency_s))
    cli = fab.register("saw-cli")
    srv = fab.register("saw-srv")
    server_chan = ReliableChannel(srv, peer="saw-cli")
    stop = threading.Event()

    def serve():
        while not stop.is_set():
            server_chan.serve_one(lambda src, m: {"ok": m["i"]}, timeout=0.02)

    th = threading.Thread(target=serve, daemon=True)
    th.start()
    chan = ReliableChannel(cli, peer="saw-srv", timeout=0.5)
    try:
        t0 = time.perf_counter()
        for i in range(n_msgs):
            chan.request({"i": i})
        dt = time.perf_counter() - t0
    finally:
        stop.set()
        th.join(timeout=1.0)
    return {"n_msgs": n_msgs, "msgs_per_s": n_msgs / dt}


# ---------------------------------------------------------------------------


def _best_of(fn, repeats: int = 3) -> dict:
    """Max-throughput of N repeats: robust to transient CPU contention (the
    gate below compares two measurements, so one depressed sample must not
    flip it)."""
    return max((fn() for _ in range(repeats)), key=lambda r: r["msgs_per_s"])


def run(smoke: bool = False) -> dict:
    scale = 8 if smoke else 1
    baseline = _best_of(lambda: bench_per_message_baseline(40_000 // scale))
    emit("dataplane_permsg_baseline", 1e6 / baseline["msgs_per_s"],
         f"msgs_per_s={baseline['msgs_per_s']:.0f}")

    default: Dict[str, dict] = {}
    for b in BATCHES:
        r = _best_of(lambda b=b: bench_default(b, 160_000 // scale))
        default[str(b)] = r
        emit(f"dataplane_default_b{b}", 1e6 / r["msgs_per_s"],
             f"msgs_per_s={r['msgs_per_s']:.0f};bytes_per_s={r['bytes_per_s']:.0f}")

    compressed: Dict[str, dict] = {}
    comp_batches = (1, 64) if smoke else BATCHES
    for b in comp_batches:
        r = bench_compressed(b, 3 if smoke else 10,
                             msg_elems=256 if smoke else 1024)
        compressed[str(b)] = r
        emit(f"dataplane_compressed_b{b}", 1e6 / r["msgs_per_s"],
             f"msgs_per_s={r['msgs_per_s']:.0f};wire_ratio={r['wire_ratio']:.3f}")

    saw = bench_reliable_stop_and_wait(100 // scale + 20)
    emit("dataplane_reliable_stopwait", 1e6 / saw["msgs_per_s"],
         f"msgs_per_s={saw['msgs_per_s']:.0f}")
    reliable: Dict[str, dict] = {"stop_and_wait": saw}
    rel_batches = (64,) if smoke else BATCHES
    for b in rel_batches:
        r = bench_reliable(b, 2000 // scale)
        reliable[str(b)] = r
        emit(f"dataplane_reliable_b{b}", 1e6 / r["msgs_per_s"],
             f"msgs_per_s={r['msgs_per_s']:.0f};window={r['window']}")

    speedup = default["64"]["msgs_per_s"] / baseline["msgs_per_s"]
    out = {
        "smoke": smoke,
        "per_message_baseline": baseline,
        "default": default,
        "compressed": compressed,
        "reliable": reliable,
        "speedup_batch64": speedup,
    }
    OUT.parent.mkdir(parents=True, exist_ok=True)
    OUT.write_text(json.dumps(out, indent=2))
    emit("dataplane_speedup_batch64", 0.0, f"speedup={speedup:.1f}x")
    assert speedup >= 10.0, (
        f"batched data plane only {speedup:.1f}x over per-message baseline")
    return out


def main() -> None:
    run(smoke=False)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="scaled-down pass for CI; still writes dataplane.json")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(smoke=args.smoke)
