"""Paper Fig. 9 analogue: KV-store op latency — full Bertha stack vs
no-chunnel (inlined) vs no-chunnel-no-mux baselines."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, pct
from repro.core import Fabric, FnChunnel, LinkModel, LockedConn, make_stack
from repro.core.capability import CapabilitySet
from repro.serving.router import AddressedTransport, ClientShardChunnel, KVBackend, KVClient


def run(config: str, n_req: int = 200) -> list:
    fabric = Fabric(default_link=LinkModel(latency_s=0.0005))
    backends = [KVBackend(fabric, f"kv{i}") for i in range(4)]
    ep = fabric.register("cli")
    if config == "full":
        # serialization + sharding + reliability-tag chunnels (3 functional)
        ser = FnChunnel(fn_name="Serialize", on_send=lambda m: m,
                        caps=CapabilitySet.exact("ser:dict"))
        rel = FnChunnel(fn_name="Reliability",
                        on_send=lambda m: {**m, "_seq": m["rid"]})
        stack = make_stack(ser, rel,
                           ClientShardChunnel(backends=tuple(b.addr for b in backends)),
                           AddressedTransport(ep))
    elif config == "no_chunnel":
        stack = make_stack(ClientShardChunnel(backends=tuple(b.addr for b in backends)),
                           AddressedTransport(ep))
    else:  # no_chunnel_no_mux: direct to a single fixed backend
        class Direct(FnChunnel):
            def connect_wrap(self, inner):
                dp = inner

                class DP:
                    def send(self, msgs):
                        for m in msgs:
                            m = dict(m)
                            m["_route_to"] = backends[0].addr
                            dp.send([m])

                    def recv(self, buf, timeout=None):
                        return dp.recv(buf, timeout)

                return DP()

        stack = make_stack(Direct(fn_name="Direct"), AddressedTransport(ep))

    client = KVClient(fabric, ep, LockedConn(stack.preferred()))
    lats = []
    for i in range(n_req):
        _, lat = client.request("get", f"k{i % 11}", timeout=3.0)
        lats.append(lat)
    for b in backends:
        b.close()
    return lats


def main() -> None:
    base = None
    for config in ("no_chunnel_no_mux", "no_chunnel", "full"):
        lats = run(config)
        p50 = pct(lats, 50)
        if base is None:
            base = p50
        emit(f"kvlat_{config}_p50", p50 * 1e6,
             f"p95={pct(lats,95)*1e6:.0f}us;vs_base={p50/base - 1:+.1%}")


if __name__ == "__main__":
    main()
