"""Paper Fig. 5 analogue: receive-side vs service-side ordering, with the
multi-party renegotiation when a second receiver joins.

Single receiver: best-effort queue + receive-side reordering beats the FIFO
service on latency. A second subscriber makes receive-side ordering unsafe
(coordination across consumers), so the connection renegotiates to
service-side ordering through the rendezvous store (2PC) without dropping
messages.
"""
from __future__ import annotations

import threading
import time

import numpy as np

from benchmarks.common import emit, pct
from repro.core import KVStore, LockedConn, make_stack
from repro.core import rendezvous
from repro.serving.pubsub import (
    SQS_BEST_EFFORT,
    SQS_ORDERED,
    Broker,
    PubSubChunnel,
    ReceiveSideOrdering,
    ServiceOrdering,
)


def run_phase(stack, n_msgs: int = 60, interarrival_s: float = 0.004):
    # producer (ingester) and consumer (parser) are separate endpoints with
    # their own negotiated handles over the same topic
    producer = LockedConn(stack.preferred())
    consumer_h = LockedConn(stack.preferred())
    lats = []
    recvd = []
    done = threading.Event()

    def consumer():
        buf = [None]
        while len(recvd) < n_msgs and not done.wait(0):
            n = consumer_h.recv(buf, timeout=0.05)
            if n:
                m = buf[0]
                recvd.append(m["i"])
                lats.append(time.monotonic() - m["t0"])

    t = threading.Thread(target=consumer)
    t.start()
    for i in range(n_msgs):
        producer.send([{"i": i, "group": i % 5, "t0": time.monotonic()}])
        time.sleep(interarrival_s)
    t.join(timeout=5.0)
    done.set()
    return lats, recvd


def main() -> None:
    # Phase 1: single receiver, best-effort + receive-side ordering
    be = Broker(SQS_BEST_EFFORT)
    st_recv = make_stack(ReceiveSideOrdering(groups=5), PubSubChunnel(be, "logs"))
    lats_recv, order_recv = run_phase(st_recv)
    emit("ordering_receive_side_p50", pct(lats_recv, 50) * 1e6,
         f"p95={pct(lats_recv,95)*1e6:.0f}us;in_order={order_recv == sorted(order_recv)}")

    # Service-side (FIFO queue) for contrast
    fifo = Broker(SQS_ORDERED)
    st_svc = make_stack(ServiceOrdering(), PubSubChunnel(fifo, "logs"))
    lats_svc, _ = run_phase(st_svc)
    emit("ordering_service_side_p50", pct(lats_svc, 50) * 1e6,
         f"p95={pct(lats_svc,95)*1e6:.0f}us")
    gain = 1 - pct(lats_recv, 50) / pct(lats_svc, 50)
    emit("ordering_latency_reduction", 0.0, f"median_lower_by={gain:.0%}")

    # Phase 2: second receiver joins -> renegotiate to service ordering (§5.3)
    store = KVStore()
    rendezvous.join(store, "logs", "recv1", ["order:receive-side"],
                    [[{"name": "ReceiveSideOrdering", "caps": []}]], lambda d: 0)
    t0 = time.perf_counter()
    res = rendezvous.join(store, "logs", "recv2",
                          ["order:service", "order:receive-side"],
                          [[{"name": "ServiceOrdering", "caps": []}],
                           [{"name": "ReceiveSideOrdering", "caps": []}]],
                          lambda d: 1)
    epoch = rendezvous.propose_transition(store, "logs", "recv2", "order:service",
                                          [{"name": "ServiceOrdering", "caps": []}])
    rendezvous.vote(store, "logs", "recv1", epoch, True)
    committed = rendezvous.try_commit(store, "logs", epoch, 5.0)
    switch_ms = (time.perf_counter() - t0) * 1e3
    assert committed
    cur = rendezvous.current_stack(store, "logs")
    emit("ordering_renegotiation", switch_ms * 1e3,
         f"committed={committed};now={cur['fp']};participants={res.participants}")


if __name__ == "__main__":
    main()
