"""Fleet SLO plane end-to-end: federation → burn-rate breach → earlier switch.

Two scenarios assert the PR's tentpole loop (docs/architecture.md §11):

``run_slo_guard_scenario`` — one edge region talks to a ``WanGateway`` hub
through the negotiated Select [FastWire | WanLink] while a ``ChaosPlan``
degrades its links (latency + jitter + loss). The region's metrics registry
is published over the KV obs plane (``MetricsPublisher``), federated
(``MetricsFederator``), and judged by an ``SLOEngine`` whose latency SLO
reads the *federated per-region* p95 — intent-level: the objective's
threshold sits far below any "on fire" hard threshold. The ``slo_guard``
policy arms on the budget's burn rate and flips the region to the
compressed+reliable WAN stack; a shadow raw-threshold controller runs on the
SAME telemetry in the same run, and the scenario asserts the guard fired
STRICTLY EARLIER. Both rules watch one monotonically-adapting EwmaQuantile
p95 estimate, so the ordering is structural (the estimate crosses the low
SLO bound before the high raw bound), not a race. The breach also trips the
flight recorder (``flightrec_slo_breach_*.json``).

``run_trace_calibration`` — two annotated chunnels whose ANNOTATIONS invert
their MEASURED costs: the trace records say which is actually slower, and
``calibrate_from_traces`` flips the scored-negotiation ranking. Asserts the
measured ``op_latency_s`` lands within 2x of an independent direct timing of
the same transform (acceptance criterion).

Artifact: benchmarks/out/slo_scenario.json (CI uploads it).
"""
from __future__ import annotations

import json
import os
import pathlib
import statistics
import time

from repro.core import (
    Fabric,
    FabricTransport,
    FnChunnel,
    KVStore,
    LinkModel,
    LockedConn,
    ReconfigController,
    Rule,
    Select,
    above,
    conn_controller,
    make_stack,
)
from repro.core.cost import (
    LATENCY_FIRST,
    Candidate,
    CostModel,
    chunnel_cost,
    rank,
)
from repro.obs.calibrate import calibrate_from_traces
from repro.obs.federate import MetricsFederator, MetricsPublisher
from repro.obs.metrics import MetricsRegistry
from repro.obs.slo import SLO, SLOEngine

SLO_OUT = pathlib.Path(__file__).resolve().parent / "out" / "slo_scenario.json"

#: the SLO's latency bound (intent: "requests feel fast") vs the raw
#: emergency threshold a hand-written rule would use — the gap between them
#: is exactly the earlier-detection margin the burn-rate policy buys. The
#: clean-path echo RTT is ~1.5-2 ms (gateway poll at 1 ms), the degraded
#: path climbs through 4-15+ ms, so the p95 estimate crosses the SLO bound
#: ticks before the emergency bound.
SLO_P95_S = 0.0035
HARD_P95_S = 0.012


def run_slo_guard_scenario(*, fast: bool = False) -> dict:
    from repro.chaos import ChaosInjector, ChaosPlan
    from repro.comm.chunnels import WanLinkChunnel
    from repro.obs.flight import RECORDER
    from repro.serving.gateway import WanGateway

    fabric = Fabric(default_link=LinkModel(latency_s=0.0002), seed=13)
    # 1 ms serve poll keeps the CLEAN echo RTT well under the SLO bound —
    # the healthy phase must not burn budget
    gw = WanGateway(fabric, "hub", poll_s=0.001)
    store = KVStore()

    ep_fast = fabric.register("edge/fastlink")
    ep_wan = fabric.register("edge/wanlink")
    stack = make_stack(Select(
        FabricTransport(ep_fast, "hub/fast", label="FastWire"),
        WanLinkChunnel(ep_wan, "hub/wan", mtu_bytes=2048, window=8,
                       timeout_s=0.03, retries=8),
    ))
    handle = LockedConn(stack.preferred())  # FastWire

    # -- observability plane: registry -> KV publish -> federation ----------
    registry = MetricsRegistry()
    # non-destructive sampling: the controller owns the telemetry's rate
    # window; publishing must peek, not reset (see MetricsPublisher docs)
    registry.register("conn",
                      lambda: handle.telemetry.snapshot(reset_window=False),
                      instance="edge-conn")
    pub = MetricsPublisher(store, "slo-fleet", "edge-1", registry,
                           region="edge")
    # a second, healthy member in another region: the federation must keep
    # regions apart — core's clean p95 must not dilute edge's breach
    core_reg = MetricsRegistry()
    core_reg.register("conn", lambda: {"ops_per_s": 40.0,
                                       "rtt_p95_s": 0.0004,
                                       "rtt_p50_s": 0.0002}, "core-conn")
    core_pub = MetricsPublisher(store, "slo-fleet", "core-1", core_reg,
                                region="core")
    fed = MetricsFederator(store, "slo-fleet", ttl_s=5.0)

    # -- SLO engine: windows sized to the scenario's wall clock -------------
    engine = SLOEngine(
        [SLO("region_latency", "obs.region.edge.conn.rtt_p95_s",
             objective=0.95, threshold=SLO_P95_S)],
        fast_window_s=0.15, slow_window_s=0.6, budget_window_s=60.0,
        recorder=RECORDER)

    ctl = conn_controller(
        handle, stack, policy="slo_guard",
        policy_params={"slo": "region_latency",
                       "safe_names": ("WanLink",), "hold": 1},
        cooldown_s=0.0)

    # shadow raw-threshold controller over the SAME telemetry snapshots: the
    # baseline the guard must beat (recording switch fn; never moves data).
    # hold=2 is the repo's standard hysteresis for single-metric threshold
    # rules (latency_slo / wan_region_adaptive defaults) — a raw threshold
    # NEEDS it against one noisy sample; the guard's burn windows already
    # smooth, which is why slo_guard defaults hold=1
    raw_fired: list = []
    raw_ctl = ReconfigController(
        rules=[Rule("raw-threshold", above("rtt_p95_s", HARD_P95_S),
                    "WanLink", hold=2)],
        switch=lambda t: raw_fired.append(t),
        current=lambda: "FastWire", cooldown_s=0.0)

    weather = LinkModel(latency_s=0.004, jitter_s=0.002, loss=0.2)
    plan = ChaosPlan(seed=13)
    plan.degrade("edge", "hub", weather, at=0.0, label="edge-weather")
    inj = ChaosInjector(fabric, plan).start()

    def on_wan() -> bool:
        return any(c.name == "WanLink" for c in handle.stack.chunnels)

    rid = [0]

    def probe(timeout: float = 0.04) -> None:
        rid[0] += 1
        t0 = time.monotonic()
        if on_wan():
            try:
                handle.send([{"rid": rid[0]}])
                handle.telemetry.record_rtt(time.monotonic() - t0)
            except TimeoutError:
                handle.telemetry.record_rtt(timeout)
            return
        handle.send([{"rid": rid[0]}])
        buf = [None]
        deadline = t0 + timeout
        while True:
            t = deadline - time.monotonic()
            if t <= 0 or not handle.recv(buf, timeout=max(t, 0.0)):
                handle.telemetry.record_rtt(timeout)  # timeouts drag p95 up
                return
            m = buf[0]
            if isinstance(m, dict) and m.get("rid") == rid[0]:
                handle.telemetry.record_rtt(time.monotonic() - t0)
                return

    max_ticks = 30 if fast else 45
    probes_per_tick = 4
    guard_tick = raw_tick = None
    clean_ticks = 3      # pre-weather baseline so the budget starts intact
    timeline = []
    budget_series = []
    try:
        for tick in range(max_ticks):
            if tick >= clean_ticks:
                inj.poll()  # weather applies after the clean phase
            for _ in range(probes_per_tick):
                probe()
                time.sleep(0.002)
            pub.publish()
            core_pub.publish()
            view = fed.view()
            sigs = engine.observe(view)
            snap = handle.telemetry.snapshot()   # the ONE reset consumer
            snap.update(sigs)
            d = ctl.tick(snap)
            rd = raw_ctl.tick(dict(snap))
            if guard_tick is None and d.reason == "switched":
                guard_tick = tick
            if raw_tick is None and rd.fired:
                raw_tick = tick
            timeline.append({
                "tick": tick,
                "p95_ms": round((snap.get("rtt_p95_s") or 0.0) * 1e3, 3),
                "burn_fast": round(sigs["slo.region_latency.burn_fast"], 2),
                "burn_slow": round(sigs["slo.region_latency.burn_slow"], 2),
                "alarm": sigs["slo.region_latency.alarm"],
                "guard": d.reason, "raw_fired": bool(rd.fired),
            })
            budget_series.append(
                sigs["slo.region_latency.budget_remaining"])
            if (guard_tick is not None and raw_tick is not None
                    and tick >= raw_tick + 2):
                break
    finally:
        inj.stop()
        gw_stats = gw.stats()
        gw.close()
        pub.retire()
        core_pub.retire()

    final_view = view
    return {
        "scenario": "slo-guard-vs-raw-threshold",
        "slo_threshold_s": SLO_P95_S, "hard_threshold_s": HARD_P95_S,
        "guard": {
            "switch_tick": guard_tick,
            "switches": [d.to_json() for d in ctl.switch_log()],
            "chunnels": [c.name for c in handle.stack.chunnels],
            "capabilities": sorted(str(c) for ch in handle.stack.chunnels
                                   for c in ch.capabilities()),
            "counts": ctl.counts(),
        },
        "raw": {"fired_tick": raw_tick, "counts": raw_ctl.counts()},
        "slo": {"events": engine.events, "report": engine.report(),
                "budget_remaining_series": budget_series},
        "federation": {
            "members": final_view.get("obs.members"),
            "edge_p95_s": final_view.get(
                "obs.region.edge.conn.rtt_p95_s"),
            "core_p95_s": final_view.get(
                "obs.region.core.conn.rtt_p95_s"),
            "publish_conflicts": pub.conflicts + core_pub.conflicts,
        },
        "flightrec": os.path.join(
            RECORDER.out_dir, "flightrec_slo_breach_region_latency.json"),
        "timeline": timeline,
        "gateway": gw_stats,
        "weather": {"latency_s": weather.latency_s,
                    "jitter_s": weather.jitter_s, "loss": weather.loss},
    }


def run_trace_calibration() -> dict:
    """Annotations lie; traces measure; the ranking flips (acceptance)."""
    from repro.comm.chunnels import reset_cost_calibration
    from repro.obs.trace import TRACER

    # annotations INVERTED vs the real transforms: "Quick" claims 0.1ms but
    # sleeps ~2ms per batch; "Steady" claims 5ms but sleeps ~0.3ms
    def slow_xf(msgs):
        time.sleep(0.002)
        return msgs

    def quick_xf(msgs):
        time.sleep(0.0003)
        return msgs

    quick = FnChunnel("Quick", on_send_batch=slow_xf,
                      cost=CostModel(op_latency_s=1e-4))
    steady = FnChunnel("Steady", on_send_batch=quick_xf,
                       cost=CostModel(op_latency_s=5e-3))

    def candidates():
        return [Candidate("quick-stack", chunnel_cost(quick), "Quick"),
                Candidate("steady-stack", chunnel_cost(steady), "Steady")]

    def order():
        return [c.label for _u, c in rank(candidates(), LATENCY_FIRST)]

    reset_cost_calibration()
    nominal = order()

    # independent direct timing of the same transforms (median of N) — what
    # the trace-derived estimate must land within 2x of
    def direct(fn, n=7):
        durs = []
        for _ in range(n):
            t0 = time.perf_counter()
            fn([b"x" * 128] * 8)
            durs.append(time.perf_counter() - t0)
        return statistics.median(durs)

    bench = {"Quick": direct(slow_xf), "Steady": direct(quick_xf)}

    was = TRACER.enabled
    TRACER.enable()
    try:
        for ch in (quick, steady):
            dp = ch.connect_wrap(None)
            for _ in range(9):
                dp.send([b"x" * 128] * 8)
        records = TRACER.collect(clear=True)
    finally:
        if not was:
            TRACER.disable()

    cal = calibrate_from_traces(records, min_samples=3, apply=True)
    measured = order()

    out = {
        "nominal_order": nominal, "measured_order": measured,
        "rank_changed": nominal != measured,
        "calibration": {n: f for n, f in cal.chunnels.items()},
        "samples": cal.samples,
        "bench_direct_s": bench,
        "within_2x": {
            n: (cal.chunnels[n]["op_latency_s"] / bench[n]
                if bench.get(n) else None)
            for n in cal.chunnels if n in bench},
    }
    reset_cost_calibration()   # never leak measured costs into other benches
    return out


def _assert_slo_acceptance(res: dict) -> None:
    gs = res["guard_scenario"]
    g = gs["guard"]
    # the guard fired, on the burn rule, and landed on compressed+reliable
    assert g["switch_tick"] is not None, g
    assert g["switches"], g
    assert g["switches"][0]["rule"] == "slo_guard:region_latency:burn", g
    assert "WanLink" in g["chunnels"], g
    assert any("wan-gbn" in c for c in g["capabilities"]), g
    assert any("q8b" in c for c in g["capabilities"]), g
    # the raw-threshold baseline fired too — but strictly LATER
    raw_tick = gs["raw"]["fired_tick"]
    assert raw_tick is not None, gs["raw"]
    assert g["switch_tick"] < raw_tick, (g["switch_tick"], raw_tick)
    # breach is a first-class event; the budget visibly burned down
    kinds = [e["kind"] for e in gs["slo"]["events"]]
    assert "breach" in kinds, gs["slo"]["events"]
    series = gs["slo"]["budget_remaining_series"]
    assert series and series[-1] < 1.0, series[-5:]
    # the breach tripped the flight recorder
    assert os.path.exists(gs["flightrec"]), gs["flightrec"]
    # federation really carried two members and kept regions apart
    f = gs["federation"]
    assert f["members"] == 2, f
    assert f["edge_p95_s"] > f["core_p95_s"], f

    c = res["calibration"]
    assert c["rank_changed"], c
    assert c["nominal_order"] == ["Quick", "Steady"], c
    assert c["measured_order"] == ["Steady", "Quick"], c
    for name, ratio in c["within_2x"].items():
        assert ratio is not None and 0.5 <= ratio <= 2.0, (name, ratio, c)


def emit_slo_scenario(*, fast: bool = False) -> dict:
    """Run both scenario halves, write the JSON artifact, assert the
    acceptance shape. Shared by main() and run.py --smoke."""
    from repro.obs.flight import RECORDER
    from repro.obs.trace import TRACER

    was_enabled = TRACER.enabled
    TRACER.enable()   # SLO breaches must reach the flight recorder
    try:
        with RECORDER.capture("slo_smoke"):
            res = {"guard_scenario": run_slo_guard_scenario(fast=fast),
                   "calibration": run_trace_calibration()}
            SLO_OUT.parent.mkdir(parents=True, exist_ok=True)
            SLO_OUT.write_text(json.dumps(res, indent=2, default=float))
            _assert_slo_acceptance(res)
    finally:
        if not was_enabled:
            TRACER.disable()
    return res


def main() -> None:
    res = emit_slo_scenario()
    g = res["guard_scenario"]["guard"]
    print(f"slo_guard switch tick {g['switch_tick']} vs raw "
          f"{res['guard_scenario']['raw']['fired_tick']}; "
          f"artifact: {SLO_OUT}")


if __name__ == "__main__":
    main()
