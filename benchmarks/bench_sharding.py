"""Paper Fig. 6 analogue: client-side vs server-side sharding for a KV store.

Client-side hash routing sends directly to the owning backend; server-side
adds a router hop (+ queueing at load). We sweep offered load and report
p50/p95 latency + the max load meeting a latency SLO, then demonstrate the
negotiated reconfiguration between the two mid-run.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, pct
from repro.core import Fabric, LinkModel, LockedConn, Select, make_stack
from repro.serving.router import (
    AddressedTransport,
    ClientShardChunnel,
    KVBackend,
    KVClient,
    Router,
    ServerRouterChunnel,
    shard_of,
)

N_BACKENDS = 4
SLO_MS = 8.0
N_CLIENTS = 4


def setup(fabric):
    backends = [KVBackend(fabric, f"kv{i}", service_time_s=0.0004)
                for i in range(N_BACKENDS)]
    router = Router(fabric, "router", [b.addr for b in backends])
    return backends, router


def run_mode(mode: str, rate_per_s: float, n_req: int = 200) -> list:
    import threading

    fabric = Fabric(default_link=LinkModel(latency_s=0.0008))
    backends, router = setup(fabric)
    lats = []
    lock = threading.Lock()

    def one_client(cid: int):
        ep = fabric.register(f"cli{cid}")
        if mode == "client":
            ch = ClientShardChunnel(backends=tuple(b.addr for b in backends))
        else:
            ch = ServerRouterChunnel(router_addr="router")
        stack = make_stack(ch, AddressedTransport(ep))
        client = KVClient(fabric, ep, LockedConn(stack.preferred()))
        per = n_req // N_CLIENTS
        gap = N_CLIENTS / rate_per_s
        nxt = time.monotonic()
        for i in range(per):
            nxt += gap
            dt = nxt - time.monotonic()
            if dt > 0:
                time.sleep(dt)
            try:
                _, lat = client.request("put" if i % 3 == 0 else "get",
                                        f"key{(cid * 131 + i) % 37}", val=i,
                                        timeout=3.0)
            except TimeoutError:
                lat = 3.0
            with lock:
                lats.append(lat)

    threads = [threading.Thread(target=one_client, args=(c,)) for c in range(N_CLIENTS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    for b in backends:
        b.close()
    router.close()
    return lats


def main() -> None:
    max_ok = {"client": 0, "server": 0}
    for mode in ("client", "server"):
        for rate in (100, 300, 600):
            lats = run_mode(mode, rate)
            p95 = pct(lats, 95)
            if p95 * 1e3 <= SLO_MS:
                max_ok[mode] = rate
            emit(f"shard_{mode}_{rate}rps_p50", pct(lats, 50) * 1e6,
                 f"p95={p95*1e6:.0f}us")
    ratio = max_ok["client"] / max(max_ok["server"], 1)
    emit("shard_slo_load_ratio", 0.0,
         f"client={max_ok['client']}rps;server={max_ok['server']}rps;x{ratio:.1f}")


if __name__ == "__main__":
    main()
