"""Paper Fig. 4 analogue: ETL pipeline end-to-end processing latency with the
Kafka vs managed pub/sub Select, across offered loads.

producers -> ingesters -(pub/sub Select)-> parsers -> consumer summary.
Kafka: lower latency at high load but fixed hourly cost; managed pub/sub:
per-message cost, fine at low load. The crossover is why no single static
choice wins — Bertha's reconfiguration picks per deployment/workload (§7).
"""
from __future__ import annotations

import threading
import time

import numpy as np

from benchmarks.common import emit, pct
from repro.core import LockedConn, make_stack
from repro.serving.pubsub import GCP_PUBSUB, KAFKA, Broker, PubSubChunnel


def run_etl(broker: Broker, interarrival_s: float, n_batches: int = 40,
            batch: int = 16):
    # ingester (producer) and parser (consumer) hold separate handles
    stack = make_stack(PubSubChunnel(broker, "etl"))
    producer = LockedConn(stack.preferred())
    consumer = LockedConn(stack.preferred())
    done = []
    lock = threading.Lock()
    target = n_batches * batch

    def parser():
        buf = [None]
        misses = 0
        while len(done) < target and misses < 20:
            n = consumer.recv(buf, timeout=0.1)
            if not n:
                misses += 1
                continue
            misses = 0
            m = buf[0]
            # lightweight parse + summary update
            _ = sum(ord(c) for c in m["rec"][:32])
            with lock:
                done.append(time.monotonic() - m["t0"])

    t = threading.Thread(target=parser)
    t.start()
    rec = "x" * 150
    for b in range(n_batches):
        for i in range(batch):
            producer.send([{"rec": rec, "t0": time.monotonic()}])
        time.sleep(interarrival_s)
    t.join(timeout=15.0)
    return done or [float("nan")]


def main() -> None:
    for name, model in (("kafka", KAFKA), ("gcp_pubsub", GCP_PUBSUB)):
        for inter_ms in (20.0, 2.0, 0.5):
            broker = Broker(model)
            lats = run_etl(broker, inter_ms / 1e3)
            cost = broker.cost + model.fixed_cost_per_h * (40 * inter_ms / 3.6e6)
            emit(f"etl_{name}_inter{inter_ms}ms_p50", pct(lats, 50) * 1e6,
                 f"p95={pct(lats,95)*1e6:.0f}us;msgs={len(lats)};cost=${cost:.6f}")


if __name__ == "__main__":
    main()
