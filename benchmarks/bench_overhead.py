"""Paper Fig. 7/8 analogue: marginal cost of stacking no-op chunnels.

Bertha's claim: trace/compile-time composition (Rust monomorphization =>
jit trace-time here) makes the stack free at runtime. We verify three ways:
  (1) the compiled HLO with 0..5 no-op step chunnels is IDENTICAL,
  (2) steady-state step wall time is flat in stack depth,
  (3) the cost that DOES grow (trace time) is off the data path.
For contrast, an eager (non-jit) datapath pays per-op per-chunnel cost — the
paper's 0-27% regime lives there.
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.comm.chunnels import StepChunnel, apply_grad_stack
from repro.configs import get_smoke_config
from repro.configs.base import TrainConfig
from repro.models import build
from repro.optim import adamw


class NoopChunnel(StepChunnel):
    """Reads and forwards the tree (black-box add0 so it can't be elided
    before jit; XLA then proves it identity — that's the point)."""

    manual_axes = ()

    def init_state(self, _):
        return ()

    def apply(self, tree, state, ctx):
        return jax.tree.map(lambda g: g + 0.0, tree), state


def build_step(n_chunnels: int):
    cfg = get_smoke_config("llama3.2-1b")
    model = build(cfg)
    tcfg = TrainConfig()
    lr = adamw.lr_schedule(tcfg)
    chunnels = tuple(NoopChunnel() for _ in range(n_chunnels))

    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        grads, _ = apply_grad_stack(chunnels, grads, tuple(() for _ in chunnels),
                                    {"mesh": None})
        params, opt, _ = adamw.update(grads, opt, params, lr(opt.count), tcfg)
        return params, opt, loss

    return model, step


def run_tracing_overhead(batch: int = 64, *, iters: int = 1500, reps: int = 3,
                         smoke: bool = False) -> dict:
    """Gate the tracing runtime's cost on the batched fabric hot path.

    Two invariants (ISSUE acceptance):
      * tracing DISABLED must be within 3% of free — measured as the cost of
        the inline ``if TRACER.enabled:`` guards a batch round trip executes,
        relative to the round trip itself;
      * tracing ENABLED (batch-level record_batch, no per-message spans) must
        stay under 10% throughput overhead at batch=64.

    Noise discipline (timeit's): scheduler noise is strictly one-sided — it
    only ever ADDS time — so the minimum over many samples is the estimator
    that converges to the true cost. Disabled/enabled passes run interleaved
    (a load drift between two separate measurement phases would otherwise
    bias whichever mode ran second) and each mode's min is taken across all
    its samples; the gate compares min to min. The guard loop is measured
    best-of too. Returns the measurements; raises AssertionError on breach.
    """
    from repro.core.fabric import Fabric, LinkModel
    from repro.obs.trace import TRACER

    if smoke:
        iters = 800  # long enough per pass that one descheduling event
        # cannot dominate a pair's ratio
    payload = [b"x" * 64] * batch

    def one_pass() -> float:
        fab = Fabric(default_link=LinkModel(), seed=0)
        a = fab.register("ovt-a")
        b = fab.register("ovt-b")
        buf = [None] * batch
        t0 = time.perf_counter()
        for _ in range(iters):
            a.send_batch("ovt-b", payload)
            got = 0
            while got < batch:
                n = b.recv_many(buf, timeout=0.1)
                if not n:
                    break
                got += n
        return (time.perf_counter() - t0) / iters

    was_enabled = TRACER.enabled
    disabled = enabled = float("inf")
    try:
        TRACER.disable()
        one_pass()  # warmup: prime allocator + branch caches
        # Noise is one-sided, so the running min only improves with more
        # samples — when a gate would fail, settle the machine (collect the
        # garbage the prior benchmarks in this process left behind, yield the
        # scheduler) and fold in another round before concluding the cost is
        # real. A genuine regression survives every retry; a polluted run
        # (e.g. right after the dataplane sweep in --smoke) does not.
        for attempt in range(3):
            if attempt:
                import gc
                gc.collect()
                time.sleep(0.2)
            for _ in range(max(reps, 9)):
                TRACER.disable()
                disabled = min(disabled, one_pass())
                TRACER.enable()
                enabled = min(enabled, one_pass())
            if enabled / disabled - 1.0 < 0.10:
                break
    finally:
        if not was_enabled:
            TRACER.disable()
        else:
            TRACER.enable()

    # disabled-path cost: the guard is a single attribute read; a batch round
    # trip crosses a handful of instrumentation points, so charge 8 guards
    # per batch against the measured batch time. Best-of, minus an empty-loop
    # baseline so the measurement scaffolding (range iteration) is not billed
    # to the guard itself.
    n_checks = 50_000
    guard_s = base_s = float("inf")
    for _ in range(5):
        t0 = time.perf_counter()
        for _ in range(n_checks):
            if TRACER.enabled:
                pass  # pragma: no cover - guard cost measurement only
        guard_s = min(guard_s, (time.perf_counter() - t0) / n_checks)
        t0 = time.perf_counter()
        for _ in range(n_checks):
            pass
        base_s = min(base_s, (time.perf_counter() - t0) / n_checks)
    disabled_frac = 8 * max(0.0, guard_s - base_s) / disabled
    enabled_frac = max(0.0, enabled / disabled - 1.0)

    emit(f"overhead_tracing_b{batch}", disabled * 1e6,
         f"enabled_us={enabled * 1e6:.2f};enabled_overhead={enabled_frac:.3f};"
         f"disabled_guard_frac={disabled_frac:.5f}")
    assert disabled_frac < 0.03, (
        f"disabled tracing guards cost {disabled_frac:.1%} of a batch "
        f"round trip (gate: <3%)")
    assert enabled_frac < 0.10, (
        f"enabled tracing costs {enabled_frac:.1%} throughput at "
        f"batch={batch} (gate: <10%)")
    out = {"batch": batch, "disabled_s": disabled, "enabled_s": enabled,
           "enabled_overhead": enabled_frac,
           "disabled_guard_frac": disabled_frac}
    # CI artifact: benchmarks/check_regression.py compares this against the
    # committed baseline.json
    out_path = pathlib.Path(__file__).resolve().parent / "out" / "overhead.json"
    out_path.parent.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(out, indent=2))
    return out


def main() -> None:
    cfg = get_smoke_config("llama3.2-1b")
    rng = jax.random.PRNGKey(0)
    model, _ = build_step(0)
    params = model.init(rng)
    opt = adamw.init(params)
    batch = {
        "tokens": jax.random.randint(rng, (4, 64), 0, cfg.vocab_size),
        "labels": jax.random.randint(rng, (4, 64), 0, cfg.vocab_size),
    }

    hlo0 = None
    for n in (0, 1, 2, 5):
        _, step = build_step(n)
        t0 = time.perf_counter()
        jitted = jax.jit(step)
        lowered = jitted.lower(params, opt, batch)
        trace_ms = (time.perf_counter() - t0) * 1e3
        compiled = lowered.compile()
        hlo = compiled.as_text()
        if n == 0:
            hlo0 = hlo
        identical = "hlo_identical=%s" % (hlo == hlo0)

        p, o = params, opt
        def run(p=p, o=o):
            out = jitted(p, o, batch)
            jax.block_until_ready(out[2])

        dt = timeit(run, warmup=2, iters=10)
        emit(f"overhead_jit_{n}chunnels", dt * 1e6,
             f"{identical};trace_ms={trace_ms:.0f}")

    # eager contrast: per-call chunnel cost is real without trace-time fusion
    tree = {"g": jnp.ones((256, 256))}
    for n in (0, 1, 5):
        chs = tuple(NoopChunnel() for _ in range(n))

        def eager(chs=chs):
            t, _ = apply_grad_stack(chs, tree, tuple(() for _ in chs), {"mesh": None})
            jax.block_until_ready(t["g"])

        dt = timeit(eager, warmup=3, iters=50)
        emit(f"overhead_eager_{n}chunnels", dt * 1e6, "")

    # host-fabric split accounting: sent vs delivered vs dropped counters
    # (lossy + unroutable traffic no longer inflates "sent == delivered")
    from repro.core.fabric import Fabric, LinkModel

    fab = Fabric(default_link=LinkModel(loss=0.1), seed=0)
    a = fab.register("ovh-a")
    fab.register("ovh-b")
    a.send_batch("ovh-b", [b"x" * 64] * 1000)
    a.send_batch("nowhere", [b"y" * 64] * 10)
    c = fab.counters.snapshot()
    emit("overhead_fabric_counters", 0.0,
         f"sent={c['sent']};delivered={c['delivered']};"
         f"dropped_loss={c['dropped_loss']};"
         f"dropped_unroutable={c['dropped_unroutable']}")

    # tracing runtime cost gates (<3% disabled / <10% enabled at batch=64)
    run_tracing_overhead(batch=64)


if __name__ == "__main__":
    main()
