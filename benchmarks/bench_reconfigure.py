"""Paper Fig. 10 analogue: lock vs lock-free (barrier) reconfiguration —
plus the closed loop: controller-INITIATED switches in both planes.

Measures (a) steady-state per-op latency of each mechanism under multi-thread
load (the lock's fast-path tax) and (b) the reconfiguration blip (switch
duration) for each, swapping between two datapath implementations mid-run.

The controller scenarios go beyond the hand-triggered Fig. 10 swap: a
ReconfigController observes live telemetry and initiates the switch itself —

  kv       the §7.3 serving plane: offered load ramps up and the controller
           moves the routing Select from ServerRouter to ClientShard (and
           back when load drains) — the paper's Fig. 6 scenario end-to-end,
  trainer  the training plane: a straggling pod's heartbeat step times arm
           the straggler rule and the controller commits a negotiated
           transition xla -> localsgd mid-run (recovery rule switches back
           once the straggler heals).

Both scenarios record telemetry before/after each switch and the switch blip
in benchmarks/out/controller_scenarios.json.

run_scored_negotiation compares the multi-objective scorer against the
historical first-compatible rule over one offer under different live
workloads (chatty vs bulk), emitting benchmarks/out/scored_negotiation.json —
the cost-model-drives-the-choice claim (Morpheus, PAPERS.md) end-to-end.
"""
from __future__ import annotations

import json
import os
import pathlib
import sys
import threading
import time

from benchmarks.common import emit, pct
from repro.core import (
    BYTES_FIRST,
    BarrierConn,
    CapabilitySet,
    CostModel,
    Fabric,
    FabricTransport,
    FnChunnel,
    LATENCY_FIRST,
    LinkModel,
    LockedConn,
    Select,
    conn_controller,
    make_stack,
    pick_compatible,
    score_stack,
)
from repro.serving.router import KVBackend, KVClient, Router, routing_stack

JSON_OUT = pathlib.Path(__file__).parent / "out" / "controller_scenarios.json"
SCORED_OUT = pathlib.Path(__file__).parent / "out" / "scored_negotiation.json"


def _stack(fabric, tag):
    ep = fabric.register(f"bench-{tag}-{time.monotonic_ns()}")
    return make_stack(FnChunnel(fn_name=f"Impl{tag}", on_send=lambda m: m),
                      FabricTransport(ep, "sink"))


def run_mechanism(mechanism: str, n_threads: int = 3, duration_s: float = 1.2,
                  reconfigure_at: float = 0.5):
    fabric = Fabric()
    st_a, st_b = _stack(fabric, "A"), _stack(fabric, "B")
    handle = (LockedConn(st_a.preferred()) if mechanism == "lock"
              else BarrierConn(st_a.preferred(), n_threads=n_threads))
    lat: list = []
    stop = threading.Event()

    def pump():
        while not stop.is_set():
            t0 = time.perf_counter()
            handle.send([b"x"])
            lat.append(time.perf_counter() - t0)

    threads = [threading.Thread(target=pump) for _ in range(n_threads)]
    for t in threads:
        t.start()
    time.sleep(reconfigure_at)
    t0 = time.perf_counter()
    ok = handle.reconfigure(st_b.preferred())
    switch_s = time.perf_counter() - t0
    time.sleep(duration_s - reconfigure_at)
    stop.set()
    for t in threads:
        t.join()
    assert ok and handle.stats.switches == 1
    return lat, switch_s


# ---------------------------------------------------------------------------
# Scored vs first-compatible negotiation (multi-objective pick_compatible)
# ---------------------------------------------------------------------------


def run_scored_negotiation() -> dict:
    """One server offer, three capability-compatible implementations with
    different cost profiles; negotiate under two live workloads:

      chatty   high op rate, few bytes  -> latency term dominates
      bulk     few ops, high byte rate  -> DCN-byte term dominates

    First-compatible always returns the server-preferred Legacy option; the
    scorer picks FastPath for the chatty workload and ZipWire for bulk."""
    caps = CapabilitySet.exact("wire:obj")

    def impl(name, lat_s, byte_ratio):
        return FnChunnel(fn_name=name, caps=caps,
                         cost=CostModel(op_latency_s=lat_s,
                                        dcn_bytes_per_byte=byte_ratio))

    legacy = impl("Legacy", 5e-3, 1.0)     # server-preferred, good at nothing
    zipw = impl("ZipWire", 3e-3, 0.25)     # compresses the wire
    fast = impl("FastPath", 4e-4, 1.0)     # lowest per-op latency
    server = make_stack(Select(legacy, zipw, fast))
    client = make_stack(Select(legacy, zipw, fast))
    offer = client.offer()

    workloads = {
        "chatty": ({"ops_per_s": 2000.0, "bytes_per_s": 5e4}, LATENCY_FIRST),
        "bulk": ({"ops_per_s": 5.0, "bytes_per_s": 5e7}, BYTES_FIRST),
    }
    out = {}
    for label, (snap, objective) in workloads.items():
        first_opt, _ = pick_compatible(server, offer, mode="first")
        scored_opt, _ = pick_compatible(server, offer, snapshot=snap,
                                        objective=objective)
        out[label] = {
            "snapshot": snap,
            "objective": objective.name,
            "first_compatible": first_opt.chunnels[0].name,
            "scored": scored_opt.chunnels[0].name,
            "utilities": {
                opt.chunnels[0].name: score_stack(opt, objective, snap)
                for opt in server.options()
            },
        }
    return out


def emit_scored_negotiation() -> dict:
    """Run the scored-vs-first comparison, write the JSON artifact, and check
    the expected winners (shared by main() and run.py --smoke)."""
    scored = run_scored_negotiation()
    SCORED_OUT.parent.mkdir(parents=True, exist_ok=True)
    SCORED_OUT.write_text(json.dumps(scored, indent=2, default=float))
    assert all(r["first_compatible"] == "Legacy" for r in scored.values()), scored
    assert scored["chatty"]["scored"] == "FastPath", scored["chatty"]
    assert scored["bulk"]["scored"] == "ZipWire", scored["bulk"]
    return scored


def run_controller_kv(*, fast: bool = False) -> dict:
    """Offered load ramps low -> high -> low; the controller (not the bench)
    initiates the ServerRouter -> ClientShard switch at load and the switch
    back once load drains.

    The low phases issue closed-loop (blocking) requests; the high phase
    offers load open-loop — paced fire-and-forget sends through the routing
    stack with periodic blocking probes for round-trip telemetry — so the
    measured ops_per_s tracks the *offered* rate (sleep-paced, hence robust
    to slow CI machines) rather than being capped at 1/rtt."""
    n_backends = 4
    # (label, offered_rps, n_req, open_loop)
    phases_spec = ([("low", 70.0, 40, False), ("high", 450.0, 250, True),
                    ("low", 55.0, 60, False)]
                   if fast else
                   [("low", 80.0, 80, False), ("high", 450.0, 500, True),
                    ("low", 60.0, 100, False)])
    tick_every = 10
    fabric = Fabric(default_link=LinkModel(latency_s=0.0008))
    backends = [KVBackend(fabric, f"ctlkv{i}", service_time_s=0.0004)
                for i in range(n_backends)]
    router = Router(fabric, "ctl-router", [b.addr for b in backends])
    ep = fabric.register("ctl-cli")
    stack = routing_stack(ep, [b.addr for b in backends],
                          router_addr="ctl-router", prefer="server")
    handle = LockedConn(stack.preferred())  # ServerRouter: the low-load default
    client = KVClient(fabric, ep, handle)
    # policy comes from the plugin registry (registered by the serving plane),
    # not a hand-assembled Rule list — the §7.3 point that applications ship
    # policies without editing the runtime
    policy = "kv_load_adaptive"
    ctl = conn_controller(
        handle, stack,
        policy=policy,
        policy_params={"high_ops_per_s": 150.0, "low_ops_per_s": 120.0, "hold": 2},
        cooldown_s=0.2,
    )

    drain = [None]

    def drain_replies():
        # AddressedTransport.recv returns after the first message when given
        # a timeout, so draining the fire-and-forget replies means looping
        # until the inbox is empty — otherwise stale rid=-1 replies pile up
        # and skew the next closed-loop phase's measured latency.
        while handle.recv(drain, timeout=0.001):
            pass

    phases = []
    try:
        for label, rate, n_req, open_loop in phases_spec:
            gap = 1.0 / rate
            nxt = time.monotonic()
            for i in range(n_req):
                nxt += gap
                dt = nxt - time.monotonic()
                if dt > 0:
                    time.sleep(dt)
                try:
                    if open_loop and i % 25 != 0:
                        handle.send([{"op": "get", "key": f"k{i % 37}",
                                      "rid": -1, "reply_to": ep.addr}])
                        if i % 10 == 0:
                            drain_replies()
                    else:
                        client.request("put" if i % 3 == 0 else "get",
                                       f"k{i % 37}", val=i, timeout=1.0)
                except TimeoutError:
                    pass
                if (i + 1) % tick_every == 0:
                    ctl.tick(handle.telemetry.snapshot())
            if open_loop:
                drain_replies()  # leave no stale replies for the next phase
            phases.append({
                "phase": label, "offered_rps": rate, "n_req": n_req,
                "stack_after": repr(handle.stack),
                "telemetry_after": (ctl.decisions[-1].snapshot
                                    if ctl.decisions else {}),
            })
    finally:
        for b in backends:
            b.close()
        router.close()

    return {
        "plane": "kv",
        "policy": policy,
        "phases": phases,
        "switches": [d.to_json() for d in ctl.switch_log()],
        "decisions": [d.to_json() for d in ctl.decisions],
        "blip_s": handle.stats.last_switch_s,
        "total_switches": handle.stats.switches,
        "final_stack": repr(handle.stack),
    }


# ---------------------------------------------------------------------------
# Controller-driven trainer scenario (straggler mitigation, closed loop)
# ---------------------------------------------------------------------------


def run_controller_trainer(num_steps: int = 18) -> dict:
    """host1's heartbeat reports a persistent straggler; the trainer's
    controller commits a negotiated xla -> localsgd transition mid-run and
    (once the straggler heals) the recovery rule arms the way back."""
    os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
    import jax
    from repro import compat
    from repro.configs import get_smoke_config
    from repro.configs.base import ShapeConfig, TrainConfig
    from repro.data.synthetic import batches_for
    from repro.launch.mesh import make_test_mesh
    from repro.train.trainer import HostSpec, ReconfigurableTrainer

    n_dev = jax.device_count()
    mesh_shape = (2, 4) if n_dev >= 8 else ((2, 1) if n_dev >= 2 else (1, 1))
    mesh = make_test_mesh(mesh_shape, ("pod", "model"))
    cfg = get_smoke_config("llama3.2-1b")
    shape = ShapeConfig("ctl", 64, 4, "train")
    offers = ["xla", "localsgd", "compressed_int8"]

    def pod_times(step_idx, dt):
        # heartbeat plane: host1 runs 3x slow between steps 4 and 10
        slow = 3.0 if 4 <= step_idx <= 10 else 1.0
        return {"host0": dt, "host1": dt * slow}

    # use_mesh (scoped), so the ambient mesh doesn't leak into later bench
    # modules when this runs inside the full run.py sweep
    with compat.use_mesh(mesh):
        tr = ReconfigurableTrainer(
            cfg, shape, mesh,
            tcfg=TrainConfig(learning_rate=1e-3, warmup_steps=2, total_steps=64),
            transport="xla",
            hosts=[HostSpec(0, list(offers)), HostSpec(1, list(offers))],
        )
        ctl = tr.make_controller(straggler_threshold=1.3, recover_threshold=1.2,
                                 hold=2, recover_hold=2, cooldown_s=0.0)
        state = tr.init_state(jax.random.PRNGKey(0))
        gen = batches_for(cfg, shape)
        state, hist = tr.run(state, gen, num_steps, controller=ctl,
                             pod_times=pod_times)
    switches = [d.to_json() for d in ctl.switch_log()]
    assert any(s["target"] == "localsgd" for s in switches), \
        f"controller never initiated the straggler mitigation: {switches}"
    return {
        "plane": "trainer",
        "num_steps": num_steps,
        "final_transport": tr.transport_name,
        "reconfig_log": tr.reconfig_log,
        "switches": switches,
        "decisions": [d.to_json() for d in ctl.decisions],
        "losses": [float(m["loss"]) for m in hist],
    }


def main() -> None:
    for mech in ("lock", "barrier"):
        lat, switch_s = run_mechanism(mech)
        emit(f"reconfig_{mech}_fastpath_p50", pct(lat, 50) * 1e6,
             f"p95={pct(lat, 95)*1e6:.2f}us;n={len(lat)}")
        emit(f"reconfig_{mech}_switch", switch_s * 1e6, "")

    scored = emit_scored_negotiation()
    for label, row in scored.items():
        emit(f"negotiate_scored_{label}", 0.0,
             f"first={row['first_compatible']};scored={row['scored']}")
    print(f"# scored negotiation JSON: {SCORED_OUT}", file=sys.stderr, flush=True)

    results = {"kv": run_controller_kv(), "trainer": run_controller_trainer()}
    JSON_OUT.parent.mkdir(parents=True, exist_ok=True)
    JSON_OUT.write_text(json.dumps(results, indent=2, default=float))
    kv, trainer = results["kv"], results["trainer"]
    assert kv["switches"], "controller never initiated a KV routing switch"
    emit("reconfig_ctl_kv_switches", kv["blip_s"] * 1e6,
         f"n={len(kv['switches'])};policy={kv['policy']};"
         f"final={kv['final_stack'].split(' ')[0]}")
    emit("reconfig_ctl_trainer_switches", 0.0,
         f"n={len(trainer['switches'])};final={trainer['final_transport']}")
    print(f"# controller scenario JSON: {JSON_OUT}", file=sys.stderr, flush=True)


if __name__ == "__main__":
    main()
