"""Paper Fig. 10 analogue: lock vs lock-free (barrier) reconfiguration —
plus the closed loop: controller-INITIATED switches in both planes.

Measures (a) steady-state per-op latency of each mechanism under multi-thread
load (the lock's fast-path tax) and (b) the reconfiguration blip (switch
duration) for each, swapping between two datapath implementations mid-run.

The controller scenarios go beyond the hand-triggered Fig. 10 swap: a
ReconfigController observes live telemetry and initiates the switch itself —

  kv       the §7.3 serving plane: offered load ramps up and the controller
           moves the routing Select from ServerRouter to ClientShard (and
           back when load drains) — the paper's Fig. 6 scenario end-to-end,
  trainer  the training plane: a straggling pod's heartbeat step times arm
           the straggler rule and the controller commits a negotiated
           transition xla -> localsgd mid-run (recovery rule switches back
           once the straggler heals).

Both scenarios record telemetry before/after each switch and the switch blip
in benchmarks/out/controller_scenarios.json.

run_scored_negotiation compares the multi-objective scorer against the
historical first-compatible rule over one offer under different live
workloads (chatty vs bulk), emitting benchmarks/out/scored_negotiation.json —
the cost-model-drives-the-choice claim (Morpheus, PAPERS.md) end-to-end.

run_fleet_kv is the FLEET-scope §7.3 scenario (repro.fleet): N simulated KV
clients publish telemetry into the rendezvous KV store, a FleetAggregator
folds it with an external SignalSource, and ONE fleet_controller switches
ServerRouter↔ClientShard for the whole fleet in a single rendezvous epoch
when the AGGREGATE offered load crosses the policy threshold — while every
individual client stays below the per-client threshold the old per-connection
policy would have needed (benchmarks/out/fleet_scenario.json).

run_controller_barrier extends the closed loop to the lock-free mechanism: a
multi-threaded BarrierConn data plane under a controller-INITIATED switch
(latency_slo policy), emitting the switch blip + stop-the-world blocked time
beside the LockedConn KV scenario.
"""
from __future__ import annotations

import json
import os
import pathlib
import sys
import threading
import time

from benchmarks.common import emit, pct
from repro.core import (
    BYTES_FIRST,
    BarrierConn,
    CapabilitySet,
    CostModel,
    Fabric,
    FabricTransport,
    FnChunnel,
    HostAgent,
    KVStore,
    LATENCY_FIRST,
    LinkModel,
    LockedConn,
    Select,
    conn_controller,
    make_stack,
    pick_compatible,
    score_stack,
)
from repro.fleet import (
    FleetAggregator,
    FleetMember,
    FleetPublisher,
    SpotPriceSignal,
    fleet_conn_id,
    fleet_controller,
)
from repro.serving.router import KVBackend, KVClient, Router, routing_stack

JSON_OUT = pathlib.Path(__file__).parent / "out" / "controller_scenarios.json"
SCORED_OUT = pathlib.Path(__file__).parent / "out" / "scored_negotiation.json"
FLEET_OUT = pathlib.Path(__file__).parent / "out" / "fleet_scenario.json"
CHAOS_OUT = pathlib.Path(__file__).parent / "out" / "chaos_scenarios.json"


def _stack(fabric, tag):
    ep = fabric.register(f"bench-{tag}-{time.monotonic_ns()}")
    return make_stack(FnChunnel(fn_name=f"Impl{tag}", on_send=lambda m: m),
                      FabricTransport(ep, "sink"))


def run_mechanism(mechanism: str, n_threads: int = 3, duration_s: float = 1.2,
                  reconfigure_at: float = 0.5):
    fabric = Fabric()
    st_a, st_b = _stack(fabric, "A"), _stack(fabric, "B")
    handle = (LockedConn(st_a.preferred()) if mechanism == "lock"
              else BarrierConn(st_a.preferred(), n_threads=n_threads))
    lat: list = []
    stop = threading.Event()

    def pump():
        while not stop.is_set():
            t0 = time.perf_counter()
            handle.send([b"x"])
            lat.append(time.perf_counter() - t0)

    threads = [threading.Thread(target=pump) for _ in range(n_threads)]
    for t in threads:
        t.start()
    time.sleep(reconfigure_at)
    t0 = time.perf_counter()
    ok = handle.reconfigure(st_b.preferred())
    switch_s = time.perf_counter() - t0
    time.sleep(duration_s - reconfigure_at)
    stop.set()
    for t in threads:
        t.join()
    assert ok and handle.stats.switches == 1
    return lat, switch_s


# ---------------------------------------------------------------------------
# Scored vs first-compatible negotiation (multi-objective pick_compatible)
# ---------------------------------------------------------------------------


def run_scored_negotiation() -> dict:
    """One server offer, three capability-compatible implementations with
    different cost profiles; negotiate under two live workloads:

      chatty   high op rate, few bytes  -> latency term dominates
      bulk     few ops, high byte rate  -> DCN-byte term dominates

    First-compatible always returns the server-preferred Legacy option; the
    scorer picks FastPath for the chatty workload and ZipWire for bulk."""
    caps = CapabilitySet.exact("wire:obj")

    def impl(name, lat_s, byte_ratio):
        return FnChunnel(fn_name=name, caps=caps,
                         cost=CostModel(op_latency_s=lat_s,
                                        dcn_bytes_per_byte=byte_ratio))

    legacy = impl("Legacy", 5e-3, 1.0)     # server-preferred, good at nothing
    zipw = impl("ZipWire", 3e-3, 0.25)     # compresses the wire
    fast = impl("FastPath", 4e-4, 1.0)     # lowest per-op latency
    server = make_stack(Select(legacy, zipw, fast))
    client = make_stack(Select(legacy, zipw, fast))
    offer = client.offer()

    workloads = {
        "chatty": ({"ops_per_s": 2000.0, "bytes_per_s": 5e4}, LATENCY_FIRST),
        "bulk": ({"ops_per_s": 5.0, "bytes_per_s": 5e7}, BYTES_FIRST),
    }
    out = {}
    for label, (snap, objective) in workloads.items():
        first_opt, _ = pick_compatible(server, offer, mode="first")
        scored_opt, _ = pick_compatible(server, offer, snapshot=snap,
                                        objective=objective)
        out[label] = {
            "snapshot": snap,
            "objective": objective.name,
            "first_compatible": first_opt.chunnels[0].name,
            "scored": scored_opt.chunnels[0].name,
            "utilities": {
                opt.chunnels[0].name: score_stack(opt, objective, snap)
                for opt in server.options()
            },
        }
    return out


def emit_scored_negotiation() -> dict:
    """Run the scored-vs-first comparison, write the JSON artifact, and check
    the expected winners (shared by main() and run.py --smoke)."""
    scored = run_scored_negotiation()
    SCORED_OUT.parent.mkdir(parents=True, exist_ok=True)
    SCORED_OUT.write_text(json.dumps(scored, indent=2, default=float))
    assert all(r["first_compatible"] == "Legacy" for r in scored.values()), scored
    assert scored["chatty"]["scored"] == "FastPath", scored["chatty"]
    assert scored["bulk"]["scored"] == "ZipWire", scored["bulk"]
    return scored


def run_controller_kv(*, fast: bool = False) -> dict:
    """Offered load ramps low -> high -> low; the controller (not the bench)
    initiates the ServerRouter -> ClientShard switch at load and the switch
    back once load drains.

    The low phases issue closed-loop (blocking) requests; the high phase
    offers load open-loop — paced fire-and-forget sends through the routing
    stack with periodic blocking probes for round-trip telemetry — so the
    measured ops_per_s tracks the *offered* rate (sleep-paced, hence robust
    to slow CI machines) rather than being capped at 1/rtt."""
    n_backends = 4
    # (label, offered_rps, n_req, open_loop)
    phases_spec = ([("low", 70.0, 40, False), ("high", 450.0, 250, True),
                    ("low", 55.0, 60, False)]
                   if fast else
                   [("low", 80.0, 80, False), ("high", 450.0, 500, True),
                    ("low", 60.0, 100, False)])
    tick_every = 10
    fabric = Fabric(default_link=LinkModel(latency_s=0.0008))
    backends = [KVBackend(fabric, f"ctlkv{i}", service_time_s=0.0004)
                for i in range(n_backends)]
    router = Router(fabric, "ctl-router", [b.addr for b in backends])
    ep = fabric.register("ctl-cli")
    stack = routing_stack(ep, [b.addr for b in backends],
                          router_addr="ctl-router", prefer="server")
    handle = LockedConn(stack.preferred())  # ServerRouter: the low-load default
    client = KVClient(fabric, ep, handle)
    # policy comes from the plugin registry (registered by the serving plane),
    # not a hand-assembled Rule list — the §7.3 point that applications ship
    # policies without editing the runtime
    policy = "kv_load_adaptive"
    ctl = conn_controller(
        handle, stack,
        policy=policy,
        policy_params={"high_ops_per_s": 150.0, "low_ops_per_s": 120.0, "hold": 2},
        cooldown_s=0.2,
    )

    drain = [None]

    def drain_replies():
        # AddressedTransport.recv returns after the first message when given
        # a timeout, so draining the fire-and-forget replies means looping
        # until the inbox is empty — otherwise stale rid=-1 replies pile up
        # and skew the next closed-loop phase's measured latency.
        while handle.recv(drain, timeout=0.001):
            pass

    phases = []
    try:
        for label, rate, n_req, open_loop in phases_spec:
            gap = 1.0 / rate
            nxt = time.monotonic()
            for i in range(n_req):
                nxt += gap
                dt = nxt - time.monotonic()
                if dt > 0:
                    time.sleep(dt)
                try:
                    if open_loop and i % 25 != 0:
                        handle.send([{"op": "get", "key": f"k{i % 37}",
                                      "rid": -1, "reply_to": ep.addr}])
                        if i % 10 == 0:
                            drain_replies()
                    else:
                        client.request("put" if i % 3 == 0 else "get",
                                       f"k{i % 37}", val=i, timeout=1.0)
                except TimeoutError:
                    pass
                if (i + 1) % tick_every == 0:
                    ctl.tick(handle.telemetry.snapshot())
            if open_loop:
                drain_replies()  # leave no stale replies for the next phase
            phases.append({
                "phase": label, "offered_rps": rate, "n_req": n_req,
                "stack_after": repr(handle.stack),
                "telemetry_after": (ctl.decisions[-1].snapshot
                                    if ctl.decisions else {}),
            })
    finally:
        for b in backends:
            b.close()
        router.close()

    return {
        "plane": "kv",
        "policy": policy,
        "phases": phases,
        "switches": [d.to_json() for d in ctl.switch_log()],
        "decisions": [d.to_json() for d in ctl.decisions],
        "blip_s": handle.stats.last_switch_s,
        "total_switches": handle.stats.switches,
        "final_stack": repr(handle.stack),
    }


# ---------------------------------------------------------------------------
# Controller-driven trainer scenario (straggler mitigation, closed loop)
# ---------------------------------------------------------------------------


def run_controller_trainer(num_steps: int = 18) -> dict:
    """host1's heartbeat reports a persistent straggler; the trainer's
    controller commits a negotiated xla -> localsgd transition mid-run and
    (once the straggler heals) the recovery rule arms the way back."""
    os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
    import jax
    from repro import compat
    from repro.configs import get_smoke_config
    from repro.configs.base import ShapeConfig, TrainConfig
    from repro.data.synthetic import batches_for
    from repro.launch.mesh import make_test_mesh
    from repro.train.trainer import HostSpec, ReconfigurableTrainer

    n_dev = jax.device_count()
    mesh_shape = (2, 4) if n_dev >= 8 else ((2, 1) if n_dev >= 2 else (1, 1))
    mesh = make_test_mesh(mesh_shape, ("pod", "model"))
    cfg = get_smoke_config("llama3.2-1b")
    shape = ShapeConfig("ctl", 64, 4, "train")
    offers = ["xla", "localsgd", "compressed_int8"]

    def pod_times(step_idx, dt):
        # heartbeat plane: host1 runs 3x slow between steps 4 and 10
        slow = 3.0 if 4 <= step_idx <= 10 else 1.0
        return {"host0": dt, "host1": dt * slow}

    # use_mesh (scoped), so the ambient mesh doesn't leak into later bench
    # modules when this runs inside the full run.py sweep
    with compat.use_mesh(mesh):
        tr = ReconfigurableTrainer(
            cfg, shape, mesh,
            tcfg=TrainConfig(learning_rate=1e-3, warmup_steps=2, total_steps=64),
            transport="xla",
            hosts=[HostSpec(0, list(offers)), HostSpec(1, list(offers))],
        )
        # fleet signal plane: publish this job's step telemetry so a fleet
        # aggregator can fold it with other jobs' (cross-job DCN budgets)
        tr.attach_fleet(fleet_id="trainfleet", period_s=0.0)
        agg = FleetAggregator(tr.store, "trainfleet", ttl_s=600.0)
        ctl = tr.make_controller(straggler_threshold=1.3, recover_threshold=1.2,
                                 hold=2, recover_hold=2, cooldown_s=0.0)
        state = tr.init_state(jax.random.PRNGKey(0))
        gen = batches_for(cfg, shape)
        state, hist = tr.run(state, gen, num_steps, controller=ctl,
                             pod_times=pod_times)
    switches = [d.to_json() for d in ctl.switch_log()]
    assert any(s["target"] == "localsgd" for s in switches), \
        f"controller never initiated the straggler mitigation: {switches}"
    fleet_view = agg.aggregate()
    assert fleet_view["fleet.members"] == 1, fleet_view
    return {
        "plane": "trainer",
        "num_steps": num_steps,
        "final_transport": tr.transport_name,
        "reconfig_log": tr.reconfig_log,
        "switches": switches,
        "decisions": [d.to_json() for d in ctl.decisions],
        "losses": [float(m["loss"]) for m in hist],
        "fleet_view": {k: v for k, v in fleet_view.items()
                       if not isinstance(v, dict)},
    }


# ---------------------------------------------------------------------------
# Fleet-scope §7.3 scenario (repro.fleet): aggregate-driven, one epoch
# ---------------------------------------------------------------------------


def run_fleet_kv(*, n_clients: int = 4, fast: bool = False) -> dict:
    """N KV clients, ONE decision: per-client offered load never crosses the
    threshold a per-client policy would need, but the fleet AGGREGATE does —
    the fleet controller commits ServerRouter -> ClientShard for everyone in
    a single rendezvous epoch, and back once the aggregate drains.

    Single-threaded driver: clients send open-loop (sleep-paced, so the
    measured rates track the offered rates on slow CI machines) with periodic
    blocking probes for RTT telemetry; each member's ``poll()`` heartbeats
    its publisher, votes on in-flight proposals, and applies committed
    epochs."""
    n_backends = 3
    fleet_high, fleet_low = 180.0, 110.0
    per_client_high = 150.0   # what the PER-CLIENT policy would have needed
    # (label, per-client rps, iterations)
    phases_spec = ([("low", 25.0, 16), ("high", 70.0, 36), ("low", 18.0, 26)]
                   if fast else
                   [("low", 25.0, 30), ("high", 70.0, 64), ("low", 18.0, 40)])
    tick_every = 4
    probe_every = 7
    fleet_id = "kvfleet"
    fabric = Fabric(default_link=LinkModel(latency_s=0.0005))
    backends = [KVBackend(fabric, f"fkv{i}", service_time_s=0.0003)
                for i in range(n_backends)]
    router = Router(fabric, "fleet-router", [b.addr for b in backends])
    store = KVStore()
    members, clients = [], []
    for i in range(n_clients):
        ep = fabric.register(f"fleet-cli{i}")
        st = routing_stack(ep, [b.addr for b in backends],
                           router_addr="fleet-router", prefer="server")
        handle = LockedConn(st.preferred())
        pub = FleetPublisher(store, fleet_id, f"cli{i}", handle.telemetry,
                             period_s=0.02)
        m = FleetMember(store, fleet_id, f"cli{i}", handle, st, publisher=pub)
        m.join()
        members.append(m)
        clients.append(KVClient(fabric, ep, handle))
    spot = SpotPriceSignal(trace=[0.7], period_s=3600.0)  # calm market
    # generous TTL: heartbeat expiry has its own test; a loaded CI runner
    # stalling the single-threaded driver for a second must not age the whole
    # fleet out mid-phase and fake a load drain
    agg = FleetAggregator(store, fleet_id, ttl_s=3.0, sources=[spot])
    policy = "kv_fleet_adaptive"
    ctl = fleet_controller(
        store, fleet_id, members[0].stack,
        policy=policy,
        policy_params={"fleet_high_qps": fleet_high, "fleet_low_qps": fleet_low,
                       "hold": 2, "spot_cap_usd_per_h": 3.0},
        pump=lambda: [m.poll() for m in members],
        cooldown_s=0.15,
    )

    drain_buf = [None]

    def drain(handle):
        while handle.recv(drain_buf, timeout=0.001):
            pass

    phases = []
    peak_member_qps = 0.0
    try:
        for label, per_rps, n_iter in phases_spec:
            gap = 1.0 / per_rps
            nxt = time.monotonic()
            for it in range(n_iter):
                nxt += gap
                dt = nxt - time.monotonic()
                if dt > 0:
                    time.sleep(dt)
                for cli, m in zip(clients, members):
                    try:
                        if it % probe_every == 0:
                            cli.request("get", f"k{it % 23}", timeout=1.0)
                        else:
                            m.handle.send([{"op": "get", "key": f"k{it % 23}",
                                            "rid": -1, "reply_to": cli.addr}])
                    except TimeoutError:
                        pass
                if it % 3 == 2:
                    for m in members:
                        drain(m.handle)
                if (it + 1) % tick_every == 0:
                    for m in members:
                        m.poll()
                    snap = agg.aggregate()
                    member_qps = snap["fleet.member_qps"].values()
                    if member_qps:
                        peak_member_qps = max(peak_member_qps, *member_qps)
                    ctl.tick(snap)
            for m in members:
                drain(m.handle)
            cur = store.get(f"{fleet_conn_id(fleet_id)}/stack")
            phases.append({
                "phase": label,
                "per_client_rps": per_rps,
                "aggregate_rps": per_rps * n_clients,
                "epoch": cur["epoch"],
                "stacks": [repr(m.handle.stack) for m in members],
                "fleet_snapshot": dict(ctl.decisions[-1].snapshot)
                if ctl.decisions else {},
            })
    finally:
        for b in backends:
            b.close()
        router.close()

    return {
        "mode": "fleet",
        "policy": policy,
        "n_clients": n_clients,
        "thresholds": {"fleet_high_qps": fleet_high, "fleet_low_qps": fleet_low,
                       "per_client_high_qps": per_client_high},
        "phases": phases,
        "switches": [d.to_json() for d in ctl.switch_log()],
        "counts": ctl.counts(),
        "peak_member_qps": peak_member_qps,
        "member_transitions": {m.member: m.transitions for m in members},
        "member_switches": [m.handle.stats.switches for m in members],
        "publisher_conflicts": sum(m.publisher.conflicts for m in members),
        "store_conflicts": store.conflicts,
        "ext.spot_usd_per_h": spot.value(),
    }


def emit_fleet_scenario(*, fast: bool = False) -> dict:
    """Run the fleet scenario, write the JSON artifact, and assert the
    acceptance shape: one fleet-wide switch per load transition, committed in
    a single rendezvous epoch, driven by the AGGREGATE (every individual
    client stayed below the per-client threshold). Shared by main() and
    run.py --smoke."""
    res = run_fleet_kv(fast=fast)
    FLEET_OUT.parent.mkdir(parents=True, exist_ok=True)
    FLEET_OUT.write_text(json.dumps(res, indent=2, default=float))

    low1, high, low2 = res["phases"]
    assert low1["epoch"] == 1 and all("ServerRouter" in s for s in low1["stacks"]), low1
    assert high["epoch"] == 2 and all(s.startswith("ClientShard") for s in high["stacks"]), high
    assert low2["epoch"] == 3 and all(s.startswith("ServerRouter") for s in low2["stacks"]), low2
    # exactly one committed switch per transition, fleet-wide
    assert res["counts"]["committed"] == 2, res["counts"]
    assert all(n == 2 for n in res["member_switches"]), res["member_switches"]
    # the decision was the aggregate's, not any single client's
    up = res["switches"][0]
    agg_at_switch = up["snapshot"]["fleet.offered_qps"]
    thr = res["thresholds"]
    assert up["rule"] == "fleet-high-load->client-shard", up
    assert agg_at_switch > thr["fleet_high_qps"], up["snapshot"]
    assert res["peak_member_qps"] < thr["per_client_high_qps"], res["peak_member_qps"]
    return res


# ---------------------------------------------------------------------------
# Controller-driven BarrierConn scenario (lock-free mechanism, closed loop)
# ---------------------------------------------------------------------------


def run_controller_barrier(n_threads: int = 3, *, fast: bool = False) -> dict:
    """Multi-threaded BarrierConn data plane; the controller (latency_slo
    policy over live op-latency telemetry) initiates the SlowPath -> FastPath
    switch itself, paying the stop-the-world barrier mid-traffic. Emits the
    blip and total blocked time beside the LockedConn KV scenario."""
    caps = CapabilitySet.exact("wire:obj")

    def _slow_send(m):
        time.sleep(2e-3)
        return m

    slow = FnChunnel(fn_name="SlowPath", caps=caps, on_send=_slow_send,
                     cost=CostModel(op_latency_s=2e-3, switch_blip_s=1e-4))
    fast_c = FnChunnel(fn_name="FastPath", caps=caps, on_send=lambda m: m,
                       cost=CostModel(op_latency_s=1e-4, switch_blip_s=1e-4))
    fabric = Fabric()
    ep = fabric.register(f"barrier-ctl-{time.monotonic_ns()}")
    stack = make_stack(Select(slow, fast_c), FabricTransport(ep, "sink"))
    handle = BarrierConn(stack.preferred(), n_threads=n_threads)
    ctl = conn_controller(
        handle, stack,
        policy="latency_slo",
        policy_params={"slo_s": 1e-3, "metric": "op_p95_s", "hold": 2},
        cooldown_s=0.1,
    )
    lat = {"SlowPath": [], "FastPath": []}
    stop = threading.Event()

    def pump():
        while not stop.is_set():
            t0 = time.perf_counter()
            handle.send([b"x"])
            lat[handle.stack.chunnels[0].name].append(time.perf_counter() - t0)

    threads = [threading.Thread(target=pump) for _ in range(n_threads)]
    for t in threads:
        t.start()
    t_end = time.monotonic() + (0.6 if fast else 1.2)
    while time.monotonic() < t_end:
        time.sleep(0.03)
        ctl.tick(handle.telemetry.snapshot())
    stop.set()
    for t in threads:
        t.join()
    assert handle.stats.switches == 1, handle.stats
    assert lat["SlowPath"] and lat["FastPath"], {k: len(v) for k, v in lat.items()}
    return {
        "plane": "barrier",
        "policy": "latency_slo",
        "n_threads": n_threads,
        "p50_before_us": pct(lat["SlowPath"], 50) * 1e6,
        "p50_after_us": pct(lat["FastPath"], 50) * 1e6,
        "blip_s": handle.stats.last_switch_s,
        "total_blocked_s": handle.stats.total_blocked_s,
        "switches": [d.to_json() for d in ctl.switch_log()],
        "counts": ctl.counts(),
        "final_stack": repr(handle.stack),
    }


# ---------------------------------------------------------------------------
# Chaos scenarios: hostile-network regions + coordinator crash mid-commit
# ---------------------------------------------------------------------------


def run_chaos_regions(*, fast: bool = False) -> dict:
    """Region-aware link adaptation under injected WAN weather (§7 / ROADMAP
    direction 5).

    Two regions talk to one ``WanGateway`` hub through the same negotiated
    Select [FastWire | WanLink]. A ``ChaosPlan`` degrades every link between
    the WAN region and the hub (latency + jitter + heavy loss) and later
    rides a short hard partition on top of the weather. Each region's driver
    probes its link every tick and feeds two scenario keys into the
    controller snapshot — ``link.timeout_ratio`` (probe timeouts per window)
    and ``link.retransmit_ratio`` (windowed WAN go-back-N retransmits per
    frame) — so the ``wan_region_adaptive`` policy moves the lossy region to
    the compressed+reliable WAN option while the clean DCN region stays on
    the fast path, in the same run."""
    import numpy as np

    from repro.chaos import ChaosInjector, ChaosPlan
    from repro.comm.chunnels import WanLinkChunnel  # registers the policy
    from repro.serving.gateway import WanGateway

    fabric = Fabric(default_link=LinkModel(latency_s=0.0002), seed=7)
    gw = WanGateway(fabric, "hub")

    class Region:
        def __init__(self, name: str):
            self.name = name
            self.ep_fast = fabric.register(f"{name}/fastlink")
            self.ep_wan = fabric.register(f"{name}/wanlink")
            self.stack = make_stack(Select(
                FabricTransport(self.ep_fast, "hub/fast", label="FastWire"),
                WanLinkChunnel(self.ep_wan, "hub/wan", mtu_bytes=2048,
                               window=8, timeout_s=0.03, retries=8),
            ))
            self.handle = LockedConn(self.stack.preferred())  # FastWire
            self.ctl = conn_controller(
                self.handle, self.stack,
                policy="wan_region_adaptive",
                policy_params={"breach_timeout_ratio": 0.05,
                               "recover_timeout_ratio": 0.01,
                               "recover_retransmit_ratio": 0.02, "hold": 2},
                cooldown_s=0.15,
            )
            self.rid = 0
            self.timeouts = self.probes = 0
            self._prev = (None, 0, 0)  # (dp id, retransmits, frames_sent)

        def on_wan(self) -> bool:
            return any(c.name == "WanLink" for c in self.handle.stack.chunnels)

        def probe(self, timeout: float = 0.08) -> None:
            self.rid += 1
            self.probes += 1
            if self.on_wan():
                # delivery is confirmed by the window acks themselves
                try:
                    self.handle.send([{"rid": self.rid}])
                except TimeoutError:
                    self.timeouts += 1
                return
            # fast path: fire-and-forget send, wait for the gateway echo
            self.handle.send([{"rid": self.rid}])
            buf = [None]
            deadline = time.monotonic() + timeout
            while True:
                t = deadline - time.monotonic()
                if t <= 0 or not self.handle.recv(buf, timeout=max(t, 0.0)):
                    self.timeouts += 1
                    return
                m = buf[0]
                if isinstance(m, dict) and m.get("rid") == self.rid:
                    return  # stale echoes of timed-out probes are skipped

        def rtx_ratio(self) -> float:
            """Windowed WAN retransmits per frame; 0.0 on the fast path."""
            if not self.on_wan():
                self._prev = (None, 0, 0)
                return 0.0
            s = self.handle.dp.stats()
            prev_id, prev_rtx, prev_fr = self._prev
            if prev_id != id(self.handle.dp):  # fresh datapath after a swap
                prev_rtx = prev_fr = 0
            d_rtx = s["retransmits"] - prev_rtx
            d_fr = s["frames_sent"] - prev_fr
            self._prev = (id(self.handle.dp), s["retransmits"],
                          s["frames_sent"])
            return d_rtx / max(1, d_fr)

        def tick(self):
            snap = self.handle.telemetry.snapshot()
            snap["link.timeout_ratio"] = self.timeouts / max(1, self.probes)
            snap["link.retransmit_ratio"] = self.rtx_ratio()
            self.timeouts = self.probes = 0
            return self.ctl.tick(snap)

    wan, dcn = Region("wan-cli"), Region("dcn-cli")
    weather = LinkModel(latency_s=0.004, jitter_s=0.002, loss=0.25)
    plan = ChaosPlan(seed=7)
    plan.degrade("wan-cli", "hub", weather, at=0.0, label="wan-weather")
    # a short hard partition riding on the weather, pulled by the driver one
    # tick after the WAN region adopted the WAN stack: the link must absorb
    # it (failed sends + keepalive misses), not wedge or leak partial blobs
    plan.partition("wan-cli", "hub", on="storm", for_s=0.2, label="wan-storm")
    inj = ChaosInjector(fabric, plan).start()
    inj.poll()  # apply the weather before the first probe window

    # deterministic tensor payload exercising MTU chunking on the WAN wire
    blob = (np.arange(64 * 257, dtype=np.float32).reshape(64, 257) - 8000.0)

    max_ticks = 8 if fast else 14
    probes_per_tick = 4 if fast else 6
    storm_at = None          # tick index at which the storm fires
    post_storm = 0
    wan_switch_tick = None
    try:
        for tick in range(max_ticks):
            inj.poll()
            if storm_at == tick:
                inj.fire("storm")
            for r in (wan, dcn):
                if r.on_wan():
                    r.handle.dp.ping(retries=2)  # keepalive probe
                for _ in range(probes_per_tick):
                    r.probe()
                    inj.poll()  # autoheal mid-window, not at tick granularity
                    time.sleep(0.004)
                if r.on_wan() and tick % 2 == 0:
                    try:
                        r.handle.send([blob])  # chunked + quantized tensor
                    except TimeoutError:
                        pass  # counted in failed_sends by the datapath
            for r in (wan, dcn):
                d = r.tick()
                if (r is wan and wan_switch_tick is None
                        and d.reason == "switched"):
                    wan_switch_tick = tick
                    storm_at = tick + 1
            if storm_at is not None and tick > storm_at:
                post_storm += 1
            if post_storm >= 2:
                break  # storm evidence collected; no need to run the tail out
    finally:
        wan_stats = wan.handle.dp.stats() if wan.on_wan() else {}
        inj.stop()
        gw_stats = gw.stats()
        gw.close()

    def region_result(r: Region) -> dict:
        return {
            "final_stack": repr(r.handle.stack),
            "chunnels": [c.name for c in r.handle.stack.chunnels],
            "capabilities": sorted(
                str(c) for ch in r.handle.stack.chunnels
                for c in ch.capabilities()),
            "switches": [d.to_json() for d in r.ctl.switch_log()],
            "counts": r.ctl.counts(),
            "total_switches": r.handle.stats.switches,
        }

    return {
        "scenario": "chaos-regions",
        "wan": {**region_result(wan), "link_stats": wan_stats,
                "switch_tick": wan_switch_tick},
        "dcn": region_result(dcn),
        "gateway": gw_stats,
        "events": inj.log,
        "weather": {"latency_s": weather.latency_s,
                    "jitter_s": weather.jitter_s, "loss": weather.loss},
        "storm_tick": storm_at,
    }


def run_chaos_partition_2pc(*, fast: bool = False) -> dict:
    """Coordinator crash exactly mid-commit, then heal (§4.2 failure path).

    Three HostAgents share a multilateral connection; A coordinates a 2PC
    switch with a small chaos reliability budget. The ``ChaosPlan`` hangs a
    crash of A on the ``mid_commit`` trigger, pulled from the commit hook —
    the decision is recorded, then A blackholes before ANY phase-2
    notification lands, stranding B and C prepared. Their resync queries
    fail (counted) until the plan restarts A, after which the epoch-query
    path converges every survivor onto the committed epoch with zero
    stranded prepared peers."""
    from repro.chaos import ChaosInjector, ChaosPlan

    fabric = Fabric(default_link=LinkModel(latency_s=0.0003), seed=11)
    agents = {n: HostAgent(fabric, n) for n in ("2pc-A", "2pc-B", "2pc-C")}
    hA = agents["2pc-A"]
    conn = "chaos-conn"

    def member_stack(name):
        ep = fabric.register(f"{name}/data")
        return make_stack(
            Select(FnChunnel(fn_name="Blue", on_send=lambda m: m),
                   FnChunnel(fn_name="Green", on_send=lambda m: m)),
            FabricTransport(ep, "hub"))

    stacks = {n: member_stack(n) for n in agents}
    handleA = LockedConn(stacks["2pc-A"].preferred())
    target = stacks["2pc-A"].options()[1]  # Blue -> Green
    # identical stacks on every member: the proposed fingerprint must resolve
    assert all(st.find(target.fingerprint()) for st in stacks.values())
    for n in ("2pc-B", "2pc-C"):
        agents[n].register_participant(
            conn, LockedConn(stacks[n].preferred()), stacks[n].find,
            resync_after_s=0.12)

    plan = ChaosPlan(seed=3)
    plan.crash("2pc-A", on="mid_commit", label="coordinator-crash")
    plan.restart("coordinator-crash", at=0.45)
    inj = ChaosInjector(fabric, plan).start()

    # pull the crash trigger from the commit hook: the decision is recorded,
    # then the coordinator vanishes before any phase-2 notification lands
    record = hA.record_decision

    def record_and_vanish(conn_id, epoch, fp):
        record(conn_id, epoch, fp)
        inj.fire("mid_commit")

    hA.record_decision = record_and_vanish

    t0 = time.monotonic()
    ok = hA.reconfigure_multilateral(handleA, target, ["2pc-B", "2pc-C"],
                                     conn, timeout=0.04, retries=2)

    parts = {n: agents[n].participant(conn) for n in ("2pc-B", "2pc-C")}
    deadline = time.monotonic() + (4.0 if fast else 6.0)
    converge_s = None
    try:
        while time.monotonic() < deadline:
            inj.poll()
            if (all(p.prepared is None for p in parts.values())
                    and all(p.epoch == handleA.stats.switches
                            for p in parts.values())):
                converge_s = time.monotonic() - t0
                break
            time.sleep(0.01)
        fps = {"2pc-A": handleA.stack.fingerprint()}
        fps.update({n: p.handle.stack.fingerprint()
                    for n, p in parts.items()})
        epochs = {"2pc-A": handleA.stats.switches}
        epochs.update({n: p.epoch for n, p in parts.items()})
        return {
            "scenario": "partition-2pc",
            "commit_ok": ok,
            "converged": converge_s is not None,
            "converge_s": converge_s,
            "stranded_prepared": sum(p.prepared is not None
                                     for p in parts.values()),
            "resync_failures": {n: p.resync_failures
                                for n, p in parts.items()},
            "epochs": epochs,
            "fingerprints": fps,
            "target_fp": target.fingerprint(),
            "events": inj.log,
        }
    finally:
        inj.stop()
        for a in agents.values():
            a.close()


def emit_chaos_scenarios(*, fast: bool = False) -> dict:
    """Run both chaos scenarios, write the JSON artifact, and assert the
    acceptance shape: in ONE run the controller selects compressed+reliable
    on the lossy WAN region AND keeps the fast path on the clean DCN region;
    the partition-during-2PC scenario ends with zero stranded prepared peers
    and every survivor on one committed epoch. Shared by main() and
    run.py --smoke."""
    from repro.obs.flight import RECORDER
    from repro.obs.trace import TRACER

    # trace the chaos runs so a failed acceptance assertion dumps the spans
    # leading up to it (benchmarks/out/flightrec_chaos_smoke_assert.json)
    was_enabled = TRACER.enabled
    TRACER.enable()
    try:
        with RECORDER.capture("chaos_smoke"):
            res = {"regions": run_chaos_regions(fast=fast),
                   "partition_2pc": run_chaos_partition_2pc(fast=fast)}
            CHAOS_OUT.parent.mkdir(parents=True, exist_ok=True)
            CHAOS_OUT.write_text(json.dumps(res, indent=2, default=float))
            _assert_chaos_acceptance(res)
    finally:
        if not was_enabled:
            TRACER.disable()
    return res


def _assert_chaos_acceptance(res: dict) -> None:
    wan, dcn = res["regions"]["wan"], res["regions"]["dcn"]
    # lossy WAN region: switched by the link-health rule onto the WAN option,
    # whose capabilities spell out compressed (q8 blocks) + reliable (gbn)
    assert wan["switches"], wan
    assert wan["switches"][0]["rule"] == "lossy-wan->compressed-reliable", wan
    assert "WanLink" in wan["chunnels"], wan
    assert any("wan-gbn" in c for c in wan["capabilities"]), wan
    assert any("q8b" in c for c in wan["capabilities"]), wan
    # clean DCN region, same run: never left the fast path
    assert not dcn["switches"] and "FastWire" in dcn["chunnels"], dcn
    # the WAN wire really carried chunked+reassembled blobs and repaired loss
    assert res["regions"]["gateway"]["wan_blobs"] >= 1, res["regions"]
    ls = wan["link_stats"]
    assert ls.get("retransmits", 0) > 0, ls
    # the storm left evidence (failed sends or keepalive misses), not a wedge
    assert ls.get("failed_sends", 0) + ls.get("keepalive_failures", 0) > 0, ls

    p2 = res["partition_2pc"]
    assert p2["commit_ok"] and p2["converged"], p2
    assert p2["stranded_prepared"] == 0, p2
    assert set(p2["fingerprints"].values()) == {p2["target_fp"]}, p2
    assert len(set(p2["epochs"].values())) == 1, p2
    # the crash really blocked resync for a while (queries failed, then healed)
    assert sum(p2["resync_failures"].values()) >= 1, p2


def main() -> None:
    for mech in ("lock", "barrier"):
        lat, switch_s = run_mechanism(mech)
        emit(f"reconfig_{mech}_fastpath_p50", pct(lat, 50) * 1e6,
             f"p95={pct(lat, 95)*1e6:.2f}us;n={len(lat)}")
        emit(f"reconfig_{mech}_switch", switch_s * 1e6, "")

    scored = emit_scored_negotiation()
    for label, row in scored.items():
        emit(f"negotiate_scored_{label}", 0.0,
             f"first={row['first_compatible']};scored={row['scored']}")
    print(f"# scored negotiation JSON: {SCORED_OUT}", file=sys.stderr, flush=True)

    results = {"kv": run_controller_kv(), "trainer": run_controller_trainer(),
               "barrier": run_controller_barrier()}
    JSON_OUT.parent.mkdir(parents=True, exist_ok=True)
    JSON_OUT.write_text(json.dumps(results, indent=2, default=float))
    kv, trainer, barrier = results["kv"], results["trainer"], results["barrier"]
    assert kv["switches"], "controller never initiated a KV routing switch"
    emit("reconfig_ctl_kv_switches", kv["blip_s"] * 1e6,
         f"n={len(kv['switches'])};policy={kv['policy']};"
         f"final={kv['final_stack'].split(' ')[0]}")
    emit("reconfig_ctl_trainer_switches", 0.0,
         f"n={len(trainer['switches'])};final={trainer['final_transport']}")
    emit("reconfig_ctl_barrier_switch", barrier["blip_s"] * 1e6,
         f"blocked_us={barrier['total_blocked_s']*1e6:.1f};"
         f"p50_before={barrier['p50_before_us']:.0f}us;"
         f"p50_after={barrier['p50_after_us']:.0f}us")
    print(f"# controller scenario JSON: {JSON_OUT}", file=sys.stderr, flush=True)

    fleet = emit_fleet_scenario()
    emit("reconfig_fleet_kv", 0.0,
         f"clients={fleet['n_clients']};epochs={fleet['phases'][-1]['epoch']};"
         f"switches={fleet['counts']['committed']};"
         f"peak_member_qps={fleet['peak_member_qps']:.0f}")
    print(f"# fleet scenario JSON: {FLEET_OUT}", file=sys.stderr, flush=True)

    chaos = emit_chaos_scenarios()
    wan, p2 = chaos["regions"]["wan"], chaos["partition_2pc"]
    emit("reconfig_chaos_regions", 0.0,
         f"wan_switch_tick={wan['switch_tick']};"
         f"wan_rule={wan['switches'][0]['rule']};"
         f"dcn_switches={len(chaos['regions']['dcn']['switches'])};"
         f"retransmits={wan['link_stats'].get('retransmits', 0)}")
    emit("reconfig_chaos_2pc", (p2["converge_s"] or 0.0) * 1e6,
         f"stranded={p2['stranded_prepared']};"
         f"resync_failures={sum(p2['resync_failures'].values())};"
         f"epoch={p2['epochs']['2pc-A']}")
    print(f"# chaos scenario JSON: {CHAOS_OUT}", file=sys.stderr, flush=True)


if __name__ == "__main__":
    main()
