"""Paper Fig. 10 analogue: lock vs lock-free (barrier) reconfiguration.

Measures (a) steady-state per-op latency of each mechanism under multi-thread
load (the lock's fast-path tax) and (b) the reconfiguration blip (switch
duration) for each, swapping between two datapath implementations mid-run.
"""
from __future__ import annotations

import threading
import time

import numpy as np

from benchmarks.common import emit, pct
from repro.core import BarrierConn, Fabric, FabricTransport, FnChunnel, LockedConn, make_stack


def _stack(fabric, tag):
    ep = fabric.register(f"bench-{tag}-{time.monotonic_ns()}")
    return make_stack(FnChunnel(fn_name=f"Impl{tag}", on_send=lambda m: m),
                      FabricTransport(ep, "sink"))


def run_mechanism(mechanism: str, n_threads: int = 3, duration_s: float = 1.2,
                  reconfigure_at: float = 0.5):
    fabric = Fabric()
    st_a, st_b = _stack(fabric, "A"), _stack(fabric, "B")
    handle = (LockedConn(st_a.preferred()) if mechanism == "lock"
              else BarrierConn(st_a.preferred(), n_threads=n_threads))
    lat: list = []
    stop = threading.Event()

    def pump():
        while not stop.is_set():
            t0 = time.perf_counter()
            handle.send([b"x"])
            lat.append(time.perf_counter() - t0)

    threads = [threading.Thread(target=pump) for _ in range(n_threads)]
    for t in threads:
        t.start()
    time.sleep(reconfigure_at)
    t0 = time.perf_counter()
    ok = handle.reconfigure(st_b.preferred())
    switch_s = time.perf_counter() - t0
    time.sleep(duration_s - reconfigure_at)
    stop.set()
    for t in threads:
        t.join()
    assert ok and handle.stats.switches == 1
    return lat, switch_s


def main() -> None:
    for mech in ("lock", "barrier"):
        lat, switch_s = run_mechanism(mech)
        emit(f"reconfig_{mech}_fastpath_p50", pct(lat, 50) * 1e6,
             f"p95={pct(lat, 95)*1e6:.2f}us;n={len(lat)}")
        emit(f"reconfig_{mech}_switch", switch_s * 1e6, "")


if __name__ == "__main__":
    main()
