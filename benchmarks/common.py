"""Shared benchmark helpers; each bench prints ``name,us_per_call,derived``."""
from __future__ import annotations

import time

import numpy as np


def bench_mesh(shape=(2, 4), axes=("pod", "data")):
    """Benchmark meshes share the compat-backed test-mesh builder so the
    harness runs on every supported JAX (0.4.x cannot type mesh axes
    natively) and cannot diverge from the test tier."""
    from repro.launch.mesh import make_test_mesh

    return make_test_mesh(shape, axes)


def smoke_check() -> None:
    """Tiny end-to-end sanity used by ``run.py --smoke``: build a compat mesh,
    run one jitted shard_map psum on it, and emit a CSV row. Catches
    version-compat regressions in the mesh/shard_map path without paying for
    a full benchmark sweep."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro import compat

    n = jax.device_count()
    mesh = bench_mesh((n,), ("data",))
    f = compat.shard_map(lambda x: jax.lax.psum(x, "data"), mesh=mesh,
                         in_specs=P(), out_specs=P(), check_vma=False,
                         axis_names={"data"})
    out = jax.jit(f)(jnp.ones((4,)))
    assert float(np.asarray(out)[0]) == float(n), out
    t = timeit(lambda: jax.block_until_ready(jax.jit(f)(jnp.ones((4,)))))
    emit("smoke_psum", t * 1e6, f"devices={n}")


def timeit(fn, *, warmup: int = 3, iters: int = 20) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters


def pct(xs, p):
    return float(np.percentile(np.asarray(xs), p))


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.2f},{derived}")
