"""Shared benchmark helpers; each bench prints ``name,us_per_call,derived``."""
from __future__ import annotations

import time

import numpy as np


def timeit(fn, *, warmup: int = 3, iters: int = 20) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters


def pct(xs, p):
    return float(np.percentile(np.asarray(xs), p))


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.2f},{derived}")
