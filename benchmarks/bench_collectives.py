"""TPU-side transport Select: per-transport collective profile (bytes by kind,
DCN vs ICI) from the compiled multi-pod HLO for a small dense arch.

This is the §Perf instrument: the numbers show what each gradient-transport
chunnel does to the collective roofline term. Numerical equivalence of the
transports is covered by tests/test_comm.py; wall-clock on real links is out
of scope for the CPU container (see EXPERIMENTS.md §Roofline).

Each transport compiles in its own subprocess: a 512-host-device XLA compile
retains several GB, and the CPU container kills accumulating processes.
compressed_int8 (full-tree quantized all-gather) is excluded — it exceeds the
XLA-CPU compiler's host memory at 1.2B params (§Perf refuted-hypothesis log);
its compile-feasible form is hier_compressed (quantizes 1/16 shards).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import emit

TRANSPORTS = ("xla", "psum", "ring", "hierarchical", "hier_compressed")

_INNER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json, sys
from repro.launch.dryrun import lower_cell
rec = lower_cell("llama3.2-1b", "train_4k", multi_pod=True, transport=sys.argv[1])
r = rec["roofline"]
print("RESULT " + json.dumps({
    "collective_s": r["collective_s"],
    "dcn": r["dcn_bytes_per_dev"],
    "total": r["coll_bytes_per_dev"],
    "dom": r["dominant"],
}))
"""


def main() -> None:
    env = dict(os.environ)
    env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
    for transport in TRANSPORTS:
        try:
            out = subprocess.run(
                [sys.executable, "-c", _INNER, transport],
                env=env, capture_output=True, text=True, timeout=1200)
            line = next((l for l in out.stdout.splitlines()
                         if l.startswith("RESULT ")), None)
            if line is None:
                emit(f"collectives_{transport}", 0.0,
                     f"failed:rc={out.returncode}")
                continue
            r = json.loads(line[len("RESULT "):])
            emit(f"collectives_{transport}", r["collective_s"] * 1e6,
                 f"dcn_GB={r['dcn']/1e9:.3f};total_GB={r['total']/1e9:.2f};"
                 f"dom={r['dom']}")
        except Exception as e:
            emit(f"collectives_{transport}", 0.0, f"failed:{type(e).__name__}")
    # psum/ring over pod hit an XLA-CPU SPMD partitioner assertion
    # (spmd_partitioner_util.cc:504) on the 3-axis production mesh; they work
    # on 2-axis meshes (tests/test_substrate.py, examples/train_reconfigure.py)


if __name__ == "__main__":
    main()
