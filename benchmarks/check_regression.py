"""Performance-regression gate: this run's artifacts vs the committed baseline.

``benchmarks/baseline.json`` records derated reference values for the two
numbers the PR acceptance gates track:

  * data-plane throughput at batch=64 (``benchmarks/out/dataplane.json``,
    written by ``bench_dataplane.run``) — higher is better; fail when the
    measured value falls more than ``TOLERANCE`` (30%) below baseline;
  * tracing overhead when ENABLED at batch=64
    (``benchmarks/out/overhead.json``, written by ``run_tracing_overhead``)
    — lower is better. Overhead fractions are tiny and jittery, so the
    allowance is ``max(baseline * (1 + TOLERANCE), baseline + abs_slack)``:
    the absolute slack keeps a 0.01-vs-0.013 wobble from failing CI while
    the relative bound still catches a real hot-path regression.

Run after the benches have written their artifacts (``run.py --smoke`` does
both, in order). Writes ``benchmarks/out/regression_report.json`` — the CI
comparison artifact — and exits 1 on any regression. Missing artifacts are
regressions too: a bench that silently stopped emitting its artifact must
not look like a pass.
"""
from __future__ import annotations

import json
import pathlib
import sys
from typing import Optional

HERE = pathlib.Path(__file__).resolve().parent
BASELINE = HERE / "baseline.json"
OUT_DIR = HERE / "out"
REPORT = OUT_DIR / "regression_report.json"

#: relative regression allowed before CI fails (ISSUE acceptance: >30% fails)
TOLERANCE = 0.30


def _load(path: pathlib.Path) -> Optional[dict]:
    try:
        return json.loads(path.read_text())
    except (OSError, ValueError):
        return None


def check(baseline_path: pathlib.Path = BASELINE,
          out_dir: pathlib.Path = OUT_DIR) -> dict:
    """Compare artifacts to the baseline; return the report dict.

    Report shape: ``{"checks": [...], "regressions": [...]}`` where each
    check row carries name, measured, baseline, allowed bound, direction,
    and ok. The report is also written to ``out/regression_report.json``.
    """
    base = json.loads(baseline_path.read_text())
    checks = []

    def add(name: str, measured: Optional[float], baseline: float,
            allowed: float, direction: str) -> None:
        if measured is None:
            ok = False
        elif direction == "min":     # higher is better; allowed is the floor
            ok = measured >= allowed
        else:                        # lower is better; allowed is the ceiling
            ok = measured <= allowed
        checks.append({"name": name, "measured": measured,
                       "baseline": baseline, "allowed": allowed,
                       "direction": direction, "ok": ok})

    dp = _load(out_dir / "dataplane.json")
    b = base["dataplane"]
    add("dataplane.batch64_msgs_per_s",
        (dp or {}).get("default", {}).get("64", {}).get("msgs_per_s"),
        b["batch64_msgs_per_s"],
        b["batch64_msgs_per_s"] * (1.0 - TOLERANCE), "min")
    add("dataplane.speedup_batch64", (dp or {}).get("speedup_batch64"),
        b["speedup_batch64"], b["speedup_batch64"] * (1.0 - TOLERANCE), "min")

    ov = _load(out_dir / "overhead.json")
    b = base["overhead"]
    add("overhead.enabled_overhead", (ov or {}).get("enabled_overhead"),
        b["enabled_overhead"],
        max(b["enabled_overhead"] * (1.0 + TOLERANCE),
            b["enabled_overhead"] + b["abs_slack"]), "max")

    report = {"baseline": str(baseline_path), "tolerance": TOLERANCE,
              "checks": checks,
              "regressions": [c for c in checks if not c["ok"]]}
    out_dir.mkdir(parents=True, exist_ok=True)
    REPORT.write_text(json.dumps(report, indent=2))
    return report


def main() -> int:
    report = check()
    for c in report["checks"]:
        mark = "ok  " if c["ok"] else "FAIL"
        meas = "missing" if c["measured"] is None else f"{c['measured']:.4g}"
        bound = "floor" if c["direction"] == "min" else "ceiling"
        print(f"  {mark} {c['name']:<32} measured={meas:<10} "
              f"{bound}={c['allowed']:.4g} (baseline {c['baseline']:.4g})")
    print(f"report: {REPORT}")
    if report["regressions"]:
        names = [c["name"] for c in report["regressions"]]
        print(f"REGRESSION: {', '.join(names)} "
              f"(>{TOLERANCE:.0%} worse than baseline.json — if this is an "
              f"intended trade-off, refresh the baseline in the same PR)",
            file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
