"""Benchmark harness — one module per paper table/figure (DESIGN.md §6).

Prints ``name,us_per_call,derived`` CSV rows.

  Fig 4  bench_pipeline      ETL e2e latency: Kafka vs managed pub/sub
  Fig 5  bench_ordering      receive-side vs service ordering + renegotiation
  Fig 6  bench_sharding      client-side vs server-side KV sharding
  Fig7/8 bench_overhead      marginal no-op chunnel cost (jit + eager)
  Fig 9  bench_kv_latency    full stack vs inlined baselines
  Fig 10 bench_reconfigure   lock vs barrier reconfiguration
  (TPU)  bench_collectives   gradient-transport Select collective profile
  (§8)   bench_dataplane     batched data plane msgs/s vs per-message baseline
"""
from __future__ import annotations

import argparse
import importlib
import sys
import time
import traceback

MODULES = [
    "benchmarks.bench_dataplane",
    "benchmarks.bench_overhead",
    "benchmarks.bench_slo",
    "benchmarks.bench_reconfigure",
    "benchmarks.bench_kv_latency",
    "benchmarks.bench_sharding",
    "benchmarks.bench_ordering",
    "benchmarks.bench_pipeline",
    "benchmarks.bench_collectives",
]


def smoke() -> None:
    """Dry pass for CI (scripts/verify.sh): import every bench module (their
    heavy work lives in main(), so imports are cheap), run one compat
    mesh + shard_map sanity, run the scored-vs-first-compatible negotiation
    comparison, and run the controller-driven KV reconfigure scenario
    headless through the policy registry — a regression anywhere in the
    close-the-loop path (telemetry -> scorer -> policy -> switch) fails
    tier-1, not just the full bench sweep. Fails loudly on any import or
    compat regression."""
    from benchmarks import common
    from repro import compat

    print("name,us_per_call,derived")
    for mod_name in MODULES:
        importlib.import_module(mod_name)
        print(f"# {mod_name} import ok", file=sys.stderr)
    common.smoke_check()

    from benchmarks.bench_reconfigure import (
        emit_chaos_scenarios,
        emit_fleet_scenario,
        emit_scored_negotiation,
        run_controller_kv,
    )

    scored = emit_scored_negotiation()
    print("smoke_scored_negotiation,0.00,"
          f"chatty={scored['chatty']['scored']};bulk={scored['bulk']['scored']}")

    res = run_controller_kv(fast=True)
    assert res["switches"], "controller-initiated KV switch did not fire"
    assert res["policy"] == "kv_load_adaptive", res.get("policy")  # via registry
    assert "ClientShard" in res["switches"][0]["target"], res["switches"][0]
    print(f"smoke_controller_kv,{res['blip_s'] * 1e6:.2f},"
          f"switches={len(res['switches'])};policy={res['policy']}")

    # batched data plane: scaled-down throughput pass (asserts the ≥10x
    # batch=64 speedup over the per-message baseline internally and writes
    # benchmarks/out/dataplane.json — a CI artifact)
    from benchmarks.bench_dataplane import run as run_dataplane

    dp = run_dataplane(smoke=True)
    print("smoke_dataplane,0.00,"
          f"speedup_batch64={dp['speedup_batch64']:.1f}x;"
          f"default_b64_msgs_per_s={dp['default']['64']['msgs_per_s']:.0f}")

    # fleet signal plane: aggregate-driven switch, one rendezvous epoch for
    # the whole fleet (asserts the acceptance shape internally and writes
    # benchmarks/out/fleet_scenario.json — a CI artifact)
    fleet = emit_fleet_scenario(fast=True)
    print("smoke_fleet_kv,0.00,"
          f"clients={fleet['n_clients']};"
          f"switches={fleet['counts']['committed']};"
          f"epochs={fleet['phases'][-1]['epoch']};"
          f"peak_member_qps={fleet['peak_member_qps']:.0f}")

    # chaos harness: injected WAN weather + storm drives the region onto the
    # compressed+reliable WAN option while the clean region keeps the fast
    # path, and a coordinator crashed exactly mid-commit converges with zero
    # stranded prepared peers (asserts the acceptance shape internally and
    # writes benchmarks/out/chaos_scenarios.json — a CI artifact)
    chaos = emit_chaos_scenarios(fast=True)
    _wan, _p2 = chaos["regions"]["wan"], chaos["partition_2pc"]
    print("smoke_chaos,0.00,"
          f"wan_rule={_wan['switches'][0]['rule']};"
          f"dcn_switches={len(chaos['regions']['dcn']['switches'])};"
          f"stranded={_p2['stranded_prepared']};"
          f"resync_failures={sum(_p2['resync_failures'].values())}")

    # tracing plane: the disabled path must be ~free and the enabled path
    # cheap at batch=64 (gates asserted inside run_tracing_overhead)
    from benchmarks.bench_overhead import run_tracing_overhead

    tr = run_tracing_overhead(batch=64, smoke=True)
    print("smoke_tracing_overhead,0.00,"
          f"enabled_overhead={tr['enabled_overhead']:.3f};"
          f"disabled_guard_frac={tr['disabled_guard_frac']:.5f}")

    # SLO plane: federated metrics drive an error-budget burn-rate alarm
    # that arms the switch BEFORE the raw p95 threshold would (asserts the
    # acceptance shape internally and writes benchmarks/out/slo_scenario.json
    # — a CI artifact)
    from benchmarks.bench_slo import emit_slo_scenario

    slo = emit_slo_scenario(fast=True)
    _g = slo["guard_scenario"]["guard"]
    print("smoke_slo_guard,0.00,"
          f"guard_tick={_g['switch_tick']};"
          f"raw_tick={slo['guard_scenario']['raw']['fired_tick']};"
          f"rank_changed={slo['calibration']['rank_changed']}")

    # regression gate: committed baseline vs this run's artifacts
    from benchmarks.check_regression import check as check_regression

    reg = check_regression()
    print("smoke_regression_gate,0.00,"
          f"checked={len(reg['checks'])};regressions={len(reg['regressions'])}")

    print("# smoke ok on jax compat paths:", file=sys.stderr)
    for line in compat.report().splitlines():
        print(f"#   {line}", file=sys.stderr)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="import-and-sanity dry pass (no full benchmarks)")
    args = ap.parse_args()
    if args.smoke:
        smoke()
        return
    print("name,us_per_call,derived")
    failures = 0
    for mod_name in MODULES:
        t0 = time.time()
        try:
            mod = __import__(mod_name, fromlist=["main"])
            mod.main()
            print(f"# {mod_name} done in {time.time()-t0:.1f}s", file=sys.stderr)
        except Exception as e:
            failures += 1
            print(f"{mod_name}_FAILED,0,{type(e).__name__}: {e}")
            traceback.print_exc(limit=5, file=sys.stderr)
    if failures:
        raise SystemExit(f"{failures} benchmark modules failed")


if __name__ == "__main__":
    main()
