#!/usr/bin/env bash
# One-stop verification entrypoint (CI + pre-PR):
#   1. compat feature report  — fails if the compat layer cannot bind on this JAX
#   2. static lint            — repro.lint --strict: stack verification,
#                               concurrency analysis, compat-boundary + hygiene
#                               over src/repro (docs/architecture.md §7)
#   3. tier-1 test suite      — pyproject pythonpath makes the prefix optional,
#                               but we keep it so the script also works on
#                               pytest < 7 installs
#   4. benchmark smoke pass   — import + mesh/shard_map sanity for the bench
#                               tier, plus the controller-driven reconfigure
#                               scenario (telemetry -> policy -> switch) and
#                               the chaos smoke (WAN-weather region switch +
#                               coordinator crash mid-commit, emitting
#                               benchmarks/out/chaos_scenarios.json) run
#                               headless so the close-the-loop and failure
#                               paths are tier-1
#   5. perf regression gate   — benchmarks/check_regression.py compares this
#                               run's artifacts (dataplane.json, overhead.json)
#                               against the committed benchmarks/baseline.json
#                               and fails on >30% regression, writing
#                               benchmarks/out/regression_report.json
#   6. observability smoke     — repro.obs CLI: KV-switch scenario traced end
#                               to end; asserts the Chrome trace stitches one
#                               causal trace across both endpoints and the
#                               Prometheus export parses
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== repro.compat report =="
python -m repro.compat

echo "== repro.lint (strict) =="
python -m repro.lint --strict --stacks --json benchmarks/out/lint_report.json

echo "== tier-1 tests =="
python -m pytest -q

echo "== benchmark smoke (incl. chaos scenarios) =="
python -m benchmarks.run --smoke

echo "== data-plane throughput smoke =="
# scaled-down batched-vs-per-message sweep; asserts the >=10x batch=64
# speedup and writes benchmarks/out/dataplane.json (a CI artifact)
python -m benchmarks.bench_dataplane --smoke

echo "== perf regression gate (vs benchmarks/baseline.json) =="
# re-run after the full-size dataplane smoke so the gate judges the freshest
# artifacts; fails (exit 1) on >30% regression and writes
# benchmarks/out/regression_report.json for inspection
python -m benchmarks.check_regression

echo "== observability smoke (stitched trace + metrics export) =="
# runs the KV-switch scenario end to end, writes a Chrome trace_event JSON
# and a Prometheus-text export, then re-parses both and asserts ONE stitched
# trace covering controller decision -> negotiation -> 2PC -> swap on both
# endpoints (docs/architecture.md §10)
python -m repro.obs --trace benchmarks/out/kv_switch.trace.json \
  --metrics benchmarks/out/metrics.prom --check

echo "verify.sh: all green"
