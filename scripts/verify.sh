#!/usr/bin/env bash
# One-stop verification entrypoint (CI + pre-PR):
#   1. compat feature report  — fails if the compat layer cannot bind on this JAX
#   2. static lint            — repro.lint --strict: stack verification,
#                               concurrency analysis, compat-boundary + hygiene
#                               over src/repro (docs/architecture.md §7)
#   3. tier-1 test suite      — pyproject pythonpath makes the prefix optional,
#                               but we keep it so the script also works on
#                               pytest < 7 installs
#   4. benchmark smoke pass   — import + mesh/shard_map sanity for the bench
#                               tier, plus the controller-driven reconfigure
#                               scenario (telemetry -> policy -> switch) and
#                               the chaos smoke (WAN-weather region switch +
#                               coordinator crash mid-commit, emitting
#                               benchmarks/out/chaos_scenarios.json) run
#                               headless so the close-the-loop and failure
#                               paths are tier-1
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== repro.compat report =="
python -m repro.compat

echo "== repro.lint (strict) =="
python -m repro.lint --strict --stacks --json benchmarks/out/lint_report.json

echo "== tier-1 tests =="
python -m pytest -q

echo "== benchmark smoke (incl. chaos scenarios) =="
python -m benchmarks.run --smoke

echo "== data-plane throughput smoke =="
# scaled-down batched-vs-per-message sweep; asserts the >=10x batch=64
# speedup and writes benchmarks/out/dataplane.json (a CI artifact)
python -m benchmarks.bench_dataplane --smoke

echo "verify.sh: all green"
