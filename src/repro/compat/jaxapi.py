"""Version-portable JAX API shim (the only place allowed to touch
version-gated JAX symbols).

Bound once at import from the probes in :mod:`repro.compat.versions`:

  ``AxisType``             enum with ``Auto``/``Explicit``/``Manual`` members
  ``make_mesh``            ``jax.make_mesh`` incl. ``axis_types=`` everywhere
  ``get_abstract_mesh``    ambient mesh or None (alias ``current_mesh``)
  ``axis_is_auto``         axis-type query without private attributes
  ``axis_size``            mesh axis size for Mesh and AbstractMesh alike
  ``shard_map``            0.6-style ``check_vma=``/``axis_names=`` signature
  ``set_mesh``/``use_mesh``  ambient-mesh management (see meshctx)
  ``tree_map``             ``jax.tree.map`` / ``jax.tree_map``

On 0.4.x, axis types are *advisory*: they are tracked in a side table so
``axis_is_auto`` answers consistently, but the partitioner treats every
axis as Auto (which matches 0.4.x semantics — everything is
auto-partitioned).
"""
from __future__ import annotations

import enum
import logging
from typing import Mapping, Optional, Sequence

import jax

from repro.compat import meshctx
from repro.compat.meshctx import current_mesh, set_mesh, use_mesh  # noqa: F401
from repro.compat.versions import has

log = logging.getLogger(__name__)

__all__ = [
    "AUTO",
    "AxisType",
    "EXPLICIT",
    "MANUAL",
    "make_mesh",
    "get_abstract_mesh",
    "current_mesh",
    "axis_is_auto",
    "axis_size",
    "cost_analysis",
    "manual_axes_in_scope",
    "named_axis_size",
    "shard_map",
    "set_mesh",
    "use_mesh",
    "tree_map",
    "bound_paths",
]


# ---------------------------------------------------------------------------
# AxisType
# ---------------------------------------------------------------------------

if has("axis_type"):
    AxisType = jax.sharding.AxisType
else:

    class AxisType(enum.Enum):
        """Stand-in for ``jax.sharding.AxisType`` on JAX < 0.5.

        Members mirror the native enum by *name*, which is what every
        comparison in this module uses, so meshes built with either enum
        behave identically under ``axis_is_auto``.
        """

        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


# Member aliases for consumers: the acceptance rule for this layer is that
# no file outside repro/compat spells a version-gated symbol name, so
# callers write `axis_types=(compat.AUTO,) * n` rather than naming the enum.
AUTO = AxisType.Auto
EXPLICIT = getattr(AxisType, "Explicit", None) or getattr(AxisType, "User")
MANUAL = getattr(AxisType, "Manual", None) or getattr(AxisType, "Collective")


def _type_name(t) -> str:
    return str(getattr(t, "name", t)).lower()


def _is_auto_type(t) -> bool:
    return _type_name(t) == "auto"


# ---------------------------------------------------------------------------
# make_mesh
# ---------------------------------------------------------------------------


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str], *,
              axis_types: Optional[Sequence] = None, devices=None):
    """``jax.make_mesh`` that accepts ``axis_types`` on every supported JAX.

    ``axis_types`` entries may be ``compat.AxisType`` or the native enum;
    they are forwarded to JAX when the installed version enforces them and
    recorded in the compat side table otherwise (advisory on 0.4.x).
    """
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if axis_types is not None and has("make_mesh_axis_types"):
        native = jax.sharding.AxisType
        kwargs["axis_types"] = tuple(
            t if isinstance(t, native) else getattr(native, str(getattr(t, "name", t)))
            for t in axis_types)
    if has("make_mesh"):
        mesh = jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)
    else:  # < 0.4.35
        from jax.experimental import mesh_utils

        devs = mesh_utils.create_device_mesh(
            tuple(axis_shapes), devices=kwargs.get("devices"))
        mesh = jax.sharding.Mesh(devs, tuple(axis_names))
    if axis_types is not None:
        meshctx.record_axis_types(
            mesh, dict(zip(axis_names, axis_types)))
    return mesh


# ---------------------------------------------------------------------------
# ambient mesh / axis-type queries
# ---------------------------------------------------------------------------


def get_abstract_mesh():
    """The ambient mesh, or None when no mesh context is active.

    Unlike native ``jax.sharding.get_abstract_mesh`` (which returns an
    *empty* AbstractMesh), this returns None so callers can write
    ``if mesh is None`` on every JAX version.
    """
    return current_mesh()


_probe_warned = False


def _axis_type_of(mesh, name: str):
    """Best-effort axis type for ``mesh``'s axis ``name`` (None = unknown)."""
    rec = meshctx.recorded_axis_types(mesh)
    if rec is not None and name in rec:
        return rec[name]
    n2t = getattr(mesh, "_name_to_type", None)
    if isinstance(n2t, Mapping) and name in n2t:
        return n2t[name]
    at = getattr(mesh, "axis_types", None)
    if isinstance(at, Mapping):  # 0.4.x-internal layout: {type: axis-or-axes}
        for t, axes in at.items():
            axes = (axes,) if isinstance(axes, str) else tuple(axes or ())
            if name in axes:
                return t
    elif at is not None:  # >= 0.5 layout: tuple aligned with axis_names
        mapping = dict(zip(getattr(mesh, "axis_names", ()), at))
        if name in mapping:
            return mapping[name]
    return None


def manual_axes_in_scope() -> frozenset:
    """Mesh axes currently under manual (shard_map/pmap) control at trace time.

    On >= 0.5 the abstract mesh itself reports manual axes via axis types,
    so this only needs the trace-state probe on the legacy path.
    """
    if has("get_abstract_mesh"):
        return frozenset()
    try:
        from jax._src import core as jcore

        return frozenset(jcore.get_axis_env().axis_names())
    except Exception as e:
        _warn_probe_once("axis-env", e)
        return frozenset()


def _warn_probe_once(what: str, e: Exception) -> None:
    global _probe_warned
    if not _probe_warned:
        _probe_warned = True
        log.debug("compat %s probe failed (%s); treating axes as Auto "
                  "from here on", what, e)


def axis_is_auto(mesh, name: str) -> bool:
    """True when ``mesh``'s axis ``name`` is auto-partitioned (or the mesh
    cannot say — unknown axes default to Auto, matching 0.4.x semantics).
    Axes bound as named axes at trace time (inside shard_map) report False,
    matching the Manual axis type >= 0.5 assigns them.

    Replaces ad-hoc ``mesh._name_to_type`` probes wrapped in silent
    ``except Exception`` blocks: a failed probe is logged once at DEBUG
    instead of swallowed, so mis-sharding stays diagnosable.
    """
    if mesh is None:
        return True
    if name in manual_axes_in_scope():
        return False
    try:
        t = _axis_type_of(mesh, name)
    except Exception as e:
        _warn_probe_once("axis-type", e)
        return True
    return True if t is None else _is_auto_type(t)


def axis_size(mesh, name: str) -> int:
    """Size of a named mesh axis, for physical Mesh and AbstractMesh alike."""
    shape = getattr(mesh, "shape", None)
    if isinstance(shape, Mapping):
        return int(shape[name])
    return int(dict(zip(mesh.axis_names, mesh.axis_sizes))[name])


def cost_analysis(compiled) -> Mapping:
    """XLA cost analysis of a ``Compiled`` as a flat dict on every JAX.

    0.4.x returns a one-element *list* of dicts (per program); >= 0.5
    returns the dict directly.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        return cost[0] if cost else {}
    return cost


def named_axis_size(name: str) -> int:
    """``jax.lax.axis_size`` (>= 0.6) for code running inside shard_map.

    On older JAX, ``psum(1, name)`` of a Python constant is evaluated
    statically, so the result is usable for Python-level loop bounds in
    both implementations.
    """
    if has("lax_axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)


# ---------------------------------------------------------------------------
# shard_map
# ---------------------------------------------------------------------------

if has("shard_map"):
    _shard_map_impl = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _shard_map_impl

try:
    import inspect as _inspect

    _SHARD_MAP_PARAMS = frozenset(
        _inspect.signature(_shard_map_impl).parameters)
except (TypeError, ValueError):  # pragma: no cover - exotic builds
    _SHARD_MAP_PARAMS = frozenset({"mesh", "in_specs", "out_specs"})


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True,
              axis_names=None):
    """0.6-style ``jax.shard_map`` on every supported JAX.

    ``axis_names`` is the set of axes under manual control (None = all of
    them) and ``check_vma`` maps to legacy ``check_rep``. On JAX without
    native ``axis_names`` support the region runs FULLY manual — the
    un-named axes are not left to the auto partitioner (see the comment
    below for why); results are unchanged, partitioned compute on the
    un-named axes is not.
    """
    kwargs = {"mesh": mesh, "in_specs": in_specs, "out_specs": out_specs}
    if "check_vma" in _SHARD_MAP_PARAMS:
        kwargs["check_vma"] = check_vma
    elif "check_rep" in _SHARD_MAP_PARAMS:
        kwargs["check_rep"] = check_vma
    if axis_names is not None and "axis_names" in _SHARD_MAP_PARAMS:
        kwargs["axis_names"] = set(axis_names)
    # On the legacy (`auto=`) generation we deliberately do NOT request
    # partial-auto: 0.4.x's SPMD partitioner hard-aborts (CHECK failures in
    # spmd_partitioner.cc / hlo_sharding_util.cc) on collective-permute and
    # all-gather inside a partial-auto region. Running fully manual instead
    # is numerically identical — inputs along the un-named axes are
    # replicated by the given in_specs — at the cost of replicated compute
    # on those axes (the documented 0.4.x degradation).
    return _shard_map_impl(f, **kwargs)


# ---------------------------------------------------------------------------
# tree utilities
# ---------------------------------------------------------------------------

if has("tree_module"):
    tree_map = jax.tree.map
else:  # pragma: no cover - ancient JAX
    tree_map = jax.tree_map


def bound_paths() -> dict:
    """Which implementation each shim entry point is bound to (for report())."""
    return {
        "AxisType": "native jax.sharding.AxisType" if has("axis_type")
        else "legacy compat enum (advisory)",
        "make_mesh": "native axis_types=" if has("make_mesh_axis_types")
        else ("jax.make_mesh + side table" if has("make_mesh")
              else "mesh_utils.create_device_mesh + side table"),
        "get_abstract_mesh": "native jax.sharding.get_abstract_mesh"
        if has("get_abstract_mesh") else "legacy tracked mesh context",
        "set_mesh": "native jax.set_mesh" if has("set_mesh")
        else ("jax.sharding.use_mesh (persistent)" if has("use_mesh")
              else "legacy `with mesh:` (persistent)"),
        "use_mesh": "native jax.sharding.use_mesh" if has("use_mesh")
        else "legacy `with mesh:`",
        "shard_map": ("jax.shard_map" if has("shard_map")
                      else "jax.experimental.shard_map")
        + (" (check_vma/axis_names)" if "check_vma" in _SHARD_MAP_PARAMS
           else " (check_rep; fully manual — partial-auto unsafe here)"),
        "named_axis_size": "jax.lax.axis_size" if has("lax_axis_size")
        else "static psum(1, axis)",
        "tree_map": "jax.tree.map" if has("tree_module") else "jax.tree_map",
    }
