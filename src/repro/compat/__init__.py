"""JAX version-compatibility layer (supported: 0.4.x floor 0.4.37 → 0.6.x).

One probed-once adaptation layer (see PAPERS.md: Morpheus; online code
specialization) so the rest of the stack never touches a version-gated
JAX symbol. Everything mesh-, axis-type- or shard_map-shaped goes
through here:

    from repro import compat
    mesh = compat.make_mesh((2, 4), ("data", "model"),
                            axis_types=(compat.AxisType.Auto,) * 2)
    compat.set_mesh(mesh)
    f = compat.shard_map(fn, mesh=mesh, in_specs=..., out_specs=...,
                         check_vma=False, axis_names={"data"})

``python -m repro.compat`` prints the feature-detection report.
"""
from repro.compat.jaxapi import (  # noqa: F401
    AUTO,
    EXPLICIT,
    MANUAL,
    AxisType,
    axis_is_auto,
    axis_size,
    cost_analysis,
    current_mesh,
    get_abstract_mesh,
    make_mesh,
    manual_axes_in_scope,
    named_axis_size,
    set_mesh,
    shard_map,
    tree_map,
    use_mesh,
)
from repro.compat.versions import (  # noqa: F401
    JAX_VERSION,
    features,
    has,
    jax_at_least,
    report,
)

__all__ = [
    "AUTO",
    "EXPLICIT",
    "MANUAL",
    "AxisType",
    "axis_is_auto",
    "axis_size",
    "cost_analysis",
    "current_mesh",
    "get_abstract_mesh",
    "make_mesh",
    "manual_axes_in_scope",
    "named_axis_size",
    "set_mesh",
    "shard_map",
    "tree_map",
    "use_mesh",
    "JAX_VERSION",
    "features",
    "has",
    "jax_at_least",
    "report",
]
