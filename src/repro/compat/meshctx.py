"""Mesh-context tracking that works on every supported JAX.

On JAX >= 0.5 the library itself tracks the active mesh
(``jax.sharding.use_mesh`` / ``jax.set_mesh`` + ``get_abstract_mesh``).
On 0.4.x there is no abstract-mesh context, so this module keeps the
process-wide active mesh itself: ``set_mesh``/``use_mesh`` enter the
physical ``with mesh:`` context (which is what makes bare-PartitionSpec
``with_sharding_constraint`` work under jit on 0.4.x) and record the
mesh so :func:`current_mesh` can answer without private attributes.

It also owns the axis-type side table: on JAX versions whose ``Mesh``
cannot carry axis types, ``compat.make_mesh`` records the requested
types here and ``compat.axis_is_auto`` consults the table, so consumers
never reach into ``mesh._name_to_type``.
"""
from __future__ import annotations

import contextlib
import threading
import weakref
from typing import Dict, Optional

import jax

from repro.compat.versions import has

# ---------------------------------------------------------------------------
# axis-type side table
# ---------------------------------------------------------------------------

# id(mesh) -> {axis_name: AxisType-like}, purged by a weakref finalizer when
# the mesh dies. Keyed by identity, not the mesh itself: Mesh hashes/compares
# by value, so value-equal meshes would alias one entry and a WeakKeyDictionary
# would drop a live mesh's record when an equal, earlier mesh is collected.
_AXIS_TYPES: Dict[int, Dict[str, object]] = {}
_AXIS_TYPES_LOCK = threading.Lock()


def _purge_axis_types(key: int) -> None:
    with _AXIS_TYPES_LOCK:
        _AXIS_TYPES.pop(key, None)


def record_axis_types(mesh, mapping: Dict[str, object]) -> None:
    try:
        weakref.finalize(mesh, _purge_axis_types, id(mesh))
    except TypeError:  # un-weakref-able mesh stand-ins
        return
    with _AXIS_TYPES_LOCK:
        _AXIS_TYPES[id(mesh)] = dict(mapping)


def recorded_axis_types(mesh) -> Optional[Dict[str, object]]:
    with _AXIS_TYPES_LOCK:
        return _AXIS_TYPES.get(id(mesh))


# ---------------------------------------------------------------------------
# active-mesh tracking (0.4.x path) / delegation (>= 0.5 path)
# ---------------------------------------------------------------------------

_local = threading.local()


def _tracked() -> list:
    if not hasattr(_local, "stack"):
        _local.stack = []
    return _local.stack


# persistent context entered by set_mesh on the legacy path; closed and
# replaced when set_mesh is called again (tests re-set the mesh per module)
_persistent: Optional[contextlib.ExitStack] = None
_persistent_mesh = None


def set_mesh(mesh):
    """Make ``mesh`` the process's ambient mesh (compat ``jax.set_mesh``).

    Returns the mesh so launchers can write ``mesh = compat.set_mesh(m)``.
    """
    global _persistent, _persistent_mesh
    if has("set_mesh"):
        jax.set_mesh(mesh)
        _persistent_mesh = mesh
        return mesh
    if _persistent is not None:
        _persistent.close()
        _persistent = None
        _persistent_mesh = None
    es = contextlib.ExitStack()
    if has("use_mesh"):
        es.enter_context(jax.sharding.use_mesh(mesh))
    else:
        es.enter_context(mesh)  # 0.4.x: thread_resources mesh context
    _persistent = es
    _persistent_mesh = mesh
    return mesh


@contextlib.contextmanager
def use_mesh(mesh):
    """Scoped version of :func:`set_mesh` (compat ``jax.sharding.use_mesh``)."""
    if has("use_mesh"):
        with jax.sharding.use_mesh(mesh):
            yield mesh
        return
    _tracked().append(mesh)
    try:
        with mesh:
            yield mesh
    finally:
        _tracked().pop()


def current_mesh():
    """The ambient mesh, or None.

    On >= 0.5 this is the library's abstract mesh; on 0.4.x it is whatever
    physical mesh ``set_mesh``/``use_mesh``/``with mesh:`` made current.
    The result always answers ``axis_names`` and sizes (see
    ``compat.axis_size``); treat it as read-only.
    """
    if has("get_abstract_mesh"):
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is not None and getattr(mesh, "axis_names", ()):
            return mesh
        return None
    stack = _tracked()
    if stack:
        return stack[-1]
    if _persistent_mesh is not None:
        return _persistent_mesh
    if has("thread_resources"):
        try:
            from jax._src import mesh as mesh_lib

            phys = mesh_lib.thread_resources.env.physical_mesh
            if phys is not None and not phys.empty:
                return phys
        except Exception:
            pass
    return None
