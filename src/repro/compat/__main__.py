"""``python -m repro.compat`` — print the environment/feature report."""
from repro.compat import report

if __name__ == "__main__":
    print(report())
