"""Feature detection for the installed JAX (probed once, cached).

The reconfiguration thesis applies to our own substrate: instead of
sprinkling ``try/except AttributeError`` at every call site, the
environment is probed once at import and the right implementation is
bound (Morpheus-style runtime specialization). Everything outside
``repro.compat`` talks to the shim in :mod:`repro.compat.jaxapi`;
this module only answers "what does the installed JAX support?".

Supported range: JAX 0.4.x (floor 0.4.37) through 0.6.x. On 0.4.x the
explicit axis-type machinery does not exist, so axis types recorded via
``compat.make_mesh`` are advisory (tracked in a side table) rather than
enforced by the partitioner.
"""
from __future__ import annotations

import inspect
from typing import Callable, Dict

import jax


def _parse_version(v: str) -> tuple:
    parts = []
    for p in v.split("."):
        digits = ""
        for ch in p:
            if ch.isdigit():
                digits += ch
            else:
                break
        if not digits:
            break
        parts.append(int(digits))
    return tuple(parts) or (0,)


JAX_VERSION: tuple = _parse_version(jax.__version__)


def jax_at_least(v: str) -> bool:
    """True when the installed JAX is at least version ``v`` ("0.5", "0.4.37")."""
    return JAX_VERSION >= _parse_version(v)


def _sig_has(fn, param: str) -> bool:
    try:
        return param in inspect.signature(fn).parameters
    except (TypeError, ValueError):
        return False


def _probe_internal_axis_types() -> bool:
    try:
        from jax._src import mesh as mesh_lib  # noqa: F401

        return hasattr(mesh_lib, "AxisTypes")
    except Exception:
        return False


def _probe_thread_resources() -> bool:
    try:
        from jax._src import mesh as mesh_lib

        return hasattr(mesh_lib, "thread_resources")
    except Exception:
        return False


#: name -> zero-arg probe. Each answers one capability question; results are
#: cached in _RESULTS so the environment is only inspected once per process.
_PROBES: Dict[str, Callable[[], bool]] = {
    # public axis-type machinery (jax.sharding.AxisType, >= 0.5/0.6)
    "axis_type": lambda: hasattr(jax.sharding, "AxisType"),
    # jax.make_mesh exists at top level (>= 0.4.35)
    "make_mesh": lambda: hasattr(jax, "make_mesh"),
    # jax.make_mesh accepts axis_types= (>= 0.5)
    "make_mesh_axis_types": lambda: hasattr(jax, "make_mesh")
    and _sig_has(jax.make_mesh, "axis_types"),
    # jax.sharding.get_abstract_mesh (>= 0.5)
    "get_abstract_mesh": lambda: hasattr(jax.sharding, "get_abstract_mesh"),
    # jax.set_mesh (>= 0.6) / jax.sharding.use_mesh (>= 0.5)
    "set_mesh": lambda: hasattr(jax, "set_mesh"),
    "use_mesh": lambda: hasattr(jax.sharding, "use_mesh"),
    # top-level jax.shard_map (>= 0.5.3); kwarg generations within it
    "shard_map": lambda: hasattr(jax, "shard_map"),
    "shard_map_check_vma": lambda: hasattr(jax, "shard_map")
    and _sig_has(jax.shard_map, "check_vma"),
    "shard_map_axis_names": lambda: hasattr(jax, "shard_map")
    and _sig_has(jax.shard_map, "axis_names"),
    # jax.lax.axis_size (>= 0.6); older JAX uses static psum(1, axis)
    "lax_axis_size": lambda: hasattr(jax.lax, "axis_size"),
    # jax.tree.map namespace (>= 0.4.25; jax.tree_map removed in 0.6)
    "tree_module": lambda: hasattr(jax, "tree") and hasattr(jax.tree, "map"),
    # 0.4.x-internal axis-type enum / mesh context plumbing (fallback paths)
    "internal_axis_types": _probe_internal_axis_types,
    "thread_resources": _probe_thread_resources,
}

_RESULTS: Dict[str, bool] = {}


def has(feature: str) -> bool:
    """Cached feature probe, e.g. ``has("axis_types")`` / ``has("set_mesh")``."""
    # accept the plural alias used in docs/issues
    if feature == "axis_types":
        feature = "axis_type"
    if feature not in _PROBES:
        raise KeyError(f"unknown compat feature {feature!r}; "
                       f"known: {sorted(_PROBES)}")
    if feature not in _RESULTS:
        try:
            _RESULTS[feature] = bool(_PROBES[feature]())
        except Exception:
            _RESULTS[feature] = False
    return _RESULTS[feature]


def features() -> Dict[str, bool]:
    return {name: has(name) for name in _PROBES}


def report() -> str:
    """Human-readable account of the probed environment and bound code paths."""
    from repro.compat import jaxapi  # late import: jaxapi imports this module

    lines = [
        f"repro.compat: JAX {jax.__version__} "
        f"(parsed {'.'.join(map(str, JAX_VERSION))}, "
        f"backend={jax.default_backend()}, devices={jax.device_count()})",
        "feature probes:",
    ]
    for name, ok in sorted(features().items()):
        lines.append(f"  {'+' if ok else '-'} {name}")
    lines.append("bound code paths:")
    for api, path in sorted(jaxapi.bound_paths().items()):
        lines.append(f"  {api}: {path}")
    return "\n".join(lines)
