"""Pure-jnp oracle for the int8 block-quantization kernel."""
from __future__ import annotations

import jax.numpy as jnp


def quantize_blocks_ref(x2d: jnp.ndarray, *, block: int = 256):
    amax = jnp.max(jnp.abs(x2d), axis=1)
    scales = jnp.where(amax > 0, amax / 127.0, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(x2d / scales[:, None]), -127, 127).astype(jnp.int8)
    return q, scales


def dequantize_blocks_ref(q: jnp.ndarray, scales: jnp.ndarray, *, block: int = 256):
    return q.astype(jnp.float32) * scales[:, None]
