"""jit'd public wrappers matching repro.comm.compress's interface."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.quantize.quantize import dequantize_blocks, quantize_blocks

# interpret=True executes the kernel body on CPU (validation); on TPU deploys
# the compiled Mosaic kernel.
INTERPRET = jax.default_backend() != "tpu"


def quantize_int8(x: jnp.ndarray, *, block: int = 256):
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % block
    flat = jnp.pad(flat, (0, pad)).reshape(-1, block)
    return quantize_blocks(flat, block=block, interpret=INTERPRET)


def dequantize_int8(q: jnp.ndarray, scales: jnp.ndarray, shape, *, block: int = 256):
    n = 1
    for s in shape:
        n *= s
    out = dequantize_blocks(q, scales, block=block, interpret=INTERPRET)
    return out.reshape(-1)[:n].reshape(shape)
