"""Pallas TPU kernel: int8 block quantization (the compressed-wire hot spot).

The gradient-compression chunnel quantizes the full gradient vector every step
— O(N_params) elementwise work that sits on the critical path right before the
DCN collective. The kernel tiles rows of blocks into VMEM, computes per-block
amax/scale on the VPU, and writes int8 + fp32 scales.

Tiling: input reshaped to (n_blocks, block); grid over row tiles of
ROWS_PER_TILE blocks so each tile is ROWS x block fp32 = 128KB in VMEM
(well under the ~16MB budget, leaving room for double buffering).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS_PER_TILE = 128


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...]  # (ROWS, block) fp32
    amax = jnp.max(jnp.abs(x), axis=1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale[:, None]), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale.astype(jnp.float32)


def _dequant_kernel(q_ref, s_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)
    o_ref[...] = q * s_ref[...][:, None]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def quantize_blocks(x2d: jnp.ndarray, *, block: int = 256, interpret: bool = True):
    """x2d: (n_blocks, block) fp32 -> (q int8, scales fp32)."""
    n = x2d.shape[0]
    rows = min(ROWS_PER_TILE, n)
    pad = (-n) % rows
    if pad:
        x2d = jnp.pad(x2d, ((0, pad), (0, 0)))
    grid = (x2d.shape[0] // rows,)
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rows, block), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((rows, block), lambda i: (i, 0)),
            pl.BlockSpec((rows,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x2d.shape, jnp.int8),
            jax.ShapeDtypeStruct((x2d.shape[0],), jnp.float32),
        ],
        interpret=interpret,
    )(x2d)
    return q[:n], s[:n]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def dequantize_blocks(q: jnp.ndarray, scales: jnp.ndarray, *, block: int = 256,
                      interpret: bool = True):
    n = q.shape[0]
    rows = min(ROWS_PER_TILE, n)
    pad = (-n) % rows
    if pad:
        q = jnp.pad(q, ((0, pad), (0, 0)))
        scales = jnp.pad(scales, (0, pad))
    grid = (q.shape[0] // rows,)
    out = pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows, block), lambda i: (i, 0)),
            pl.BlockSpec((rows,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((rows, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, jnp.float32),
        interpret=interpret,
    )(q, scales)
    return out[:n]
