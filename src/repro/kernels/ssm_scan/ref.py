"""Pure-jnp oracle: the associative-scan chunk from repro/models/ssm.py."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.ssm import _scan_chunk


def ssm_scan_chunk_ref(a, bx, h0):
    """a, bx: (B, C, d_in, N); h0: (B, d_in, N) -> (h_seq, h_last)."""
    a_t = a.transpose(1, 0, 2, 3)
    bx_t = bx.transpose(1, 0, 2, 3)
    h_all, h_last = _scan_chunk(a_t.astype(jnp.float32), bx_t.astype(jnp.float32),
                                h0.astype(jnp.float32))
    return h_all.transpose(1, 0, 2, 3), h_last
