"""Pallas TPU kernel: chunked selective-scan (mamba/hymba hot spot).

The SSM recurrence h_t = a_t * h_{t-1} + bx_t is sequential in t but fully
parallel over the (d_in, N) state lanes — a natural TPU shape: iterate t on
the scalar core, vectorize (d_in x N) tiles on the VPU, keep the running
state h in VMEM scratch for the whole chunk (no HBM round-trips per step).

Grid: (B, n_d_tiles); each program instance scans its (chunk, D_TILE, N)
slab serially in t. VMEM: a/bx slabs 2 * chunk*D_TILE*N*4B (chunk=64,
D_TILE=256, N=16 -> 4 MB) + h scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

D_TILE = 256


def _scan_kernel(a_ref, bx_ref, h0_ref, hseq_ref, hlast_ref, h_sc, *, chunk):
    h_sc[...] = h0_ref[0]

    def step(t, _):
        h = a_ref[0, t] * h_sc[...] + bx_ref[0, t]
        h_sc[...] = h
        hseq_ref[0, t] = h
        return 0

    jax.lax.fori_loop(0, chunk, step, 0)
    hlast_ref[0] = h_sc[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssm_scan_chunk(a: jnp.ndarray, bx: jnp.ndarray, h0: jnp.ndarray, *,
                   interpret: bool = True):
    """One chunk of h_t = a_t h_{t-1} + bx_t.

    a, bx: (B, C, d_in, N) fp32; h0: (B, d_in, N).
    Returns (h_seq (B, C, d_in, N), h_last (B, d_in, N)).
    """
    B, C, d_in, N = a.shape
    tile = min(D_TILE, d_in)
    pad = (-d_in) % tile
    if pad:
        padded = lambda x: jnp.pad(x, ((0, 0),) * (x.ndim - 2) + ((0, pad), (0, 0)),
                                   constant_values=1.0 if x is a else 0.0)
        a = jnp.pad(a, ((0, 0), (0, 0), (0, pad), (0, 0)), constant_values=1.0)
        bx = jnp.pad(bx, ((0, 0), (0, 0), (0, pad), (0, 0)))
        h0 = jnp.pad(h0, ((0, 0), (0, pad), (0, 0)))
    d_p = a.shape[2]
    grid = (B, d_p // tile)
    kernel = functools.partial(_scan_kernel, chunk=C)
    h_seq, h_last = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, C, tile, N), lambda b, d: (b, 0, d, 0)),
            pl.BlockSpec((1, C, tile, N), lambda b, d: (b, 0, d, 0)),
            pl.BlockSpec((1, tile, N), lambda b, d: (b, d, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, C, tile, N), lambda b, d: (b, 0, d, 0)),
            pl.BlockSpec((1, tile, N), lambda b, d: (b, d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(a.shape, jnp.float32),
            jax.ShapeDtypeStruct(h0.shape, jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((tile, N), jnp.float32)],
        interpret=interpret,
    )(a.astype(jnp.float32), bx.astype(jnp.float32), h0.astype(jnp.float32))
    if pad:
        h_seq = h_seq[:, :, :d_in]
        h_last = h_last[:, :d_in]
    return h_seq, h_last
