"""jit'd public wrapper; interpret on CPU, compiled Mosaic on TPU."""
from __future__ import annotations

import jax

from repro.kernels.ssm_scan.ssm_scan import ssm_scan_chunk as _scan

INTERPRET = jax.default_backend() != "tpu"


def ssm_scan_chunk(a, bx, h0):
    return _scan(a, bx, h0, interpret=INTERPRET)
