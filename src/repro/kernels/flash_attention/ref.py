"""Pure-jnp oracle for the flash-attention kernel (dense masked softmax)."""
from __future__ import annotations

from repro.models.attention import attention_dense


def flash_attention_ref(q, k, v, *, causal=True, window=None):
    return attention_dense(q, k, v, causal=causal, window=window)
