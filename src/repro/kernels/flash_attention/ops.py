"""jit'd public wrapper; interpret on CPU, compiled Mosaic on TPU."""
from __future__ import annotations

import jax

from repro.kernels.flash_attention.flash_attention import flash_attention as _fa

INTERPRET = jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal=True, window=None, block_q=128, block_k=128):
    # interpret-mode block sizes shrink automatically for tiny test shapes
    bq = min(block_q, q.shape[1]) if q.shape[1] >= 8 else q.shape[1]
    bk = min(block_k, k.shape[1]) if k.shape[1] >= 8 else k.shape[1]
    return _fa(q, k, v, causal=causal, window=window, block_q=bq, block_k=bk,
               interpret=INTERPRET)
