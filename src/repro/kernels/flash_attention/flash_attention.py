"""Pallas TPU flash attention (blockwise online softmax, GQA via index maps).

Motivation (DESIGN.md / §Perf): the pure-jnp chunked attention computes the
full S x S masked score matrix (2x the causal-optimal FLOPs) and streams
scores through HBM. This kernel keeps the (block_q x block_k) score tile in
VMEM, skips strictly-upper causal tiles entirely, and accumulates in fp32
VMEM scratch.

Grid: (B, H, n_q, n_kv) with the kv dimension innermost (sequential
revisiting of the same output block). GQA is handled in the K/V BlockSpec
index maps (kv_head = q_head // group) — no materialized head expansion.

Block sizes default to (128, 128): MXU-aligned; the VMEM working set
(q,k,v tiles + fp32 score tile + fp32 acc) is ~0.5 MB, leaving headroom for
double buffering within the ~16 MB budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *, scale, causal,
               window, block_q, block_k, n_kv, seq_kv):
    i = pl.program_id(2)  # q block
    j = pl.program_id(3)  # kv block

    @pl.when(j == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q_start = i * block_q
    k_start = j * block_k
    # Tiles strictly above the causal diagonal contribute nothing.
    run = (k_start <= q_start + block_q - 1) if causal else True
    if window is not None:
        run = jnp.logical_and(run, q_start - (k_start + block_k - 1) < window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (block_q, hd)
        k = k_ref[0, 0].astype(jnp.float32)  # (block_k, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = (q @ k.T) * scale  # (block_q, block_k)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        ok = kpos < seq_kv
        if causal:
            ok &= kpos <= qpos
        if window is not None:
            ok &= qpos - kpos < window
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(ok, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_sc[...] = l_sc[...] * corr + jnp.sum(p, axis=1)
        acc_sc[...] = acc_sc[...] * corr[:, None] + p @ v
        m_sc[...] = m_new

    @pl.when(j == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_sc[...], 1e-20)
        o_ref[0, 0] = (acc_sc[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jnp.ndarray,  # (B, Sq, H, hd)
    k: jnp.ndarray,  # (B, Skv, KH, hd)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window=None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
):
    B, Sq, H, hd = q.shape
    Skv, KH = k.shape[1], k.shape[2]
    assert H % KH == 0, (H, KH)
    group = H // KH
    scale = hd**-0.5

    pad_q = (-Sq) % block_q
    pad_k = (-Skv) % block_k
    qt = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0))).transpose(0, 2, 1, 3)
    kt = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0))).transpose(0, 2, 1, 3)
    vt = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0))).transpose(0, 2, 1, 3)
    n_q = qt.shape[2] // block_q
    n_kv = kt.shape[2] // block_k

    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, n_kv=n_kv, seq_kv=Skv)

    out = pl.pallas_call(
        kernel,
        grid=(B, H, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, i, j: (b, h, i, 0)),
            # GQA: the kv head index is derived from the q head in the index map
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, i, j, g=group: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(qt.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)[:, :Sq]
