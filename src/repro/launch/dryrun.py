import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every (architecture x input shape) cell, lower + compile the real step
function (train_step for train shapes, serve_step for prefill/decode) on the
single-pod 16x16 mesh AND the 2x16x16 multi-pod mesh, print
memory_analysis()/cost_analysis(), and record the roofline terms
(EXPERIMENTS.md §Dry-run / §Roofline read from the JSON this writes).

  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro import compat
from repro.analysis import roofline
from repro.comm.chunnels import make_transport
from repro.configs import ARCH_IDS, SHAPES, get_config, get_shape, shape_applicable
from repro.configs.base import ShardingConfig, TrainConfig
from repro.launch.mesh import make_production_mesh
from repro.models.registry import build
from repro.models.sharding import kv_partition_mode
from repro.serving import steps as serve_steps
from repro.train import step as train_step_mod


def input_specs(arch: str, shape_name: str):
    """ShapeDtypeStruct stand-ins for every model input of the cell."""
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    model = build(cfg)
    specs = model.batch_specs(shape)
    if shape.kind == "decode":
        specs = {"batch": specs, "cache": model.cache_specs(shape)}
    return specs


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               transport: str = "xla", moe_dispatch: str | None = None,
               attn_chunk: int | None = None, remat: str | None = None,
               kv_partition: str = "auto"):
    """Lower + compile one cell; returns the result record."""
    cfg = get_config(arch)
    if moe_dispatch and cfg.moe:
        import dataclasses
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, dispatch=moe_dispatch))
    if attn_chunk:
        cfg = cfg.replace(attn_chunk=attn_chunk)
    if remat:
        cfg = cfg.replace(remat=remat)
    shape = get_shape(shape_name)
    ok, skip_reason = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "skipped": True, "reason": skip_reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    compat.set_mesh(mesh)  # enables trace-time activation sharding constraints
    sh = ShardingConfig(pod_transport=transport, kv_partition=kv_partition)
    t0 = time.time()

    if shape.kind == "train":
        chunnels = () if transport == "xla" or not multi_pod else (
            make_transport(transport, **(
                {"fast_axis": "data", "slow_axis": "pod"}
                if transport in ("hierarchical", "hier_compressed") else {"axis": "pod"})),
        )
        model = build(cfg, mesh=mesh)
        tcfg = TrainConfig()
        # donation: the production configuration — the output state aliases
        # the input state buffers, so memory_analysis reflects the real step
        jitted = train_step_mod.jit_train_step(
            model, tcfg, chunnels, mesh, sh, model.batch_specs(shape),
            donate=True)
        state = train_step_mod.state_shapes(model, chunnels, tcfg)
        lowered = jitted.lower(state, model.batch_specs(shape))
    elif shape.kind == "prefill":
        model = build(cfg, mesh=mesh)
        jitted = serve_steps.jit_prefill(model, mesh, sh, model.batch_specs(shape))
        lowered = jitted.lower(model.param_shapes(), model.batch_specs(shape))
    else:  # decode
        attn_fn = None
        if kv_partition_mode(cfg, mesh, sh) == "sequence" and cfg.family not in ("ssm",):
            from repro.comm.kvshard import make_seq_sharded_decode
            attn_fn = make_seq_sharded_decode(mesh, "model")
        model = build(cfg, mesh=mesh, decode_attn_fn=attn_fn)
        cache = model.cache_specs(shape)
        jitted = serve_steps.jit_decode(model, mesh, sh, model.batch_specs(shape),
                                        cache, donate_cache=False)
        lowered = jitted.lower(model.param_shapes(), cache, model.batch_specs(shape))

    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compat.cost_analysis(compiled)
    hlo = compiled.as_text()
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    rf = roofline.analyze(hlo, cfg, shape, mesh_shape)

    per_dev_bytes = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                     + mem.generated_code_size_in_bytes
                     + max(0, mem.output_size_in_bytes - mem.alias_size_in_bytes))
    rec = {
        "arch": arch,
        "shape": shape_name,
        "kind": shape.kind,
        "multi_pod": multi_pod,
        "mesh": mesh_shape,
        "transport": transport,
        "kv_partition": (kv_partition_mode(cfg, mesh, sh)
                         if shape.kind == "decode" else None),
        "moe_dispatch": cfg.moe.dispatch if cfg.moe else None,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "per_device_total": per_dev_bytes,
            "fits_16GB": bool(per_dev_bytes < 16e9),
        },
        "cost_analysis": {k: float(v) for k, v in cost.items()
                          if k in ("flops", "bytes accessed")},
        "roofline": rf.to_dict(),
        "skipped": False,
    }
    return rec


def cell_id(rec) -> str:
    pod = "2pod" if rec["multi_pod"] else "1pod"
    return f"{rec['arch']}__{rec['shape']}__{pod}"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true", help="2x16x16 mesh only")
    ap.add_argument("--single-pod", action="store_true", help="16x16 mesh only")
    ap.add_argument("--transport", default="xla")
    ap.add_argument("--moe-dispatch", default=None)
    ap.add_argument("--attn-chunk", type=int, default=None)
    ap.add_argument("--remat", default=None)
    ap.add_argument("--kv-partition", default="auto")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)

    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    pods = [False, True]
    if args.multi_pod:
        pods = [True]
    if args.single_pod:
        pods = [False]

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in pods:
                t0 = time.time()
                try:
                    rec = lower_cell(arch, shape, multi_pod=mp,
                                     transport=args.transport,
                                     moe_dispatch=args.moe_dispatch,
                                     attn_chunk=args.attn_chunk,
                                     remat=args.remat,
                                     kv_partition=args.kv_partition)
                except Exception as e:  # a failure here is a bug in the system
                    n_fail += 1
                    rec = {"arch": arch, "shape": shape, "multi_pod": mp,
                           "skipped": False, "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-2000:]}
                    print(f"FAIL {arch} {shape} mp={mp}: {e}")
                tag = f"__{args.tag}" if args.tag else ""
                fn = out / f"{cell_id(rec) if 'mesh' in rec or 'reason' in rec or True else ''}{tag}.json"
                fn = out / (cell_id(rec) + tag + ".json")
                fn.write_text(json.dumps(rec, indent=1))
                status = ("SKIP" if rec.get("skipped") else
                          ("ERR " if "error" in rec else "OK  "))
                extra = ""
                if not rec.get("skipped") and "roofline" in rec:
                    r = rec["roofline"]
                    extra = (f" dom={r['dominant']} comp={r['compute_s']:.3e}s "
                             f"mem={r['memory_s']:.3e}s coll={r['collective_s']:.3e}s "
                             f"fits={rec['memory']['fits_16GB']}")
                    print(f"{status} {arch:24s} {shape:12s} {'2pod' if mp else '1pod'} "
                          f"({time.time()-t0:5.1f}s){extra}")
                    if not rec.get("skipped") and "memory" in rec:
                        print(f"     memory_analysis: {rec['memory']}")
                        print(f"     cost_analysis:   {rec['cost_analysis']}")
                else:
                    print(f"{status} {arch:24s} {shape:12s} {'2pod' if mp else '1pod'} "
                          f"({time.time()-t0:5.1f}s) {rec.get('reason', rec.get('error', ''))[:90]}")
    if n_fail:
        raise SystemExit(f"{n_fail} cells failed")


if __name__ == "__main__":
    main()
