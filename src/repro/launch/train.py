"""Training launcher: negotiate the step stack, train, checkpoint, reconfigure.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --steps 50 \\
      --smoke --transport xla --ckpt /tmp/ckpt

On the CPU container use --smoke (reduced config). On a real cluster the same
entrypoint runs per host; the rendezvous store is where hosts agree on the
stack before compiling (SPMD safety, DESIGN.md §2).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np
from repro import compat

from repro.configs import get_config, get_smoke_config
from repro.configs.base import ShapeConfig, ShardingConfig, TrainConfig
from repro.data.synthetic import batches_for
from repro.launch.mesh import make_production_mesh, make_test_mesh
from repro.train.trainer import HostSpec, ReconfigurableTrainer, StragglerPolicy


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--transport", default="xla")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--mesh", default="none", choices=("none", "test", "single", "multi"))
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    mesh = {
        "none": lambda: make_test_mesh((1, 1)),
        "test": make_test_mesh,
        "single": make_production_mesh,
        "multi": lambda: make_production_mesh(multi_pod=True),
    }[args.mesh]()
    compat.set_mesh(mesh)

    trainer = ReconfigurableTrainer(
        cfg, shape, mesh, tcfg=TrainConfig(warmup_steps=10, total_steps=args.steps),
        transport=args.transport, ckpt_dir=args.ckpt,
        hosts=[HostSpec(0, [args.transport, "xla"])],
    )
    gen = batches_for(cfg, shape)
    state = trainer.init_state(jax.random.PRNGKey(0))
    if args.resume and args.ckpt:
        state, at = trainer.restore()
        print(f"resumed from step {at}")

    t0 = time.time()
    state, hist = trainer.run(state, gen, args.steps,
                              ckpt_every=args.ckpt_every)
    dt = time.time() - t0
    losses = [h["loss"] for h in hist]
    print(f"arch={cfg.name} transport={trainer.transport_name} steps={len(hist)} "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({dt/max(len(hist),1)*1e3:.0f} ms/step)")
    assert np.isfinite(losses[-1])
    if trainer.reconfig_log:
        print("reconfigurations:", trainer.reconfig_log)


if __name__ == "__main__":
    main()
