"""Production mesh builders (assignment-mandated shapes).

Functions, not module constants, so importing never touches jax device state.
"""
from __future__ import annotations

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes,
                            axis_types=(compat.AUTO,) * len(axes))


def make_test_mesh(shape=(2, 4), axes=("data", "model")):
    return compat.make_mesh(shape, axes,
                            axis_types=(compat.AUTO,) * len(axes))


# TPU v5e hardware model used by the roofline analysis (per chip).
HW = {
    "peak_flops_bf16": 197e12,  # FLOP/s
    "hbm_bw": 819e9,  # B/s
    "ici_link_bw": 50e9,  # B/s per link (~ ICI)
    "ici_links": 4,  # torus links per chip usable for a collective
    "dcn_bw": 25e9,  # B/s per chip across pods (DCN tier)
}
