"""Serving launcher: prefill + batched decode with the KV-partition chunnel.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \\
      --batch 4 --prompt-len 64 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from repro import compat

from repro.configs import get_config, get_smoke_config
from repro.launch.mesh import make_test_mesh
from repro.models import build


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_test_mesh((1, 1))
    compat.set_mesh(mesh)
    model = build(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)

    B, S = args.batch, args.prompt_len
    batch = {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        f = cfg.frontend
        batch["patches"] = jax.random.normal(rng, (B, f.num_positions, f.embed_dim),
                                             jnp.bfloat16)
    if cfg.family == "audio":
        src = max(1, S // cfg.encdec.src_ratio)
        batch["frames"] = jax.random.normal(rng, (B, src, cfg.frontend.embed_dim),
                                            jnp.bfloat16)

    t0 = time.time()
    cache, logits = jax.jit(model.prefill)(params, batch)
    jax.block_until_ready(logits)
    t_pre = time.time() - t0

    # grow caches for generation
    def grow(leaf):
        if hasattr(leaf, "ndim") and leaf.ndim >= 4:
            pad = [(0, 0)] * leaf.ndim
            pad[-3] = (0, args.gen + 1)
            return jnp.pad(leaf, pad)
        return leaf

    if cfg.family in ("dense", "moe", "vlm", "audio"):
        cache = jax.tree.map(grow, cache)
    if cfg.family == "hybrid":
        for i in cfg.global_layers:
            for n in ("k", "v"):
                cache["layers"][i][n] = jnp.pad(
                    cache["layers"][i][n], ((0, 0), (0, args.gen + 1), (0, 0), (0, 0)))

    decode = jax.jit(model.decode)
    toks = jnp.argmax(logits, -1)[:, None]
    out = [toks]
    t0 = time.time()
    for _ in range(args.gen):
        cache, logits = decode(params, cache, {"tokens": toks})
        toks = jnp.argmax(logits, -1)[:, None]
        out.append(toks)
    jax.block_until_ready(logits)
    t_dec = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    assert bool(jnp.all(jnp.isfinite(logits)))
    print(f"arch={cfg.name} prefill({B}x{S})={t_pre*1e3:.0f}ms "
          f"decode={t_dec/args.gen*1e3:.1f}ms/tok "
          f"first row: {np.asarray(gen[0])[:10]}")


if __name__ == "__main__":
    main()
