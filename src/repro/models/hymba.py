"""Hymba: hybrid-head LM — parallel attention + SSM branches per layer
(arXiv:2411.13676), SWA(window) everywhere except 3 global layers.

Uniform per-layer param structure (attn + ssm + mlp), so training scans layer
segments; decode unrolls layers (heterogeneous caches: ring-buffer KV for SWA
layers, full KV for global layers, SSM state for every layer).
Sub-quadratic => long_500k runs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import layers as L
from repro.models import pshard
from repro.models import ssm
from repro.models import transformer as T
from repro.models.stacking import apply_stack, make_segments, stacked_init


def hymba_layer_init(rng, cfg: ModelConfig):
    r = jax.random.split(rng, 3)
    return {
        "ln1": L.norm_init(cfg.d_model, cfg.norm),
        "attn": T.attn_block_init(r[0], cfg),
        "ssm": ssm.ssm_init(r[1], cfg.d_model, cfg.ssm),
        "gn_attn": L.norm_init(cfg.d_model, cfg.norm),
        "gn_ssm": L.norm_init(cfg.d_model, cfg.norm),
        "ln2": L.norm_init(cfg.d_model, cfg.norm),
        "mlp": L.mlp_init(r[2], cfg.d_model, cfg.d_ff),
    }


def init_params(rng, cfg: ModelConfig):
    return T.init_params(rng, cfg, layer_init=hymba_layer_init)


def hymba_layer(p, x, cfg: ModelConfig, positions, *, window=None):
    """Parallel attention + SSM branches; normalized-mean fusion (hymba §2)."""
    xn = L.apply_norm(p["ln1"], x, eps=cfg.norm_eps)
    a = T.attn_block(p["attn"], xn, cfg, positions, window=window)
    s, _ = ssm.ssm_apply(p["ssm"], xn, cfg.ssm)
    fused = 0.5 * (
        L.apply_norm(p["gn_attn"], a, eps=cfg.norm_eps)
        + L.apply_norm(p["gn_ssm"], s, eps=cfg.norm_eps)
    )
    h = x + fused
    return pshard.shard_activations(
        h + L.mlp(p["mlp"], L.apply_norm(p["ln2"], h, eps=cfg.norm_eps), act=cfg.act))


def segments(cfg: ModelConfig):
    return make_segments(
        cfg.num_layers,
        cfg.global_layers,
        special_kw={"window": None},
        default_kw={"window": cfg.sliding_window},
    )


def hidden_states(params, tokens, cfg: ModelConfig):
    x = pshard.shard_activations(L.embed(params["embed"], tokens))
    positions = jnp.arange(tokens.shape[1])

    def body(p, h, **kw):
        return hymba_layer(p, h, cfg, positions, **kw)

    x = apply_stack(
        params["layers"], x, body,
        segments=segments(cfg), num_layers=cfg.num_layers,
        scan=cfg.scan_layers, remat=cfg.remat,
    )
    return L.apply_norm(params["final_norm"], x, eps=cfg.norm_eps)


def loss_fn(params, batch, cfg: ModelConfig, *, loss_chunk=None):
    h = hidden_states(params, batch["tokens"], cfg)
    chunk = loss_chunk if loss_chunk is not None else cfg.loss_chunk
    return L.chunked_lm_loss(h, T.head_weight(params, cfg), batch["labels"], chunk=chunk,
                             real_vocab=cfg.vocab_size)


# ---------------------------------------------------------------------------
# Serving — heterogeneous caches, unrolled layers
# ---------------------------------------------------------------------------


def _kv_capacity(idx: int, cfg: ModelConfig, seq_cap: int) -> int:
    if idx in cfg.global_layers:
        return seq_cap
    return min(cfg.sliding_window, seq_cap)


def init_cache(cfg: ModelConfig, batch: int, capacity: int, dtype=jnp.bfloat16):
    hd = cfg.head_dim_
    layers = []
    for idx in range(cfg.num_layers):
        cap = _kv_capacity(idx, cfg, capacity)
        st = ssm.init_state(batch, cfg.d_model, cfg.ssm)
        layers.append({
            "k": jnp.zeros((batch, cap, cfg.num_kv_heads, hd), dtype),
            "v": jnp.zeros((batch, cap, cfg.num_kv_heads, hd), dtype),
            "ssm_h": st.h,
            "ssm_conv": st.conv,
        })
    return {"layers": layers, "len": jnp.zeros((), jnp.int32)}


def cache_specs(cfg: ModelConfig, batch: int, capacity: int, dtype=jnp.bfloat16):
    return jax.eval_shape(lambda: init_cache(cfg, batch, capacity, dtype))


def _decode_layer(p, x, cache_l, cfg: ModelConfig, pos, *, is_global: bool, attn_fn=None):
    B = x.shape[0]
    xn = L.apply_norm(p["ln1"], x, eps=cfg.norm_eps)
    positions = pos + jnp.arange(1)
    q, k, v = T.qkv(p["attn"], xn, cfg, positions)
    cap = cache_l["k"].shape[1]
    write = pos if is_global else pos % cap  # ring buffer for SWA layers
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        cache_l["k"], k.astype(cache_l["k"].dtype), write, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        cache_l["v"], v.astype(cache_l["v"].dtype), write, axis=1)
    n_valid = jnp.minimum(pos + 1, cap)
    if attn_fn is not None and is_global:
        o = attn_fn(q, k_cache, v_cache, n_valid, None)
    else:
        o = attn.decode_attention_local(q, k_cache, v_cache, n_valid)
    a = L.linear(p["attn"]["wo"], o.reshape(B, 1, -1))
    s, st_new = ssm.ssm_decode(
        p["ssm"], xn, cfg.ssm, ssm.SSMState(h=cache_l["ssm_h"], conv=cache_l["ssm_conv"])
    )
    fused = 0.5 * (
        L.apply_norm(p["gn_attn"], a, eps=cfg.norm_eps)
        + L.apply_norm(p["gn_ssm"], s, eps=cfg.norm_eps)
    )
    h = x + fused
    h = h + L.mlp(p["mlp"], L.apply_norm(p["ln2"], h, eps=cfg.norm_eps), act=cfg.act)
    new_cache = {"k": k_cache, "v": v_cache, "ssm_h": st_new.h, "ssm_conv": st_new.conv}
    return h, new_cache


def decode_step(params, cache, batch, cfg: ModelConfig, *, attn_fn=None):
    pos = cache["len"]
    x = L.embed(params["embed"], batch["tokens"])
    new_layers = []
    for idx in range(cfg.num_layers):
        p_l = jax.tree.map(lambda a: a[idx], params["layers"])
        x, c_new = _decode_layer(
            p_l, x, cache["layers"][idx], cfg, pos,
            is_global=idx in cfg.global_layers, attn_fn=attn_fn,
        )
        new_layers.append(c_new)
    x = L.apply_norm(params["final_norm"], x, eps=cfg.norm_eps)
    logits = L.mask_padded_vocab(
        x[:, -1] @ T.head_weight(params, cfg).astype(x.dtype), cfg.vocab_size)
    return {"layers": new_layers, "len": pos + 1}, logits


def prefill(params, batch, cfg: ModelConfig):
    """Prompt processing: full hidden states + caches for continuation."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = L.embed(params["embed"], tokens)
    positions = jnp.arange(S)
    new_layers = []
    for idx in range(cfg.num_layers):
        p_l = jax.tree.map(lambda a: a[idx], params["layers"])
        is_global = idx in cfg.global_layers
        window = None if is_global else cfg.sliding_window
        xn = L.apply_norm(p_l["ln1"], x, eps=cfg.norm_eps)
        q, k, v = T.qkv(p_l["attn"], xn, cfg, positions)
        o = attn.attention(q, k, v, impl=cfg.attn_impl, causal=True, window=window,
                           chunk=cfg.attn_chunk)
        a = L.linear(p_l["attn"]["wo"], o.reshape(B, S, -1))
        s, st_new = ssm.ssm_apply(p_l["ssm"], xn, cfg.ssm)
        fused = 0.5 * (
            L.apply_norm(p_l["gn_attn"], a, eps=cfg.norm_eps)
            + L.apply_norm(p_l["gn_ssm"], s, eps=cfg.norm_eps)
        )
        x = x + fused
        x = x + L.mlp(p_l["mlp"], L.apply_norm(p_l["ln2"], x, eps=cfg.norm_eps), act=cfg.act)
        cap = _kv_capacity(idx, cfg, S)
        kk, vv = k.astype(jnp.bfloat16), v.astype(jnp.bfloat16)
        if not is_global and S > cap:
            # Keep the last `window` entries, ring-aligned so slot = pos % cap.
            keep_start = S - cap
            kk, vv = kk[:, keep_start:], vv[:, keep_start:]
            # kk[i] holds position S-cap+i; slot j must hold position with
            # pos % cap == j  =>  out[j] = kk[(j - (S-cap)) % cap]
            roll = (S - cap) % cap
            kk = jnp.roll(kk, roll, axis=1)
            vv = jnp.roll(vv, roll, axis=1)
        new_layers.append({
            "k": kk, "v": vv, "ssm_h": st_new.h, "ssm_conv": st_new.conv,
        })
    x = L.apply_norm(params["final_norm"], x, eps=cfg.norm_eps)
    logits = L.mask_padded_vocab(
        x[:, -1] @ T.head_weight(params, cfg).astype(x.dtype), cfg.vocab_size)
    return {"layers": new_layers, "len": jnp.asarray(S, jnp.int32)}, logits
