"""Encoder-decoder transformer (seamless-m4t family).

The audio frontend is a STUB per the assignment: the batch carries precomputed
frame embeddings (B, S_src, D) — input_specs() provides them — standing in for
the conv feature extractor. Encoder is bidirectional; decoder is causal with
cross-attention. Decode caches both self-KV (growing) and cross-KV (static).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import layers as L
from repro.models import pshard
from repro.models import transformer as T
from repro.models.stacking import apply_stack, apply_stack_with_cache, stacked_init


def enc_layer_init(rng, cfg: ModelConfig):
    r1, r2 = jax.random.split(rng)
    return {
        "ln1": L.norm_init(cfg.d_model, cfg.norm),
        "attn": T.attn_block_init(r1, cfg),
        "ln2": L.norm_init(cfg.d_model, cfg.norm),
        "mlp": L.mlp_init(r2, cfg.d_model, cfg.d_ff),
    }


def dec_layer_init(rng, cfg: ModelConfig):
    r1, r2, r3 = jax.random.split(rng, 3)
    return {
        "ln1": L.norm_init(cfg.d_model, cfg.norm),
        "self_attn": T.attn_block_init(r1, cfg),
        "lnx": L.norm_init(cfg.d_model, cfg.norm),
        "cross_attn": T.attn_block_init(r2, cfg),
        "ln2": L.norm_init(cfg.d_model, cfg.norm),
        "mlp": L.mlp_init(r3, cfg.d_model, cfg.d_ff),
    }


def init_params(rng, cfg: ModelConfig):
    r_emb, r_enc, r_dec, r_head, r_src = jax.random.split(rng, 5)
    e = cfg.encdec
    return {
        "embed": L.embedding_init(r_emb, cfg.vocab_padded, cfg.d_model),
        "src_proj": L.linear_init(r_src, cfg.frontend.embed_dim, cfg.d_model),
        "encoder": stacked_init(enc_layer_init, r_enc, e.enc_layers, cfg),
        "enc_norm": L.norm_init(cfg.d_model, cfg.norm),
        "decoder": stacked_init(dec_layer_init, r_dec, e.dec_layers, cfg),
        "final_norm": L.norm_init(cfg.d_model, cfg.norm),
        "lm_head": L.linear_init(r_head, cfg.d_model, cfg.vocab_padded),
    }


def encode(params, frames, cfg: ModelConfig):
    """frames: (B, S_src, E) stub embeddings -> encoder output (B, S_src, D)."""
    x = L.linear(params["src_proj"], frames)
    positions = jnp.arange(frames.shape[1])

    def body(p, h):
        hn = L.apply_norm(p["ln1"], h, eps=cfg.norm_eps)
        q, k, v = T.qkv(p["attn"], hn, cfg, positions)
        o = attn.attention(q, k, v, impl=cfg.attn_impl, causal=False, chunk=cfg.attn_chunk)
        h = h + L.linear(p["attn"]["wo"], o.reshape(h.shape[0], h.shape[1], -1))
        return pshard.shard_activations(
            h + L.mlp(p["mlp"], L.apply_norm(p["ln2"], h, eps=cfg.norm_eps), act=cfg.act))

    x = apply_stack(params["encoder"], x, lambda p, h: body(p, h),
                    num_layers=cfg.encdec.enc_layers, scan=cfg.scan_layers, remat=cfg.remat)
    return L.apply_norm(params["enc_norm"], x, eps=cfg.norm_eps)


def _cross_kv(p, enc_out, cfg: ModelConfig):
    B, Ss, _ = enc_out.shape
    hd = cfg.head_dim_
    k = L.linear(p["wk"], enc_out).reshape(B, Ss, cfg.num_kv_heads, hd)
    v = L.linear(p["wv"], enc_out).reshape(B, Ss, cfg.num_kv_heads, hd)
    return k, v  # no rope on cross-attention


def _cross_attend(p, h, k, v, cfg: ModelConfig):
    B, St, _ = h.shape
    hd = cfg.head_dim_
    q = L.linear(p["wq"], h).reshape(B, St, cfg.num_heads, hd)
    o = attn.attention(q, k, v, impl=cfg.attn_impl, causal=False, chunk=cfg.attn_chunk)
    return L.linear(p["wo"], o.reshape(B, St, -1))


def decode_states(params, tokens, enc_out, cfg: ModelConfig):
    x = L.embed(params["embed"], tokens)
    positions = jnp.arange(tokens.shape[1])

    def body(p, h):
        hn = L.apply_norm(p["ln1"], h, eps=cfg.norm_eps)
        q, k, v = T.qkv(p["self_attn"], hn, cfg, positions)
        o = attn.attention(q, k, v, impl=cfg.attn_impl, causal=True, chunk=cfg.attn_chunk)
        h = h + L.linear(p["self_attn"]["wo"], o.reshape(h.shape[0], h.shape[1], -1))
        ck, cv = _cross_kv(p["cross_attn"], enc_out, cfg)
        h = h + _cross_attend(p["cross_attn"], L.apply_norm(p["lnx"], h, eps=cfg.norm_eps),
                              ck, cv, cfg)
        return pshard.shard_activations(
            h + L.mlp(p["mlp"], L.apply_norm(p["ln2"], h, eps=cfg.norm_eps), act=cfg.act))

    x = apply_stack(params["decoder"], x, lambda p, h: body(p, h),
                    num_layers=cfg.encdec.dec_layers, scan=cfg.scan_layers, remat=cfg.remat)
    return L.apply_norm(params["final_norm"], x, eps=cfg.norm_eps)


def loss_fn(params, batch, cfg: ModelConfig, *, loss_chunk=None):
    enc_out = encode(params, batch["frames"], cfg)
    h = decode_states(params, batch["tokens"], enc_out, cfg)
    chunk = loss_chunk if loss_chunk is not None else cfg.loss_chunk
    return L.chunked_lm_loss(h, params["lm_head"]["w"], batch["labels"], chunk=chunk,
                             real_vocab=cfg.vocab_size)


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, capacity: int, src_len: int, dtype=jnp.bfloat16):
    hd = cfg.head_dim_
    e = cfg.encdec
    kv = lambda s: jnp.zeros((e.dec_layers, batch, s, cfg.num_kv_heads, hd), dtype)
    return {
        "k": kv(capacity), "v": kv(capacity),
        "xk": kv(src_len), "xv": kv(src_len),
        "len": jnp.zeros((), jnp.int32),
    }


def cache_specs(cfg: ModelConfig, batch: int, capacity: int, src_len: int, dtype=jnp.bfloat16):
    return jax.eval_shape(lambda: init_cache(cfg, batch, capacity, src_len, dtype))


def prefill(params, batch, cfg: ModelConfig):
    """Encoder pass + decoder prompt pass; returns (cache, last logits)."""
    enc_out = encode(params, batch["frames"], cfg)
    tokens = batch["tokens"]
    B, St = tokens.shape
    x = L.embed(params["embed"], tokens)
    positions = jnp.arange(St)

    def body(p, h, cache_l):
        hn = L.apply_norm(p["ln1"], h, eps=cfg.norm_eps)
        q, k, v = T.qkv(p["self_attn"], hn, cfg, positions)
        o = attn.attention(q, k, v, impl=cfg.attn_impl, causal=True, chunk=cfg.attn_chunk)
        h = h + L.linear(p["self_attn"]["wo"], o.reshape(B, St, -1))
        ck, cv = _cross_kv(p["cross_attn"], enc_out, cfg)
        h = h + _cross_attend(p["cross_attn"], L.apply_norm(p["lnx"], h, eps=cfg.norm_eps),
                              ck, cv, cfg)
        h = h + L.mlp(p["mlp"], L.apply_norm(p["ln2"], h, eps=cfg.norm_eps), act=cfg.act)
        return h, {"k": k.astype(jnp.bfloat16), "v": v.astype(jnp.bfloat16),
                   "xk": ck.astype(jnp.bfloat16), "xv": cv.astype(jnp.bfloat16)}

    empty = {n: jnp.zeros((cfg.encdec.dec_layers, 0), jnp.bfloat16) for n in ("k", "v", "xk", "xv")}
    x, cache = apply_stack_with_cache(
        params["decoder"], x, empty, lambda p, h, c: body(p, h, c),
        num_layers=cfg.encdec.dec_layers, scan=cfg.scan_layers, remat="none",
    )
    x = L.apply_norm(params["final_norm"], x, eps=cfg.norm_eps)
    logits = L.mask_padded_vocab(
        x[:, -1] @ params["lm_head"]["w"].astype(x.dtype), cfg.vocab_size)
    return {**cache, "len": jnp.asarray(St, jnp.int32)}, logits


def decode_step(params, cache, batch, cfg: ModelConfig, *, attn_fn=None):
    tokens = batch["tokens"]
    B = tokens.shape[0]
    pos = cache["len"]
    x = L.embed(params["embed"], tokens)
    positions = pos + jnp.arange(1)
    attn_fn = attn_fn or (
        lambda q, kc, vc, n, window: attn.decode_attention_local(q, kc, vc, n, window=window)
    )

    def body(p, h, cache_l):
        hn = L.apply_norm(p["ln1"], h, eps=cfg.norm_eps)
        q, k, v = T.qkv(p["self_attn"], hn, cfg, positions)
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            cache_l["k"], k.astype(cache_l["k"].dtype), pos, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            cache_l["v"], v.astype(cache_l["v"].dtype), pos, axis=1)
        o = attn_fn(q, k_cache, v_cache, pos + 1, None)
        h = h + L.linear(p["self_attn"]["wo"], o.reshape(B, 1, -1))
        # static cross-attention over the cached encoder KV
        hd = cfg.head_dim_
        qx = L.linear(p["cross_attn"]["wq"],
                      L.apply_norm(p["lnx"], h, eps=cfg.norm_eps)).reshape(
                          B, 1, cfg.num_heads, hd)
        ox = attn.decode_attention_local(qx, cache_l["xk"], cache_l["xv"],
                                         cache_l["xk"].shape[1])
        h = h + L.linear(p["cross_attn"]["wo"], ox.reshape(B, 1, -1))
        h = h + L.mlp(p["mlp"], L.apply_norm(p["ln2"], h, eps=cfg.norm_eps), act=cfg.act)
        return h, {"k": k_cache, "v": v_cache, "xk": cache_l["xk"], "xv": cache_l["xv"]}

    x, new_cache = apply_stack_with_cache(
        params["decoder"], x,
        {n: cache[n] for n in ("k", "v", "xk", "xv")},
        lambda p, h, c: body(p, h, c),
        num_layers=cfg.encdec.dec_layers, scan=cfg.scan_layers, remat="none",
    )
    x = L.apply_norm(params["final_norm"], x, eps=cfg.norm_eps)
    logits = L.mask_padded_vocab(
        x[:, -1] @ params["lm_head"]["w"].astype(x.dtype), cfg.vocab_size)
    return {**new_cache, "len": pos + 1}, logits
