"""Attention implementations (a Bertha Select: xla_dense | xla_chunked | pallas).

All variants share one numerics contract, tested against each other:
  q: (B, Sq, H, hd), k/v: (B, Skv, KH, hd), H % KH == 0 (GQA)
  returns (B, Sq, H, hd)

``xla_dense``   materializes (B,H,Sq,Skv) scores — smoke tests / small seqs.
``xla_chunked`` online-softmax scan over KV blocks — the at-scale default; lives
                entirely in jnp so the 512-device dry-run lowers it.
``pallas``      TPU flash-attention kernel (kernels/flash_attention), validated
                against xla_dense in interpret mode; selected on real TPUs.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _mask_bias(qpos, kpos, *, causal: bool, window: Optional[int], kv_len: Optional[int]):
    """Additive mask bias (qlen, klen) in fp32."""
    ok = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        ok &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        ok &= qpos[:, None] - kpos[None, :] < window
    if kv_len is not None:
        ok &= kpos[None, :] < kv_len
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _expand_kv(x: jnp.ndarray, group: int) -> jnp.ndarray:
    """(B, S, KH, hd) -> (B, S, KH*group, hd) by repeating each kv head."""
    if group == 1:
        return x
    return jnp.repeat(x, group, axis=2)


def attention_dense(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset=0,
    kv_len=None,
) -> jnp.ndarray:
    B, Sq, H, hd = q.shape
    KH = k.shape[2]
    k = _expand_kv(k, H // KH)
    v = _expand_kv(v, H // KH)
    scale = hd**-0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    qpos = q_offset + jnp.arange(Sq)
    kpos = jnp.arange(k.shape[1])
    scores = scores + _mask_bias(qpos, kpos, causal=causal, window=window, kv_len=kv_len)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", w, v)


def attention_chunked(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    chunk: int = 1024,
    q_offset=0,
    kv_len=None,
) -> jnp.ndarray:
    """Memory-efficient online-softmax attention: scan over KV chunks.

    Live memory is O(Sq * chunk) per head instead of O(Sq * Skv). The scan body
    computes full (masked) scores for its chunk; causal masking therefore costs
    ~2x the minimal causal FLOPs — the Pallas kernel removes that on TPU
    (see EXPERIMENTS.md §Perf).
    """
    B, Sq, H, hd = q.shape
    Skv, KH = k.shape[1], k.shape[2]
    group = H // KH
    scale = hd**-0.5

    pad = (-Skv) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n = k.shape[1] // chunk
    ks = k.reshape(B, n, chunk, KH, hd).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, n, chunk, KH, hd).transpose(1, 0, 2, 3, 4)
    starts = jnp.arange(n) * chunk

    qpos = q_offset + jnp.arange(Sq)
    qf = q.astype(jnp.bfloat16)
    limit = Skv if kv_len is None else kv_len

    # checkpoint: recompute the (B,H,Sq,chunk) scores in backward instead of
    # stacking them per scan step (flash-attention-style backward).
    @jax.checkpoint
    def body(carry, xs):
        m, l, acc = carry
        k_c, v_c, start = xs
        k_c = _expand_kv(k_c, group)
        v_c = _expand_kv(v_c, group)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, k_c.astype(jnp.bfloat16))
        s = s.astype(jnp.float32) * scale
        kpos = start + jnp.arange(chunk)
        s = s + _mask_bias(qpos, kpos, causal=causal, window=window, kv_len=limit)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhqk,bkhd->bhqd", p.astype(jnp.bfloat16), v_c.astype(jnp.bfloat16))
        acc_new = acc * corr[..., None] + pv.astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    a0 = jnp.zeros((B, H, Sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (ks, vs, starts))
    out = acc / jnp.maximum(l, 1e-20)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def attention(
    q,
    k,
    v,
    *,
    impl: str = "xla_chunked",
    causal: bool = True,
    window: Optional[int] = None,
    chunk: int = 1024,
    q_offset=0,
    kv_len=None,
):
    if impl == "xla_dense":
        return attention_dense(
            q, k, v, causal=causal, window=window, q_offset=q_offset, kv_len=kv_len
        )
    if impl == "xla_chunked":
        return attention_chunked(
            q, k, v, causal=causal, window=window, chunk=chunk, q_offset=q_offset, kv_len=kv_len
        )
    if impl == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops

        return fa_ops.flash_attention(q, k, v, causal=causal, window=window)
    raise ValueError(f"unknown attention impl {impl!r}")


# ---------------------------------------------------------------------------
# Decode attention (single new token against a KV cache)
# ---------------------------------------------------------------------------


def decode_attention_local(
    q: jnp.ndarray,  # (B, 1, H, hd)
    k_cache: jnp.ndarray,  # (B, S, KH, hd)
    v_cache: jnp.ndarray,
    cache_len,  # scalar or (B,) number of valid cache entries
    *,
    window: Optional[int] = None,
) -> jnp.ndarray:
    """Reference decode attention with a fully local cache.

    The production sequence-sharded variant (flash-decode partial-softmax
    combine across the model axis) lives in repro/comm/kvshard.py and is tested
    against this oracle.
    """
    B, _, H, hd = q.shape
    KH = k_cache.shape[2]
    k = _expand_kv(k_cache, H // KH)
    v = _expand_kv(v_cache, H // KH)
    scale = hd**-0.5
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    kpos = jnp.arange(k.shape[1])
    valid = kpos[None, :] < jnp.asarray(cache_len).reshape(-1, 1)
    if window is not None:
        valid &= kpos[None, :] >= jnp.asarray(cache_len).reshape(-1, 1) - window
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", w, v)
