"""Uniform Model API over all assigned families.

    model = build(cfg, mesh=None)
    params = model.init(rng)
    loss   = model.loss(params, batch)
    cache, logits = model.prefill(params, batch)
    cache, logits = model.decode(params, cache, batch)

Batch contents per family (input_specs in launch/dryrun.py mirrors this):
  dense/moe/ssm/hybrid: tokens, labels
  vlm:   + patches (B, P, D) stub CLIP embeddings (first P positions)
  audio: + frames (B, S_src, E) stub conv features; tokens are decoder tokens
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig, ShardingConfig
from repro.models import encdec, hymba, moe, sharding, transformer, xlstm


@dataclass
class Model:
    cfg: ModelConfig
    mesh: Any = None
    decode_attn_fn: Optional[Callable] = None  # KV-partition chunnel slot

    # -- construction -------------------------------------------------------
    def init(self, rng):
        return _family(self.cfg).init(rng, self.cfg)

    def param_shapes(self):
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))

    def param_specs(self, sh: ShardingConfig):
        return sharding.param_specs(self.param_shapes(), sh, self.mesh)

    # -- steps ---------------------------------------------------------------
    def loss(self, params, batch):
        return _family(self.cfg).loss(params, batch, self.cfg, self.mesh)

    def prefill(self, params, batch):
        return _family(self.cfg).prefill(params, batch, self.cfg, self.mesh)

    def decode(self, params, cache, batch):
        return _family(self.cfg).decode(
            params, cache, batch, self.cfg, self.mesh, self.decode_attn_fn
        )

    # -- shapes ---------------------------------------------------------------
    def batch_specs(self, shape: ShapeConfig, *, dtype=jnp.int32):
        return _family(self.cfg).batch_specs(self.cfg, shape)

    def cache_specs(self, shape: ShapeConfig):
        return _family(self.cfg).cache_specs(self.cfg, shape)

    def init_cache(self, batch: int, capacity: int):
        return _family(self.cfg).init_cache(self.cfg, batch, capacity)


@dataclass
class _Family:
    init: Callable
    loss: Callable
    prefill: Callable
    decode: Callable
    batch_specs: Callable
    cache_specs: Callable
    init_cache: Callable


def _tok_specs(cfg, shape, *, decode=False):
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if decode:
        return {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}
    return {
        "tokens": jax.ShapeDtypeStruct((B, S), i32),
        "labels": jax.ShapeDtypeStruct((B, S), i32),
    }


# -- dense ------------------------------------------------------------------

_DENSE = _Family(
    init=lambda rng, cfg: transformer.init_params(rng, cfg),
    loss=lambda p, b, cfg, mesh: transformer.loss_fn(p, b, cfg),
    prefill=lambda p, b, cfg, mesh: transformer.prefill(p, b, cfg),
    decode=lambda p, c, b, cfg, mesh, afn: transformer.decode_step(p, c, b, cfg, attn_fn=afn),
    batch_specs=lambda cfg, shape: _tok_specs(cfg, shape, decode=shape.kind == "decode"),
    cache_specs=lambda cfg, shape: transformer.cache_specs(
        cfg, shape.global_batch, shape.seq_len
    ),
    init_cache=lambda cfg, batch, cap: transformer.init_cache(cfg, batch, cap),
)

# -- moe ----------------------------------------------------------------------

_MOE = _Family(
    init=lambda rng, cfg: moe.init_params(rng, cfg),
    loss=lambda p, b, cfg, mesh: moe.loss_fn(p, b, cfg, mesh=mesh),
    prefill=lambda p, b, cfg, mesh: moe.prefill(p, b, cfg, mesh=mesh),
    decode=lambda p, c, b, cfg, mesh, afn: moe.decode_step(p, c, b, cfg, mesh=mesh, attn_fn=afn),
    batch_specs=_DENSE.batch_specs,
    cache_specs=_DENSE.cache_specs,
    init_cache=_DENSE.init_cache,
)

# -- ssm (xlstm) ---------------------------------------------------------------

_SSM = _Family(
    init=lambda rng, cfg: xlstm.init_params(rng, cfg),
    loss=lambda p, b, cfg, mesh: xlstm.loss_fn(p, b, cfg),
    prefill=lambda p, b, cfg, mesh: xlstm.prefill(p, b, cfg),
    decode=lambda p, c, b, cfg, mesh, afn: xlstm.decode_step(p, c, b, cfg),
    batch_specs=_DENSE.batch_specs,
    cache_specs=lambda cfg, shape: xlstm.state_specs(cfg, shape.global_batch),
    init_cache=lambda cfg, batch, cap: xlstm.init_state(cfg, batch),
)

# -- hybrid (hymba) -------------------------------------------------------------

_HYBRID = _Family(
    init=lambda rng, cfg: hymba.init_params(rng, cfg),
    loss=lambda p, b, cfg, mesh: hymba.loss_fn(p, b, cfg),
    prefill=lambda p, b, cfg, mesh: hymba.prefill(p, b, cfg),
    decode=lambda p, c, b, cfg, mesh, afn: hymba.decode_step(p, c, b, cfg, attn_fn=afn),
    batch_specs=_DENSE.batch_specs,
    cache_specs=lambda cfg, shape: hymba.cache_specs(cfg, shape.global_batch, shape.seq_len),
    init_cache=lambda cfg, batch, cap: hymba.init_cache(cfg, batch, cap),
)

# -- vlm --------------------------------------------------------------------


def _vlm_batch_specs(cfg, shape):
    specs = _tok_specs(cfg, shape, decode=shape.kind == "decode")
    if shape.kind != "decode":
        f = cfg.frontend
        specs["patches"] = jax.ShapeDtypeStruct(
            (shape.global_batch, f.num_positions, f.embed_dim), jnp.bfloat16
        )
    return specs


_VLM = _Family(
    init=_DENSE.init,
    loss=_DENSE.loss,
    prefill=_DENSE.prefill,
    decode=_DENSE.decode,
    batch_specs=_vlm_batch_specs,
    cache_specs=_DENSE.cache_specs,
    init_cache=_DENSE.init_cache,
)

# -- audio (enc-dec) -----------------------------------------------------------


def _audio_batch_specs(cfg, shape):
    e = cfg.encdec
    B, S = shape.global_batch, shape.seq_len
    src = max(1, S // e.src_ratio)
    specs = _tok_specs(cfg, shape, decode=shape.kind == "decode")
    if shape.kind != "decode":
        specs["frames"] = jax.ShapeDtypeStruct((B, src, cfg.frontend.embed_dim), jnp.bfloat16)
    return specs


_AUDIO = _Family(
    init=lambda rng, cfg: encdec.init_params(rng, cfg),
    loss=lambda p, b, cfg, mesh: encdec.loss_fn(p, b, cfg),
    prefill=lambda p, b, cfg, mesh: encdec.prefill(p, b, cfg),
    decode=lambda p, c, b, cfg, mesh, afn: encdec.decode_step(p, c, b, cfg, attn_fn=afn),
    batch_specs=_audio_batch_specs,
    cache_specs=lambda cfg, shape: encdec.cache_specs(
        cfg, shape.global_batch, shape.seq_len,
        max(1, shape.seq_len // cfg.encdec.src_ratio),
    ),
    init_cache=lambda cfg, batch, cap: encdec.init_cache(
        cfg, batch, cap, max(1, cap // cfg.encdec.src_ratio)
    ),
)

_FAMILIES = {
    "dense": _DENSE,
    "moe": _MOE,
    "ssm": _SSM,
    "hybrid": _HYBRID,
    "vlm": _VLM,
    "audio": _AUDIO,
}


def _family(cfg: ModelConfig) -> _Family:
    return _FAMILIES[cfg.family]


def build(cfg: ModelConfig, mesh=None, decode_attn_fn=None) -> Model:
    cfg.validate()
    return Model(cfg=cfg, mesh=mesh, decode_attn_fn=decode_attn_fn)
