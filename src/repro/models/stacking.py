"""Layer stacking: scanned (compile-time compact) or unrolled (roofline probes).

A model is a sequence of *segments*; each segment is a run of identically-shaped
layers scanned together with static per-segment kwargs (e.g. hymba's sliding
window vs global-attention layers). Param pytrees are stacked along a leading
layer axis per segment.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Sequence

import jax
import jax.numpy as jnp


class Segment(NamedTuple):
    start: int
    length: int
    static: dict  # static kwargs for the layer body


def make_segments(num_layers: int, special: Sequence[int], special_kw: dict, default_kw: dict):
    """Split [0, L) into runs of default layers with special layers unrolled."""
    segs: list[Segment] = []
    prev = 0
    for s in sorted(special):
        if s > prev:
            segs.append(Segment(prev, s - prev, dict(default_kw)))
        segs.append(Segment(s, 1, dict(special_kw)))
        prev = s + 1
    if prev < num_layers:
        segs.append(Segment(prev, num_layers - prev, dict(default_kw)))
    return segs


def slice_layers(stacked, start: int, length: int):
    return jax.tree.map(lambda a: jax.lax.slice_in_dim(a, start, start + length, axis=0), stacked)


def _remat(fn: Callable, policy: str) -> Callable:
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    return jax.checkpoint(fn)  # "full"


def apply_stack(
    stacked_params,
    x,
    body: Callable,  # body(layer_params, x, **static) -> x
    *,
    segments: Sequence[Segment] | None = None,
    num_layers: int,
    scan: bool = True,
    remat: str = "full",
    remat_group: int = 1,
    static: dict | None = None,
):
    """Run ``x`` through the layer stack.

    ``scan=True`` uses lax.scan per segment (small HLO, fast 512-device compile);
    ``scan=False`` unrolls — used by the roofline flop probes so per-layer cost
    is visible to XLA cost analysis (scan bodies are counted once).

    ``remat_group=g`` checkpoints every g layers instead of every layer: the
    remat-saved residual stack shrinks g-fold (L/g boundary activations) at no
    extra recompute (each layer is still recomputed exactly once in backward).
    Standard deep-stack memory lever (used for the 88/94-layer archs).
    """
    segments = segments or [Segment(0, num_layers, dict(static or {}))]
    for seg in segments:
        seg_params = slice_layers(stacked_params, seg.start, seg.length)
        g = remat_group if (scan and remat_group > 1 and seg.length % remat_group == 0
                            and seg.length > remat_group) else 1

        def one(p, h, kw=tuple(sorted(seg.static.items()))):
            return body(p, h, **dict(kw))

        if g > 1:
            def grouped(p_g, h):
                for i in range(g):
                    h = one(jax.tree.map(lambda a: a[i], p_g), h)
                return h
            fn = _remat(grouped, remat)
            seg_params = jax.tree.map(
                lambda a: a.reshape((seg.length // g, g) + a.shape[1:]), seg_params)
        else:
            fn = _remat(one, remat)

        if scan and seg.length // g > 1:

            def scan_body(h, p, fn=fn):
                return fn(p, h), None

            x, _ = jax.lax.scan(scan_body, x, seg_params)
        else:
            for i in range(seg.length // g):
                p_i = jax.tree.map(lambda a: a[i], seg_params)
                x = fn(p_i, x)
    return x


def apply_stack_with_cache(
    stacked_params,
    x,
    caches,  # pytree with leading layer axis per leaf
    body: Callable,  # body(layer_params, x, cache, **static) -> (x, new_cache)
    *,
    segments: Sequence[Segment] | None = None,
    num_layers: int,
    scan: bool = True,
    remat: str = "none",
    static: dict | None = None,
):
    """Like apply_stack but threads per-layer cache state (KV caches, SSM state)."""
    segments = segments or [Segment(0, num_layers, dict(static or {}))]
    new_cache_segs = []
    for seg in segments:
        seg_params = slice_layers(stacked_params, seg.start, seg.length)
        seg_cache = slice_layers(caches, seg.start, seg.length)
        fn = _remat(
            lambda p, h, c, kw=tuple(sorted(seg.static.items())): body(p, h, c, **dict(kw)),
            remat,
        )
        if scan and seg.length > 1:

            def scan_body(h, pc, fn=fn):
                p, c = pc
                h, c_new = fn(p, h, c)
                return h, c_new

            x, seg_cache_new = jax.lax.scan(scan_body, x, (seg_params, seg_cache))
        else:
            outs = []
            for i in range(seg.length):
                p_i = jax.tree.map(lambda a: a[i], seg_params)
                c_i = jax.tree.map(lambda a: a[i], seg_cache)
                x, c_new = fn(p_i, x, c_i)
                outs.append(c_new)
            seg_cache_new = jax.tree.map(lambda *ls: jnp.stack(ls, axis=0), *outs)
        new_cache_segs.append(seg_cache_new)
    new_caches = jax.tree.map(lambda *segs: jnp.concatenate(segs, axis=0), *new_cache_segs)
    return x, new_caches


def stacked_init(layer_init: Callable, rng, num_layers: int, *args: Any):
    """vmap a per-layer initializer over split rngs -> stacked params."""
    rngs = jax.random.split(rng, num_layers)
    return jax.vmap(lambda r: layer_init(r, *args))(rngs)
