"""Activation sharding constraints (data-parallel batch pinning).

With FSDP-sharded params (contraction dims over 'data'), the XLA partitioner
may legally choose tensor-parallel-over-data activation layouts (batch
replicated) — catastrophic for memory at global-batch scale. Pinning the batch
dim of activations at layer boundaries forces ZeRO-3 semantics: weights are
all-gathered, activations stay batch-sharded.

Helpers no-op when no mesh context / axes are unavailable (smoke tests run on
one device), and only constrain over AUTO axes (so they compose with the
partial-manual shard_map used by explicit transports).
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

from repro import compat


def _auto_batch_axes():
    mesh = compat.current_mesh()
    if mesh is None or not getattr(mesh, "axis_names", ()):
        return None, ()
    # compat.axis_is_auto logs a failed axis-type probe once at DEBUG
    # instead of silently treating the axis as constrainable.
    axes = tuple(a for a in ("pod", "data")
                 if a in mesh.axis_names and compat.axis_is_auto(mesh, a))
    return mesh, axes


def shard_batch(x, dim: int = 0):
    """Constrain x's dim to be sharded over the (auto) batch axes."""
    mesh, axes = _auto_batch_axes()
    if mesh is None or not axes or x.ndim <= dim:
        return x
    n = 1
    for a in axes:
        n *= compat.axis_size(mesh, a)
    if x.shape[dim] % n != 0 or x.shape[dim] == 0:
        return x
    spec = [None] * x.ndim
    spec[dim] = axes if len(axes) > 1 else axes[0]
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:
        return x


def shard_tree_batch(tree, dim: int = 0):
    return jax.tree.map(lambda x: shard_batch(x, dim), tree)


def shard_activations(x, batch_dim: int = 0, seq_dim: int = 1):
    """Sequence-parallel residual stream (Korthikanti et al.): batch over the
    data axes AND sequence over 'model' at layer boundaries, so remat-saved
    layer inputs are L x (B/dp) x (S/tp) x D instead of TP-replicated in S.
    The partitioner inserts the standard SP all-gather/reduce-scatter pair
    around each layer's TP blocks."""
    mesh, axes = _auto_batch_axes()
    if mesh is None or x.ndim < 3:
        return shard_batch(x, batch_dim) if mesh is not None else x
    spec = [None] * x.ndim
    if axes:
        n = 1
        for a in axes:
            n *= compat.axis_size(mesh, a)
        if x.shape[batch_dim] % n == 0 and x.shape[batch_dim] > 0:
            spec[batch_dim] = axes if len(axes) > 1 else axes[0]
    if "model" in mesh.axis_names:
        is_auto = compat.axis_is_auto(mesh, "model")
        m = compat.axis_size(mesh, "model")
        if is_auto and x.shape[seq_dim] % m == 0 and x.shape[seq_dim] >= m:
            spec[seq_dim] = "model"
    if all(a is None for a in spec):
        return x
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:
        return x


def shard_model_dim(x, dim: int, batch_dim: int = 0):
    """Batch over the data axes; ``dim`` over 'model' when divisible. Used by
    the SSM branch: the time recurrence cannot shard S, but the state channels
    (d_in) are embarrassingly parallel over the model axis."""
    mesh, axes = _auto_batch_axes()
    if mesh is None or x.ndim <= dim:
        return x
    spec = [None] * x.ndim
    if axes:
        n = 1
        for a in axes:
            n *= compat.axis_size(mesh, a)
        if x.shape[batch_dim] % n == 0 and x.shape[batch_dim] > 0:
            spec[batch_dim] = axes if len(axes) > 1 else axes[0]
    if "model" in mesh.axis_names and compat.axis_is_auto(mesh, "model"):
        m = compat.axis_size(mesh, "model")
        if x.shape[dim] % m == 0 and x.shape[dim] >= m:
            spec[dim] = "model"
    if all(a is None for a in spec):
        return x
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:
        return x


def shard_heads(x, batch_dim: int = 0, head_dim: int = 2):
    """Constrain (B, S, H, hd) attention tensors: batch over the data axes,
    heads over 'model' when divisible (GQA kv heads fall back to replicated).
    Pins multi-pod attention layouts the propagator otherwise replicates."""
    mesh, axes = _auto_batch_axes()
    if mesh is None or x.ndim <= head_dim:
        return x
    spec = [None] * x.ndim
    if axes:
        n = 1
        for a in axes:
            n *= compat.axis_size(mesh, a)
        if x.shape[batch_dim] % n == 0 and x.shape[batch_dim] > 0:
            spec[batch_dim] = axes if len(axes) > 1 else axes[0]
    if "model" in mesh.axis_names and compat.axis_is_auto(mesh, "model"):
        m = compat.axis_size(mesh, "model")
        if x.shape[head_dim] % m == 0:
            spec[head_dim] = "model"
    if all(a is None for a in spec):
        return x
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:
        return x
