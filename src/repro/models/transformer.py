"""Dense GQA transformer LM (llama/qwen/mistral/granite families).

Exposes the family-independent Model API used by train/serve/launch:
  init(rng) -> params
  loss(params, batch) -> scalar
  prefill(params, batch) -> (cache, logits_last)
  decode(params, cache, batch) -> (cache, logits)
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import layers as L
from repro.models import pshard
from repro.models.stacking import Segment, apply_stack, apply_stack_with_cache, stacked_init


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def attn_block_init(rng, cfg: ModelConfig):
    r = jax.random.split(rng, 5)
    hd = cfg.head_dim_
    return {
        "wq": L.linear_init(r[0], cfg.d_model, cfg.num_heads * hd, bias=cfg.qkv_bias),
        "wk": L.linear_init(r[1], cfg.d_model, cfg.num_kv_heads * hd, bias=cfg.qkv_bias),
        "wv": L.linear_init(r[2], cfg.d_model, cfg.num_kv_heads * hd, bias=cfg.qkv_bias),
        "wo": L.linear_init(r[3], cfg.num_heads * hd, cfg.d_model),
    }


def dense_layer_init(rng, cfg: ModelConfig):
    r1, r2 = jax.random.split(rng)
    return {
        "ln1": L.norm_init(cfg.d_model, cfg.norm),
        "attn": attn_block_init(r1, cfg),
        "ln2": L.norm_init(cfg.d_model, cfg.norm),
        "mlp": L.mlp_init(r2, cfg.d_model, cfg.d_ff, gated=cfg.mlp_gated),
    }


def init_params(rng, cfg: ModelConfig, layer_init=dense_layer_init):
    r_emb, r_layers, r_head = jax.random.split(rng, 3)
    p = {
        "embed": L.embedding_init(r_emb, cfg.vocab_padded, cfg.d_model),
        "layers": stacked_init(layer_init, r_layers, cfg.num_layers, cfg),
        "final_norm": L.norm_init(cfg.d_model, cfg.norm),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = L.linear_init(r_head, cfg.d_model, cfg.vocab_padded)
    return p


def head_weight(params, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return params["embed"]["table"].T
    return params["lm_head"]["w"]


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def qkv(p, x, cfg: ModelConfig, positions):
    B, S, _ = x.shape
    hd = cfg.head_dim_
    q = L.linear(p["wq"], x).reshape(B, S, cfg.num_heads, hd)
    k = L.linear(p["wk"], x).reshape(B, S, cfg.num_kv_heads, hd)
    v = L.linear(p["wv"], x).reshape(B, S, cfg.num_kv_heads, hd)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    return pshard.shard_heads(q), pshard.shard_heads(k), pshard.shard_heads(v)


def attn_block(p, x, cfg: ModelConfig, positions, *, window=None, impl=None):
    q, k, v = qkv(p, x, cfg, positions)
    o = attn.attention(
        q, k, v, impl=impl or cfg.attn_impl, causal=True, window=window, chunk=cfg.attn_chunk
    )
    B, S = x.shape[:2]
    return L.linear(p["wo"], o.reshape(B, S, -1))


def dense_layer(p, x, cfg: ModelConfig, positions, *, window=None):
    h = x + attn_block(p["attn"], L.apply_norm(p["ln1"], x, eps=cfg.norm_eps), cfg, positions,
                       window=window)
    h = h + L.mlp(p["mlp"], L.apply_norm(p["ln2"], h, eps=cfg.norm_eps), act=cfg.act)
    return h


def hidden_states(params, tokens, cfg: ModelConfig, *, extra_embeds=None):
    """tokens: (B, S) -> final hidden states (B, S, D).

    ``extra_embeds``: optional (B, P, D) frontend embeddings (VLM patches) that
    replace the first P token positions.
    """
    x = L.embed(params["embed"], tokens)
    if extra_embeds is not None:
        P = extra_embeds.shape[1]
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x[:, P:]], axis=1)
    positions = jnp.arange(tokens.shape[1])
    x = pshard.shard_activations(x)

    def body(p, h, **kw):
        return pshard.shard_activations(dense_layer(p, h, cfg, positions, **kw))

    x = apply_stack(
        params["layers"], x, body,
        num_layers=cfg.num_layers, scan=cfg.scan_layers, remat=cfg.remat, remat_group=cfg.remat_group,
        static={"window": cfg.sliding_window},
    )
    return L.apply_norm(params["final_norm"], x, eps=cfg.norm_eps)


def loss_fn(params, batch, cfg: ModelConfig, *, loss_chunk: Optional[int] = None):
    h = hidden_states(params, batch["tokens"], cfg, extra_embeds=batch.get("patches"))
    chunk = loss_chunk if loss_chunk is not None else cfg.loss_chunk
    return L.chunked_lm_loss(h, head_weight(params, cfg), batch["labels"], chunk=chunk,
                             real_vocab=cfg.vocab_size)


# ---------------------------------------------------------------------------
# Serving: prefill + decode with KV cache
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, capacity: int, dtype=jnp.bfloat16):
    hd = cfg.head_dim_
    shape = (cfg.num_layers, batch, capacity, cfg.num_kv_heads, hd)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "len": jnp.zeros((), jnp.int32),
    }


def cache_specs(cfg: ModelConfig, batch: int, capacity: int, dtype=jnp.bfloat16):
    hd = cfg.head_dim_
    shape = (cfg.num_layers, batch, capacity, cfg.num_kv_heads, hd)
    return {
        "k": jax.ShapeDtypeStruct(shape, dtype),
        "v": jax.ShapeDtypeStruct(shape, dtype),
        "len": jax.ShapeDtypeStruct((), jnp.int32),
    }


def prefill(params, batch, cfg: ModelConfig):
    """Process the full prompt; return (cache, last-position logits)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = L.embed(params["embed"], tokens)
    if batch.get("patches") is not None:
        P = batch["patches"].shape[1]
        x = jnp.concatenate([batch["patches"].astype(x.dtype), x[:, P:]], axis=1)
    positions = jnp.arange(S)

    def body(p, h, cache_l, **kw):
        q, k, v = qkv(p["attn"], L.apply_norm(p["ln1"], h, eps=cfg.norm_eps), cfg, positions)
        o = attn.attention(
            q, k, v, impl=cfg.attn_impl, causal=True, chunk=cfg.attn_chunk, **kw
        )
        h = h + L.linear(p["attn"]["wo"], o.reshape(B, S, -1))
        h = h + L.mlp(p["mlp"], L.apply_norm(p["ln2"], h, eps=cfg.norm_eps), act=cfg.act)
        return pshard.shard_activations(h), {"k": k.astype(jnp.bfloat16), "v": v.astype(jnp.bfloat16)}

    empty = {
        "k": jnp.zeros((cfg.num_layers, 0), jnp.bfloat16),  # placeholder, replaced by ys
        "v": jnp.zeros((cfg.num_layers, 0), jnp.bfloat16),
    }
    x, kv_cache = apply_stack_with_cache(
        params["layers"], x, empty, body,
        num_layers=cfg.num_layers, scan=cfg.scan_layers, remat="none",
        static={"window": cfg.sliding_window},
    )
    x = L.apply_norm(params["final_norm"], x, eps=cfg.norm_eps)
    logits = L.mask_padded_vocab(
        x[:, -1] @ head_weight(params, cfg).astype(x.dtype), cfg.vocab_size)
    cache = {"k": kv_cache["k"], "v": kv_cache["v"], "len": jnp.asarray(S, jnp.int32)}
    return cache, logits


def decode_step(params, cache, batch, cfg: ModelConfig, *, attn_fn=None):
    """One-token decode against the KV cache. batch["tokens"]: (B, 1).

    ``attn_fn(q, k_cache, v_cache, kv_len, window)`` is the decode-attention
    chunnel slot: local dense (default) or the sequence-sharded flash-decode
    from repro/comm/kvshard.py.
    """
    tokens = batch["tokens"]
    B = tokens.shape[0]
    pos = cache["len"]
    x = L.embed(params["embed"], tokens)
    positions = pos + jnp.arange(1)
    attn_fn = attn_fn or (
        lambda q, kc, vc, n, window: attn.decode_attention_local(q, kc, vc, n, window=window)
    )

    def body(p, h, cache_l, **kw):
        q, k, v = qkv(p["attn"], L.apply_norm(p["ln1"], h, eps=cfg.norm_eps), cfg, positions)
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            cache_l["k"], k.astype(cache_l["k"].dtype), pos, axis=1
        )
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            cache_l["v"], v.astype(cache_l["v"].dtype), pos, axis=1
        )
        o = attn_fn(q, k_cache, v_cache, pos + 1, kw.get("window"))
        h = h + L.linear(p["attn"]["wo"], o.reshape(B, 1, -1))
        h = h + L.mlp(p["mlp"], L.apply_norm(p["ln2"], h, eps=cfg.norm_eps), act=cfg.act)
        return pshard.shard_batch(h), {"k": k_cache, "v": v_cache}

    x, new_kv = apply_stack_with_cache(
        params["layers"], x, {"k": cache["k"], "v": cache["v"]}, body,
        num_layers=cfg.num_layers, scan=cfg.scan_layers, remat="none",
        static={"window": cfg.sliding_window},
    )
    x = L.apply_norm(params["final_norm"], x, eps=cfg.norm_eps)
    logits = L.mask_padded_vocab(
        x[:, -1] @ head_weight(params, cfg).astype(x.dtype), cfg.vocab_size)
    new_cache = {"k": new_kv["k"], "v": new_kv["v"], "len": pos + 1}
    return new_cache, logits
