"""Parameter/batch/cache PartitionSpec rules for the production mesh.

Layout (see DESIGN.md §4):
  TP   over 'model'  — d_ff / head / vocab / expert dims
  FSDP over 'data'   — the non-TP matrix dim (ZeRO-3), required for 100B+ archs
  DP   over 'pod'    — params replicated; gradient sync is the pod-transport
                       chunnel Select (xla | ring | hierarchical | compressed)

Rules are name-based on the owning parameter, padded with None for any leading
stacking dims, so they apply to scanned (L, ...) stacks, xlstm per-layer dicts,
and MoE (L, E, ...) expert banks alike.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShardingConfig

# param name -> spec for the trailing dims
_COL = ("wq", "wk", "wv", "wz", "wi", "wf", "wo_gate", "src_proj")  # (d_in, out*) -> out over model
_ROW = ("wo", "down", "out_proj")  # (in*, d_out) -> in over model
_GLU_UP = ("gate", "up")


def _pad(spec: tuple, ndim: int, shape: tuple[int, ...] = (), axis_sizes: dict | None = None) -> P:
    full = (None,) * (ndim - len(spec)) + tuple(spec)
    if axis_sizes and shape:
        # pjit rejects in_shardings whose dim isn't divisible by the axis size
        # (e.g. hymba vocab 32001, xlstm per-head biases) or that name an axis
        # absent from the mesh: drop those axes.
        fixed = []
        for dim, ax in zip(shape, full):
            if ax is None:
                fixed.append(None)
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            if any(a not in axis_sizes for a in axes):
                fixed.append(None)
                continue
            n = 1
            for a in axes:
                n *= axis_sizes[a]
            fixed.append(ax if (n > 0 and dim % n == 0) else None)
        full = tuple(fixed)
    return P(*full)


def param_spec(path: tuple[str, ...], shape: tuple[int, ...], sh: ShardingConfig,
               axis_sizes: dict | None = None) -> P:
    def _pad(spec: tuple, ndim: int, _shape=shape, _ax=axis_sizes):  # shadow w/ context
        return globals()["_pad"](spec, ndim, _shape, _ax)

    fsdp = "data" if sh.fsdp else None
    names = [str(k) for k in path]
    ndim = len(shape)
    owner = None
    for n in reversed(names):
        if not n.isdigit() and n not in ("w", "b", "scale", "bias", "table"):
            owner = n
            break
    leaf = names[-1]
    in_moe = "moe" in names

    if leaf == "table" or owner == "embed":
        return _pad(("model", fsdp), ndim)
    if owner == "lm_head":
        return _pad((fsdp, "model"), ndim) if leaf == "w" else _pad(("model",), ndim)
    if owner == "router":
        return _pad((fsdp, None), ndim) if leaf == "w" else _pad((None,), ndim)
    if in_moe and owner in _GLU_UP:  # (E, D, F)
        return _pad(("model", fsdp, None), ndim)
    if in_moe and owner == "down":  # (E, F, D)
        return _pad(("model", None, fsdp), ndim)
    if leaf in ("scale", "bias") or owner in ("r",) or leaf in ("dt_bias", "D", "conv_b"):
        return _pad((), ndim)
    if leaf == "A_log" or owner == "A_log":
        return _pad(("model", None), ndim)
    if leaf == "conv_w" or owner == "conv_w":
        return _pad((None, "model"), ndim)
    if owner in _COL or owner in _GLU_UP or owner in ("in_proj", "x_proj"):
        if leaf == "b":
            return _pad(("model",), ndim)
        return _pad((fsdp, "model"), ndim)
    if owner == "dt_proj":  # (dt_rank, d_in)
        return _pad((None, "model"), ndim) if leaf == "w" else _pad(("model",), ndim)
    if owner in _ROW:
        if leaf == "b":
            return _pad((), ndim)
        return _pad(("model", fsdp), ndim)
    return _pad((), ndim)  # replicate by default (small leaves)


def param_specs(params_shape: Any, sh: ShardingConfig, mesh=None):
    """Map a param pytree (arrays or ShapeDtypeStructs) to PartitionSpecs."""
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape)) if mesh is not None else None
    flat = jax.tree_util.tree_flatten_with_path(params_shape)[0]
    treedef = jax.tree.structure(params_shape)
    specs = []
    for path, leaf in flat:
        keys = tuple(
            getattr(k, "key", getattr(k, "idx", getattr(k, "name", str(k)))) for k in path
        )
        keys = tuple(str(k) for k in keys)
        specs.append(param_spec(keys, leaf.shape, sh, axis_sizes))
    return jax.tree.unflatten(treedef, specs)


# ---------------------------------------------------------------------------
# Batch / cache specs
# ---------------------------------------------------------------------------


def batch_axes(mesh) -> tuple:
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return axes


def data_spec(shape: tuple[int, ...], mesh, *, batch_dim: int = 0) -> P:
    """Shard the batch dim over pod+data when divisible, else replicate."""
    axes = batch_axes(mesh)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    spec = [None] * len(shape)
    if shape[batch_dim] % n == 0 and shape[batch_dim] > 0:
        spec[batch_dim] = axes if len(axes) > 1 else axes[0]
    return P(*spec)


def kv_partition_mode(cfg: ModelConfig, mesh, sh: ShardingConfig) -> str:
    """'heads' when kv heads divide the model axis, else 'sequence'."""
    if sh.kv_partition != "auto":
        return sh.kv_partition
    m = mesh.shape.get("model", 1)
    return "heads" if cfg.num_kv_heads % m == 0 else "sequence"


def cache_spec_for(shape: tuple[int, ...], cfg: ModelConfig, mesh, sh: ShardingConfig) -> P:
    """Spec for a KV-cache leaf shaped (..., B, S, KH, hd)."""
    mode = kv_partition_mode(cfg, mesh, sh)
    axes = batch_axes(mesh)
    b_ax = axes if len(axes) > 1 else (axes[0] if axes else None)
    ndim = len(shape)
    # trailing dims: (B, S, KH, hd)
    n_batch = 1
    for a in axes:
        n_batch *= mesh.shape[a]
    b_spec = b_ax if (shape[ndim - 4] % max(n_batch, 1) == 0) else None
    if mode == "heads":
        spec = (b_spec, None, "model", None)
    else:
        m = mesh.shape.get("model", 1)
        s_ok = shape[ndim - 3] % max(m, 1) == 0
        spec = (b_spec, "model" if s_ok else None, None, None)
    return P(*((None,) * (ndim - 4) + spec))
