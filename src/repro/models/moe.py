"""Mixture-of-Experts transformer (qwen3-moe, dbrx).

The expert-dispatch layer is a Bertha Select between chunnels with different
collective schedules (see repro/comm/moe_dispatch.py for the negotiation side):

  dense      weighted einsum over ALL experts — tiny-config oracle
  grouped    capacity-based gather/scatter dispatch, sharding left to the XLA
             partitioner (paper-faithful "kernel stack" default)
  alltoall   explicit expert-parallel all-to-all over the 'model' axis
  allgather  each rank computes its local experts for all tokens, psum combine

All variants share the routing math and are tested for agreement.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro import compat
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import layers as L
from repro.models import pshard
from repro.models import transformer as T
from repro.models.stacking import apply_stack, apply_stack_with_cache, stacked_init

AUX_LOSS_COEF = 0.01


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def moe_mlp_init(rng, cfg: ModelConfig):
    m = cfg.moe
    r = jax.random.split(rng, 4)
    E, D, F = m.num_experts, cfg.d_model, m.d_ff_expert
    s_in, s_out = D**-0.5, F**-0.5
    return {
        "router": {"w": L.truncated_normal_init(r[0], (D, E), s_in)},
        "gate": L.truncated_normal_init(r[1], (E, D, F), s_in),
        "up": L.truncated_normal_init(r[2], (E, D, F), s_in),
        "down": L.truncated_normal_init(r[3], (E, F, D), s_out),
    }


def moe_layer_init(rng, cfg: ModelConfig):
    r1, r2 = jax.random.split(rng)
    return {
        "ln1": L.norm_init(cfg.d_model, cfg.norm),
        "attn": T.attn_block_init(r1, cfg),
        "ln2": L.norm_init(cfg.d_model, cfg.norm),
        "moe": moe_mlp_init(r2, cfg),
    }


# ---------------------------------------------------------------------------
# Routing (shared by all dispatch chunnels)
# ---------------------------------------------------------------------------


def capacity(num_tokens: int, cfg: ModelConfig) -> int:
    m = cfg.moe
    return max(1, int(math.ceil(num_tokens * m.top_k * m.capacity_factor / m.num_experts)))


def route(router_p, x2d, cfg: ModelConfig):
    """x2d: (T, D). Returns (gates (T,k), expert_ids (T,k) i32, aux_loss)."""
    m = cfg.moe
    logits = x2d.astype(jnp.float32) @ router_p["w"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, m.top_k)
    gate_vals = gate_vals / jnp.maximum(jnp.sum(gate_vals, -1, keepdims=True), 1e-9)
    # Switch-style load-balance loss: E * sum_e fraction_e * router_prob_e
    onehot = jax.nn.one_hot(expert_ids[:, 0], m.num_experts, dtype=jnp.float32)
    frac = jnp.mean(onehot, axis=0)
    aux = m.num_experts * jnp.sum(frac * jnp.mean(probs, axis=0)) * AUX_LOSS_COEF
    return gate_vals, expert_ids, aux


def _positions_in_expert(expert_ids, E: int, C: int):
    """Capacity assignment. expert_ids: (T, k) -> pos (T, k) i32, keep (T, k) bool."""
    Tn, k = expert_ids.shape
    flat = expert_ids.reshape(-1)  # (T*k,) — token-major, slot-minor priority
    onehot = jax.nn.one_hot(flat, E, dtype=jnp.int32)  # (T*k, E)
    pos_flat = (jnp.cumsum(onehot, axis=0) - 1) * onehot  # pos within expert queue
    pos = jnp.sum(pos_flat, axis=-1).reshape(Tn, k)
    keep = pos < C
    return pos, keep


def expert_ffn(p, x, cfg: ModelConfig, dtype=jnp.bfloat16):
    """Batched expert SwiGLU. x: (E, C, D) -> (E, C, D)."""
    g = jnp.einsum("ecd,edf->ecf", x.astype(dtype), p["gate"].astype(dtype))
    u = jnp.einsum("ecd,edf->ecf", x.astype(dtype), p["up"].astype(dtype))
    a = jax.nn.silu(g) if cfg.act == "silu" else jax.nn.gelu(g)
    return jnp.einsum("ecf,efd->ecd", a * u, p["down"].astype(dtype))


# ---------------------------------------------------------------------------
# Dispatch chunnels
# ---------------------------------------------------------------------------


def dispatch_dense(p, x2d, cfg: ModelConfig):
    """Oracle: compute every expert for every token (tiny configs only)."""
    gates, ids, aux = route(p["router"], x2d, cfg)
    m = cfg.moe
    dtype = jnp.bfloat16
    g = jnp.einsum("td,edf->tef", x2d.astype(dtype), p["gate"].astype(dtype))
    u = jnp.einsum("td,edf->tef", x2d.astype(dtype), p["up"].astype(dtype))
    a = jax.nn.silu(g) if cfg.act == "silu" else jax.nn.gelu(g)
    y_all = jnp.einsum("tef,efd->ted", a * u, p["down"].astype(dtype))  # (T, E, D)
    dense_gates = jnp.sum(
        jax.nn.one_hot(ids, m.num_experts, dtype=jnp.float32) * gates[..., None], axis=1
    )  # (T, E)
    y = jnp.einsum("ted,te->td", y_all.astype(jnp.float32), dense_gates)
    return y.astype(x2d.dtype), aux


def _gather_scatter_ffn(p, x2d, gates, ids, cfg: ModelConfig, C: int):
    """Shared capacity gather -> expert ffn -> scatter combine. x2d: (T, D)."""
    Tn, D = x2d.shape
    E = cfg.moe.num_experts
    pos, keep = _positions_in_expert(ids, E, C)
    tok_idx = jnp.broadcast_to(jnp.arange(Tn)[:, None], ids.shape)
    # Sentinel row T gathers zeros for dropped/empty slots.
    x_pad = jnp.concatenate([x2d, jnp.zeros((1, D), x2d.dtype)], axis=0)
    slot_tok = jnp.full((E, C), Tn, jnp.int32)
    slot_tok = slot_tok.at[ids.reshape(-1), pos.reshape(-1)].set(
        jnp.where(keep.reshape(-1), tok_idx.reshape(-1), Tn), mode="drop"
    )
    x_sorted = x_pad[slot_tok]  # (E, C, D)
    y_sorted = expert_ffn(p, x_sorted, cfg)  # (E, C, D)
    y_tk = y_sorted[ids, pos]  # (T, k, D)
    w = (gates * keep).astype(jnp.float32)
    return jnp.einsum("tkd,tk->td", y_tk.astype(jnp.float32), w).astype(x2d.dtype)


def dispatch_grouped(p, x2d, cfg: ModelConfig):
    """Capacity dispatch; collective schedule left to the XLA partitioner."""
    gates, ids, aux = route(p["router"], x2d, cfg)
    C = capacity(x2d.shape[0], cfg)
    return _gather_scatter_ffn(p, x2d, gates, ids, cfg, C), aux


def _token_axes(mesh):
    """All batch-ish axes tokens are split over inside the manual region: the
    pod axis (when present) must be manual too, or the partitioner falls back
    to 'involuntary full rematerialization' reshards at the region boundary."""
    return tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)


def _batch_axes(mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _gathered_weights(router_w, gate_w, up_w, down_w, data_axis):
    """ZeRO-3 inside the manual region: params arrive FSDP-sharded on their
    d_model dim over ``data_axis``; all-gather working copies (bf16 for the
    expert banks) so each rank computes with full-D weights."""
    ag = lambda a, ax: jax.lax.all_gather(a, data_axis, axis=ax, tiled=True)
    return (
        ag(router_w.astype(jnp.float32), 0),          # (D, E)
        ag(gate_w.astype(jnp.bfloat16), 1),           # (E_loc, D, F)
        ag(up_w.astype(jnp.bfloat16), 1),
        ag(down_w.astype(jnp.bfloat16), 2),           # (E_loc, F, D)
    )


def _route_and_sort(x_loc, router_w, cfg, E):
    """Local routing + capacity sort. x_loc: (T_loc, D) -> (E, C, D) bf16."""
    Ts = x_loc.shape[0]
    gates, ids, aux = route({"w": router_w}, x_loc, cfg)
    C = capacity(Ts, cfg)
    pos, keep = _positions_in_expert(ids, E, C)
    tok_idx = jnp.broadcast_to(jnp.arange(Ts)[:, None], ids.shape)
    x_pad = jnp.concatenate([x_loc, jnp.zeros((1, x_loc.shape[1]), x_loc.dtype)], 0)
    slot_tok = jnp.full((E, C), Ts, jnp.int32)
    slot_tok = slot_tok.at[ids.reshape(-1), pos.reshape(-1)].set(
        jnp.where(keep.reshape(-1), tok_idx.reshape(-1), Ts), mode="drop"
    )
    x_sorted = x_pad[slot_tok].astype(jnp.bfloat16)  # (E, C, D)
    return x_sorted, gates, ids, pos, keep, C, aux


def dispatch_alltoall(p, x3d, cfg: ModelConfig, mesh, axis: str = "model",
                      data_axis: str = "data"):
    """Explicit expert-parallel all-to-all, fully manual over (data, model).

    Tokens are partitioned over data x model (T/256 per chip); each chip routes
    its slice, all-to-alls capacity buffers to the expert owners along the
    model axis, computes its E/|model| experts (with ZeRO-gathered weights),
    and all-to-alls back. No tensor is ever replicated over either axis.
    """
    n = mesh.shape[axis]
    E = cfg.moe.num_experts
    assert E % n == 0, (E, n)

    def inner(x3d, router_w, gate_w, up_w, down_w):
        # local flatten: (B_loc, S_loc, D) -> (T_cell, D); the in_spec matches
        # the sequence-parallel activation layout exactly, so the region
        # boundary moves no data at all.
        B_l, S_l, D_l = x3d.shape
        x_loc = x3d.reshape(B_l * S_l, D_l)
        router_w, gate_w, up_w, down_w = _gathered_weights(
            router_w, gate_w, up_w, down_w, data_axis)
        x_sorted, gates, ids, pos, keep, C, aux = _route_and_sort(
            x_loc, router_w, cfg, E)
        # (n, E_loc, C, D) --a2a--> indexed by source rank
        x_send = x_sorted.reshape(n, E // n, C, -1)
        x_recv = jax.lax.all_to_all(x_send, axis, split_axis=0, concat_axis=0, tiled=False)
        x_pe = x_recv.transpose(1, 0, 2, 3).reshape(E // n, n * C, -1)
        y_pe = expert_ffn({"gate": gate_w, "up": up_w, "down": down_w}, x_pe, cfg)
        y_send = y_pe.reshape(E // n, n, C, -1).transpose(1, 0, 2, 3)
        y_recv = jax.lax.all_to_all(y_send, axis, split_axis=0, concat_axis=0, tiled=False)
        y_sorted = y_recv.reshape(E, C, -1)  # back in this rank's slot order
        y_tk = y_sorted[ids, pos]
        w = (gates * keep).astype(jnp.float32)
        y_loc = jnp.einsum("tkd,tk->td", y_tk.astype(jnp.float32), w)
        aux = jax.lax.pmean(jax.lax.pmean(aux, axis), data_axis)
        return y_loc.reshape(B_l, S_l, D_l).astype(x3d.dtype), aux

    tok_axes = _token_axes(mesh)
    b_axes = _batch_axes(mesh)
    f = compat.shard_map(
        inner,
        mesh=mesh,
        in_specs=(
            P(b_axes, axis, None),                  # (B, S, D) in SP layout
            P(data_axis, None),                     # router (D, E); pod-replicated
            P(axis, data_axis, None),               # gate (E, D, F)
            P(axis, data_axis, None),               # up
            P(axis, None, data_axis),               # down (E, F, D)
        ),
        out_specs=(P(b_axes, axis, None), P()),
        check_vma=False,
        axis_names=set(tok_axes),
    )
    return f(x3d, p["router"]["w"], p["gate"], p["up"], p["down"])


def dispatch_allgather(p, x3d, cfg: ModelConfig, mesh, axis: str = "model",
                       data_axis: str = "data"):
    """Each model-rank computes its local experts for its data-row's tokens:
    tokens are all-gathered along the model axis (instead of a2a'd), partial
    outputs psum'd back. More collective bytes than a2a for top_k << E, but no
    routing-dependent traffic — a latency-stable alternative (the Select's
    second branch)."""
    n = mesh.shape[axis]
    E = cfg.moe.num_experts
    assert E % n == 0
    E_loc = E // n

    def inner(x3d, router_w, gate_w, up_w, down_w):
        B_l, S_l, D_l = x3d.shape
        x_loc = x3d.reshape(B_l * S_l, D_l)
        router_w, gate_w, up_w, down_w = _gathered_weights(
            router_w, gate_w, up_w, down_w, data_axis)
        rank = jax.lax.axis_index(axis)
        # gather this data-row's tokens along the model axis (bf16 wire)
        x_row = jax.lax.all_gather(x_loc.astype(jnp.bfloat16), axis, axis=0, tiled=True)
        Tn = x_row.shape[0]
        gates, ids, aux = route({"w": router_w}, x_row.astype(jnp.float32), cfg)
        C = capacity(Tn, cfg)
        pos, keep = _positions_in_expert(ids, E, C)
        local = (ids // E_loc) == rank
        keep_loc = keep & local
        ids_loc = ids - rank * E_loc
        tok_idx = jnp.broadcast_to(jnp.arange(Tn)[:, None], ids.shape)
        x_pad = jnp.concatenate([x_row, jnp.zeros((1, x_row.shape[1]), x_row.dtype)], 0)
        slot_tok = jnp.full((E_loc, C), Tn, jnp.int32)
        slot_tok = slot_tok.at[
            jnp.where(keep_loc, ids_loc, E_loc).reshape(-1), pos.reshape(-1)
        ].set(tok_idx.reshape(-1), mode="drop")
        x_sorted = x_pad[slot_tok].astype(jnp.bfloat16)
        y_sorted = expert_ffn({"gate": gate_w, "up": up_w, "down": down_w}, x_sorted, cfg)
        y_tk = y_sorted[jnp.where(keep_loc, ids_loc, 0), pos]
        w = (gates * keep_loc).astype(jnp.float32)
        y_part = jnp.einsum("tkd,tk->td", y_tk.astype(jnp.float32), w)
        y_row = jax.lax.psum(y_part, axis)  # (Tn, D)
        # keep only this rank's slice of the row
        Ts = Tn // n
        y_loc = jax.lax.dynamic_slice_in_dim(y_row, rank * Ts, Ts, axis=0)
        aux = jax.lax.pmean(jax.lax.pmean(aux, axis), data_axis)
        return y_loc.reshape(B_l, S_l, D_l).astype(x3d.dtype), aux

    tok_axes = _token_axes(mesh)
    b_axes = _batch_axes(mesh)
    f = compat.shard_map(
        inner,
        mesh=mesh,
        in_specs=(
            P(b_axes, axis, None),
            P(data_axis, None),
            P(axis, data_axis, None),
            P(axis, data_axis, None),
            P(axis, None, data_axis),
        ),
        out_specs=(P(b_axes, axis, None), P()),
        check_vma=False,
        axis_names=set(tok_axes),
    )
    return f(x3d, p["router"]["w"], p["gate"], p["up"], p["down"])


def moe_ffn(p, x3d, cfg: ModelConfig, mesh=None):
    """Dispatch Select resolution (negotiated upstream; see comm/moe_dispatch).

    x3d: (B, S, D) in the sequence-parallel layout. Returns ((B, S, D), aux).
    """
    impl = cfg.moe.dispatch
    B, S, D = x3d.shape
    axes = getattr(mesh, "axis_names", ()) if mesh is not None else ()
    n_batch, n_model = 1, axes and mesh.shape.get("model", 1) or 1
    for a in ("pod", "data"):
        if a in axes:
            n_batch *= mesh.shape[a]
    manual_ok = (
        mesh is not None and "model" in axes and "data" in axes
        and B % max(n_batch, 1) == 0 and S % max(n_model, 1) == 0
        and cfg.moe.num_experts % mesh.shape["model"] == 0
    )
    if impl in ("dense", "grouped") or not manual_ok:
        impl = impl if impl in ("dense", "grouped") else "grouped"
        x2d = x3d.reshape(B * S, D)
        y, aux = (dispatch_dense(p, x2d, cfg) if impl == "dense"
                  else dispatch_grouped(p, x2d, cfg))
        return y.reshape(B, S, D), aux
    # XLA-CPU workaround: a bf16 operand crossing a partial-manual shard_map
    # boundary crashes the CPU backend under grad ("Invalid binary instruction
    # opcode copy"; bisected: norm->bf16->shard_map in a checkpointed scan).
    # Cross the boundary in f32 — the dispatch internals cast to bf16 before
    # every collective, so wire bytes are unchanged. Revisit on TPU backends.
    dt = x3d.dtype
    x3d = x3d.astype(jnp.float32)
    if impl == "alltoall":
        y, aux = dispatch_alltoall(p, x3d, cfg, mesh)
    elif impl == "allgather":
        y, aux = dispatch_allgather(p, x3d, cfg, mesh)
    else:
        raise ValueError(f"unknown moe dispatch {impl!r}")
    return y.astype(dt), aux


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------


def moe_layer(p, carry, cfg: ModelConfig, positions, *, window=None, mesh=None):
    x, aux_acc = carry
    B, S, D = x.shape
    h = x + T.attn_block(p["attn"], L.apply_norm(p["ln1"], x, eps=cfg.norm_eps), cfg, positions,
                         window=window)
    y, aux = moe_ffn(p["moe"], L.apply_norm(p["ln2"], h, eps=cfg.norm_eps), cfg, mesh)
    return (pshard.shard_activations(h + y), aux_acc + aux)


def init_params(rng, cfg: ModelConfig):
    return T.init_params(rng, cfg, layer_init=moe_layer_init)


def hidden_states(params, tokens, cfg: ModelConfig, *, mesh=None, extra_embeds=None):
    x = L.embed(params["embed"], tokens)
    if extra_embeds is not None:
        Pn = extra_embeds.shape[1]
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x[:, Pn:]], axis=1)
    x = pshard.shard_activations(x)
    positions = jnp.arange(tokens.shape[1])

    def body(p, carry, **kw):
        return moe_layer(p, carry, cfg, positions, mesh=mesh, **kw)

    x, aux = apply_stack(
        params["layers"], (x, jnp.zeros((), jnp.float32)), body,
        num_layers=cfg.num_layers, scan=cfg.scan_layers, remat=cfg.remat, remat_group=cfg.remat_group,
        static={"window": cfg.sliding_window},
    )
    return L.apply_norm(params["final_norm"], x, eps=cfg.norm_eps), aux


def loss_fn(params, batch, cfg: ModelConfig, *, mesh=None, loss_chunk: Optional[int] = None):
    h, aux = hidden_states(params, batch["tokens"], cfg, mesh=mesh)
    chunk = loss_chunk if loss_chunk is not None else cfg.loss_chunk
    lm = L.chunked_lm_loss(h, T.head_weight(params, cfg), batch["labels"], chunk=chunk,
                           real_vocab=cfg.vocab_size)
    return lm + aux


init_cache = T.init_cache
cache_specs = T.cache_specs


def prefill(params, batch, cfg: ModelConfig, *, mesh=None):
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = L.embed(params["embed"], tokens)
    positions = jnp.arange(S)

    def body(p, carry, cache_l, **kw):
        h, aux_acc = carry
        q, k, v = T.qkv(p["attn"], L.apply_norm(p["ln1"], h, eps=cfg.norm_eps), cfg, positions)
        o = attn.attention(q, k, v, impl=cfg.attn_impl, causal=True, chunk=cfg.attn_chunk, **kw)
        h = h + L.linear(p["attn"]["wo"], o.reshape(B, S, -1))
        y, aux = moe_ffn(p["moe"], L.apply_norm(p["ln2"], h, eps=cfg.norm_eps), cfg, mesh)
        return (pshard.shard_activations(h + y), aux_acc + aux), {
            "k": k.astype(jnp.bfloat16), "v": v.astype(jnp.bfloat16)
        }

    empty = {"k": jnp.zeros((cfg.num_layers, 0), jnp.bfloat16),
             "v": jnp.zeros((cfg.num_layers, 0), jnp.bfloat16)}
    (x, _aux), kv_cache = apply_stack_with_cache(
        params["layers"], (x, jnp.zeros((), jnp.float32)), empty, body,
        num_layers=cfg.num_layers, scan=cfg.scan_layers, remat="none",
        static={"window": cfg.sliding_window},
    )
    x = L.apply_norm(params["final_norm"], x, eps=cfg.norm_eps)
    logits = L.mask_padded_vocab(
        x[:, -1] @ T.head_weight(params, cfg).astype(x.dtype), cfg.vocab_size)
    return {"k": kv_cache["k"], "v": kv_cache["v"], "len": jnp.asarray(S, jnp.int32)}, logits


def decode_step(params, cache, batch, cfg: ModelConfig, *, mesh=None, attn_fn=None):
    tokens = batch["tokens"]
    B = tokens.shape[0]
    pos = cache["len"]
    x = L.embed(params["embed"], tokens)
    positions = pos + jnp.arange(1)
    attn_fn = attn_fn or (
        lambda q, kc, vc, n_valid, window: attn.decode_attention_local(
            q, kc, vc, n_valid, window=window
        )
    )

    def body(p, carry, cache_l, **kw):
        h, aux_acc = carry
        q, k, v = T.qkv(p["attn"], L.apply_norm(p["ln1"], h, eps=cfg.norm_eps), cfg, positions)
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            cache_l["k"], k.astype(cache_l["k"].dtype), pos, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            cache_l["v"], v.astype(cache_l["v"].dtype), pos, axis=1)
        o = attn_fn(q, k_cache, v_cache, pos + 1, kw.get("window"))
        h = h + L.linear(p["attn"]["wo"], o.reshape(B, 1, -1))
        y, aux = moe_ffn(p["moe"], L.apply_norm(p["ln2"], h, eps=cfg.norm_eps), cfg, mesh)
        return (h + y, aux_acc + aux), {"k": k_cache, "v": v_cache}

    (x, _aux), new_kv = apply_stack_with_cache(
        params["layers"], (x, jnp.zeros((), jnp.float32)),
        {"k": cache["k"], "v": cache["v"]}, body,
        num_layers=cfg.num_layers, scan=cfg.scan_layers, remat="none",
        static={"window": cfg.sliding_window},
    )
    x = L.apply_norm(params["final_norm"], x, eps=cfg.norm_eps)
    logits = L.mask_padded_vocab(
        x[:, -1] @ T.head_weight(params, cfg).astype(x.dtype), cfg.vocab_size)
    return {"k": new_kv["k"], "v": new_kv["v"], "len": pos + 1}, logits
