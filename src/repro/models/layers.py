"""Core parameterized layers (functional, explicit param pytrees).

Params are nested dicts of fp32 arrays; forward passes cast to the config's
compute dtype (bf16 on TPU). No framework dependency — pure jax.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

Initializer = jax.nn.initializers.Initializer


def truncated_normal_init(rng, shape, scale: float = 0.02, dtype=jnp.float32):
    return scale * jax.random.truncated_normal(rng, -2.0, 2.0, shape, dtype)


def linear_init(rng, d_in: int, d_out: int, *, bias: bool = False, scale: float | None = None):
    w_rng, _ = jax.random.split(rng)
    scale = scale if scale is not None else d_in**-0.5
    p = {"w": truncated_normal_init(w_rng, (d_in, d_out), scale)}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


def linear(p, x, dtype=jnp.bfloat16):
    y = x.astype(dtype) @ p["w"].astype(dtype)
    if "b" in p:
        y = y + p["b"].astype(dtype)
    return y


def norm_init(d: int, kind: str = "rmsnorm"):
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def apply_norm(p, x, *, eps: float = 1e-6, dtype=jnp.bfloat16):
    xf = x.astype(jnp.float32)
    if "bias" in p:  # layernorm
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"] + p["bias"]
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"]
    return y.astype(dtype)


def embedding_init(rng, vocab: int, d: int):
    return {"table": truncated_normal_init(rng, (vocab, d), 0.02)}


def embed(p, tokens, dtype=jnp.bfloat16):
    return p["table"].astype(dtype)[tokens]


def mlp_init(rng, d: int, f: int, gated: bool = True):
    r1, r2, r3 = jax.random.split(rng, 3)
    p = {
        "up": linear_init(r2, d, f),
        "down": linear_init(r3, f, d, scale=f**-0.5),
    }
    if gated:
        p["gate"] = linear_init(r1, d, f)
    return p


def mlp(p, x, act: str = "silu", dtype=jnp.bfloat16):
    """SwiGLU / GeGLU (gated) or classic 2-matrix feed-forward."""
    u = linear(p["up"], x, dtype)
    if "gate" in p:
        g = linear(p["gate"], x, dtype)
        a = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)
        return linear(p["down"], a * u, dtype)
    a = jax.nn.silu(u) if act == "silu" else jax.nn.gelu(u)
    return linear(p["down"], a, dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------


def mask_padded_vocab(logits: jnp.ndarray, real_vocab: int) -> jnp.ndarray:
    """-inf at padded vocab columns (vocab_padded > vocab_size)."""
    V = logits.shape[-1]
    if V == real_vocab:
        return logits
    idx = jax.lax.broadcasted_iota(jnp.int32, (V,), 0)
    return jnp.where(idx < real_vocab, logits, jnp.asarray(-1e30, logits.dtype))


def softmax_cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                          real_vocab: int | None = None) -> jnp.ndarray:
    """logits: (..., V) fp; labels: (...) int32. Returns mean loss (fp32)."""
    logits = logits.astype(jnp.float32)
    if real_vocab is not None:
        logits = mask_padded_vocab(logits, real_vocab)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def chunked_lm_loss(
    h: jnp.ndarray,
    head_w: jnp.ndarray,
    labels: jnp.ndarray,
    *,
    chunk: Optional[int] = None,
    dtype=jnp.bfloat16,
    real_vocab: Optional[int] = None,
) -> jnp.ndarray:
    """Cross-entropy over a (possibly huge) vocab without materializing all logits.

    h: (B, S, D) final hidden states; head_w: (D, V); labels: (B, S).
    When ``chunk`` divides S, scans over sequence chunks so the live logits are
    (B, chunk, V). chunk=None computes unchunked (used by roofline flop probes so
    the lm-head matmul is not hidden inside a while loop body).
    """
    from repro.models import pshard

    B, S, D = h.shape
    h = pshard.shard_batch(h)
    if chunk is None or chunk >= S:
        logits = h.astype(dtype) @ head_w.astype(dtype)
        return softmax_cross_entropy(logits, labels, real_vocab)
    assert S % chunk == 0, (S, chunk)
    n = S // chunk
    hs = h.reshape(B, n, chunk, D).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, n, chunk).transpose(1, 0, 2)

    # checkpoint: recompute the (B, chunk, V) logits in backward instead of
    # stacking them across scan steps (that residual is n x B x chunk x V).
    @jax.checkpoint
    def body(acc, xs):
        hc, lc = xs
        hc = pshard.shard_batch(hc)
        logits = hc.astype(dtype) @ head_w.astype(dtype)
        logits = logits.astype(jnp.float32)
        if real_vocab is not None:
            logits = mask_padded_vocab(logits, real_vocab)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(logz - gold), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hs, ls))
    return total / (B * S)
