"""Selective state-space (mamba-style) core, used by hymba's SSM branch.

Diagonal SSM: h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t x_t ;  y_t = C_t . h_t + D x_t
Parallelized with jax.lax.associative_scan inside sequence chunks (bounded
memory) and a lax.scan carry across chunks. The Pallas TPU kernel for this
hot-spot lives in kernels/ssm_scan with this module as its oracle.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SSMConfig
from repro.models import layers as L
from repro.models import pshard


def ssm_init(rng, d_model: int, s: SSMConfig):
    d_in = s.expand * d_model
    dt_rank = s.dt_rank or max(1, -(-d_model // 16))
    r = jax.random.split(rng, 7)
    return {
        "in_proj": L.linear_init(r[0], d_model, 2 * d_in),  # x and gate z
        "conv_w": L.truncated_normal_init(r[1], (s.conv_dim, d_in), 0.2),
        "conv_b": jnp.zeros((d_in,), jnp.float32),
        "x_proj": L.linear_init(r[2], d_in, dt_rank + 2 * s.state_dim),  # dt, B, C
        "dt_proj": L.linear_init(r[3], dt_rank, d_in),
        "dt_bias": jnp.log(
            jnp.exp(jax.random.uniform(r[4], (d_in,), minval=1e-3, maxval=1e-1)) - 1.0
        ),
        # S4D-real initialization of A (negative reals)
        "A_log": jnp.log(jnp.broadcast_to(jnp.arange(1, s.state_dim + 1, dtype=jnp.float32),
                                          (d_in, s.state_dim))),
        "D": jnp.ones((d_in,), jnp.float32),
        "out_proj": L.linear_init(r[5], d_in, d_model),
    }


class SSMState(NamedTuple):
    h: jnp.ndarray  # (B, d_in, N) carried SSM state
    conv: jnp.ndarray  # (B, conv_dim - 1, d_in) causal-conv tail


def init_state(batch: int, d_model: int, s: SSMConfig, dtype=jnp.float32) -> SSMState:
    d_in = s.expand * d_model
    return SSMState(
        h=jnp.zeros((batch, d_in, s.state_dim), dtype),
        conv=jnp.zeros((batch, s.conv_dim - 1, d_in), dtype),
    )


def _causal_conv(x, w, b, tail):
    """x: (B,S,C), w: (K,C) depthwise, tail: (B,K-1,C) from the previous segment."""
    K = w.shape[0]
    xp = jnp.concatenate([tail.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i : i + x.shape[1]] * w[i].astype(x.dtype) for i in range(K))
    new_tail = xp[:, -(K - 1) :] if K > 1 else jnp.zeros_like(tail)
    return out + b.astype(x.dtype), new_tail


def _scan_chunk(a, bx, h0):
    """Associative scan of h_t = a_t * h_{t-1} + bx_t within a chunk.

    a, bx: (C, B, d_in, N); h0: (B, d_in, N). Returns (h_all (C,...), h_last).
    """
    a0 = jnp.concatenate([jnp.ones_like(a[:1]), a[1:]], axis=0)  # fold h0 into bx[0]
    bx0 = bx.at[0].add(a[0] * h0)

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    a_c, b_c = jax.lax.associative_scan(combine, (a0, bx0), axis=0)
    return b_c, b_c[-1]


def ssm_apply(
    p,
    x: jnp.ndarray,  # (B, S, D)
    s: SSMConfig,
    state: Optional[SSMState] = None,
    *,
    chunk: int = 256,
    impl: str = "jnp",
):
    """Returns (y (B,S,D), new_state). Sub-quadratic in S; O(B*chunk*d_in*N) live."""
    B, S, D = x.shape
    d_in = s.expand * D
    dt_rank = s.dt_rank or max(1, -(-D // 16))
    state = state if state is not None else init_state(B, D, s)

    xz = L.linear(p["in_proj"], x)  # (B,S,2*d_in)
    xs, z = jnp.split(xz, 2, axis=-1)
    # TP layout for the SSM branch: the time recurrence cannot shard S, but
    # the state channels are independent — pin d_in over 'model'.
    xs = pshard.shard_model_dim(xs, 2)
    z = pshard.shard_model_dim(z, 2)
    xs, conv_tail = _causal_conv(xs, p["conv_w"], p["conv_b"], state.conv)
    xs = jax.nn.silu(xs)

    proj = L.linear(p["x_proj"], xs)  # (B,S,dt_rank+2N)
    dt_in, Bmat, Cmat = jnp.split(proj, [dt_rank, dt_rank + s.state_dim], axis=-1)
    dt = jax.nn.softplus(
        L.linear(p["dt_proj"], dt_in).astype(jnp.float32) + p["dt_bias"]
    )  # (B,S,d_in)
    A = -jnp.exp(p["A_log"])  # (d_in, N)
    a = jnp.exp(dt[..., None] * A)  # (B,S,d_in,N)
    bx = (dt * xs.astype(jnp.float32))[..., None] * Bmat.astype(jnp.float32)[..., None, :]
    a = pshard.shard_model_dim(a, 2)
    bx = pshard.shard_model_dim(bx, 2)

    pad = (-S) % chunk
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
        bx = jnp.pad(bx, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cmat = jnp.pad(Cmat, ((0, 0), (0, pad), (0, 0)))
    n = a.shape[1] // chunk
    a_ch = a.reshape(B, n, chunk, d_in, s.state_dim).transpose(1, 2, 0, 3, 4)
    bx_ch = bx.reshape(B, n, chunk, d_in, s.state_dim).transpose(1, 2, 0, 3, 4)
    C_ch = Cmat.astype(jnp.float32).reshape(B, n, chunk, s.state_dim).transpose(1, 2, 0, 3)

    def body(h, inputs):
        # contract with C INSIDE the chunk so the full (B,S,d_in,N) state
        # sequence never materializes (only (chunk,B,d_in,N) transients)
        a_c, bx_c, C_c = inputs
        h_all, h_last = _scan_chunk(a_c, bx_c, h)
        y_c = jnp.einsum("cbdn,cbn->cbd", h_all, C_c)
        return h_last, y_c

    h_final, y_seq = jax.lax.scan(body, state.h.astype(jnp.float32),
                                  (a_ch, bx_ch, C_ch))
    y = y_seq.transpose(2, 0, 1, 3).reshape(B, n * chunk, d_in)[:, :S]
    y = y + p["D"] * xs.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    y = L.linear(p["out_proj"], y)
    return y, SSMState(h=h_final, conv=conv_tail)


def ssm_decode(p, x, s: SSMConfig, state: SSMState):
    """Single-token recurrence. x: (B, 1, D)."""
    return ssm_apply(p, x, s, state, chunk=1)
