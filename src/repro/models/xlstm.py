"""xLSTM LM (sLSTM + mLSTM blocks, arXiv:2405.04517).

mLSTM: matrix-memory cell, chunkwise-parallel (gated linear attention form):
    C_t = f_t C_{t-1} + i_t v_t k_t^T ;  n_t = f_t n_{t-1} + i_t k_t
    h_t = (C_t q_t) / max(|n_t . q_t|, 1)
sLSTM: scalar-memory cell with a true sequential recurrence (lax.scan over
time), as the paper notes it is not parallelizable.

Numerics note (DESIGN.md): we use bounded sigmoid input/forget gates instead of
the paper's exponential gating + stabilizer state; the memory structure (the
architectural contribution) is unchanged, the stabilizer bookkeeping is not.

No KV cache: serving carries recurrent state, so long_500k decode is O(1)/token.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import pshard
from repro.models.stacking import stacked_init


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def mlstm_init(rng, cfg: ModelConfig):
    D = cfg.d_model
    H, hd = cfg.num_heads, cfg.head_dim_
    r = jax.random.split(rng, 7)
    return {
        "ln": L.norm_init(D, cfg.norm),
        "wq": L.linear_init(r[0], D, H * hd),
        "wk": L.linear_init(r[1], D, H * hd),
        "wv": L.linear_init(r[2], D, H * hd),
        "wi": L.linear_init(r[3], D, H, bias=True),
        "wf": L.linear_init(r[4], D, H, bias=True),
        "wo_gate": L.linear_init(r[5], D, H * hd),
        "wo": L.linear_init(r[6], H * hd, D),
    }


def mlstm_state(batch: int, cfg: ModelConfig, dtype=jnp.float32):
    H, hd = cfg.num_heads, cfg.head_dim_
    return {
        "C": jnp.zeros((batch, H, hd, hd), dtype),
        "n": jnp.zeros((batch, H, hd), dtype),
    }


def _mlstm_chunk(q, k, v, i, logf, C0, n0):
    """One chunk of the chunkwise-parallel mLSTM.

    q,k,v: (B,C,H,hd); i: (B,C,H) input gate in [0,1]; logf: (B,C,H) <= 0.
    C0: (B,H,hd,hd); n0: (B,H,hd). Returns (h (B,C,H,hd), C1, n1).
    """
    Bn, Cn, H, hd = q.shape
    scale = hd**-0.5
    q = q.astype(jnp.float32) * scale
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    F = jnp.cumsum(logf, axis=1)  # (B,C,H) cumulative log-forget within chunk
    # Intra-chunk: D[j,u] = exp(F_j - F_u) * i_u  for u <= j
    Dmat = jnp.exp(F[:, :, None, :] - F[:, None, :, :])  # (B,j,u,H)
    causal = jnp.tril(jnp.ones((Cn, Cn), bool))
    Dmat = jnp.where(causal[None, :, :, None], Dmat * i[:, None, :, :], 0.0)
    s = jnp.einsum("bjhd,buhd->bjuh", q, k)
    sv = s * Dmat
    h_intra = jnp.einsum("bjuh,buhd->bjhd", sv, v)
    # Inter-chunk: contribution of carry C0, n0 decayed to each position
    decay = jnp.exp(F)  # (B,C,H)
    h_inter = jnp.einsum("bjh,bhde,bjhd->bjhe", decay, C0, q)
    n_inter = jnp.einsum("bjh,bhd,bjhd->bjh", decay, n0, q)
    # normalizer: n_j . q_j = sum_u D[j,u] (k_u . q_j)
    nq_intra = jnp.sum(sv, axis=2)
    denom = jnp.maximum(jnp.abs(nq_intra + n_inter), 1.0)
    h = (h_intra + h_inter) / denom[..., None]
    # carry updates
    last_decay = jnp.exp(F[:, -1])  # (B,H)
    w_u = jnp.exp(F[:, -1:, :] - F) * i  # (B,C,H): decay from u to end
    C1 = last_decay[:, :, None, None] * C0 + jnp.einsum("buh,buhd,buhe->bhde", w_u, k, v)
    n1 = last_decay[:, :, None] * n0 + jnp.einsum("buh,buhd->bhd", w_u, k)
    return h, C1, n1


def mlstm_apply(p, x, cfg: ModelConfig, state=None, *, chunk: Optional[int] = None):
    """x: (B,S,D) -> (y, new_state). Chunkwise parallel, O(S*chunk) scores."""
    B, S, D = x.shape
    H, hd = cfg.num_heads, cfg.head_dim_
    chunk = chunk or (cfg.xlstm.chunk_size if cfg.xlstm else 64)
    chunk = min(chunk, S)
    state = state if state is not None else mlstm_state(B, cfg)

    xn = L.apply_norm(p["ln"], x, eps=cfg.norm_eps)
    q = L.linear(p["wq"], xn).reshape(B, S, H, hd)
    k = L.linear(p["wk"], xn).reshape(B, S, H, hd)
    v = L.linear(p["wv"], xn).reshape(B, S, H, hd)
    i = jax.nn.sigmoid(L.linear(p["wi"], xn, dtype=jnp.float32))
    logf = jax.nn.log_sigmoid(L.linear(p["wf"], xn, dtype=jnp.float32))

    pad = (-S) % chunk
    if pad:
        zpad = lambda a: jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
        q, k, v, i = zpad(q), zpad(k), zpad(v), zpad(i)
        logf = jnp.pad(logf, ((0, 0), (0, pad), (0, 0)))  # logf=0 => f=1 keeps carry
    n = q.shape[1] // chunk
    resh = lambda a: a.reshape((B, n, chunk) + a.shape[2:]).transpose(
        (1, 0, 2) + tuple(range(3, a.ndim + 1)))
    qs, ks, vs, is_, fs = map(resh, (q, k, v, i, logf))

    def body(carry, xs):
        C0, n0 = carry
        qc, kc, vc, ic, fc = xs
        h, C1, n1 = _mlstm_chunk(qc, kc, vc, ic, fc, C0, n0)
        return (C1, n1), h

    (C1, n1), hs = jax.lax.scan(body, (state["C"], state["n"]), (qs, ks, vs, is_, fs))
    h = hs.transpose(1, 0, 2, 3, 4).reshape(B, n * chunk, H, hd)[:, :S]
    o = jax.nn.sigmoid(L.linear(p["wo_gate"], xn, dtype=jnp.float32)).reshape(B, S, H, hd)
    y = (h * o).astype(x.dtype).reshape(B, S, H * hd)
    return x + L.linear(p["wo"], y), {"C": C1, "n": n1}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_init(rng, cfg: ModelConfig):
    D = cfg.d_model
    r = jax.random.split(rng, 8)
    f_up = int(D * 4 / 3)
    return {
        "ln": L.norm_init(D, cfg.norm),
        "wz": L.linear_init(r[0], D, D, bias=True),
        "wi": L.linear_init(r[1], D, D, bias=True),
        "wf": L.linear_init(r[2], D, D, bias=True),
        "wo_gate": L.linear_init(r[3], D, D, bias=True),
        "r": L.truncated_normal_init(r[4], (4, D), 0.02),  # diagonal recurrence / gate
        "ln2": L.norm_init(D, cfg.norm),
        "ffn": {
            "gate": L.linear_init(r[5], D, f_up),
            "up": L.linear_init(r[6], D, f_up),
            "down": L.linear_init(r[7], f_up, D),
        },
    }


def slstm_state(batch: int, cfg: ModelConfig, dtype=jnp.float32):
    D = cfg.d_model
    z = jnp.zeros((batch, D), dtype)
    return {"c": z, "n": z + 1e-6, "h": z}


def slstm_apply(p, x, cfg: ModelConfig, state=None):
    """Sequential recurrence over time (the paper: sLSTM is not parallelizable)."""
    B, S, D = x.shape
    state = state if state is not None else slstm_state(B, cfg)
    xn = L.apply_norm(p["ln"], x, eps=cfg.norm_eps)
    # Precompute input contributions for all timesteps
    zx = L.linear(p["wz"], xn, dtype=jnp.float32)
    ix = L.linear(p["wi"], xn, dtype=jnp.float32)
    fx = L.linear(p["wf"], xn, dtype=jnp.float32)
    ox = L.linear(p["wo_gate"], xn, dtype=jnp.float32)
    rz, ri, rf, ro = p["r"][0], p["r"][1], p["r"][2], p["r"][3]

    def step(carry, xs):
        c, n, h = carry
        zt, it, ft, ot = xs
        z = jnp.tanh(zt + rz * h)
        i = jax.nn.sigmoid(it + ri * h)
        f = jax.nn.sigmoid(ft + rf * h)
        o = jax.nn.sigmoid(ot + ro * h)
        c = f * c + i * z
        n = f * n + i
        h = o * c / jnp.maximum(n, 1e-6)
        return (c, n, h), h

    xs = tuple(a.transpose(1, 0, 2) for a in (zx, ix, fx, ox))
    (c, n, h), hs = jax.lax.scan(step, (state["c"], state["n"], state["h"]), xs)
    y = hs.transpose(1, 0, 2).astype(x.dtype)
    x = x + y
    x = x + L.mlp(p["ffn"], L.apply_norm(p["ln2"], x, eps=cfg.norm_eps), act=cfg.act)
    return x, {"c": c, "n": n, "h": h}


# ---------------------------------------------------------------------------
# Model (alternating blocks; uniform param structure via union pytree)
# ---------------------------------------------------------------------------


def is_slstm(i: int, cfg: ModelConfig) -> bool:
    every = cfg.xlstm.slstm_every if cfg.xlstm else 2
    return (i % every) == every - 1


def init_params(rng, cfg: ModelConfig):
    # Layer kinds are static (derived from cfg via is_slstm), so the param tree
    # holds arrays only — it stays a valid jit input.
    r_emb, r_l, r_head = jax.random.split(rng, 3)
    rngs = jax.random.split(r_l, cfg.num_layers)
    layers = [
        slstm_init(rngs[i], cfg) if is_slstm(i, cfg) else mlstm_init(rngs[i], cfg)
        for i in range(cfg.num_layers)
    ]
    return {
        "embed": L.embedding_init(r_emb, cfg.vocab_padded, cfg.d_model),
        "layers": layers,  # heterogeneous: kept as a list (segments, not scanned)
        "final_norm": L.norm_init(cfg.d_model, cfg.norm),
        "lm_head": L.linear_init(r_head, cfg.d_model, cfg.vocab_padded),
    }


def init_state(cfg: ModelConfig, batch: int):
    states = []
    for idx in range(cfg.num_layers):
        if is_slstm(idx, cfg):
            states.append(slstm_state(batch, cfg))
        else:
            states.append(mlstm_state(batch, cfg))
    return {"layers": states, "len": jnp.zeros((), jnp.int32)}


def state_specs(cfg: ModelConfig, batch: int):
    return jax.eval_shape(lambda: init_state(cfg, batch))


def forward(params, tokens, cfg: ModelConfig, state=None, *, collect_state: bool = False):
    x = L.embed(params["embed"], tokens)
    new_states = []
    for idx, lp in enumerate(params["layers"]):
        st = state["layers"][idx] if state is not None else None
        if is_slstm(idx, cfg):
            x, s_new = slstm_apply(lp, x, cfg, st)
        else:
            x, s_new = mlstm_apply(lp, x, cfg, st)
        x = pshard.shard_batch(x)
        new_states.append(s_new)
    x = L.apply_norm(params["final_norm"], x, eps=cfg.norm_eps)
    if collect_state:
        return x, new_states
    return x


def loss_fn(params, batch, cfg: ModelConfig, *, loss_chunk=None):
    h = forward(params, batch["tokens"], cfg)
    chunk = loss_chunk if loss_chunk is not None else cfg.loss_chunk
    return L.chunked_lm_loss(h, params["lm_head"]["w"], batch["labels"], chunk=chunk,
                             real_vocab=cfg.vocab_size)


def prefill(params, batch, cfg: ModelConfig):
    tokens = batch["tokens"]
    h, states = forward(params, tokens, cfg, state=None, collect_state=True)
    logits = L.mask_padded_vocab(
        h[:, -1] @ params["lm_head"]["w"].astype(h.dtype), cfg.vocab_size)
    return {"layers": states, "len": jnp.asarray(tokens.shape[1], jnp.int32)}, logits


def decode_step(params, cache, batch, cfg: ModelConfig):
    h, states = forward(params, batch["tokens"], cfg, state=cache, collect_state=True)
    logits = L.mask_padded_vocab(
        h[:, -1] @ params["lm_head"]["w"].astype(h.dtype), cfg.vocab_size)
    return {"layers": states, "len": cache["len"] + 1}, logits
