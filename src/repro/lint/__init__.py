"""repro.lint — static verification for the reconfigurable network stack.

Three analyzer families over ``src/repro`` (see docs/architecture.md §7):

  stack verifier   migration-hook signatures (AST) + capability closure,
                   swap-name alignment, dead Select options and semantic
                   ordering on real ``Stack`` objects (``verify_stack``)
  concurrency      lock graphs, blocking calls under a held lock, unguarded
                   shared-attribute writes
  compat/hygiene   version-gated JAX symbols outside src/repro/compat/,
                   silent exception swallows, mutable default args

CLI: ``python -m repro.lint [paths] [--strict] [--stacks] [--json OUT]``.
Suppress a finding in place with ``# lint: allow[rule] reason`` (the reason
is mandatory); adopt legacy debt with ``--write-baseline``/``--baseline``.
"""
from .engine import RULES, lint_module, lint_paths, lint_sources, Module
from .findings import Finding, PragmaMap, load_baseline, write_baseline
from .rules_stack import builtin_stacks, verify_stack

__all__ = [
    "RULES", "Finding", "Module", "PragmaMap", "builtin_stacks",
    "lint_module", "lint_paths", "lint_sources", "load_baseline",
    "verify_stack", "write_baseline",
]
