"""Hygiene rules for the control-plane packages (core / fleet / comm / serving).

  silent-except    an ``except Exception:`` (or bare ``except:``) whose body
                   is only ``pass``/``continue``/``...`` erases the failure
                   entirely. In a control plane built on retries and voting,
                   a swallowed exception turns a diagnosable fault into a
                   silent hang or a stale decision. Catching broadly is fine
                   — PROVABLY DOING SOMETHING with it (log, count, re-raise,
                   fall back) is the requirement; see compat/jaxapi.py's
                   ``_warn_probe_once`` for the sanctioned log-once pattern.
  mutable-default  ``def f(x, acc=[])`` shares one list across every call —
                   the classic aliasing bug. Use ``None`` + fill-in.

Scope: these rules run only over the packages named in the scope list below.
``src/repro/compat/`` is deliberately out of scope for silent-except: it is
the probing layer, where a swallowed probe failure IS the documented fallback
mechanism (each probe logs once at DEBUG through its own machinery).
"""
from __future__ import annotations

import ast
from typing import List

from .engine import Module, analyzer
from .findings import Finding

#: path fragments the hygiene rules apply to (control-plane packages)
HYGIENE_SCOPE = ("repro/core/", "repro/fleet/", "repro/comm/",
                 "repro/serving/", "repro/lint/", "repro/chaos/",
                 "repro/obs/")

MUTABLE_CTORS = {"list", "dict", "set"}


def _in_scope(path: str) -> bool:
    norm = path.replace("\\", "/")
    return any(frag in norm for frag in HYGIENE_SCOPE)


def _is_swallow_body(body: List[ast.stmt]) -> bool:
    for stmt in body:
        if isinstance(stmt, ast.Pass) or isinstance(stmt, ast.Continue):
            continue
        if (isinstance(stmt, ast.Expr)
                and isinstance(stmt.value, ast.Constant)):
            continue  # docstring or `...`
        return False
    return True


def _catches_broadly(handler: ast.ExceptHandler) -> bool:
    t = handler.type
    if t is None:
        return True  # bare except
    if isinstance(t, ast.Name) and t.id in ("Exception", "BaseException"):
        return True
    if isinstance(t, ast.Tuple):
        return any(isinstance(e, ast.Name)
                   and e.id in ("Exception", "BaseException") for e in t.elts)
    return False


def _is_mutable_default(node: ast.expr) -> bool:
    if isinstance(node, (ast.List, ast.Dict, ast.Set)):
        return True
    return (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
            and node.func.id in MUTABLE_CTORS and not node.args
            and not node.keywords)


@analyzer
def check_hygiene(mod: Module) -> List[Finding]:
    if not _in_scope(mod.path):
        return []
    out: List[Finding] = []
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.ExceptHandler):
            if _catches_broadly(node) and _is_swallow_body(node.body):
                out.append(Finding(
                    "silent-except", mod.path, node.lineno, node.col_offset,
                    "except swallows every exception with no log/counter/"
                    "re-raise — at minimum log once at DEBUG "
                    "(compat/jaxapi.py _warn_probe_once pattern)"))
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defaults = list(node.args.defaults) + [
                d for d in node.args.kw_defaults if d is not None]
            for d in defaults:
                if _is_mutable_default(d):
                    out.append(Finding(
                        "mutable-default", mod.path, d.lineno, d.col_offset,
                        f"{node.name}() has a mutable default argument — one "
                        "object is shared across every call; use None and "
                        "fill in"))
    return out
