"""Findings, suppression pragmas, and the baseline model for ``repro.lint``.

A Finding is one rule violation at one source location. Suppression is inline
and local: a ``# lint: allow[rule] reason`` pragma on the offending line (or
the line directly above it) silences that rule there — and ONLY there. The
reason is mandatory; an empty reason is itself a finding, so every suppression
in the tree carries a written justification.

Baselines exist for adopting the linter on a codebase with pre-existing debt:
``--write-baseline`` records fingerprints of current findings, and later runs
drop any finding whose fingerprint is baselined. Fingerprints hash the rule,
the file, and the *stripped source line* — not the line number — so baselined
findings survive unrelated edits above them but resurface if the flagged code
itself changes. This repo ships with no baseline: everything the linter
surfaced was either fixed or pragma-annotated.
"""
from __future__ import annotations

import hashlib
import json
import re
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Set

#: matches a comment of the form "lint: allow[rule-a,rule-b] justification"
PRAGMA_RE = re.compile(r"#\s*lint:\s*allow\[([^\]]*)\]\s*(.*)$")


@dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int  # 1-based; 0 for whole-file / synthetic (runtime stack) findings
    col: int
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule}: {self.message}"

    def to_json(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "col": self.col, "message": self.message}

    def fingerprint(self, source_line: str = "") -> str:
        h = hashlib.sha1()
        h.update(self.rule.encode())
        h.update(b"\0")
        h.update(self.path.encode())
        h.update(b"\0")
        h.update(source_line.strip().encode())
        return h.hexdigest()[:16]


def _comment_lines(source: str):
    """(lineno, text) of real COMMENT tokens — a pragma quoted inside a
    docstring (e.g. documentation of the pragma syntax itself) is not a
    pragma. Falls back to raw lines if the file does not tokenize."""
    import io
    import tokenize
    try:
        return [(tok.start[0], tok.string)
                for tok in tokenize.generate_tokens(io.StringIO(source).readline)
                if tok.type == tokenize.COMMENT]
    except (tokenize.TokenError, IndentationError, SyntaxError):
        return list(enumerate(source.splitlines(), start=1))


@dataclass
class Pragma:
    line: int
    rules: List[str]
    reason: str
    used: bool = False


class PragmaMap:
    """All ``lint: allow`` pragmas in one file, by line."""

    def __init__(self, source: str):
        self.by_line: Dict[int, Pragma] = {}
        for i, text in _comment_lines(source):
            m = PRAGMA_RE.search(text)
            if m:
                rules = [r.strip() for r in m.group(1).split(",") if r.strip()]
                self.by_line[i] = Pragma(i, rules, m.group(2).strip())

    def _match(self, line: int, rule: str) -> Optional[Pragma]:
        # a pragma covers its own line and the line directly below it (so it
        # can sit above a long statement without fighting the line length)
        for ln in (line, line - 1):
            p = self.by_line.get(ln)
            if p and rule in p.rules:
                return p
        return None

    def allows(self, finding: Finding) -> bool:
        p = self._match(finding.line, finding.rule)
        if p is None:
            return False
        p.used = True
        return True

    def allows_at(self, line: int, rule: str) -> bool:
        """Pragma lookup at an explicit line (the engine uses this to honor a
        pragma on a ``def`` line for every finding inside that function)."""
        p = self._match(line, rule)
        if p is None:
            return False
        p.used = True
        return True

    def problems(self, path: str, known_rules: Set[str]) -> List[Finding]:
        out = []
        for p in self.by_line.values():
            if not p.reason:
                out.append(Finding(
                    "pragma-missing-reason", path, p.line, 0,
                    "lint: allow pragma must carry a written justification"))
            for r in p.rules:
                if r not in known_rules:
                    out.append(Finding(
                        "pragma-unknown-rule", path, p.line, 0,
                        f"pragma names unknown rule {r!r}"))
        return out


# ---------------------------------------------------------------------------
# Baseline
# ---------------------------------------------------------------------------


def load_baseline(path) -> Set[str]:
    with open(path) as f:
        data = json.load(f)
    return {e["fingerprint"] for e in data.get("findings", [])}


def write_baseline(path, findings: Iterable[Finding],
                   source_lines: Dict[str, List[str]]) -> None:
    entries = []
    for f in sorted(findings, key=lambda x: (x.path, x.line, x.rule)):
        lines = source_lines.get(f.path, [])
        src = lines[f.line - 1] if 0 < f.line <= len(lines) else ""
        entries.append({"fingerprint": f.fingerprint(src), "rule": f.rule,
                        "path": f.path, "line": f.line,
                        "source": src.strip()})
    with open(path, "w") as fh:
        json.dump({"findings": entries}, fh, indent=2)
        fh.write("\n")


def apply_baseline(findings: List[Finding], fingerprints: Set[str],
                   source_lines: Dict[str, List[str]]) -> List[Finding]:
    kept = []
    for f in findings:
        lines = source_lines.get(f.path, [])
        src = lines[f.line - 1] if 0 < f.line <= len(lines) else ""
        if f.fingerprint(src) not in fingerprints:
            kept.append(f)
    return kept
