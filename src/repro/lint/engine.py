"""Rule registry and file runner for ``repro.lint``.

Analyzers are plain functions ``(Module) -> List[Finding]`` registered under a
family name. Rules (the finding IDs analyzers emit) are declared in ``RULES``
so pragmas can be validated against the known set — a pragma naming a rule
that does not exist is itself a finding, which keeps stale suppressions from
rotting in place after a rule is renamed.

To add a rule: declare its ID + one-line doc in ``RULES``, emit it from an
analyzer registered with ``@analyzer``, and add a good/bad fixture pair to
``tests/test_lint.py`` (see docs/architecture.md §7).
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, Dict, List, Optional

from .findings import Finding, PragmaMap

#: rule id -> one-line description (the rule catalog; see docs/architecture.md)
RULES: Dict[str, str] = {
    # stack verifier (static half; runtime half lives in rules_stack.verify_stack)
    "stack-migrate-signature":
        "migrate_state/apply_state/restore_state has a non-standard signature",
    "stack-capability-closure":
        "stack options differ in exact capabilities on a non-multilateral chunnel",
    "stack-swap-alignment":
        "chunnel name reused across swap options with a different class, or "
        "duplicated within one option (breaks migrate_state alignment)",
    "stack-dead-option":
        "a Select combination can never instantiate (adjacent WireTypes clash)",
    "stack-semantic-order":
        "semantic classes are mis-ordered (e.g. reliability above compression)",
    # concurrency analyzer
    "lock-order":
        "lock acquisition order inverts between code paths, or a "
        "non-reentrant lock is re-acquired on the same path",
    "blocking-under-lock":
        "blocking call (sleep/join/recv/queue.get/KV transact*/RPC) while "
        "holding a lock",
    "unguarded-attr":
        "shared mutable attribute written without the class lock (or from a "
        "thread target) while other methods access it",
    # data plane
    "per-message-hot-path":
        "per-element delivery loop (.send/.put/.publish per message) inside "
        "a Datapath/Fabric/Endpoint hot-path method — batch it, or lift a "
        "scalar transform with the per_message adapter",
    "span-in-hot-loop":
        "span creation (.span/.begin_span) inside a loop of a Datapath/"
        "Fabric/Endpoint hot-path method — spans are control-plane; the data "
        "plane records one TRACER.record_batch per batch",
    # compat boundary + hygiene
    "compat-boundary":
        "version-gated JAX symbol used outside src/repro/compat/",
    "silent-except":
        "except clause swallows all exceptions without logging or re-raising",
    "mutable-default":
        "mutable default argument ([], {}, set()) shared across calls",
    # pragma meta-rules (emitted by the engine itself)
    "pragma-missing-reason":
        "lint: allow pragma with no written justification",
    "pragma-unknown-rule":
        "lint: allow pragma naming a rule that does not exist",
}


@dataclass
class Module:
    """One parsed source file handed to every analyzer."""

    path: str                 # display path (repo-relative when possible)
    source: str
    tree: ast.AST
    lines: List[str] = field(default_factory=list)

    @classmethod
    def parse(cls, path: str, source: str) -> "Module":
        return cls(path=path, source=source,
                   tree=ast.parse(source, filename=path),
                   lines=source.splitlines())


Analyzer = Callable[[Module], List[Finding]]
_ANALYZERS: List[Analyzer] = []


def analyzer(fn: Analyzer) -> Analyzer:
    _ANALYZERS.append(fn)
    return fn


def _load_analyzers() -> None:
    # import for registration side effects; idempotent
    from . import (  # noqa: F401
        rules_compat,
        rules_concurrency,
        rules_dataplane,
        rules_hygiene,
        rules_stack,
    )


def lint_module(mod: Module) -> List[Finding]:
    """Run every analyzer over one module and apply its pragmas.

    Suppression scope: a pragma on the offending line (or the line directly
    above) silences that line; a pragma on a ``def`` line silences the rule
    for the whole function — for documented patterns like "callers hold the
    lock" that would otherwise need one pragma per statement."""
    _load_analyzers()
    pragmas = PragmaMap(mod.source)
    spans = [(n.lineno, getattr(n, "end_lineno", n.lineno) or n.lineno)
             for n in ast.walk(mod.tree)
             if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
    findings: List[Finding] = []
    for an in _ANALYZERS:
        findings.extend(an(mod))
    kept = []
    for f in findings:
        if pragmas.allows(f):
            continue
        if any(s <= f.line <= e and pragmas.allows_at(s, f.rule)
               for s, e in spans):
            continue
        kept.append(f)
    kept.extend(pragmas.problems(mod.path, set(RULES)))
    kept.sort(key=lambda f: (f.line, f.col, f.rule))
    return kept


def lint_sources(sources: Dict[str, str]) -> List[Finding]:
    """String-based entry point (used by the fixture tests)."""
    out: List[Finding] = []
    for path, src in sources.items():
        out.extend(lint_module(Module.parse(path, src)))
    return out


def iter_py_files(paths: List[str]) -> List[Path]:
    files: List[Path] = []
    for p in paths:
        pp = Path(p)
        if pp.is_dir():
            files.extend(sorted(pp.rglob("*.py")))
        elif pp.suffix == ".py":
            files.append(pp)
    return files


def display_path(p: Path, root: Optional[Path]) -> str:
    try:
        return str(p.resolve().relative_to(root)) if root else str(p)
    except ValueError:
        return str(p)


def lint_paths(paths: List[str], root: Optional[Path] = None):
    """Lint every .py under ``paths``.

    Returns ``(findings, source_lines)`` where source_lines maps display path
    -> list of lines (needed for baseline fingerprints).
    """
    findings: List[Finding] = []
    source_lines: Dict[str, List[str]] = {}
    for f in iter_py_files(paths):
        disp = display_path(f, root)
        try:
            src = f.read_text()
            mod = Module.parse(disp, src)
        except (SyntaxError, UnicodeDecodeError) as e:
            findings.append(Finding("syntax", disp, getattr(e, "lineno", 0) or 0,
                                    0, f"cannot parse: {e}"))
            continue
        source_lines[disp] = mod.lines
        findings.extend(lint_module(mod))
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings, source_lines
