"""Data-plane rules: keep Datapath/Fabric hot paths batched.

  span-in-hot-loop       span creation (``.span``/``.begin_span``) inside a
                         loop of a hot-path method. Tracing the data plane is
                         batch-granular by design (``TRACER.record_batch`` is
                         one tuple append); a Span per message would blow the
                         <10% enabled-tracing budget bench_overhead gates.

  per-message-hot-path   a loop (or comprehension) inside a hot-path method
                         of a Datapath/Fabric/Endpoint class performs a
                         per-element delivery call (``.send``/``.put``/
                         ``.put_nowait``/``.publish``/``.request``). The
                         batched data plane (docs/architecture.md §8) moves
                         whole batches per call — one inner ``send``, one
                         fabric ``send_batch``, one device program. A
                         per-element singleton-send loop silently reverts the
                         hot path to the per-message regime this repo
                         refactored away. Per-message transforms that truly
                         cannot vectorize go through the explicit
                         ``repro.core.chunnel.per_message`` adapter (which
                         contains the one sanctioned per-element loop);
                         grouping loops that call ``.send_batch`` per
                         destination stay legal.

Hot classes: ``Fabric``/``Endpoint``/``Broker`` by name, anything named
``*DP``/``*Datapath``, anything deriving from a base so named (nested class
definitions included), plus the observability aggregation classes
(``MetricsFederator``/``SLOEngine``/``MetricsPublisher``) — their
``observe``/``view``/``merged``/``publish`` methods run once per control
tick over every member/SLO, so a per-element delivery call or a span per
loop iteration there multiplies by fleet size exactly like a per-message
loop on the data plane. Hot methods: send / recv / send_batch / recv_many /
send_many / publish_batch / observe / view / merged / publish.
"""
from __future__ import annotations

import ast
from typing import List

from .engine import Module, analyzer
from .findings import Finding

HOT_CLASS_NAMES = {"Fabric", "Endpoint", "Broker",
                   "MetricsFederator", "SLOEngine", "MetricsPublisher"}
HOT_METHODS = {"send", "recv", "send_batch", "recv_many", "send_many",
               "publish_batch", "observe", "view", "merged", "publish"}
DELIVERY_ATTRS = {"send", "put", "put_nowait", "publish", "request"}

_LOOPS = (ast.For, ast.While, ast.ListComp, ast.SetComp, ast.GeneratorExp,
          ast.DictComp)


def _base_names(cls: ast.ClassDef) -> List[str]:
    names = [cls.name]
    for b in cls.bases:
        if isinstance(b, ast.Name):
            names.append(b.id)
        elif isinstance(b, ast.Attribute):
            names.append(b.attr)
    return names


def _is_hot_class(cls: ast.ClassDef) -> bool:
    return any(n in HOT_CLASS_NAMES or n.endswith("DP") or "Datapath" in n
               for n in _base_names(cls))


def _delivery_calls(loop: ast.AST) -> List[ast.Call]:
    out = []
    for sub in ast.walk(loop):
        if (isinstance(sub, ast.Call) and isinstance(sub.func, ast.Attribute)
                and sub.func.attr in DELIVERY_ATTRS):
            out.append(sub)
    return out


@analyzer
def check_per_message_hot_path(mod: Module) -> List[Finding]:
    out: List[Finding] = []
    for node in ast.walk(mod.tree):
        if not (isinstance(node, ast.ClassDef) and _is_hot_class(node)):
            continue
        for item in node.body:
            if not (isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef))
                    and item.name in HOT_METHODS):
                continue
            seen = set()  # a call inside nested loops reports once
            for sub in ast.walk(item):
                if not isinstance(sub, _LOOPS):
                    continue
                for call in _delivery_calls(sub):
                    if (call.lineno, call.col_offset) in seen:
                        continue
                    seen.add((call.lineno, call.col_offset))
                    out.append(Finding(
                        "per-message-hot-path", mod.path, call.lineno,
                        call.col_offset,
                        f"{node.name}.{item.name} delivers per element "
                        f"(.{call.func.attr} inside a loop) — batch it: one "
                        "inner send / fabric send_batch per call, or lift a "
                        "scalar transform with repro.core.chunnel.per_message"))
    return out


#: tracer calls that allocate a Span — forbidden per message on the data
#: plane; ``record_batch`` (one tuple per batch) and ``.event`` stay legal
SPAN_CTORS = {"span", "begin_span", "start_span"}


def _span_calls(loop: ast.AST) -> List[ast.Call]:
    out = []
    for sub in ast.walk(loop):
        if (isinstance(sub, ast.Call) and isinstance(sub.func, ast.Attribute)
                and sub.func.attr in SPAN_CTORS):
            out.append(sub)
    return out


@analyzer
def check_span_in_hot_loop(mod: Module) -> List[Finding]:
    """Observability counterpart of ``per-message-hot-path``: span objects
    (dict attrs, event lists, stack pushes) in a per-message loop would eat
    the <10% enabled-tracing budget ``bench_overhead`` gates. Batch-level
    spans (outside any loop) and ``record_batch``/``event`` are fine."""
    out: List[Finding] = []
    for node in ast.walk(mod.tree):
        if not (isinstance(node, ast.ClassDef) and _is_hot_class(node)):
            continue
        for item in node.body:
            if not (isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef))
                    and item.name in HOT_METHODS):
                continue
            seen = set()
            for sub in ast.walk(item):
                if not isinstance(sub, _LOOPS):
                    continue
                for call in _span_calls(sub):
                    if (call.lineno, call.col_offset) in seen:
                        continue
                    seen.add((call.lineno, call.col_offset))
                    out.append(Finding(
                        "span-in-hot-loop", mod.path, call.lineno,
                        call.col_offset,
                        f"{node.name}.{item.name} creates a span per loop "
                        f"iteration (.{call.func.attr}) — record one "
                        "TRACER.record_batch per batch instead; spans are "
                        "reserved for control-plane phases"))
    return out
