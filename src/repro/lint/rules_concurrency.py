"""Concurrency analyzer: lock graphs, blocking-under-lock, unguarded writes.

Everything here is per-module and per-class, driven by the repo's actual
threading idiom: locks live as ``self._lock = threading.Lock()`` attributes
(or module-level ``NAME = threading.Lock()``) and are held via ``with``.
Three rules:

  lock-order           nested ``with`` acquisitions define a directed graph
                       over locks; a cycle means two code paths can acquire
                       the same pair in opposite orders (classic deadlock).
                       Re-acquiring a held non-reentrant Lock/Condition on
                       the same path is reported immediately.
  blocking-under-lock  a call that can block — ``time.sleep``, thread
                       ``join``, ``queue.get``, fabric/RPC ``send``/``recv``/
                       ``request``, ``wait`` on events/barriers, any KV
                       ``transact*``, or a caller-supplied callable — made
                       while a lock is held turns that lock into a
                       convoy/deadlock hazard. ``cond.wait()`` on the
                       condition currently held is the sanctioned idiom and
                       is not flagged. Closures passed to a PESSIMISTIC
                       ``.transact(fn)`` are analyzed as if they held the
                       store lock, because they do (rendezvous.KVStore).
  unguarded-attr       in a class that owns a lock, a plain ``self.x = ...``
                       (or ``self.x[k] = ...``) outside any ``with lock:``
                       in a non-``__init__`` method, where other methods also
                       touch ``x``, bypasses the discipline the lock exists
                       for. In a class that spawns threads at itself
                       (``threading.Thread(target=self.m)``), writes inside
                       the thread-target methods get the same treatment even
                       without a lock attribute.

The analysis is intentionally shallow (no inter-procedural lock tracking
beyond txn closures and thread-target transitive self-calls): it is tuned to
have zero false positives on this codebase's idiom, with ``# lint: allow``
carrying the documented exceptions (pessimistic transactions, the LockedConn
switch point).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from .engine import Module, analyzer
from .findings import Finding
from .rules_compat import collect_import_aliases

LOCK_FACTORIES = {"Lock", "RLock", "Condition"}
QUEUE_FACTORIES = {"Queue", "SimpleQueue", "LifoQueue", "PriorityQueue"}

#: method names that can block the calling thread (receiver-independent)
BLOCKING_METHODS = {"recv", "request", "transact", "try_transact",
                    "transact_retry", "send"}
INIT_METHODS = {"__init__", "__post_init__"}


def _resolves_to(aliases: Dict[str, str], node: ast.AST, dotted: str) -> bool:
    return _dotted(aliases, node) == dotted


def _dotted(aliases: Dict[str, str], node: ast.AST) -> Optional[str]:
    """Resolve a Name/Attribute chain through the module's import aliases."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    root = aliases.get(node.id)
    if root is None:
        return None
    return ".".join([root] + list(reversed(parts)))


def _factory_kind(aliases: Dict[str, str], call: ast.AST,
                  factories: Set[str], module: str) -> Optional[str]:
    """'Lock' for ``threading.Lock()`` / ``Lock()`` (aliased), etc."""
    if not isinstance(call, ast.Call):
        return None
    d = _dotted(aliases, call.func)
    if d and d.startswith(module + ".") and d.split(".")[-1] in factories:
        return d.split(".")[-1]
    return None


def _self_attr(node: ast.AST) -> Optional[str]:
    """'x' for ``self.x``; None otherwise."""
    if (isinstance(node, ast.Attribute) and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        return node.attr
    return None


def _write_target_attr(target: ast.AST) -> Optional[str]:
    """Attr name written by an assignment target: self.x or self.x[...]."""
    a = _self_attr(target)
    if a is not None:
        return a
    if isinstance(target, ast.Subscript):
        return _self_attr(target.value)
    return None


class _ClassInfo:
    def __init__(self, node: ast.ClassDef, aliases: Dict[str, str]):
        self.node = node
        self.name = node.name
        self.methods: Dict[str, ast.FunctionDef] = {
            m.name: m for m in node.body
            if isinstance(m, (ast.FunctionDef, ast.AsyncFunctionDef))}
        self.lock_attrs: Dict[str, str] = {}
        self.queue_attrs: Set[str] = set()
        self.thread_attrs: Set[str] = set()
        self.thread_targets: Set[str] = set()
        for sub in ast.walk(node):
            if isinstance(sub, ast.Assign) and len(sub.targets) == 1:
                attr = _self_attr(sub.targets[0])
                if attr:
                    kind = _factory_kind(aliases, sub.value, LOCK_FACTORIES,
                                         "threading")
                    if kind:
                        self.lock_attrs[attr] = kind
                    elif _factory_kind(aliases, sub.value, QUEUE_FACTORIES,
                                       "queue"):
                        self.queue_attrs.add(attr)
                    elif _factory_kind(aliases, sub.value,
                                       {"Thread", "Timer"}, "threading"):
                        self.thread_attrs.add(attr)
            if isinstance(sub, ast.Call) and _factory_kind(
                    aliases, sub, {"Thread", "Timer"}, "threading"):
                for kw in sub.keywords:
                    if kw.arg == "target":
                        t = _self_attr(kw.value)
                        if t:
                            self.thread_targets.add(t)
        # transitive: self.m() called from a thread target also runs there
        work = list(self.thread_targets)
        while work:
            m = self.methods.get(work.pop())
            if m is None:
                continue
            for sub in ast.walk(m):
                if isinstance(sub, ast.Call):
                    callee = _self_attr(sub.func)
                    if callee in self.methods and callee not in self.thread_targets:
                        self.thread_targets.add(callee)
                        work.append(callee)


class _HeldVisitor(ast.NodeVisitor):
    """Walk one function tracking which locks are held; emit blocking/edge
    info. ``held`` entries are (key, kind, display) tuples."""

    def __init__(self, mod: Module, aliases: Dict[str, str],
                 cls: Optional[_ClassInfo], module_locks: Dict[str, str],
                 fn: ast.FunctionDef, edges: Dict[Tuple[str, str], int],
                 out: List[Finding], initial_held=None):
        self.mod = mod
        self.aliases = aliases
        self.cls = cls
        self.module_locks = module_locks
        self.fn = fn
        self.edges = edges
        self.out = out
        self.held: List[Tuple[str, str, str]] = list(initial_held or [])
        a = fn.args
        self.params = {p.arg for p in a.posonlyargs + a.args + a.kwonlyargs
                       if p.arg != "self"}
        self.local_defs: Dict[str, ast.FunctionDef] = {
            n.name: n for n in ast.walk(fn)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
            and n is not fn}

    # -- lock identification -------------------------------------------------
    def _lock_of(self, expr: ast.AST) -> Optional[Tuple[str, str, str]]:
        attr = _self_attr(expr)
        if attr and self.cls and attr in self.cls.lock_attrs:
            return (f"{self.cls.name}.{attr}", self.cls.lock_attrs[attr],
                    f"self.{attr}")
        if isinstance(expr, ast.Name) and expr.id in self.module_locks:
            return (f"<module>.{expr.id}", self.module_locks[expr.id], expr.id)
        return None

    def visit_With(self, node: ast.With) -> None:
        acquired = []
        for item in node.items:
            lk = self._lock_of(item.context_expr)
            if lk is None:
                continue
            for held_key, held_kind, held_disp in self.held:
                if held_key == lk[0]:
                    if lk[1] in ("Lock", "Condition"):
                        self.out.append(Finding(
                            "lock-order", self.mod.path, node.lineno,
                            node.col_offset,
                            f"{lk[2]} ({lk[1]}) re-acquired while already "
                            "held — non-reentrant: this deadlocks"))
                else:
                    self.edges.setdefault((held_key, lk[0]), node.lineno)
            acquired.append(lk)
        self.held.extend(acquired)
        self.generic_visit(node)
        for _ in acquired:
            self.held.pop()

    visit_AsyncWith = visit_With

    def visit_FunctionDef(self, node) -> None:
        # nested defs do not inherit the held set at their *call* site; they
        # are analyzed separately (txn closures get the store lock injected)
        return

    visit_AsyncFunctionDef = visit_FunctionDef
    visit_Lambda = visit_FunctionDef

    # -- blocking calls --------------------------------------------------------
    def _flag(self, node: ast.AST, what: str) -> None:
        _, _, disp = self.held[-1]
        self.out.append(Finding(
            "blocking-under-lock", self.mod.path, node.lineno,
            node.col_offset, f"{what} while holding {disp}"))

    def visit_Call(self, node: ast.Call) -> None:
        # pessimistic txn closures: fn passed to .transact runs LOCKED
        if (isinstance(node.func, ast.Attribute)
                and node.func.attr == "transact" and node.args
                and isinstance(node.args[0], ast.Name)
                and node.args[0].id in self.local_defs):
            inner = self.local_defs[node.args[0].id]
            v = _HeldVisitor(
                self.mod, self.aliases, self.cls, self.module_locks, inner,
                self.edges, self.out,
                initial_held=[("<kv-store>", "RLock",
                               "the KV store lock (pessimistic transact)")])
            for stmt in inner.body:
                v.visit(stmt)
        if self.held:
            self._check_blocking(node)
        self.generic_visit(node)

    def _check_blocking(self, node: ast.Call) -> None:
        f = node.func
        if isinstance(f, ast.Name):
            if f.id in self.params:
                self._flag(node, f"call to caller-supplied {f.id}()")
            elif self.aliases.get(f.id) == "time.sleep":
                self._flag(node, "time.sleep()")
            return
        if not isinstance(f, ast.Attribute):
            return
        meth, recv = f.attr, f.value
        if _resolves_to(self.aliases, f, "time.sleep"):
            self._flag(node, "time.sleep()")
        elif meth == "wait":
            lk = self._lock_of(recv)
            if lk is not None and any(h[0] == lk[0] for h in self.held):
                return  # cond.wait() on the held condition releases it
            self._flag(node, f".{meth}()")
        elif meth == "join":
            attr = _self_attr(recv)
            if self.cls and attr in self.cls.thread_attrs:
                self._flag(node, f"thread join self.{attr}.join()")
        elif meth == "get":
            attr = _self_attr(recv)
            is_queue = self.cls and attr in self.cls.queue_attrs
            has_timeout = any(kw.arg == "timeout" for kw in node.keywords)
            if is_queue or has_timeout:
                self._flag(node, f".get() on a queue")
        elif meth in BLOCKING_METHODS:
            self._flag(node, f".{meth}()")


def _analyze_writes(mod: Module, cls: _ClassInfo,
                    out: List[Finding]) -> None:
    """unguarded-attr for one class."""
    if not cls.lock_attrs and not cls.thread_targets:
        return
    accessed_in: Dict[str, Set[str]] = {}
    for mname, fn in cls.methods.items():
        for sub in ast.walk(fn):
            attr = _self_attr(sub)
            if attr:
                accessed_in.setdefault(attr, set()).add(mname)

    for mname, fn in cls.methods.items():
        if mname in INIT_METHODS:
            continue
        writes: List[Tuple[str, int, int, bool]] = []

        def walk(node: ast.AST, locked: bool) -> None:
            if isinstance(node, ast.With):
                now_locked = locked or any(
                    _self_attr(i.context_expr) in cls.lock_attrs
                    for i in node.items)
                for child in ast.iter_child_nodes(node):
                    walk(child, now_locked)
                return
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
                return
            targets: List[ast.AST] = []
            if isinstance(node, ast.Assign):
                targets = node.targets
            elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
                targets = [node.target]
            elif isinstance(node, ast.Delete):
                targets = node.targets
            for t in targets:
                attr = _write_target_attr(t)
                if attr and attr not in cls.lock_attrs:
                    writes.append((attr, node.lineno, node.col_offset, locked))
            for child in ast.iter_child_nodes(node):
                walk(child, locked)

        for stmt in fn.body:
            walk(stmt, False)
        for attr, lineno, col, locked in writes:
            if locked:
                continue
            others = accessed_in.get(attr, set()) - {mname} - INIT_METHODS
            if not others:
                continue
            if cls.lock_attrs:
                out.append(Finding(
                    "unguarded-attr", mod.path, lineno, col,
                    f"{cls.name}.{mname} writes self.{attr} without holding "
                    f"the class lock, but {', '.join(sorted(others))} also "
                    "touches it"))
            elif mname in cls.thread_targets:
                out.append(Finding(
                    "unguarded-attr", mod.path, lineno, col,
                    f"{cls.name}.{mname} runs on a spawned thread and writes "
                    f"self.{attr} with no lock, but "
                    f"{', '.join(sorted(others))} also touches it"))


def _cycle_findings(mod: Module, edges: Dict[Tuple[str, str], int]
                    ) -> List[Finding]:
    graph: Dict[str, Set[str]] = {}
    for a, b in edges:
        graph.setdefault(a, set()).add(b)
    out: List[Finding] = []
    reported = set()

    def dfs(start: str, node: str, path: List[str]) -> None:
        for nxt in graph.get(node, ()):
            if nxt == start:
                cyc = tuple(sorted(path + [nxt]))
                if cyc not in reported:
                    reported.add(cyc)
                    line = edges.get((node, nxt), 0)
                    out.append(Finding(
                        "lock-order", mod.path, line, 0,
                        "lock-order inversion: "
                        + " -> ".join(path + [nxt])
                        + " closes a cycle — two paths acquire these locks "
                        "in opposite orders"))
            elif nxt not in path:
                dfs(start, nxt, path + [nxt])

    for n in list(graph):
        dfs(n, n, [n])
    return out


@analyzer
def check_concurrency(mod: Module) -> List[Finding]:
    aliases = collect_import_aliases(mod.tree)
    out: List[Finding] = []
    edges: Dict[Tuple[str, str], int] = {}

    module_locks: Dict[str, str] = {}
    for node in mod.tree.body:
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)):
            kind = _factory_kind(aliases, node.value, LOCK_FACTORIES,
                                 "threading")
            if kind:
                module_locks[node.targets[0].id] = kind

    def run_fn(fn, cls: Optional[_ClassInfo]) -> None:
        v = _HeldVisitor(mod, aliases, cls, module_locks, fn, edges, out)
        for stmt in fn.body:
            v.visit(stmt)

    for node in mod.tree.body:
        if isinstance(node, ast.ClassDef):
            cls = _ClassInfo(node, aliases)
            for fn in cls.methods.values():
                run_fn(fn, cls)
            _analyze_writes(mod, cls, out)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            run_fn(node, None)

    out.extend(_cycle_findings(mod, edges))
    return out
