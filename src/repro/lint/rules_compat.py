"""Compat-boundary rule: version-gated JAX symbols stay in src/repro/compat/.

The ROADMAP rule this enforces: the repo supports JAX 0.4.37 through 0.6.x,
and every symbol whose name/location/semantics moved across that range is
wrapped once in ``repro.compat``. A direct use anywhere else works on the
developer's JAX and breaks on the other floor — in CI at best, at a user's
site at worst. The checker is import-resolution-aware: it builds the module's
alias table from its ``import``/``from`` statements and resolves dotted
chains back to their roots, so ``from jax.experimental.shard_map import
shard_map`` and ``import jax.experimental.shard_map as smap`` are both caught
while ``compat.shard_map`` (the sanctioned wrapper) is not.

Gated symbols (see compat/jaxapi.py for what moved where):

  shard_map            jax.experimental.shard_map -> jax.shard_map (0.6)
  AxisType             new in 0.5.x (explicit-sharding mesh axis types)
  set_mesh/use_mesh    0.5+ context-mesh API (0.4 uses mesh context managers)
  get_abstract_mesh    0.5+
  make_mesh(axis_types=...)   the kwarg is 0.5+; bare make_mesh is fine
  cost_analysis        Compiled.cost_analysis() return shape moved
  lax.axis_size        moved/renamed across the range
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional

from .engine import Module, analyzer
from .findings import Finding

GATED_TERMINALS = {"shard_map", "AxisType", "set_mesh", "use_mesh",
                   "get_abstract_mesh"}
GATED_EXACT = {"jax.lax.axis_size"}


def collect_import_aliases(tree: ast.AST) -> Dict[str, str]:
    """Local name -> fully dotted path it denotes, from import statements.

    ``import jax.lax`` binds ``jax``; ``from jax import lax as L`` binds
    ``L`` -> ``jax.lax``; relative imports are ignored.
    """
    aliases: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.asname:
                    aliases[a.asname] = a.name
                else:
                    aliases[a.name.split(".")[0]] = a.name.split(".")[0]
        elif isinstance(node, ast.ImportFrom) and node.level == 0 and node.module:
            for a in node.names:
                if a.name == "*":
                    continue
                aliases[a.asname or a.name] = f"{node.module}.{a.name}"
    return aliases


def _resolve(aliases: Dict[str, str], node: ast.AST) -> Optional[str]:
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    root = aliases.get(node.id)
    if root is None:
        return None
    return ".".join([root] + list(reversed(parts)))


def _in_compat(path: str) -> bool:
    norm = path.replace("\\", "/")
    return "repro/compat/" in norm or norm.startswith("compat/")


def _is_gated(dotted: str) -> Optional[str]:
    if not (dotted == "jax" or dotted.startswith("jax.")):
        return None
    if dotted in GATED_EXACT:
        return dotted
    last = dotted.split(".")[-1]
    if last in GATED_TERMINALS:
        return dotted
    return None


class _CompatVisitor(ast.NodeVisitor):
    def __init__(self, mod: Module, aliases: Dict[str, str],
                 out: List[Finding]):
        self.mod = mod
        self.aliases = aliases
        self.out = out

    def _finding(self, node: ast.AST, what: str) -> None:
        self.out.append(Finding(
            "compat-boundary", self.mod.path, node.lineno, node.col_offset,
            f"{what} is version-gated across the supported JAX range — "
            "go through repro.compat (ROADMAP: no file outside "
            "src/repro/compat/ touches a gated symbol)"))

    def visit_Call(self, node: ast.Call) -> None:
        d = _resolve(self.aliases, node.func)
        if (d and (d == "jax" or d.startswith("jax."))
                and d.split(".")[-1] == "make_mesh"
                and any(kw.arg == "axis_types" for kw in node.keywords)):
            self._finding(node, f"{d}(axis_types=...)")
        if (isinstance(node.func, ast.Attribute)
                and node.func.attr == "cost_analysis"):
            recv = _resolve(self.aliases, node.func.value)
            if recv is None or not recv.startswith("repro.compat"):
                self._finding(node, ".cost_analysis()")
        self.generic_visit(node)

    def visit_Attribute(self, node: ast.Attribute) -> None:
        d = _resolve(self.aliases, node)
        gated = _is_gated(d) if d else None
        if gated and gated.split(".")[-1] != "cost_analysis":
            self._finding(node, gated)
            return  # don't re-flag inner segments of the same chain
        self.generic_visit(node)

    def visit_Name(self, node: ast.Name) -> None:
        if not isinstance(node.ctx, ast.Load):
            return
        d = self.aliases.get(node.id)
        if d and _is_gated(d):
            # a bare name bound BY IMPORT to a gated jax symbol
            self._finding(node, d)


@analyzer
def check_compat_boundary(mod: Module) -> List[Finding]:
    if _in_compat(mod.path):
        return []
    aliases = collect_import_aliases(mod.tree)
    out: List[Finding] = []
    # flag gated from-imports at the import site too (the import alone is
    # already a floor break when the symbol moved modules)
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.ImportFrom) and node.level == 0 and node.module:
            for a in node.names:
                full = f"{node.module}.{a.name}"
                if _is_gated(full):
                    out.append(Finding(
                        "compat-boundary", mod.path, node.lineno,
                        node.col_offset,
                        f"import of version-gated {full} — go through "
                        "repro.compat"))
    _CompatVisitor(mod, aliases, out).visit(mod.tree)
    # dedupe per (line, message)
    seen, uniq = set(), []
    for f in out:
        key = (f.line, f.message)
        if key not in seen:
            seen.add(key)
            uniq.append(f)
    return uniq
