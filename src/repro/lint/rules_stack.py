"""Stack verifier: static + runtime checks on chunnel/stack definitions.

Static half (AST, runs over every linted file): migration-hook signatures.
``ConnHandle._do_swap`` calls ``migrate_state(old_datapath)`` and duck-types
``restore_state(state)``; ``ReconfigParticipant`` calls ``apply_state(state)``.
A hook with the wrong arity only explodes mid-swap — exactly the moment the
paper promises is safe — so we reject it at lint time.

Runtime half (``verify_stack``): instantiable checks on a real ``Stack``
object, reached via ``python -m repro.lint --stacks`` and the tests:

  stack-dead-option         a Select combination the Stack silently drops
                            (Stack.options() swallows StackTypeError combos;
                            a dead alternative is almost always a typo)
  stack-capability-closure  two options differ in an exact capability carried
                            by a non-multilateral chunnel — the runtime could
                            swap unilaterally and break the wire contract
  stack-swap-alignment      one chunnel name maps to different classes across
                            options (migrate_state aligns old->new state BY
                            NAME), or is duplicated within one option
  stack-semantic-order      semantic classes out of order top-down (e.g.
                            reliability above compression re-adds redundancy
                            the compressor just removed)
  stack-migrate-signature   (runtime variant) a shipped chunnel class overrides
                            a migration hook with the wrong arity
"""
from __future__ import annotations

import ast
import inspect
from typing import Dict, List

from .engine import Module, analyzer
from .findings import Finding

_MIGRATION_HOOKS = {
    "migrate_state": "(self, old)",
    "apply_state": "(self, state)",
    "restore_state": "(self, state)",
}

#: semantic class order, TOP of the stack first. A chunnel's classes are the
#: ``<feature>:`` prefixes of its capability labels; a class earlier in this
#: list must never sit *below* a later one. Unknown features are skipped.
SEMANTIC_ORDER = [
    "serialize",
    "order",
    "compression",
    "encryption",
    "reliability",
    "route",
    "layout",
    "transport",
    "wire",
    "pubsub",
]


def _hook_arity_ok(n_pos: int, has_vararg: bool, hook: str) -> bool:
    return n_pos == 2 and not has_vararg


@analyzer
def check_migration_signatures(mod: Module) -> List[Finding]:
    out: List[Finding] = []
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.ClassDef):
            continue
        for item in node.body:
            if not isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            expected = _MIGRATION_HOOKS.get(item.name)
            if expected is None:
                continue
            if any(isinstance(d, ast.Name) and d.id == "staticmethod"
                   for d in item.decorator_list):
                continue
            a = item.args
            n_pos = len(a.posonlyargs) + len(a.args)
            if not _hook_arity_ok(n_pos, a.vararg is not None, item.name):
                out.append(Finding(
                    "stack-migrate-signature", mod.path, item.lineno,
                    item.col_offset,
                    f"{node.name}.{item.name} must take exactly {expected} — "
                    f"the swap machinery calls it with one argument"))
    return out


# ---------------------------------------------------------------------------
# Runtime stack verification
# ---------------------------------------------------------------------------


def _classes_of(ch) -> List[str]:
    feats = []
    for cap in ch.capabilities():
        feat = cap.label.split(":", 1)[0] if ":" in cap.label else None
        if feat in SEMANTIC_ORDER and feat not in feats:
            feats.append(feat)
    return feats


def verify_stack(stack, name: str = "stack") -> List[Finding]:
    """Verify a real ``repro.core.Stack`` (or anything with ``.entries`` and
    ``.options()``). Findings use the synthetic path ``<stack:name>``."""
    from repro.core.stack import ConcreteStack, StackTypeError, _expand

    path = f"<stack:{name}>"

    def finding(rule: str, msg: str) -> Finding:
        return Finding(rule, path, 0, 0, msg)

    out: List[Finding] = []

    # dead options: re-run the expansion Stack.options() silently filters
    for combo in _expand(tuple(stack.entries)):
        try:
            ConcreteStack(combo)
        except StackTypeError as e:
            out.append(finding(
                "stack-dead-option",
                "Select combination [" + " -> ".join(c.name for c in combo)
                + f"] can never instantiate: {e}"))

    options = stack.options()

    # migration hook arity on every shipped chunnel class
    seen_classes = set()
    for opt in options:
        for ch in opt.chunnels:
            cls = type(ch)
            if cls in seen_classes:
                continue
            seen_classes.add(cls)
            for hook, expected in _MIGRATION_HOOKS.items():
                fn = getattr(cls, hook, None)
                if fn is None:
                    continue
                try:
                    params = list(inspect.signature(fn).parameters.values())
                except (TypeError, ValueError):
                    continue
                pos = [p for p in params if p.kind in
                       (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD)]
                var = any(p.kind == p.VAR_POSITIONAL for p in params)
                if not _hook_arity_ok(len(pos), var, hook):
                    out.append(finding(
                        "stack-migrate-signature",
                        f"{cls.__name__}.{hook} must take exactly {expected}"))

    # swap alignment: name -> class consistent across options, unique within
    name_class: Dict[str, type] = {}
    for i, opt in enumerate(options):
        names_here = set()
        for ch in opt.chunnels:
            if ch.name in names_here:
                out.append(finding(
                    "stack-swap-alignment",
                    f"option {i} uses chunnel name {ch.name!r} twice — "
                    "migrate_state aligns old->new state by name"))
            names_here.add(ch.name)
            prev = name_class.setdefault(ch.name, type(ch))
            if prev is not type(ch):
                out.append(finding(
                    "stack-swap-alignment",
                    f"chunnel name {ch.name!r} maps to {prev.__name__} in one "
                    f"option and {type(ch).__name__} in another — a swap "
                    "would hand one class's state to the other"))

    # capability closure: exact labels that differ between two options must
    # come from multilateral chunnels (the swap needs negotiated agreement)
    for i in range(len(options)):
        for j in range(i + 1, len(options)):
            a, b = options[i], options[j]
            diff = (a.capabilities().exact_labels()
                    ^ b.capabilities().exact_labels())
            if not diff:
                continue
            for opt, idx in ((a, i), (b, j)):
                for ch in opt.chunnels:
                    bad = [l for l in ch.capabilities().exact_labels()
                           if l in diff]
                    if bad and not ch.multilateral:
                        out.append(finding(
                            "stack-capability-closure",
                            f"options {i} and {j} differ in exact "
                            f"capabilities {sorted(bad)} carried by "
                            f"non-multilateral {ch.name!r} — swapping would "
                            "change the wire contract without agreement"))

    # semantic ordering, top-down within each option
    for i, opt in enumerate(options):
        chs = list(opt.chunnels)
        for u in range(len(chs)):
            for v in range(u + 1, len(chs)):
                for cu in _classes_of(chs[u]):
                    for cv in _classes_of(chs[v]):
                        if SEMANTIC_ORDER.index(cu) > SEMANTIC_ORDER.index(cv):
                            out.append(finding(
                                "stack-semantic-order",
                                f"option {i}: {chs[u].name!r} ({cu}) sits "
                                f"above {chs[v].name!r} ({cv}) but class "
                                f"{cu!r} belongs below {cv!r}"))
    # dedupe (the pairwise loops can repeat a message)
    seen, uniq = set(), []
    for f in out:
        if f.message not in seen:
            seen.add(f.message)
            uniq.append(f)
    return uniq


def builtin_stacks() -> Dict[str, object]:
    """The repo's shipped reconfigurable stacks, built for verification.

    Imports are local: comm.chunnels pulls in jax, and the router stack needs
    a throwaway fabric endpoint.
    """
    from repro.comm.chunnels import TRANSPORTS
    from repro.core import Fabric, Select, make_stack
    from repro.serving.router import routing_stack

    fab = Fabric()
    ep = fab.register("lint-probe")
    return {
        "router": routing_stack(ep, ["b0", "b1"]),
        "trainer-transports": make_stack(
            Select(*[cls() for cls in TRANSPORTS.values()])),
    }
