"""CLI: ``python -m repro.lint [paths...] [options]``.

Exit status: 0 when no unsuppressed findings remain, 1 otherwise (2 on bad
usage). Default target is the repo's ``src/repro`` tree. ``--stacks``
additionally builds the repo's shipped reconfigurable stacks (router Select,
trainer transport Select — imports jax) and runs the runtime stack verifier
over them. ``--json`` writes the full findings report for CI artifacts.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from .engine import RULES, lint_paths
from .findings import apply_baseline, load_baseline, write_baseline
from .rules_stack import builtin_stacks, verify_stack


def _default_root() -> Path:
    # src/repro/lint/__main__.py -> repo root is parents[3]
    here = Path(__file__).resolve()
    root = here.parents[3]
    return root if (root / "src" / "repro").is_dir() else Path.cwd()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.lint",
        description="static stack/concurrency/compat verification")
    ap.add_argument("paths", nargs="*",
                    help="files or directories (default: src/repro)")
    ap.add_argument("--strict", action="store_true",
                    help="exit non-zero on any unsuppressed finding")
    ap.add_argument("--stacks", action="store_true",
                    help="also verify the shipped reconfigurable stacks "
                         "(imports jax)")
    ap.add_argument("--json", metavar="OUT",
                    help="write a JSON findings report")
    ap.add_argument("--baseline", metavar="PATH",
                    help="drop findings recorded in this baseline file")
    ap.add_argument("--write-baseline", metavar="PATH",
                    help="record current findings as the new baseline")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule, doc in sorted(RULES.items()):
            print(f"{rule:26s} {doc}")
        return 0

    root = _default_root()
    paths = args.paths or [str(root / "src" / "repro")]
    findings, source_lines = lint_paths(paths, root=root)

    if args.baseline:
        findings = apply_baseline(findings, load_baseline(args.baseline),
                                  source_lines)
    if args.write_baseline:
        write_baseline(args.write_baseline, findings, source_lines)
        print(f"baseline: {len(findings)} finding(s) -> {args.write_baseline}")
        return 0

    stack_results = {}
    if args.stacks:
        for name, stack in builtin_stacks().items():
            fs = verify_stack(stack, name)
            stack_results[name] = len(fs)
            findings.extend(fs)

    for f in findings:
        print(f.format())

    if args.json:
        out = Path(args.json)
        out.parent.mkdir(parents=True, exist_ok=True)
        report = {
            "n_findings": len(findings),
            "strict": bool(args.strict),
            "paths": paths,
            "stacks_verified": stack_results,
            "findings": [f.to_json() for f in findings],
        }
        out.write_text(json.dumps(report, indent=2) + "\n")

    n = len(findings)
    tail = f" over {len(source_lines)} file(s)"
    if args.stacks:
        tail += f", {len(stack_results)} stack(s) verified"
    print(f"repro.lint: {n} finding(s){tail}")
    if n and args.strict:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
