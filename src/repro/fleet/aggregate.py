"""Aggregate: fold member records + external signals into ONE fleet snapshot.

The output of ``FleetAggregator.aggregate()`` is a plain dict that drops
straight into the existing policy machinery (``ReconfigController.tick``,
``above``/``below`` predicates, ``ScoredTarget`` scoring) — the fleet keys
are namespaced ``fleet.*`` and external signals ``ext.*``, so one registered
policy can combine them:

    Rule("high", above("fleet.offered_qps", 200), ...)
    Rule("spike", all_of(above("ext.spot_usd_per_h", 3.0),
                         below("fleet.offered_qps", 200)), ...)

Aggregate keys (the fleet policy API):

  fleet.members             fresh member count
  fleet.stale_members       roster entries whose heartbeat age exceeded ttl_s
  fleet.offered_qps         sum of member ``ops_per_s`` — the §7.3 signal
  fleet.bytes_per_s         sum of member byte rates
  fleet.ops                 sum of member op totals
  fleet.rtt_p50_s           qps-weighted mean of member p50s (None until fed)
  fleet.rtt_p95_s           max member p95 — the conservative quantile combine
  fleet.straggler_ratio     max member straggler_ratio (trainer fleets)
  fleet.qps_imbalance       max member qps / mean member qps (serving-plane
                            straggler view; 1.0 when balanced or empty)
  fleet.member_qps          {member: qps} detail for dashboards/audits
  fleet.heartbeat_age_s     oldest fresh heartbeat's age
  fleet.switches            sum of member switch counts (blip accounting)

plus every registered ``SignalSource``'s keys, merged verbatim. A failing
source is skipped (counted in ``signal_errors``) — a flaky carbon API must
not take the control loop down with it.
"""
from __future__ import annotations

import logging
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.rendezvous import KVStore
from repro.fleet.publish import fleet_conn_id, member_key, roster_key
from repro.fleet.signals import SignalSource

log = logging.getLogger(__name__)


class FleetAggregator:
    """Fold fleet member records into fleet metrics; merge signal sources.

    Args:
        store, fleet_id: where the publishers write.
        ttl_s: heartbeat age beyond which a member is stale and dropped from
            the aggregate (and, with ``expire=True``, removed from the store).
        sources: initial ``SignalSource``s (``add_source`` registers more).
        expire: physically delete stale records/roster entries — AND evict
            the member from the fleet's rendezvous membership map, so a
            crashed member stops blocking ``try_commit``'s unanimous-ack
            requirement and the fleet can keep switching (an evicted member
            that comes back rejoins from its next ``FleetMember.poll``). The
            expiry transaction re-checks freshness first — a member that
            republished between our read and the txn survives.
        plane: key-prefix namespace, matching the publishers'. Only the
            default ``"fleet"`` plane carries rendezvous membership, so the
            eviction side effect of expiry is skipped on any other plane.
        now: clock override for deterministic tests.
    """

    def __init__(self, store: KVStore, fleet_id: str, *, ttl_s: float = 1.0,
                 sources: Sequence[SignalSource] = (), expire: bool = True,
                 plane: str = "fleet",
                 now: Callable[[], float] = time.monotonic):
        self.store = store
        self.fleet_id = fleet_id
        self.ttl_s = ttl_s
        self.expire = expire
        self.plane = plane
        self.sources: List[SignalSource] = list(sources)
        self._now = now
        self.signal_errors = 0
        self.expired_total = 0

    def add_source(self, source: SignalSource) -> SignalSource:
        self.sources.append(source)
        return source

    # -- member view ----------------------------------------------------------
    def member_records(self, now: Optional[float] = None
                       ) -> Tuple[Dict[str, dict], List[str]]:
        """(fresh records by member, stale member names). Stale = roster entry
        with no record or a heartbeat older than ``ttl_s``."""
        now = self._now() if now is None else now
        roster = self.store.get(roster_key(self.fleet_id, self.plane)) or {}
        fresh: Dict[str, dict] = {}
        stale: List[str] = []
        for m in roster:
            rec = self.store.get(member_key(self.fleet_id, m, self.plane))
            if rec is not None and now - rec.get("at", 0.0) <= self.ttl_s:
                fresh[m] = rec
            else:
                stale.append(m)
        if stale and self.expire:
            self._expire(stale, now)
        return fresh, stale

    def _expire(self, members: List[str], now: float) -> None:
        members_map_key = f"{fleet_conn_id(self.fleet_id)}/members"
        # rendezvous membership only exists on the coordination plane; an
        # obs-plane aggregator expires records without touching 2PC state
        evict_rdv = self.plane == "fleet"

        def _fn(txn):
            dropped = evicted = 0
            roster = dict(txn.get(roster_key(self.fleet_id, self.plane)) or {})
            rdv = dict(txn.get(members_map_key) or {}) if evict_rdv else {}
            for m in members:
                rec = txn.get(member_key(self.fleet_id, m, self.plane))
                if rec is not None and now - rec.get("at", 0.0) <= self.ttl_s:
                    continue  # republished since we looked: not stale anymore
                roster.pop(m, None)
                # also evict from the rendezvous membership map: a crashed
                # member must not block try_commit's unanimous acks forever
                if evict_rdv:
                    evicted += rdv.pop(m, None) is not None
                txn.delete(member_key(self.fleet_id, m, self.plane))
                dropped += 1
            if dropped:   # a no-op put would still bump the roster version
                txn.put(roster_key(self.fleet_id, self.plane), roster)
            if evicted:
                txn.put(members_map_key, rdv)
            return dropped

        self.expired_total += self.store.transact_retry(_fn)

    # -- the fold -------------------------------------------------------------
    def aggregate(self, now: Optional[float] = None) -> dict:
        """One fleet-wide snapshot dict (see module docstring for the keys)."""
        now = self._now() if now is None else now
        fresh, stale = self.member_records(now)
        snaps = {m: rec.get("snapshot", {}) for m, rec in fresh.items()}
        qps = {m: float(s.get("ops_per_s") or 0.0) for m, s in snaps.items()}
        total_qps = sum(qps.values())
        mean_qps = total_qps / len(qps) if qps else 0.0

        def _sum(key: str) -> float:
            return float(sum(s.get(key) or 0.0 for s in snaps.values()))

        def _max(key: str, default=None):
            vals = [s.get(key) for s in snaps.values() if s.get(key) is not None]
            return max(vals) if vals else default

        # qps-weighted p50: members carrying the load dominate the combined
        # latency estimate; uniform weights when the fleet is idle
        p50_pairs = [(qps[m], s["rtt_p50_s"]) for m, s in snaps.items()
                     if s.get("rtt_p50_s") is not None]
        if p50_pairs:
            wsum = sum(w for w, _ in p50_pairs)
            p50 = (sum(w * v for w, v in p50_pairs) / wsum if wsum > 0
                   else sum(v for _, v in p50_pairs) / len(p50_pairs))
        else:
            p50 = None

        out: Dict[str, Any] = {
            "fleet.members": len(fresh),
            "fleet.stale_members": len(stale),
            "fleet.offered_qps": total_qps,
            "fleet.bytes_per_s": _sum("bytes_per_s"),
            "fleet.ops": _sum("ops"),
            "fleet.rtt_p50_s": p50,
            "fleet.rtt_p95_s": _max("rtt_p95_s"),
            "fleet.straggler_ratio": _max("straggler_ratio", 1.0),
            "fleet.qps_imbalance": (max(qps.values()) / mean_qps
                                    if qps and mean_qps > 0 else 1.0),
            "fleet.member_qps": qps,
            "fleet.heartbeat_age_s": (max(now - rec.get("at", now)
                                          for rec in fresh.values())
                                      if fresh else None),
            "fleet.switches": int(_sum("switches")),
        }
        for src in self.sources:
            try:
                out.update(src.read(now) or {})
            except Exception as e:
                # an external feed must not take the control loop down — but
                # the failure stays diagnosable: counted in signal_errors AND
                # logged at DEBUG (the compat probe pattern), never swallowed
                log.debug("signal source %r failed: %s",
                          getattr(src, "name", "?"), e)
                self.signal_errors += 1
        return out
