"""Act fleet-wide: ONE decision over the aggregate, committed in ONE epoch.

Without this layer, Bertha's §7.3 switch is per-client: N controllers over N
``ConnTelemetry``s each cross their own threshold at their own time, and the
fleet flaps independently. Here a single ``fleet_controller`` runs the policy
once over the ``FleetAggregator`` snapshot and drives the switch through the
rendezvous transition protocol (``propose_transition``/``vote``/
``try_commit``) — every member lands on the same stack in the same epoch, and
a member that never offered the target vetoes the whole transition (the §4.2
guarantee survives at fleet scope).

``FleetMember`` is a member's fleet-facing shim around its live
``ConnHandle``: ``join()`` registers through the rendezvous (late joiners
recover and adopt the committed stack, §5.3a), and ``poll()`` — called from
the member's own loop — heartbeats its publisher, votes on any pending
proposal (accept iff the fingerprint resolves in its negotiated option set),
and applies newly committed epochs to the local handle.
"""
from __future__ import annotations

import time
from typing import Any, Callable, List, Optional, Sequence

from repro.core import rendezvous
from repro.core.controller import (
    PolicyContext,
    ReconfigController,
    Rule,
    policy_rules,
    stack_candidates,
)
from repro.core.rendezvous import KVStore, TxnConflict
from repro.fleet.publish import FleetPublisher, fleet_conn_id


class FleetMember:
    """One endpoint's membership in a fleet: publish + vote + apply.

    Args:
        store, fleet_id, member: the fleet and our name in it.
        handle: the live ``ConnHandle`` fleet transitions reconfigure.
        stack: the member's negotiated ``Stack`` — its options are what we
            can vote for and switch to (fingerprints are structural, so
            equivalent stacks match across members).
        publisher: optional ``FleetPublisher`` heartbeated by ``poll()``.
    """

    def __init__(self, store: KVStore, fleet_id: str, member: str,
                 handle: Any, stack: Any, *,
                 publisher: Optional[FleetPublisher] = None):
        self.store = store
        self.fleet_id = fleet_id
        self.conn_id = fleet_conn_id(fleet_id)
        self.member = member
        self.handle = handle
        self.stack = stack
        self.publisher = publisher
        self.epoch = 0           # last committed epoch applied locally
        self.transitions: List[dict] = []   # audit: {"epoch", "fp", "applied"}
        self._unresolved_epoch: Optional[int] = None  # logged-once failures

    # -- membership -----------------------------------------------------------
    def join(self) -> rendezvous.JoinResult:
        """Register via the rendezvous (§5.3). If a stack is already
        committed, adopt it locally — a late joiner recovers the fleet's
        configuration without having negotiated."""
        options = self.stack.options()
        fps = [opt.fingerprint() for opt in options]
        descs = [opt.describe() for opt in options]

        def _compat(committed_desc: list) -> Optional[int]:
            names = [c["name"] for c in committed_desc]
            for i, opt in enumerate(options):
                if [c.name for c in opt.chunnels] == names:
                    return i
            return None

        res = rendezvous.join(self.store, self.conn_id, self.member,
                              fps, descs, _compat)
        self._adopt(res.stack_fp, res.epoch)
        return res

    def leave(self) -> int:
        if self.publisher is not None:
            self.publisher.retire()
        return rendezvous.leave(self.store, self.conn_id, self.member)

    # -- the member's loop ----------------------------------------------------
    def poll(self, now: Optional[float] = None) -> bool:
        """One pump of the member's fleet duties: heartbeat-publish telemetry,
        re-join if the fleet evicted us (heartbeat-TTL expiry while we were
        merely stalled — see ``FleetAggregator``), vote on any pending
        proposal, apply a newly committed epoch. Returns True if this poll
        reconfigured the local handle."""
        if self.publisher is not None:
            self.publisher.maybe_publish(now)
        if self.member not in (self.store.get(f"{self.conn_id}/members") or {}):
            self.join()
        self.vote_pending()
        return self.apply_committed()

    def vote_pending(self) -> Optional[bool]:
        """Vote on an in-flight proposal we haven't acked: accept iff the
        proposed fingerprint resolves in OUR negotiated options — a member
        that cannot run the target refuses, and ``try_commit`` aborts the
        whole transition (no member can be forced onto a stack it never
        offered). Returns the vote cast, or None if nothing was pending."""
        prop = self.store.get(f"{self.conn_id}/proposal")
        if prop is None or self.member in prop.get("acks", {}):
            return None
        accept = self.stack.find(prop["fp"]) is not None
        rendezvous.vote(self.store, self.conn_id, self.member,
                        prop["epoch"], accept)
        return accept

    def apply_committed(self) -> bool:
        """Adopt the committed stack if its epoch is newer than what we run."""
        cur = rendezvous.current_stack(self.store, self.conn_id)
        if cur is None or cur["epoch"] <= self.epoch:
            return False
        return self._adopt(cur["fp"], cur["epoch"])

    def _adopt(self, fp: str, epoch: int) -> bool:
        """Try to run the committed ``fp``; advance ``self.epoch`` ONLY when
        we actually run it. A fingerprint that doesn't resolve in our options
        (possible for a joiner whose stack matched the committed one by
        chunnel names but not fingerprints) must not be silently marked
        adopted — the epoch stays behind, the divergence is visible in
        ``transitions``, and any later committed epoch is still picked up."""
        if self.handle.stack.fingerprint() == fp:
            self.epoch = epoch
            return False
        opt = self.stack.find(fp)
        applied = opt is not None and bool(self.handle.reconfigure(opt))
        if applied:
            self.epoch = epoch
            self.transitions.append({"epoch": epoch, "fp": fp, "applied": True})
        elif self._unresolved_epoch != epoch:     # log the failure once
            self._unresolved_epoch = epoch
            self.transitions.append({"epoch": epoch, "fp": fp, "applied": False})
        return applied


def fleet_controller(
    store: KVStore,
    fleet_id: str,
    stack: Any,
    rules: Optional[Sequence[Rule]] = None,
    *,
    policy: Optional[str] = None,
    policy_params: Optional[dict] = None,
    default: Any = None,
    coordinator: str = "fleet-controller",
    vote_timeout_s: float = 2.0,
    retry_backoff_s: Optional[float] = None,
    pump: Optional[Callable[[], Any]] = None,
    poll_s: float = 0.002,
    **kw,
) -> ReconfigController:
    """A ``ReconfigController`` whose decisions commit FLEET-WIDE.

    Tick it with ``FleetAggregator.aggregate()`` snapshots. Pass EITHER an
    explicit ``rules`` list OR a registered ``policy`` name (the factory sees
    ``stack``'s options as scoreable candidates, exactly like
    ``conn_controller``). ``current()`` reads the committed fleet stack from
    the rendezvous, so the controller is stateless across restarts — a new
    coordinator picks up where the last one left off.

    ``switch(target)`` publishes a ``propose_transition``, then waits for the
    members' votes: ``pump`` (when given) is invoked while waiting — drive
    the members' ``poll()`` from it in single-threaded drivers and tests;
    without it the members are expected to poll from their own threads and we
    sleep ``poll_s`` between ``try_commit`` attempts. A concurrent proposal
    (``TxnConflict``) or any member's refusal reports the switch as
    not-committed. The controller's ``cooldown_s`` only damps COMMITTED
    switches, so failed attempts carry their own damping: after one, no new
    proposal is published for ``retry_backoff_s`` (default ``vote_timeout_s``)
    — an armed rule cannot drive a propose/abort storm, and a silent member
    costs at most one ``vote_timeout_s`` wait per backoff window.
    """
    if (rules is None) == (policy is None):
        raise ValueError("pass exactly one of rules= or policy=")
    if policy is not None:
        ctx = PolicyContext(candidates=stack_candidates(stack),
                            default=default,
                            params=dict(policy_params or {}))
        rules = policy_rules(policy, ctx)
    conn_id = fleet_conn_id(fleet_id)
    backoff_s = vote_timeout_s if retry_backoff_s is None else retry_backoff_s
    last_failed_at: List[float] = []

    def current() -> str:
        cur = rendezvous.current_stack(store, conn_id)
        return cur["fp"] if cur else stack.preferred().fingerprint()

    def switch(target: Any) -> bool:
        if last_failed_at and time.monotonic() - last_failed_at[0] < backoff_s:
            return False   # failed-attempt damping; see docstring
        try:
            epoch = rendezvous.propose_transition(
                store, conn_id, coordinator,
                target.fingerprint(), target.describe())
        except (TxnConflict, ValueError):
            # a transition is in flight, or no fleet has joined yet
            last_failed_at[:] = [time.monotonic()]
            return False
        t0 = time.monotonic()
        while True:
            if pump is not None:
                pump()
            r = rendezvous.try_commit(store, conn_id, epoch,
                                      vote_timeout_s, t0)
            if r is not None:
                if pump is not None:
                    pump()   # let members apply the committed epoch promptly
                if r:
                    last_failed_at.clear()
                else:
                    last_failed_at[:] = [time.monotonic()]
                return bool(r)
            if pump is None:
                time.sleep(poll_s)

    return ReconfigController(rules, switch, current, **kw)
