"""Publish: each fleet member's telemetry into the rendezvous KV store.

A ``FleetPublisher`` attaches to one ``ConnTelemetry`` (a serving client's
connection, a trainer job) and periodically writes a versioned,
heartbeat-stamped snapshot record under the fleet's key prefix:

  fleet/<fleet_id>/roster            {member: last_heartbeat}
  fleet/<fleet_id>/member/<name>     {member, seq, at, snapshot}

Records are written with the store's OPTIMISTIC transactions
(``KVStore.try_transact``): the roster is a shared read-modify-write, and N
publishers updating it concurrently is exactly the lost-update hazard the
version validation catches — conflicting publishers retry with backoff
(``publisher.conflicts`` counts them; tests drive this deliberately).

Staleness is by heartbeat AGE, not presence: a member that dies simply stops
stamping ``at``, and the ``FleetAggregator`` drops (and optionally expires)
it once the age exceeds the fleet TTL — no failure detector, no leases.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Optional

from repro.core.rendezvous import KVStore, TxnConflict


def fleet_conn_id(fleet_id: str) -> str:
    """The rendezvous connection id a fleet coordinates under — its committed
    stack/epoch lives at ``fleet/<fleet_id>/stack`` via the ordinary
    ``propose_transition``/``vote``/``try_commit`` machinery."""
    return f"fleet/{fleet_id}"


def roster_key(fleet_id: str, plane: str = "fleet") -> str:
    return f"{plane}/{fleet_id}/roster"


def member_key(fleet_id: str, member: str, plane: str = "fleet") -> str:
    return f"{plane}/{fleet_id}/member/{member}"


class FleetPublisher:
    """Periodically publish one member's telemetry snapshot into the fleet.

    Args:
        store, fleet_id, member: where and as whom to publish.
        telemetry: the ``ConnTelemetry`` to snapshot.
        period_s: minimum gap between publishes for ``maybe_publish`` (0 means
            every call); ``publish()`` always publishes.
        reset_window: whether our snapshot starts a new telemetry rate window.
            True when the publisher is the telemetry's ONLY snapshot consumer
            (fleet-managed connections with no local controller); False when a
            local controller also ticks this telemetry — rates then cover the
            interval since ITS last tick, and the two consumers don't fight
            over the window (see ``ConnTelemetry.snapshot``).
        plane: key-prefix namespace. The default ``"fleet"`` plane doubles as
            the rendezvous coordination prefix; the observability federation
            publishes metrics snapshots under ``"obs"`` so the two record
            streams never collide in one store.
        now: clock override for deterministic tests.
    """

    def __init__(self, store: KVStore, fleet_id: str, member: str,
                 telemetry: Any, *, period_s: float = 0.05,
                 reset_window: bool = True, max_retries: int = 32,
                 plane: str = "fleet",
                 now: Callable[[], float] = time.monotonic):
        self.store = store
        self.fleet_id = fleet_id
        self.member = member
        self.telemetry = telemetry
        self.period_s = period_s
        self.reset_window = reset_window
        self.max_retries = max_retries
        self.plane = plane
        self._now = now
        self.key = member_key(fleet_id, member, plane)
        self.roster = roster_key(fleet_id, plane)
        self.seq = 0            # version of OUR record (monotonic per member)
        self.published = 0
        self.conflicts = 0      # optimistic retries we personally paid
        self._last_pub: Optional[float] = None

    def publish(self, extra: Optional[Dict[str, Any]] = None,
                now: Optional[float] = None) -> dict:
        """Snapshot the telemetry and write the member record; returns the
        record. ``extra`` keys are merged into the snapshot (per-member
        signals the telemetry doesn't carry, e.g. a locally probed value)."""
        now = self._now() if now is None else now
        snap = self._snapshot()
        if extra:
            snap.update(extra)
        self.seq += 1
        rec = {"member": self.member, "seq": self.seq, "at": now,
               "snapshot": snap}

        def _fn(txn):
            roster = dict(txn.get(self.roster) or {})
            roster[self.member] = now
            txn.put(self.roster, roster)
            txn.put(self.key, rec)

        self.store.transact_retry(
            _fn, max_retries=self.max_retries,
            on_conflict=self._count_conflict)
        self.published += 1
        self._last_pub = now
        return rec

    def _snapshot(self) -> Dict[str, Any]:
        """What one published record carries. Subclasses (e.g. the obs-plane
        ``MetricsPublisher``) override this to ship richer payloads than a
        flat telemetry snapshot."""
        return dict(self.telemetry.snapshot(reset_window=self.reset_window))

    def _count_conflict(self) -> None:
        self.conflicts += 1

    def maybe_publish(self, now: Optional[float] = None,
                      extra: Optional[Dict[str, Any]] = None) -> Optional[dict]:
        """``publish()`` if at least ``period_s`` has passed; None otherwise.
        Call it from the data-plane loop — it is the heartbeat."""
        now = self._now() if now is None else now
        if self._last_pub is not None and now - self._last_pub < self.period_s:
            return None
        return self.publish(extra, now)

    def retire(self) -> None:
        """Remove this member's record and roster entry (clean leave — a
        crashed member instead ages out by heartbeat TTL)."""
        def _fn(txn):
            roster = dict(txn.get(self.roster) or {})
            roster.pop(self.member, None)
            txn.put(self.roster, roster)
            txn.delete(self.key)

        self.store.transact_retry(_fn, max_retries=self.max_retries,
                                  on_conflict=self._count_conflict)
