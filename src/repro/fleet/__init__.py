"""Fleet signal plane: publish → aggregate → act fleet-wide.

The missing layer between per-connection telemetry (``repro.core.telemetry``)
and policy scoring (``repro.core.cost``): every member publishes
heartbeat-stamped telemetry snapshots into the rendezvous KV store
(``FleetPublisher``), a ``FleetAggregator`` folds the fresh records plus
pluggable external ``SignalSource``s (carbon intensity, spot price, measured
link bandwidth) into ONE namespaced snapshot dict, and a ``fleet_controller``
runs the reconfiguration decision once over that aggregate — committing the
switch through the rendezvous epoch protocol so the whole fleet lands on the
same stack in the same epoch instead of N clients flapping independently.

See docs/architecture.md §6 for the lifecycle and the SignalSource guide.
"""
from repro.fleet.aggregate import FleetAggregator
from repro.fleet.controller import FleetMember, fleet_controller
from repro.fleet.publish import (
    FleetPublisher,
    fleet_conn_id,
    member_key,
    roster_key,
)
from repro.fleet.signals import (
    CallbackSignal,
    CarbonIntensitySignal,
    LinkBandwidthSignal,
    SignalError,
    SignalSource,
    SpotPriceSignal,
    StaticSignal,
    measure_link_bandwidth,
)

__all__ = [
    "CallbackSignal", "CarbonIntensitySignal", "FleetAggregator",
    "FleetMember", "FleetPublisher", "LinkBandwidthSignal", "SignalError",
    "SignalSource", "SpotPriceSignal", "StaticSignal", "fleet_conn_id",
    "fleet_controller", "measure_link_bandwidth", "member_key", "roster_key",
]
