"""External signal sources merged into fleet snapshots.

A ``SignalSource`` contributes namespaced keys (``ext.*``) to the snapshot
dict a ``FleetAggregator`` produces, so registered policies can write
predicates that COMBINE fleet aggregates with out-of-band signals — carbon
intensity, spot price, measured link bandwidth — without the controller core
knowing any of them exist (ROADMAP "Multi-source predicates"; cf. Morpheus:
the payoff of runtime specialization comes from a continuous shared view of
runtime signals feeding the decision).

Sources are read once per aggregation tick and must be cheap; anything slow
(a real HTTP carbon API, a bandwidth probe) caches internally and refreshes
on its own cadence (see ``LinkBandwidthSignal.refresh_s``).
"""
from __future__ import annotations

import logging
import time
from typing import Callable, Dict, Optional, Sequence

from repro.core.fabric import Fabric

log = logging.getLogger(__name__)


class SignalError(RuntimeError):
    """A signal source could not produce a value this tick.

    Typed so the aggregator (and tests) can tell an expected source outage
    from a programming error; carries the probe failure as ``__cause__`` when
    one triggered it."""


class SignalSource:
    """One external signal: ``read(now)`` returns namespaced snapshot keys.

    Implementations OWN their key namespace (conventionally ``ext.<what>``) —
    the aggregator merges the dicts verbatim, so two sources emitting the same
    key is a configuration error, not something the plane resolves."""

    #: human-readable source name (diagnostics; keys carry the namespace)
    name = "signal"

    def read(self, now: Optional[float] = None) -> Dict[str, float]:
        raise NotImplementedError


class StaticSignal(SignalSource):
    """Fixed values — config-pinned signals and deterministic tests."""

    def __init__(self, values: Dict[str, float], name: str = "static"):
        self.values = dict(values)
        self.name = name

    def read(self, now: Optional[float] = None) -> Dict[str, float]:
        return dict(self.values)


class CallbackSignal(SignalSource):
    """Adapter for an arbitrary ``fn(now) -> {key: value}``."""

    def __init__(self, fn: Callable[[Optional[float]], Dict[str, float]],
                 name: str = "callback"):
        self.fn = fn
        self.name = name

    def read(self, now: Optional[float] = None) -> Dict[str, float]:
        return dict(self.fn(now) or {})


class _TraceSignal(SignalSource):
    """Base for signals that replay a periodic trace against the clock —
    the offline stand-in for a live feed (grid carbon API, cloud spot market).
    ``trace[i]`` holds for ``period_s``; the trace wraps."""

    key = "ext.value"

    def __init__(self, trace: Sequence[float], *, period_s: float = 60.0,
                 now: Callable[[], float] = time.monotonic):
        if not trace:
            raise ValueError(f"{type(self).__name__} needs a non-empty trace")
        self.trace = list(trace)
        self.period_s = period_s
        self._now = now
        self._t0 = now()

    def value(self, now: Optional[float] = None) -> float:
        now = self._now() if now is None else now
        idx = int(max(now - self._t0, 0.0) / self.period_s)
        return float(self.trace[idx % len(self.trace)])

    def read(self, now: Optional[float] = None) -> Dict[str, float]:
        return {self.key: self.value(now)}


class CarbonIntensitySignal(_TraceSignal):
    """Grid carbon intensity, gCO2/kWh — ``ext.carbon_gco2``."""

    name = "carbon"
    key = "ext.carbon_gco2"


class SpotPriceSignal(_TraceSignal):
    """Spot instance price, $/h — ``ext.spot_usd_per_h``."""

    name = "spot"
    key = "ext.spot_usd_per_h"


# ---------------------------------------------------------------------------
# Measured link bandwidth (mesh-aware cost models, ROADMAP)
# ---------------------------------------------------------------------------


def measure_link_bandwidth(fabric: Optional[Fabric] = None, *,
                           payload_bytes: int = 1 << 16,
                           n_msgs: int = 32,
                           timeout_s: float = 1.0) -> float:
    """Measured bytes/s of one fabric link, from a ``bench_collectives``-style
    micro-run: time ``n_msgs`` payloads of ``payload_bytes`` through a fresh
    endpoint pair. On a fabric with a ``LinkModel`` this observes the modeled
    latency; on the default zero-latency fabric it measures the in-process
    copy floor — either way the value orders byte-heavy options honestly,
    which is all the cost scorer needs."""
    fabric = fabric or Fabric()
    tag = time.monotonic_ns()
    src = fabric.register(f"bwprobe-src-{tag}")
    dst = fabric.register(f"bwprobe-dst-{tag}")
    payload = b"\x00" * payload_bytes
    try:
        t0 = time.perf_counter()
        got = 0
        for _ in range(n_msgs):
            src.send(dst.addr, payload)
            if dst.recv(timeout=timeout_s) is not None:
                got += 1
        dt = max(time.perf_counter() - t0, 1e-9)
    finally:
        src.close()
        dst.close()
    if got == 0:
        raise TimeoutError("bandwidth probe received nothing")
    return got * payload_bytes / dt


class LinkBandwidthSignal(SignalSource):
    """Measured slow-tier bandwidth — ``ext.link_bytes_per_s`` plus its
    reciprocal ``ext.dcn_s_per_byte`` (the ``Objective`` normalizer, see
    ``repro.comm.chunnels.calibrated_objective``).

    The probe is a micro-run (``measure_link_bandwidth`` by default, or any
    ``probe() -> bytes/s`` — e.g. one derived from ``bench_collectives``
    output); it runs at most once per ``refresh_s`` and the cached value is
    served in between, so reading this source per aggregation tick stays
    cheap."""

    name = "link_bw"

    def __init__(self, probe: Optional[Callable[[], float]] = None, *,
                 fabric: Optional[Fabric] = None,
                 refresh_s: float = 30.0,
                 now: Callable[[], float] = time.monotonic):
        self.probe = probe or (lambda: measure_link_bandwidth(fabric))
        self.refresh_s = refresh_s
        self._now = now
        self._measured_at: Optional[float] = None
        self._bytes_per_s: Optional[float] = None
        self.probes = 0

    def read(self, now: Optional[float] = None) -> Dict[str, float]:
        now = self._now() if now is None else now
        if (self._measured_at is None
                or now - self._measured_at >= self.refresh_s):
            # stamp success AND failure: a failing probe is retried after
            # refresh_s, never on every aggregation tick (it can block for
            # seconds). With a cached measurement we keep serving it; without
            # one the failure is the aggregator's to count (signal_errors).
            self._measured_at = now
            try:
                self._bytes_per_s = float(self.probe())
                self.probes += 1
            except Exception as e:
                # the compat probe pattern (jaxapi._warn_probe_once): a
                # failed probe is logged at DEBUG, never swallowed silently.
                # With a cached measurement we keep serving it; without one
                # the typed error below tells the aggregator why.
                log.debug("link bandwidth probe failed: %s", e)
                if self._bytes_per_s is None:
                    raise SignalError(
                        f"bandwidth probe failed with no cached value: {e}"
                    ) from e
        bw = self._bytes_per_s
        if not bw:
            # no usable measurement yet (first probe failed, or measured 0):
            # refuse cheaply until the next refresh window instead of
            # emitting None/inf values into the snapshot
            raise SignalError("bandwidth probe has not succeeded yet")
        return {"ext.link_bytes_per_s": bw,
                "ext.dcn_s_per_byte": 1.0 / bw}
