"""Sharded, async, atomic checkpointing with reshard-on-restore.

Layout:
    <dir>/step_<k>.tmp/...      (in-flight)
    <dir>/step_<k>/leaf_<i>.npy (one file per pytree leaf)
    <dir>/step_<k>/manifest.json  (tree structure, shapes, dtypes, step)
    <dir>/LATEST                  (atomic pointer, written last)

Fault-tolerance contract:
  * a crash mid-save never corrupts the previous checkpoint (tmp dir + rename
    + LATEST pointer written last);
  * restore works onto a *different* mesh (elastic restart): arrays are loaded
    host-side and device_put with the new sharding;
  * async mode snapshots to host memory synchronously (consistent cut) and
    writes in a background thread — training continues immediately.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from pathlib import Path
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

# numpy can't natively serialize bf16: round-trip via a uint16 view
_BF16 = np.dtype(ml_dtypes.bfloat16)


def _flatten_with_names(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    names, leaves = [], []
    for path, leaf in flat:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        names.append(name)
        leaves.append(leaf)
    return names, leaves


class Checkpointer:
    def __init__(self, directory: str | Path, *, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._inflight: Optional[Future] = None

    # -- save -----------------------------------------------------------------
    def save(self, step: int, state: Any, *, asynchronous: bool = False) -> Optional[Future]:
        names, leaves = _flatten_with_names(state)
        # Consistent cut: fetch to host before returning control.
        host = [np.asarray(l) for l in leaves]
        treedef = jax.tree.structure(state)
        if asynchronous:
            self.wait()
            self._inflight = self._pool.submit(self._write, step, names, host, treedef)
            return self._inflight
        self._write(step, names, host, treedef)
        return None

    def wait(self) -> None:
        if self._inflight is not None:
            self._inflight.result()
            self._inflight = None

    def _write(self, step: int, names, host, treedef) -> None:
        tmp = self.dir / f"step_{step}.tmp"
        final = self.dir / f"step_{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "leaves": [], "time": time.time()}
        for i, (name, arr) in enumerate(zip(names, host)):
            to_save = arr.view(np.uint16) if arr.dtype == _BF16 else arr
            np.save(tmp / f"leaf_{i}.npy", to_save)
            manifest["leaves"].append(
                {"i": i, "name": name, "shape": list(arr.shape), "dtype": str(arr.dtype)})
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
        latest_tmp = self.dir / "LATEST.tmp"
        latest_tmp.write_text(str(step))
        os.replace(latest_tmp, self.dir / "LATEST")  # atomic commit point
        self._gc()

    def _gc(self) -> None:
        steps = sorted(self.steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # -- restore ----------------------------------------------------------------
    def steps(self) -> list[int]:
        return sorted(int(p.name.split("_")[1]) for p in self.dir.glob("step_*")
                      if not p.name.endswith(".tmp"))

    def latest_step(self) -> Optional[int]:
        f = self.dir / "LATEST"
        if not f.exists():
            return None
        s = int(f.read_text().strip())
        return s if (self.dir / f"step_{s}").exists() else None

    def restore(self, like: Any, *, step: Optional[int] = None,
                shardings: Any = None) -> tuple[Any, int]:
        """Restore into the structure of ``like``; optionally reshard onto a
        new mesh by passing per-leaf ``shardings`` (elastic restart)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = self.dir / f"step_{step}"
        manifest = json.loads((d / "manifest.json").read_text())
        names_like, leaves_like = _flatten_with_names(like)
        by_name = {l["name"]: l for l in manifest["leaves"]}
        out = []
        for name, leaf in zip(names_like, leaves_like):
            meta = by_name.get(name)
            if meta is None:
                raise KeyError(f"checkpoint missing leaf {name}")
            arr = np.load(d / f"leaf_{meta['i']}.npy")
            if meta["dtype"] == "bfloat16":
                arr = arr.view(_BF16)
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(f"{name}: shape {arr.shape} != expected {leaf.shape}")
            out.append(arr)
        tree = jax.tree.unflatten(jax.tree.structure(like), out)
        if shardings is not None:
            tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree, shardings)
        else:
            tree = jax.tree.map(jax.device_put, tree)
        return tree, step
