"""Deterministic synthetic data pipeline with sharded loading + exact resume.

Every batch is a pure function of (seed, step, host_shard), so:
  * each host materializes only its shard (no cross-host traffic),
  * restart-at-step-k reproduces the identical stream (checkpoint resume),
  * elastic re-sharding (N -> M hosts) replays the same global batches.

The token stream is a mixture of Zipf-distributed unigrams and shifted-copy
spans so the LM loss has learnable structure (quickstart shows it dropping).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclass
class DataConfig:
    seed: int = 1234
    vocab_size: int = 32000
    seq_len: int = 1024
    global_batch: int = 8
    zipf_a: float = 1.2
    copy_prob: float = 0.5  # fraction of sequences containing a copy span


class SyntheticLM:
    """Sharded deterministic LM batches."""

    def __init__(self, cfg: DataConfig, *, host_id: int = 0, num_hosts: int = 1):
        assert cfg.global_batch % num_hosts == 0
        self.cfg = cfg
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.local_batch = cfg.global_batch // num_hosts
        # Zipf-ish unigram distribution over the vocab (stable across hosts)
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_a)
        self.p = (p / p.sum()).astype(np.float64)

    def _rng(self, step: int, row: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.cfg.seed, step, row]))

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        """Batch for ``step``; rows are globally indexed so any host layout
        reproduces the same global batch."""
        c = self.cfg
        rows = range(self.host_id * self.local_batch,
                     (self.host_id + 1) * self.local_batch)
        toks = np.empty((self.local_batch, c.seq_len + 1), np.int32)
        for i, row in enumerate(rows):
            rng = self._rng(step, row)
            seq = rng.choice(c.vocab_size, size=c.seq_len + 1, p=self.p)
            if rng.random() < c.copy_prob and c.seq_len >= 32:
                span = c.seq_len // 4
                start = rng.integers(0, c.seq_len - 2 * span)
                seq[start + span : start + 2 * span] = seq[start : start + span]
            toks[i] = seq
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def batches_for(cfg: ModelConfig, shape: ShapeConfig, *, seed: int = 1234,
                host_id: int = 0, num_hosts: int = 1):
    ds = SyntheticLM(
        DataConfig(seed=seed, vocab_size=cfg.vocab_size, seq_len=shape.seq_len,
                   global_batch=shape.global_batch),
        host_id=host_id, num_hosts=num_hosts)

    def gen(step: int) -> Dict[str, np.ndarray]:
        batch = ds.batch(step)
        extras = frontend_stub(cfg, shape, step)
        batch.update(extras)
        return batch

    return gen


def frontend_stub(cfg: ModelConfig, shape: ShapeConfig, step: int) -> dict:
    """Precomputed modality-frontend embeddings (assignment: stubs)."""
    out = {}
    rng = np.random.default_rng(np.random.SeedSequence([9, step]))
    if cfg.family == "vlm" and cfg.frontend:
        f = cfg.frontend
        out["patches"] = rng.standard_normal(
            (shape.global_batch, f.num_positions, f.embed_dim)).astype(np.float32) * 0.02
    if cfg.family == "audio" and cfg.frontend:
        src = max(1, shape.seq_len // cfg.encdec.src_ratio)
        out["frames"] = rng.standard_normal(
            (shape.global_batch, src, cfg.frontend.embed_dim)).astype(np.float32) * 0.02
    return out
