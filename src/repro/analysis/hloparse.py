"""Parse collective ops (+ bytes) out of compiled HLO text, with while-loop
trip-count correction.

cost_analysis() does not report collective bytes, so we sum operand sizes of
every all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
in ``compiled.as_text()``. Ops inside while bodies (lax.scan over layers /
attention chunks) appear once; we recover trip counts from each while op's
``backend_config={"known_trip_count":{"n":...}}`` and multiply, following the
call graph (body= / condition= / to_apply= / calls=) so nested scans compose.

HLO shapes in the SPMD-partitioned module are PER-DEVICE, so returned bytes are
per-device per-step.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8, "s32": 4,
    "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1,
    "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_HEADER_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_CALL_RE = re.compile(r"(?:body|condition|to_apply|calls)=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count"?\s*:\s*\{\s*"n"\s*:\s*"?(\d+)')


def shape_bytes(shape_str: str) -> int:
    """bytes of 'f32[128,256]{1,0}' or a tuple '(f32[2], bf16[4,4])'."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def split_computations(hlo: str) -> Tuple[Dict[str, List[str]], Optional[str]]:
    """computation name -> op lines; also returns the ENTRY computation name."""
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    entry: Optional[str] = None
    for line in hlo.splitlines():
        stripped = line.rstrip()
        if cur is None:
            if stripped.endswith("{") and ("->" in stripped or stripped.startswith(("ENTRY", "%"))):
                m = _HEADER_RE.match(stripped)
                if m:
                    cur = m.group(1)
                    comps[cur] = []
                    if stripped.lstrip().startswith("ENTRY"):
                        entry = cur
            continue
        if stripped == "}" or stripped.endswith("} // " + cur) or stripped == "} ":
            cur = None
            continue
        comps[cur].append(line)
    return comps, entry


def _while_multipliers(comps: Dict[str, List[str]], entry: Optional[str]) -> Dict[str, float]:
    """computation -> product of enclosing while trip counts."""
    # edges: computation -> [(callee, multiplier)]
    edges: Dict[str, List[Tuple[str, float]]] = defaultdict(list)
    for name, lines in comps.items():
        for ln in lines:
            trip = 1.0
            if "while(" in ln:
                tm = _TRIP_RE.search(ln)
                if tm:
                    trip = float(tm.group(1))
            for cm in _CALL_RE.finditer(ln):
                callee = cm.group(1)
                edges[name].append((callee, trip if "while(" in ln else 1.0))

    mults: Dict[str, float] = {}

    def visit(name: str, acc: float, depth: int = 0):
        if depth > 64 or name not in comps:
            return
        if mults.get(name, 0.0) >= acc:
            return
        mults[name] = acc
        for callee, m in edges.get(name, ()):
            visit(callee, acc * m, depth + 1)

    roots = [entry] if entry else []
    if not roots:
        roots = [n for n in comps if "main" in n]
    for r in roots:
        if r:
            visit(r, 1.0)
    for n in comps:
        mults.setdefault(n, 1.0)
    return mults


@dataclass
class CollectiveOp:
    kind: str
    bytes: float
    mult: float
    line: str


@dataclass
class CollectiveStats:
    ops: List[CollectiveOp] = field(default_factory=list)

    def add(self, kind: str, nbytes: float, mult: float, line: str):
        self.ops.append(CollectiveOp(kind, nbytes, mult, line))

    @property
    def total_bytes(self) -> float:
        return sum(o.bytes * o.mult for o in self.ops)

    def by_kind(self) -> Dict[str, float]:
        out: Dict[str, float] = defaultdict(float)
        for o in self.ops:
            out[o.kind] += o.bytes * o.mult
        return dict(out)

    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = defaultdict(int)
        for o in self.ops:
            out[o.kind] += max(1, int(o.mult))
        return dict(out)

    def to_dict(self) -> dict:
        return {"counts": self.counts(), "bytes": self.by_kind(),
                "total_bytes": float(self.total_bytes)}


_COLL_RE = re.compile(
    r"=\s*(?:\([^=]*\)\s*)?[\w\[\],\{\} ]*?\b(" + "|".join(COLLECTIVES) + r")(-start)?\(")


def iter_collectives(hlo: str):
    """Yield (kind, bytes, multiplier, line) for every collective op."""
    comps, entry = split_computations(hlo)
    mults = _while_multipliers(comps, entry)
    for name, lines in comps.items():
        mult = mults.get(name, 1.0)
        for ln in lines:
            if "-done" in ln:
                continue
            found = None
            for kind in COLLECTIVES:
                if f" {kind}(" in ln or f" {kind}-start(" in ln:
                    found = kind
                    break
            if found is None:
                continue
            # result shape sits between '=' and the op name:
            #   %all-reduce.1 = f32[256,512]{1,0} all-reduce(...)
            rhs = ln.split("=", 1)[1] if "=" in ln else ln
            idx = rhs.find(f" {found}")
            shape_str = rhs[:idx] if idx > 0 else rhs
            yield found, float(shape_bytes(shape_str)), mult, ln


def collective_stats(hlo: str) -> CollectiveStats:
    stats = CollectiveStats()
    for kind, nbytes, mult, ln in iter_collectives(hlo):
        stats.add(kind, nbytes, mult, ln)
    return stats
