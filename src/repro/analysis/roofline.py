"""Three-term roofline from the dry-run artifacts (TPU v5e target).

  compute    = FLOPs_per_device / peak_bf16
  memory     = HBM_bytes_per_device / hbm_bw
  collective = ICI_bytes/(links*link_bw) + DCN_bytes/dcn_bw   (per device)

FLOPs/HBM bytes come from the analytic implementation-faithful model
(analysis/flops.py — see its docstring for why not cost_analysis), validated
against an unrolled HLO compile in tests/test_flops_validation.py.
Collective bytes are parsed from the compiled HLO (per-device shapes) with
while-loop trip-count correction; ops are attributed to the DCN tier when
their replica groups cross a pod boundary.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Optional

from repro.analysis import flops as F
from repro.analysis import hloparse
from repro.launch.mesh import HW


@dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    flops_per_dev: float
    bytes_per_dev: float
    coll_bytes_per_dev: float
    dcn_bytes_per_dev: float
    model_flops: float
    hlo_useful_ratio: float  # MODEL_FLOPS / implementation FLOPs
    step_time_s: float  # max of the three terms (no-overlap bound is their sum)
    mfu: float  # model_flops / (chips * peak * step_time)

    def to_dict(self):
        return {k: getattr(self, k) for k in self.__dataclass_fields__}


def _split_ici_dcn(hlo: str, pod_size: int) -> tuple[float, float, dict]:
    """Return (ici_bytes, dcn_bytes, stats_dict) per device."""
    stats = hloparse.collective_stats(hlo)
    ici = dcn = 0.0
    for kind, nbytes, mult, ln in hloparse.iter_collectives(hlo):
        if _crosses_pod(ln, pod_size):
            dcn += nbytes * mult
        else:
            ici += nbytes * mult
    return ici, dcn, stats.to_dict()


def _crosses_pod(line: str, pod_size: int) -> bool:
    if pod_size <= 0:
        return False
    m = re.search(r"replica_groups=\{\{([^}]+)\}", line)
    if m:
        ids = [int(x) for x in re.split(r"[,\s]+", m.group(1)) if x.strip().isdigit()]
        return len({i // pod_size for i in ids}) > 1
    # iota format: replica_groups=[G,S]<=[N](perm) — groups of stride layout.
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[(\d+)\](?:T\(([\d,]+)\))?", line)
    if m:
        g, s, n = int(m.group(1)), int(m.group(2)), int(m.group(3))
        perm = m.group(4)
        if n <= pod_size:
            return False
        # default iota: consecutive ids per group -> crosses only if group size
        # exceeds pod; transposed iota (T(1,0)) strides across pods.
        if perm and perm != "0,1":
            return True
        return s > pod_size
    return False


def analyze(
    hlo: str,
    cfg,
    shape,
    mesh_shape: dict,
    *,
    extra_collective_bytes: float = 0.0,
) -> Roofline:
    n_chips = 1
    for v in mesh_shape.values():
        n_chips *= v
    pod_chips = n_chips // mesh_shape.get("pod", 1)
    cost = F.step_cost(cfg, shape, mesh_shape)
    fpd = cost.flops / n_chips
    bpd = cost.bytes_hbm / n_chips
    ici, dcn, _ = _split_ici_dcn(hlo, pod_chips if mesh_shape.get("pod", 1) > 1 else 0)
    ici += extra_collective_bytes

    compute_s = fpd / HW["peak_flops_bf16"]
    memory_s = bpd / HW["hbm_bw"]
    collective_s = ici / (HW["ici_links"] * HW["ici_link_bw"]) + dcn / HW["dcn_bw"]
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    step = max(terms.values())
    mfu = cost.model_flops / (n_chips * HW["peak_flops_bf16"] * step) if step > 0 else 0.0
    return Roofline(
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        flops_per_dev=fpd,
        bytes_per_dev=bpd,
        coll_bytes_per_dev=ici + dcn,
        dcn_bytes_per_dev=dcn,
        model_flops=cost.model_flops,
        hlo_useful_ratio=cost.model_flops / max(cost.flops, 1.0),
        step_time_s=step,
        mfu=mfu,
    )
