"""Analytic FLOP/byte accounting for the implemented step functions.

Why analytic: XLA's cost_analysis() on a scanned program counts each while-body
once (measured; see DESIGN.md §7), so HLO numbers underreport by ~L× for the
layer stack and ~n_chunks× for chunked attention. We therefore account FLOPs
and HBM bytes analytically — matmul-exact, implementation-faithful — and
validate against an unrolled-HLO compile where feasible
(tests/test_flops_validation.py).

Implementation-faithful means: chunked attention computes FULL S_kv with
masking (2x the causal-optimal attention FLOPs; the Pallas kernel / banded
chunks remove this — tracked in §Perf), MoE counts capacity padding, remat
recomputes the layer forward.

MODEL_FLOPS is the usual 6·N·D (dense) / 6·N_active·D (MoE) useful-work figure;
the ratio MODEL_FLOPS / HLO_FLOPS exposes remat + masking + capacity waste.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ModelConfig, ShapeConfig


@dataclass
class StepCost:
    flops: float  # total across the job, per step
    bytes_hbm: float  # total HBM traffic across the job, per step
    model_flops: float  # 6*N*D useful-work reference
    params: float  # trained parameter count
    notes: str = ""


def param_count(cfg: ModelConfig) -> float:
    D, V, hd = cfg.d_model, cfg.vocab_size, cfg.head_dim_
    H, KH, F, L = cfg.num_heads, cfg.num_kv_heads, cfg.d_ff, cfg.num_layers
    if cfg.family == "ssm":  # xlstm
        n_s = sum(1 for i in range(L) if (i % (cfg.xlstm.slstm_every)) == cfg.xlstm.slstm_every - 1)
        n_m = L - n_s
        mlstm = D * H * hd * 3 + D * H * 2 + D * H * hd + H * hd * D
        f_up = int(D * 4 / 3)
        slstm = 4 * D * D + 4 * D + 3 * D * f_up
        return V * D + n_m * mlstm + n_s * slstm + D * V
    attn = D * (H + 2 * KH) * hd + H * hd * D
    n_mats = 3 if cfg.mlp_gated else 2
    if cfg.family == "moe":
        m = cfg.moe
        ffn = D * m.num_experts + 3 * m.num_experts * D * m.d_ff_expert
    else:
        ffn = n_mats * D * F
    layer = attn + ffn
    if cfg.family == "hybrid":
        s = cfg.ssm
        d_in = s.expand * D
        dtr = s.dt_rank or max(1, -(-D // 16))
        ssm_p = (D * 2 * d_in + s.conv_dim * d_in + d_in * (dtr + 2 * s.state_dim)
                 + dtr * d_in + d_in * s.state_dim + d_in + d_in * D)
        layer = attn + ffn + ssm_p
    total = V * D + L * layer + (0 if cfg.tie_embeddings else D * V)
    if cfg.family == "audio":
        e = cfg.encdec
        enc_layer = attn + ffn
        dec_layer = 2 * attn + ffn
        total = V * D + e.enc_layers * enc_layer + e.dec_layers * dec_layer + D * V
    return float(total)


def active_param_count(cfg: ModelConfig) -> float:
    if cfg.family != "moe":
        return param_count(cfg)
    D, V, hd = cfg.d_model, cfg.vocab_size, cfg.head_dim_
    H, KH, L = cfg.num_heads, cfg.num_kv_heads, cfg.num_layers
    m = cfg.moe
    attn = D * (H + 2 * KH) * hd + H * hd * D
    ffn_active = D * m.num_experts + 3 * m.top_k * D * m.d_ff_expert
    return float(V * D + L * (attn + ffn_active) + D * V)


# ---------------------------------------------------------------------------
# per-component forward FLOPs (total across the job)
# ---------------------------------------------------------------------------


def _attn_fwd(T, S_kv, H, KH, hd, D, qkv_bias=False):
    proj = 2 * T * D * (H + 2 * KH) * hd + 2 * T * H * hd * D
    scores = 4 * T * S_kv * H * hd  # QK^T + PV, full-S_kv masked (impl-faithful)
    return proj + scores


def _mlp_fwd(T, D, F, gated: bool = True):
    return (6 if gated else 4) * T * D * F


def _moe_fwd(T, cfg: ModelConfig):
    import math

    m = cfg.moe
    D = cfg.d_model
    C = max(1, math.ceil(T * m.top_k * m.capacity_factor / m.num_experts))
    router = 2 * T * D * m.num_experts
    experts = 6 * (m.num_experts * C) * D * m.d_ff_expert  # capacity padding counted
    return router + experts


def _ssm_fwd(T, cfg: ModelConfig):
    s = cfg.ssm
    D = cfg.d_model
    d_in = s.expand * D
    dtr = s.dt_rank or max(1, -(-D // 16))
    proj = 2 * T * D * 2 * d_in + 2 * T * d_in * (dtr + 2 * s.state_dim) \
        + 2 * T * dtr * d_in + 2 * T * d_in * D
    conv = 2 * T * s.conv_dim * d_in
    scan = 12 * T * d_in * s.state_dim  # discretize + assoc-scan + C.h
    return proj + conv + scan


def _mlstm_fwd(T, cfg: ModelConfig):
    D, H, hd = cfg.d_model, cfg.num_heads, cfg.head_dim_
    C = cfg.xlstm.chunk_size if cfg.xlstm else 64
    proj = 2 * T * D * (3 * H * hd + 2 * H + H * hd) + 2 * T * H * hd * D
    intra = 4 * T * C * H * hd  # scores + h_intra within chunk
    inter = 6 * T * H * hd * hd  # q.C0, C1 update, n updates
    return proj + intra + inter


def _slstm_fwd(T, cfg: ModelConfig):
    D = cfg.d_model
    f_up = int(D * 4 / 3)
    return 8 * T * D * D + 25 * T * D + 6 * T * D * f_up


def fwd_flops_layerwise(cfg: ModelConfig, shape: ShapeConfig, kind: str):
    """(layers_fwd, head_fwd) total-job forward FLOPs.

    kind: 'train'/'prefill' (full sequence) or 'decode' (one token vs cache).
    """
    B, S = shape.global_batch, shape.seq_len
    D, V, hd = cfg.d_model, cfg.vocab_size, cfg.head_dim_
    H, KH, F, L = cfg.num_heads, cfg.num_kv_heads, cfg.d_ff, cfg.num_layers

    if kind == "decode":
        T, S_kv = B, S  # one new token, cache of S
    else:
        T, S_kv = B * S, S

    if cfg.family == "ssm":
        every = cfg.xlstm.slstm_every
        n_s = sum(1 for i in range(L) if (i % every) == every - 1)
        n_m = L - n_s
        if kind == "decode":
            layers = n_m * (_mlstm_fwd(T, cfg)) + n_s * _slstm_fwd(T, cfg)
        else:
            layers = n_m * _mlstm_fwd(T, cfg) + n_s * _slstm_fwd(T, cfg)
        head = 2 * T * D * V if kind == "train" else 2 * B * D * V
        return layers, head

    if cfg.family == "audio":
        e = cfg.encdec
        S_src = max(1, S // e.src_ratio)
        T_src = B * S_src
        if kind == "decode":
            T_dec, S_self, enc_T = B, S, 0  # encoder already cached
            enc = 0.0
        else:
            T_dec, S_self = B * S, S
            enc = e.enc_layers * (_attn_fwd(T_src, S_src, H, KH, hd, D) + _mlp_fwd(T_src, D, F))
        self_attn = _attn_fwd(T_dec, S_self, H, KH, hd, D)
        cross_q = 2 * T_dec * D * H * hd + 2 * T_dec * H * hd * D
        cross_kv = 0 if kind == "decode" else 2 * T_src * D * 2 * KH * hd
        cross_scores = 4 * T_dec * S_src * H * hd
        dec = e.dec_layers * (self_attn + cross_q + cross_kv + cross_scores + _mlp_fwd(T_dec, D, F))
        head = 2 * T_dec * D * V if kind == "train" else 2 * B * D * V
        return enc + dec, head

    # token-stack families
    per_layer_attn = []
    for i in range(L):
        if cfg.family == "hybrid" and cfg.sliding_window and i not in cfg.global_layers:
            skv = S_kv if kind != "decode" else min(cfg.sliding_window, S_kv)
            # impl-faithful: chunked prefill masks but computes full S_kv
            skv_impl = S_kv if kind != "decode" else skv
            per_layer_attn.append(_attn_fwd(T, skv_impl, H, KH, hd, D, cfg.qkv_bias))
        else:
            per_layer_attn.append(_attn_fwd(T, S_kv, H, KH, hd, D, cfg.qkv_bias))
    attn_total = sum(per_layer_attn)

    if cfg.family == "moe":
        ffn_total = L * _moe_fwd(T, cfg)
    else:
        ffn_total = L * _mlp_fwd(T, D, F, cfg.mlp_gated)
    ssm_total = L * _ssm_fwd(T, cfg) if cfg.family == "hybrid" else 0.0
    head = 2 * T * D * V if kind == "train" else 2 * B * D * V
    return attn_total + ffn_total + ssm_total, head


def step_cost(cfg: ModelConfig, shape: ShapeConfig, mesh_shape: dict) -> StepCost:
    """Total-job per-step cost for the cell's step function."""
    kind = shape.kind
    layers_fwd, head_fwd = fwd_flops_layerwise(cfg, shape, kind)
    N = param_count(cfg)
    Na = active_param_count(cfg)
    n_chips = 1
    for v in mesh_shape.values():
        n_chips *= v

    if kind == "train":
        remat_factor = 4.0 if cfg.remat == "full" else 3.0  # fwd+bwd(2x)+re-fwd
        flops = layers_fwd * remat_factor + head_fwd * 3.0
        tokens = shape.tokens
        model_flops = 6.0 * Na * tokens
    else:
        flops = layers_fwd + head_fwd
        tokens = shape.global_batch if kind == "decode" else shape.tokens
        model_flops = 2.0 * Na * tokens

    bytes_hbm = _bytes_model(cfg, shape, kind, mesh_shape, N)
    return StepCost(flops=flops, bytes_hbm=bytes_hbm, model_flops=model_flops, params=N)


def _bytes_model(cfg: ModelConfig, shape: ShapeConfig, kind: str, mesh_shape: dict,
                 N: float) -> float:
    """Coarse HBM-traffic model (total across job, per step).

    train : weights bf16 read x3 (fwd/bwd/remat) + AdamW fp32 m/v/p rw (24B) +
            grad write (4B) -> ~34B/param consumed per TP rank, plus
            activation stream ~ 2B * tokens * (10*D + 4*F_eff) per layer.
    decode: weights bf16 once + full KV-cache read + small activations.
    """
    D, F, L = cfg.d_model, cfg.d_ff, cfg.num_layers
    KH, hd = cfg.num_kv_heads, cfg.head_dim_
    model_par = mesh_shape.get("model", 1)
    B, S = shape.global_batch, shape.seq_len
    n_chips = 1
    for v in mesh_shape.values():
        n_chips *= v

    F_eff = cfg.moe.d_ff_expert * cfg.moe.top_k if cfg.family == "moe" else F
    if cfg.family == "ssm":
        F_eff = 2 * D

    if kind == "train":
        weight_traffic = N * (3 * 2 + 4 + 24) * (n_chips / model_par) / n_chips * n_chips
        # each TP rank reads N/model_par params; n_chips/model_par ranks groups ->
        # total = N/model_par * 2B * 3 * n_chips ... simplify per-job:
        weight_traffic = (N / model_par) * (3 * 2) * n_chips + N * 28  # opt state sharded once
        act = 2.0 * shape.tokens * (10 * D + 4 * F_eff) * L * 2  # fwd+bwd streams
        return weight_traffic + act
    if kind == "prefill":
        weight_traffic = (N / model_par) * 2 * n_chips
        act = 2.0 * shape.tokens * (10 * D + 4 * F_eff) * L
        cache_write = 2.0 * L * B * S * KH * hd * 2
        return weight_traffic + act + cache_write
    # decode
    weight_traffic = N * 2  # every param read once per token (batch amortizes reads)
    if cfg.family == "hybrid":
        cache = 2.0 * 2 * B * (
            sum(min(cfg.sliding_window, S) for i in range(L) if i not in cfg.global_layers)
            + len(cfg.global_layers) * S) * KH * hd
        ssm_state = 4.0 * L * B * cfg.ssm.expand * D * cfg.ssm.state_dim * 2
        cache += ssm_state
    elif cfg.family == "ssm":
        H = cfg.num_heads
        cache = 4.0 * L * B * (H * hd * hd) * 2  # mLSTM matrix state rw
    elif cfg.family == "audio":
        e = cfg.encdec
        cache = 2.0 * 2 * B * e.dec_layers * (S + S // e.src_ratio) * KH * hd
    else:
        cache = 2.0 * 2 * L * B * S * KH * hd  # k+v bf16 read
    act = 2.0 * B * (10 * D + 4 * F_eff) * L
    return weight_traffic + cache + act
