"""Render EXPERIMENTS.md §Dry-run/§Roofline tables from results/dryrun JSON."""
from __future__ import annotations

import json
from pathlib import Path


def load(out_dir="results/dryrun"):
    recs = []
    for f in sorted(Path(out_dir).glob("*.json")):
        recs.append(json.loads(f.read_text()))
    return recs


def fmt_s(x):
    if x == 0:
        return "0"
    return f"{x:.2e}"


def roofline_table(recs, multi_pod=False) -> str:
    rows = [
        "| arch | shape | dom | compute s | memory s | collective s | DCN GB | "
        "MODEL_TF | useful | MFU-bound | mem GB | fits |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("multi_pod") != multi_pod:
            continue
        if r.get("skipped"):
            rows.append(
                f"| {r['arch']} | {r['shape']} | SKIP | — | — | — | — | — | — | — | — | — |")
            continue
        if "error" in r:
            rows.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | | | | | |")
            continue
        rf = r["roofline"]
        m = r["memory"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {rf['dominant']} | {fmt_s(rf['compute_s'])} | "
            f"{fmt_s(rf['memory_s'])} | {fmt_s(rf['collective_s'])} | "
            f"{rf['dcn_bytes_per_dev']/1e9:.2f} | {rf['model_flops']/1e12:.0f} | "
            f"{rf['hlo_useful_ratio']:.2f} | {rf['mfu']:.2f} | "
            f"{m['per_device_total']/1e9:.1f} | {'Y' if m['fits_16GB'] else 'N'} |")
    return "\n".join(rows)


def dryrun_summary(recs) -> str:
    ok = sum(1 for r in recs if not r.get("skipped") and "error" not in r)
    skip = sum(1 for r in recs if r.get("skipped"))
    err = sum(1 for r in recs if "error" in r)
    lines = [f"compiled OK: {ok}, skipped (recorded): {skip}, failed: {err}", ""]
    lines.append("| arch | shape | mesh | lower s | compile s | HLO flops/dev | "
                 "HLO bytes/dev | coll ops |")
    lines.append("|---|---|---|---|---|---|---|---|")
    for r in recs:
        if r.get("skipped") or "error" in r:
            continue
        mesh = "2x16x16" if r["multi_pod"] else "16x16"
        ca = r.get("cost_analysis", {})
        lines.append(
            f"| {r['arch']} | {r['shape']} | {mesh} | {r['lower_s']} | {r['compile_s']} | "
            f"{ca.get('flops', 0):.2e} | {ca.get('bytes accessed', 0):.2e} | "
            f"{r['roofline']['coll_bytes_per_dev']/1e9:.2f} GB |")
    return "\n".join(lines)


if __name__ == "__main__":
    recs = load()
    print("## Single-pod (16x16)\n")
    print(roofline_table(recs, multi_pod=False))
    print("\n## Multi-pod (2x16x16)\n")
    print(roofline_table(recs, multi_pod=True))
