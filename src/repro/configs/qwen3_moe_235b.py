"""qwen3-moe-235b-a22b [moe] — 94L d_model=4096 64H (GQA kv=4) vocab=151936.

MoE: 128 experts, top-8, per-expert d_ff=1536. [hf:Qwen/Qwen3-30B-A3B; hf]
Most representative arch for the paper's technique: the MoE dispatch layer is a
Select between all-to-all EP and allgather dispatch chunnels.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab_size=151936,
    rope_theta=1e6,
    norm_eps=1e-6,
    moe=MoEConfig(num_experts=128, top_k=8, d_ff_expert=1536),
    remat_group=1,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="qwen3-moe-smoke",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=96,
        vocab_size=256,
        attn_impl="xla_dense",
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=96),
    )
