"""dbrx-132b [moe] — 40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352.

MoE: 16 experts, top-4, fine-grained. [hf:databricks/dbrx-base; unverified]
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab_size=100352,
    rope_theta=5e5,
    norm_eps=1e-5,
    moe=MoEConfig(num_experts=16, top_k=4, d_ff_expert=10752),
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="dbrx-smoke",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=96,
        vocab_size=256,
        attn_impl="xla_dense",
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=96),
    )
