"""phi-3-vision-4.2b [vlm] — 32L d_model=3072 32H (MHA kv=32) d_ff=8192 vocab=32064.

phi3-mini backbone + CLIP frontend. [hf:microsoft/Phi-3-vision-128k-instruct; hf]
The CLIP frontend is a STUB: input_specs() provides 576 precomputed patch
embeddings occupying the first 576 sequence positions; the rest are text tokens.
"""
from repro.configs.base import FrontendConfig, ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    rope_theta=1e6,
    norm_eps=1e-5,
    frontend=FrontendConfig(kind="patch", num_positions=576, embed_dim=3072),
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="phi3v-smoke",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        attn_impl="xla_dense",
        frontend=FrontendConfig(kind="patch", num_positions=8, embed_dim=64),
    )
