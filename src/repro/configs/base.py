"""Config schema for Berthax model architectures and run shapes.

Every assigned architecture gets a module ``src/repro/configs/<id>.py`` exposing
``CONFIG`` (the exact published dims) and ``smoke_config()`` (a reduced config of
the same family for CPU smoke tests).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    # Select between dispatch implementations (a Bertha routing chunnel).
    dispatch: str = "alltoall"  # "alltoall" | "allgather" | "dense"


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 16
    conv_dim: int = 4
    expand: int = 2
    dt_rank: Optional[int] = None  # default ceil(d_model/16)


@dataclass(frozen=True)
class XLSTMConfig:
    # Ratio of sLSTM:mLSTM blocks; blocks alternate in segments.
    slstm_every: int = 2  # every Nth block is an sLSTM block (rest mLSTM)
    chunk_size: int = 64  # chunkwise-parallel mLSTM chunk length


@dataclass(frozen=True)
class EncDecConfig:
    enc_layers: int
    dec_layers: int
    # Audio/encoder source length as a fraction of the shape's seq_len:
    # seamless stub provides precomputed frames at seq_len // src_ratio.
    src_ratio: int = 4


@dataclass(frozen=True)
class FrontendConfig:
    """Modality frontend STUB: input_specs() provides precomputed embeddings."""

    kind: str  # "patch" (vision) | "frames" (audio)
    num_positions: int  # e.g. 576 CLIP patches
    embed_dim: int  # frontend output dim (== d_model after projection)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | audio | vlm | hybrid
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # defaults to d_model // num_heads
    qkv_bias: bool = False
    rope_theta: float = 500000.0
    norm_eps: float = 1e-5
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    act: str = "silu"
    mlp_gated: bool = True  # SwiGLU (3 mats) vs classic 2-mat MLP (granite)
    tie_embeddings: bool = False
    max_position_embeddings: int = 131072

    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    encdec: Optional[EncDecConfig] = None
    frontend: Optional[FrontendConfig] = None

    # Attention structure
    sliding_window: Optional[int] = None  # None = full attention
    global_layers: Tuple[int, ...] = ()  # layers with full attn (hymba)
    # Attention implementation Select (a Bertha chunnel choice):
    #   xla_dense    materialized scores (small seqs)
    #   xla_chunked  online-softmax scan over KV blocks (default at scale)
    #   pallas       TPU flash-attention kernel (validated in interpret mode)
    attn_impl: str = "xla_chunked"
    attn_chunk: int = 1024

    # Training knobs
    remat: str = "full"  # none | full | dots
    remat_group: int = 1  # checkpoint every N layers (saved-stack / N)
    scan_layers: bool = True
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # Sequence-chunked LM loss (None = materialize all logits; used by the
    # roofline validation probes so the lm-head matmul isn't inside a scan)
    loss_chunk: Optional[int] = 512

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    @property
    def vocab_padded(self) -> int:
        """Embedding/lm-head allocation size: vocab padded to a multiple of 256
        so the vocab dim shards over any mesh axis (Megatron-style). Logits at
        padded columns are masked to -inf in the loss/decode paths."""
        return -(-self.vocab_size // 256) * 256

    @property
    def head_dim_(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    @property
    def q_group(self) -> int:
        return self.num_heads // self.num_kv_heads

    def validate(self) -> None:
        assert self.num_heads % self.num_kv_heads == 0, (
            f"{self.name}: heads {self.num_heads} not divisible by kv {self.num_kv_heads}"
        )
        if self.family == "moe":
            assert self.moe is not None
        if self.family == "hybrid":
            assert self.ssm is not None
        if self.family == "audio":
            assert self.encdec is not None and self.frontend is not None
        if self.family == "vlm":
            assert self.frontend is not None


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


# The four assigned LM shapes. decode_* / long_* lower serve_step (one new token
# against a KV cache of seq_len), NOT train_step.
SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# long_500k needs sub-quadratic attention: run only for SSM/hybrid archs,
# skip (with reason recorded) for pure full-attention archs. See DESIGN.md §5.
SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether (arch, shape) is a runnable cell; reason if skipped."""
    if shape.name == "long_500k" and cfg.family not in SUBQUADRATIC_FAMILIES:
        return False, (
            "long_500k skipped: full-attention arch (O(S^2)/full-cache at 524288); "
            "run only for SSM/hybrid per assignment"
        )
    return True, ""


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1000
    microbatches: int = 1  # gradient-accumulation microbatches per step
    # AdamW moment dtype: bf16 moments (fp32 master params retained) are the
    # standard memory/quality trade at 100B+ scale.
    opt_dtype: str = "bfloat16"


@dataclass(frozen=True)
class ShardingConfig:
    """How the model maps onto the production mesh (a Bertha routing chunnel)."""

    fsdp: bool = True  # shard params/opt-state over the data axis (ZeRO-3)
    # Gradient transport Select across the pod (DCN) tier:
    #   xla | ring | hierarchical | compressed_int8 | localsgd
    pod_transport: str = "xla"
    # KV-cache partitioning for decode: "auto" resolves per-arch:
    #   heads if num_kv_heads % model_axis == 0 else sequence (flash-decode).
    kv_partition: str = "auto"
    remat: str = "full"
