"""Architecture config registry: ``--arch <id>`` resolves here."""
from __future__ import annotations

import importlib

from repro.configs.base import (
    SHAPES,
    ModelConfig,
    ShapeConfig,
    ShardingConfig,
    TrainConfig,
    shape_applicable,
)

_ARCH_MODULES = {
    "qwen2-7b": "repro.configs.qwen2_7b",
    "granite-34b": "repro.configs.granite_34b",
    "llama3.2-1b": "repro.configs.llama3_2_1b",
    "mistral-nemo-12b": "repro.configs.mistral_nemo_12b",
    "qwen3-moe-235b-a22b": "repro.configs.qwen3_moe_235b",
    "dbrx-132b": "repro.configs.dbrx_132b",
    "xlstm-125m": "repro.configs.xlstm_125m",
    "seamless-m4t-medium": "repro.configs.seamless_m4t_medium",
    "phi-3-vision-4.2b": "repro.configs.phi3_vision_4b",
    "hymba-1.5b": "repro.configs.hymba_1_5b",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    cfg = importlib.import_module(_ARCH_MODULES[arch]).CONFIG
    cfg.validate()
    return cfg


def get_smoke_config(arch: str) -> ModelConfig:
    cfg = importlib.import_module(_ARCH_MODULES[arch]).smoke_config()
    cfg.validate()
    return cfg


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


__all__ = [
    "ARCH_IDS",
    "SHAPES",
    "ModelConfig",
    "ShapeConfig",
    "ShardingConfig",
    "TrainConfig",
    "get_config",
    "get_smoke_config",
    "get_shape",
    "shape_applicable",
]
