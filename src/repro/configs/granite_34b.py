"""granite-34b [dense] — 88L d_model=6144 48H (MQA kv=1) d_ff=24576 vocab=49152.

Llama-architecture code model; multi-query attention. [arXiv:2405.04324; hf]
kv=1 < model-axis 16 forces the sequence-sharded KV-cache chunnel for decode.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b",
    family="dense",
    num_layers=88,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    rope_theta=1e4,
    norm_eps=1e-5,
    remat_group=2,
    # gpt-bigcode heritage: classic 2-matrix gelu MLP (yields the declared 34B;
    # a gated SwiGLU at d_ff=24576 would be ~47B)
    act="gelu",
    mlp_gated=False,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="granite-34b-smoke",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=1,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        attn_impl="xla_dense",
    )
