"""xlstm-125m [ssm] — 12L d_model=768 4H d_ff=0 vocab=50304.

sLSTM + mLSTM blocks (alternating). [arXiv:2405.04517; unverified]
No attention KV cache: serve_step carries recurrent state — the KV-partition
chunnel is inapplicable (see DESIGN.md §Arch-applicability). Sub-quadratic:
long_500k runs.
"""
from repro.configs.base import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    head_dim=192,
    d_ff=0,  # xLSTM blocks carry their own up/down projections (expand=2)
    vocab_size=50304,
    norm_eps=1e-5,
    xlstm=XLSTMConfig(slstm_every=2, chunk_size=64),
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="xlstm-smoke",
        num_layers=2,
        d_model=64,
        num_heads=2,
        num_kv_heads=2,
        head_dim=32,
        vocab_size=256,
        xlstm=XLSTMConfig(slstm_every=2, chunk_size=16),
    )
