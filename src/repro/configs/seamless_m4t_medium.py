"""seamless-m4t-medium [audio] — 12L d_model=1024 16H (kv=16) d_ff=4096 vocab=256206.

Encoder-decoder, multimodal. [arXiv:2308.11596; hf]
The audio frontend is a STUB: input_specs() provides precomputed frame embeddings
of length seq_len // 4 (conv-subsampled frames). num_layers=12 per stack
(12 encoder + 12 decoder), matching the assignment's per-stack layer count.
"""
from repro.configs.base import EncDecConfig, FrontendConfig, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    num_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    rope_theta=1e4,
    norm="layernorm",
    norm_eps=1e-5,
    encdec=EncDecConfig(enc_layers=12, dec_layers=12, src_ratio=4),
    frontend=FrontendConfig(kind="frames", num_positions=0, embed_dim=1024),
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="seamless-smoke",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        attn_impl="xla_dense",
        encdec=EncDecConfig(enc_layers=2, dec_layers=2, src_ratio=4),
        frontend=FrontendConfig(kind="frames", num_positions=0, embed_dim=64),
    )
