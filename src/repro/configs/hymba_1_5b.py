"""hymba-1.5b [hybrid] — 32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001.

Parallel attention + mamba heads per layer; ssm_state=16. [arXiv:2411.13676; hf]
Sliding-window attention (1024) for all layers except 3 global layers
(first/middle/last), so long_500k is sub-quadratic and runs.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    rope_theta=1e4,
    norm_eps=1e-5,
    sliding_window=1024,
    global_layers=(0, 15, 31),
    # 25 heads don't divide the model axis (replicated attention heads):
    # smaller KV chunks keep the per-chunk score transients ~1GB
    attn_chunk=256,
    ssm=SSMConfig(state_dim=16, conv_dim=4, expand=2),
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="hymba-smoke",
        num_layers=4,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        attn_impl="xla_dense",
        sliding_window=8,
        global_layers=(0, 3),
        ssm=SSMConfig(state_dim=4, conv_dim=4, expand=2),
    )
