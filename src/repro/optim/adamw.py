"""AdamW with decoupled weight decay + global-norm clipping (from scratch)."""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


class AdamWState(NamedTuple):
    m: dict
    v: dict
    count: jnp.ndarray


def init(params, dtype=jnp.bfloat16) -> AdamWState:
    zeros = lambda: jax.tree.map(lambda p: jnp.zeros(p.shape, dtype), params)
    return AdamWState(m=zeros(), v=zeros(), count=jnp.zeros((), jnp.int32))


def init_shape(params_shape, dtype=jnp.bfloat16) -> AdamWState:
    zeros = lambda: jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, dtype), params_shape)
    return AdamWState(m=zeros(), v=zeros(), count=jax.ShapeDtypeStruct((), jnp.int32))


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale, tree), norm


def update(grads, state: AdamWState, params, lr, cfg: TrainConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if cfg.grad_clip > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = global_norm(grads)
    count = state.count + 1
    b1, b2 = cfg.beta1, cfg.beta2
    mdt = state.m and jax.tree.leaves(state.m)[0].dtype or jnp.float32
    m = jax.tree.map(
        lambda mm, g: (b1 * mm.astype(jnp.float32) + (1 - b1) * g).astype(mdt),
        state.m, grads)
    v = jax.tree.map(
        lambda vv, g: (b2 * vv.astype(jnp.float32) + (1 - b2) * g * g).astype(mdt),
        state.v, grads)
    c1 = 1 - b1 ** count.astype(jnp.float32)
    c2 = 1 - b2 ** count.astype(jnp.float32)

    def upd(p, mm, vv):
        mm, vv = mm.astype(jnp.float32), vv.astype(jnp.float32)
        step = (mm / c1) / (jnp.sqrt(vv / c2) + cfg.eps)
        return (p.astype(jnp.float32) - lr * (step + cfg.weight_decay * p.astype(jnp.float32))
                ).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, AdamWState(m=m, v=v, count=count), {"grad_norm": gnorm}


def lr_schedule(cfg: TrainConfig) -> Callable:
    def lr(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
        prog = jnp.clip((step - cfg.warmup_steps)
                        / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return cfg.learning_rate * warm * (0.1 + 0.9 * cos)

    return lr
