from repro.optim import adamw
from repro.optim.adamw import AdamWState, clip_by_global_norm, global_norm, lr_schedule

__all__ = ["adamw", "AdamWState", "clip_by_global_norm", "global_norm", "lr_schedule"]
