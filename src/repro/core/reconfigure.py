"""Runtime reconfiguration (Bertha §4, §6.2).

Replacing a chunnel implementation in a live connection requires a *switch
point* after which no thread uses the old datapath or its state. Two
coordination mechanisms, both implemented and microbenchmarked
(benchmarks/bench_reconfigure.py ~ paper Fig. 10):

  LockedConn   every send/recv takes a mutex; reconfigure() holds it across
               negotiation + state migration + swap. Simple; fast-path pays a
               lock per op.
  BarrierConn  fast path reads one boolean; reconfigure() raises the flag,
               waits for all data threads to park at a barrier (stop-the-world
               moment), swaps, releases. Near-zero fast-path cost; larger
               switch blip.

Multilateral chunnels additionally run a two-phase commit across peers while
the switch-point is held (negotiation uses the connection, so the barrier/lock
must protect it — §6.2).

Invariants of the swap itself (relied on by the reconfiguration controller):
  * State migration is aligned by chunnel NAME, not stack position — stacks of
    different depth (or with reordered layers) cannot silently skip
    ``migrate_state`` for layers a positional zip would have dropped.
  * Once every 2PC peer has voted ready, the decision is COMMIT: delivery
    failures in phase 2 are swallowed (presumed commit), never propagated out
    of the switch point, so a flaky peer cannot strand the group half-committed.
    A peer that missed the commit notification does not wait for its next
    prepare: it issues an *epoch query* back to the coordinator
    (``ReconfigParticipant.needs_resync`` / ``apply_state``, pumped by the
    HostAgent loop) and applies the committed stack — or clears its prepared
    state if the proposal turned out aborted.
  * Every handle carries a ``ConnTelemetry`` (repro.core.telemetry); the data
    path records op latency/bytes and the reconfig blip stats are folded into
    each telemetry snapshot.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional

from repro.core.chunnel import Datapath
from repro.core.fabric import approx_size
from repro.core.stack import ConcreteStack
from repro.core.telemetry import ConnTelemetry
from repro.obs.trace import NOOP_SPAN, TRACER


@dataclass
class ReconfigStats:
    switches: int = 0
    last_switch_s: float = 0.0
    total_blocked_s: float = 0.0


class ConnHandle:
    """Shared API of both mechanisms."""

    def __init__(self, stack: ConcreteStack):
        self.stack = stack
        self.dp: Datapath = stack.instantiate()
        self.stats = ReconfigStats()
        self.telemetry = ConnTelemetry()
        self.telemetry.bind_reconfig(self.stats)

    # -- data plane -----------------------------------------------------------
    def send(self, msgs) -> None:
        raise NotImplementedError

    def recv(self, buf, timeout=None) -> int:
        raise NotImplementedError

    def _record_send(self, msgs, t0: float) -> None:
        self.telemetry.record_send(len(msgs), sum(map(approx_size, msgs)),
                                   time.perf_counter() - t0)
        if TRACER.enabled:  # batch-level record only (lint: span-in-hot-loop)
            TRACER.record_batch("conn.send", len(msgs), len(msgs))

    def _record_recv(self, buf, n: int) -> None:
        if n:
            self.telemetry.record_recv(n, sum(approx_size(m) for m in buf[:n]))
            if TRACER.enabled:
                TRACER.record_batch("conn.recv", n, n)

    # -- control plane --------------------------------------------------------
    def reconfigure(self, new_stack: ConcreteStack,
                    coordinate: Optional[Callable[[], bool]] = None) -> bool:
        """Switch the live connection to ``new_stack`` (Bertha §4.2/Fig. 3).

        Acquires the mechanism's switch point (mutex for ``LockedConn``,
        stop-the-world barrier for ``BarrierConn``), then — with no thread on
        the old datapath — migrates transferable chunnel state (aligned by
        chunnel NAME), instantiates the new stack, and swaps it in.

        Args:
            new_stack: the fully-resolved ``ConcreteStack`` to switch to,
                typically one of the negotiated Stack's options.
            coordinate: optional callback run *inside* the switch point; used
                by ``HostAgent.reconfigure_multilateral`` to run the 2PC while
                the connection is quiesced (§6.2 — negotiation uses the
                connection, so the lock/barrier must protect it). Returning
                False aborts the switch with the old stack intact.

        Returns:
            True if the swap committed; False if ``coordinate`` aborted it.
            The switch blip is recorded in ``stats.last_switch_s`` and folded
            into every telemetry snapshot.
        """
        raise NotImplementedError

    def _do_swap(self, new_stack: ConcreteStack) -> None:
        # Bertha Fig. 3: ② migrate state old -> new, ③ swap implementation.
        # Alignment is by chunnel NAME: a positional zip silently skips
        # migrate_state for trailing layers when the stacks differ in depth
        # (e.g. GradCompressed error-feedback residuals dropped when switching
        # to a shorter stack) and spuriously migrates unchanged layers that
        # merely moved position.
        sp = (TRACER.span("reconfig.swap",
                          attrs={"old": self.stack.fingerprint(),
                                 "new": new_stack.fingerprint(),
                                 "mechanism": type(self).__name__})
              if TRACER.enabled else NOOP_SPAN)
        with sp:
            old_by_name: dict = {}
            for ch in self.stack.chunnels:
                old_by_name.setdefault(ch.name, ch)
            state = {}
            for new_ch in new_stack.chunnels:
                old_ch = old_by_name.get(new_ch.name)
                if old_ch is None or type(old_ch) is not type(new_ch):
                    state.update(new_ch.migrate_state(self.dp))
            old_dp = self.dp
            self.dp = new_stack.instantiate()
            if state and hasattr(self.dp, "restore_state"):
                self.dp.restore_state(state)
            if hasattr(old_dp, "close"):
                old_dp.close()
            self.stack = new_stack
            self.stats.switches += 1
            sp.set(migrated_keys=sorted(state))


class LockedConn(ConnHandle):
    def __init__(self, stack: ConcreteStack):
        super().__init__(stack)
        self._lock = threading.Lock()

    def send(self, msgs):
        msgs = msgs if isinstance(msgs, (list, tuple)) else list(msgs)
        t0 = time.perf_counter()
        with self._lock:
            # lint: allow[blocking-under-lock] the mechanism: every data op runs under the switch-point mutex (§6.2) — that serialization IS LockedConn's measured cost
            self.dp.send(msgs)
        self._record_send(msgs, t0)

    def recv(self, buf, timeout=None):
        with self._lock:
            # lint: allow[blocking-under-lock] the mechanism: recv blocks under the switch-point mutex by design (§6.2); BarrierConn is the lock-free alternative
            n = self.dp.recv(buf, timeout)
        self._record_recv(buf, n)
        return n

    def reconfigure(self, new_stack, coordinate=None):
        t0 = time.perf_counter()
        with self._lock:  # switch point = lock release
            # lint: allow[blocking-under-lock] §6.2: the 2PC coordinate() callback MUST run inside the switch point — negotiation uses the connection, so the lock protects it
            if coordinate is not None and not coordinate():
                return False
            self._do_swap(new_stack)
        self.stats.last_switch_s = time.perf_counter() - t0
        if TRACER.enabled:
            TRACER.event("reconfig.blip",
                         attrs={"mechanism": "LockedConn",
                                "blip_s": self.stats.last_switch_s})
        return True


class BarrierConn(ConnHandle):
    """Lock-free fast path (§6.2): one boolean read per op; stop-the-world
    barrier only during a reconfiguration."""

    def __init__(self, stack: ConcreteStack, n_threads: int = 1):
        super().__init__(stack)
        self.n_threads = n_threads
        self._pause = False  # plain attribute read: GIL-atomic
        self._barrier = threading.Barrier(n_threads + 1)
        self._resume = threading.Event()
        self._resume.set()

    def _checkpoint(self):
        if self._pause:
            t0 = time.perf_counter()
            self._barrier.wait()
            self._resume.wait()
            self.stats.total_blocked_s += time.perf_counter() - t0

    def send(self, msgs):
        self._checkpoint()
        msgs = msgs if isinstance(msgs, (list, tuple)) else list(msgs)
        t0 = time.perf_counter()
        self.dp.send(msgs)
        self._record_send(msgs, t0)

    def recv(self, buf, timeout=None):
        self._checkpoint()
        n = self.dp.recv(buf, timeout)
        self._record_recv(buf, n)
        return n

    def reconfigure(self, new_stack, coordinate=None):
        t0 = time.perf_counter()
        self._resume.clear()
        self._pause = True
        self._barrier.wait()  # all data threads parked: the switch point
        try:
            if coordinate is not None and not coordinate():
                return False
            self._do_swap(new_stack)
            return True
        finally:
            self._pause = False
            self._barrier.reset()
            self._resume.set()
            self.stats.last_switch_s = time.perf_counter() - t0
            if TRACER.enabled:
                TRACER.event("reconfig.blip",
                             attrs={"mechanism": "BarrierConn",
                                    "blip_s": self.stats.last_switch_s})


# ---------------------------------------------------------------------------
# Multilateral two-phase commit between connection peers (§4.2)
# ---------------------------------------------------------------------------


def two_phase_commit(chan_request: Callable[[str, dict], dict], peers: List[str],
                     new_fp: str, *, timeout_s: float = 2.0,
                     epoch: Optional[int] = None,
                     on_decide: Optional[Callable[[], None]] = None) -> bool:
    """Coordinator side. chan_request(peer, msg) -> reply (reliable).

    Phase 1: all peers must accept for the transition to commit; any refusal
    or timeout aborts (a faulty peer cannot force others to switch).

    Phase 2 is presumed-commit: once every peer has voted ready the decision
    IS commit, so delivery failures must not escape the switch point and
    strand a mixed prepared/committed group — the notification loops swallow
    timeouts (the ReliableChannel already retries underneath; a peer that
    stays prepared resyncs eagerly via the epoch query, see
    ``ReconfigParticipant``). ``epoch`` (the coordinator's post-commit switch
    count) is piggybacked on the commit so peers can order it against later
    queries.

    ``on_decide`` fires exactly at the commit point — after the last ready
    vote, BEFORE any phase-2 notification. The coordinator uses it to record
    the decided epoch so that an epoch query arriving while notifications are
    still draining (they can block for seconds on an unreachable peer) is
    answered with the COMMIT decision, not the not-yet-applied local state —
    otherwise a merely-delayed peer would mistake the in-flight commit for an
    abort, clear its prepared state, and refuse the real commit when it
    lands."""
    ready = []
    sp = (TRACER.span("2pc.prepare", attrs={"fp": new_fp, "peers": list(peers)})
          if TRACER.enabled else NOOP_SPAN)
    with sp:
        for p in peers:
            try:
                r = chan_request(p, {"type": "reconfig_prepare", "fp": new_fp})
            except TimeoutError:
                r = {"type": "reconfig_refuse"}
            sp.event("vote", peer=p, vote=r.get("type"))
            if r.get("type") != "reconfig_ready":
                sp.set(status="aborted", aborted_by=p)
                for q in ready:
                    try:
                        chan_request(q, {"type": "reconfig_abort", "fp": new_fp})
                    except TimeoutError:
                        pass  # abort is also just a notification of a made decision
                return False
            ready.append(p)
    if TRACER.enabled:
        # the presumed-commit point: after the last ready vote, before any
        # phase-2 notification (the decision exists even if none land)
        TRACER.event("2pc.decide", attrs={"fp": new_fp, "epoch": epoch})
    if on_decide is not None:
        on_decide()
    commit = {"type": "reconfig_commit", "fp": new_fp}
    if epoch is not None:
        commit["epoch"] = epoch
    sp = (TRACER.span("2pc.commit", attrs={"fp": new_fp, "epoch": epoch})
          if TRACER.enabled else NOOP_SPAN)
    with sp:
        for p in peers:
            try:
                chan_request(p, commit)
                sp.event("notified", peer=p)
            except TimeoutError:
                sp.event("notify_lost", peer=p, drop_reason="timeout")
                # decision already made; see docstring
    return True


class ReconfigParticipant:
    """Peer side of the 2PC; wire into the host agent's message loop.

    2PC here is presumed-commit: once every peer voted ready the decision IS
    commit, and phase-2 notifications are best-effort. A peer that misses the
    commit (or abort) would historically stay prepared until its next
    prepare; instead, after ``resync_after_s`` of being prepared it asks the
    coordinator for the connection's current epoch + active fingerprint
    (``needs_resync`` names whom to ask; the owning ``HostAgent`` sends the
    ``reconfig_query`` and feeds the reply to ``apply_state``).

    ``epoch`` is the coordinator's switch counter: a reply with a NEWER epoch
    than we last acted on means a decision was made without us — we adopt the
    committed stack if it resolves; either way the stale prepared state is
    cleared (an equal epoch means the proposal aborted).
    """

    def __init__(self, handle: ConnHandle,
                 resolve: Callable[[str], Optional[ConcreteStack]],
                 *, resync_after_s: float = 1.0,
                 now: Callable[[], float] = time.monotonic):
        self.handle = handle
        self.resolve = resolve  # fp -> ConcreteStack we could switch to
        self.resync_after_s = resync_after_s
        self.epoch = 0  # last coordinator epoch we have acted on
        self._now = now
        self._prepared: Optional[str] = None
        self._prepared_src: Optional[str] = None
        self._prepared_at: Optional[float] = None
        self.resync_failures = 0  # epoch queries that timed out (chaos stat)

    @property
    def prepared(self) -> Optional[str]:
        """Fingerprint this peer is currently prepared for (None once the
        decision arrived or was resynced) — 'stranded' means non-None long
        after the coordinator decided."""
        return self._prepared

    def _clear_prepared(self) -> None:
        self._prepared = self._prepared_src = self._prepared_at = None

    def handle_msg(self, src: str, msg: dict) -> dict:
        t = msg.get("type")
        if t == "reconfig_prepare":
            with (TRACER.span("2pc.peer.prepare",
                              attrs={"coordinator": src, "fp": msg["fp"]})
                  if TRACER.enabled else NOOP_SPAN) as sp:
                st = self.resolve(msg["fp"])
                if st is None:
                    sp.set(vote="reconfig_refuse")
                    return {"type": "reconfig_refuse"}
                self._prepared = msg["fp"]
                self._prepared_src = src
                self._prepared_at = self._now()
                sp.set(vote="reconfig_ready")
                return {"type": "reconfig_ready"}
        if t == "reconfig_commit" and self._prepared == msg["fp"]:
            with (TRACER.span("2pc.peer.commit",
                              attrs={"coordinator": src, "fp": msg["fp"]})
                  if TRACER.enabled else NOOP_SPAN) as sp:
                st = self.resolve(msg["fp"])
                self.handle.reconfigure(st)  # nests the peer's reconfig.swap
                self.epoch = int(msg.get("epoch") or self.epoch + 1)
                self._clear_prepared()
                sp.set(epoch=self.epoch)
                return {"type": "reconfig_done"}
        if t == "reconfig_abort":
            if TRACER.enabled:
                TRACER.event("2pc.peer.abort",
                             attrs={"coordinator": src, "fp": msg.get("fp")})
            self._clear_prepared()
            return {"type": "reconfig_aborted"}
        return {"type": "reconfig_refuse"}

    # -- prepared-peer resync (epoch query) -----------------------------------
    def needs_resync(self, now: Optional[float] = None) -> Optional[str]:
        """Address of the coordinator to query, when this peer has been
        sitting prepared longer than ``resync_after_s`` (i.e. the phase-2
        notification is presumed lost); None otherwise."""
        if self._prepared is None or self._prepared_src is None:
            return None
        now = self._now() if now is None else now
        if now - self._prepared_at < self.resync_after_s:
            return None
        return self._prepared_src

    def defer_resync(self) -> None:
        """Push the next resync attempt out by a full window (called when a
        query itself timed out — don't hot-loop on an unreachable peer).
        Counted in ``resync_failures``: under a coordinator partition this
        climbs until heal, then the next window converges."""
        self.resync_failures += 1
        if self._prepared_at is not None:
            self._prepared_at = self._now()

    def apply_state(self, state: dict) -> bool:
        """Fold a ``reconfig_state`` query reply in; returns True if a missed
        commit was applied.

        A newer coordinator epoch with a resolvable fingerprint different
        from our active stack means we missed a commit: adopt it. A
        ``pending`` reply means the 2PC is still collecting votes — nothing
        is decided, so we stay prepared and re-query next window. Anything
        else (same epoch ⇒ the proposal aborted; ``reconfig_refuse`` ⇒ the
        coordinator no longer knows the connection) just clears the stale
        prepared state — the documented §4.2 failure semantics, now reached
        eagerly instead of at the next prepare."""
        if state.get("type") != "reconfig_state":
            self._clear_prepared()
            return False
        if state.get("pending"):
            self.defer_resync()  # decision in flight: wait, don't conclude
            return False
        fp = state.get("fp")
        epoch = int(state.get("epoch") or 0)
        applied = False
        if epoch > self.epoch and fp:
            st = self.resolve(fp)
            if st is not None and self.handle.stack.fingerprint() != fp:
                applied = bool(self.handle.reconfigure(st))
            self.epoch = epoch
        if TRACER.enabled:
            TRACER.event("2pc.resync",
                         attrs={"fp": fp, "epoch": epoch, "applied": applied})
        self._clear_prepared()
        return applied
