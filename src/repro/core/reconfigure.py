"""Runtime reconfiguration (Bertha §4, §6.2).

Replacing a chunnel implementation in a live connection requires a *switch
point* after which no thread uses the old datapath or its state. Two
coordination mechanisms, both implemented and microbenchmarked
(benchmarks/bench_reconfigure.py ~ paper Fig. 10):

  LockedConn   every send/recv takes a mutex; reconfigure() holds it across
               negotiation + state migration + swap. Simple; fast-path pays a
               lock per op.
  BarrierConn  fast path reads one boolean; reconfigure() raises the flag,
               waits for all data threads to park at a barrier (stop-the-world
               moment), swaps, releases. Near-zero fast-path cost; larger
               switch blip.

Multilateral chunnels additionally run a two-phase commit across peers while
the switch-point is held (negotiation uses the connection, so the barrier/lock
must protect it — §6.2).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional

from repro.core.chunnel import Datapath
from repro.core.stack import ConcreteStack


@dataclass
class ReconfigStats:
    switches: int = 0
    last_switch_s: float = 0.0
    total_blocked_s: float = 0.0


class ConnHandle:
    """Shared API of both mechanisms."""

    def __init__(self, stack: ConcreteStack):
        self.stack = stack
        self.dp: Datapath = stack.instantiate()
        self.stats = ReconfigStats()

    # -- data plane -----------------------------------------------------------
    def send(self, msgs) -> None:
        raise NotImplementedError

    def recv(self, buf, timeout=None) -> int:
        raise NotImplementedError

    # -- control plane --------------------------------------------------------
    def reconfigure(self, new_stack: ConcreteStack,
                    coordinate: Optional[Callable[[], bool]] = None) -> bool:
        """Switch to ``new_stack``. ``coordinate`` runs *inside* the switch
        point (for multilateral 2PC); returning False aborts the switch."""
        raise NotImplementedError

    def _do_swap(self, new_stack: ConcreteStack) -> None:
        # Bertha Fig. 3: ② migrate state old -> new, ③ swap implementation.
        state = {}
        for old_ch, new_ch in zip(self.stack.chunnels, new_stack.chunnels):
            if type(old_ch) is not type(new_ch):
                state.update(new_ch.migrate_state(self.dp))
        old_dp = self.dp
        self.dp = new_stack.instantiate()
        if state and hasattr(self.dp, "restore_state"):
            self.dp.restore_state(state)
        if hasattr(old_dp, "close"):
            old_dp.close()
        self.stack = new_stack
        self.stats.switches += 1


class LockedConn(ConnHandle):
    def __init__(self, stack: ConcreteStack):
        super().__init__(stack)
        self._lock = threading.Lock()

    def send(self, msgs):
        with self._lock:
            self.dp.send(msgs)

    def recv(self, buf, timeout=None):
        with self._lock:
            return self.dp.recv(buf, timeout)

    def reconfigure(self, new_stack, coordinate=None):
        t0 = time.perf_counter()
        with self._lock:  # switch point = lock release
            if coordinate is not None and not coordinate():
                return False
            self._do_swap(new_stack)
        self.stats.last_switch_s = time.perf_counter() - t0
        return True


class BarrierConn(ConnHandle):
    """Lock-free fast path (§6.2): one boolean read per op; stop-the-world
    barrier only during a reconfiguration."""

    def __init__(self, stack: ConcreteStack, n_threads: int = 1):
        super().__init__(stack)
        self.n_threads = n_threads
        self._pause = False  # plain attribute read: GIL-atomic
        self._barrier = threading.Barrier(n_threads + 1)
        self._resume = threading.Event()
        self._resume.set()

    def _checkpoint(self):
        if self._pause:
            t0 = time.perf_counter()
            self._barrier.wait()
            self._resume.wait()
            self.stats.total_blocked_s += time.perf_counter() - t0

    def send(self, msgs):
        self._checkpoint()
        self.dp.send(msgs)

    def recv(self, buf, timeout=None):
        self._checkpoint()
        return self.dp.recv(buf, timeout)

    def reconfigure(self, new_stack, coordinate=None):
        t0 = time.perf_counter()
        self._resume.clear()
        self._pause = True
        self._barrier.wait()  # all data threads parked: the switch point
        try:
            if coordinate is not None and not coordinate():
                return False
            self._do_swap(new_stack)
            return True
        finally:
            self._pause = False
            self._barrier.reset()
            self._resume.set()
            self.stats.last_switch_s = time.perf_counter() - t0


# ---------------------------------------------------------------------------
# Multilateral two-phase commit between connection peers (§4.2)
# ---------------------------------------------------------------------------


def two_phase_commit(chan_request: Callable[[str, dict], dict], peers: List[str],
                     new_fp: str, *, timeout_s: float = 2.0) -> bool:
    """Coordinator side. chan_request(peer, msg) -> reply (reliable).
    All peers must accept for the transition to commit; any refusal or timeout
    aborts (a faulty peer cannot force others to switch)."""
    ready = []
    for p in peers:
        try:
            r = chan_request(p, {"type": "reconfig_prepare", "fp": new_fp})
        except TimeoutError:
            r = {"type": "reconfig_refuse"}
        if r.get("type") != "reconfig_ready":
            for q in ready:
                chan_request(q, {"type": "reconfig_abort", "fp": new_fp})
            return False
        ready.append(p)
    for p in peers:
        chan_request(p, {"type": "reconfig_commit", "fp": new_fp})
    return True


class ReconfigParticipant:
    """Peer side of the 2PC; wire into the host agent's message loop."""

    def __init__(self, handle: ConnHandle, resolve: Callable[[str], Optional[ConcreteStack]]):
        self.handle = handle
        self.resolve = resolve  # fp -> ConcreteStack we could switch to
        self._prepared: Optional[str] = None

    def handle_msg(self, src: str, msg: dict) -> dict:
        t = msg.get("type")
        if t == "reconfig_prepare":
            st = self.resolve(msg["fp"])
            if st is None:
                return {"type": "reconfig_refuse"}
            self._prepared = msg["fp"]
            return {"type": "reconfig_ready"}
        if t == "reconfig_commit" and self._prepared == msg["fp"]:
            st = self.resolve(msg["fp"])
            self.handle.reconfigure(st)
            self._prepared = None
            return {"type": "reconfig_done"}
        if t == "reconfig_abort":
            self._prepared = None
            return {"type": "reconfig_aborted"}
        return {"type": "reconfig_refuse"}
