"""Cost models and multi-objective scoring over negotiated option sets.

Bertha's promise is that the *runtime* picks the best communication stack for
where a program runs and what it needs (§5, §7) — but picking requires a cost
model (cf. Morpheus, PAPERS.md: online specialization pays off only when a
cost model drives the choice). This module is that model:

  CostModel   per-chunnel static annotations: estimated added latency per
              data-plane op, DCN/wire bytes emitted per payload byte, and the
              switch blip paid to instantiate it (re-jit, barrier, 2PC).
  Objective   the weights (and unit normalizers) that fold the three cost
              dimensions into one scalar. ``LATENCY_FIRST`` / ``BYTES_FIRST``
              are the built-in presets policies name instead of naming targets.
  utility     CostModel x Objective x live telemetry snapshot -> scalar,
              higher is better. Telemetry scales the static model to the
              actual workload: the latency term is paid once per op
              (``ops_per_s``), the byte term once per payload byte
              (``bytes_per_s``), and the blip is amortized over
              ``Objective.amortize_s`` — and only charged to options that are
              not already active, which is a natural switch damper.
  ScoredTarget a *dynamic* Rule target for ``repro.core.controller``: resolved
              per tick to the argmax-utility candidate under the live
              snapshot, instead of hard-coding one target per rule.

Ties break toward the earlier candidate (server/developer preference order),
so stacks whose chunnels carry no cost annotations behave exactly like the
historical first-compatible selection.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.obs.trace import NOOP_SPAN, TRACER


def target_label(target: Any) -> str:
    """Stable identity of a switch target: a ConcreteStack's fingerprint, or
    str() for plain labels (e.g. trainer transport names)."""
    fp = getattr(target, "fingerprint", None)
    return fp() if callable(fp) else str(target)


@dataclass(frozen=True)
class CostModel:
    """Static cost annotations of one chunnel (or a whole concrete stack).

    op_latency_s        estimated latency this chunnel adds to each data-plane
                        op (a send batch, an RTT, a training step)
    dcn_bytes_per_byte  wire/DCN bytes emitted per payload byte: 1.0 is
                        neutral, a compressor is < 1, a replicator is > 1
    switch_blip_s       estimated cost of switching TO this chunnel (re-jit,
                        stop-the-world barrier, 2PC round-trips)

    The neutral default makes unannotated chunnels free — scoring then
    degrades gracefully to preference order.
    """

    op_latency_s: float = 0.0
    dcn_bytes_per_byte: float = 1.0
    switch_blip_s: float = 0.0


NEUTRAL = CostModel()

# -- measured overrides (trace-derived calibration) ---------------------------
# ``repro.obs.calibrate`` installs MEASURED per-chunnel cost fields (keyed by
# chunnel name, values are partial CostModel field dicts) and per-stack switch
# blips (keyed by ConcreteStack fingerprint, from reconfig.swap span
# durations). The hand-written annotations stay as priors; measured fields
# override them wherever a trace produced enough samples. Process-wide, like
# ``repro.comm.chunnels.cost_calibration`` (which funnels into this).
_MEASURED_CHUNNELS: Dict[str, Dict[str, float]] = {}
_MEASURED_BLIPS: Dict[str, float] = {}


def chunnel_name(ch: Any) -> str:
    """The name trace records/calibration key a chunnel by: ``fn_name``
    (FnChunnel), then ``name``, then the class name."""
    return (getattr(ch, "fn_name", None) or getattr(ch, "name", None)
            or type(ch).__name__)


def install_measured_costs(chunnels: Optional[Dict[str, Dict[str, float]]] = None,
                           stack_blips: Optional[Dict[str, float]] = None
                           ) -> None:
    """Merge measured cost fields into the process-wide override tables.

    ``chunnels`` maps chunnel name -> partial CostModel fields (e.g.
    ``{"op_latency_s": 2.1e-3, "dcn_bytes_per_byte": 0.4}``); ``stack_blips``
    maps stack fingerprint -> measured switch blip seconds.
    """
    for name, fields in (chunnels or {}).items():
        _MEASURED_CHUNNELS.setdefault(name, {}).update(fields)
    _MEASURED_BLIPS.update(stack_blips or {})


def measured_costs() -> Tuple[Dict[str, Dict[str, float]], Dict[str, float]]:
    """(chunnel overrides, stack blips) currently installed (copies)."""
    return ({k: dict(v) for k, v in _MEASURED_CHUNNELS.items()},
            dict(_MEASURED_BLIPS))


def reset_measured_costs() -> None:
    _MEASURED_CHUNNELS.clear()
    _MEASURED_BLIPS.clear()


def chunnel_cost(ch: Any) -> CostModel:
    """A chunnel's cost model: its static annotation (NEUTRAL when it
    carries none), with any MEASURED fields overriding the annotation."""
    fn = getattr(ch, "cost_model", None)
    out = fn() if callable(fn) else None
    out = out if isinstance(out, CostModel) else NEUTRAL
    if _MEASURED_CHUNNELS:
        m = _MEASURED_CHUNNELS.get(chunnel_name(ch))
        if m:
            out = replace(out, **m)
    return out


def stack_cost(stack: Any) -> CostModel:
    """Fold a ConcreteStack's chunnel cost models into one.

    Latencies and blips add; byte ratios multiply (a compressor below a
    replicator compresses the replicated bytes). A measured whole-stack blip
    (from ``reconfig.swap`` span durations) replaces the additive estimate —
    the swap IS the blip, measured end to end."""
    lat = blip = 0.0
    ratio = 1.0
    for ch in getattr(stack, "chunnels", ()):
        c = chunnel_cost(ch)
        lat += c.op_latency_s
        blip += c.switch_blip_s
        ratio *= c.dcn_bytes_per_byte
    if _MEASURED_BLIPS:   # keep fingerprint() off the common path
        fp = getattr(stack, "fingerprint", None)
        if callable(fp):
            measured = _MEASURED_BLIPS.get(fp())
            if measured is not None:
                blip = measured
    return CostModel(lat, ratio, blip)


@dataclass(frozen=True)
class Objective:
    """Weights + unit normalizers folding a CostModel into one scalar.

    ``dcn_s_per_byte`` converts wire bytes into seconds (1/bandwidth; default
    1 GB/s of DCN), so every term of the objective is in seconds of overhead
    per second of wall clock and the weights are comparable. ``amortize_s`` is
    the horizon over which a switch blip is written off — a short horizon
    makes the scorer switch-averse."""

    w_latency: float = 1.0
    w_bytes: float = 1.0
    w_blip: float = 1.0
    dcn_s_per_byte: float = 1e-9
    amortize_s: float = 30.0
    name: str = "balanced"


DEFAULT_OBJECTIVE = Objective()
LATENCY_FIRST = Objective(w_latency=1.0, w_bytes=0.05, name="latency_first")
BYTES_FIRST = Objective(w_latency=0.05, w_bytes=1.0, name="bytes_first")

#: workload assumed when scoring with NO telemetry at all (negotiation before
#: any traffic): 1 op/s and 1 MB/s keep both cost dimensions in play, so a
#: bytes-weighted objective still orders options by their byte annotations
NOMINAL_OPS_PER_S = 1.0
NOMINAL_BYTES_PER_S = 1e6


def utility(cost: CostModel, objective: Objective = DEFAULT_OBJECTIVE,
            snapshot: Optional[dict] = None, *, switching: bool = False) -> float:
    """Score one option under live telemetry; HIGHER is better.

    The value is the negated modeled overhead rate (seconds of communication
    overhead per second of wall clock):

      w_latency * op_latency_s      * ops_per_s
    + w_bytes   * dcn_bytes_per_byte * bytes_per_s * dcn_s_per_byte
    + w_blip    * switch_blip_s / amortize_s          (only if ``switching``)

    With no snapshot (negotiation time, before any traffic) the nominal
    workload ``NOMINAL_OPS_PER_S``/``NOMINAL_BYTES_PER_S`` applies, so BOTH
    dimensions' annotations still order the options (a bytes-weighted
    objective must not silently degrade to latency-only). Rates MEASURED as
    0.0 stay 0 — an idle connection's scores must not rank candidates by
    traffic that does not exist.
    """
    s = snapshot if snapshot is not None else {
        "ops_per_s": NOMINAL_OPS_PER_S, "bytes_per_s": NOMINAL_BYTES_PER_S}
    ops = s.get("ops_per_s")
    ops = NOMINAL_OPS_PER_S if ops is None else ops
    byte_rate = s.get("bytes_per_s") or 0.0
    c = (objective.w_latency * cost.op_latency_s * ops
         + objective.w_bytes * cost.dcn_bytes_per_byte * byte_rate
         * objective.dcn_s_per_byte)
    if switching:
        c += objective.w_blip * cost.switch_blip_s / max(objective.amortize_s, 1e-9)
    return -c


def score_stack(stack: Any, objective: Objective = DEFAULT_OBJECTIVE,
                snapshot: Optional[dict] = None, *, switching: bool = False) -> float:
    """``utility`` of a whole ConcreteStack (folds its chunnel cost models)."""
    return utility(stack_cost(stack), objective, snapshot, switching=switching)


@dataclass(frozen=True)
class Candidate:
    """One scoreable switch target: what ``switch()`` receives, its cost
    model, and a stable label compared against ``current()``."""

    target: Any
    cost: CostModel = NEUTRAL
    label: str = ""

    def __post_init__(self):
        if not self.label:
            object.__setattr__(self, "label", target_label(self.target))

    def multilateral(self) -> bool:
        m = getattr(self.target, "multilateral", None)
        return bool(m()) if callable(m) else False


def as_candidate(obj: Any) -> Candidate:
    """Coerce a Candidate / ConcreteStack / plain label into a Candidate.
    ConcreteStacks get their folded chunnel cost models; anything else is
    neutral unless wrapped in a Candidate explicitly."""
    if isinstance(obj, Candidate):
        return obj
    if hasattr(obj, "chunnels"):
        return Candidate(obj, stack_cost(obj))
    return Candidate(obj)


def rank(candidates: Sequence[Candidate], objective: Objective = DEFAULT_OBJECTIVE,
         snapshot: Optional[dict] = None,
         current_label: Optional[str] = None) -> List[Tuple[float, Candidate]]:
    """Score every candidate (blip charged only to non-current ones), best
    first; ties keep the input preference order."""
    scored = [(utility(c.cost, objective, snapshot,
                       switching=(c.label != current_label)), i, c)
              for i, c in enumerate(candidates)]
    scored.sort(key=lambda t: (-t[0], t[1]))
    return [(u, c) for u, _, c in scored]


class ScoredTarget:
    """A Rule target that is an *objective*, not a stack: resolved per
    controller tick to the argmax-utility candidate under the live snapshot.

    ``margin`` adds hysteresis in score space: the argmax must beat the
    currently-active candidate's utility by ``margin * |current utility|``
    before the resolution moves off it (on top of the switch-blip term, which
    already biases toward staying put)."""

    def __init__(self, candidates: Sequence[Any],
                 objective: Objective = DEFAULT_OBJECTIVE, *, margin: float = 0.0):
        self.candidates = [as_candidate(c) for c in candidates]
        if not self.candidates:
            raise ValueError("ScoredTarget needs at least one candidate")
        self.objective = objective
        self.margin = margin

    def multilateral(self) -> bool:
        return any(c.multilateral() for c in self.candidates)

    def resolve(self, snapshot: Optional[dict] = None,
                current_label: Optional[str] = None) -> Any:
        """The argmax-utility candidate's target under ``snapshot``."""
        sp = (TRACER.span("negotiate.score",
                          attrs={"objective": self.objective.name,
                                 "current": current_label})
              if TRACER.enabled else NOOP_SPAN)
        with sp:
            ranked = rank(self.candidates, self.objective, snapshot,
                          current_label)
            best_u, best = ranked[0]
            # per-candidate utilities: the trace's record of which stacks
            # lost the scoring round and by how much
            sp.set(scores={c.label: u for u, c in ranked},
                   chosen=best.label)
            if current_label is not None and best.label != current_label:
                cur = next(((u, c) for u, c in ranked
                            if c.label == current_label), None)
                if cur is not None and best_u <= cur[0] + self.margin * abs(cur[0]):
                    sp.set(chosen=current_label, reason="margin_hold")
                    return cur[1].target
            return best.target

    def __repr__(self):
        return (f"ScoredTarget({len(self.candidates)} candidates, "
                f"objective={self.objective.name})")


def resolve_target(target: Any, snapshot: Optional[dict] = None,
                   current_label: Optional[str] = None) -> Any:
    """Resolve a (possibly dynamic) Rule target: objects with a ``resolve``
    method (ScoredTarget) are evaluated against the snapshot; anything else is
    already concrete."""
    r = getattr(target, "resolve", None)
    return r(snapshot, current_label) if callable(r) else target
