"""Bertha core: reconfigurable, extensible communication stacks.

Glossary (paper Table 1):
  Chunnel          a specific piece of network functionality
  Chunnel stack    an application's specification of the chunnels it wants
  Reconfiguration  picking/changing chunnel implementations at runtime
  Negotiation      ensuring implementations are compatible across endpoints
"""
from repro.core.capability import Capability, CapabilitySet
from repro.core.chunnel import ANY, Chunnel, Datapath, FnChunnel, WireType
from repro.core.controller import (
    Decision,
    PolicyContext,
    ReconfigController,
    Rule,
    above,
    all_of,
    any_of,
    available_policies,
    below,
    conn_controller,
    get_policy,
    option_named,
    policy_rules,
    register_policy,
    stack_candidates,
    target_label,
)
from repro.core.cost import (
    BYTES_FIRST,
    DEFAULT_OBJECTIVE,
    LATENCY_FIRST,
    Candidate,
    CostModel,
    Objective,
    ScoredTarget,
    score_stack,
    stack_cost,
    utility,
)
from repro.core.fabric import Fabric, LinkModel, ReliableChannel
from repro.core.negotiate import (
    NegotiatedConn,
    NegotiationError,
    ServerNegotiator,
    ZeroRttCache,
    client_negotiate,
    pick_compatible,
)
from repro.core.reconfigure import BarrierConn, ConnHandle, LockedConn
from repro.core.rendezvous import KVStore, TxnConflict
from repro.core.runtime import FabricTransport, HostAgent
from repro.core.stack import ConcreteStack, Select, Stack, StackTypeError, make_stack
from repro.core.telemetry import ConnTelemetry, Ewma, EwmaQuantile

__all__ = [
    "ANY", "BYTES_FIRST", "Capability", "CapabilitySet", "Candidate", "Chunnel",
    "ConcreteStack", "ConnHandle", "ConnTelemetry", "CostModel",
    "DEFAULT_OBJECTIVE", "Datapath", "Decision", "Ewma", "EwmaQuantile",
    "Fabric", "FabricTransport", "FnChunnel", "HostAgent", "KVStore",
    "LATENCY_FIRST", "LinkModel", "LockedConn", "BarrierConn", "NegotiatedConn",
    "NegotiationError", "Objective", "PolicyContext", "ReconfigController",
    "ReliableChannel", "Rule", "ScoredTarget", "Select", "ServerNegotiator",
    "Stack", "StackTypeError", "TxnConflict", "WireType", "ZeroRttCache",
    "above", "all_of",
    "any_of", "available_policies", "below", "client_negotiate",
    "conn_controller", "get_policy", "make_stack", "option_named",
    "pick_compatible", "policy_rules", "register_policy", "score_stack",
    "stack_candidates", "stack_cost", "target_label", "utility",
]
