"""Bertha core: reconfigurable, extensible communication stacks.

Glossary (paper Table 1):
  Chunnel          a specific piece of network functionality
  Chunnel stack    an application's specification of the chunnels it wants
  Reconfiguration  picking/changing chunnel implementations at runtime
  Negotiation      ensuring implementations are compatible across endpoints
"""
from repro.core.capability import Capability, CapabilitySet
from repro.core.chunnel import ANY, Chunnel, Datapath, FnChunnel, WireType
from repro.core.controller import (
    Decision,
    ReconfigController,
    Rule,
    above,
    all_of,
    any_of,
    below,
    conn_controller,
    option_named,
    target_label,
)
from repro.core.fabric import Fabric, LinkModel, ReliableChannel
from repro.core.negotiate import (
    NegotiatedConn,
    NegotiationError,
    ServerNegotiator,
    ZeroRttCache,
    client_negotiate,
    pick_compatible,
)
from repro.core.reconfigure import BarrierConn, ConnHandle, LockedConn
from repro.core.rendezvous import KVStore
from repro.core.runtime import FabricTransport, HostAgent
from repro.core.stack import ConcreteStack, Select, Stack, StackTypeError, make_stack
from repro.core.telemetry import ConnTelemetry, Ewma, EwmaQuantile

__all__ = [
    "ANY", "Capability", "CapabilitySet", "Chunnel", "ConcreteStack", "ConnHandle",
    "ConnTelemetry", "Datapath", "Decision", "Ewma", "EwmaQuantile", "Fabric",
    "FabricTransport", "FnChunnel", "HostAgent", "KVStore",
    "LinkModel", "LockedConn", "BarrierConn", "NegotiatedConn", "NegotiationError",
    "ReconfigController", "ReliableChannel", "Rule", "Select", "ServerNegotiator",
    "Stack", "StackTypeError", "WireType", "ZeroRttCache", "above", "all_of",
    "any_of", "below", "client_negotiate", "conn_controller", "make_stack",
    "option_named", "pick_compatible", "target_label",
]
