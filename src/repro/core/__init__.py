"""Bertha core: reconfigurable, extensible communication stacks.

Glossary (paper Table 1):
  Chunnel          a specific piece of network functionality
  Chunnel stack    an application's specification of the chunnels it wants
  Reconfiguration  picking/changing chunnel implementations at runtime
  Negotiation      ensuring implementations are compatible across endpoints
"""
from repro.core.capability import Capability, CapabilitySet
from repro.core.chunnel import ANY, Chunnel, Datapath, FnChunnel, WireType
from repro.core.fabric import Fabric, LinkModel, ReliableChannel
from repro.core.negotiate import (
    NegotiatedConn,
    NegotiationError,
    ServerNegotiator,
    ZeroRttCache,
    client_negotiate,
    pick_compatible,
)
from repro.core.reconfigure import BarrierConn, ConnHandle, LockedConn
from repro.core.rendezvous import KVStore
from repro.core.runtime import FabricTransport, HostAgent
from repro.core.stack import ConcreteStack, Select, Stack, StackTypeError, make_stack

__all__ = [
    "ANY", "Capability", "CapabilitySet", "Chunnel", "ConcreteStack", "ConnHandle",
    "Datapath", "Fabric", "FabricTransport", "FnChunnel", "HostAgent", "KVStore",
    "LinkModel", "LockedConn", "BarrierConn", "NegotiatedConn", "NegotiationError",
    "ReliableChannel", "Select", "ServerNegotiator", "Stack", "StackTypeError",
    "WireType", "ZeroRttCache", "client_negotiate", "make_stack", "pick_compatible",
]
