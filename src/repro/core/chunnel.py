"""The Chunnel abstraction (Bertha §3).

A Chunnel is a single unit of communication functionality that can
  (a) transform data (serialize / compress / encrypt),
  (b) decide where data goes (shard / route / replicate), or
  (c) touch the transport (send/receive).

``connect_wrap(inner)`` composes a Chunnel over an inner Datapath, mirroring the
paper's ChunnelTransformer/ChunnelDatapath split. Datapath type safety is
enforced at stack-assembly time via WireTypes (the Rust-compile-time check is a
Python raise-at-build-time check here — both happen before any data flows).

Two chunnel families share this interface:
  * host chunnels  — move Python messages over the host fabric (pub/sub,
    routing, reliability, ordering): the paper's §7 application plane.
  * step chunnels  — transform the jitted training/serving step dataflow
    (gradient wire formats, collective schedules): the TPU "transport" plane.
    Their connect_wrap composes *trace-time*, so like Rust monomorphization the
    compiled program carries zero dynamic-dispatch overhead (verified in
    benchmarks/bench_overhead.py).
"""
from __future__ import annotations

import abc
import time
from dataclasses import dataclass, field
from typing import Any, Iterable, Optional, Sequence

from repro.core.capability import CapabilitySet
from repro.core.cost import NEUTRAL, CostModel
from repro.obs.trace import TRACER


@dataclass(frozen=True)
class WireType:
    """Datapath data type, e.g. WireType('grads', dtype='f32')."""

    name: str
    attrs: tuple = ()  # sorted (key, value) pairs

    @staticmethod
    def of(name: str, **attrs) -> "WireType":
        return WireType(name, tuple(sorted(attrs.items())))

    def __str__(self) -> str:
        a = ",".join(f"{k}={v}" for k, v in self.attrs)
        return f"{self.name}[{a}]" if a else self.name


ANY = WireType.of("any")


def types_match(a: WireType, b: WireType) -> bool:
    return ANY in (a, b) or a == b


class Datapath(abc.ABC):
    """A live connection endpoint (the paper's ChunnelDatapath).

    The contract is batch-aware (docs/architecture.md §8): ``send`` takes the
    WHOLE batch and implementations must transform/forward it per call — one
    inner ``send``, one fabric ``send_batch``, one device program — never a
    per-element loop of singleton sends. Per-message transforms are lifted to
    the batch contract only through the explicit :func:`per_message` adapter
    (the one sanctioned per-element loop; ``repro.lint``'s
    ``per-message-hot-path`` rule flags hand-written ones). ``recv`` fills
    ``buf`` and may block up to ``timeout`` for the FIRST message only — it
    drains what is available rather than waiting for a full buffer."""

    @abc.abstractmethod
    def send(self, msgs: Iterable[Any]) -> None: ...

    @abc.abstractmethod
    def recv(self, buf: list, timeout: Optional[float] = None) -> int:
        """Fill ``buf`` with received messages; return count."""

    def close(self) -> None:
        pass


def per_message(fn: Any) -> Any:
    """Lift a per-message transform to the batch contract — the explicit
    escape hatch for transforms that genuinely cannot vectorize. This is the
    only sanctioned per-element loop on a Datapath hot path; the
    ``per-message-hot-path`` lint rule exists to flag hand-written ones."""

    def _batch(msgs: list) -> list:
        return [fn(m) for m in msgs]

    _batch.per_message = True  # type: ignore[attr-defined]
    _batch.__wrapped__ = fn  # type: ignore[attr-defined]
    return _batch


class Chunnel(abc.ABC):
    """The paper's ChunnelTransformer: wraps an inner Datapath with new
    functionality and reports type/capability metadata for negotiation."""

    #: data type accepted from the layer above / produced to the layer below
    upper_type: WireType = ANY
    lower_type: WireType = ANY
    #: True if replacing this chunnel requires agreement among all endpoints
    multilateral: bool = False

    @property
    def name(self) -> str:
        return type(self).__name__

    def capabilities(self) -> CapabilitySet:
        """Relative-compatibility labels (Bertha §5.2); opaque to the runtime."""
        return CapabilitySet.exact(self.name)

    def cost_model(self) -> CostModel:
        """Static cost annotations scored by ``repro.core.cost`` during
        negotiation and controller ticks. The neutral default keeps
        unannotated chunnels out of the objective (scoring then falls back to
        preference order)."""
        return NEUTRAL

    @abc.abstractmethod
    def connect_wrap(self, inner: Optional[Datapath]) -> Datapath: ...

    def migrate_state(self, old: Optional[Datapath]) -> dict:
        """Extract transferable connection state from the implementation being
        replaced (Bertha §4.2 step 2). Default: nothing to carry."""
        return {}

    def fingerprint(self) -> str:
        caps = ";".join(sorted(str(c) for c in self.capabilities()))
        return f"{self.name}({caps})<{self.upper_type}->{self.lower_type}>"

    def __repr__(self) -> str:
        return self.name


@dataclass
class FnChunnel(Chunnel):
    """Convenience: build a transform chunnel from send/recv functions.

    ``on_send``/``on_recv`` are per-message transforms, lifted to the batch
    contract through :func:`per_message`. ``on_send_batch``/``on_recv_batch``
    take and return the whole list in one call and win when both are given —
    supply these for anything that can amortize work across the batch."""

    fn_name: str = "FnChunnel"
    on_send: Any = None
    on_recv: Any = None
    upper: WireType = ANY
    lower: WireType = ANY
    caps: Optional[CapabilitySet] = None
    multilateral_: bool = False
    cost: Optional[CostModel] = None
    on_send_batch: Any = None
    on_recv_batch: Any = None

    def __post_init__(self):
        self.upper_type = self.upper
        self.lower_type = self.lower
        self.multilateral = self.multilateral_

    @property
    def name(self) -> str:
        return self.fn_name

    def capabilities(self) -> CapabilitySet:
        return self.caps if self.caps is not None else CapabilitySet.exact(self.name)

    def cost_model(self) -> CostModel:
        return self.cost if self.cost is not None else NEUTRAL

    def connect_wrap(self, inner: Optional[Datapath]) -> Datapath:
        return _FnDatapath(self, inner)


def _approx_bytes(msgs) -> int:
    """Summed payload size of a batch, counting only sized bytes-like items
    (str/bytes); opaque objects contribute 0 — the calibration consumer
    treats a zero total as 'no byte information', not as compression."""
    return sum(len(m) for m in msgs if isinstance(m, (bytes, bytearray, str)))


class _FnDatapath(Datapath):
    def __init__(self, ch: FnChunnel, inner: Optional[Datapath]):
        self.ch = ch
        self.inner = inner
        self._send_batch = ch.on_send_batch or (
            per_message(ch.on_send) if ch.on_send else None)
        self._recv_batch = ch.on_recv_batch or (
            per_message(ch.on_recv) if ch.on_recv else None)

    def send(self, msgs):
        if not isinstance(msgs, list):
            msgs = list(msgs)
        if TRACER.enabled:  # batch-level only: see the span-in-hot-loop rule
            # timed transform + byte sizes feed calibrate_from_traces: one
            # perf_counter pair per BATCH, inside the enabled guard, so the
            # disabled path stays two attribute reads
            t0 = time.perf_counter()
            out = self._send_batch(msgs) if self._send_batch else msgs
            dur = time.perf_counter() - t0
            TRACER.record_batch(
                "chunnel.send", len(msgs), len(out),
                {"chunnel": self.ch.fn_name, "dur": dur,
                 "bytes_in": _approx_bytes(msgs),
                 "bytes_out": _approx_bytes(out)})
        else:
            out = self._send_batch(msgs) if self._send_batch else msgs
        if self.inner is not None:
            self.inner.send(out)

    def recv(self, buf, timeout=None):
        if self.inner is None:
            return 0
        n = self.inner.recv(buf, timeout)
        if self._recv_batch and n:
            out = self._recv_batch(buf[:n])
            n = min(len(out), len(buf))
            buf[:n] = out[:n]
        if TRACER.enabled and n:
            TRACER.record_batch("chunnel.recv", n, n,
                                {"chunnel": self.ch.fn_name})
        return n
