"""Rendezvous-based multi-party negotiation (Bertha §5.3).

A key-value store with serializable multi-key transactions records each
multi-endpoint connection's negotiated datapath stack, so endpoints can
(a) recover the stack without having participated in negotiation, and
(b) propose transitions that commit via two-phase agreement among the
current participants.

The in-memory store mirrors the Redis/etcd interface the paper assumes
(compare-and-swap inside a transaction); it can be sharded per connection-id
since negotiation state is never shared across connections.

Two transaction disciplines:

  transact        PESSIMISTIC — ``fn`` runs with the store lock held; never
                  conflicts. Right for short control-plane transactions
                  (join/vote/commit), whose critical sections are tiny.
  try_transact    OPTIMISTIC — ``fn`` runs against a read-tracking snapshot
                  view with NO lock held; the commit re-acquires the lock,
                  validates every read key's version, and raises
                  ``TxnConflict`` if another writer interleaved.
                  ``transact_retry`` wraps it with bounded backoff. Right for
                  the fleet signal plane, where many publishers read-modify-
                  write a shared roster concurrently and must not serialize
                  their (snapshot-building) work behind one global lock.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple


class TxnConflict(RuntimeError):
    pass


class KVStore:
    """Versioned KV store with serializable multi-key transactions."""

    def __init__(self):
        self._data: Dict[str, Any] = {}
        self._ver: Dict[str, int] = {}
        self._lock = threading.RLock()
        #: optimistic commits rejected because a read key's version moved —
        #: observability for contention tests and the fleet publisher
        self.conflicts = 0

    def get(self, key: str) -> Any:
        with self._lock:
            return self._data.get(key)

    def version(self, key: str) -> int:
        with self._lock:
            return self._ver.get(key, 0)

    def read_versioned(self, key: str) -> Tuple[Any, int]:
        """(value, version) read atomically — the unit of optimistic reads."""
        with self._lock:
            return self._data.get(key), self._ver.get(key, 0)

    def keys(self, prefix: str = "") -> List[str]:
        """All live keys under ``prefix`` (the etcd range-scan analogue) —
        fleet debugging/tooling; membership itself is roster-driven (the
        roster and member records are written in one atomic txn)."""
        with self._lock:
            return sorted(k for k in self._data if k.startswith(prefix))

    def transact(self, fn: Callable[["Txn"], Any]) -> Any:
        """Run fn against a serializable view; commits atomically."""
        with self._lock:
            txn = Txn(self)
            # lint: allow[blocking-under-lock] pessimistic discipline (module docstring): fn is a tiny control-plane txn body (join/vote/commit) and MUST run serialized under the store lock
            out = fn(txn)
            self._apply(txn)
            return out

    def try_transact(self, fn: Callable[["Txn"], Any]) -> Any:
        """One OPTIMISTIC attempt: ``fn`` runs against a snapshot view without
        the store lock (first read of each key pins its value+version for the
        rest of the transaction); the commit validates that no read key's
        version moved and raises ``TxnConflict`` otherwise. ``fn`` must be
        pure against the txn view — it may run several times under
        ``transact_retry``."""
        txn = Txn(self, track_reads=True)
        out = fn(txn)
        with self._lock:
            for k, ver in txn.reads.items():
                if self._ver.get(k, 0) != ver:
                    self.conflicts += 1
                    raise TxnConflict(
                        f"key {k!r} moved to v{self._ver.get(k, 0)} "
                        f"(read at v{ver})")
            self._apply(txn)
            return out

    def transact_retry(self, fn: Callable[["Txn"], Any], *,
                       max_retries: int = 32, backoff_s: float = 2e-4,
                       on_conflict: Optional[Callable[[], None]] = None) -> Any:
        """``try_transact`` with bounded linear-backoff retries; the standard
        wrapper for contended read-modify-write (fleet publishers updating the
        shared roster). ``on_conflict`` fires once per retried conflict."""
        for attempt in range(max_retries + 1):
            try:
                return self.try_transact(fn)
            except TxnConflict:
                if on_conflict is not None:
                    on_conflict()
                if attempt == max_retries:
                    raise
                time.sleep(backoff_s * (attempt + 1))

    def _apply(self, txn: "Txn") -> None:  # lint: allow[unguarded-attr] every caller (transact/try_transact) holds self._lock; RLock makes taking it here redundant, not wrong — kept out of the hot commit path
        for k, v in txn.writes.items():
            self._data[k] = v
            self._ver[k] = self._ver.get(k, 0) + 1
        for k in txn.deletes:
            self._data.pop(k, None)
            self._ver[k] = self._ver.get(k, 0) + 1

    def compare_and_swap(self, key: str, expect_version: int, value: Any) -> bool:
        with self._lock:
            if self._ver.get(key, 0) != expect_version:
                return False
            self._data[key] = value
            self._ver[key] = expect_version + 1
            return True


class Txn:
    def __init__(self, store: KVStore, *, track_reads: bool = False):
        self._store = store
        self._track = track_reads
        self.writes: Dict[str, Any] = {}
        self.deletes: set = set()
        self.reads: Dict[str, int] = {}     # key -> version at first read
        self._read_cache: Dict[str, Any] = {}

    def get(self, key: str) -> Any:
        if key in self.writes:
            return self.writes[key]
        if key in self.deletes:
            return None
        if self._track:
            # snapshot view: first read pins (value, version) for the txn
            if key not in self.reads:
                val, ver = self._store.read_versioned(key)
                self.reads[key] = ver
                self._read_cache[key] = val
            return self._read_cache[key]
        return self._store._data.get(key)

    def put(self, key: str, value: Any) -> None:
        self.deletes.discard(key)
        self.writes[key] = value

    def delete(self, key: str) -> None:
        self.writes.pop(key, None)
        self.deletes.add(key)


# ---------------------------------------------------------------------------
# Multi-party negotiation protocol
# ---------------------------------------------------------------------------


@dataclass
class JoinResult:
    stack_fp: str
    stack_desc: list
    participants: int
    epoch: int
    proposed: bool  # True if we were first and our proposal committed


def join(store: KVStore, conn_id: str, member: str, offer_fps: List[str],
         offer_descs: List[list], caps_compatible: Callable[[list], Optional[int]]) -> JoinResult:
    """Join a multi-endpoint connection (§5.3).

    Proposes our preferred stack with CAS; if a stack is already in place,
    checks compatibility (caps_compatible returns the index of our first
    compatible option against the committed stack, or None)."""

    def _fn(txn: Txn) -> JoinResult:
        cur = txn.get(f"{conn_id}/stack")
        if cur is None:
            txn.put(f"{conn_id}/stack", {
                "fp": offer_fps[0], "desc": offer_descs[0], "epoch": 1,
            })
            txn.put(f"{conn_id}/members", {member: 1})
            return JoinResult(offer_fps[0], offer_descs[0], 1, 1, True)
        idx = caps_compatible(cur["desc"])
        if idx is None:
            raise ValueError(
                f"{member}: no offered stack compatible with committed stack of {conn_id}"
            )
        members = dict(txn.get(f"{conn_id}/members") or {})
        members[member] = cur["epoch"]
        txn.put(f"{conn_id}/members", members)
        return JoinResult(cur["fp"], cur["desc"], len(members), cur["epoch"], False)

    return store.transact(_fn)


def leave(store: KVStore, conn_id: str, member: str) -> int:
    def _fn(txn: Txn) -> int:
        members = dict(txn.get(f"{conn_id}/members") or {})
        members.pop(member, None)
        txn.put(f"{conn_id}/members", members)
        return len(members)

    return store.transact(_fn)


def current_stack(store: KVStore, conn_id: str) -> Optional[dict]:
    """Late joiners recover the stack without having negotiated (§5.3a)."""
    return store.get(f"{conn_id}/stack")


# -- two-phase transition ----------------------------------------------------


def propose_transition(store: KVStore, conn_id: str, proposer: str,
                       new_fp: str, new_desc: list) -> int:
    """Phase 1: publish a proposal; returns the proposal epoch."""

    def _fn(txn: Txn) -> int:
        cur = txn.get(f"{conn_id}/stack")
        if cur is None:
            raise ValueError("no such connection")
        if txn.get(f"{conn_id}/proposal") is not None:
            raise TxnConflict("a transition is already in flight")
        epoch = cur["epoch"] + 1
        txn.put(f"{conn_id}/proposal", {
            "fp": new_fp, "desc": new_desc, "epoch": epoch,
            "proposer": proposer, "acks": {proposer: True},
        })
        return epoch

    return store.transact(_fn)


def vote(store: KVStore, conn_id: str, member: str, epoch: int, accept: bool) -> None:
    def _fn(txn: Txn) -> None:
        prop = txn.get(f"{conn_id}/proposal")
        if prop is None or prop["epoch"] != epoch:
            return
        acks = dict(prop["acks"])
        acks[member] = accept
        txn.put(f"{conn_id}/proposal", {**prop, "acks": acks})

    store.transact(_fn)


def try_commit(store: KVStore, conn_id: str, epoch: int,
               timeout_s: float, t0: Optional[float] = None) -> Optional[bool]:
    """Phase 2: commit iff ALL members acked; abort on any refusal or timeout.
    A faulty peer can therefore never force others to switch (§4.2 fn. 3).
    Returns True committed / False aborted / None still pending."""
    t0 = t0 if t0 is not None else time.monotonic()

    def _fn(txn: Txn) -> Optional[bool]:
        prop = txn.get(f"{conn_id}/proposal")
        if prop is None or prop["epoch"] != epoch:
            return False
        members = txn.get(f"{conn_id}/members") or {}
        acks = prop["acks"]
        if any(acks.get(m) is False for m in members):
            txn.delete(f"{conn_id}/proposal")
            return False
        if all(acks.get(m) for m in members):
            txn.put(f"{conn_id}/stack", {
                "fp": prop["fp"], "desc": prop["desc"], "epoch": prop["epoch"],
            })
            txn.delete(f"{conn_id}/proposal")
            return True
        if time.monotonic() - t0 > timeout_s:
            txn.delete(f"{conn_id}/proposal")
            return False
        return None

    return store.transact(_fn)
