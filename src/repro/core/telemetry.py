"""Connection & step telemetry: the observation half of Bertha's closed loop.

``ReconfigStats`` (reconfigure.py) records what a switch *cost*; this module
records the signals that tell a policy *when* to switch: bytes on the wire,
per-op latency (incremental EWMA quantile estimates), per-pod step times for
straggler detection, and snapshot-windowed rates. Every ``ConnHandle`` carries
a ``ConnTelemetry``; the trainer feeds one per job. ``snapshot()`` produces a
plain dict consumed by ``repro.core.controller`` — keys are part of the policy
API and documented there.

Updates are deliberately lock-free: counters ride the GIL the same way
``BarrierConn``'s pause flag does, so the data fast path pays a couple of
clock reads and float ops, never a mutex. Telemetry is advisory — a rare lost
increment under thread races is acceptable, and ``snapshot()`` sees a
consistent-enough view for threshold policies.
"""
from __future__ import annotations

import statistics
import time
from typing import Any, Callable, Dict, Optional


class Ewma:
    """Exponentially weighted moving average; ``value`` is None until fed."""

    __slots__ = ("alpha", "value")

    def __init__(self, alpha: float = 0.2):
        self.alpha = alpha
        self.value: Optional[float] = None

    def update(self, x: float) -> float:
        v = self.value
        self.value = x if v is None else v + self.alpha * (x - v)
        return self.value


class EwmaQuantile:
    """Incremental quantile tracking (Robbins–Monro stochastic approximation).

    The estimate moves up by ``step * q`` on samples above it and down by
    ``step * (1 - q)`` on samples below; at equilibrium a fraction ``q`` of
    samples fall below the estimate. ``step`` is scaled by an EWMA of the
    absolute deviation so the estimator adapts to the signal's magnitude
    without configuration.
    """

    __slots__ = ("q", "alpha", "value", "_spread")

    def __init__(self, q: float, alpha: float = 0.1):
        assert 0.0 < q < 1.0, q
        self.q = q
        self.alpha = alpha
        self.value: Optional[float] = None
        self._spread = Ewma(alpha)

    def update(self, x: float) -> float:
        if self.value is None:
            self.value = x
            self._spread.update(abs(x) * 0.1 + 1e-12)
            return x
        spread = self._spread.update(abs(x - self.value))
        step = self.alpha * max(spread, 1e-12)
        if x > self.value:
            self.value += step * self.q
        elif x < self.value:
            self.value -= step * (1.0 - self.q)
        return self.value


def _batch_bucket(n: int) -> str:
    """Power-of-two histogram bucket label for a batch size."""
    if n <= 0:
        return "0"
    if n == 1:
        return "1"
    lo = 1 << (n.bit_length() - 1)
    return f"{lo}-{lo * 2 - 1}"


class ConnTelemetry:
    """Per-connection (or per-job) counters feeding the policy engine.

    The data plane calls the ``record_*`` methods; the control plane calls
    ``snapshot()`` once per controller tick. Rates (``ops_per_s`` /
    ``bytes_per_s``) are measured over the interval since the previous
    snapshot, so exactly one consumer (the controller) should snapshot a given
    telemetry object.
    """

    def __init__(self, *, now: Callable[[], float] = time.monotonic):
        self._now = now
        self.created_at = now()
        # totals
        self.ops = 0              # completed data-plane operations (send batches / rtts / steps)
        self.msgs_out = 0
        self.msgs_in = 0
        self.bytes_out = 0
        self.bytes_in = 0
        self.wire_bytes = 0       # explicitly accounted wire/DCN bytes (trainer plane)
        self.steps = 0
        # latency estimators
        self.op_mean = Ewma(0.2)
        self.op_p50 = EwmaQuantile(0.50)
        self.op_p95 = EwmaQuantile(0.95)
        self.rtt_p50 = EwmaQuantile(0.50)
        self.rtt_p95 = EwmaQuantile(0.95)
        # batch shape of the data plane (docs/architecture.md §8): power-of-two
        # msgs-per-send histogram + incremental batch-size quantiles, so cost
        # models and fleet aggregates can tell a per-message regime (batch=1)
        # from a vectorized one
        self.batch_hist: Dict[str, int] = {}
        self.batch_p50 = EwmaQuantile(0.50)
        self.batch_p95 = EwmaQuantile(0.95)
        # per-pod step-time EWMAs (straggler detection)
        self._pods: Dict[str, Ewma] = {}
        # reconfig blip stats folded in live from the owning handle
        self._reconfig_stats: Any = None
        # snapshot window
        self._win_t = self.created_at
        self._win_ops = 0
        self._win_bytes = 0

    # -- recording --------------------------------------------------------------
    def record_send(self, n_msgs: int, n_bytes: int, dt_s: float) -> None:
        self.ops += 1
        self.msgs_out += n_msgs
        self.bytes_out += n_bytes
        self.op_mean.update(dt_s)
        self.op_p50.update(dt_s)
        self.op_p95.update(dt_s)
        b = _batch_bucket(n_msgs)
        self.batch_hist[b] = self.batch_hist.get(b, 0) + 1
        self.batch_p50.update(float(n_msgs))
        self.batch_p95.update(float(n_msgs))

    def record_recv(self, n_msgs: int, n_bytes: int) -> None:
        self.msgs_in += n_msgs
        self.bytes_in += n_bytes

    def record_rtt(self, dt_s: float) -> None:
        """Application-observed round-trip latency (e.g. a KV request)."""
        self.rtt_p50.update(dt_s)
        self.rtt_p95.update(dt_s)

    def record_wire(self, n_bytes: int) -> None:
        """Explicit wire-byte accounting for planes whose bytes do not pass
        through send() (the jitted step's DCN traffic)."""
        self.wire_bytes += n_bytes

    def record_step(self, reports: Dict[str, float]) -> None:
        """One training step's heartbeat reports, ``{pod: step_time_s}``.
        Counts one step/op regardless of how many pods report — per-pod
        counting would inflate ``steps`` and step-driven rates by the pod
        count."""
        self.steps += 1
        self.ops += 1
        for pod, dt_s in reports.items():
            self._pods.setdefault(pod, Ewma(0.3)).update(dt_s)

    def bind_reconfig(self, stats: Any) -> None:
        """Fold a live ``ReconfigStats`` into every snapshot (duck-typed:
        needs .switches / .last_switch_s / .total_blocked_s)."""
        self._reconfig_stats = stats

    # -- derived signals --------------------------------------------------------
    def pod_step_times(self) -> Dict[str, float]:
        return {p: e.value for p, e in self._pods.items() if e.value is not None}

    def straggler_ratio(self) -> float:
        """Slowest pod's step-time EWMA over the median of the OTHER pods' —
        1.0 means no straggler; needs at least two reporting pods to be
        meaningful. The straggler is excluded from its own baseline: with the
        straggler in the denominator a 2-pod job could never exceed 2.0 (a
        3x straggler would read exactly 1.5), capping what thresholds are
        reachable."""
        times = sorted(self.pod_step_times().values())
        if len(times) < 2:
            return 1.0
        slowest, rest = times[-1], times[:-1]
        base = statistics.median(rest)
        return slowest / base if base > 0 else 1.0

    def snapshot(self, *, reset_window: bool = True) -> dict:
        """One consistent-enough view of every signal, as a plain dict — the
        input to ``ReconfigController.tick`` and the scoring functions in
        ``repro.core.cost``.

        Keys (all part of the policy API): totals (``ops``, ``steps``,
        ``msgs_out``/``msgs_in``, ``bytes_out``/``bytes_in``, ``wire_bytes``),
        windowed rates (``ops_per_s``, ``bytes_per_s`` — measured since the
        previous window reset — plus ``window_s``, the measured window
        length, so ``ops_per_s * window_s`` reconstructs the exact op count
        handed to this window), latency estimates (``op_mean_s``,
        ``op_p50_s``/``op_p95_s``, ``rtt_p50_s``/``rtt_p95_s``; None until
        fed), batch shape (``batch_hist`` — power-of-two msgs-per-send
        histogram, ``batch_p50``/``batch_p95``, ``msgs_per_op``), the step
        plane (``pods``, ``step_time_s``, ``straggler_ratio``), and the
        folded reconfig stats (``switches``, ``last_switch_s``,
        ``total_blocked_s``).

        ``reset_window=True`` (the controller's once-per-tick call) starts a
        new rate window; exactly ONE consumer may do that. Everyone else —
        e.g. a ServerNegotiator scoring an offer mid-window — must pass
        ``reset_window=False`` to peek without disturbing the rates.
        """
        now = self._now()
        dt = max(now - self._win_t, 1e-9)
        # Capture each shared counter EXACTLY ONCE. Recorders append
        # concurrently (plain ints riding the GIL): re-reading self.ops
        # for the window reset after the rate computation would hand any
        # increment landing between the two reads to neither window —
        # the rate of this snapshot excludes it, and the next window's
        # baseline already includes it, so the sample is lost forever.
        ops_now = self.ops
        total_bytes = self.bytes_out + self.wire_bytes
        ops_per_s = (ops_now - self._win_ops) / dt
        bytes_per_s = (total_bytes - self._win_bytes) / dt
        if reset_window:
            self._win_t = now
            self._win_ops = ops_now
            self._win_bytes = total_bytes
        rs = self._reconfig_stats
        pods = self.pod_step_times()
        return {
            "uptime_s": now - self.created_at,
            "window_s": dt,
            "ops": ops_now,
            "steps": self.steps,
            "msgs_out": self.msgs_out,
            "msgs_in": self.msgs_in,
            "bytes_out": self.bytes_out,
            "bytes_in": self.bytes_in,
            "wire_bytes": self.wire_bytes,
            "ops_per_s": ops_per_s,
            "bytes_per_s": bytes_per_s,
            "op_mean_s": self.op_mean.value,
            "op_p50_s": self.op_p50.value,
            "op_p95_s": self.op_p95.value,
            "batch_hist": dict(self.batch_hist),
            "batch_p50": self.batch_p50.value,
            "batch_p95": self.batch_p95.value,
            "msgs_per_op": self.msgs_out / self.ops if self.ops else None,
            "rtt_p50_s": self.rtt_p50.value,
            "rtt_p95_s": self.rtt_p95.value,
            "pods": pods,
            "step_time_s": statistics.median(pods.values()) if pods else None,
            "straggler_ratio": self.straggler_ratio(),
            "switches": getattr(rs, "switches", 0),
            "last_switch_s": getattr(rs, "last_switch_s", 0.0),
            "total_blocked_s": getattr(rs, "total_blocked_s", 0.0),
        }
