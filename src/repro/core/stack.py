"""Chunnel stacks: typed composition with Select alternatives (Bertha §3, §4.1).

``make_stack(a, b, c)`` composes top-down (a processes app data first; c is the
transport at the bottom). Entries may be Chunnels or Selects; Selects may nest,
so a stack denotes a *tree of concrete stacks* in preference order. Composition
is associative but not commutative.

Type checking happens at assembly time: adjacent WireTypes must match, else
``StackTypeError`` — the Python analogue of the paper's compile error.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Iterable, List, Optional, Sequence, Union

from repro.core.capability import CapabilitySet
from repro.core.chunnel import Chunnel, Datapath, WireType, types_match


class StackTypeError(TypeError):
    pass


@dataclass(frozen=True)
class Select:
    """Preference-ordered alternatives at one stack layer (Bertha §4.1).

    Unilateral selects swap locally; if any option is multilateral, switching
    requires negotiated agreement (§5)."""

    options: tuple  # of Entry (Chunnel | Select | tuple-of-Entry sub-stacks)

    def __init__(self, *options):
        object.__setattr__(self, "options", tuple(options))

    def __repr__(self):
        return "Select(" + " | ".join(map(repr, self.options)) + ")"


Entry = Union[Chunnel, Select, tuple]


def _expand(entry: Entry) -> List[List[Chunnel]]:
    """All concrete chunnel runs an entry can denote, in preference order."""
    if isinstance(entry, Chunnel):
        return [[entry]]
    if isinstance(entry, Select):
        out: List[List[Chunnel]] = []
        for opt in entry.options:
            out.extend(_expand(opt))
        return out
    if isinstance(entry, (tuple, list)):
        parts = [_expand(e) for e in entry]
        return [list(itertools.chain(*combo)) for combo in itertools.product(*parts)]
    raise TypeError(f"not a stack entry: {entry!r}")


class ConcreteStack:
    """A fully resolved chunnel sequence (one choice per Select)."""

    def __init__(self, chunnels: Sequence[Chunnel]):
        self.chunnels = list(chunnels)
        self.type_check()

    def type_check(self) -> None:
        for above, below in zip(self.chunnels, self.chunnels[1:]):
            if not types_match(above.lower_type, below.upper_type):
                raise StackTypeError(
                    f"{above.name} produces {above.lower_type} but "
                    f"{below.name} accepts {below.upper_type}"
                )

    def capabilities(self) -> CapabilitySet:
        caps = CapabilitySet()
        for c in self.chunnels:
            caps = caps.union_(c.capabilities())
        return caps

    def multilateral(self) -> bool:
        return any(c.multilateral for c in self.chunnels)

    def fingerprint(self) -> str:
        return "|".join(c.fingerprint() for c in self.chunnels)

    def instantiate(self) -> Datapath:
        """Recursive bottom-up connect_wrap (Bertha Fig. 2)."""
        dp: Optional[Datapath] = None
        for ch in reversed(self.chunnels):
            dp = ch.connect_wrap(dp)
        assert dp is not None, "empty stack"
        return dp

    def describe(self) -> list:
        return [
            {
                "name": c.name,
                "caps": c.capabilities().to_wire(),
                "upper": str(c.upper_type),
                "lower": str(c.lower_type),
                "multilateral": c.multilateral,
            }
            for c in self.chunnels
        ]

    def __repr__(self):
        return " -> ".join(c.name for c in self.chunnels)

    def __iter__(self):
        return iter(self.chunnels)

    def __len__(self):
        return len(self.chunnels)


class Stack:
    """A stack *specification*: chunnels and selects, top to bottom."""

    def __init__(self, *entries: Entry):
        self.entries = entries
        self._options: Optional[List[ConcreteStack]] = None
        if not self.options():
            raise StackTypeError("stack has no type-correct concrete option")

    def options(self) -> List[ConcreteStack]:
        """All type-correct concrete stacks, in developer preference order.

        Type-incorrect combinations are rejected here — the 'compile error'
        happens at assembly, before any connection exists. Entries are
        immutable, so the expansion + type-check cartesian product is
        computed once and memoized (preferred()/find()/offer() are hot on
        every negotiation round)."""
        if self._options is None:
            out = []
            for combo in _expand(tuple(self.entries)):
                try:
                    out.append(ConcreteStack(combo))
                except StackTypeError:
                    continue
            self._options = out
        return list(self._options)

    def preferred(self) -> ConcreteStack:
        return self.options()[0]

    def offer(self) -> list:
        """Wire form of all options (sent during negotiation §5.1)."""
        return [s.describe() for s in self.options()]

    def find(self, fingerprint: str) -> Optional[ConcreteStack]:
        for s in self.options():
            if s.fingerprint() == fingerprint:
                return s
        return None

    def __repr__(self):
        return "Stack(" + ", ".join(map(repr, self.entries)) + ")"


def make_stack(*entries: Entry) -> Stack:
    """Bertha's ``make_stack!`` macro."""
    return Stack(*entries)


def offered_capabilities(offer: list) -> List[CapabilitySet]:
    """Capability sets of each offered concrete stack (server side of §5.2)."""
    out = []
    for stack_desc in offer:
        caps = CapabilitySet()
        for ch in stack_desc:
            caps = caps.union_(CapabilitySet.from_wire(ch["caps"]))
        out.append(caps)
    return out
