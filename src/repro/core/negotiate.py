"""Point-to-point negotiation (Bertha §5.1–§5.2) + zero-RTT resumption (§6.1).

Client sends its Chunnel-stack options over the base connection; the server
filters to capability-compatible concrete stacks (§5.2 comparison) and — when
it has scoring evidence (an Objective or live telemetry; ``ServerNegotiator``
gates on this, bare servers keep preference order) — scores them with the
multi-objective cost model (``repro.core.cost``) and picks the argmax,
falling back to its own preference order on ties; both sides then instantiate
via recursive connect_wrap.
A returned nonce encodes the chosen select branches (used e.g. by the §7.3
load-balancer to inform backends).

Zero-RTT: the client caches the negotiated fingerprint per (peer, offer) and
optimistically instantiates it while the server confirms or proposes a
replacement (QUIC-0RTT-style, §6.1).

Invariants (relied on by the §7.3 load balancer and the reconfiguration
controller):
  * The client's offer carries the real ``ConcreteStack.fingerprint()`` of
    each option, and the server stores the chosen one verbatim — so a 0-RTT
    resumption of the same stack yields the SAME nonce as the original 1-RTT
    negotiation (the nonce is a pure function of the two fingerprints).
  * The 0-RTT branch validates the client's claimed fingerprint against the
    server's cached value; a stale or unknown claim falls back to 1-RTT
    instead of silently minting a nonce for a stack that was never agreed.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from repro.core.capability import CapabilitySet
from repro.core.cost import DEFAULT_OBJECTIVE, Objective, score_stack
from repro.core.fabric import ReliableChannel
from repro.core.stack import ConcreteStack, Stack, offered_capabilities
from repro.obs.trace import NOOP_SPAN, TRACER


class NegotiationError(RuntimeError):
    pass


def _nonce(server_fp: str, client_fp: str) -> str:
    return hashlib.sha256(f"{server_fp}||{client_fp}".encode()).hexdigest()[:16]


def compatible_pairs(server_stack: Stack, client_offer: list) -> list:
    """All (server_option, client_option_index) pairs that pass the §5.2
    capability comparison, in server preference order; each server option is
    paired with the first (most-preferred) compatible client option."""
    client_caps = offered_capabilities(client_offer)
    out = []
    for s_opt in server_stack.options():
        s_caps = s_opt.capabilities()
        for idx, c_caps in enumerate(client_caps):
            if s_caps.compatible_with(c_caps):
                out.append((s_opt, idx))
                break
    return out


def pick_compatible(
    server_stack: Stack,
    client_offer: list,
    *,
    snapshot: Optional[dict] = None,
    objective: Optional[Objective] = None,
    mode: str = "scored",
    scores: Optional[dict] = None,
) -> Optional[Tuple[ConcreteStack, int]]:
    """Server side of §5.2, multi-objective: among ALL capability-compatible
    (server option, client option) pairs, pick the server option whose folded
    cost model (repro.core.cost) maximizes ``utility`` under ``objective`` and
    the live telemetry ``snapshot``.

    Ties (including the common all-neutral-cost-model case) break toward
    server preference order, with client preference as the per-option
    tiebreak — so unannotated stacks negotiate exactly as the historical
    first-compatible rule did. ``mode="first"`` forces that legacy behavior
    (kept for the scored-vs-first comparison in bench_reconfigure).

    Returns (server_choice, client_option_index) or None when no pair is
    compatible. ``scores`` (when given a dict) is filled with the
    per-candidate utilities ``{server_fp: u}`` — the negotiation span
    records them so a trace explains *which* stacks lost and by how much.
    """
    pairs = compatible_pairs(server_stack, client_offer)
    if not pairs:
        return None
    if mode == "first":
        return pairs[0]
    obj = objective or DEFAULT_OBJECTIVE
    best, best_u = None, float("-inf")
    for s_opt, idx in pairs:  # strict > keeps preference order on ties
        u = score_stack(s_opt, obj, snapshot)
        if scores is not None:
            scores[s_opt.fingerprint()] = u
        if u > best_u:
            best, best_u = (s_opt, idx), u
    return best


@dataclass
class NegotiatedConn:
    stack: ConcreteStack
    nonce: str
    zero_rtt: bool = False


class ZeroRttCache:
    """client-side: (peer, offer-digest) -> fingerprint of the agreed stack."""

    def __init__(self):
        self._cache: Dict[Tuple[str, str], str] = {}

    @staticmethod
    def _key(peer: str, stack: Stack) -> Tuple[str, str]:
        digest = hashlib.sha256(
            "||".join(s.fingerprint() for s in stack.options()).encode()
        ).hexdigest()[:16]
        return (peer, digest)

    def get(self, peer: str, stack: Stack) -> Optional[str]:
        return self._cache.get(self._key(peer, stack))

    def put(self, peer: str, stack: Stack, fp: str) -> None:
        self._cache[self._key(peer, stack)] = fp

    def invalidate(self, peer: str, stack: Stack) -> None:
        self._cache.pop(self._key(peer, stack), None)


def client_negotiate(
    chan: ReliableChannel,
    stack: Stack,
    cache: Optional[ZeroRttCache] = None,
) -> NegotiatedConn:
    peer = chan.peer
    with (TRACER.span("negotiate.client", attrs={"peer": peer})
          if TRACER.enabled else NOOP_SPAN) as sp:
        if cache is not None:
            fp = cache.get(peer, stack)
            if fp is not None and stack.find(fp) is not None:
                reply = chan.request({"type": "zero_rtt", "fp": fp})
                if reply.get("type") == "zero_rtt_ok":
                    sp.set(zero_rtt=True, fp=fp)
                    return NegotiatedConn(stack.find(fp), reply["nonce"],
                                          zero_rtt=True)
                if reply.get("type") == "negotiate_failed":
                    cache.invalidate(peer, stack)  # tear down; fall through to 1-RTT
                # else: fall through

        offer = stack.offer()
        reply = chan.request({
            "type": "offer",
            "options": offer,
            # real fingerprints, index-aligned with options: the server caches the
            # chosen one so 0-RTT resumption reproduces the 1-RTT nonce exactly
            "fps": [opt.fingerprint() for opt in stack.options()],
        })
        if reply.get("type") == "reject":
            sp.set(status="rejected", reason=reply.get("reason"))
            raise NegotiationError(f"server rejected: {reply.get('reason')}")
        if reply.get("type") != "accept":
            sp.set(status="error")
            raise NegotiationError(f"unexpected reply: {reply}")
        chosen = stack.options()[reply["client_idx"]]
        if cache is not None:
            cache.put(peer, stack, chosen.fingerprint())
        sp.set(zero_rtt=False, fp=chosen.fingerprint(), nonce=reply["nonce"])
        return NegotiatedConn(chosen, reply["nonce"])


class ServerNegotiator:
    """Server-side handler; plug into a HostAgent's message loop.

    ``objective`` sets the scoring weights ``pick_compatible`` uses over the
    compatible option set; ``telemetry`` (a ConnTelemetry) feeds the live
    workload rates into the score (read non-destructively — the negotiator
    must not consume another consumer's snapshot window).

    Scoring is EVIDENCE-GATED: with neither an objective nor telemetry
    configured, offers resolve by preference order (``mode="first"``). A bare
    server must not let static sub-millisecond annotations override the
    operator's declared Select order — e.g. ``routing_stack(prefer="server")``
    deliberately defaults to the slower-but-reprovisionable ServerRouter at
    idle, and only the load-adaptive policy (live telemetry) should move off
    it."""

    def __init__(self, stack: Stack, *, objective: Optional[Objective] = None,
                 telemetry: Optional[object] = None):
        self.stack = stack
        self.objective = objective
        self.telemetry = telemetry
        self._last: Dict[str, str] = {}  # peer -> negotiated client fp (for 0-RTT)
        self.negotiated: Dict[str, ConcreteStack] = {}  # peer -> server stack

    def _snapshot(self) -> Optional[dict]:
        if self.telemetry is None:
            return None
        return self.telemetry.snapshot(reset_window=False)

    def handle(self, src: str, msg: dict) -> dict:
        t = msg.get("type")
        if t == "offer":
            sp = (TRACER.span("negotiate.offer", attrs={"peer": src})
                  if TRACER.enabled else NOOP_SPAN)
            with sp:
                return self._handle_offer(src, msg, sp)
        if t == "zero_rtt":
            return self._handle_zero_rtt(src, msg)
        return {"type": "reject", "reason": f"unknown message {t}"}

    def _handle_offer(self, src: str, msg: dict, sp) -> dict:
        snap = self._snapshot()
        mode = ("scored" if (self.objective is not None or snap is not None)
                else "first")
        scores: Optional[dict] = {} if TRACER.enabled else None
        picked = pick_compatible(self.stack, msg["options"],
                                 snapshot=snap, objective=self.objective,
                                 mode=mode, scores=scores)
        if picked is None:
            sp.set(mode=mode, status="rejected",
                   reason="no compatible stack")
            return {"type": "reject", "reason": "no compatible stack"}
        s_opt, c_idx = picked
        # Cache the client's REAL fingerprint (sent index-aligned with the
        # offer) for 0-RTT resumption: the client caches
        # chosen.fingerprint() on its side, so both ends must derive the
        # nonce from the same string or resumption mints a different nonce
        # than the original negotiation. repr(desc) is only a last-resort
        # fallback for pre-fps clients (their 0-RTT will renegotiate).
        fps = msg.get("fps") or []
        client_fp = fps[c_idx] if c_idx < len(fps) else repr(msg["options"][c_idx])
        self._last[src] = client_fp
        self.negotiated[src] = s_opt
        # per-candidate utilities are THE evidence for why this stack
        # won — they ride the span so traces explain the choice
        sp.set(mode=mode, chosen=s_opt.fingerprint(), client_idx=c_idx,
               candidates=scores)
        return {
            "type": "accept",
            "client_idx": c_idx,
            "server_fp": s_opt.fingerprint(),
            "nonce": _nonce(s_opt.fingerprint(), client_fp),
        }

    def _handle_zero_rtt(self, src: str, msg: dict) -> dict:
        with (TRACER.span("negotiate.zero_rtt", attrs={"peer": src})
              if TRACER.enabled else NOOP_SPAN) as sp:
            cached = self._last.get(src)
            server_choice = self.negotiated.get(src)
            # Validate the client's claim against OUR cache of what was agreed
            # — resuming a stack we never negotiated must fall back to 1-RTT.
            if cached is None or server_choice is None or msg.get("fp") != cached:
                sp.set(status="fallback", reason="unknown or stale claim")
                return {"type": "negotiate_failed", "proposal": self.stack.offer()[:1]}
            # Re-validate that the previously negotiated server stack is still
            # on offer (our own Select preferences may have changed since).
            if self.stack.find(server_choice.fingerprint()) is not None:
                sp.set(fp=server_choice.fingerprint())
                return {
                    "type": "zero_rtt_ok",
                    "nonce": _nonce(server_choice.fingerprint(), cached),
                }
            sp.set(status="fallback", reason="stack no longer offered")
            return {"type": "negotiate_failed", "proposal": self.stack.offer()[:1]}
