"""Host agents: the Bertha runtime gluing fabric + negotiation + reconfiguration.

A HostAgent owns a fabric endpoint and a listener thread. Servers register a
Stack; clients ``connect(addr, stack)`` which negotiates (§5) and returns a
reconfigurable ConnHandle (§4). In the training framework each participating
host runs one agent; negotiation guarantees every host compiles the *same*
step-function stack — the SPMD-safety property that makes Bertha's
compatibility checking load-bearing on a TPU cluster.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, Iterable, List, Optional

from repro.core.chunnel import Chunnel, Datapath, WireType
from repro.core.fabric import Endpoint, Fabric, ReliableChannel
from repro.core.negotiate import (
    NegotiatedConn,
    NegotiationError,
    ServerNegotiator,
    ZeroRttCache,
    client_negotiate,
)
from repro.core.reconfigure import BarrierConn, ConnHandle, LockedConn, ReconfigParticipant
from repro.core.stack import ConcreteStack, Stack

BYTES = WireType.of("bytes")


class FabricTransport(Chunnel):
    """Bottom-of-stack transport over the host fabric (bootstraps from unit
    type, like the paper's KernelUdpChunnel)."""

    upper_type = BYTES
    lower_type = WireType.of("unit")

    def __init__(self, ep: Endpoint, peer: str, label: str = "FabricTransport"):
        self.ep = ep
        self.peer = peer
        self._label = label

    @property
    def name(self) -> str:
        return self._label

    def connect_wrap(self, inner: Optional[Datapath]) -> Datapath:
        assert inner is None, "transport chunnels bootstrap from the unit type"
        return _FabricDatapath(self.ep, self.peer)


class _FabricDatapath(Datapath):
    def __init__(self, ep: Endpoint, peer: str):
        self.ep = ep
        self.peer = peer

    def send(self, msgs: Iterable[Any]) -> None:
        for m in msgs:
            self.ep.send(self.peer, {"_data": m})

    def recv(self, buf: list, timeout: Optional[float] = None) -> int:
        n = 0
        deadline = None if timeout is None else time.monotonic() + timeout
        while n < len(buf):
            t = None if deadline is None else max(0.0, deadline - time.monotonic())
            got = self.ep.recv(timeout=t)
            if got is None:
                break
            _, m = got
            if isinstance(m, dict) and "_data" in m:
                buf[n] = m["_data"]
                n += 1
                deadline = time.monotonic()  # drain whatever is queued
        return n


class HostAgent:
    def __init__(self, fabric: Fabric, addr: str, *, mechanism: str = "lock",
                 n_data_threads: int = 1):
        self.fabric = fabric
        self.addr = addr
        self.ep = fabric.register(addr)
        self.ctrl = fabric.register(addr + "/ctrl")
        self.mechanism = mechanism
        self.n_data_threads = n_data_threads
        self.zero_rtt = ZeroRttCache()
        self._negotiator: Optional[ServerNegotiator] = None
        self._participants: Dict[str, ReconfigParticipant] = {}
        self._handlers: Dict[str, Callable[[str, dict], dict]] = {}
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    # -- server side -----------------------------------------------------------
    def listen(self, stack: Stack) -> ServerNegotiator:
        self._negotiator = ServerNegotiator(stack)
        return self._negotiator

    def on(self, msg_type: str, handler: Callable[[str, dict], dict]) -> None:
        self._handlers[msg_type] = handler

    def _dispatch(self, src: str, body: dict) -> dict:
        t = body.get("type", "")
        if t in ("offer", "zero_rtt"):
            if self._negotiator is None:
                return {"type": "reject", "reason": "not listening"}
            return self._negotiator.handle(src, body)
        if t.startswith("reconfig_"):
            # Strict conn-id dispatch: an unknown id must be refused, never
            # routed to an arbitrary participant — a reconfig_prepare/commit
            # for conn B must not prepare or swap conn A's stack.
            conn = body.get("conn", "")
            part = self._participants.get(conn)
            if part is None:
                return {"type": "reconfig_refuse", "reason": f"unknown conn {conn!r}"}
            return part.handle_msg(src, body)
        h = self._handlers.get(t)
        if h is not None:
            return h(src, body)
        return {"type": "error", "reason": f"no handler for {t!r}"}

    def _loop(self) -> None:
        chan = ReliableChannel(self.ctrl, peer="*")
        while not self._stop.is_set():
            chan.serve_one(self._dispatch, timeout=0.05)

    # -- client side -----------------------------------------------------------
    def connect(self, peer: str, stack: Stack, *, use_zero_rtt: bool = False) -> ConnHandle:
        chan = ReliableChannel(self.ep, peer + "/ctrl")
        neg = client_negotiate(chan, stack, self.zero_rtt if use_zero_rtt else None)
        handle = self._make_handle(neg.stack)
        handle.nonce = neg.nonce
        handle.was_zero_rtt = neg.zero_rtt
        handle.source_stack = stack
        return handle

    def accept_stack(self, peer: str) -> Optional[ConcreteStack]:
        if self._negotiator is None:
            return None
        return self._negotiator.negotiated.get(peer)

    def _make_handle(self, concrete: ConcreteStack) -> ConnHandle:
        if self.mechanism == "barrier":
            return BarrierConn(concrete, n_threads=self.n_data_threads)
        return LockedConn(concrete)

    def register_participant(self, conn_id: str, handle: ConnHandle,
                             resolve: Callable[[str], Optional[ConcreteStack]]) -> None:
        self._participants[conn_id] = ReconfigParticipant(handle, resolve)

    def request(self, peer: str, msg: dict, *, timeout: float = 0.1, retries: int = 40) -> dict:
        chan = ReliableChannel(self.ep, peer + "/ctrl", timeout=timeout, retries=retries)
        return chan.request(msg)

    def reconfigure_multilateral(self, handle: ConnHandle, new_stack: ConcreteStack,
                                 peers: List[str], conn_id: str) -> bool:
        """Unilateral swap + 2PC with peers, run inside the switch point
        (§4.2: negotiation happens while the lock/barrier is held)."""
        from repro.core.reconfigure import two_phase_commit

        def coordinate() -> bool:
            return two_phase_commit(
                lambda p, m: self.request(p, {**m, "conn": conn_id}),
                peers, new_stack.fingerprint(),
            )

        return handle.reconfigure(new_stack, coordinate=coordinate)

    def close(self) -> None:
        self._stop.set()
        self._thread.join(timeout=1.0)
        self.ep.close()
        self.ctrl.close()
