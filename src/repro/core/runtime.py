"""Host agents: the Bertha runtime gluing fabric + negotiation + reconfiguration.

A HostAgent owns a fabric endpoint and a listener thread. Servers register a
Stack; clients ``connect(addr, stack)`` which negotiates (§5) and returns a
reconfigurable ConnHandle (§4). In the training framework each participating
host runs one agent; negotiation guarantees every host compiles the *same*
step-function stack — the SPMD-safety property that makes Bertha's
compatibility checking load-bearing on a TPU cluster.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, Iterable, List, Optional

from repro.core.chunnel import Chunnel, Datapath, WireType
from repro.core.fabric import Endpoint, Fabric, ReliableChannel
from repro.core.negotiate import (
    NegotiatedConn,
    NegotiationError,
    ServerNegotiator,
    ZeroRttCache,
    client_negotiate,
)
from repro.core.reconfigure import BarrierConn, ConnHandle, LockedConn, ReconfigParticipant
from repro.core.stack import ConcreteStack, Stack
from repro.obs.trace import TRACER

BYTES = WireType.of("bytes")

#: consecutive failed epoch queries before the flight-recorder strand alarm
_STRAND_ALARM_FAILURES = 3


class FabricTransport(Chunnel):
    """Bottom-of-stack transport over the host fabric (bootstraps from unit
    type, like the paper's KernelUdpChunnel)."""

    upper_type = BYTES
    lower_type = WireType.of("unit")

    def __init__(self, ep: Endpoint, peer: str, label: str = "FabricTransport"):
        self.ep = ep
        self.peer = peer
        self._label = label

    @property
    def name(self) -> str:
        return self._label

    def connect_wrap(self, inner: Optional[Datapath]) -> Datapath:
        assert inner is None, "transport chunnels bootstrap from the unit type"
        return _FabricDatapath(self.ep, self.peer)


class _FabricDatapath(Datapath):
    def __init__(self, ep: Endpoint, peer: str):
        self.ep = ep
        self.peer = peer

    def send(self, msgs: Iterable[Any]) -> None:
        frames = [{"_data": m} for m in msgs]
        if frames:
            self.ep.send_batch(self.peer, frames)

    def recv(self, buf: list, timeout: Optional[float] = None) -> int:
        n = 0
        tmp: list = [None] * len(buf)
        deadline = None if timeout is None else time.monotonic() + timeout
        while n < len(buf):
            t = None if deadline is None else max(0.0, deadline - time.monotonic())
            got = self.ep.recv_many(tmp, max_n=len(buf) - n, timeout=t)
            if not got:
                break
            for k in range(got):  # unwrap frames (non-data frames are skipped)
                m = tmp[k][1]
                if isinstance(m, dict) and "_data" in m:
                    buf[n] = m["_data"]
                    n += 1
            if n:
                deadline = time.monotonic()  # drain whatever is queued
        return n


class HostAgent:
    """One Bertha runtime endpoint: fabric address + listener thread +
    negotiation/reconfiguration state.

    Servers call ``listen(stack)``; clients call ``connect(addr, stack)`` and
    get back a reconfigurable ``ConnHandle``. Multilateral switches go
    through ``reconfigure_multilateral`` (2PC); peers participate via
    ``register_participant``. The listener loop also pumps the prepared-peer
    resync: any participant stuck prepared past its resync window gets its
    coordinator queried for the connection's current epoch + stack (a
    dedicated ``<addr>/resync`` endpoint carries the query so it cannot steal
    frames from in-flight negotiations on the main endpoint)."""

    def __init__(self, fabric: Fabric, addr: str, *, mechanism: str = "lock",
                 n_data_threads: int = 1):
        self.fabric = fabric
        self.addr = addr
        self.ep = fabric.register(addr)
        self.ctrl = fabric.register(addr + "/ctrl")
        self._resync_ep = fabric.register(addr + "/resync")
        self.mechanism = mechanism
        self.n_data_threads = n_data_threads
        self.zero_rtt = ZeroRttCache()
        self._negotiator: Optional[ServerNegotiator] = None
        self._participants: Dict[str, ReconfigParticipant] = {}
        self._coordinating: Dict[str, ConnHandle] = {}
        self._decided: Dict[str, tuple] = {}  # conn -> (epoch, fp) at commit point
        self._pending: Dict[str, str] = {}    # conn -> fp of an undecided 2PC
        self._handlers: Dict[str, Callable[[str, dict], dict]] = {}
        self._chans: Dict[str, ReliableChannel] = {}  # per-peer client channels
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    # -- server side -----------------------------------------------------------
    def listen(self, stack: Stack) -> ServerNegotiator:
        self._negotiator = ServerNegotiator(stack)
        return self._negotiator

    def on(self, msg_type: str, handler: Callable[[str, dict], dict]) -> None:
        self._handlers[msg_type] = handler

    def _dispatch(self, src: str, body: dict) -> dict:
        t = body.get("type", "")
        if t in ("offer", "zero_rtt"):
            if self._negotiator is None:
                return {"type": "reject", "reason": "not listening"}
            return self._negotiator.handle(src, body)
        if t == "reconfig_query":
            # Prepared-peer resync: report the connection's current epoch
            # (switch count) + active stack fingerprint. A coordinator answers
            # from the handle it drove through the 2PC; a fellow peer answers
            # from its own committed knowledge (its epoch orders the same way).
            conn = body.get("conn", "")
            h = self._coordinating.get(conn)
            if h is not None:
                epoch, fp = h.stats.switches, h.stack.fingerprint()
                dec = self._decided.get(conn)
                if dec is not None and dec[0] > epoch:
                    # commit decided but the local swap has not applied yet
                    # (phase-2 notifications still draining): answer with the
                    # DECISION, or a delayed peer reads "aborted" and later
                    # refuses the real commit (permanent divergence)
                    epoch, fp = dec
                elif conn in self._pending:
                    # phase 1 still collecting votes: nothing is decided, so a
                    # prepared peer must WAIT, not conclude "aborted" from the
                    # unchanged epoch (a slow co-peer's prepare can outlast a
                    # fast peer's resync window)
                    return {"type": "reconfig_state", "conn": conn,
                            "epoch": epoch, "fp": fp, "pending": True}
                return {"type": "reconfig_state", "conn": conn,
                        "epoch": epoch, "fp": fp}
            part = self._participants.get(conn)
            if part is not None:
                return {"type": "reconfig_state", "conn": conn,
                        "epoch": part.epoch,
                        "fp": part.handle.stack.fingerprint()}
            return {"type": "reconfig_refuse", "reason": f"unknown conn {conn!r}"}
        if t.startswith("reconfig_"):
            # Strict conn-id dispatch: an unknown id must be refused, never
            # routed to an arbitrary participant — a reconfig_prepare/commit
            # for conn B must not prepare or swap conn A's stack.
            conn = body.get("conn", "")
            part = self._participants.get(conn)
            if part is None:
                return {"type": "reconfig_refuse", "reason": f"unknown conn {conn!r}"}
            return part.handle_msg(src, body)
        h = self._handlers.get(t)
        if h is not None:
            return h(src, body)
        return {"type": "error", "reason": f"no handler for {t!r}"}

    def _loop(self) -> None:
        chan = ReliableChannel(self.ctrl, peer="*")
        while not self._stop.is_set():
            chan.serve_one(self._dispatch, timeout=0.05)
            self._resync_prepared()

    def _resync_prepared(self) -> None:
        """Eagerly resolve peers stuck in the prepared state: query each
        overdue participant's coordinator for the current epoch/stack and
        fold the answer in (commit the missed decision or clear the stale
        prepared flag). Runs on the listener thread; query timeouts defer the
        participant to its next window instead of blocking the loop."""
        for conn_id, part in list(self._participants.items()):
            src = part.needs_resync()
            if src is None:
                continue
            chan = ReliableChannel(self._resync_ep, src + "/ctrl",
                                   timeout=0.05, retries=4)
            try:
                reply = chan.request({"type": "reconfig_query", "conn": conn_id})
            except TimeoutError:
                part.defer_resync()
                # Stranded-peer alarm: a prepared participant whose epoch
                # queries keep timing out cannot learn the 2PC verdict.
                # Dump the flight recorder once per conn (no-op when
                # tracing is disabled) so the spans leading up to the
                # strand survive for python -m repro.obs to render.
                if part.resync_failures == _STRAND_ALARM_FAILURES and TRACER.enabled:
                    from repro.obs.flight import strand_alarm
                    strand_alarm(conn_id, src, part.resync_failures)
                continue
            part.apply_state(reply if isinstance(reply, dict) else {})

    # -- client side -----------------------------------------------------------
    def connect(self, peer: str, stack: Stack, *, use_zero_rtt: bool = False) -> ConnHandle:
        chan = ReliableChannel(self.ep, peer + "/ctrl")
        neg = client_negotiate(chan, stack, self.zero_rtt if use_zero_rtt else None)
        handle = self._make_handle(neg.stack)
        handle.nonce = neg.nonce
        handle.was_zero_rtt = neg.zero_rtt
        handle.source_stack = stack
        return handle

    def accept_stack(self, peer: str) -> Optional[ConcreteStack]:
        if self._negotiator is None:
            return None
        return self._negotiator.negotiated.get(peer)

    def _make_handle(self, concrete: ConcreteStack) -> ConnHandle:
        if self.mechanism == "barrier":
            return BarrierConn(concrete, n_threads=self.n_data_threads)
        return LockedConn(concrete)

    def register_participant(self, conn_id: str, handle: ConnHandle,
                             resolve: Callable[[str], Optional[ConcreteStack]],
                             *, resync_after_s: float = 1.0) -> None:
        """Make this agent a 2PC participant for ``conn_id``: prepares/commits
        arriving for that connection drive ``handle``; ``resolve`` maps a
        proposed fingerprint to a ConcreteStack we could switch to (None ⇒
        refuse). ``resync_after_s`` bounds how long the peer may sit prepared
        before the epoch-query resync kicks in."""
        self._participants[conn_id] = ReconfigParticipant(
            handle, resolve, resync_after_s=resync_after_s)

    def participant(self, conn_id: str) -> Optional[ReconfigParticipant]:
        """The registered participant for ``conn_id`` (chaos scenarios assert
        on its ``prepared``/``epoch``/``resync_failures`` state)."""
        return self._participants.get(conn_id)

    def coordinate(self, conn_id: str, handle: ConnHandle) -> None:
        """Record this agent as ``conn_id``'s 2PC coordinator so it can
        answer peers' ``reconfig_query`` resyncs from ``handle``'s live state
        (epoch = switch count, fp = active stack).
        ``reconfigure_multilateral`` calls this automatically."""
        self._coordinating[conn_id] = handle

    def record_decision(self, conn_id: str, epoch: int, fp: str) -> None:
        """Record a 2PC commit DECISION for ``conn_id`` (fired by
        ``two_phase_commit``'s on_decide hook, at the commit point, before
        phase-2 notifications). Epoch queries arriving while notifications
        drain — or before the local swap applies — are answered from this
        record instead of the stale pre-swap handle state."""
        self._decided[conn_id] = (epoch, fp)

    def _chan(self, peer: str, timeout: float, retries: int) -> ReliableChannel:
        """Cached per-peer client channel on the main endpoint (keeps the
        receiver's window/dedupe state warm across calls)."""
        ch = self._chans.get(peer)
        if ch is None or ch.timeout != timeout or ch.retries != retries:
            ch = ReliableChannel(self.ep, peer, timeout=timeout, retries=retries)
            self._chans[peer] = ch
        return ch

    def request(self, peer: str, msg: dict, *, timeout: float = 0.1, retries: int = 40) -> dict:
        return self._chan(peer + "/ctrl", timeout, retries).request(msg)

    def request_many(self, peer: str, msgs: List[dict], *, timeout: float = 0.1,
                     retries: int = 40, window: Optional[int] = None) -> List[dict]:
        """Pipelined reliable requests to one peer: up to W frames in flight
        (ReliableChannel.request_window) instead of one RTT per frame."""
        return self._chan(peer + "/ctrl", timeout, retries).request_window(
            msgs, window=window)

    def reconfigure_multilateral(self, handle: ConnHandle, new_stack: ConcreteStack,
                                 peers: List[str], conn_id: str, *,
                                 timeout: float = 0.1,
                                 retries: int = 40) -> bool:
        """Switch a multilateral stack across all endpoints of ``conn_id``.

        Runs the two-phase commit with ``peers`` *inside* ``handle``'s switch
        point (§4.2: negotiation happens while the lock/barrier is held, so
        no data thread can race the group decision), then swaps locally.

        Args:
            handle: this side's live connection (LockedConn/BarrierConn).
            new_stack: the agreed target — must resolve on every peer (each
                participant's ``resolve`` refuses unknown fingerprints, which
                aborts the 2PC).
            peers: fabric addresses of the other endpoints.
            conn_id: the connection's group identity; peers registered it via
                ``register_participant``.
            timeout/retries: per-request reliability budget. The defaults
                tolerate seconds of peer unreachability; chaos scenarios pass
                a small budget so a coordinator crashed mid-commit releases
                the switch point quickly (phase-2 stays presumed-commit
                either way).

        Returns:
            True if all peers voted ready and the swap committed; False if
            any peer refused/timed out (everyone keeps the old stack). Once
            committed, phase-2 delivery is best-effort: a peer that misses
            the notification resyncs eagerly through the epoch query this
            agent answers as coordinator (see ``coordinate``).
        """
        from repro.core.reconfigure import two_phase_commit

        self.coordinate(conn_id, handle)
        epoch = handle.stats.switches + 1  # our count once this commits
        fp = new_stack.fingerprint()

        def coordinate() -> bool:
            return two_phase_commit(
                lambda p, m: self.request(p, {**m, "conn": conn_id},
                                          timeout=timeout, retries=retries),
                peers, fp, epoch=epoch,
                on_decide=lambda: self.record_decision(conn_id, epoch, fp),
            )

        self._pending[conn_id] = fp  # queries during phase 1 answer "pending"
        try:
            return handle.reconfigure(new_stack, coordinate=coordinate)
        finally:
            self._pending.pop(conn_id, None)

    def close(self) -> None:
        self._stop.set()
        self._thread.join(timeout=1.0)
        self.ep.close()
        self.ctrl.close()
        self._resync_ep.close()
