"""Capabilities: relative-compatibility metadata for negotiation (Bertha §5.2).

Checking implementation equivalence is undecidable, so chunnels declare opaque
capability labels instead. Two match modes (as found sufficient in the paper):

  exact   — must be present in BOTH endpoints' stacks (e.g. serialization /
            wire format: both sides must speak it)
  compose — must be present in AT LEAST ONE stack (e.g. sharding / routing:
            one side doing it suffices)

Label convention "<feature>:<impl>" lets independent implementations declare
compatibility by reusing a label (the paper's ProtoBuf/ProtoACC example).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import FrozenSet, Iterable


@dataclass(frozen=True, order=True)
class Capability:
    label: str
    mode: str = "exact"  # "exact" | "compose"

    def __post_init__(self):
        assert self.mode in ("exact", "compose"), self.mode

    def __str__(self) -> str:
        return f"{self.label}/{self.mode}"

    def to_wire(self) -> dict:
        return {"label": self.label, "mode": self.mode}

    @staticmethod
    def from_wire(d: dict) -> "Capability":
        return Capability(d["label"], d["mode"])


class CapabilitySet(frozenset):
    """A frozenset of Capability with Bertha's two-mode comparison."""

    @staticmethod
    def exact(*labels: str) -> "CapabilitySet":
        return CapabilitySet(Capability(l, "exact") for l in labels)

    @staticmethod
    def compose(*labels: str) -> "CapabilitySet":
        return CapabilitySet(Capability(l, "compose") for l in labels)

    def union_(self, other: Iterable[Capability]) -> "CapabilitySet":
        return CapabilitySet(frozenset(self) | frozenset(other))

    def exact_labels(self) -> FrozenSet[str]:
        return frozenset(c.label for c in self if c.mode == "exact")

    def compose_labels(self) -> FrozenSet[str]:
        return frozenset(c.label for c in self if c.mode == "compose")

    def compatible_with(self, other: "CapabilitySet") -> bool:
        """§5.2: exact capabilities must match on both sides; compositional
        capabilities must appear in at least one side (always true if present
        anywhere — they never *block*; what blocks is an exact mismatch)."""
        return self.exact_labels() == other.exact_labels()

    def to_wire(self) -> list:
        return sorted((c.to_wire() for c in self), key=lambda d: (d["label"], d["mode"]))

    @staticmethod
    def from_wire(items: list) -> "CapabilitySet":
        return CapabilitySet(Capability.from_wire(d) for d in items)


def stack_compatible(a: CapabilitySet, b: CapabilitySet) -> bool:
    return a.compatible_with(b)
