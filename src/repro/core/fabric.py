"""In-process host fabric: the 'network' under host-level chunnels.

Best-effort datagram delivery between named endpoints with configurable
latency and loss (so the negotiation protocol's reliability layer is exercised
for real). Used by the §7-style application benchmarks and the negotiation /
reconfiguration protocols; the tensor math itself rides the JAX mesh.

The data path is batched (docs/architecture.md §8): ``Fabric.send_batch``
moves a whole list of messages with one registration-table read, one RNG
acquisition (loss applied per message via a precomputed Bernoulli mask, one
jitter draw per batch), one byte-accounting update and one delivery timer.
``Endpoint`` inboxes are bounded ring buffers (deque + condition variable);
``recv_many`` drains everything available under a single wakeup. The fabric
registration lock guards only register/unregister/set_link — never delivery.
"""
from __future__ import annotations

import queue
import random
import threading
import time
import warnings
from collections import deque
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.obs.trace import TRACER


@dataclass
class LinkModel:
    latency_s: float = 0.0
    jitter_s: float = 0.0
    loss: float = 0.0  # probability a datagram is dropped


@dataclass
class FabricCounters:
    """Split datagram accounting (msgs + bytes). ``sent`` counts everything
    offered to the fabric; a sent datagram is then exactly one of delivered /
    dropped_loss / dropped_unroutable / dropped_overflow (or still in flight
    on a latency timer). Plain ints riding the GIL — advisory, like telemetry."""

    sent: int = 0
    delivered: int = 0
    dropped_loss: int = 0
    dropped_unroutable: int = 0
    dropped_overflow: int = 0  # receiver ring full
    sent_bytes: int = 0
    delivered_bytes: int = 0

    #: Pre-split aliases still found in older dashboards/scripts → the
    #: canonical split field they read today. The exporter schema
    #: (repro.obs.metrics) only ever sees snapshot()'s canonical names.
    LEGACY_ALIASES = {"sent_msgs": "sent", "sent_bytes": "sent_bytes"}

    def snapshot(self) -> dict:
        return {
            "sent": self.sent,
            "delivered": self.delivered,
            "dropped_loss": self.dropped_loss,
            "dropped_unroutable": self.dropped_unroutable,
            "dropped_overflow": self.dropped_overflow,
            "sent_bytes": self.sent_bytes,
            "delivered_bytes": self.delivered_bytes,
        }

    def legacy(self, name: str) -> int:
        """Single deprecation funnel for pre-split counter names.

        Every legacy surface (``Fabric.sent_msgs``/``Fabric.sent_bytes``)
        routes here so there is exactly one warning site to delete when
        the aliases are removed."""
        try:
            canon = self.LEGACY_ALIASES[name]
        except KeyError:
            raise AttributeError(f"unknown legacy counter alias: {name!r}")
        warnings.warn(
            f"counter alias {name!r} is deprecated; read the split name "
            f"{canon!r} via FabricCounters.snapshot()",
            DeprecationWarning, stacklevel=3)
        return getattr(self, canon)


class Endpoint:
    """A named fabric endpoint with a bounded ring-buffer inbox.

    The ring is a deque guarded by one condition variable; a batch delivery
    appends every message and signals waiters once, so per-message cost on
    the hot path is a single ``deque.append``."""

    def __init__(self, addr: str, fabric: "Fabric", *, capacity: int = 65536):
        self.addr = addr
        self.fabric = fabric
        self.capacity = capacity
        self._ring: deque = deque()
        self._cv = threading.Condition()

    # -- sending ---------------------------------------------------------------
    def send(self, dst: str, msg: Any) -> None:
        self.fabric.send_batch(self.addr, dst, (msg,))

    def send_batch(self, dst: str, msgs: Sequence[Any]) -> int:
        """Vectorized send; returns the number of messages accepted for
        delivery (i.e. not lost / unroutable)."""
        return self.fabric.send_batch(self.addr, dst, msgs)

    # -- receiving -------------------------------------------------------------
    def recv(self, timeout: Optional[float] = None) -> Optional[Tuple[str, Any]]:
        buf: List[Any] = [None]
        n = self.recv_many(buf, timeout=timeout)
        return buf[0] if n else None

    def recv_many(self, buf: list, max_n: Optional[int] = None,
                  timeout: Optional[float] = None) -> int:
        """Drain up to ``min(len(buf), max_n)`` queued ``(src, msg)`` pairs
        into ``buf`` under one condition acquisition. Blocks up to ``timeout``
        for the first message only — it never waits for a full buffer."""
        want = len(buf) if max_n is None else min(max_n, len(buf))
        if want <= 0:
            return 0
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            while not self._ring:
                if deadline is None:
                    self._cv.wait()
                else:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        return 0
                    self._cv.wait(remaining)
            avail = len(self._ring)
            if want >= avail:
                buf[:avail] = self._ring  # bulk drain, C-level iteration
                self._ring.clear()
                n = avail
            else:
                pop = self._ring.popleft
                for i in range(want):
                    buf[i] = pop()
                n = want
        # Record only short reads (outside the ring lock): a full read is the
        # steady state and already visible from the sender's record — skipping
        # it keeps the enabled-tracing cost at one record per round trip
        # (bench_overhead gates <10% at batch=64). A short read marks the tail
        # of a burst (or starvation), which is the receiver-side event worth a
        # timeline instant.
        if n < want and TRACER.enabled:
            TRACER.record_batch("fabric.recv_many", n, n)
        return n

    def _deliver_batch(self, items: Sequence[Tuple[str, Any]]) -> int:
        """Fabric-side delivery: append a batch, notify waiters once. Returns
        how many messages fit in the ring (the rest are overflow-dropped)."""
        with self._cv:
            space = self.capacity - len(self._ring)
            if space <= 0:
                return 0
            accepted = min(space, len(items))
            self._ring.extend(items if accepted == len(items) else items[:accepted])
            self._cv.notify_all()
            return accepted

    def pending(self) -> int:
        with self._cv:
            return len(self._ring)

    def close(self) -> None:
        self.fabric.unregister(self.addr)


class Fabric:
    def __init__(self, *, default_link: LinkModel | None = None, seed: int = 0,
                 endpoint_capacity: int = 65536):
        self._eps: Dict[str, Endpoint] = {}
        self._links: Dict[Tuple[str, str], LinkModel] = {}
        self._default = default_link or LinkModel()
        self._capacity = endpoint_capacity
        self._rng = random.Random(seed)
        # registration lock: register/unregister/set_link only (control plane)
        self._lock = threading.Lock()
        # small data-plane lock serializing the shared RNG; held once per batch
        self._rng_lock = threading.Lock()
        self._register_hooks: List[Callable[[str], None]] = []
        self.counters = FabricCounters()

    # -- control plane (registration lock) --------------------------------------
    def register(self, addr: str) -> Endpoint:
        with self._lock:
            if addr in self._eps:
                raise ValueError(f"address in use: {addr}")
            ep = Endpoint(addr, self, capacity=self._capacity)
            self._eps[addr] = ep
            hooks = tuple(self._register_hooks)
        for cb in hooks:  # outside the lock: hooks may call set_link etc.
            cb(addr)
        return ep

    def unregister(self, addr: str) -> None:
        with self._lock:
            self._eps.pop(addr, None)

    def set_link(self, src: str, dst: str, model: LinkModel) -> None:
        with self._lock:
            self._links[(src, dst)] = model

    def clear_link(self, src: str, dst: str) -> None:
        """Remove a per-pair override so the pair reverts to the default link."""
        with self._lock:
            self._links.pop((src, dst), None)

    def get_link(self, src: str, dst: str) -> LinkModel:
        """Effective link model for a pair (override if set, else default)."""
        with self._lock:
            return self._links.get((src, dst), self._default)

    def link_override(self, src: str, dst: str) -> Optional[LinkModel]:
        """The per-pair override, or None if the pair rides the default link.
        Fault injectors use this to save/restore state across heal events."""
        with self._lock:
            return self._links.get((src, dst))

    def endpoints(self) -> List[str]:
        """Snapshot of registered endpoint addresses (control plane only)."""
        with self._lock:
            return list(self._eps)

    def add_register_hook(self, cb: Callable[[str], None]) -> None:
        """Observe endpoint registration (chaos injection, service discovery).
        Hooks run after the endpoint is routable, outside the fabric lock."""
        with self._lock:
            self._register_hooks.append(cb)

    def remove_register_hook(self, cb: Callable[[str], None]) -> None:
        with self._lock:
            try:
                self._register_hooks.remove(cb)
            except ValueError:
                pass

    # -- data plane (no registration lock) ---------------------------------------
    def send(self, src: str, dst: str, msg: Any) -> None:
        self.send_batch(src, dst, (msg,))

    def send_batch(self, src: str, dst: str, msgs: Sequence[Any]) -> int:
        """The batched hot path: one link lookup, one RNG acquisition (loss
        applied via a per-message Bernoulli mask, one jitter draw), one byte
        accounting update and one delivery (timer) per batch. Returns the
        number of messages accepted for delivery."""
        if not isinstance(msgs, (list, tuple)):
            msgs = list(msgs)
        if not msgs:
            return 0
        # dict reads ride the GIL; _links/_eps are only mutated under _lock
        m = self._links.get((src, dst), self._default)
        ep = self._eps.get(dst)
        # not under any lock; inline len() for the common bytes payload
        sizes = [len(x) if type(x) is bytes else _approx_size(x) for x in msgs]
        c = self.counters
        with self._rng_lock:
            # shared Random() under a lock: an unguarded draw can repeat/skip
            # states under contention
            rng = self._rng.random
            jitter = rng() if m.jitter_s else 0.0
            mask = [rng() >= m.loss for _ in msgs] if m.loss else None
        c.sent += len(msgs)
        c.sent_bytes += sum(sizes)
        if ep is None:
            c.dropped_unroutable += len(msgs)
            if TRACER.enabled:  # dropped batches close with a drop_reason
                TRACER.record_batch("fabric.send_batch", len(msgs), 0,
                                    {"dst": dst, "drop_reason": "unroutable"})
            return 0
        if mask is None:
            kept = msgs  # not mutated downstream: items/sizes are derived views
            kept_sizes = sizes
        else:
            kept = [x for x, keep in zip(msgs, mask) if keep]
            kept_sizes = [s for s, keep in zip(sizes, mask) if keep]
            c.dropped_loss += len(msgs) - len(kept)
        if TRACER.enabled:  # one tuple per batch, never per message (§10)
            TRACER.record_batch(
                "fabric.send_batch", len(msgs), len(kept),
                {"drop_reason": "loss"} if len(kept) < len(msgs) else None)
        if not kept:
            return 0
        items = [(src, x) for x in kept]
        delay = m.latency_s + jitter * m.jitter_s
        if delay > 0:
            t = threading.Timer(delay, self._deliver, args=(ep, items, kept_sizes))
            t.daemon = True
            t.start()
        else:
            self._deliver(ep, items, kept_sizes)
        return len(kept)

    def _deliver(self, ep: Endpoint, items: List[Tuple[str, Any]],
                 sizes: List[int]) -> None:
        accepted = ep._deliver_batch(items)
        c = self.counters
        c.delivered += accepted
        c.dropped_overflow += len(items) - accepted
        c.delivered_bytes += sum(sizes) if accepted == len(items) else sum(sizes[:accepted])
        if accepted < len(items) and TRACER.enabled:
            TRACER.record_batch("fabric.deliver", len(items), accepted,
                                {"dst": ep.addr, "drop_reason": "overflow"})

    # -- legacy accounting aliases (deprecated: read counters.snapshot()) --------
    @property
    def sent_msgs(self) -> int:
        return self.counters.legacy("sent_msgs")

    @property
    def sent_bytes(self) -> int:
        return self.counters.legacy("sent_bytes")


def approx_size(msg: Any) -> int:
    """Rough wire size of a fabric message — used for accounting (fabric
    byte counters, connection telemetry), not for framing."""
    return _approx_size(msg)


def _approx_size(msg: Any) -> int:
    if isinstance(msg, (bytes, bytearray)):
        return len(msg)
    if isinstance(msg, str):
        return len(msg)
    if isinstance(msg, dict):
        return sum(_approx_size(k) + _approx_size(v) for k, v in msg.items())
    if isinstance(msg, (list, tuple)):
        return sum(_approx_size(v) for v in msg)
    nbytes = getattr(msg, "nbytes", None)  # numpy/JAX arrays
    if isinstance(nbytes, int):
        return nbytes
    return 8


import itertools

# Sequence numbers are process-global and monotonic so a fresh channel between
# the same endpoints can never collide with the receiver's dedupe window.
_GLOBAL_SEQ = itertools.count(1)
_GLOBAL_SEQ_LOCK = threading.Lock()


def _next_seq() -> int:
    with _GLOBAL_SEQ_LOCK:
        return next(_GLOBAL_SEQ)


class ReliableChannel:
    """Reliability + ordering over the best-effort fabric — Bertha §5.1: 'a
    simple reliability and ordering protocol ... used for negotiation'.
    Application chunnels bring their own reliability.

    ``request`` is the classic stop-and-wait RPC. ``request_window`` pipelines
    up to ``window`` frames before blocking on acks (go-back-N retransmit,
    cumulative ``_cum`` acks), so multi-frame flows to one peer stop paying a
    full RTT per frame. The receiver (``serve_one``) processes window frames
    in order, holding out-of-order arrivals, and answers retransmissions of
    already-processed frames from a per-window reply cache — the handler
    still observes exactly-once semantics."""

    def __init__(self, ep: Endpoint, peer: str, *, timeout: float = 0.05,
                 retries: int = 40, window: int = 8,
                 reply_cache_size: int = 64, max_windows: int = 32):
        self.ep = ep
        self.peer = peer
        self.timeout = timeout
        self.retries = retries
        self.window = window
        self.reply_cache_size = reply_cache_size
        self.max_windows = max_windows
        self._rx_seq: Dict[str, int] = {}
        self._reply_cache: Dict[Tuple[str, int], Any] = {}
        # per-peer insertion order: seqs are process-global (sparse per peer),
        # so eviction must go by arrival order, not by seq arithmetic
        self._reply_order: Dict[str, deque] = {}
        self._win_rx: Dict[Tuple[str, int], dict] = {}
        self._win_order: deque = deque()
        self._pending: "queue.Queue[Tuple[str, Any]]" = queue.Queue()
        # advisory counters (plain ints riding the GIL, like FabricCounters):
        # frames sent a 2nd+ time, and duplicate frames answered from cache
        self.retransmits = 0
        self.dup_replies = 0

    # -- client side -------------------------------------------------------------
    def request(self, msg: Any, *, retries: Optional[int] = None) -> Any:
        """Send reliably and wait for the (piggybacked) reply. ``retries``
        overrides the channel default for this call (fail-fast probes)."""
        seq = _next_seq()
        frame = {"_seq": seq, "body": msg}
        # The frame dict is built ONCE: a retransmission reuses the same
        # "_tc", so the wire span id is stable across retries by design.
        sp = TRACER.begin_span("rc.request",
                               attrs={"peer": self.peer, "seq": seq})
        if sp:
            frame["_tc"] = sp.ctx
        n_tries = self.retries if retries is None else retries
        for attempt in range(n_tries):
            if attempt:
                self.retransmits += 1
                sp.event("retransmit", retry=attempt)
            self.ep.send(self.peer, frame)
            deadline = time.monotonic() + self.timeout
            while True:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    break
                got = self.ep.recv(timeout=remaining)
                if got is None:
                    break
                src, m = got
                if isinstance(m, dict) and m.get("_ack") == seq and src == self.peer:
                    sp.end()
                    return m["body"]
                self._pending.put((src, m))
        sp.end(status="timeout", drop_reason="no_reply", retries=n_tries)
        raise TimeoutError(f"no reply from {self.peer} after {n_tries} retries")

    def request_window(self, msgs: Sequence[Any], *,
                       window: Optional[int] = None) -> List[Any]:
        """Pipelined reliable request: up to W frames in flight before
        blocking on acks. Returns the replies in request order. Raises
        TimeoutError after ``retries`` consecutive no-progress rounds."""
        msgs = list(msgs)
        n = len(msgs)
        if n == 0:
            return []
        W = max(1, self.window if window is None else window)
        win_id = _next_seq()
        frames = [{"_seq": _next_seq(), "_win": (win_id, i, n), "body": b}
                  for i, b in enumerate(msgs)]
        # One span for the whole window; every frame carries the same
        # "_tc" and the dicts are reused on go-back-N resends, so a
        # retransmitted frame keeps its original span id (tagged retry=n
        # below) instead of minting a new identity per attempt.
        sp = TRACER.begin_span("rc.window",
                               attrs={"peer": self.peer, "n": n, "win": win_id})
        if sp:
            tc = sp.ctx
            for f in frames:
                f["_tc"] = tc
        seq2idx = {f["_seq"]: i for i, f in enumerate(frames)}
        replies: List[Any] = [None] * n
        acked = [False] * n
        sent = [0] * n  # per-frame send counts (retry=sent[i] on resend)
        base = 0
        stalls = 0
        while True:
            while base < n and acked[base]:
                base += 1
            if base >= n:
                sp.end()
                return replies
            hi = min(base + W, n)
            # go-back-N: (re)send every unacked frame in the window as a batch
            resend = [i for i in range(base, hi) if not acked[i]]
            for i in resend:
                if sent[i]:
                    self.retransmits += 1
                    sp.event("retransmit", frame=i, retry=sent[i])
                sent[i] += 1
            self.ep.send_batch(self.peer, [frames[i] for i in resend])
            deadline = time.monotonic() + self.timeout
            progress = False
            while True:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    break
                got = self.ep.recv(timeout=remaining)
                if got is None:
                    break
                src, m = got
                if (src == self.peer and isinstance(m, dict)
                        and m.get("_ack") in seq2idx):
                    i = seq2idx[m["_ack"]]
                    if not acked[i]:
                        acked[i] = True
                        replies[i] = m["body"]
                        progress = True
                    if all(acked[base:min(base + W, n)]):
                        break  # window fully acked: slide + refill immediately
                else:
                    self._pending.put(got)
            if progress:
                stalls = 0
            else:
                stalls += 1
                if stalls >= self.retries:
                    sp.end(status="timeout", drop_reason="window_stalled",
                           acked=sum(acked))
                    raise TimeoutError(
                        f"window to {self.peer} stalled after {self.retries} retries")

    # -- server side -------------------------------------------------------------
    def serve_one(self, handler: Callable[[str, Any], Any],
                  timeout: Optional[float] = None) -> bool:
        """Receive one reliable frame, dedupe, reply via handler."""
        got = None
        try:
            got = self._pending.get_nowait()
        except queue.Empty:
            got = self.ep.recv(timeout=timeout)
        if got is None:
            return False
        src, m = got
        if not (isinstance(m, dict) and "_seq" in m):
            return False
        if "_win" in m:
            return self._serve_window(src, m, handler)
        seq = m["_seq"]
        last = self._rx_seq.get(src, 0)
        if seq > last:
            tc = m.get("_tc") if TRACER.enabled else None
            if tc is not None:
                # re-parent the handler's spans under the sender's span so
                # one trace stitches across endpoints
                with TRACER.adopt(tc):
                    reply = handler(src, m["body"])
            else:
                reply = handler(src, m["body"])
            self._cache_reply(src, seq, reply)
        else:
            # Retransmission (our ack was lost): resend the cached reply so the
            # handler observes exactly-once semantics.
            reply = self._reply_cache.get((src, seq))
            self.dup_replies += 1
        self._rx_seq[src] = max(last, seq)
        self.ep.send(src, {"_ack": seq, "body": reply})
        return True

    def _cache_reply(self, src: str, seq: int, reply: Any) -> None:
        self._reply_cache[(src, seq)] = reply
        order = self._reply_order.setdefault(src, deque())
        order.append(seq)
        while len(order) > self.reply_cache_size:
            self._reply_cache.pop((src, order.popleft()), None)

    def _serve_window(self, src: str, m: dict, handler) -> bool:
        win_id, idx, _n = m["_win"]
        key = (src, win_id)
        st = self._win_rx.get(key)
        if st is None:
            st = {"next": 0, "held": {}, "replies": {}}
            self._win_rx[key] = st
            self._win_order.append(key)
            while len(self._win_order) > self.max_windows:
                self._win_rx.pop(self._win_order.popleft(), None)
        if idx < st["next"]:
            # retransmission of a processed frame: cached reply, handler not re-run
            self.dup_replies += 1
            self.ep.send(src, {"_ack": m["_seq"], "_cum": st["next"] - 1,
                               "body": st["replies"].get(idx)})
            return True
        st["held"][idx] = m
        acks = []
        while st["next"] in st["held"]:
            f = st["held"].pop(st["next"])
            tc = f.get("_tc") if TRACER.enabled else None
            if tc is not None:
                with TRACER.adopt(tc):
                    reply = handler(src, f["body"])
            else:
                reply = handler(src, f["body"])
            st["replies"][st["next"]] = reply
            acks.append({"_ack": f["_seq"], "body": reply})
            st["next"] += 1
        if acks:
            cum = st["next"] - 1
            for a in acks:
                a["_cum"] = cum
            self.ep.send_batch(src, acks)
        return True
