"""In-process host fabric: the 'network' under host-level chunnels.

Best-effort datagram delivery between named endpoints with configurable
latency and loss (so the negotiation protocol's reliability layer is exercised
for real). Used by the §7-style application benchmarks and the negotiation /
reconfiguration protocols; the tensor math itself rides the JAX mesh.
"""
from __future__ import annotations

import queue
import random
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple


@dataclass
class LinkModel:
    latency_s: float = 0.0
    jitter_s: float = 0.0
    loss: float = 0.0  # probability a datagram is dropped


class Endpoint:
    def __init__(self, addr: str, fabric: "Fabric"):
        self.addr = addr
        self.fabric = fabric
        self.inbox: "queue.Queue[Tuple[str, Any]]" = queue.Queue()

    def send(self, dst: str, msg: Any) -> None:
        self.fabric.send(self.addr, dst, msg)

    def recv(self, timeout: Optional[float] = None) -> Optional[Tuple[str, Any]]:
        try:
            return self.inbox.get(timeout=timeout)
        except queue.Empty:
            return None

    def close(self) -> None:
        self.fabric.unregister(self.addr)


class Fabric:
    def __init__(self, *, default_link: LinkModel | None = None, seed: int = 0):
        self._eps: Dict[str, Endpoint] = {}
        self._links: Dict[Tuple[str, str], LinkModel] = {}
        self._default = default_link or LinkModel()
        self._rng = random.Random(seed)
        self._lock = threading.Lock()
        self.sent_bytes = 0
        self.sent_msgs = 0

    def register(self, addr: str) -> Endpoint:
        with self._lock:
            if addr in self._eps:
                raise ValueError(f"address in use: {addr}")
            ep = Endpoint(addr, self)
            self._eps[addr] = ep
            return ep

    def unregister(self, addr: str) -> None:
        with self._lock:
            self._eps.pop(addr, None)

    def set_link(self, src: str, dst: str, model: LinkModel) -> None:
        with self._lock:
            self._links[(src, dst)] = model

    def _model(self, src: str, dst: str) -> LinkModel:
        with self._lock:
            return self._links.get((src, dst), self._default)

    def send(self, src: str, dst: str, msg: Any) -> None:
        m = self._model(src, dst)
        size = _approx_size(msg)  # recurses over the payload: not under lock
        with self._lock:
            if m.loss and self._rng.random() < m.loss:
                return  # best-effort: dropped
            ep = self._eps.get(dst)
            self.sent_msgs += 1
            self.sent_bytes += size
            # rng draw inside the lock: Random() is shared across senders and
            # an unguarded draw can repeat/skip states under contention
            jitter = self._rng.random() if m.jitter_s else 0.0
        if ep is None:
            return  # unroutable: best-effort
        delay = m.latency_s + jitter * m.jitter_s
        if delay > 0:
            t = threading.Timer(delay, ep.inbox.put, args=((src, msg),))
            t.daemon = True
            t.start()
        else:
            ep.inbox.put((src, msg))


def approx_size(msg: Any) -> int:
    """Rough wire size of a fabric message — used for accounting (fabric
    byte counters, connection telemetry), not for framing."""
    return _approx_size(msg)


def _approx_size(msg: Any) -> int:
    if isinstance(msg, (bytes, bytearray)):
        return len(msg)
    if isinstance(msg, str):
        return len(msg)
    if isinstance(msg, dict):
        return sum(_approx_size(k) + _approx_size(v) for k, v in msg.items())
    if isinstance(msg, (list, tuple)):
        return sum(_approx_size(v) for v in msg)
    return 8


import itertools

# Sequence numbers are process-global and monotonic so a fresh channel between
# the same endpoints can never collide with the receiver's dedupe window.
_GLOBAL_SEQ = itertools.count(1)
_GLOBAL_SEQ_LOCK = threading.Lock()


def _next_seq() -> int:
    with _GLOBAL_SEQ_LOCK:
        return next(_GLOBAL_SEQ)


class ReliableChannel:
    """Stop-and-wait reliability + ordering over the best-effort fabric —
    Bertha §5.1: 'a simple reliability and ordering protocol ... used for
    negotiation'. Application chunnels bring their own reliability."""

    def __init__(self, ep: Endpoint, peer: str, *, timeout: float = 0.05, retries: int = 40):
        self.ep = ep
        self.peer = peer
        self.timeout = timeout
        self.retries = retries
        self._rx_seq: Dict[str, int] = {}
        self._reply_cache: Dict[Tuple[str, int], Any] = {}
        self._pending: "queue.Queue[Tuple[str, Any]]" = queue.Queue()

    def request(self, msg: Any) -> Any:
        """Send reliably and wait for the (piggybacked) reply."""
        seq = _next_seq()
        frame = {"_seq": seq, "body": msg}
        for _ in range(self.retries):
            self.ep.send(self.peer, frame)
            deadline = time.monotonic() + self.timeout
            while True:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    break
                got = self.ep.recv(timeout=remaining)
                if got is None:
                    break
                src, m = got
                if isinstance(m, dict) and m.get("_ack") == seq and src == self.peer:
                    return m["body"]
                self._pending.put((src, m))
        raise TimeoutError(f"no reply from {self.peer} after {self.retries} retries")

    def serve_one(self, handler: Callable[[str, Any], Any], timeout: Optional[float] = None) -> bool:
        """Receive one reliable frame, dedupe, reply via handler."""
        got = None
        try:
            got = self._pending.get_nowait()
        except queue.Empty:
            got = self.ep.recv(timeout=timeout)
        if got is None:
            return False
        src, m = got
        if not (isinstance(m, dict) and "_seq" in m):
            return False
        seq = m["_seq"]
        last = self._rx_seq.get(src, 0)
        if seq > last:
            reply = handler(src, m["body"])
            self._reply_cache[(src, seq)] = reply
            self._reply_cache.pop((src, seq - 8), None)  # bounded cache
        else:
            # Retransmission (our ack was lost): resend the cached reply so the
            # handler observes exactly-once semantics.
            reply = self._reply_cache.get((src, seq))
        self._rx_seq[src] = max(last, seq)
        self.ep.send(src, {"_ack": seq, "body": reply})
        return True
