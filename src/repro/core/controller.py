"""Policy engine closing Bertha's reconfiguration loop.

The paper's pitch is that the stack changes at runtime in response to "where
applications run, the requests they serve, and the performance they need" —
the mechanisms (negotiate, 2PC, LockedConn/BarrierConn swap) live in their
own modules; this is the *policy* that drives them (cf. Morpheus-style
profile-guided re-specialization, PAPERS.md).

A ``ReconfigController`` maps a telemetry snapshot (``repro.core.telemetry``)
to a target configuration — typically a ``ConcreteStack`` drawn from the
negotiated ``Stack``'s options — and drives the switch mechanism:
``ConnHandle.reconfigure`` for unilateral swaps,
``HostAgent.reconfigure_multilateral`` (2PC) for multilateral ones, or a
trainer's rendezvous transition. Two dampers prevent flapping:

  hysteresis  a rule's predicate must hold for ``hold`` consecutive ticks
              before the rule may fire
  cooldown    after a committed switch no rule may fire for ``cooldown_s``

Every tick appends a ``Decision`` (fired or not, with the snapshot that
motivated it) to ``controller.decisions`` — the audit log the benchmarks emit
as JSON.

Rule targets come in two kinds: a *concrete* target (a ConcreteStack, or a
plain label like a trainer transport name) fires as written, while a
``ScoredTarget`` (repro.core.cost) names an OBJECTIVE — it is resolved each
tick to the argmax-utility candidate of the negotiated option set under the
live snapshot, so rules express "cheapest", "lowest latency", "fewest DCN
bytes" instead of hard-coding one stack per rule.

The *policy plugin registry* lets applications ship whole rule-sets without
editing core:

    @register_policy("carbon_aware")
    def carbon_aware(ctx: PolicyContext) -> list[Rule]:
        return [Rule("carbon", above("gco2_per_kwh", ctx.params["cap"]),
                     ScoredTarget(ctx.candidates, BYTES_FIRST))]

    ctl = conn_controller(handle, stack, policy="carbon_aware",
                          policy_params={"cap": 400.0})

Built-ins: ``latency_slo`` (SLO breach ⇒ lowest-latency option),
``byte_budget`` (byte-rate cap ⇒ fewest-wire-bytes option), ``cost_aware``
(track the utility argmax continuously), ``slo_guard`` (error-budget
burn-rate signals from ``repro.obs.slo`` ⇒ a safe stack before raw
thresholds trip). The trainer registers
``trainer_default`` and the KV serving plane ``kv_load_adaptive`` the same
way — through the public decorator, not by editing this module.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, Dict, List, Optional, Sequence

from repro.core.cost import (
    BYTES_FIRST,
    DEFAULT_OBJECTIVE,
    LATENCY_FIRST,
    Candidate,
    ScoredTarget,
    resolve_target,
    stack_cost,
    target_label,
)
from repro.obs.trace import NOOP_SPAN, TRACER


class _SnoopDict(dict):
    """Snapshot view recording which keys a rule predicate actually read.

    Only used while tracing is enabled: the controller span's
    ``predicates`` attribute carries exactly the metric values the armed
    rule's predicate consulted — the *why it fired* evidence — without
    dumping the whole snapshot into every span."""

    def __init__(self, base: dict):
        super().__init__(base)
        self.read = set()

    def get(self, key, default=None):
        self.read.add(key)
        return super().get(key, default)

    def __getitem__(self, key):
        self.read.add(key)
        return super().__getitem__(key)


def above(metric: str, threshold: float) -> Callable[[dict], bool]:
    """Predicate: snapshot[metric] is known and exceeds threshold."""
    return lambda s: s.get(metric) is not None and s[metric] > threshold


def below(metric: str, threshold: float) -> Callable[[dict], bool]:
    return lambda s: s.get(metric) is not None and s[metric] < threshold


def all_of(*preds: Callable[[dict], bool]) -> Callable[[dict], bool]:
    return lambda s: all(p(s) for p in preds)


def any_of(*preds: Callable[[dict], bool]) -> Callable[[dict], bool]:
    return lambda s: any(p(s) for p in preds)


@dataclass
class Rule:
    """One policy clause: when ``when(snapshot)`` has held for ``hold``
    consecutive ticks, propose switching to ``target``. Higher ``priority``
    wins when several rules are armed the same tick."""

    name: str
    when: Callable[[dict], bool]
    target: Any
    hold: int = 2
    priority: int = 0


@dataclass
class Decision:
    """One controller tick's outcome (appended to ``controller.decisions``)."""

    tick: int
    at: float
    rule: Optional[str]          # armed rule that was considered, if any
    target: Optional[str]        # its target's label
    fired: bool                  # switch() was invoked
    committed: bool              # switch() reported success
    reason: str                  # "switched" | "cooldown" | "refused" | "idle"
    snapshot: dict = field(repr=False, default_factory=dict)

    def to_json(self) -> dict:
        return {
            "tick": self.tick, "at": self.at, "rule": self.rule,
            "target": self.target, "fired": self.fired,
            "committed": self.committed, "reason": self.reason,
            "snapshot": self.snapshot,
        }


class ReconfigController:
    """Telemetry in, (damped) reconfigurations out.

    Args:
        rules: the policy, a sequence of ``Rule`` (names must be unique —
            build them by hand or through the policy registry via
            ``get_policy``/``conn_controller(policy=...)``).
        switch: ``switch(target) -> bool`` performs the transition and
            reports whether it committed. Dynamic (``ScoredTarget``) rule
            targets are resolved before this is called, so ``switch`` always
            receives a concrete target.
        current: ``current() -> str`` names the active configuration
            (compared against ``target_label`` so the controller never
            re-selects what is already running — which is also how a
            "recovered → default" rule stays quiet while the default is
            active).
        cooldown_s: minimum wall-clock gap after a committed switch before
            any rule may fire again.
        now: clock override for deterministic tests.
        max_history: bound on the retained ``decisions`` audit log. Lifetime
            totals survive eviction — read ``counts()`` for them; only the
            per-decision snapshots are windowed. (``max_decisions`` is the
            legacy spelling of the same knob.)

    Call ``tick(snapshot)`` once per control interval with a telemetry
    snapshot (``ConnTelemetry.snapshot()``); read ``decisions`` /
    ``switch_log()`` for the audit trail and ``counts()`` for lifetime
    totals.
    """

    def __init__(
        self,
        rules: Sequence[Rule],
        switch: Callable[[Any], bool],
        current: Callable[[], str],
        *,
        cooldown_s: float = 5.0,
        now: Callable[[], float] = time.monotonic,
        max_history: int = 4096,
        max_decisions: Optional[int] = None,
    ):
        names = [r.name for r in rules]
        if len(set(names)) != len(names):
            # duplicate names would silently share one hysteresis streak
            raise ValueError(f"duplicate rule names: {names}")
        self.rules: List[Rule] = sorted(rules, key=lambda r: -r.priority)
        self.switch = switch
        self.current = current
        self.cooldown_s = cooldown_s
        self._now = now
        self._streak: Dict[str, int] = {r.name: 0 for r in self.rules}
        self._last_switch_t: Optional[float] = None
        self._ticks = 0
        # bounded: a long-lived loop ticking every step must not grow memory
        # linearly in run length (each Decision retains a snapshot dict)
        if max_decisions is not None:   # legacy alias for max_history
            max_history = max_decisions
        self.decisions: Deque[Decision] = deque(maxlen=max_history)
        # lifetime totals: decisions fall off the deque, these never reset
        self.total_fired = 0
        self.total_committed = 0
        self.fired_by_rule: Dict[str, int] = {r.name: 0 for r in self.rules}

    def streak(self, rule_name: str) -> int:
        return self._streak[rule_name]

    def in_cooldown(self) -> bool:
        return (self._last_switch_t is not None
                and self._now() - self._last_switch_t < self.cooldown_s)

    def tick(self, snapshot: dict) -> Decision:
        """Evaluate every rule against ``snapshot``; fire at most one switch.

        The highest-priority armed rule CLAIMS the tick even when its target
        is already active: a satisfied high-priority rule must suppress
        lower-priority ones, or two persistently-armed rules with different
        targets would take turns re-arming each other (e.g. straggler ⇒
        localsgd and byte-budget ⇒ compressed flipping every ``hold`` ticks,
        each flip paying a renegotiation + re-jit).

        Dynamic targets (``ScoredTarget``) are resolved HERE, against this
        tick's snapshot and the active configuration — the Decision records
        the resolved target's label, and ``switch`` receives the resolved
        target."""
        self._ticks += 1
        now = self._now()
        cur = self.current()
        tracing = TRACER.enabled
        snap_view = _SnoopDict(snapshot) if tracing else snapshot
        armed: Optional[Rule] = None
        for r in self.rules:  # priority order; streaks advance for ALL rules
            if r.when(snap_view):
                self._streak[r.name] += 1
            else:
                self._streak[r.name] = 0
            if armed is None and self._streak[r.name] >= r.hold:
                armed = r
        # One span per ARMED tick (idle ticks are the steady state and would
        # drown the ring); it wraps resolve + switch so the 2PC/swap spans
        # nest under the controller decision that caused them.
        sp = NOOP_SPAN
        if tracing and armed is not None:
            sp = TRACER.span("controller.tick", attrs={
                "tick": self._ticks,
                "rule": armed.name,
                "streak": self._streak[armed.name],
                "current": cur,
                # why it fired: the metric values the predicates consulted
                "predicates": {k: snapshot.get(k)
                               for k in sorted(snap_view.read, key=str)},
            })
        with sp:
            target = label = None
            if armed is not None:
                target = resolve_target(armed.target, snapshot, cur)
                label = target_label(target)
                sp.set(target=label)
            if armed is None or label == cur:
                d = Decision(self._ticks, now,
                             armed.name if armed else None, label,
                             False, False, "idle", snapshot)
            elif self.in_cooldown():
                sp.set(reason="cooldown")
                d = Decision(self._ticks, now, armed.name, label,
                             False, False, "cooldown", snapshot)
            else:
                committed = bool(self.switch(target))
                if committed:
                    self._last_switch_t = now
                    for k in self._streak:  # re-arm from scratch after a transition
                        self._streak[k] = 0
                self.total_fired += 1
                self.total_committed += int(committed)
                self.fired_by_rule[armed.name] += 1
                d = Decision(self._ticks, now, armed.name, label,
                             True, committed, "switched" if committed else "refused",
                             snapshot)
            sp.set(reason=d.reason)
        self.decisions.append(d)
        return d

    def switch_log(self) -> List[Decision]:
        """Committed switches still in the retained ``decisions`` window —
        ``counts()["committed"]`` is the lifetime total."""
        return [d for d in self.decisions if d.fired and d.committed]

    def counts(self) -> dict:
        """Lifetime decision totals — preserved across ``max_history``
        eviction of the per-decision audit log."""
        return {"ticks": self._ticks, "fired": self.total_fired,
                "committed": self.total_committed,
                "by_rule": dict(self.fired_by_rule)}


# ---------------------------------------------------------------------------
# Policy plugin registry
# ---------------------------------------------------------------------------


@dataclass
class PolicyContext:
    """What a registered policy factory gets to work with.

    candidates  the negotiated option set as scoreable ``Candidate``s (target
                + cost model + label) — ScoredTargets draw from these
    default     the configuration to fall back to when a recovery clause
                applies (None disables recovery rules in the built-ins)
    params      free-form knobs forwarded from the caller
                (``conn_controller(policy_params=...)`` or
                ``make_controller(policy_params=...)``)
    """

    candidates: List[Candidate] = field(default_factory=list)
    default: Any = None
    params: Dict[str, Any] = field(default_factory=dict)

    def param(self, name: str, default: Any = None) -> Any:
        return self.params.get(name, default)

    def candidate_named(self, *names: str) -> Candidate:
        """First candidate whose label matches, or whose target (a
        ConcreteStack) contains a chunnel with any of the given names."""
        for c in self.candidates:
            if c.label in names:
                return c
            chs = getattr(c.target, "chunnels", None)
            if chs is not None and any(ch.name in names for ch in chs):
                return c
        raise KeyError(f"no candidate named {names}; have "
                       f"{[c.label for c in self.candidates]}")


#: name -> factory(PolicyContext) -> Sequence[Rule]
_POLICIES: Dict[str, Callable[[PolicyContext], Sequence[Rule]]] = {}


def register_policy(name: str, *, override: bool = False) -> Callable:
    """Class/function decorator registering a policy factory under ``name``.

    A policy factory takes a ``PolicyContext`` and returns the ``Rule`` list
    a controller should run — this is how applications ship cost-aware /
    SLO-aware / carbon-aware policies without editing core (ROADMAP).
    Re-registering an existing name raises unless ``override=True``.
    """
    def deco(fn: Callable[[PolicyContext], Sequence[Rule]]):
        if name in _POLICIES and not override:
            raise ValueError(
                f"policy {name!r} already registered "
                f"(pass override=True to replace it)")
        _POLICIES[name] = fn
        fn.policy_name = name
        return fn

    return deco


def get_policy(name: str) -> Callable[[PolicyContext], Sequence[Rule]]:
    try:
        return _POLICIES[name]
    except KeyError:
        raise KeyError(f"unknown policy {name!r}; registered: "
                       f"{available_policies()}") from None


def available_policies() -> List[str]:
    return sorted(_POLICIES)


def policy_rules(name: str, ctx: PolicyContext) -> List[Rule]:
    """Instantiate a registered policy's rules against a context."""
    return list(get_policy(name)(ctx))


# -- built-in policies: rules name objectives, not targets -------------------


@register_policy("cost_aware")
def cost_aware_policy(ctx: PolicyContext) -> List[Rule]:
    """Track the utility argmax of the whole candidate set continuously.

    params: objective (default balanced), margin (score-space hysteresis,
    default 0.1), hold, priority. The rule is always armed; damping comes
    from hold + margin + the blip term charged to non-current candidates.
    """
    tgt = ScoredTarget(ctx.candidates,
                       ctx.param("objective", DEFAULT_OBJECTIVE),
                       margin=ctx.param("margin", 0.1))
    return [Rule("cost_aware", lambda s: True, tgt,
                 hold=ctx.param("hold", 2), priority=ctx.param("priority", 0))]


@register_policy("latency_slo")
def latency_slo_policy(ctx: PolicyContext) -> List[Rule]:
    """SLO breach ⇒ the lowest-latency compatible option.

    params: slo_s (required), metric (default ``rtt_p95_s``), hold,
    recover_s (default slo_s/2), recover_hold, priority. With a
    ``ctx.default`` a recovery rule drops back once the metric clears
    recover_s."""
    slo = ctx.params["slo_s"]
    metric = ctx.param("metric", "rtt_p95_s")
    hold = ctx.param("hold", 2)
    rules = [Rule("latency_slo:breach", above(metric, slo),
                  ScoredTarget(ctx.candidates, LATENCY_FIRST),
                  hold=hold, priority=ctx.param("priority", 2))]
    if ctx.default is not None:
        rules.append(Rule("latency_slo:recovered",
                          below(metric, ctx.param("recover_s", slo / 2)),
                          ctx.default,
                          hold=ctx.param("recover_hold", 2 * hold), priority=0))
    return rules


@register_policy("byte_budget")
def byte_budget_policy(ctx: PolicyContext) -> List[Rule]:
    """Byte-rate over budget ⇒ the fewest-wire-bytes option.

    params: bytes_per_s (required), metric (default ``bytes_per_s``), hold,
    recover_frac (default 0.7: recovery arms below recover_frac * budget),
    recover_hold, priority."""
    budget = ctx.params["bytes_per_s"]
    metric = ctx.param("metric", "bytes_per_s")
    hold = ctx.param("hold", 2)
    rules = [Rule("byte_budget:over", above(metric, budget),
                  ScoredTarget(ctx.candidates, BYTES_FIRST),
                  hold=hold, priority=ctx.param("priority", 1))]
    if ctx.default is not None:
        rules.append(Rule("byte_budget:recovered",
                          below(metric, ctx.param("recover_frac", 0.7) * budget),
                          ctx.default,
                          hold=ctx.param("recover_hold", 2 * hold), priority=0))
    return rules


@register_policy("slo_guard")
def slo_guard_policy(ctx: PolicyContext) -> List[Rule]:
    """Error-budget burn (``repro.obs.slo``) ⇒ a safe stack, *before* any
    raw-threshold rule would fire.

    Reads the ``slo.<name>.*`` signals an ``SLOEngine`` exports (merge them
    into the controller's snapshot, or ``add_source`` the engine on a fleet
    aggregator): the breach clause arms when BOTH burn windows exceed their
    thresholds — exactly the engine's alarm condition, but evaluated inside
    the controller so hold/priority/cooldown damping applies uniformly.
    Burn-rate arming is the point: a budget burns the moment the metric
    crosses the *objective's* threshold, which sits well below any "the
    service is on fire" hard threshold, so the guard moves first.

    params: slo (required — the SLO's name), fast_burn/slow_burn (default
    14.4/6.0, match the engine's), safe_names (chunnel/candidate names to
    flip to; default: ScoredTarget over all candidates under ``objective``,
    default LATENCY_FIRST), hold (default 1 — the engine's windows already
    smooth), priority (default 3), recover_hold. With a ``ctx.default`` a
    recovery clause drops back once the engine clears the alarm.
    """
    name = ctx.params["slo"]
    fast_burn = ctx.param("fast_burn", 14.4)
    slow_burn = ctx.param("slow_burn", 6.0)
    safe_names = ctx.param("safe_names")
    if safe_names:
        target: Any = ctx.candidate_named(*safe_names).target
    else:
        target = ScoredTarget(ctx.candidates,
                              ctx.param("objective", LATENCY_FIRST))
    rules = [Rule(f"slo_guard:{name}:burn",
                  all_of(above(f"slo.{name}.burn_fast", fast_burn),
                         above(f"slo.{name}.burn_slow", slow_burn)),
                  target, hold=ctx.param("hold", 1),
                  priority=ctx.param("priority", 3))]
    if ctx.default is not None:
        rules.append(Rule(f"slo_guard:{name}:recovered",
                          below(f"slo.{name}.alarm", 0.5), ctx.default,
                          hold=ctx.param("recover_hold", 2), priority=0))
    return rules


# ---------------------------------------------------------------------------
# Plumbing helpers for the common planes
# ---------------------------------------------------------------------------


def option_named(stack, *names: str):
    """First of the negotiated Stack's options containing a chunnel with any
    of the given names — how policies name targets without holding object
    references into the stack tree."""
    for opt in stack.options():
        if any(c.name in names for c in opt.chunnels):
            return opt
    raise KeyError(f"no stack option contains a chunnel named {names}")


def stack_candidates(stack) -> List[Candidate]:
    """The negotiated ``Stack``'s options as scoreable candidates (targets
    are ConcreteStacks, labels their fingerprints, costs the folded chunnel
    cost models)."""
    return [Candidate(opt, stack_cost(opt)) for opt in stack.options()]


def conn_controller(
    handle,
    stack,
    rules: Optional[Sequence[Rule]] = None,
    *,
    policy: Optional[str] = None,
    policy_params: Optional[dict] = None,
    default=None,
    agent=None,
    peers: Sequence[str] = (),
    conn_id: str = "",
    **kw,
) -> ReconfigController:
    """Close the loop over a live ``ConnHandle`` whose targets come from the
    negotiated ``Stack``'s options.

    Pass EITHER an explicit ``rules`` list OR a registered ``policy`` name
    (with ``policy_params`` / ``default``) — the policy factory then receives
    the stack's options as scoreable candidates, so its rules can name
    objectives instead of concrete stacks.

    Unilateral targets swap locally; when an ``agent`` (plus peers/conn_id)
    is given, multilateral targets go through
    ``HostAgent.reconfigure_multilateral``'s 2PC. A multilateral target
    without an agent is refused at construction — a silent one-sided swap
    would be exactly the endpoint divergence negotiation exists to prevent."""
    if (rules is None) == (policy is None):
        raise ValueError("pass exactly one of rules= or policy=")
    if policy is not None:
        ctx = PolicyContext(candidates=stack_candidates(stack),
                            default=default, params=dict(policy_params or {}))
        rules = policy_rules(policy, ctx)
    if agent is None:
        for r in rules:
            m = getattr(r.target, "multilateral", None)
            if callable(m) and m():
                raise ValueError(
                    f"rule {r.name!r} targets a multilateral stack; pass "
                    f"agent/peers/conn_id so the switch runs the 2PC")

    def switch(target) -> bool:
        if agent is not None and target.multilateral():
            return agent.reconfigure_multilateral(handle, target, list(peers), conn_id)
        return handle.reconfigure(target)

    return ReconfigController(
        rules, switch, lambda: handle.stack.fingerprint(), **kw)
