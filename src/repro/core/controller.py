"""Policy engine closing Bertha's reconfiguration loop.

The paper's pitch is that the stack changes at runtime in response to "where
applications run, the requests they serve, and the performance they need" —
the mechanisms (negotiate, 2PC, LockedConn/BarrierConn swap) live in their
own modules; this is the *policy* that drives them (cf. Morpheus-style
profile-guided re-specialization, PAPERS.md).

A ``ReconfigController`` maps a telemetry snapshot (``repro.core.telemetry``)
to a target configuration — typically a ``ConcreteStack`` drawn from the
negotiated ``Stack``'s options — and drives the switch mechanism:
``ConnHandle.reconfigure`` for unilateral swaps,
``HostAgent.reconfigure_multilateral`` (2PC) for multilateral ones, or a
trainer's rendezvous transition. Two dampers prevent flapping:

  hysteresis  a rule's predicate must hold for ``hold`` consecutive ticks
              before the rule may fire
  cooldown    after a committed switch no rule may fire for ``cooldown_s``

Every tick appends a ``Decision`` (fired or not, with the snapshot that
motivated it) to ``controller.decisions`` — the audit log the benchmarks emit
as JSON.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, Dict, List, Optional, Sequence


def target_label(target: Any) -> str:
    """Stable identity of a switch target: a ConcreteStack's fingerprint, or
    str() for plain labels (e.g. trainer transport names)."""
    fp = getattr(target, "fingerprint", None)
    return fp() if callable(fp) else str(target)


def above(metric: str, threshold: float) -> Callable[[dict], bool]:
    """Predicate: snapshot[metric] is known and exceeds threshold."""
    return lambda s: s.get(metric) is not None and s[metric] > threshold


def below(metric: str, threshold: float) -> Callable[[dict], bool]:
    return lambda s: s.get(metric) is not None and s[metric] < threshold


def all_of(*preds: Callable[[dict], bool]) -> Callable[[dict], bool]:
    return lambda s: all(p(s) for p in preds)


def any_of(*preds: Callable[[dict], bool]) -> Callable[[dict], bool]:
    return lambda s: any(p(s) for p in preds)


@dataclass
class Rule:
    """One policy clause: when ``when(snapshot)`` has held for ``hold``
    consecutive ticks, propose switching to ``target``. Higher ``priority``
    wins when several rules are armed the same tick."""

    name: str
    when: Callable[[dict], bool]
    target: Any
    hold: int = 2
    priority: int = 0


@dataclass
class Decision:
    """One controller tick's outcome (appended to ``controller.decisions``)."""

    tick: int
    at: float
    rule: Optional[str]          # armed rule that was considered, if any
    target: Optional[str]        # its target's label
    fired: bool                  # switch() was invoked
    committed: bool              # switch() reported success
    reason: str                  # "switched" | "cooldown" | "refused" | "idle"
    snapshot: dict = field(repr=False, default_factory=dict)

    def to_json(self) -> dict:
        return {
            "tick": self.tick, "at": self.at, "rule": self.rule,
            "target": self.target, "fired": self.fired,
            "committed": self.committed, "reason": self.reason,
            "snapshot": self.snapshot,
        }


class ReconfigController:
    """Telemetry in, (damped) reconfigurations out.

    ``switch(target) -> bool`` performs the transition and reports whether it
    committed; ``current() -> str`` names the active configuration (compared
    against ``target_label`` so the controller never re-selects what is
    already running — which is also how a "recovered → default" rule stays
    quiet while the default is active).
    """

    def __init__(
        self,
        rules: Sequence[Rule],
        switch: Callable[[Any], bool],
        current: Callable[[], str],
        *,
        cooldown_s: float = 5.0,
        now: Callable[[], float] = time.monotonic,
        max_decisions: int = 4096,
    ):
        names = [r.name for r in rules]
        if len(set(names)) != len(names):
            # duplicate names would silently share one hysteresis streak
            raise ValueError(f"duplicate rule names: {names}")
        self.rules: List[Rule] = sorted(rules, key=lambda r: -r.priority)
        self.switch = switch
        self.current = current
        self.cooldown_s = cooldown_s
        self._now = now
        self._streak: Dict[str, int] = {r.name: 0 for r in self.rules}
        self._last_switch_t: Optional[float] = None
        self._ticks = 0
        # bounded: a long-lived loop ticking every step must not grow memory
        # linearly in run length (each Decision retains a snapshot dict)
        self.decisions: Deque[Decision] = deque(maxlen=max_decisions)

    def streak(self, rule_name: str) -> int:
        return self._streak[rule_name]

    def in_cooldown(self) -> bool:
        return (self._last_switch_t is not None
                and self._now() - self._last_switch_t < self.cooldown_s)

    def tick(self, snapshot: dict) -> Decision:
        """Evaluate every rule against ``snapshot``; fire at most one switch.

        The highest-priority armed rule CLAIMS the tick even when its target
        is already active: a satisfied high-priority rule must suppress
        lower-priority ones, or two persistently-armed rules with different
        targets would take turns re-arming each other (e.g. straggler ⇒
        localsgd and byte-budget ⇒ compressed flipping every ``hold`` ticks,
        each flip paying a renegotiation + re-jit)."""
        self._ticks += 1
        now = self._now()
        cur = self.current()
        armed: Optional[Rule] = None
        for r in self.rules:  # priority order; streaks advance for ALL rules
            if r.when(snapshot):
                self._streak[r.name] += 1
            else:
                self._streak[r.name] = 0
            if armed is None and self._streak[r.name] >= r.hold:
                armed = r
        if armed is None or target_label(armed.target) == cur:
            d = Decision(self._ticks, now,
                         armed.name if armed else None,
                         target_label(armed.target) if armed else None,
                         False, False, "idle", snapshot)
        elif self.in_cooldown():
            d = Decision(self._ticks, now, armed.name, target_label(armed.target),
                         False, False, "cooldown", snapshot)
        else:
            committed = bool(self.switch(armed.target))
            if committed:
                self._last_switch_t = now
                for k in self._streak:  # re-arm from scratch after a transition
                    self._streak[k] = 0
            d = Decision(self._ticks, now, armed.name, target_label(armed.target),
                         True, committed, "switched" if committed else "refused",
                         snapshot)
        self.decisions.append(d)
        return d

    def switch_log(self) -> List[Decision]:
        return [d for d in self.decisions if d.fired and d.committed]


# ---------------------------------------------------------------------------
# Plumbing helpers for the common planes
# ---------------------------------------------------------------------------


def option_named(stack, *names: str):
    """First of the negotiated Stack's options containing a chunnel with any
    of the given names — how policies name targets without holding object
    references into the stack tree."""
    for opt in stack.options():
        if any(c.name in names for c in opt.chunnels):
            return opt
    raise KeyError(f"no stack option contains a chunnel named {names}")


def conn_controller(
    handle,
    stack,
    rules: Sequence[Rule],
    *,
    agent=None,
    peers: Sequence[str] = (),
    conn_id: str = "",
    **kw,
) -> ReconfigController:
    """Close the loop over a live ``ConnHandle`` whose targets come from the
    negotiated ``Stack``'s options. Unilateral targets swap locally; when an
    ``agent`` (plus peers/conn_id) is given, multilateral targets go through
    ``HostAgent.reconfigure_multilateral``'s 2PC. A multilateral target
    without an agent is refused at construction — a silent one-sided swap
    would be exactly the endpoint divergence negotiation exists to prevent."""
    if agent is None:
        for r in rules:
            m = getattr(r.target, "multilateral", None)
            if callable(m) and m():
                raise ValueError(
                    f"rule {r.name!r} targets a multilateral stack; pass "
                    f"agent/peers/conn_id so the switch runs the 2PC")

    def switch(target) -> bool:
        if agent is not None and target.multilateral():
            return agent.reconfigure_multilateral(handle, target, list(peers), conn_id)
        return handle.reconfigure(target)

    return ReconfigController(
        rules, switch, lambda: handle.stack.fingerprint(), **kw)
