"""Train/serve step builders with the Bertha seam.

The gradient path is:  value_and_grad  ->  [grad chunnel stack]  ->  AdamW.

With the paper-faithful 'xla' transport the step is a plain jit function and
XLA schedules every collective (the 'kernel networking' default). Any other
transport takes MANUAL control of its mesh axes (usually the pod/DCN tier) by
wrapping the whole step in a partial-auto shard_map: inside, the batch is the
pod-local shard, XLA still auto-partitions data/model, and the chunnel stack
explicitly places the cross-pod collectives. Reconfiguring the transport
re-traces the step with a different stack — state (params/opt/EF-residuals)
carries over, connections (the mesh) do not re-establish (paper req. #4).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P
from repro import compat

from repro.comm.chunnels import (
    StepChunnel,
    apply_grad_stack,
    init_grad_states,
    stack_manual_axes,
)
from repro.configs.base import ModelConfig, ShardingConfig, TrainConfig
from repro.models.registry import Model
from repro.models.sharding import data_spec
from repro.optim import adamw


class TrainState(NamedTuple):
    params: Any
    opt: adamw.AdamWState
    comm: Any  # chunnel states (EF residuals, localsgd counters, ...)
    step: jnp.ndarray


def init_state(model: Model, rng, tcfg: TrainConfig = TrainConfig()) -> TrainState:
    params = model.init(rng)
    return TrainState(
        params=params,
        opt=adamw.init(params, jnp.dtype(tcfg.opt_dtype)),
        comm=(),
        step=jnp.zeros((), jnp.int32),
    )


def state_shapes(model: Model, grad_chunnels: Sequence[StepChunnel],
                 tcfg: TrainConfig = TrainConfig()) -> TrainState:
    p = model.param_shapes()
    return TrainState(
        params=p,
        opt=adamw.init_shape(p, jnp.dtype(tcfg.opt_dtype)),
        comm=init_grad_states(grad_chunnels, p),
        step=jax.ShapeDtypeStruct((), jnp.int32),
    )


def make_train_step(
    model: Model,
    tcfg: TrainConfig,
    grad_chunnels: Sequence[StepChunnel],
    mesh,
) -> Callable:
    """Returns step(state, batch) -> (state, metrics)."""
    lr_fn = adamw.lr_schedule(tcfg)
    manual = stack_manual_axes(grad_chunnels) & set(mesh.axis_names)
    ctx = {"mesh": mesh}

    def grads_of(params, batch):
        if tcfg.microbatches <= 1:
            return jax.value_and_grad(model.loss)(params, batch)
        # gradient accumulation: scan over microbatch splits of the batch's
        # leading dim; activation live-set shrinks by the microbatch factor
        n = tcfg.microbatches

        def split(x):
            return x.reshape((n, x.shape[0] // n) + x.shape[1:])

        mb = jax.tree.map(split, batch)

        def acc_body(carry, mb_i):
            loss_acc, g_acc = carry
            l, g = jax.value_and_grad(model.loss)(params, mb_i)
            g_acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32) / n, g_acc, g)
            return (loss_acc + l / n, g_acc), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, grads), _ = jax.lax.scan(acc_body, (jnp.zeros((), jnp.float32), zeros), mb)
        return loss, grads

    def core(params, opt, comm, step, batch, pod_scale):
        loss, grads = grads_of(params, batch)
        grads = jax.tree.map(lambda g: g * pod_scale, grads)
        grads, comm = apply_grad_stack(grad_chunnels, grads, comm, ctx)
        params, opt, metrics = adamw.update(grads, opt, params, lr_fn(step), tcfg)
        return params, opt, comm, loss, metrics

    if not manual:

        def step_fn(state: TrainState, batch) -> tuple:
            params, opt, comm, loss, metrics = core(
                state.params, state.opt, state.comm, state.step, batch, 1.0)
            return (
                TrainState(params, opt, comm, state.step + 1),
                {"loss": loss, **metrics},
            )

        return step_fn

    n_manual = 1
    for a in manual:
        n_manual *= mesh.shape[a]

    def step_fn(state: TrainState, batch) -> tuple:
        # XLA-CPU workaround (see moe_ffn): bf16 operands crossing a
        # partial-manual shard_map boundary crash the CPU backend under grad.
        # Cross in f32 and restore the original dtypes at both edges.
        opt_dtypes = jax.tree.map(lambda a: a.dtype, state.opt)

        def widen(tree):
            return jax.tree.map(
                lambda a: a.astype(jnp.float32) if a.dtype == jnp.bfloat16 else a, tree)

        def narrow(tree, dtypes):
            return jax.tree.map(lambda a, d: a.astype(d), tree, dtypes)

        def inner(params, opt, comm, step, batch_local):
            # batch_local is this pod's shard; grads averaged across `manual`
            # axes by the transport chunnel itself (each applies 1/n or pmean).
            opt_n = narrow(opt, opt_dtypes)
            params, opt_n, comm, loss, metrics = core(
                params, opt_n, comm, step, batch_local, 1.0)
            loss = sum(jax.lax.pmean(loss, a) for a in manual) / len(manual)
            metrics = {k: sum(jax.lax.pmean(v, a) for a in manual) / len(manual)
                       for k, v in metrics.items()}
            return params, widen(opt_n), comm, loss, metrics

        batch_specs = jax.tree.map(lambda _: P(*(tuple(manual),)), batch)
        rep = lambda tree: jax.tree.map(lambda _: P(), tree)
        f = compat.shard_map(
            inner,
            mesh=mesh,
            in_specs=(rep(state.params), rep(state.opt), rep(state.comm), P(),
                      batch_specs),
            out_specs=(rep(state.params), rep(state.opt), rep(state.comm), P(), P()),
            check_vma=False,
            axis_names=manual,
        )
        params, opt, comm, loss, metrics = f(
            state.params, widen(state.opt), state.comm, state.step, batch)
        return TrainState(params, narrow(opt, opt_dtypes), comm, state.step + 1), \
            {"loss": loss, **metrics}

    return step_fn


# ---------------------------------------------------------------------------
# jit wrappers with production shardings
# ---------------------------------------------------------------------------


def _zero1_pod(spec: P, shape, mesh) -> P:
    """ZeRO-1 over the pod axis: optimizer moments additionally shard their
    FSDP ('data') dim over 'pod'. Params stay pod-replicated; the update's
    pod all-gather is the standard ZeRO-1 cost."""
    if "pod" not in mesh.axis_names:
        return spec
    pod = mesh.shape["pod"]
    data = mesh.shape.get("data", 1)
    out = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if ax == "data" and dim % (data * pod) == 0:
            out.append(("data", "pod"))
        else:
            out.append(ax)
    return P(*out)


def shardings_for(model: Model, mesh, sh: ShardingConfig, grad_chunnels=()):
    """(state_shardings, batch_sharding_fn) for jit in/out_shardings."""
    pspecs = model.param_specs(sh)
    pshapes = model.param_shapes()
    ns = lambda spec: NamedSharding(mesh, spec)
    param_sh = jax.tree.map(ns, pspecs)
    mom_sh = jax.tree.map(
        lambda spec, shp: ns(_zero1_pod(spec, shp.shape, mesh)), pspecs, pshapes)
    opt_sh = adamw.AdamWState(m=mom_sh, v=mom_sh,
                              count=ns(P()))
    comm_shapes = init_grad_states(grad_chunnels, model.param_shapes())
    comm_sh = jax.tree.map(
        lambda leaf: ns(P()), comm_shapes,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    # EF residuals share the param tree structure -> reuse param specs
    comm_sh = []
    for ch, st in zip(grad_chunnels, comm_shapes):
        if st == ():
            comm_sh.append(())
        elif isinstance(st, dict) and "step" in st:
            comm_sh.append(jax.tree.map(lambda _: ns(P()), st))
        else:
            comm_sh.append(param_sh)
    state_sh = TrainState(params=param_sh, opt=opt_sh, comm=tuple(comm_sh), step=ns(P()))

    def batch_sharding(batch_specs: dict):
        return {
            k: ns(data_spec(v.shape, mesh)) for k, v in batch_specs.items()
        }

    return state_sh, batch_sharding


def jit_train_step(model, tcfg, grad_chunnels, mesh, sh: ShardingConfig,
                   batch_specs: dict, donate: bool = True):
    step_fn = make_train_step(model, tcfg, grad_chunnels, mesh)
    state_sh, batch_sh_fn = shardings_for(model, mesh, sh, grad_chunnels)
    metrics_sh = None  # let XLA pick (scalars)
    return jax.jit(
        step_fn,
        in_shardings=(state_sh, batch_sh_fn(batch_specs)),
        out_shardings=(state_sh, metrics_sh),
        donate_argnums=(0,) if donate else (),
    )
