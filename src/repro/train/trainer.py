"""Reconfigurable trainer: the Bertha runtime driving the JAX step.

One HostAgent per participating host negotiates the step stack (gradient
transport + MoE dispatch + KV partitioning chunnels) through the rendezvous
store before compiling — guaranteeing every host lowers the identical SPMD
program. The trainer then runs the jitted step, and can RECONFIGURE between
steps without losing state:

  * params/optimizer state carry over (they live outside the chunnels),
  * chunnel state is migrated (e.g. error-feedback residuals are re-zeroed
    when the wire format changes — the paper's state-translation step),
  * the switch point is the step boundary (data plane is single-threaded per
    host here; the lock/barrier mechanisms are exercised by the §8.3 bench).

Fault tolerance:
  * periodic + async checkpoints (atomic, resharding restore),
  * heartbeat monitor: hosts report step times; persistent stragglers trigger
    a negotiated transition to a DCN-lighter transport (compressed / localsgd)
    — reconfiguration as *mitigation*, the paper's core pitch,
  * elastic restart: on membership change, re-negotiate via rendezvous, then
    restore the latest checkpoint onto the new mesh.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.checkpoint.ckpt import Checkpointer
from repro.comm.chunnels import StepChunnel, init_grad_states, make_transport
from repro.configs.base import ModelConfig, ShapeConfig, ShardingConfig, TrainConfig
from repro.core import KVStore, Stack, make_stack
from repro.core.stack import ConcreteStack
from repro.core import rendezvous
from repro.models.registry import Model, build
from repro.train import step as step_mod


@dataclass
class HostSpec:
    host_id: int
    offers: List[str]  # transport names this host supports, in preference order


@dataclass
class StragglerPolicy:
    window: int = 16
    slow_factor: float = 1.5
    fallback: str = "compressed_int8"  # negotiated transition target


class ReconfigurableTrainer:
    def __init__(
        self,
        cfg: ModelConfig,
        shape: ShapeConfig,
        mesh,
        *,
        tcfg: TrainConfig = TrainConfig(),
        sharding: ShardingConfig = ShardingConfig(),
        transport: str = "xla",
        ckpt_dir: Optional[str] = None,
        store: Optional[KVStore] = None,
        hosts: Optional[Sequence[HostSpec]] = None,
        conn_id: str = "trainjob",
    ):
        self.cfg = cfg
        self.shape = shape
        self.mesh = mesh
        self.tcfg = tcfg
        self.sharding = sharding
        self.store = store or KVStore()
        self.conn_id = conn_id
        self.hosts = list(hosts or [HostSpec(0, [transport])])
        self.transport_name = self._negotiate_transport()
        self.model = build(cfg, mesh=mesh)
        self.ckpt = Checkpointer(ckpt_dir) if ckpt_dir else None
        self.step_times: List[float] = []
        self.reconfig_log: List[dict] = []
        self._build_step()

    # -- negotiation (multi-party, rendezvous §5.3) ----------------------------
    def _transport_chunnels(self, name: str) -> tuple:
        if name == "xla" or "pod" not in self.mesh.axis_names:
            return ()
        kw = ({"fast_axis": "data", "slow_axis": "pod"}
              if name in ("hierarchical", "hier_compressed") else {"axis": "pod"})
        return (make_transport(name, **kw),)

    def _negotiate_transport(self) -> str:
        chosen = None
        for h in self.hosts:
            descs = [[{"name": t, "caps": [{"label": f"transport:{t}", "mode": "exact"}],
                       "upper": "grads", "lower": "unit", "multilateral": True}]
                     for t in h.offers]

            def compat(committed_desc, h=h):
                names = {c["name"] for c in committed_desc}
                for i, t in enumerate(h.offers):
                    if t in names:
                        return i
                return None

            member = f"host{h.host_id}"
            try:
                res = rendezvous.join(self.store, self.conn_id, member,
                                      h.offers, descs, compat)
                chosen = res.stack_desc[0]["name"]
            except ValueError:
                # §5.3: an incompatible joiner proposes a transition to a stack
                # it supports; existing members vote (accept iff they offer it)
                committed = False
                for idx, target in enumerate(h.offers):
                    epoch = rendezvous.propose_transition(
                        self.store, self.conn_id, member, target, descs[idx])
                    members = self.store.get(f"{self.conn_id}/members") or {}
                    for m in members:
                        voter = next((x for x in self.hosts
                                      if f"host{x.host_id}" == m), None)
                        ok = voter is not None and target in voter.offers
                        rendezvous.vote(self.store, self.conn_id, m, epoch, ok)
                    rendezvous.vote(self.store, self.conn_id, member, epoch, True)
                    # proposer must be a member for commit accounting
                    if rendezvous.try_commit(self.store, self.conn_id, epoch, 5.0):
                        committed = True
                        res = rendezvous.join(self.store, self.conn_id, member,
                                              h.offers, descs, compat)
                        chosen = res.stack_fp
                        break
                if not committed:
                    raise
        return chosen or "xla"

    # -- step construction -------------------------------------------------------
    def _build_step(self) -> None:
        self.chunnels = self._transport_chunnels(self.transport_name)
        self.jitted = step_mod.jit_train_step(
            self.model, self.tcfg, self.chunnels, self.mesh, self.sharding,
            self.model.batch_specs(self.shape), donate=False)
        self.state_sh, _ = step_mod.shardings_for(
            self.model, self.mesh, self.sharding, self.chunnels)

    def init_state(self, rng) -> step_mod.TrainState:
        st = step_mod.init_state(self.model, rng, self.tcfg)
        comm = init_grad_states(self.chunnels, self.model.param_shapes())
        comm = jax.tree.map(
            lambda s: jax.numpy.zeros(s.shape, s.dtype) if hasattr(s, "shape") else s,
            comm,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        st = st._replace(comm=comm)
        # place the state on the mesh with the step's shardings
        return jax.tree.map(jax.device_put, st, self.state_sh)

    # -- training loop --------------------------------------------------------------
    def run(self, state, batches: Callable[[int], dict], num_steps: int,
            *, ckpt_every: int = 0, straggler: Optional[StragglerPolicy] = None,
            inject_slow: Optional[Callable[[int], float]] = None) -> tuple:
        metrics_hist = []
        for i in range(num_steps):
            step_idx = int(state.step)
            batch = {k: jax.numpy.asarray(v) for k, v in batches(step_idx).items()}
            t0 = time.perf_counter()
            state, metrics = self.jitted(state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            if inject_slow is not None:
                extra = inject_slow(step_idx)
                if extra > 0:
                    time.sleep(extra)
                    dt += extra
            self.step_times.append(dt)
            metrics_hist.append({k: float(v) for k, v in metrics.items()})
            if ckpt_every and self.ckpt and (step_idx + 1) % ckpt_every == 0:
                self.ckpt.save(step_idx + 1, state, asynchronous=True)
            if straggler is not None:
                state = self._maybe_mitigate(state, straggler)
        if self.ckpt:
            self.ckpt.wait()
        return state, metrics_hist

    # -- straggler mitigation via reconfiguration -----------------------------------
    def _maybe_mitigate(self, state, pol: StragglerPolicy):
        if self.transport_name == pol.fallback or len(self.step_times) < 2 * pol.window:
            return state
        recent = np.median(self.step_times[-pol.window:])
        base = np.median(self.step_times[: pol.window])
        if recent > pol.slow_factor * base:
            state = self.reconfigure(state, pol.fallback)
        return state

    def reconfigure(self, state, new_transport: str):
        """Negotiated transition (2PC via rendezvous) + state migration + re-jit."""
        desc = [{"name": new_transport,
                 "caps": [{"label": f"transport:{new_transport}", "mode": "exact"}],
                 "upper": "grads", "lower": "unit", "multilateral": True}]
        epoch = rendezvous.propose_transition(
            self.store, self.conn_id, "host0", new_transport, desc)
        for h in self.hosts:  # every host votes (here: all accept if they offer it)
            ok = new_transport in h.offers or h.host_id == 0
            rendezvous.vote(self.store, self.conn_id, f"host{h.host_id}", epoch, ok)
        committed = rendezvous.try_commit(self.store, self.conn_id, epoch, timeout_s=5.0)
        if not committed:
            self.reconfig_log.append({"to": new_transport, "committed": False})
            return state
        old = self.transport_name
        self.transport_name = new_transport
        self._build_step()
        # state migration: grads/opt carry over; chunnel state re-initialized
        # for the new wire format (EF residuals cannot survive a format change)
        comm = init_grad_states(self.chunnels, self.model.param_shapes())
        comm = jax.tree.map(
            lambda s: jax.numpy.zeros(s.shape, s.dtype) if hasattr(s, "shape") else s,
            comm, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        state = state._replace(comm=comm)
        state = jax.tree.map(jax.device_put, state, self.state_sh)
        self.reconfig_log.append({"from": old, "to": new_transport, "committed": True,
                                  "at_step": int(state.step)})
        return state

    # -- checkpoint/restart -----------------------------------------------------------
    def save(self, state, step: Optional[int] = None):
        assert self.ckpt is not None
        self.ckpt.save(step if step is not None else int(state.step), state)

    def restore(self, like=None):
        assert self.ckpt is not None
        like = like if like is not None else step_mod.state_shapes(self.model, self.chunnels)
        return self.ckpt.restore(like)
