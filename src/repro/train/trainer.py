"""Reconfigurable trainer: the Bertha runtime driving the JAX step.

One HostAgent per participating host negotiates the step stack (gradient
transport + MoE dispatch + KV partitioning chunnels) through the rendezvous
store before compiling — guaranteeing every host lowers the identical SPMD
program. The trainer then runs the jitted step, and can RECONFIGURE between
steps without losing state:

  * params/optimizer state carry over (they live outside the chunnels),
  * chunnel state is migrated (e.g. error-feedback residuals are re-zeroed
    when the wire format changes — the paper's state-translation step),
  * the switch point is the step boundary (data plane is single-threaded per
    host here; the lock/barrier mechanisms are exercised by the §8.3 bench).

Fault tolerance:
  * periodic + async checkpoints (atomic, resharding restore),
  * heartbeat monitor: hosts report step times; persistent stragglers trigger
    a negotiated transition to a DCN-lighter transport (compressed / localsgd)
    — reconfiguration as *mitigation*, the paper's core pitch,
  * elastic restart: on membership change, re-negotiate via rendezvous, then
    restore the latest checkpoint onto the new mesh.

Closed loop: the trainer feeds a ConnTelemetry (per-pod step times from the
heartbeat plane, estimated DCN bytes per step) and ``make_controller()``
builds a ReconfigController from a REGISTERED policy (default
``trainer_default``: straggler ratio ⇒ localsgd, DCN-byte budget ⇒ lighter
wire format, recovery ⇒ back to the default — with hysteresis and cooldown so
the loop cannot flap). The negotiated transport option set is exposed as
scoreable candidates (``transport_candidates``) so policies can name
objectives instead of transports. Pass the controller to ``run()``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.checkpoint.ckpt import Checkpointer
from repro.comm.chunnels import (
    TRANSPORTS,
    StepChunnel,
    calibrate_cost_models,
    init_grad_states,
    make_transport,
)
from repro.configs.base import ModelConfig, ShapeConfig, ShardingConfig, TrainConfig
from repro.core import KVStore, Stack, make_stack
from repro.core.controller import (
    PolicyContext,
    ReconfigController,
    Rule,
    above,
    policy_rules,
    register_policy,
)
from repro.core.cost import BYTES_FIRST, Candidate, CostModel, ScoredTarget, chunnel_cost
from repro.core.stack import ConcreteStack
from repro.core.telemetry import ConnTelemetry
from repro.core import rendezvous
from repro.models.registry import Model, build
from repro.train import step as step_mod


@dataclass
class HostSpec:
    host_id: int
    offers: List[str]  # transport names this host supports, in preference order


@dataclass
class StragglerPolicy:
    window: int = 16
    slow_factor: float = 1.5
    fallback: str = "compressed_int8"  # negotiated transition target


@register_policy("trainer_default")
def trainer_default_policy(ctx: PolicyContext) -> List[Rule]:
    """The trainer's standard closed-loop policy, shipped through the plugin
    registry (applications register policies; core never hard-codes them):

      straggler_ratio > threshold   ⇒ ``mitigation`` (sync less often)
      f32 DCN rate    > byte budget ⇒ lighter wire format — an explicit
                                      ``budget_target``, or (when None) the
                                      fewest-DCN-bytes option scored over the
                                      negotiated transport candidates
      both signals healthy          ⇒ back to ``ctx.default``

    The budget/recovery rules read ``dcn_bytes_per_s_f32`` (what the DEFAULT
    transport WOULD cost right now) rather than the live byte rate, so
    committing a lighter wire format does not instantly disarm the very rule
    that selected it (a flap source hysteresis alone cannot fix).
    """
    p = ctx.params
    straggler_threshold = p.get("straggler_threshold", 1.5)
    recover_threshold = p.get("recover_threshold", 1.15)
    budget = p.get("dcn_budget_bytes_per_s")
    mitigation = p.get("mitigation", "localsgd")
    budget_target = p.get("budget_target", "compressed_int8")
    hold = p.get("hold", 2)
    recover_hold = p.get("recover_hold")
    default = ctx.default

    def recovered(s: dict) -> bool:
        if s.get("straggler_ratio", 1.0) >= recover_threshold:
            return False
        if budget is not None and s.get("dcn_bytes_per_s_f32", 0.0) > budget:
            return False
        return True

    rules = [
        Rule("straggler->mitigation", above("straggler_ratio", straggler_threshold),
             mitigation, hold=hold, priority=2),
    ]
    if budget is not None:
        if budget_target is not None:
            tgt = budget_target
        else:
            # scored argmin-DCN-bytes — but never the mitigation transport:
            # cost models only cover communication cost, and localsgd-style
            # mitigations win that contest by changing training semantics
            # (gradient staleness), which only the straggler rule may buy
            sync = [c for c in ctx.candidates if c.label != mitigation]
            tgt = ScoredTarget(sync or ctx.candidates, BYTES_FIRST)
        rules.append(
            Rule("dcn-budget->compressed", above("dcn_bytes_per_s_f32", budget),
                 tgt, hold=hold, priority=1))
    rules.append(
        Rule("recovered->default", recovered, default,
             hold=recover_hold if recover_hold is not None else 2 * hold,
             priority=0))
    return rules


class ReconfigurableTrainer:
    def __init__(
        self,
        cfg: ModelConfig,
        shape: ShapeConfig,
        mesh,
        *,
        tcfg: TrainConfig = TrainConfig(),
        sharding: ShardingConfig = ShardingConfig(),
        transport: str = "xla",
        ckpt_dir: Optional[str] = None,
        store: Optional[KVStore] = None,
        hosts: Optional[Sequence[HostSpec]] = None,
        conn_id: str = "trainjob",
    ):
        self.cfg = cfg
        self.shape = shape
        self.mesh = mesh
        self.tcfg = tcfg
        self.sharding = sharding
        self.store = store or KVStore()
        self.conn_id = conn_id
        self.hosts = list(hosts or [HostSpec(0, [transport])])
        self.transport_name = self._negotiate_transport()
        self.model = build(cfg, mesh=mesh)
        self.ckpt = Checkpointer(ckpt_dir) if ckpt_dir else None
        self.step_times: List[float] = []
        self.reconfig_log: List[dict] = []
        self.telemetry = ConnTelemetry()
        self._param_bytes = 4 * sum(  # f32 gradient bytes per full sync
            int(np.prod(s.shape)) for s in jax.tree.leaves(self.model.param_shapes()))
        self._live_state = None  # current TrainState while a controller drives run()
        self._fleet_pub = None   # optional fleet signal plane (attach_fleet)
        # mesh-aware cost models (ROADMAP): transport cost annotations divide
        # DCN bytes by the LIVE fast-axis width, not the NOMINAL_FAST guess
        calibrate_cost_models(mesh=mesh, fast_axis="data")
        self._build_step()

    # -- negotiation (multi-party, rendezvous §5.3) ----------------------------
    def _transport_chunnels(self, name: str) -> tuple:
        if name == "xla" or "pod" not in self.mesh.axis_names:
            return ()
        kw = ({"fast_axis": "data", "slow_axis": "pod"}
              if name in ("hierarchical", "hier_compressed") else {"axis": "pod"})
        return (make_transport(name, **kw),)

    def _negotiate_transport(self) -> str:
        chosen = None
        for h in self.hosts:
            descs = [[{"name": t, "caps": [{"label": f"transport:{t}", "mode": "exact"}],
                       "upper": "grads", "lower": "unit", "multilateral": True}]
                     for t in h.offers]

            def compat(committed_desc, h=h):
                names = {c["name"] for c in committed_desc}
                for i, t in enumerate(h.offers):
                    if t in names:
                        return i
                return None

            member = f"host{h.host_id}"
            try:
                res = rendezvous.join(self.store, self.conn_id, member,
                                      h.offers, descs, compat)
                chosen = res.stack_desc[0]["name"]
            except ValueError:
                # §5.3: an incompatible joiner proposes a transition to a stack
                # it supports; existing members vote (accept iff they offer it)
                committed = False
                for idx, target in enumerate(h.offers):
                    epoch = rendezvous.propose_transition(
                        self.store, self.conn_id, member, target, descs[idx])
                    members = self.store.get(f"{self.conn_id}/members") or {}
                    for m in members:
                        voter = next((x for x in self.hosts
                                      if f"host{x.host_id}" == m), None)
                        ok = voter is not None and target in voter.offers
                        rendezvous.vote(self.store, self.conn_id, m, epoch, ok)
                    rendezvous.vote(self.store, self.conn_id, member, epoch, True)
                    # proposer must be a member for commit accounting
                    if rendezvous.try_commit(self.store, self.conn_id, epoch, 5.0):
                        committed = True
                        res = rendezvous.join(self.store, self.conn_id, member,
                                              h.offers, descs, compat)
                        chosen = res.stack_fp
                        break
                if not committed:
                    raise
        return chosen or "xla"

    # -- step construction -------------------------------------------------------
    def _build_step(self) -> None:
        self.chunnels = self._transport_chunnels(self.transport_name)
        self.jitted = step_mod.jit_train_step(
            self.model, self.tcfg, self.chunnels, self.mesh, self.sharding,
            self.model.batch_specs(self.shape), donate=False)
        self.state_sh, _ = step_mod.shardings_for(
            self.model, self.mesh, self.sharding, self.chunnels)
        # The next step pays (re)compilation: that blip is reconfiguration
        # cost, not a data-plane signal — keep it out of the step-time
        # telemetry or it swamps the straggler EWMAs (and a post-switch
        # recompile would re-arm the very rule that caused the switch).
        self._skip_step_telemetry = True

    def init_state(self, rng) -> step_mod.TrainState:
        st = step_mod.init_state(self.model, rng, self.tcfg)
        comm = init_grad_states(self.chunnels, self.model.param_shapes())
        comm = jax.tree.map(
            lambda s: jax.numpy.zeros(s.shape, s.dtype) if hasattr(s, "shape") else s,
            comm,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        st = st._replace(comm=comm)
        # place the state on the mesh with the step's shardings
        return jax.tree.map(jax.device_put, st, self.state_sh)

    # -- telemetry ------------------------------------------------------------------
    def _dcn_bytes_per_step(self) -> int:
        """Estimated cross-pod (DCN) gradient bytes per step under the active
        transport — the byte signal the controller budgets against. Coarse on
        purpose: one all-reduce ~ one param-sized exchange per chip, scaled by
        the transport's wire format / sync cadence."""
        if "pod" not in self.mesh.axis_names or self.mesh.shape["pod"] < 2:
            return 0
        pb = self._param_bytes
        name = self.transport_name
        if name in ("compressed_int8",):
            return pb // 4
        if name == "hier_compressed":
            return pb // (4 * max(self.mesh.shape.get("data", 1), 1))
        if name == "hierarchical":
            return pb // max(self.mesh.shape.get("data", 1), 1)
        if name == "localsgd":
            sync_every = next((ch.sync_every for ch in self.chunnels
                               if hasattr(ch, "sync_every")), 4)
            return pb // max(sync_every, 1)
        return pb  # xla / psum / ring: full f32 gradients every step

    def _record_step_telemetry(self, dt: float,
                               pod_times: Optional[Callable[[int, float], Dict[str, float]]],
                               step_idx: int) -> None:
        reports = (pod_times(step_idx, dt) if pod_times is not None
                   else {f"host{h.host_id}": dt for h in self.hosts})
        self.telemetry.record_step(reports)
        self.telemetry.record_wire(self._dcn_bytes_per_step())
        if self._fleet_pub is not None:
            self._fleet_pub.maybe_publish(
                extra={"transport": self.transport_name})

    def attach_fleet(self, fleet_id: str = "trainfleet", member: Optional[str] = None,
                     *, store: Optional[KVStore] = None, period_s: float = 0.0):
        """Join the fleet signal plane: publish this job's step telemetry
        into the rendezvous KV (``repro.fleet.FleetPublisher``) so a
        ``FleetAggregator`` can fold it with other jobs' — cross-job DCN
        budgets, fleet-wide straggler views. ``reset_window=False`` because a
        local controller (``make_controller``) may also be snapshotting this
        telemetry; the published rates then cover its tick window. Defaults
        to this trainer's own rendezvous store; pass the shared one in
        multi-job deployments."""
        from repro.fleet import FleetPublisher

        self._fleet_pub = FleetPublisher(
            store or self.store, fleet_id,
            member or f"host{self.hosts[0].host_id}:{self.conn_id}",
            self.telemetry, period_s=period_s, reset_window=False)
        return self._fleet_pub

    def _controller_snapshot(self, dt: float) -> dict:
        snap = self.telemetry.snapshot()
        # What the DEFAULT (f32 every-step) transport would currently cost:
        # budget/recovery rules compare against this so switching to a lighter
        # wire format doesn't immediately un-arm the rule that caused it.
        pod_active = "pod" in self.mesh.axis_names and self.mesh.shape["pod"] >= 2
        snap["dcn_bytes_per_s_f32"] = (self._param_bytes / max(dt, 1e-9)
                                       if pod_active else 0.0)
        return snap

    # -- training loop --------------------------------------------------------------
    def run(self, state, batches: Callable[[int], dict], num_steps: int,
            *, ckpt_every: int = 0, straggler: Optional[StragglerPolicy] = None,
            inject_slow: Optional[Callable[[int], float]] = None,
            controller: Optional[ReconfigController] = None,
            pod_times: Optional[Callable[[int, float], Dict[str, float]]] = None) -> tuple:
        """Run ``num_steps``. ``pod_times(step, own_dt) -> {pod: dt}`` models
        the heartbeat plane (other hosts reporting step times); ``controller``
        (from ``make_controller``) closes the loop — it observes the telemetry
        after every step and may commit a negotiated transport transition
        between steps (the switch point of this single-data-thread plane)."""
        metrics_hist = []
        try:
            return self._run_loop(state, batches, num_steps, metrics_hist,
                                  ckpt_every, straggler, inject_slow,
                                  controller, pod_times)
        finally:
            # even on a mid-run exception, don't pin params/opt state forever
            self._live_state = None

    def _run_loop(self, state, batches, num_steps, metrics_hist, ckpt_every,
                  straggler, inject_slow, controller, pod_times) -> tuple:
        for i in range(num_steps):
            step_idx = int(state.step)
            batch = {k: jax.numpy.asarray(v) for k, v in batches(step_idx).items()}
            t0 = time.perf_counter()
            state, metrics = self.jitted(state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            if inject_slow is not None:
                extra = inject_slow(step_idx)
                if extra > 0:
                    time.sleep(extra)
                    dt += extra
            self.step_times.append(dt)
            metrics_hist.append({k: float(v) for k, v in metrics.items()})
            if ckpt_every and self.ckpt and (step_idx + 1) % ckpt_every == 0:
                self.ckpt.save(step_idx + 1, state, asynchronous=True)
            if straggler is not None:
                state = self._maybe_mitigate(state, straggler)
            if self._skip_step_telemetry:
                self._skip_step_telemetry = False  # compile step: blip, not signal
            else:
                self._record_step_telemetry(dt, pod_times, step_idx)
                if controller is not None:
                    self._live_state = state
                    controller.tick(self._controller_snapshot(dt))
                    state = self._live_state  # controller_switch may have migrated it
        if self.ckpt:
            self.ckpt.wait()
        return state, metrics_hist

    # -- straggler mitigation via reconfiguration -----------------------------------
    def _maybe_mitigate(self, state, pol: StragglerPolicy):
        if self.transport_name == pol.fallback or len(self.step_times) < 2 * pol.window:
            return state
        recent = np.median(self.step_times[-pol.window:])
        base = np.median(self.step_times[: pol.window])
        if recent > pol.slow_factor * base:
            state = self.reconfigure(state, pol.fallback)
        return state

    def reconfigure(self, state, new_transport: str):
        """Negotiated transition (2PC via rendezvous) + state migration + re-jit."""
        desc = [{"name": new_transport,
                 "caps": [{"label": f"transport:{new_transport}", "mode": "exact"}],
                 "upper": "grads", "lower": "unit", "multilateral": True}]
        epoch = rendezvous.propose_transition(
            self.store, self.conn_id, "host0", new_transport, desc)
        for h in self.hosts:  # peers vote their offer lists; the proposer
            # (host0, who initiated this transition) consents by proposing —
            # a peer that doesn't offer the target vetoes the whole switch
            ok = new_transport in h.offers or h.host_id == 0
            rendezvous.vote(self.store, self.conn_id, f"host{h.host_id}", epoch, ok)
        committed = rendezvous.try_commit(self.store, self.conn_id, epoch, timeout_s=5.0)
        if not committed:
            self.reconfig_log.append({"to": new_transport, "committed": False})
            return state
        old = self.transport_name
        self.transport_name = new_transport
        self._build_step()
        # state migration: grads/opt carry over; chunnel state re-initialized
        # for the new wire format (EF residuals cannot survive a format change)
        comm = init_grad_states(self.chunnels, self.model.param_shapes())
        comm = jax.tree.map(
            lambda s: jax.numpy.zeros(s.shape, s.dtype) if hasattr(s, "shape") else s,
            comm, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        state = state._replace(comm=comm)
        state = jax.tree.map(jax.device_put, state, self.state_sh)
        self.reconfig_log.append({"from": old, "to": new_transport, "committed": True,
                                  "at_step": int(state.step)})
        return state

    # -- closed-loop controller -------------------------------------------------------
    def controller_switch(self, target: str) -> bool:
        """Switch callback for a ReconfigController: rendezvous-negotiated
        transition + state migration + re-jit, applied to the live state."""
        assert self._live_state is not None, "controller_switch outside run()"
        before = len(self.reconfig_log)
        self._live_state = self.reconfigure(self._live_state, target)
        return (len(self.reconfig_log) > before
                and self.reconfig_log[-1]["committed"])

    def transport_candidates(self, *, include_mitigations: bool = False) -> List[Candidate]:
        """The negotiated transport option set as scoreable candidates: every
        transport ALL hosts offer (host0's preference order), each annotated
        with its chunnel's cost model so ScoredTargets can rank them. Targets
        stay the transport *names* — ``controller_switch`` turns the chosen
        name into a rendezvous-negotiated transition.

        Transports that trade gradient freshness for communication (chunnel
        ``exact_sync = False``, e.g. localsgd) are EXCLUDED by default: their
        cost models honestly win the comm-cost contest, so any scoring policy
        (``cost_aware``, a scored byte budget) would adopt them steady-state
        and silently change training semantics. Mitigation rules name them
        directly by label instead; pass ``include_mitigations=True`` only if
        the policy knowingly accepts staleness."""
        common = [t for t in self.hosts[0].offers
                  if all(t in h.offers for h in self.hosts)]
        out = []
        for t in common:
            try:
                ch = TRANSPORTS[t]()
            except (KeyError, TypeError):
                out.append(Candidate(t, CostModel(), t))
                continue
            if not include_mitigations and not getattr(ch, "exact_sync", True):
                continue
            out.append(Candidate(t, chunnel_cost(ch), t))
        return out

    def make_controller(
        self,
        *,
        policy: str = "trainer_default",
        policy_params: Optional[dict] = None,
        straggler_threshold: float = 1.5,
        recover_threshold: float = 1.15,
        dcn_budget_bytes_per_s: Optional[float] = None,
        mitigation: str = "localsgd",
        budget_target: Optional[str] = "compressed_int8",
        default: Optional[str] = None,
        hold: int = 2,
        recover_hold: Optional[int] = None,
        cooldown_s: float = 0.0,
        now: Callable[[], float] = time.monotonic,
    ) -> ReconfigController:
        """Build the controller ``run()`` ticks once per step, by
        instantiating a REGISTERED policy against this trainer's negotiated
        option set (see ``trainer_default_policy`` for the standard rules;
        pass ``policy=`` to run any other registered policy, e.g.
        ``cost_aware`` with ``policy_params={"objective": ...}``).

        The keyword knobs feed the policy's params (``policy_params`` wins on
        conflict). Whatever target a rule resolves to must appear in every
        PEER host's offers or the rendezvous vote aborts the transition (the
        proposing host consents by proposing) — policy cannot override the
        peers' negotiation."""
        params = {
            "straggler_threshold": straggler_threshold,
            "recover_threshold": recover_threshold,
            "dcn_budget_bytes_per_s": dcn_budget_bytes_per_s,
            "mitigation": mitigation,
            "budget_target": budget_target,
            "hold": hold,
            "recover_hold": recover_hold,
        }
        params.update(policy_params or {})
        ctx = PolicyContext(candidates=self.transport_candidates(),
                            default=default or self.transport_name,
                            params=params)
        rules = policy_rules(policy, ctx)
        return ReconfigController(
            rules, self.controller_switch, lambda: self.transport_name,
            cooldown_s=cooldown_s, now=now)

    # -- checkpoint/restart -----------------------------------------------------------
    def save(self, state, step: Optional[int] = None):
        assert self.ckpt is not None
        self.ckpt.save(step if step is not None else int(state.step), state)

    def restore(self, like=None):
        assert self.ckpt is not None
        like = like if like is not None else step_mod.state_shapes(self.model, self.chunnels)
        return self.ckpt.restore(like)
