"""SLO engine: declarative objectives, error budgets, burn-rate alarms.

The controller plane (``ReconfigController`` + policies) reasons over flat
signal dicts. Raw thresholds ("p95 > 5ms") are brittle: they fire on one
noisy sample and say nothing about how much unreliability the service can
still afford. This module turns objectives into *budget* arithmetic, the SRE
formulation:

  * an ``SLO`` declares an objective over ONE metric of the federated view
    (``repro.obs.federate``) — a latency quantile bound, an error ratio, or
    an availability floor. The error budget is ``1 - objective``: the
    fraction of time (or requests) allowed to be bad per budget window.
  * the ``SLOEngine`` samples the view, classifies each instant as good/bad,
    and maintains a rolling, time-weighted bad-fraction over TWO windows —
    fast (default 5s) and slow (default 60s). ``burn rate`` is the windowed
    bad-fraction divided by the budget: burn 1.0 spends exactly the budget
    over the budget window; burn 14.4 exhausts a 30-day budget in 2 days.
  * an alarm (breach) requires BOTH windows above their burn thresholds —
    the fast window gives low detection latency, the slow window keeps a
    transient spike from paging — and resolves when the fast window falls
    back under its threshold (the standard multi-window reset).

Breach/recovery are first-class events: they appear in ``events``, emit
``TRACER`` instants, trip the flight recorder (post-hoc ring dump, §10), and
are exported as ``slo.*`` keys so any policy predicate — and the
``slo_guard`` built-in — can arm a stack switch on budget burn instead of a
raw threshold.

Windowing note for short runs: window means divide by
``min(window, elapsed)`` — the fraction is over *observed* time, so a
benchmark that has only run 3s still produces a meaningful fast-window burn,
while a long-running service gets true multi-window dilution.

Lock discipline (enforced by ``repro.lint``'s blocking-under-lock rule):
``observe`` computes under ``_lock`` but fires tracer events and flight-
recorder dumps only AFTER releasing it — the recorder does file I/O and the
KV-backed view callables must never be invoked under the engine's lock.

Stdlib-only (plus sibling ``obs`` modules): importable from ``repro.obs``
without dragging in the fleet or core planes.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import (Any, Callable, Deque, Dict, List, Mapping, Optional,
                    Sequence, Tuple)

from repro.obs.flight import RECORDER, FlightRecorder
from repro.obs.trace import TRACER

__all__ = ["SLO", "SLOEngine", "latency_slo_for", "error_ratio_slo_for",
           "availability_slo_for"]

_KINDS = ("latency", "error_ratio", "availability")


@dataclass(frozen=True)
class SLO:
    """One declarative objective over one metric of a signal view.

    Args:
        name: signal namespace — the engine exports ``slo.<name>.*`` keys.
        metric: the view key to judge (e.g. ``obs.conn.rtt_p95_s`` or
            ``obs.region.edge.conn.rtt_p95_s`` from the federated view).
        objective: target good-fraction in [0, 1); the error budget is
            ``1 - objective``.
        threshold: for ``kind="latency"``: the bound the metric must stay
            under — an instant is bad iff ``value > threshold``.
        kind: ``latency`` (binary bad on threshold crossing),
            ``error_ratio`` (the metric IS the bad fraction, clamped to
            [0, 1]), or ``availability`` (bad = 1 - clamped metric).
    """

    name: str
    metric: str
    objective: float = 0.99
    threshold: Optional[float] = None
    kind: str = "latency"

    def __post_init__(self) -> None:
        if self.kind not in _KINDS:
            raise ValueError(f"unknown SLO kind {self.kind!r}; "
                             f"expected one of {_KINDS}")
        if not 0.0 <= self.objective < 1.0:
            raise ValueError(f"objective must be in [0, 1), "
                             f"got {self.objective}")
        if self.kind == "latency" and self.threshold is None:
            raise ValueError("latency SLOs need a threshold")

    @property
    def budget(self) -> float:
        """Allowed bad-fraction per budget window (never zero — a 100%%
        objective would make every burn rate infinite)."""
        return max(1e-9, 1.0 - self.objective)

    def bad_fraction(self, view: Mapping[str, Any]) -> Optional[float]:
        """Classify one view sample: 0.0 good .. 1.0 bad; None = no data."""
        v = view.get(self.metric)
        if v is None:
            return None
        try:
            v = float(v)
        except (TypeError, ValueError):
            return None
        if v != v:  # NaN: the metric exists but carries no information
            return None
        if self.kind == "latency":
            return 1.0 if v > float(self.threshold) else 0.0
        clamped = min(1.0, max(0.0, v))
        return clamped if self.kind == "error_ratio" else 1.0 - clamped


def latency_slo_for(metric: str, threshold: float, *, name: str = "latency",
                    objective: float = 0.99) -> SLO:
    return SLO(name=name, metric=metric, objective=objective,
               threshold=threshold, kind="latency")


def error_ratio_slo_for(metric: str, *, name: str = "errors",
                        objective: float = 0.999) -> SLO:
    return SLO(name=name, metric=metric, objective=objective,
               kind="error_ratio")


def availability_slo_for(metric: str, *, name: str = "availability",
                         objective: float = 0.99) -> SLO:
    return SLO(name=name, metric=metric, objective=objective,
               kind="availability")


@dataclass
class _Track:
    """Per-SLO rolling state: (t, bad) samples + budget integral."""

    samples: Deque[Tuple[float, float]] = field(default_factory=deque)
    first_t: Optional[float] = None
    last_t: Optional[float] = None
    last_bad: float = 0.0
    bad_seconds: float = 0.0     # integral of bad over the whole run
    alarm: bool = False
    breaches: int = 0
    recoveries: int = 0

    def window_mean(self, now: float, window: float) -> float:
        """Time-weighted mean of bad over [now - window, now].

        Each sample's value holds until the next sample (step function); the
        denominator is clipped to observed time so short runs still produce
        a defined fraction instead of dividing a 3s history by 60s.
        """
        if self.first_t is None:
            return 0.0
        lo = now - window
        span = min(window, max(0.0, now - self.first_t))
        if span <= 0.0:
            return self.last_bad
        total = 0.0
        pts = list(self.samples)
        for i, (t, bad) in enumerate(pts):
            t_end = pts[i + 1][0] if i + 1 < len(pts) else now
            a, b = max(t, lo), min(t_end, now)
            if b > a:
                total += bad * (b - a)
        return total / span


class SLOEngine:
    """Evaluate SLOs over a signal view; export ``slo.*`` burn-rate signals.

    Args:
        slos: the objectives to track.
        fast_window_s / slow_window_s: multi-window burn evaluation spans.
        budget_window_s: the period one full error budget covers (burn 1.0
            spends it exactly; ``budget_spent`` is the run's cumulative
            bad-time over ``budget * budget_window_s``).
        fast_burn / slow_burn: alarm thresholds per window. The defaults
            (14.4 / 6.0) are the classic page-worthy burn rates for a 30-day
            budget (2%% of budget in 1h / 5%% in 6h), kept as plain numbers
            here — what matters is fast >> slow >> 1.
        view_fn: optional view supplier; with it the engine is a
            self-contained ``SignalSource`` (``read()`` samples the view),
            without it callers push views via ``observe``.
        recorder: flight recorder tripped (``once`` per SLO) on breach.
        now: clock override for deterministic tests.
    """

    name = "slo"

    def __init__(self, slos: Sequence[SLO], *, fast_window_s: float = 5.0,
                 slow_window_s: float = 60.0,
                 budget_window_s: float = 3600.0,
                 fast_burn: float = 14.4, slow_burn: float = 6.0,
                 view_fn: Optional[Callable[[], Mapping[str, Any]]] = None,
                 recorder: Optional[FlightRecorder] = RECORDER,
                 now: Callable[[], float] = time.monotonic):
        if not slos:
            raise ValueError("SLOEngine needs at least one SLO")
        names = [s.name for s in slos]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate SLO names: {names}")
        self.slos: Tuple[SLO, ...] = tuple(slos)
        self.fast_window_s = fast_window_s
        self.slow_window_s = slow_window_s
        self.budget_window_s = budget_window_s
        self.fast_burn = fast_burn
        self.slow_burn = slow_burn
        self.view_fn = view_fn
        self.recorder = recorder
        self._now = now
        self._lock = threading.Lock()
        self._tracks: Dict[str, _Track] = {s.name: _Track() for s in slos}
        self._signals: Dict[str, Any] = {"slo.alarms": 0}
        self.events: List[dict] = []

    # -- sampling --------------------------------------------------------------
    def observe(self, view: Mapping[str, Any],
                now: Optional[float] = None) -> Dict[str, Any]:
        """Fold one view sample into every SLO's windows; return the
        ``slo.*`` signal dict. Missing/NaN metrics leave that SLO's state
        untouched (no data is not good data)."""
        now = self._now() if now is None else now
        fired: List[dict] = []       # (tracer/recorder work, done unlocked)
        with self._lock:
            horizon = max(self.slow_window_s, self.fast_window_s) * 2.0
            alarms = 0
            for slo in self.slos:
                tr = self._tracks[slo.name]
                bad = slo.bad_fraction(view)
                if bad is not None:
                    if tr.last_t is not None and now > tr.last_t:
                        # the previous sample's value held until now
                        tr.bad_seconds += tr.last_bad * (now - tr.last_t)
                    if tr.first_t is None:
                        tr.first_t = now
                    tr.samples.append((now, bad))
                    tr.last_t, tr.last_bad = now, bad
                    while (len(tr.samples) > 1
                           and tr.samples[1][0] <= now - horizon):
                        tr.samples.popleft()
                burn_fast = (tr.window_mean(now, self.fast_window_s)
                             / slo.budget)
                burn_slow = (tr.window_mean(now, self.slow_window_s)
                             / slo.budget)
                spent = tr.bad_seconds / (slo.budget * self.budget_window_s)
                if (not tr.alarm and burn_fast > self.fast_burn
                        and burn_slow > self.slow_burn):
                    tr.alarm = True
                    tr.breaches += 1
                    fired.append({"slo": slo.name, "kind": "breach", "t": now,
                                  "burn_fast": burn_fast,
                                  "burn_slow": burn_slow,
                                  "budget_spent": spent})
                elif tr.alarm and burn_fast < self.fast_burn:
                    tr.alarm = False
                    tr.recoveries += 1
                    fired.append({"slo": slo.name, "kind": "recovery",
                                  "t": now, "burn_fast": burn_fast,
                                  "burn_slow": burn_slow,
                                  "budget_spent": spent})
                alarms += tr.alarm
                p = f"slo.{slo.name}."
                self._signals[p + "bad"] = tr.last_bad
                self._signals[p + "burn_fast"] = burn_fast
                self._signals[p + "burn_slow"] = burn_slow
                self._signals[p + "alarm"] = 1.0 if tr.alarm else 0.0
                self._signals[p + "ok"] = 0.0 if tr.alarm else 1.0
                self._signals[p + "budget_spent"] = spent
                self._signals[p + "budget_remaining"] = max(0.0, 1.0 - spent)
                self._signals[p + "breaches"] = tr.breaches
            self._signals["slo.alarms"] = alarms
            self.events.extend(fired)
            out = dict(self._signals)
        # breach/recovery side effects OUTSIDE the lock: the tracer ring is
        # its own sync domain and the recorder does file I/O
        for ev in fired:
            TRACER.event(f"slo.{ev['kind']}", {k: v for k, v in ev.items()
                                               if k != "kind"})
            if ev["kind"] == "breach" and self.recorder is not None:
                self.recorder.dump(f"slo_breach_{ev['slo']}",
                                   extra=ev, once=True)
        return out

    # -- SignalSource protocol -------------------------------------------------
    def read(self, now: Optional[float] = None) -> Dict[str, Any]:
        """Latest ``slo.*`` signals; with a ``view_fn`` this re-samples the
        view first, making the engine a drop-in ``SignalSource`` for
        ``FleetAggregator.add_source`` / controller signal merges."""
        if self.view_fn is not None:
            return self.observe(self.view_fn(), now)
        with self._lock:
            return dict(self._signals)

    def signals(self) -> Dict[str, Any]:
        """Latest ``slo.*`` dict without re-sampling (peek)."""
        with self._lock:
            return dict(self._signals)

    def alarmed(self) -> List[str]:
        with self._lock:
            return [s.name for s in self.slos if self._tracks[s.name].alarm]

    # -- reporting -------------------------------------------------------------
    def report(self, now: Optional[float] = None) -> List[Dict[str, Any]]:
        """One row per SLO for dashboards/CLI: objective, budget, burns,
        alarm state, breach counts."""
        now = self._now() if now is None else now
        rows: List[Dict[str, Any]] = []
        with self._lock:
            for slo in self.slos:
                tr = self._tracks[slo.name]
                spent = tr.bad_seconds / (slo.budget * self.budget_window_s)
                rows.append({
                    "slo": slo.name, "kind": slo.kind, "metric": slo.metric,
                    "objective": slo.objective, "threshold": slo.threshold,
                    "budget": slo.budget,
                    "burn_fast": tr.window_mean(now, self.fast_window_s)
                    / slo.budget,
                    "burn_slow": tr.window_mean(now, self.slow_window_s)
                    / slo.budget,
                    "budget_spent": spent,
                    "budget_remaining": max(0.0, 1.0 - spent),
                    "alarm": tr.alarm, "breaches": tr.breaches,
                    "recoveries": tr.recoveries,
                    "samples": len(tr.samples),
                })
        return rows
