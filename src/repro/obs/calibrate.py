"""Trace-derived cost-model calibration: measured costs replace annotations.

The scorer (``repro.core.cost``) ranks negotiated options by hand-written
``CostModel`` annotations — priors the developer guessed at authoring time.
But PR 9's tracer already *measures* the real quantities on every traced
run:

  * ``chunnel.send`` batch records carry the timed transform duration and
    the batch's payload bytes before/after the transform
    (``repro.core.chunnel._FnDatapath``) — per-chunnel ``op_latency_s`` and
    ``dcn_bytes_per_byte``, measured;
  * ``wan.send`` spans carry the chunnel name and the full blocking send
    duration (window waits, retransmits) — the wire chunnel's real per-op
    latency;
  * ``reconfig.swap`` spans time the actual pause a switch inflicted, keyed
    by the NEW stack's fingerprint — the real ``switch_blip_s``.

:func:`calibrate_from_traces` folds a record list (``TRACER.collect()``, a
flight-recorder dump, a saved trace file) into a :class:`TraceCalibration`
and, with ``apply=True``, installs it through the existing
``calibrate_cost_models`` funnel (``repro.comm.chunnels``) into the scorer's
measured-override tables — closing the ROADMAP "mesh-aware cost
calibration, full loop" carry-over: annotate → trace → measure → re-score.

Robustness: medians, not means — trace durations have a heavy right tail
(GC, scheduler preemption), and a calibration that installs a tail estimate
would poison every subsequent ranking. Chunnels with fewer than
``min_samples`` records keep their annotations.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from statistics import median
from typing import Any, Dict, Iterable, List, Mapping, Optional

__all__ = ["TraceCalibration", "calibrate_from_traces"]

#: record names whose ``dur`` measures ONE data-plane op of the named chunnel
_OP_RECORDS = ("chunnel.send", "wan.send")


@dataclass
class TraceCalibration:
    """Measured cost fields extracted from one batch of trace records.

    chunnels      chunnel name -> partial ``CostModel`` field dict (only the
                  fields the trace could measure: ``op_latency_s`` always,
                  ``dcn_bytes_per_byte`` when byte sizes were recorded)
    stack_blips   ConcreteStack fingerprint -> measured switch blip seconds
    samples       chunnel name -> latency sample count behind the estimate
    """

    chunnels: Dict[str, Dict[str, float]] = field(default_factory=dict)
    stack_blips: Dict[str, float] = field(default_factory=dict)
    samples: Dict[str, int] = field(default_factory=dict)

    def __bool__(self) -> bool:
        return bool(self.chunnels or self.stack_blips)


def calibrate_from_traces(records: Iterable[Mapping[str, Any]], *,
                          min_samples: int = 3,
                          apply: bool = True) -> TraceCalibration:
    """Fold trace records into measured per-chunnel costs and stack blips.

    Args:
        records: normalized record dicts (``TRACER.collect()`` shape; a
            flight-recorder dump's ``records`` list works verbatim).
        min_samples: latency samples a chunnel needs before its annotation
            is overridden (swap blips apply from one sample — switches are
            rare and each one is a full end-to-end measurement).
        apply: install the result process-wide via ``calibrate_cost_models``
            so the next scored negotiation ranks with measured costs.
    """
    durs: Dict[str, List[float]] = {}
    bytes_in: Dict[str, int] = {}
    bytes_out: Dict[str, int] = {}
    blips: Dict[str, List[float]] = {}
    for r in records:
        attrs = r.get("attrs") or {}
        name = r.get("name")
        if name == "reconfig.swap":
            fp = attrs.get("new")
            dur = r.get("dur")
            if fp and dur:
                blips.setdefault(str(fp), []).append(float(dur))
            continue
        ch = attrs.get("chunnel")
        if not ch or name not in _OP_RECORDS:
            continue
        # batch records carry the timed transform in attrs["dur"]; spans
        # (wan.send) in the top-level "dur"
        dur = attrs.get("dur") if r.get("kind") == "batch" else r.get("dur")
        if dur is not None:
            durs.setdefault(ch, []).append(float(dur))
        bi, bo = attrs.get("bytes_in"), attrs.get("bytes_out")
        if bi and bo is not None:   # zero bytes_in = no byte information
            bytes_in[ch] = bytes_in.get(ch, 0) + int(bi)
            bytes_out[ch] = bytes_out.get(ch, 0) + int(bo)

    cal = TraceCalibration()
    for ch, samples in durs.items():
        if len(samples) < min_samples:
            continue
        fields: Dict[str, float] = {"op_latency_s": median(samples)}
        if bytes_in.get(ch):
            fields["dcn_bytes_per_byte"] = bytes_out[ch] / bytes_in[ch]
        cal.chunnels[ch] = fields
        cal.samples[ch] = len(samples)
    for fp, samples in blips.items():
        cal.stack_blips[fp] = median(samples)

    if apply and cal:
        _apply(cal)
    return cal


def _apply(cal: TraceCalibration) -> None:
    """Install through the documented funnel; the comm plane drags jax in,
    so fall back to the core tables directly where jax is unavailable."""
    try:
        from repro.comm.chunnels import calibrate_cost_models
    except Exception:  # pragma: no cover - jax-less environments
        from repro.core.cost import install_measured_costs
        install_measured_costs(chunnels=cal.chunnels,
                               stack_blips=cal.stack_blips)
        return
    calibrate_cost_models(measured=cal)
