"""Unified metrics plane: every counter family behind one ``collect()``.

The repo grew half a dozen counter surfaces (``ConnTelemetry.snapshot``,
split ``FabricCounters``, ``ReliableChannel`` retransmits, ``Reassembler``
evictions, controller decision counts, fleet aggregates), each with its
own ad-hoc dict shape. :class:`MetricsRegistry` registers *sources* —
zero-arg callables returning a flat-ish dict — under ``(family,
instance)`` and exposes one snapshot with two exporters:

* :meth:`to_prometheus` — Prometheus text exposition format
  (``repro_<family>_<metric>{instance="..."} value``). Nested one-level
  dicts become a ``key`` label; non-numeric values are skipped (they
  remain visible in the JSON exporter).
* :meth:`to_json` — the full nested snapshot, JSON-serializable.

``watch(family, obj)`` duck-types the repo's counter objects (``snapshot``
/ ``counts`` / ``stats`` / ``collect`` methods, or a dataclass-style
``__dict__`` of numbers) so call sites stay one line. Stdlib-only.
"""
from __future__ import annotations

import json
import re
import threading
from typing import Callable, Dict, List, Optional, Tuple

__all__ = ["MetricsRegistry", "parse_prometheus"]

_NAME_RE = re.compile(r"[^a-zA-Z0-9_]")

_SNAPSHOT_METHODS = ("snapshot", "counts", "stats", "collect")


def _sanitize(name: str) -> str:
    return _NAME_RE.sub("_", name)


def _esc(label: str) -> str:
    return str(label).replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


class MetricsRegistry:
    """Registry of named metric sources with Prometheus/JSON exporters."""

    def __init__(self):
        self._lock = threading.Lock()
        self._sources: Dict[Tuple[str, str], Callable[[], dict]] = {}

    # -- registration ------------------------------------------------------
    def register(self, family: str, source: Callable[[], dict],
                 instance: str = "default") -> None:
        """Register a zero-arg callable returning a dict of metrics."""
        with self._lock:
            self._sources[(family, instance)] = source

    def watch(self, family: str, obj, instance: str = "default") -> None:
        """Register a counter *object* by duck-typing its snapshot method.

        Resolution order: ``snapshot()`` / ``counts()`` / ``stats()`` /
        ``collect()``, else the object's numeric public attributes
        (covers bare counter holders like ``ReliableChannel``).
        """
        for meth in _SNAPSHOT_METHODS:
            fn = getattr(obj, meth, None)
            if callable(fn):
                self.register(family, fn, instance)
                return
        self.register(family, lambda o=obj: _numeric_attrs(o), instance)

    def watch_fields(self, family: str, obj, fields: Tuple[str, ...],
                     instance: str = "default") -> None:
        """Register an explicit attribute subset of ``obj``."""
        self.register(
            family,
            lambda o=obj, fs=fields: {f: getattr(o, f, None) for f in fs},
            instance,
        )

    def unregister(self, family: str, instance: str = "default") -> None:
        with self._lock:
            self._sources.pop((family, instance), None)

    # -- snapshot ----------------------------------------------------------
    def collect(self) -> Dict[str, Dict[str, dict]]:
        """``{family: {instance: metrics-dict}}`` — one unified snapshot.

        A failing source contributes ``{"_error": repr(exc)}`` instead of
        poisoning the whole snapshot (sources may race object teardown).
        """
        with self._lock:
            sources = list(self._sources.items())
        out: Dict[str, Dict[str, dict]] = {}
        for (family, instance), fn in sources:
            try:
                metrics = fn()
            except Exception as exc:  # lint: allow[silent-except] exporter must not die with a source
                metrics = {"_error": repr(exc)}
            if not isinstance(metrics, dict):
                metrics = {"value": metrics}
            out.setdefault(family, {})[instance] = metrics
        return out

    # -- exporters ---------------------------------------------------------
    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.collect(), indent=indent, sort_keys=True,
                          default=str)

    def to_prometheus(self) -> str:
        """Prometheus text exposition (format version 0.0.4)."""
        lines: List[str] = []
        snap = self.collect()
        for family in sorted(snap):
            for instance in sorted(snap[family]):
                metrics = snap[family][instance]
                for key in sorted(metrics):
                    val = metrics[key]
                    base = f"repro_{_sanitize(family)}_{_sanitize(key)}"
                    if isinstance(val, dict):
                        for sub in sorted(val):
                            num = _as_number(val[sub])
                            if num is None:
                                continue
                            lines.append(
                                f'{base}{{instance="{_esc(instance)}",'
                                f'key="{_esc(sub)}"}} {num!r}')
                        continue
                    num = _as_number(val)
                    if num is None:
                        continue
                    lines.append(
                        f'{base}{{instance="{_esc(instance)}"}} {num!r}')
        return "\n".join(lines) + "\n"

    def write_prometheus(self, path) -> str:
        text = self.to_prometheus()
        with open(path, "w") as f:
            f.write(text)
        return text


def _as_number(val):
    if isinstance(val, bool):
        return int(val)
    if isinstance(val, (int, float)):
        return val
    return None


def _numeric_attrs(obj) -> dict:
    out = {}
    for k, v in vars(obj).items():
        if k.startswith("_"):
            continue
        if isinstance(v, bool) or isinstance(v, (int, float)):
            out[k] = v
    return out


# labels use a greedy ``.*`` (a label VALUE may contain ``}``); the value
# charset admits inf/nan spellings in either case (repr() emits lowercase,
# canonical Prometheus writes ``+Inf``/``NaN``)
_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>.*)\})?\s+"
    r"(?P<value>[-+0-9.eEinfaINFA]+)$")

# quote-aware label pair: the value is a run of non-quote/non-backslash
# chars or backslash escapes — a comma INSIDE a quoted value no longer
# splits the pair (the old naive ``split(",")`` did)
_LABEL_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')

_UNESC = {"n": "\n", '"': '"', "\\": "\\"}


def _unescape(s: str) -> str:
    """Invert :func:`_esc` — one left-to-right scan, so a literal
    backslash-n survives as ``\\n`` text and an escaped newline comes back
    as a real newline (chained ``str.replace`` gets this wrong)."""
    if "\\" not in s:
        return s
    out: List[str] = []
    i, n = 0, len(s)
    while i < n:
        c = s[i]
        if c == "\\" and i + 1 < n:
            out.append(_UNESC.get(s[i + 1], "\\" + s[i + 1]))
            i += 2
        else:
            out.append(c)
            i += 1
    return "".join(out)


def parse_prometheus(text: str) -> List[dict]:
    """Parse exposition text back into samples; raises on malformed lines.

    Used by the CLI ``--check`` and verify.sh to assert the exporter's
    output actually parses. Label values are unescaped, so
    ``parse_prometheus(registry.to_prometheus())`` round-trips instance
    names containing quotes, backslashes, newlines, and commas exactly.
    Returns ``[{"name", "labels", "value"}]``.
    """
    samples: List[dict] = []
    for lineno, raw in enumerate(text.splitlines(), 1):
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if m is None:
            raise ValueError(f"metrics line {lineno} unparseable: {raw!r}")
        labels = {}
        if m.group("labels"):
            for k, v in _LABEL_RE.findall(m.group("labels")):
                labels[k] = _unescape(v)
        samples.append({"name": m.group("name"), "labels": labels,
                        "value": float(m.group("value"))})
    return samples
