"""The canonical traced reconfiguration run: a KV-style load-adaptive switch.

Two host agents negotiate a multilateral stack (``Fast``: latency-optimal,
``Compact``: byte-optimal), a load rule watches the client's telemetry, and
a traffic burst drives the controller through detect → score → negotiate →
2PC prepare/commit → swap on BOTH endpoints — all under one enabled tracer,
so the collected records form a single stitched trace across the wire.

This is what ``python -m repro.obs`` renders and what ``scripts/verify.sh``
asserts on; tests reuse it as the end-to-end observability fixture. Kept out
of ``repro.obs.__init__`` so the obs package root stays stdlib-only.
"""
from __future__ import annotations

import time
from typing import Optional

from repro.core.chunnel import FnChunnel, WireType
from repro.core.controller import Rule, above, conn_controller, stack_candidates
from repro.core.cost import BYTES_FIRST, LATENCY_FIRST, CostModel, ScoredTarget
from repro.core.fabric import Fabric, LinkModel
from repro.core.reconfigure import LockedConn
from repro.core.runtime import HostAgent
from repro.core.stack import Select, make_stack
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import TRACER

OBJ = WireType.of("obj")
UNIT = WireType.of("unit")

#: ops/s threshold above which the byte-optimal stack wins the tick
LOAD_THRESHOLD = 500.0


def _kv_stack():
    """Select of two multilateral wire formats sharing one capability, so
    negotiation keeps both as live reconfiguration candidates."""
    from repro.core.capability import CapabilitySet

    caps = CapabilitySet.exact("kv-wire")
    fast = FnChunnel(fn_name="Fast", upper=OBJ, lower=UNIT, caps=caps,
                     multilateral_=True,
                     cost=CostModel(op_latency_s=1e-5, dcn_bytes_per_byte=1.0,
                                    switch_blip_s=1e-4))
    compact = FnChunnel(fn_name="Compact", upper=OBJ, lower=UNIT, caps=caps,
                        multilateral_=True,
                        cost=CostModel(op_latency_s=3e-4,
                                       dcn_bytes_per_byte=0.25,
                                       switch_blip_s=1e-4))
    return make_stack(Select(fast, compact))


def run_kv_switch_scenario(*, seed: int = 7,
                           capacity: int = 8192) -> dict:
    """Run the traced KV switch end-to-end; return records + metrics.

    Enables the tracer for the duration (restoring the disabled state on
    exit), so callers get a self-contained record list no matter the
    ambient tracer state.

    Returns a dict with:
      records    normalized ``Tracer.collect()`` output for the whole run
      registry   a ``MetricsRegistry`` watching every counter family touched
      committed  whether the multilateral switch committed
      client_fp / server_fp  active fingerprints after the run (must match)
      decisions  the controller's decision log as JSON dicts
    """
    fabric = Fabric(default_link=LinkModel(), seed=seed)
    agent_a = HostAgent(fabric, "obs-a")
    agent_b = HostAgent(fabric, "obs-b")
    stack = _kv_stack()
    # give the server an objective so the offer is SCORED — the
    # negotiate.offer span then carries per-candidate utilities
    negotiator = agent_b.listen(stack)
    negotiator.objective = LATENCY_FIRST

    was_enabled = TRACER.enabled
    TRACER.enable(capacity=capacity)
    registry = MetricsRegistry()
    try:
        with TRACER.span("scenario.kv_switch", attrs={"seed": seed}):
            conn = agent_a.connect("obs-b", stack)
            handle_b = LockedConn(agent_b.accept_stack("obs-a"))
            agent_b.register_participant("kv0", handle_b, stack.find)

            ctl = conn_controller(
                conn, stack,
                [Rule("kv_load", above("ops_per_s", LOAD_THRESHOLD),
                      ScoredTarget(stack_candidates(stack), BYTES_FIRST),
                      hold=2)],
                agent=agent_a, peers=["obs-b"], conn_id="kv0",
                cooldown_s=0.0)

            # light phase: trickle below the threshold — the rule must not arm
            for _ in range(5):
                conn.send([b"k=v"])
                time.sleep(0.01)
            ctl.tick(conn.telemetry.snapshot())

            # heavy phase: burst well above the threshold with bulk values —
            # the byte term must dominate the score for Compact to win the
            # objective; hold=2 means the second armed tick fires the 2PC
            committed = False
            bulk = b"v" * 65536
            for _ in range(4):
                for _ in range(200):
                    conn.send([bulk] * 4)
                d = ctl.tick(conn.telemetry.snapshot())
                if d.committed:
                    committed = True
                    break

            with TRACER.span("scenario.drain", attrs={"msgs": 32}):
                for _ in range(32):
                    conn.send([b"k=v"])

        records = TRACER.collect()
        registry.watch("fabric", fabric.counters)
        registry.watch("conn", conn.telemetry, instance="obs-a")
        registry.watch("conn", handle_b.telemetry, instance="obs-b")
        registry.watch("controller", ctl)
        for peer, chan in agent_a._chans.items():
            registry.watch_fields("reliable_channel", chan,
                                  ("retransmits", "timeout", "retries"),
                                  instance=peer)
        return {
            "records": records,
            "registry": registry,
            "committed": committed,
            "client_fp": conn.stack.fingerprint(),
            "server_fp": handle_b.stack.fingerprint(),
            "decisions": [d.to_json() for d in ctl.decisions],
        }
    finally:
        if not was_enabled:
            TRACER.disable()
        agent_a.close()
        agent_b.close()
