"""Observability plane: causal tracing, unified metrics, flight recorder.

Everything importable from this package root is stdlib-only, so core
modules (`core/fabric.py`, `core/reconfigure.py`, ...) may import
``TRACER`` without cycles. The scenario runner (``repro.obs.scenario``),
CLI (``python -m repro.obs``), metrics federation (``repro.obs.federate``,
imports the fleet KV plane) and trace calibration (``repro.obs.calibrate``,
feeds the comm plane) import the core stack and are kept out of this root
for the same reason. The SLO engine (``repro.obs.slo``) is stdlib-only and
exported here. See docs/architecture.md §10–§11.
"""
from repro.obs.export import (
    PHASES,
    phase_durations,
    render_timeline,
    stitched_trace_ids,
    to_chrome,
    write_chrome,
)
from repro.obs.flight import RECORDER, FlightRecorder, strand_alarm
from repro.obs.metrics import MetricsRegistry, parse_prometheus
from repro.obs.slo import (
    SLO,
    SLOEngine,
    availability_slo_for,
    error_ratio_slo_for,
    latency_slo_for,
)
from repro.obs.trace import NOOP_SPAN, Span, TRACER, Tracer

__all__ = [
    "TRACER", "Tracer", "Span", "NOOP_SPAN",
    "MetricsRegistry", "parse_prometheus",
    "FlightRecorder", "RECORDER", "strand_alarm",
    "SLO", "SLOEngine", "latency_slo_for", "error_ratio_slo_for",
    "availability_slo_for",
    "to_chrome", "write_chrome", "render_timeline", "phase_durations",
    "stitched_trace_ids", "PHASES",
]
