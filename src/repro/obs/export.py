"""Exporters: Chrome ``trace_event`` JSON + ASCII switch timeline.

Input is the normalized record list from ``Tracer.collect()`` (or a
flight-recorder dump's ``"records"``). Chrome output loads in Perfetto /
``chrome://tracing``: spans become ``ph="X"`` complete events, instants
and batch records become ``ph="i"``, threads are mapped to tids with
``ph="M"`` name metadata. Timestamps are µs relative to the earliest
record. Stdlib-only.
"""
from __future__ import annotations

import json
from typing import Dict, Iterable, List, Optional, Tuple

__all__ = ["to_chrome", "write_chrome", "render_timeline", "PHASES",
           "stitched_trace_ids", "phase_durations"]

#: Canonical switch phases (detect→score→negotiate→prepare→commit→swap→
#: drain) and the span names that make them up. Order matters for the
#: timeline rendering.
PHASES: Tuple[Tuple[str, Tuple[str, ...]], ...] = (
    ("detect", ("controller.tick",)),
    ("score", ("negotiate.score",)),
    ("negotiate", ("negotiate.client", "negotiate.offer",
                   "negotiate.zero_rtt")),
    ("prepare", ("2pc.prepare", "2pc.peer.prepare")),
    ("commit", ("2pc.commit", "2pc.peer.commit", "2pc.peer.abort")),
    ("swap", ("reconfig.swap",)),
    ("drain", ("scenario.drain",)),
)


def _json_safe(val):
    try:
        json.dumps(val)
        return val
    except (TypeError, ValueError):
        return str(val)


def to_chrome(records: Iterable[dict]) -> dict:
    """Build a Chrome trace_event document from collected records."""
    records = list(records)
    if not records:
        return {"traceEvents": [], "displayTimeUnit": "ms"}
    t0 = min(r["ts"] for r in records)
    tids: Dict[str, int] = {}
    events: List[dict] = []

    def tid(thread: str) -> int:
        if thread not in tids:
            tids[thread] = len(tids) + 1
            events.append({"ph": "M", "pid": 1, "tid": tids[thread],
                           "name": "thread_name",
                           "args": {"name": thread}})
        return tids[thread]

    for r in records:
        args = {k: _json_safe(v) for k, v in (r.get("attrs") or {}).items()}
        if r.get("trace_id") is not None:
            args["trace_id"] = r["trace_id"]
            args["span_id"] = r["span_id"]
            if r.get("parent_id") is not None:
                args["parent_id"] = r["parent_id"]
        if r.get("status") not in (None, "ok"):
            args["status"] = r["status"]
        base_ts = (r["ts"] - t0) * 1e6
        if r["kind"] == "span":
            events.append({
                "ph": "X", "pid": 1, "tid": tid(r.get("thread") or "?"),
                "name": r["name"], "cat": r["name"].split(".")[0],
                "ts": base_ts, "dur": max((r.get("dur") or 0.0) * 1e6, 0.01),
                "args": args,
            })
            for ev in r.get("events") or ():
                events.append({
                    "ph": "i", "s": "t", "pid": 1,
                    "tid": tid(r.get("thread") or "?"),
                    "name": f'{r["name"]}:{ev["name"]}',
                    "cat": r["name"].split(".")[0],
                    "ts": (ev["ts"] - t0) * 1e6,
                    "args": {k: _json_safe(v)
                             for k, v in (ev.get("attrs") or {}).items()},
                })
        else:  # event / batch records render as instants
            events.append({
                "ph": "i", "s": "t", "pid": 1,
                "tid": tid(r.get("thread") or "?"),
                "name": r["name"], "cat": r["name"].split(".")[0],
                "ts": base_ts, "args": args,
            })
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome(records: Iterable[dict], path) -> dict:
    doc = to_chrome(records)
    with open(path, "w") as f:
        json.dump(doc, f)
    return doc


def stitched_trace_ids(records: Iterable[dict]) -> Dict[int, int]:
    """``{trace_id: span_count}`` over span records — the acceptance check
    asserts one trace id covers decision→negotiation→2PC→swap."""
    out: Dict[int, int] = {}
    for r in records:
        if r.get("kind") == "span" and r.get("trace_id") is not None:
            out[r["trace_id"]] = out.get(r["trace_id"], 0) + 1
    return out


def phase_durations(records: Iterable[dict]) -> Dict[str, dict]:
    """Per-phase aggregates: earliest start, wall extent, total busy, count."""
    spans = [r for r in records if r.get("kind") == "span"
             and r.get("dur") is not None]
    out: Dict[str, dict] = {}
    for phase, names in PHASES:
        sel = [s for s in spans if s["name"] in names]
        if not sel:
            continue
        start = min(s["ts"] for s in sel)
        end = max(s["ts"] + s["dur"] for s in sel)
        out[phase] = {
            "start": start,
            "extent_s": end - start,
            "busy_s": sum(s["dur"] for s in sel),
            "count": len(sel),
            "names": sorted({s["name"] for s in sel}),
        }
    return out


def render_timeline(records: Iterable[dict], width: int = 48) -> str:
    """ASCII switch timeline: one bar per phase across the trace window."""
    records = list(records)
    phases = phase_durations(records)
    if not phases:
        return "(no phase spans recorded)"
    t0 = min(p["start"] for p in phases.values())
    t1 = max(p["start"] + p["extent_s"] for p in phases.values())
    window = max(t1 - t0, 1e-9)
    traces = stitched_trace_ids(records)
    main_trace = max(traces, key=traces.get) if traces else None
    lines = [
        f"switch timeline  window={window * 1e3:.2f}ms  "
        f"trace_id={main_trace}  spans={sum(traces.values())}",
    ]
    for phase, _names in PHASES:
        p = phases.get(phase)
        if p is None:
            continue
        lo = int((p["start"] - t0) / window * width)
        ln = max(int(p["extent_s"] / window * width), 1)
        lo = min(lo, width - 1)
        ln = min(ln, width - lo)
        bar = " " * lo + "#" * ln + " " * (width - lo - ln)
        lines.append(
            f"  {phase:<9} |{bar}| {p['extent_s'] * 1e3:8.2f}ms "
            f"x{p['count']:<3} {','.join(p['names'])}")
    return "\n".join(lines)
