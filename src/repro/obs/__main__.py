"""``python -m repro.obs`` — render a reconfiguration run as a trace.

Default mode runs the canonical KV-switch scenario (repro.obs.scenario)
with tracing enabled, then:

  * writes a Chrome ``trace_event`` JSON (``--trace PATH``) loadable in
    Perfetto / chrome://tracing,
  * writes the unified metrics snapshot in Prometheus text format
    (``--metrics PATH``),
  * prints the ASCII switch timeline with per-phase durations
    (detect → score → negotiate → prepare → commit → swap → drain).

``--check`` re-parses both artifacts and asserts the acceptance
invariants: the Chrome doc is valid JSON with events, the metrics file
parses as exposition text, and ONE stitched trace id covers the
controller decision, the 2PC prepare/commit, and the swap on both
endpoints. ``--render FILE`` skips the scenario and renders a previously
written trace or flight-recorder dump instead.

Two standalone modes skip the scenario entirely (docs/architecture.md §11):

  * ``--fleet`` publishes two synthetic members through the KV obs plane
    and prints the federated dashboard — per-member rows, the merged
    ``_fleet`` row, and the per-region split.
  * ``--slo`` drives an ``SLOEngine`` through a scripted healthy → burning
    → recovered day on a fake clock and prints the error-budget report
    (burn rates, budget spent, breach/recovery counts).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.obs.export import (
    phase_durations,
    render_timeline,
    stitched_trace_ids,
    to_chrome,
    write_chrome,
)
from repro.obs.metrics import parse_prometheus

#: span names the stitched acceptance trace must contain
REQUIRED_SPANS = ("controller.tick", "2pc.prepare", "2pc.commit",
                  "reconfig.swap")


def _load_records(path: Path) -> list:
    """Records from a flight-recorder dump ({"records": [...]}) or a raw
    collect() list. Chrome trace JSON is not re-importable — point --render
    at the flight-recorder dump instead."""
    doc = json.loads(path.read_text())
    if isinstance(doc, dict):
        if "records" in doc:
            return doc["records"]
        if "traceEvents" in doc:
            raise SystemExit(
                f"{path} is a Chrome trace export; --render needs the "
                f"flight-recorder dump (flightrec_*.json) or raw records")
    if not isinstance(doc, list):
        raise SystemExit(f"{path}: unrecognized trace document")
    return doc


def check_records(records: list) -> dict:
    """Assert the stitched-trace acceptance invariants; return the summary.

    One trace id must carry the whole switch story: the controller
    decision, the 2PC prepare and commit, and a ``reconfig.swap`` on BOTH
    endpoints (coordinator + peer ⇒ at least two swap spans)."""
    traces = stitched_trace_ids(records)
    if not traces:
        raise AssertionError("no spans recorded")
    main_trace = max(traces, key=traces.get)
    names = [r["name"] for r in records
             if r.get("kind") == "span" and r.get("trace_id") == main_trace]
    missing = [n for n in REQUIRED_SPANS if n not in names]
    if missing:
        raise AssertionError(
            f"trace {main_trace} is missing spans {missing}; has {sorted(set(names))}")
    n_swaps = names.count("reconfig.swap")
    if n_swaps < 2:
        raise AssertionError(
            f"expected the swap on both endpoints in one trace; "
            f"got {n_swaps} reconfig.swap span(s)")
    return {"trace_id": main_trace, "spans": len(names), "swaps": n_swaps,
            "all_traces": traces}


def fleet_demo(*, out: "Path | None" = None) -> int:
    """--fleet: two synthetic members publish through the KV obs plane; print
    the federated dashboard (per-member, merged ``_fleet`` row, per-region)."""
    from repro.core.rendezvous import KVStore
    from repro.obs.federate import MetricsFederator, MetricsPublisher
    from repro.obs.metrics import MetricsRegistry

    store = KVStore()
    members = [("edge-1", "edge", {"ops_per_s": 300.0, "rtt_p50_s": 0.0012,
                                   "rtt_p95_s": 0.0074}),
               ("core-1", "core", {"ops_per_s": 900.0, "rtt_p50_s": 0.0003,
                                   "rtt_p95_s": 0.0009})]
    pubs = []
    for name, region, metrics in members:
        reg = MetricsRegistry()
        reg.register("conn", lambda m=metrics: dict(m), instance=f"{name}-conn")
        pub = MetricsPublisher(store, "demo-fleet", name, reg, region=region)
        pub.publish()
        pubs.append(pub)
    fed = MetricsFederator(store, "demo-fleet", ttl_s=5.0)

    view = fed.view()
    print(f"fleet demo-fleet: members={view['obs.members']} "
          f"stale={view['obs.stale_members']} "
          f"availability={view['obs.availability']:.2f}")
    print()
    print(f"  {'member':<10} {'region':<8} {'ops/s':>8} {'p50 ms':>8} "
          f"{'p95 ms':>8}")
    for (name, region, m) in members:
        print(f"  {name:<10} {region:<8} {m['ops_per_s']:>8.0f} "
              f"{m['rtt_p50_s'] * 1e3:>8.2f} {m['rtt_p95_s'] * 1e3:>8.2f}")
    merged = fed.merged()["conn"]
    print(f"  {'_fleet':<10} {'(merged)':<8} "
          f"{merged['ops_per_s']:>8.0f} "
          f"{merged['rtt_p50_s'] * 1e3:>8.2f} "
          f"{merged['rtt_p95_s'] * 1e3:>8.2f}")
    print()
    print("  per-region split (what region-scoped SLOs read):")
    for region, fams in sorted(fed.per_region().items()):
        print(f"    obs.region.{region}.conn.rtt_p95_s = "
              f"{fams['conn']['rtt_p95_s'] * 1e3:.2f} ms")
    if out is not None:
        out.parent.mkdir(parents=True, exist_ok=True)
        fed.federated_registry().write_prometheus(out)
        print(f"\nwrote {out}")
    for pub in pubs:
        pub.retire()
    return 0


def slo_demo() -> int:
    """--slo: a scripted healthy → burning → recovered day on a fake clock;
    print each phase's burn rates and the final error-budget report."""
    from repro.obs.slo import SLO, SLOEngine

    engine = SLOEngine(
        [SLO("latency", "conn.rtt_p95_s", objective=0.95, threshold=0.005),
         SLO("errors", "conn.error_ratio", objective=0.999,
             kind="error_ratio")],
        fast_window_s=5.0, slow_window_s=60.0, budget_window_s=3600.0,
        recorder=None)   # a demo must not trip the real flight recorder

    phases = [("healthy", 60, {"conn.rtt_p95_s": 0.001,
                               "conn.error_ratio": 0.0}),
              ("burning", 90, {"conn.rtt_p95_s": 0.014,
                               "conn.error_ratio": 0.02}),
              ("recovered", 120, {"conn.rtt_p95_s": 0.0012,
                                  "conn.error_ratio": 0.0})]
    t = 0.0
    print("  phase      t(s)  latency.burn_fast  latency.burn_slow  alarms")
    for label, ticks, view in phases:
        for _ in range(ticks):
            t += 1.0
            sigs = engine.observe(view, now=t)
        print(f"  {label:<9} {t:>5.0f}  "
              f"{sigs['slo.latency.burn_fast']:>17.2f}  "
              f"{sigs['slo.latency.burn_slow']:>17.2f}  "
              f"{sigs['slo.alarms']:>6}")
    print()
    print("  events:")
    for ev in engine.events:
        print(f"    t={ev['t']:>5.0f}  {ev['slo']:<8} {ev['kind']:<9} "
              f"burn_fast={ev['burn_fast']:.2f}")
    print()
    print(f"  {'slo':<8} {'objective':>9} {'spent':>7} {'remaining':>9} "
          f"{'breaches':>8} {'recoveries':>10}")
    for row in engine.report(now=t):
        print(f"  {row['slo']:<8} {row['objective']:>9.3f} "
              f"{row['budget_spent']:>7.3f} {row['budget_remaining']:>9.3f} "
              f"{row['breaches']:>8} {row['recoveries']:>10}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="Render a traced reconfiguration run "
                    "(Chrome trace + metrics + ASCII switch timeline).")
    ap.add_argument("--trace", type=Path, default=None,
                    help="write Chrome trace_event JSON here")
    ap.add_argument("--metrics", type=Path, default=None,
                    help="write the Prometheus metrics snapshot here")
    ap.add_argument("--check", action="store_true",
                    help="assert the stitched-trace + parseability invariants")
    ap.add_argument("--render", type=Path, default=None,
                    help="render an existing flight-recorder dump instead of "
                         "running the scenario")
    ap.add_argument("--width", type=int, default=48,
                    help="timeline bar width (default 48)")
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--fleet", action="store_true",
                    help="federated-dashboard demo: publish two synthetic "
                         "members over the KV obs plane and print the merged "
                         "view (skips the scenario; --metrics writes the "
                         "federated Prometheus snapshot)")
    ap.add_argument("--slo", action="store_true",
                    help="error-budget demo: drive an SLOEngine through a "
                         "scripted healthy->burning->recovered day on a fake "
                         "clock and print the burn/budget report (skips the "
                         "scenario)")
    args = ap.parse_args(argv)

    if args.fleet:
        return fleet_demo(out=args.metrics)
    if args.slo:
        return slo_demo()

    if args.render is not None:
        records = _load_records(args.render)
        registry = None
    else:
        from repro.obs.scenario import run_kv_switch_scenario

        res = run_kv_switch_scenario(seed=args.seed)
        records = res["records"]
        registry = res["registry"]
        if not res["committed"]:
            print("WARNING: the scenario's multilateral switch did not commit",
                  file=sys.stderr)
        print(f"kv-switch scenario: committed={res['committed']} "
              f"active={res['client_fp']}")

    if args.trace is not None:
        args.trace.parent.mkdir(parents=True, exist_ok=True)
        doc = write_chrome(records, args.trace)
        print(f"wrote {args.trace} ({len(doc['traceEvents'])} events)")
    if args.metrics is not None:
        if registry is None:
            print("--metrics needs the scenario run (not --render)",
                  file=sys.stderr)
            return 2
        args.metrics.parent.mkdir(parents=True, exist_ok=True)
        registry.write_prometheus(args.metrics)
        print(f"wrote {args.metrics}")

    print()
    print(render_timeline(records, width=args.width))
    print()
    for phase, p in phase_durations(records).items():
        print(f"  {phase:<9} extent={p['extent_s'] * 1e3:8.2f}ms "
              f"busy={p['busy_s'] * 1e3:8.2f}ms spans={p['count']}")

    if args.check:
        summary = check_records(records)
        if args.trace is not None:
            doc = json.loads(args.trace.read_text())
            assert doc.get("traceEvents"), "Chrome trace has no events"
        if args.metrics is not None:
            samples = parse_prometheus(args.metrics.read_text())
            assert samples, "metrics snapshot parsed to zero samples"
            print(f"check: metrics OK ({len(samples)} samples)")
        print(f"check: stitched trace OK (trace_id={summary['trace_id']}, "
              f"{summary['spans']} spans, {summary['swaps']} swaps)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
