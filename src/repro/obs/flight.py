"""Flight recorder: bounded recent-history dump on failure.

The tracer's per-thread rings (``deque(maxlen=capacity)``) *are* the
bounded history — the flight recorder is the dump trigger. Two triggers
(docs/architecture.md §10):

* :meth:`FlightRecorder.capture` — context manager wrapped around chaos
  scenario assertion blocks; an ``AssertionError`` inside dumps
  ``benchmarks/out/flightrec_<reason>.json`` and re-raises.
* :func:`strand_alarm` — called by ``HostAgent._resync_prepared`` when a
  2PC participant's resync keeps failing (a peer is prepared but cannot
  learn the verdict — the stranded-peer condition); dumps once per conn.

Dumps only happen while tracing is enabled: the recorder is an
observability feature, not an always-on side effect of running tests.
Stdlib-only.
"""
from __future__ import annotations

import glob
import json
import os
import threading
from typing import Optional, Set

from repro.obs.trace import TRACER, Tracer

__all__ = ["FlightRecorder", "RECORDER", "strand_alarm"]

_DEFAULT_OUT = os.path.join("benchmarks", "out")
_DEFAULT_KEEP = 16


class FlightRecorder:
    """Dumps the tracer's recent spans/events to a JSON file on demand.

    ``max_dumps`` (env ``REPRO_FLIGHTREC_KEEP``) caps how many
    ``flightrec_*.json`` files the out dir retains: after each write the
    oldest dumps beyond the cap are deleted, so repeated chaos runs cannot
    grow the directory unboundedly. 0 disables rotation.
    """

    def __init__(self, tracer: Optional[Tracer] = None,
                 out_dir: Optional[str] = None,
                 max_dumps: Optional[int] = None):
        self.tracer = tracer or TRACER
        self.out_dir = out_dir or os.environ.get("REPRO_FLIGHTREC_DIR",
                                                 _DEFAULT_OUT)
        if max_dumps is None:
            max_dumps = int(os.environ.get("REPRO_FLIGHTREC_KEEP",
                                           _DEFAULT_KEEP))
        self.max_dumps = max_dumps
        self._lock = threading.Lock()
        self._dumped: Set[str] = set()
        self.dumps = 0

    def dump(self, reason: str, extra: Optional[dict] = None,
             once: bool = False) -> Optional[str]:
        """Write ``flightrec_<reason>.json``; returns the path or None.

        ``once=True`` dedupes by reason (the strand alarm fires per retry
        tick; one dump per stranded conn is enough). No-op when tracing
        is disabled — there is nothing in the rings worth writing.
        """
        if not self.tracer.enabled:
            return None
        with self._lock:
            if once and reason in self._dumped:
                return None
            self._dumped.add(reason)
            self.dumps += 1
        safe = "".join(c if c.isalnum() or c in "-_." else "_"
                       for c in reason)
        os.makedirs(self.out_dir, exist_ok=True)
        path = os.path.join(self.out_dir, f"flightrec_{safe}.json")
        payload = {
            "reason": reason,
            "extra": extra or {},
            "records": self.tracer.collect(),
        }
        with open(path, "w") as f:
            json.dump(payload, f, indent=1, default=str)
        self._rotate(keep=path)
        return path

    def _rotate(self, keep: str) -> None:
        """Delete the oldest ``flightrec_*.json`` beyond ``max_dumps``.

        Ordered oldest-first by (mtime, name); the file just written is
        always retained even if a coarse filesystem clock ties every mtime.
        """
        if self.max_dumps <= 0:
            return
        dumps = glob.glob(os.path.join(self.out_dir, "flightrec_*.json"))
        if len(dumps) <= self.max_dumps:
            return
        keep_abs = os.path.abspath(keep)
        dumps.sort(key=lambda p: (os.path.getmtime(p), p))
        excess = len(dumps) - self.max_dumps
        for p in dumps:
            if excess <= 0:
                break
            if os.path.abspath(p) == keep_abs:
                continue
            try:
                os.remove(p)
                excess -= 1
            except OSError:  # pragma: no cover - raced with another writer
                pass

    def capture(self, reason: str):
        """``with RECORDER.capture("chaos_smoke"): assert ...`` — dump on
        AssertionError, then re-raise."""
        return _Capture(self, reason)


class _Capture:
    __slots__ = ("_rec", "_reason")

    def __init__(self, rec: FlightRecorder, reason: str):
        self._rec = rec
        self._reason = reason

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        if exc_type is not None and issubclass(exc_type, AssertionError):
            self._rec.dump(f"{self._reason}_assert",
                           extra={"assertion": str(exc)})
        return False


#: Process-global recorder bound to the global TRACER.
RECORDER = FlightRecorder()


def strand_alarm(conn_id: str, peer: str, failures: int) -> Optional[str]:
    """2PC stranded-peer trigger: record the event and dump once per conn."""
    TRACER.event("2pc.strand_alarm",
                 attrs={"conn_id": conn_id, "peer": peer,
                        "failures": failures, "drop_reason": "resync_stalled"})
    return RECORDER.dump(f"strand_{conn_id}",
                         extra={"conn_id": conn_id, "peer": peer,
                                "failures": failures}, once=True)
