"""Low-overhead causal tracing for the reconfiguration lifecycle.

Design constraints (docs/architecture.md §10):

* **Off-by-default cheap.** Every instrumentation site in the hot path is
  guarded by a single ``if TRACER.enabled:`` attribute read; the disabled
  path allocates nothing and is gated in ``benchmarks/bench_overhead.py``
  at <3% of a batch-send's cost. Enabled tracing must stay <10% at
  batch=64, which is why the data plane records compact tuples
  (:meth:`Tracer.record_batch`) instead of full spans.
* **Lock-free rings.** Finished records land in a per-thread
  ``deque(maxlen=...)`` reached through ``threading.local`` — appends are
  single bytecodes under the GIL, so recording never takes a lock and can
  run inside fabric/chaos critical sections without inverting lock order.
  The only lock (``_reg_lock``) guards the ring *registry* and is taken
  once per thread lifetime plus on control-plane toggles.
* **Two record tiers.** Control-plane phases (negotiation, 2PC, swaps,
  controller ticks) are full :class:`Span` objects with parentage,
  attributes, and nested events. Data-plane batches are 5-tuples
  ``(name, t, n, n_ok, extra)`` — one per *batch*, never per message
  (machine-enforced by the ``span-in-hot-loop`` lint rule).
* **Wire propagation.** ``ctx()`` returns a compact ``(trace_id,
  span_id)`` pair that rides ``ReliableChannel`` frames (``"_tc"``) and
  ``comm/wire.py`` chunk headers (``hdr["tc"]``); the receiving side
  re-parents via :meth:`Tracer.adopt`, so one trace stitches across
  endpoints and threads.

Everything here is stdlib-only so any core module may import ``TRACER``
without cycles.
"""
from __future__ import annotations

import itertools
import threading
import time
from collections import deque
from typing import Dict, Iterable, List, Optional, Tuple

__all__ = ["Span", "Tracer", "TRACER", "NOOP_SPAN"]

TraceCtx = Tuple[int, int]  # (trace_id, span_id) — the over-the-wire form

_DEFAULT_CAPACITY = 8192  # per-thread ring depth (the flight-recorder bound)

_perf = time.perf_counter  # module-global: skip the attribute walk on hot paths


class _NoopSpan:
    """Absorbs the full Span surface so call sites never branch twice.

    Falsy, so ``sp = TRACER.begin_span(...)`` followed by ``if sp:`` also
    works for manual (non-``with``) spans.
    """

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def __bool__(self):
        return False

    def set(self, **attrs):
        return self

    def event(self, name, **attrs):
        return self

    def end(self, status=None, **attrs):
        return self


NOOP_SPAN = _NoopSpan()


class Span:
    """One timed phase. Context manager or manual ``begin_span``/``end``.

    ``events`` holds ``(t, name, attrs)`` instants that stay attached to
    the span (e.g. per-peer 2PC votes, retransmits tagged ``retry=n``).
    """

    __slots__ = ("trace_id", "span_id", "parent_id", "name", "t0", "t1",
                 "attrs", "events", "status", "thread", "_tracer")

    def __init__(self, tracer: "Tracer", name: str, trace_id: int,
                 span_id: int, parent_id: Optional[int],
                 attrs: Optional[dict] = None):
        self._tracer = tracer
        self.name = name
        self.trace_id = trace_id
        self.span_id = span_id
        self.parent_id = parent_id
        self.t0 = time.perf_counter()
        self.t1: Optional[float] = None
        self.attrs = dict(attrs) if attrs else {}
        self.events: List[Tuple[float, str, dict]] = []
        self.status = "ok"
        self.thread = threading.current_thread().name

    # -- wire form ---------------------------------------------------------
    @property
    def ctx(self) -> TraceCtx:
        return (self.trace_id, self.span_id)

    # -- mutation ----------------------------------------------------------
    def set(self, **attrs) -> "Span":
        status = attrs.pop("status", None)
        if status is not None:  # mirrors end(status=...): a pre-raise
            self.status = status  # classification survives __exit__
        self.attrs.update(attrs)
        return self

    def event(self, name: str, **attrs) -> "Span":
        self.events.append((time.perf_counter(), name, attrs))
        return self

    def end(self, status: Optional[str] = None, **attrs) -> "Span":
        if self.t1 is not None:  # idempotent: double-end keeps first timing
            return self
        self.t1 = time.perf_counter()
        if status is not None:
            self.status = status
        if attrs:
            self.attrs.update(attrs)
        self._tracer._finish(self)
        return self

    # -- context-manager protocol -----------------------------------------
    def __enter__(self) -> "Span":
        self._tracer._push(self)
        return self

    def __exit__(self, exc_type, exc, tb):
        self._tracer._pop(self)
        if exc_type is not None and self.status == "ok":
            self.status = "error"
            self.attrs.setdefault("error", f"{exc_type.__name__}: {exc}")
        self.end()
        return False

    def to_dict(self) -> dict:
        return {
            "kind": "span",
            "name": self.name,
            "trace_id": self.trace_id,
            "span_id": self.span_id,
            "parent_id": self.parent_id,
            "ts": self.t0,
            "dur": (self.t1 - self.t0) if self.t1 is not None else None,
            "status": self.status,
            "thread": self.thread,
            "attrs": self.attrs,
            "events": [{"ts": t, "name": n, "attrs": a}
                       for (t, n, a) in self.events],
        }


class _RemoteParent:
    """Stack sentinel for a parent span living on another endpoint."""

    __slots__ = ("trace_id", "span_id")

    def __init__(self, tc: TraceCtx):
        self.trace_id, self.span_id = tc


class _Adopt:
    """Context manager pushing a remote trace ctx as the current parent."""

    __slots__ = ("_tracer", "_sentinel")

    def __init__(self, tracer: "Tracer", tc: Optional[TraceCtx]):
        self._tracer = tracer
        self._sentinel = _RemoteParent(tc) if tc is not None else None

    def __enter__(self):
        if self._sentinel is not None:
            self._tracer._push(self._sentinel)
        return self._sentinel

    def __exit__(self, *exc):
        if self._sentinel is not None:
            self._tracer._pop(self._sentinel)
        return False


class Tracer:
    """Process-global span/record collector. See module docstring.

    The singleton :data:`TRACER` starts disabled; ``enable()`` is the
    explicit opt-in (CLI scenario, chaos smoke, tests). All recording
    methods are safe to call from any thread at any time.
    """

    def __init__(self, capacity: int = _DEFAULT_CAPACITY):
        self.enabled = False
        self.capacity = capacity
        self._ids = itertools.count(1)  # C-level next(): no lock needed
        self._tls = threading.local()
        self._reg_lock = threading.Lock()
        # thread-id -> (thread-name, ring). Rings outlive their threads so
        # collect() still sees records from finished agent loops.
        self._rings: Dict[int, Tuple[str, deque]] = {}

    # -- control plane -----------------------------------------------------
    def enable(self, capacity: Optional[int] = None) -> None:
        with self._reg_lock:
            if capacity is not None:
                self.capacity = capacity
            self.enabled = True

    def disable(self) -> None:
        with self._reg_lock:
            self.enabled = False

    def reset(self) -> None:
        """Drop all recorded data (rings stay registered)."""
        with self._reg_lock:
            for _name, ring in self._rings.values():
                ring.clear()

    # -- ring / stack plumbing --------------------------------------------
    def _ring(self) -> deque:
        try:
            return self._tls.ring
        except AttributeError:
            ring = deque(maxlen=self.capacity)
            th = threading.current_thread()
            with self._reg_lock:
                self._rings[th.ident] = (th.name, ring)
            self._tls.ring = ring
            return ring

    def _stack(self) -> list:
        try:
            return self._tls.stack
        except AttributeError:
            stack = []
            self._tls.stack = stack
            return stack

    def _push(self, span) -> None:
        self._stack().append(span)

    def _pop(self, span) -> None:
        stack = self._stack()
        if stack and stack[-1] is span:
            stack.pop()
        elif span in stack:  # tolerate out-of-order exits
            stack.remove(span)

    def _finish(self, span: Span) -> None:
        self._ring().append(span)

    def _parent(self, ctx: Optional[TraceCtx]):
        """Resolve (trace_id, parent_span_id) for a new span/event."""
        if ctx is not None:
            return ctx[0], ctx[1]
        stack = self._stack()
        if stack:
            top = stack[-1]
            return top.trace_id, top.span_id
        return next(self._ids), None

    # -- recording API -----------------------------------------------------
    def span(self, name: str, attrs: Optional[dict] = None,
             ctx: Optional[TraceCtx] = None):
        """New span for ``with`` use; NOOP_SPAN when disabled.

        Call sites on warm paths should still guard with
        ``if TRACER.enabled:`` so the ``attrs`` dict is never built.
        """
        if not self.enabled:
            return NOOP_SPAN
        trace_id, parent_id = self._parent(ctx)
        return Span(self, name, trace_id, next(self._ids), parent_id, attrs)

    def begin_span(self, name: str, attrs: Optional[dict] = None,
                   ctx: Optional[TraceCtx] = None):
        """Manual span: caller owns ``end()``; not pushed on the stack.

        Used where the span outlives a lexical scope (e.g. a
        ``ReliableChannel`` window that retries across loop iterations and
        must keep ONE span id on every retransmitted frame).
        """
        if not self.enabled:
            return NOOP_SPAN
        trace_id, parent_id = self._parent(ctx)
        return Span(self, name, trace_id, next(self._ids), parent_id, attrs)

    def adopt(self, tc: Optional[TraceCtx]) -> _Adopt:
        """Parent subsequent spans under a ctx received over the wire."""
        return _Adopt(self, tc if self.enabled else None)

    def ctx(self) -> Optional[TraceCtx]:
        """Compact (trace_id, span_id) of the current span, for the wire."""
        if not self.enabled:
            return None
        stack = self._stack()
        if not stack:
            return None
        top = stack[-1]
        return (top.trace_id, top.span_id)

    def event(self, name: str, attrs: Optional[dict] = None,
              ctx: Optional[TraceCtx] = None) -> None:
        """Zero-duration instant (chaos faults, drops, reassembly...)."""
        if not self.enabled:
            return
        trace_id, parent_id = self._parent(ctx)
        self._ring().append({
            "kind": "event",
            "name": name,
            "trace_id": trace_id,
            "span_id": next(self._ids),
            "parent_id": parent_id,
            "ts": time.perf_counter(),
            "dur": 0.0,
            "status": "ok",
            "thread": threading.current_thread().name,
            "attrs": dict(attrs) if attrs else {},
            "events": [],
        })

    def record_batch(self, name: str, n: int, n_ok: int,
                     extra: Optional[dict] = None) -> None:
        """Fast-path batch record: one tuple append, no Span object.

        The ONLY sanctioned per-batch instrumentation for ``Datapath`` /
        fabric hot loops. Callers must pre-guard with ``TRACER.enabled``.
        The TLS ring access is inlined (no ``_ring()`` call) — this method
        sits inside the <10%-overhead budget ``bench_overhead`` gates.
        """
        try:
            ring = self._tls.ring
        except AttributeError:
            ring = self._ring()
        ring.append((name, _perf(), n, n_ok, extra))

    # -- export ------------------------------------------------------------
    def collect(self, clear: bool = False) -> List[dict]:
        """Snapshot every ring into normalized dicts, sorted by ``ts``.

        Open spans (begun, never ended) are not included — they are still
        owned by their call sites.
        """
        with self._reg_lock:
            rings = [(name, list(ring)) for name, ring in
                     self._rings.values()]
            if clear:
                for _name, ring in self._rings.values():
                    ring.clear()
        out: List[dict] = []
        for _name, entries in rings:
            for e in entries:
                if isinstance(e, Span):
                    out.append(e.to_dict())
                elif isinstance(e, dict):
                    out.append(e)
                else:  # fast-path tuple (name, t, n, n_ok, extra)
                    name, t, n, n_ok, extra = e
                    rec = {
                        "kind": "batch",
                        "name": name,
                        "trace_id": None,
                        "span_id": None,
                        "parent_id": None,
                        "ts": t,
                        "dur": 0.0,
                        "status": "ok" if n_ok == n else "partial",
                        "thread": _name,
                        "attrs": {"n": n, "n_ok": n_ok},
                        "events": [],
                    }
                    if extra:
                        rec["attrs"].update(extra)
                    out.append(rec)
        out.sort(key=lambda r: r["ts"])
        return out

    def spans(self, name: Optional[str] = None) -> List[dict]:
        """Convenience for tests: collected spans, optionally by name."""
        return [r for r in self.collect()
                if r["kind"] == "span" and (name is None or r["name"] == name)]


#: Process-global tracer. Starts disabled; ``TRACER.enable()`` opts in.
TRACER = Tracer()
